//! Monotonic wall timers and scoped accumulators used across the
//! coordinator, the cluster simulator and the bench harness.

use std::time::{Duration, Instant};

/// A simple stopwatch.
#[derive(Debug, Clone)]
pub struct Timer {
    start: Instant,
}

impl Timer {
    pub fn start() -> Timer {
        Timer { start: Instant::now() }
    }

    pub fn elapsed(&self) -> Duration {
        self.start.elapsed()
    }

    pub fn secs(&self) -> f64 {
        self.elapsed().as_secs_f64()
    }

    pub fn restart(&mut self) -> Duration {
        let e = self.start.elapsed();
        self.start = Instant::now();
        e
    }
}

/// Accumulates named durations — the profiling primitive behind the
/// coordinator-overhead numbers in EXPERIMENTS.md §Perf.
#[derive(Debug, Default, Clone)]
pub struct Accumulator {
    entries: Vec<(String, Duration, u64)>,
}

impl Accumulator {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn add(&mut self, name: &str, d: Duration) {
        if let Some(e) = self.entries.iter_mut().find(|e| e.0 == name) {
            e.1 += d;
            e.2 += 1;
        } else {
            self.entries.push((name.to_string(), d, 1));
        }
    }

    /// Time a closure under `name`.
    pub fn time<T>(&mut self, name: &str, f: impl FnOnce() -> T) -> T {
        let t = Timer::start();
        let out = f();
        self.add(name, t.elapsed());
        out
    }

    pub fn total(&self, name: &str) -> Duration {
        self.entries
            .iter()
            .find(|e| e.0 == name)
            .map(|e| e.1)
            .unwrap_or_default()
    }

    pub fn count(&self, name: &str) -> u64 {
        self.entries
            .iter()
            .find(|e| e.0 == name)
            .map(|e| e.2)
            .unwrap_or(0)
    }

    pub fn merge(&mut self, other: &Accumulator) {
        for (name, d, c) in &other.entries {
            if let Some(e) = self.entries.iter_mut().find(|e| &e.0 == name) {
                e.1 += *d;
                e.2 += *c;
            } else {
                self.entries.push((name.clone(), *d, *c));
            }
        }
    }

    /// `(name, total_seconds, calls)` rows sorted by descending total.
    pub fn rows(&self) -> Vec<(String, f64, u64)> {
        let mut rows: Vec<_> = self
            .entries
            .iter()
            .map(|(n, d, c)| (n.clone(), d.as_secs_f64(), *c))
            .collect();
        rows.sort_by(|a, b| b.1.partial_cmp(&a.1).unwrap());
        rows
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn accumulates_and_counts() {
        let mut acc = Accumulator::new();
        acc.add("a", Duration::from_millis(5));
        acc.add("a", Duration::from_millis(7));
        acc.add("b", Duration::from_millis(1));
        assert_eq!(acc.count("a"), 2);
        assert!(acc.total("a") >= Duration::from_millis(12));
        assert_eq!(acc.rows()[0].0, "a");
    }

    #[test]
    fn time_closure_runs_it() {
        let mut acc = Accumulator::new();
        let v = acc.time("work", || 21 * 2);
        assert_eq!(v, 42);
        assert_eq!(acc.count("work"), 1);
    }

    #[test]
    fn merge_combines() {
        let mut a = Accumulator::new();
        a.add("x", Duration::from_millis(1));
        let mut b = Accumulator::new();
        b.add("x", Duration::from_millis(2));
        b.add("y", Duration::from_millis(3));
        a.merge(&b);
        assert_eq!(a.count("x"), 2);
        assert_eq!(a.count("y"), 1);
    }
}
