//! Counting global allocator — the measurement side of the
//! zero-allocation superstep hot path (§Perf).
//!
//! Behind the non-default `bench-alloc` feature the crate installs
//! [`CountingAlloc`] as the global allocator (see `lib.rs`): every
//! `alloc`/`alloc_zeroed`/`realloc` bumps a process-wide counter, so the
//! perf harness and the allocation-regression test can assert that
//! steady-state driver iterations allocate *nothing*.  Without the
//! feature the probes return `None` and the default system allocator is
//! untouched — the counting wrapper never rides along in fidelity runs.

/// Whether allocation counting is compiled in.
pub fn counting_enabled() -> bool {
    cfg!(feature = "bench-alloc")
}

/// Total heap allocations since process start (`None` without the
/// `bench-alloc` feature).  Take a before/after difference around the
/// region of interest.
pub fn alloc_count() -> Option<u64> {
    #[cfg(feature = "bench-alloc")]
    {
        Some(counting::ALLOCS.load(std::sync::atomic::Ordering::Relaxed))
    }
    #[cfg(not(feature = "bench-alloc"))]
    {
        None
    }
}

/// Total heap bytes requested since process start (`None` without the
/// `bench-alloc` feature).
pub fn alloc_bytes() -> Option<u64> {
    #[cfg(feature = "bench-alloc")]
    {
        Some(counting::BYTES.load(std::sync::atomic::Ordering::Relaxed))
    }
    #[cfg(not(feature = "bench-alloc"))]
    {
        None
    }
}

#[cfg(feature = "bench-alloc")]
pub use counting::CountingAlloc;

#[cfg(feature = "bench-alloc")]
mod counting {
    use std::alloc::{GlobalAlloc, Layout, System};
    use std::sync::atomic::{AtomicU64, Ordering};

    pub(super) static ALLOCS: AtomicU64 = AtomicU64::new(0);
    pub(super) static BYTES: AtomicU64 = AtomicU64::new(0);

    /// System allocator wrapper that counts allocation calls and bytes.
    /// Frees are deliberately not tracked: the hot-path contract is "no
    /// allocator traffic at steady state", and every alloc/realloc is
    /// traffic whether or not it is later freed.
    pub struct CountingAlloc;

    unsafe impl GlobalAlloc for CountingAlloc {
        unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
            ALLOCS.fetch_add(1, Ordering::Relaxed);
            BYTES.fetch_add(layout.size() as u64, Ordering::Relaxed);
            System.alloc(layout)
        }

        unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
            ALLOCS.fetch_add(1, Ordering::Relaxed);
            BYTES.fetch_add(layout.size() as u64, Ordering::Relaxed);
            System.alloc_zeroed(layout)
        }

        unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
            ALLOCS.fetch_add(1, Ordering::Relaxed);
            BYTES.fetch_add(new_size as u64, Ordering::Relaxed);
            System.realloc(ptr, layout, new_size)
        }

        unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
            System.dealloc(ptr, layout)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn probes_agree_with_feature_flag() {
        assert_eq!(alloc_count().is_some(), counting_enabled());
        assert_eq!(alloc_bytes().is_some(), counting_enabled());
    }

    #[cfg(feature = "bench-alloc")]
    #[test]
    fn counter_observes_allocations() {
        let before = alloc_count().unwrap();
        let v: Vec<u64> = Vec::with_capacity(1024);
        std::hint::black_box(&v);
        let after = alloc_count().unwrap();
        assert!(after > before, "allocation not counted: {before} -> {after}");
    }
}
