//! A strict, dependency-free JSON parser and serializer.
//!
//! Parses the artifact manifest written by `python/compile/aot.py`, the
//! experiment config files under `configs/`, and serializes the experiment
//! reports the bench harness emits.  Supports the full JSON grammar except
//! `\u` surrogate pairs outside the BMP are passed through unvalidated.

use std::collections::BTreeMap;
use std::fmt;

/// A JSON value. Object keys are ordered (BTreeMap) so serialization is
/// deterministic — reports diff cleanly between runs.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn parse(text: &str) -> Result<Json, JsonError> {
        let mut p = Parser { b: text.as_bytes(), pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.b.len() {
            return Err(p.err("trailing characters"));
        }
        Ok(v)
    }

    // ------------------------------------------------------------ accessors

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        match self {
            Json::Num(n) if *n >= 0.0 && n.fract() == 0.0 => Some(*n as usize),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    // --------------------------------------------------------- construction

    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn arr<I: IntoIterator<Item = Json>>(items: I) -> Json {
        Json::Arr(items.into_iter().collect())
    }

    pub fn num(n: f64) -> Json {
        Json::Num(n)
    }

    pub fn str(s: &str) -> Json {
        Json::Str(s.to_string())
    }
}

impl From<f64> for Json {
    fn from(v: f64) -> Json {
        Json::Num(v)
    }
}

impl From<usize> for Json {
    fn from(v: usize) -> Json {
        Json::Num(v as f64)
    }
}

impl From<&str> for Json {
    fn from(v: &str) -> Json {
        Json::Str(v.to_string())
    }
}

#[derive(Debug)]
pub struct JsonError {
    pub msg: String,
    pub pos: usize,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for JsonError {}

struct Parser<'a> {
    b: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { msg: msg.to_string(), pos: self.pos }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let c = self.peek()?;
        self.pos += 1;
        Some(c)
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, c: u8) -> Result<(), JsonError> {
        if self.bump() == Some(c) {
            Ok(())
        } else {
            self.pos = self.pos.saturating_sub(1);
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.pos..].starts_with(s.as_bytes()) {
            self.pos += s.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{s}'")))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek().ok_or_else(|| self.err("unexpected end"))? {
            b'n' => self.lit("null", Json::Null),
            b't' => self.lit("true", Json::Bool(true)),
            b'f' => self.lit("false", Json::Bool(false)),
            b'"' => Ok(Json::Str(self.string()?)),
            b'[' => self.array(),
            b'{' => self.object(),
            b'-' | b'0'..=b'9' => self.number(),
            c => Err(self.err(&format!("unexpected '{}'", c as char))),
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            self.skip_ws();
            items.push(self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Json::Arr(items)),
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            map.insert(key, self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Json::Obj(map)),
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.bump().ok_or_else(|| self.err("unterminated string"))? {
                b'"' => return Ok(out),
                b'\\' => match self.bump().ok_or_else(|| self.err("bad escape"))? {
                    b'"' => out.push('"'),
                    b'\\' => out.push('\\'),
                    b'/' => out.push('/'),
                    b'b' => out.push('\u{8}'),
                    b'f' => out.push('\u{c}'),
                    b'n' => out.push('\n'),
                    b'r' => out.push('\r'),
                    b't' => out.push('\t'),
                    b'u' => {
                        let mut code = 0u32;
                        for _ in 0..4 {
                            let c = self.bump()
                                .ok_or_else(|| self.err("bad \\u"))?;
                            code = code * 16
                                + (c as char).to_digit(16)
                                    .ok_or_else(|| self.err("bad hex"))?;
                        }
                        out.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                    }
                    _ => return Err(self.err("bad escape char")),
                },
                c if c < 0x20 => return Err(self.err("control char in string")),
                c => {
                    // Re-assemble UTF-8 multibyte sequences.
                    if c < 0x80 {
                        out.push(c as char);
                    } else {
                        let start = self.pos - 1;
                        let len = if c >= 0xF0 { 4 } else if c >= 0xE0 { 3 } else { 2 };
                        if start + len > self.b.len() {
                            return Err(self.err("truncated utf-8"));
                        }
                        let s = std::str::from_utf8(&self.b[start..start + len])
                            .map_err(|_| self.err("invalid utf-8"))?;
                        out.push_str(s);
                        self.pos = start + len;
                    }
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(b'0'..=b'9')) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(b'0'..=b'9')) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(b'0'..=b'9')) {
                self.pos += 1;
            }
        }
        let s = std::str::from_utf8(&self.b[start..self.pos]).unwrap();
        s.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("invalid number"))
    }
}

// ---------------------------------------------------------------- serialize

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Json::Null => write!(f, "null"),
            Json::Bool(b) => write!(f, "{b}"),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    write!(f, "{}", *n as i64)
                } else {
                    write!(f, "{n}")
                }
            }
            Json::Str(s) => write_escaped(f, s),
            Json::Arr(a) => {
                write!(f, "[")?;
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{v}")?;
                }
                write!(f, "]")
            }
            Json::Obj(m) => {
                write!(f, "{{")?;
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write_escaped(f, k)?;
                    write!(f, ":{v}")?;
                }
                write!(f, "}}")
            }
        }
    }
}

fn write_escaped(f: &mut fmt::Formatter<'_>, s: &str) -> fmt::Result {
    write!(f, "\"")?;
    for c in s.chars() {
        match c {
            '"' => write!(f, "\\\"")?,
            '\\' => write!(f, "\\\\")?,
            '\n' => write!(f, "\\n")?,
            '\r' => write!(f, "\\r")?,
            '\t' => write!(f, "\\t")?,
            c if (c as u32) < 0x20 => write!(f, "\\u{:04x}", c as u32)?,
            c => write!(f, "{c}")?,
        }
    }
    write!(f, "\"")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("-1.5e2").unwrap(), Json::Num(-150.0));
        assert_eq!(Json::parse("\"a\\nb\"").unwrap(), Json::Str("a\nb".into()));
    }

    #[test]
    fn parses_nested() {
        let v = Json::parse(r#"{"a":[1,2,{"b":null}],"c":"x"}"#).unwrap();
        assert_eq!(v.get("c").unwrap().as_str(), Some("x"));
        assert_eq!(v.get("a").unwrap().as_arr().unwrap().len(), 3);
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse("'single'").is_err());
        assert!(Json::parse("{\"a\" 1}").is_err());
    }

    #[test]
    fn roundtrips() {
        let cases = [
            r#"{"a":[1,2.5,true,null,"s"],"b":{"c":-3}}"#,
            r#"[]"#,
            r#"{}"#,
            r#""Ab""#,
        ];
        for c in cases {
            let v = Json::parse(c).unwrap();
            let v2 = Json::parse(&v.to_string()).unwrap();
            assert_eq!(v, v2, "{c}");
        }
    }

    #[test]
    fn roundtrips_unicode() {
        let v = Json::parse("\"héllo ✓\"").unwrap();
        assert_eq!(v.as_str(), Some("héllo ✓"));
        assert_eq!(Json::parse(&v.to_string()).unwrap(), v);
    }

    #[test]
    fn parses_manifest_shape() {
        let text = r#"{"tile":128,"artifacts":[
            {"op":"margins","n_cap":128,"m_cap":128,
             "file":"margins_128x128.hlo.txt",
             "inputs":[{"dtype":"f32","shape":[128,128]}],
             "outputs":[{"dtype":"f32","shape":[128]}]}]}"#;
        let v = Json::parse(text).unwrap();
        assert_eq!(v.get("tile").unwrap().as_usize(), Some(128));
        let arts = v.get("artifacts").unwrap().as_arr().unwrap();
        assert_eq!(arts[0].get("op").unwrap().as_str(), Some("margins"));
        let shape = arts[0].get("inputs").unwrap().as_arr().unwrap()[0]
            .get("shape").unwrap().as_arr().unwrap();
        assert_eq!(shape[1].as_usize(), Some(128));
    }
}
