//! Minimal leveled stderr logger.
//!
//! `DDOPT_LOG=debug|info|warn|error` selects the level (default `info`).
//! A present-but-unrecognized value is *named and warned about* instead
//! of silently falling back — consistent with the strict-parse
//! convention for the `DDOPT_DIST_*` knobs, softened to a warning
//! because a typo'd log level should not kill a run.  The macros route
//! through a process-global level so hot paths can guard with a cheap
//! atomic load, and every line funnels through one locked writer that
//! stamps the elapsed time and level tag.

use std::sync::atomic::{AtomicU8, Ordering};

pub const ERROR: u8 = 0;
pub const WARN: u8 = 1;
pub const INFO: u8 = 2;
pub const DEBUG: u8 = 3;

static LEVEL: AtomicU8 = AtomicU8::new(u8::MAX);

fn init_level() -> u8 {
    use std::env::VarError;
    let (lvl, complaint) = match std::env::var("DDOPT_LOG") {
        Err(VarError::NotPresent) => (INFO, None),
        Err(VarError::NotUnicode(v)) => (INFO, Some(format!("{v:?}"))),
        Ok(v) => match v.trim() {
            "error" => (ERROR, None),
            "warn" => (WARN, None),
            "info" | "" => (INFO, None),
            "debug" => (DEBUG, None),
            _ => (INFO, Some(format!("{v:?}"))),
        },
    };
    // store before warning: the warn below routes back through
    // `level()`, which must see the resolved level, not the sentinel
    LEVEL.store(lvl, Ordering::Relaxed);
    if let Some(bad) = complaint {
        crate::warnln!(
            "unrecognized DDOPT_LOG={bad}: want error|warn|info|debug (running at info)"
        );
    }
    lvl
}

#[inline]
pub fn level() -> u8 {
    let l = LEVEL.load(Ordering::Relaxed);
    if l == u8::MAX {
        init_level()
    } else {
        l
    }
}

/// Override the level programmatically (tests, `--quiet`).
pub fn set_level(l: u8) {
    LEVEL.store(l, Ordering::Relaxed);
}

/// The single sink every log line funnels through: one locked stderr
/// write per line (threads never interleave mid-line), stamped with
/// seconds since the process's first observability tick and the level
/// tag.
pub fn log(lvl: u8, tag: &str, msg: std::fmt::Arguments<'_>) {
    if lvl <= level() {
        use std::io::Write;
        let secs = crate::obs::now_ns() as f64 / 1e9;
        let stderr = std::io::stderr();
        let mut w = stderr.lock();
        let _ = writeln!(w, "[{secs:8.3} {tag}] {msg}");
    }
}

#[macro_export]
macro_rules! info {
    ($($arg:tt)*) => {
        $crate::util::logging::log($crate::util::logging::INFO, "info",
                                   format_args!($($arg)*))
    };
}

#[macro_export]
macro_rules! warnln {
    ($($arg:tt)*) => {
        $crate::util::logging::log($crate::util::logging::WARN, "warn",
                                   format_args!($($arg)*))
    };
}

#[macro_export]
macro_rules! debugln {
    ($($arg:tt)*) => {
        $crate::util::logging::log($crate::util::logging::DEBUG, "debug",
                                   format_args!($($arg)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    // LEVEL is process-global: tests that touch it serialize here
    static TEST_LOCK: std::sync::Mutex<()> = std::sync::Mutex::new(());

    #[test]
    fn set_level_wins() {
        let _g = TEST_LOCK.lock().unwrap();
        set_level(ERROR);
        assert_eq!(level(), ERROR);
        set_level(INFO);
        assert_eq!(level(), INFO);
    }

    #[test]
    fn env_levels_parse_and_bad_values_fall_back_to_info() {
        let _g = TEST_LOCK.lock().unwrap();
        // one test covers every env case: LEVEL is process-global, so
        // splitting these into parallel #[test]s would race
        for (val, want) in [
            ("error", ERROR),
            ("warn", WARN),
            ("info", INFO),
            ("debug", DEBUG),
            ("verbose", INFO), // unrecognized: warned, falls back
            ("  debug  ", DEBUG),
        ] {
            std::env::set_var("DDOPT_LOG", val);
            LEVEL.store(u8::MAX, Ordering::Relaxed);
            assert_eq!(level(), want, "DDOPT_LOG={val:?}");
        }
        std::env::remove_var("DDOPT_LOG");
        LEVEL.store(u8::MAX, Ordering::Relaxed);
        assert_eq!(level(), INFO);
    }
}
