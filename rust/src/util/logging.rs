//! Minimal leveled stderr logger.
//!
//! `DDOPT_LOG=debug|info|warn|error` selects the level (default `info`).
//! The macros route through a process-global level so hot paths can guard
//! with a cheap atomic load.

use std::sync::atomic::{AtomicU8, Ordering};

pub const ERROR: u8 = 0;
pub const WARN: u8 = 1;
pub const INFO: u8 = 2;
pub const DEBUG: u8 = 3;

static LEVEL: AtomicU8 = AtomicU8::new(u8::MAX);

fn init_level() -> u8 {
    let lvl = match std::env::var("DDOPT_LOG").as_deref() {
        Ok("error") => ERROR,
        Ok("warn") => WARN,
        Ok("debug") => DEBUG,
        _ => INFO,
    };
    LEVEL.store(lvl, Ordering::Relaxed);
    lvl
}

#[inline]
pub fn level() -> u8 {
    let l = LEVEL.load(Ordering::Relaxed);
    if l == u8::MAX {
        init_level()
    } else {
        l
    }
}

/// Override the level programmatically (tests, `--quiet`).
pub fn set_level(l: u8) {
    LEVEL.store(l, Ordering::Relaxed);
}

pub fn log(lvl: u8, tag: &str, msg: std::fmt::Arguments<'_>) {
    if lvl <= level() {
        eprintln!("[{tag}] {msg}");
    }
}

#[macro_export]
macro_rules! info {
    ($($arg:tt)*) => {
        $crate::util::logging::log($crate::util::logging::INFO, "info",
                                   format_args!($($arg)*))
    };
}

#[macro_export]
macro_rules! warnln {
    ($($arg:tt)*) => {
        $crate::util::logging::log($crate::util::logging::WARN, "warn",
                                   format_args!($($arg)*))
    };
}

#[macro_export]
macro_rules! debugln {
    ($($arg:tt)*) => {
        $crate::util::logging::log($crate::util::logging::DEBUG, "debug",
                                   format_args!($($arg)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn set_level_wins() {
        set_level(ERROR);
        assert_eq!(level(), ERROR);
        set_level(INFO);
        assert_eq!(level(), INFO);
    }
}
