//! Declarative CLI flag parsing (no `clap` in the offline environment).
//!
//! ```no_run
//! use ddopt::util::cli::Args;
//! let mut args = Args::from_env();
//! let p: usize = args.flag("p").unwrap_or(4);
//! let method = args.flag_str("method").unwrap_or_else(|| "radisa".into());
//! args.finish().unwrap(); // errors on unknown flags
//! ```

use std::collections::BTreeMap;
use std::str::FromStr;

/// Parsed `--key value` / `--key=value` / `--switch` command-line arguments.
#[derive(Debug, Default)]
pub struct Args {
    /// Positional arguments (non-flag tokens).
    pub positional: Vec<String>,
    flags: BTreeMap<String, String>,
    seen: std::cell::RefCell<Vec<String>>,
}

impl Args {
    pub fn from_env() -> Args {
        Self::parse(std::env::args().skip(1))
    }

    pub fn parse<I: IntoIterator<Item = String>>(items: I) -> Args {
        let mut out = Args::default();
        let mut it = items.into_iter().peekable();
        while let Some(tok) = it.next() {
            if let Some(body) = tok.strip_prefix("--") {
                if let Some((k, v)) = body.split_once('=') {
                    out.flags.insert(k.to_string(), v.to_string());
                } else if it
                    .peek()
                    .map(|n| !n.starts_with("--"))
                    .unwrap_or(false)
                {
                    let v = it.next().unwrap();
                    out.flags.insert(body.to_string(), v);
                } else {
                    out.flags.insert(body.to_string(), "true".to_string());
                }
            } else {
                out.positional.push(tok);
            }
        }
        out
    }

    /// Typed flag lookup; records the key as consumed.
    pub fn flag<T: FromStr>(&self, key: &str) -> Option<T> {
        self.seen.borrow_mut().push(key.to_string());
        self.flags.get(key).and_then(|v| v.parse().ok())
    }

    pub fn flag_str(&self, key: &str) -> Option<String> {
        self.seen.borrow_mut().push(key.to_string());
        self.flags.get(key).cloned()
    }

    /// Boolean switch: present (with no value or `=true`) means true.
    pub fn switch(&self, key: &str) -> bool {
        self.seen.borrow_mut().push(key.to_string());
        matches!(self.flags.get(key).map(|s| s.as_str()), Some("true") | Some("1"))
    }

    /// Comma-separated list flag.
    pub fn flag_list(&self, key: &str) -> Option<Vec<String>> {
        self.flag_str(key)
            .map(|v| v.split(',').map(|s| s.trim().to_string()).collect())
    }

    /// Error if any provided flag was never consumed — catches typos.
    pub fn finish(&self) -> Result<(), String> {
        let seen = self.seen.borrow();
        let unknown: Vec<&String> = self
            .flags
            .keys()
            .filter(|k| !seen.contains(k))
            .collect();
        if unknown.is_empty() {
            Ok(())
        } else {
            Err(format!("unknown flags: {unknown:?}"))
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn args(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(String::from))
    }

    #[test]
    fn parses_forms() {
        let a = args("exp fig3 --p 4 --q=2 --verbose --lam 1e-3");
        assert_eq!(a.positional, vec!["exp", "fig3"]);
        assert_eq!(a.flag::<usize>("p"), Some(4));
        assert_eq!(a.flag::<usize>("q"), Some(2));
        assert!(a.switch("verbose"));
        assert_eq!(a.flag::<f64>("lam"), Some(1e-3));
        assert!(a.finish().is_ok());
    }

    #[test]
    fn missing_flag_is_none() {
        let a = args("run");
        assert_eq!(a.flag::<usize>("p"), None);
        assert!(!a.switch("verbose"));
    }

    #[test]
    fn unknown_flag_detected() {
        let a = args("--tyop 3");
        let _ = a.flag::<usize>("typo");
        assert!(a.finish().is_err());
    }

    #[test]
    fn list_flag() {
        let a = args("--methods radisa,d3ca, admm");
        // the value token is "radisa,d3ca," plus trailing "admm" is
        // positional — lists must be one token; items are trimmed
        let a2 = args("--methods radisa,d3ca,admm");
        assert_eq!(
            a2.flag_list("methods").unwrap(),
            vec!["radisa", "d3ca", "admm"]
        );
        assert_eq!(a.flag_list("methods").unwrap().len(), 3); // "", trimmed
    }

    #[test]
    fn negative_number_values() {
        let a = args("--gamma -0.5");
        // "-0.5" does not start with "--" so it binds as the value.
        assert_eq!(a.flag::<f64>("gamma"), Some(-0.5));
    }
}
