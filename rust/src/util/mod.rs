//! Infrastructure substrates.
//!
//! The offline build environment only vendors the `xla` crate's dependency
//! closure, so the usual ecosystem crates (serde, clap, rand, criterion,
//! proptest, env_logger) are unavailable; this module provides the small,
//! focused replacements the rest of the system is built on:
//!
//! * [`rng`] — SplitMix64 / xoshiro256++ PRNGs with per-(seed, partition,
//!   iteration) sub-stream derivation; every stochastic component in the
//!   repo draws from these, making runs bit-reproducible.
//! * [`alloc`] — counting global allocator (behind `bench-alloc`) that
//!   measures the zero-allocation superstep contract.
//! * [`json`] — a strict JSON parser/serializer (artifact manifest, configs,
//!   experiment reports).
//! * [`bytes`] — little-endian binary codec primitives shared by the
//!   partition-block serializer and the distributed wire protocol.
//! * [`cli`] — declarative flag parsing for the `ddopt` binary and examples.
//! * [`logging`] — leveled stderr logger.
//! * [`timer`] — monotonic wall timers and [`stats`] summaries used by the
//!   bench harness (`benchkit` role).

pub mod alloc;
pub mod bytes;
pub mod cli;
pub mod json;
pub mod logging;
pub mod rng;
pub mod stats;
pub mod timer;
