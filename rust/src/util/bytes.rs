//! Little-endian binary codec primitives — the shared framing vocabulary
//! of the partition-block serializer ([`crate::data::Partitioned`] ser/de)
//! and the distributed wire protocol ([`crate::cluster::dist::wire`]).
//!
//! Everything is explicit little-endian, length-prefixed, and
//! allocation-conscious: writers append to a caller-owned `Vec<u8>` (so a
//! frame is built in one buffer and written with one syscall), readers
//! are a cursor over a borrowed slice and fail with a descriptive error
//! instead of panicking on truncated input.  `f32`/`f64` round-trip by
//! raw bit pattern, which is what makes dist-vs-sim runs bit-identical.

use anyhow::{bail, Result};

// ----------------------------------------------------------------- write

pub fn put_u8(buf: &mut Vec<u8>, v: u8) {
    buf.push(v);
}

pub fn put_u32(buf: &mut Vec<u8>, v: u32) {
    buf.extend_from_slice(&v.to_le_bytes());
}

pub fn put_u64(buf: &mut Vec<u8>, v: u64) {
    buf.extend_from_slice(&v.to_le_bytes());
}

/// usize as u64 (stable across 32/64-bit hosts).
pub fn put_usize(buf: &mut Vec<u8>, v: usize) {
    put_u64(buf, v as u64);
}

pub fn put_f32(buf: &mut Vec<u8>, v: f32) {
    buf.extend_from_slice(&v.to_le_bytes());
}

pub fn put_f64(buf: &mut Vec<u8>, v: f64) {
    buf.extend_from_slice(&v.to_le_bytes());
}

/// u32 length prefix + UTF-8 bytes.
pub fn put_str(buf: &mut Vec<u8>, s: &str) {
    put_u32(buf, s.len() as u32);
    buf.extend_from_slice(s.as_bytes());
}

/// u64 count prefix + raw little-endian f32 payload.
pub fn put_f32s(buf: &mut Vec<u8>, v: &[f32]) {
    put_u64(buf, v.len() as u64);
    buf.reserve(v.len() * 4);
    for &x in v {
        buf.extend_from_slice(&x.to_le_bytes());
    }
}

/// u64 count prefix + raw little-endian f64 payload.
pub fn put_f64s(buf: &mut Vec<u8>, v: &[f64]) {
    put_u64(buf, v.len() as u64);
    buf.reserve(v.len() * 8);
    for &x in v {
        buf.extend_from_slice(&x.to_le_bytes());
    }
}

/// u64 count prefix + raw little-endian i32 payload.
pub fn put_i32s(buf: &mut Vec<u8>, v: &[i32]) {
    put_u64(buf, v.len() as u64);
    buf.reserve(v.len() * 4);
    for &x in v {
        buf.extend_from_slice(&x.to_le_bytes());
    }
}

/// u64 count prefix + raw little-endian u32 payload.
pub fn put_u32s(buf: &mut Vec<u8>, v: &[u32]) {
    put_u64(buf, v.len() as u64);
    buf.reserve(v.len() * 4);
    for &x in v {
        buf.extend_from_slice(&x.to_le_bytes());
    }
}

/// u64 count prefix + each usize as u64.
pub fn put_usizes(buf: &mut Vec<u8>, v: &[usize]) {
    put_u64(buf, v.len() as u64);
    buf.reserve(v.len() * 8);
    for &x in v {
        buf.extend_from_slice(&(x as u64).to_le_bytes());
    }
}

/// u64 count prefix + (usize, usize) pairs as u64 pairs.
pub fn put_pairs(buf: &mut Vec<u8>, v: &[(usize, usize)]) {
    put_u64(buf, v.len() as u64);
    buf.reserve(v.len() * 16);
    for &(a, b) in v {
        buf.extend_from_slice(&(a as u64).to_le_bytes());
        buf.extend_from_slice(&(b as u64).to_le_bytes());
    }
}

// ------------------------------------------------------------------ read

/// Cursor over a borrowed byte slice; every getter checks bounds.
pub struct ByteReader<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> ByteReader<'a> {
    pub fn new(buf: &'a [u8]) -> ByteReader<'a> {
        ByteReader { buf, pos: 0 }
    }

    pub fn remaining(&self) -> usize {
        self.buf.len() - self.pos
    }

    pub fn is_empty(&self) -> bool {
        self.remaining() == 0
    }

    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        if self.remaining() < n {
            bail!(
                "truncated frame: wanted {n} bytes at offset {}, {} left",
                self.pos,
                self.remaining()
            );
        }
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    pub fn u8(&mut self) -> Result<u8> {
        Ok(self.take(1)?[0])
    }

    pub fn u32(&mut self) -> Result<u32> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    pub fn u64(&mut self) -> Result<u64> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    pub fn usize(&mut self) -> Result<usize> {
        Ok(self.u64()? as usize)
    }

    pub fn f32(&mut self) -> Result<f32> {
        Ok(f32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    pub fn f64(&mut self) -> Result<f64> {
        Ok(f64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    pub fn str(&mut self) -> Result<String> {
        let n = self.u32()? as usize;
        Ok(String::from_utf8_lossy(self.take(n)?).into_owned())
    }

    /// Element count of a prefixed array, bounds-checked against the
    /// remaining bytes so a corrupt prefix cannot trigger a huge alloc.
    fn count(&mut self, elem_bytes: usize) -> Result<usize> {
        let n = self.u64()? as usize;
        let over = n
            .checked_mul(elem_bytes)
            .map(|b| b > self.remaining())
            .unwrap_or(true);
        if over {
            bail!(
                "corrupt array prefix: {n} elements exceeds {} remaining bytes",
                self.remaining()
            );
        }
        Ok(n)
    }

    pub fn f32s(&mut self) -> Result<Vec<f32>> {
        let mut out = Vec::new();
        self.f32s_into(&mut out)?;
        Ok(out)
    }

    /// Decode a prefixed f32 array into a reusable buffer — one bounds
    /// check for the whole array, then a bulk chunked copy (this is the
    /// per-superstep transport hot path).
    pub fn f32s_into(&mut self, out: &mut Vec<f32>) -> Result<()> {
        let n = self.count(4)?;
        let raw = self.take(4 * n)?;
        out.clear();
        out.reserve(n);
        out.extend(
            raw.chunks_exact(4)
                .map(|c| f32::from_le_bytes(c.try_into().unwrap())),
        );
        Ok(())
    }

    /// Decode a prefixed f32 array of exactly `dst.len()` elements (the
    /// caller read the count) straight into a slice — bulk, like
    /// [`ByteReader::f32s_into`].
    pub fn fill_f32s(&mut self, dst: &mut [f32]) -> Result<()> {
        let raw = self.take(4 * dst.len())?;
        for (d, c) in dst.iter_mut().zip(raw.chunks_exact(4)) {
            *d = f32::from_le_bytes(c.try_into().unwrap());
        }
        Ok(())
    }

    pub fn f64s(&mut self) -> Result<Vec<f64>> {
        let n = self.count(8)?;
        let raw = self.take(8 * n)?;
        Ok(raw
            .chunks_exact(8)
            .map(|c| f64::from_le_bytes(c.try_into().unwrap()))
            .collect())
    }

    pub fn i32s_into(&mut self, out: &mut Vec<i32>) -> Result<()> {
        let n = self.count(4)?;
        let raw = self.take(4 * n)?;
        out.clear();
        out.reserve(n);
        out.extend(
            raw.chunks_exact(4)
                .map(|c| i32::from_le_bytes(c.try_into().unwrap())),
        );
        Ok(())
    }

    /// Decode exactly `n` raw (unprefixed) i32s, *appending* to `out` —
    /// the sliced index-stream decode concatenates many per-task runs
    /// into one buffer.
    pub fn i32s_append(&mut self, out: &mut Vec<i32>, n: usize) -> Result<()> {
        let raw = self.take(4 * n)?;
        out.reserve(n);
        out.extend(
            raw.chunks_exact(4)
                .map(|c| i32::from_le_bytes(c.try_into().unwrap())),
        );
        Ok(())
    }

    pub fn u32s(&mut self) -> Result<Vec<u32>> {
        let n = self.count(4)?;
        let raw = self.take(4 * n)?;
        Ok(raw
            .chunks_exact(4)
            .map(|c| u32::from_le_bytes(c.try_into().unwrap()))
            .collect())
    }

    pub fn usizes(&mut self) -> Result<Vec<usize>> {
        let n = self.count(8)?;
        let raw = self.take(8 * n)?;
        Ok(raw
            .chunks_exact(8)
            .map(|c| u64::from_le_bytes(c.try_into().unwrap()) as usize)
            .collect())
    }

    pub fn usizes_into(&mut self, out: &mut Vec<usize>) -> Result<()> {
        let n = self.count(8)?;
        let raw = self.take(8 * n)?;
        out.clear();
        out.reserve(n);
        out.extend(
            raw.chunks_exact(8)
                .map(|c| u64::from_le_bytes(c.try_into().unwrap()) as usize),
        );
        Ok(())
    }

    pub fn pairs(&mut self) -> Result<Vec<(usize, usize)>> {
        let n = self.count(16)?;
        let mut out = Vec::with_capacity(n);
        for _ in 0..n {
            let a = self.usize()?;
            let b = self.usize()?;
            out.push((a, b));
        }
        Ok(out)
    }

    pub fn pairs_into(&mut self, out: &mut Vec<(usize, usize)>) -> Result<()> {
        let n = self.count(16)?;
        out.clear();
        out.reserve(n);
        for _ in 0..n {
            let a = self.usize()?;
            let b = self.usize()?;
            out.push((a, b));
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scalars_round_trip() {
        let mut buf = Vec::new();
        put_u8(&mut buf, 7);
        put_u32(&mut buf, 0xDEAD_BEEF);
        put_u64(&mut buf, u64::MAX - 3);
        put_usize(&mut buf, 123_456);
        put_f32(&mut buf, -0.0);
        put_f64(&mut buf, f64::NAN);
        put_str(&mut buf, "héllo");
        let mut r = ByteReader::new(&buf);
        assert_eq!(r.u8().unwrap(), 7);
        assert_eq!(r.u32().unwrap(), 0xDEAD_BEEF);
        assert_eq!(r.u64().unwrap(), u64::MAX - 3);
        assert_eq!(r.usize().unwrap(), 123_456);
        assert_eq!(r.f32().unwrap().to_bits(), (-0.0f32).to_bits());
        assert!(r.f64().unwrap().is_nan());
        assert_eq!(r.str().unwrap(), "héllo");
        assert!(r.is_empty());
    }

    #[test]
    fn arrays_round_trip_bitwise() {
        let f = vec![1.5f32, -2.25, f32::MIN_POSITIVE, 0.1];
        let i = vec![-5i32, 0, 7];
        let u = vec![3u32, 9];
        let s = vec![0usize, 42, usize::from(u16::MAX)];
        let p = vec![(1usize, 2usize), (3, 4)];
        let mut buf = Vec::new();
        put_f32s(&mut buf, &f);
        put_i32s(&mut buf, &i);
        put_u32s(&mut buf, &u);
        put_usizes(&mut buf, &s);
        put_pairs(&mut buf, &p);
        let mut r = ByteReader::new(&buf);
        let f2 = r.f32s().unwrap();
        assert_eq!(f.len(), f2.len());
        for (a, b) in f.iter().zip(&f2) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
        let mut i2 = Vec::new();
        r.i32s_into(&mut i2).unwrap();
        assert_eq!(i, i2);
        assert_eq!(r.u32s().unwrap(), u);
        assert_eq!(r.usizes().unwrap(), s);
        assert_eq!(r.pairs().unwrap(), p);
        assert!(r.is_empty());
    }

    #[test]
    fn fill_f32s_matches_prefixed_decode() {
        let f = vec![0.5f32, -1.5, 3.25];
        let mut buf = Vec::new();
        put_f32s(&mut buf, &f);
        let mut r = ByteReader::new(&buf);
        let n = r.u64().unwrap() as usize;
        let mut dst = vec![0.0f32; n];
        r.fill_f32s(&mut dst).unwrap();
        for (a, b) in f.iter().zip(&dst) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
        assert!(r.is_empty());
        // truncated input errors instead of zero-filling
        let mut r2 = ByteReader::new(&buf[..8]);
        let _ = r2.u64().unwrap();
        assert!(r2.fill_f32s(&mut dst).is_err());
    }

    #[test]
    fn i32s_append_concatenates_runs() {
        let mut buf = Vec::new();
        for v in [-1i32, 2, 3, -4] {
            buf.extend_from_slice(&v.to_le_bytes());
        }
        let mut out = vec![9i32];
        let mut r = ByteReader::new(&buf);
        r.i32s_append(&mut out, 2).unwrap();
        r.i32s_append(&mut out, 2).unwrap();
        assert_eq!(out, vec![9, -1, 2, 3, -4]);
        assert!(r.is_empty());
        let mut r2 = ByteReader::new(&buf);
        assert!(r2.i32s_append(&mut out, 5).is_err());
    }

    #[test]
    fn truncated_input_errors_cleanly() {
        let mut buf = Vec::new();
        put_u64(&mut buf, 10); // array prefix promising 10 f32s
        put_f32(&mut buf, 1.0); // ...but only one present
        let mut r = ByteReader::new(&buf);
        assert!(r.f32s().is_err());
        let mut r2 = ByteReader::new(&[1, 2]);
        assert!(r2.u32().is_err());
    }
}
