//! Deterministic PRNGs (the environment has no `rand` crate).
//!
//! [`Xoshiro`] is xoshiro256++ seeded through SplitMix64, the generator
//! recommended by Blackman & Vigna for non-cryptographic simulation work.
//! Every stochastic component in the repo draws from a stream derived with
//! [`Xoshiro::substream`] keyed by (component, partition, iteration), so a
//! run is bit-reproducible regardless of scheduling.

/// SplitMix64 step — used for seeding and key mixing.
#[inline]
pub fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E37_79B9_7F4A_7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// xoshiro256++ PRNG.
#[derive(Clone, Debug)]
pub struct Xoshiro {
    s: [u64; 4],
}

impl Xoshiro {
    /// Seed via SplitMix64 so that small/correlated seeds still give
    /// well-distributed state.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Xoshiro { s }
    }

    /// Derive an independent stream keyed by up to three coordinates
    /// (component tag, partition id, iteration).  Mixing through SplitMix64
    /// keeps streams statistically independent for distinct keys.
    pub fn substream(&self, a: u64, b: u64, c: u64) -> Self {
        let mut sm = self.s[0] ^ a.rotate_left(17) ^ b.rotate_left(37)
            ^ c.rotate_left(53) ^ 0xA076_1D64_78BD_642F;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Xoshiro { s }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[0]
            .wrapping_add(s[3])
            .rotate_left(23)
            .wrapping_add(s[0]);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1) with 53 bits of precision.
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in [0, 1) as f32.
    #[inline]
    pub fn f32(&mut self) -> f32 {
        self.f64() as f32
    }

    /// Uniform in [lo, hi).
    #[inline]
    pub fn range_f32(&mut self, lo: f32, hi: f32) -> f32 {
        lo + (hi - lo) * self.f32()
    }

    /// Unbiased integer in [0, n) (Lemire's method).
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        let n = n as u64;
        let mut x = self.next_u64();
        let mut m = (x as u128).wrapping_mul(n as u128);
        let mut l = m as u64;
        if l < n {
            let t = n.wrapping_neg() % n;
            while l < t {
                x = self.next_u64();
                m = (x as u128).wrapping_mul(n as u128);
                l = m as u64;
            }
        }
        (m >> 64) as usize
    }

    /// Standard normal via Box-Muller (polar form, no trig).
    pub fn normal(&mut self) -> f64 {
        loop {
            let u = 2.0 * self.f64() - 1.0;
            let v = 2.0 * self.f64() - 1.0;
            let s = u * u + v * v;
            if s > 0.0 && s < 1.0 {
                return u * (-2.0 * s.ln() / s).sqrt();
            }
        }
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            xs.swap(i, self.below(i + 1));
        }
    }

    /// A random permutation of 0..n.
    pub fn permutation(&mut self, n: usize) -> Vec<usize> {
        let mut p: Vec<usize> = (0..n).collect();
        self.shuffle(&mut p);
        p
    }

    /// `len` indices uniform in [0, n) — the visit order streams fed to the
    /// SDCA/SVRG kernels (both native and XLA backends consume these, which
    /// is what makes the two backends bit-comparable).
    pub fn index_stream(&mut self, n: usize, len: usize) -> Vec<i32> {
        let mut out = vec![0i32; len];
        self.fill_index_stream(n, &mut out);
        out
    }

    /// [`Xoshiro::index_stream`] into a caller-owned buffer — the
    /// coordinators refill persistent per-task streams each iteration so
    /// the steady-state hot path draws indices without allocating.  Same
    /// draws in the same order as `index_stream(n, out.len())`.
    pub fn fill_index_stream(&mut self, n: usize, out: &mut [i32]) {
        for v in out.iter_mut() {
            *v = self.below(n) as i32;
        }
    }

    /// Bernoulli(p).
    #[inline]
    pub fn coin(&mut self, p: f64) -> bool {
        self.f64() < p
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_fixed_seed() {
        let mut a = Xoshiro::new(42);
        let mut b = Xoshiro::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Xoshiro::new(1);
        let mut b = Xoshiro::new(2);
        assert_ne!(
            (0..8).map(|_| a.next_u64()).collect::<Vec<_>>(),
            (0..8).map(|_| b.next_u64()).collect::<Vec<_>>()
        );
    }

    #[test]
    fn substreams_are_independent_of_draw_order() {
        let root = Xoshiro::new(7);
        let mut s1 = root.substream(1, 2, 3);
        let _ = root.substream(9, 9, 9); // unrelated derivation
        let mut s2 = root.substream(1, 2, 3);
        for _ in 0..32 {
            assert_eq!(s1.next_u64(), s2.next_u64());
        }
    }

    #[test]
    fn substream_keys_matter() {
        let root = Xoshiro::new(7);
        let mut a = root.substream(1, 0, 0);
        let mut b = root.substream(0, 1, 0);
        let mut c = root.substream(0, 0, 1);
        let va: Vec<u64> = (0..4).map(|_| a.next_u64()).collect();
        let vb: Vec<u64> = (0..4).map(|_| b.next_u64()).collect();
        let vc: Vec<u64> = (0..4).map(|_| c.next_u64()).collect();
        assert_ne!(va, vb);
        assert_ne!(vb, vc);
        assert_ne!(va, vc);
    }

    #[test]
    fn uniform_mean_is_half() {
        let mut r = Xoshiro::new(3);
        let mean: f64 = (0..20_000).map(|_| r.f64()).sum::<f64>() / 20_000.0;
        assert!((mean - 0.5).abs() < 0.01, "mean {mean}");
    }

    #[test]
    fn below_is_in_range_and_hits_all() {
        let mut r = Xoshiro::new(5);
        let mut seen = [false; 7];
        for _ in 0..1000 {
            let v = r.below(7);
            assert!(v < 7);
            seen[v] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn normal_moments() {
        let mut r = Xoshiro::new(11);
        let n = 50_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>()
            / n as f64;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn permutation_is_a_permutation() {
        let mut r = Xoshiro::new(13);
        let mut p = r.permutation(100);
        p.sort_unstable();
        assert_eq!(p, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn index_stream_in_bounds() {
        let mut r = Xoshiro::new(17);
        let s = r.index_stream(37, 500);
        assert_eq!(s.len(), 500);
        assert!(s.iter().all(|&i| (0..37).contains(&(i as usize))));
    }
}
