//! Summary statistics for the bench harness (the `criterion` stand-in).

/// Summary of a sample of measurements (seconds, or any unit).
#[derive(Debug, Clone, PartialEq)]
pub struct Summary {
    pub n: usize,
    pub mean: f64,
    pub std: f64,
    pub min: f64,
    pub median: f64,
    pub p95: f64,
    pub max: f64,
}

impl Summary {
    pub fn of(samples: &[f64]) -> Summary {
        assert!(!samples.is_empty(), "empty sample");
        let n = samples.len();
        let mean = samples.iter().sum::<f64>() / n as f64;
        let var = samples
            .iter()
            .map(|x| (x - mean) * (x - mean))
            .sum::<f64>()
            / n as f64;
        let mut s = samples.to_vec();
        s.sort_by(|a, b| a.partial_cmp(b).unwrap());
        Summary {
            n,
            mean,
            std: var.sqrt(),
            min: s[0],
            median: percentile(&s, 50.0),
            p95: percentile(&s, 95.0),
            max: s[n - 1],
        }
    }
}

/// Percentile of a pre-sorted slice via linear interpolation.
pub fn percentile(sorted: &[f64], p: f64) -> f64 {
    assert!(!sorted.is_empty());
    if sorted.len() == 1 {
        return sorted[0];
    }
    let rank = p / 100.0 * (sorted.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    let frac = rank - lo as f64;
    sorted[lo] * (1.0 - frac) + sorted[hi] * frac
}

/// Geometric mean (used for the paper-vs-measured speedup factors).
pub fn geomean(xs: &[f64]) -> f64 {
    assert!(!xs.is_empty());
    (xs.iter().map(|x| x.ln()).sum::<f64>() / xs.len() as f64).exp()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_basics() {
        let s = Summary::of(&[1.0, 2.0, 3.0, 4.0, 5.0]);
        assert_eq!(s.n, 5);
        assert!((s.mean - 3.0).abs() < 1e-12);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 5.0);
        assert!((s.median - 3.0).abs() < 1e-12);
    }

    #[test]
    fn percentile_interpolates() {
        let s = [0.0, 10.0];
        assert!((percentile(&s, 50.0) - 5.0).abs() < 1e-12);
        assert!((percentile(&s, 95.0) - 9.5).abs() < 1e-12);
    }

    #[test]
    fn geomean_of_powers() {
        assert!((geomean(&[1.0, 4.0]) - 2.0).abs() < 1e-12);
    }

    #[test]
    #[should_panic]
    fn empty_sample_panics() {
        let _ = Summary::of(&[]);
    }
}
