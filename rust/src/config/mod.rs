//! Experiment configuration: a declarative description of a training run
//! (dataset, grid, loss, method, hyper-parameters), parseable from JSON
//! files under `configs/` and overridable from CLI flags.

use crate::cluster::ClusterConfig;
use crate::loss::Loss;
use crate::util::json::Json;
use anyhow::{anyhow, bail, Result};
use std::path::Path;

/// Which dataset to build.
#[derive(Clone, Debug, PartialEq)]
pub enum DatasetSpec {
    /// Paper Part-1 dense synthetic: P·Q partitions of n_per × m_per.
    Dense { n_per: usize, m_per: usize },
    /// Sparse synthetic stand-in with explicit shape and density.
    Sparse { n: usize, m: usize, density: f64 },
    /// LIBSVM file on disk.
    Libsvm { path: String },
}

/// A fully-specified experiment cell.
#[derive(Clone, Debug)]
pub struct ExperimentConfig {
    pub name: String,
    pub dataset: DatasetSpec,
    pub p: usize,
    pub q: usize,
    pub loss: Loss,
    pub lambda: f32,
    pub iterations: usize,
    pub seed: u64,
    /// RADiSA step-size constant γ in η_t = γ/(1+√(t−1)).
    pub gamma: f32,
    /// RADiSA batch size L (0 → one pass over the local rows).
    pub batch: usize,
    /// ADMM penalty ρ (paper sets ρ = λ).
    pub rho: f32,
    /// Cluster cost model + execution: JSON keys `cores` (simulated
    /// executor slots), `threads` (host worker threads for the superstep
    /// engine; defaults to the host's hardware parallelism), `scenario`
    /// (a cluster-condition spec string, same grammar as the CLI
    /// `--scenario` flag — e.g. `"stragglers:p=0.1,slow=10x"`), and
    /// `cluster` (execution substrate, same grammar as `--cluster`:
    /// `"sim"` or `"dist:host:port[,host:port...]"`).
    pub cluster: ClusterConfig,
    pub backend: String, // "native" | "xla"
    /// Directory for periodic optimizer-state checkpoints (JSON key
    /// `checkpoint_dir`; empty/absent = disabled).
    pub checkpoint_dir: Option<String>,
    /// Snapshot cadence in iterations (JSON key `checkpoint_every`;
    /// 0 = the driver's default of every iteration when a dir is set).
    pub checkpoint_every: usize,
}

impl Default for ExperimentConfig {
    fn default() -> Self {
        ExperimentConfig {
            name: "experiment".into(),
            dataset: DatasetSpec::Dense { n_per: 200, m_per: 150 },
            p: 2,
            q: 2,
            loss: Loss::Hinge,
            lambda: 1e-3,
            iterations: 30,
            seed: 42,
            gamma: 0.02,
            batch: 0,
            rho: 1e-3,
            cluster: ClusterConfig::default(),
            backend: "native".into(),
            checkpoint_dir: None,
            checkpoint_every: 0,
        }
    }
}

impl ExperimentConfig {
    pub fn k(&self) -> usize {
        self.p * self.q
    }

    /// Parse from a JSON document; missing keys keep defaults.
    pub fn from_json(v: &Json) -> Result<ExperimentConfig> {
        let mut c = ExperimentConfig::default();
        if let Some(s) = v.get("name").and_then(|x| x.as_str()) {
            c.name = s.to_string();
        }
        if let Some(d) = v.get("dataset") {
            let kind = d
                .get("kind")
                .and_then(|k| k.as_str())
                .ok_or_else(|| anyhow!("dataset.kind missing"))?;
            c.dataset = match kind {
                "dense" => DatasetSpec::Dense {
                    n_per: d.get("n_per").and_then(|x| x.as_usize()).unwrap_or(200),
                    m_per: d.get("m_per").and_then(|x| x.as_usize()).unwrap_or(150),
                },
                "sparse" => DatasetSpec::Sparse {
                    n: d.get("n").and_then(|x| x.as_usize()).unwrap_or(1000),
                    m: d.get("m").and_then(|x| x.as_usize()).unwrap_or(500),
                    density: d.get("density").and_then(|x| x.as_f64()).unwrap_or(0.01),
                },
                "libsvm" => DatasetSpec::Libsvm {
                    path: d
                        .get("path")
                        .and_then(|x| x.as_str())
                        .ok_or_else(|| anyhow!("dataset.path missing"))?
                        .to_string(),
                },
                other => bail!("unknown dataset kind '{other}'"),
            };
        }
        if let Some(x) = v.get("p").and_then(|x| x.as_usize()) {
            c.p = x;
        }
        if let Some(x) = v.get("q").and_then(|x| x.as_usize()) {
            c.q = x;
        }
        if let Some(x) = v.get("loss").and_then(|x| x.as_str()) {
            c.loss = Loss::parse(x).ok_or_else(|| anyhow!("unknown loss '{x}'"))?;
        }
        if let Some(x) = v.get("lambda").and_then(|x| x.as_f64()) {
            c.lambda = x as f32;
        }
        if let Some(x) = v.get("iterations").and_then(|x| x.as_usize()) {
            c.iterations = x;
        }
        if let Some(x) = v.get("seed").and_then(|x| x.as_f64()) {
            c.seed = x as u64;
        }
        if let Some(x) = v.get("gamma").and_then(|x| x.as_f64()) {
            c.gamma = x as f32;
        }
        if let Some(x) = v.get("batch").and_then(|x| x.as_usize()) {
            c.batch = x;
        }
        if let Some(x) = v.get("rho").and_then(|x| x.as_f64()) {
            c.rho = x as f32;
        }
        if let Some(x) = v.get("cores").and_then(|x| x.as_usize()) {
            c.cluster.cores = x;
        }
        if let Some(x) = v.get("threads").and_then(|x| x.as_usize()) {
            c.cluster.threads = x;
        }
        if let Some(x) = v.get("scenario").and_then(|x| x.as_str()) {
            // same spec grammar as the CLI --scenario flag
            c.cluster.scenario = crate::cluster::ClusterScenario::parse(x)?;
        }
        if let Some(x) = v.get("cluster").and_then(|x| x.as_str()) {
            // same spec grammar as the CLI --cluster flag
            c.cluster.mode = crate::cluster::ClusterMode::parse(x)?;
        }
        if let Some(x) = v.get("dist_spec") {
            // either a bool, or the CLI --dist-spec parameter string
            // ("quantile=0.75,copies=1")
            match (x.as_bool(), x.as_str()) {
                (Some(b), _) => c.cluster.dist_spec = b,
                (_, Some(s)) => {
                    let (q, k) = crate::cluster::parse_dist_spec(s)?;
                    c.cluster.dist_spec = true;
                    c.cluster.scenario.spec_quantile = q;
                    c.cluster.scenario.spec_copies = k;
                }
                _ => bail!("dist_spec must be a bool or a parameter string"),
            }
        }
        if let Some(x) = v.get("backend").and_then(|x| x.as_str()) {
            if x != "native" && x != "xla" {
                bail!("unknown backend '{x}'");
            }
            c.backend = x.to_string();
        }
        if let Some(x) = v.get("checkpoint_dir").and_then(|x| x.as_str()) {
            if !x.is_empty() {
                c.checkpoint_dir = Some(x.to_string());
            }
        }
        if let Some(x) = v.get("checkpoint_every").and_then(|x| x.as_usize()) {
            c.checkpoint_every = x;
        }
        Ok(c)
    }

    pub fn from_file(path: &Path) -> Result<ExperimentConfig> {
        let text = std::fs::read_to_string(path)?;
        let v = Json::parse(&text).map_err(|e| anyhow!("{}: {e}", path.display()))?;
        Self::from_json(&v)
    }

    /// Build the dataset this config describes.
    pub fn build_dataset(&self) -> Result<crate::data::Dataset> {
        Ok(match &self.dataset {
            DatasetSpec::Dense { n_per, m_per } => {
                crate::data::SyntheticDense::paper_part1(
                    self.p, self.q, *n_per, *m_per, 0.1, self.seed,
                )
                .build()
            }
            DatasetSpec::Sparse { n, m, density } => {
                crate::data::SyntheticSparse::new("sparse", *n, *m, *density, self.seed)
                    .build()
            }
            DatasetSpec::Libsvm { path } => {
                crate::data::read_libsvm(Path::new(path), 0)?
            }
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_full_config() {
        let text = r#"{
          "name": "fig3-cell", "p": 4, "q": 2, "loss": "hinge",
          "lambda": 1e-4, "iterations": 50, "gamma": 0.05,
          "dataset": {"kind": "dense", "n_per": 2000, "m_per": 3000},
          "cores": 8, "threads": 3, "backend": "xla",
          "scenario": "stragglers:p=0.2,slow=8x,seed=5"
        }"#;
        let c = ExperimentConfig::from_json(&Json::parse(text).unwrap()).unwrap();
        assert_eq!(c.p, 4);
        assert_eq!(c.k(), 8);
        assert_eq!(c.lambda, 1e-4);
        assert_eq!(c.backend, "xla");
        assert_eq!(c.cluster.cores, 8);
        assert_eq!(c.cluster.threads, 3);
        assert_eq!(c.cluster.scenario.straggler_p, 0.2);
        assert_eq!(c.cluster.scenario.straggler_slow, 8.0);
        assert_eq!(c.cluster.scenario.seed, 5);
        assert_eq!(c.dataset, DatasetSpec::Dense { n_per: 2000, m_per: 3000 });
    }

    #[test]
    fn scenario_defaults_to_ideal_and_rejects_bad_specs() {
        let c = ExperimentConfig::from_json(&Json::parse("{}").unwrap()).unwrap();
        assert!(c.cluster.scenario.is_ideal());
        assert!(ExperimentConfig::from_json(
            &Json::parse(r#"{"scenario":"warp:9"}"#).unwrap()
        )
        .is_err());
    }

    #[test]
    fn cluster_mode_defaults_to_sim_and_parses_dist() {
        use crate::cluster::ClusterMode;
        let c = ExperimentConfig::from_json(&Json::parse("{}").unwrap()).unwrap();
        assert_eq!(c.cluster.mode, ClusterMode::Sim);
        let c = ExperimentConfig::from_json(
            &Json::parse(r#"{"cluster":"dist:127.0.0.1:7001,127.0.0.1:7002"}"#).unwrap(),
        )
        .unwrap();
        assert_eq!(
            c.cluster.mode,
            ClusterMode::Dist(vec!["127.0.0.1:7001".into(), "127.0.0.1:7002".into()])
        );
        assert!(ExperimentConfig::from_json(
            &Json::parse(r#"{"cluster":"spark://"}"#).unwrap()
        )
        .is_err());
    }

    #[test]
    fn defaults_fill_missing() {
        let c = ExperimentConfig::from_json(&Json::parse("{}").unwrap()).unwrap();
        assert_eq!(c.p, 2);
        assert_eq!(c.loss, Loss::Hinge);
        assert_eq!(c.checkpoint_dir, None);
        assert_eq!(c.checkpoint_every, 0);
    }

    #[test]
    fn parses_checkpoint_keys() {
        let c = ExperimentConfig::from_json(
            &Json::parse(r#"{"checkpoint_dir":"results/ck","checkpoint_every":5}"#).unwrap(),
        )
        .unwrap();
        assert_eq!(c.checkpoint_dir.as_deref(), Some("results/ck"));
        assert_eq!(c.checkpoint_every, 5);
        // empty dir string means disabled, not a checkpoint dir named ""
        let c = ExperimentConfig::from_json(
            &Json::parse(r#"{"checkpoint_dir":""}"#).unwrap(),
        )
        .unwrap();
        assert_eq!(c.checkpoint_dir, None);
    }

    #[test]
    fn rejects_bad_values() {
        assert!(ExperimentConfig::from_json(
            &Json::parse(r#"{"loss":"nope"}"#).unwrap()
        )
        .is_err());
        assert!(ExperimentConfig::from_json(
            &Json::parse(r#"{"backend":"gpu"}"#).unwrap()
        )
        .is_err());
        assert!(ExperimentConfig::from_json(
            &Json::parse(r#"{"dataset":{"kind":"weird"}}"#).unwrap()
        )
        .is_err());
    }

    #[test]
    fn builds_datasets() {
        let mut c = ExperimentConfig::default();
        c.dataset = DatasetSpec::Dense { n_per: 10, m_per: 8 };
        let ds = c.build_dataset().unwrap();
        assert_eq!(ds.n(), 20);
        assert_eq!(ds.m(), 16);
        c.dataset = DatasetSpec::Sparse { n: 30, m: 40, density: 0.1 };
        let ds = c.build_dataset().unwrap();
        assert_eq!(ds.n(), 30);
    }
}
