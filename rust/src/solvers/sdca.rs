//! Native local SDCA epoch — Algorithm 2 (LOCALDUALMETHOD) of the paper.
//!
//! Semantics match `python/compile/kernels/sdca.py` one-for-one (same
//! closed-form hinge step with the 1/Q-scaled local objective, same
//! index-stream protocol, same optional β step-size override), so the
//! native and XLA backends can be compared within f32 tolerance.
//!
//! The per-step row dot/axpy on dense blocks route through the active
//! [`crate::linalg::KernelDispatch`] table (unrolled 8-accumulator
//! bodies); sparse rows stay sequential gathers.  Either way the
//! reduction order is fixed, so SDCA trajectories are bit-identical
//! under `DDOPT_KERNELS=scalar` and the dispatched table.

use crate::data::Block;

/// Precompute ‖x_i‖² for every row — done once per staging (§Perf: saves
/// an m-length pass per SDCA step).
pub fn row_norms(x: &Block) -> Vec<f32> {
    (0..x.rows()).map(|i| x.row_norm_sq(i)).collect()
}

/// Run `h` local SDCA steps on partition data `(x, y)` starting from dual
/// iterate `a0` and local primal `w0`; returns the dual delta vector.
///
/// * `norms` — precomputed ‖x_i‖² (see [`row_norms`]).
/// * `idx` — visit order (values in `[0, n_p)`), from the coordinator's
///   seeded stream; `h` may exceed `idx.len()`, in which case the stream is
///   replayed cyclically (the XLA kernel is called once per cycle instead).
/// * `lamn` — λ·n (n = *global* observation count).
/// * `invq` — 1/Q, the local-objective scaling of Algorithm 2 step 3.
/// * `beta` — if > 0, replaces ‖x_i‖² in the step denominator (the paper's
///   stabilization for small λ).
#[allow(clippy::too_many_arguments)]
pub fn sdca_epoch(
    x: &Block,
    y: &[f32],
    norms: &[f32],
    a0: &[f32],
    w0: &[f32],
    idx: &[i32],
    h: usize,
    lamn: f32,
    invq: f32,
    beta: f32,
) -> Vec<f32> {
    let mut da = vec![0.0f32; x.rows()];
    let mut a_buf = vec![0.0f32; x.rows()];
    let mut w_buf = vec![0.0f32; x.cols()];
    sdca_epoch_into(
        x, y, norms, a0, w0, idx, h, lamn, invq, beta, &mut da, &mut a_buf, &mut w_buf,
    );
    da
}

/// [`sdca_epoch`] into caller-owned buffers — the zero-allocation variant
/// the workspace hot path uses.  `da` (length n_p) receives the dual
/// delta; `a_buf`/`w_buf` are per-worker scratch of at least n_p / m_q
/// elements (their prior contents are overwritten).  Bit-identical to
/// [`sdca_epoch`].
#[allow(clippy::too_many_arguments)]
pub fn sdca_epoch_into(
    x: &Block,
    y: &[f32],
    norms: &[f32],
    a0: &[f32],
    w0: &[f32],
    idx: &[i32],
    h: usize,
    lamn: f32,
    invq: f32,
    beta: f32,
    da: &mut [f32],
    a_buf: &mut [f32],
    w_buf: &mut [f32],
) {
    let n = x.rows();
    debug_assert_eq!(y.len(), n);
    debug_assert_eq!(norms.len(), n);
    debug_assert_eq!(a0.len(), n);
    debug_assert_eq!(w0.len(), x.cols());
    debug_assert_eq!(da.len(), n);
    let a = &mut a_buf[..n];
    a.copy_from_slice(a0);
    let w = &mut w_buf[..x.cols()];
    w.copy_from_slice(w0);
    da.fill(0.0);
    for t in 0..h {
        let i = idx[t % idx.len()] as usize;
        debug_assert!(i < n);
        let yi = y[i];
        let marg = x.row_dot(i, w);
        let denom = if beta > 0.0 { beta } else { norms[i] } + 1e-12;
        let raw = a[i] * yi + lamn * (invq - yi * marg) / denom;
        let d = yi * raw.clamp(0.0, 1.0) - a[i];
        if d != 0.0 {
            a[i] += d;
            da[i] += d;
            x.row_axpy(i, d / lamn, w);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{DenseMatrix, SyntheticDense};
    use crate::loss::Loss;
    use crate::util::rng::Xoshiro;

    fn small_block(n: usize, m: usize, seed: u64) -> (Block, Vec<f32>) {
        let mut r = Xoshiro::new(seed);
        let x = DenseMatrix::from_fn(n, m, |_, _| r.range_f32(-1.0, 1.0));
        let y: Vec<f32> = (0..n)
            .map(|_| if r.coin(0.5) { 1.0 } else { -1.0 })
            .collect();
        (Block::dense(x), y)
    }

    #[test]
    fn epoch_keeps_dual_feasible() {
        let (x, y) = small_block(40, 10, 1);
        let mut rng = Xoshiro::new(2);
        let idx = rng.index_stream(40, 40);
        let a0 = vec![0.0; 40];
        let w0 = vec![0.0; 10];
        let da = sdca_epoch(&x, &y, &row_norms(&x), &a0, &w0, &idx, 40, 0.1 * 40.0, 1.0, 0.0);
        for i in 0..40 {
            assert!(Loss::Hinge.dual_feasible(a0[i] + da[i], y[i], 1e-5));
        }
    }

    #[test]
    fn epoch_increases_dual_objective_single_partition() {
        // With Q = 1 and the whole data as one partition this is plain SDCA,
        // which must increase D(alpha) from zero.
        let ds = SyntheticDense::paper_part1(1, 1, 60, 12, 0.1, 3).build();
        let part = crate::data::Partitioned::split(&ds, crate::data::Grid::new(1, 1));
        let lam = 0.1f32;
        let n = ds.n();
        let mut rng = Xoshiro::new(4);
        let idx = rng.index_stream(n, n);
        let a0 = vec![0.0; n];
        let w0 = vec![0.0; ds.m()];
        let da = sdca_epoch(&ds.x, &ds.y, &row_norms(&ds.x), &a0, &w0, &idx, n, lam * n as f32, 1.0, 0.0);
        let a1: Vec<f32> = a0.iter().zip(&da).map(|(a, d)| a + d).collect();
        let d0 = crate::solvers::dual_objective(&part, &a0, lam);
        let d1 = crate::solvers::dual_objective(&part, &a1, lam);
        assert!(d1 > d0, "dual went {d0} -> {d1}");
    }

    #[test]
    fn untouched_indices_have_zero_delta() {
        let (x, y) = small_block(10, 4, 5);
        let idx = vec![3i32; 6];
        let da = sdca_epoch(&x, &y, &row_norms(&x), &vec![0.0; 10], &vec![0.0; 4],
                            &idx, 6, 1.0, 1.0, 0.0);
        for (i, d) in da.iter().enumerate() {
            if i != 3 {
                assert_eq!(*d, 0.0);
            }
        }
    }

    #[test]
    fn beta_override_changes_step() {
        let (x, y) = small_block(10, 4, 7);
        let mut rng = Xoshiro::new(8);
        let idx = rng.index_stream(10, 10);
        let nr = row_norms(&x);
        let d_norm = sdca_epoch(&x, &y, &nr, &vec![0.0; 10], &vec![0.0; 4],
                                &idx, 10, 1.0, 1.0, 0.0);
        let d_beta = sdca_epoch(&x, &y, &nr, &vec![0.0; 10], &vec![0.0; 4],
                                &idx, 10, 1.0, 1.0, 50.0);
        // a large beta shrinks steps
        let s_norm: f32 = d_norm.iter().map(|v| v.abs()).sum();
        let s_beta: f32 = d_beta.iter().map(|v| v.abs()).sum();
        assert!(s_beta < s_norm, "{s_beta} !< {s_norm}");
    }

    #[test]
    fn index_stream_wraps_when_h_exceeds_len() {
        let (x, y) = small_block(10, 4, 9);
        let idx = vec![0i32, 1, 2];
        // h = 6 replays the 3-long stream twice; must not panic and must
        // leave rows 3.. untouched.
        let da = sdca_epoch(&x, &y, &row_norms(&x), &vec![0.0; 10], &vec![0.0; 4],
                            &idx, 6, 1.0, 1.0, 0.0);
        assert!(da[3..].iter().all(|&d| d == 0.0));
    }
}
