//! Objectives, gradients and the primal-dual map over a partitioned
//! dataset — the single-threaded reference versions the exact solver and
//! the tests use.  (The coordinator computes the same quantities through
//! the cluster substrate + backend; integration tests assert agreement.)

use crate::data::Partitioned;
use crate::linalg;
use crate::loss::Loss;

/// Full margins X w, reassembled as sum over feature partitions q of
/// x[p,q] · w[.,q] — exactly the reduce the coordinators perform.
pub fn full_margins(part: &Partitioned, w: &[f32]) -> Vec<f32> {
    debug_assert_eq!(w.len(), part.m);
    let mut mg = vec![0.0f32; part.n];
    let mut local = Vec::new();
    for p in 0..part.grid.p {
        let (r0, r1) = part.row_ranges[p];
        local.resize(r1 - r0, 0.0);
        for q in 0..part.grid.q {
            let (c0, c1) = part.col_ranges[q];
            part.block(p, q).margins_into(&w[c0..c1], &mut local);
            for (acc, &v) in mg[r0..r1].iter_mut().zip(&local) {
                *acc += v;
            }
        }
    }
    mg
}

/// F(w) = (1/n) Σ f_i(x_i·w) + (λ/2)‖w‖², in f64 for a stable gap metric.
pub fn primal_objective(part: &Partitioned, w: &[f32], loss: Loss, lam: f32) -> f64 {
    let mg = full_margins(part, w);
    primal_objective_from_margins(part, &mg, w, loss, lam)
}

/// Same, reusing precomputed margins.
pub fn primal_objective_from_margins(
    part: &Partitioned,
    mg: &[f32],
    w: &[f32],
    loss: Loss,
    lam: f32,
) -> f64 {
    let mut sum = 0.0f64;
    for i in 0..part.n {
        sum += loss.value(mg[i], part.y[i]) as f64;
    }
    sum / part.n as f64 + 0.5 * lam as f64 * linalg::nrm2_sq(w) as f64
}

/// w(α) = (λ n)⁻¹ Σ α_i x_i — the paper's primal-dual map (3), assembled
/// per feature partition via X^T α reduces.
pub fn primal_from_dual(part: &Partitioned, alpha: &[f32], lam: f32) -> Vec<f32> {
    debug_assert_eq!(alpha.len(), part.n);
    let inv = 1.0 / (lam * part.n as f32);
    let mut w = vec![0.0f32; part.m];
    let mut local = Vec::new();
    for q in 0..part.grid.q {
        let (c0, c1) = part.col_ranges[q];
        local.resize(c1 - c0, 0.0);
        for p in 0..part.grid.p {
            let (r0, r1) = part.row_ranges[p];
            part.block(p, q).atx_into(&alpha[r0..r1], &mut local);
            for (acc, &v) in w[c0..c1].iter_mut().zip(&local) {
                *acc += inv * v;
            }
        }
    }
    w
}

/// D(α) = (1/n) Σ α_i y_i − (λ/2)‖w(α)‖² (hinge).
pub fn dual_objective(part: &Partitioned, alpha: &[f32], lam: f32) -> f64 {
    let mut lin = 0.0f64;
    for i in 0..part.n {
        lin += (alpha[i] * part.y[i]) as f64;
    }
    let w = primal_from_dual(part, alpha, lam);
    lin / part.n as f64 - 0.5 * lam as f64 * linalg::nrm2_sq(&w) as f64
}

/// Loss-only gradient of one partition from its margins:
/// g = (1/n) x[p,q]^T ψ with ψ_i = f'_i(margin_i).  `n` is the *global*
/// count (the 1/n of objective (1)).
pub fn grad_from_margins(
    x: &crate::data::Block,
    y: &[f32],
    mg: &[f32],
    n_global: usize,
    loss: Loss,
) -> Vec<f32> {
    let mut g = vec![0.0f32; x.cols()];
    let mut psi = Vec::new();
    grad_from_margins_into(x, y, mg, n_global, loss, &mut g, &mut psi);
    g
}

/// [`grad_from_margins`] into a caller-owned output (length m_q) with
/// caller-owned ψ scratch — the zero-allocation variant of the workspace
/// hot path (the scratch reaches its high-water capacity after warmup).
pub fn grad_from_margins_into(
    x: &crate::data::Block,
    y: &[f32],
    mg: &[f32],
    n_global: usize,
    loss: Loss,
    out: &mut [f32],
    psi: &mut Vec<f32>,
) {
    let n_p = x.rows();
    debug_assert_eq!(y.len(), n_p);
    debug_assert_eq!(mg.len(), n_p);
    debug_assert_eq!(out.len(), x.cols());
    psi.clear();
    psi.extend((0..n_p).map(|i| loss.slope(mg[i], y[i]) / n_global as f32));
    x.atx_into(psi, out);
}

/// ∇F(w) = (1/n) Σ f'_i(x_i·w) x_i + λ w, full vector.
pub fn full_gradient(part: &Partitioned, w: &[f32], loss: Loss, lam: f32) -> Vec<f32> {
    let mg = full_margins(part, w);
    let mut g = vec![0.0f32; part.m];
    for q in 0..part.grid.q {
        let (c0, c1) = part.col_ranges[q];
        for p in 0..part.grid.p {
            let (r0, r1) = part.row_ranges[p];
            let gq = grad_from_margins(
                part.block(p, q),
                part.labels(p),
                &mg[r0..r1],
                part.n,
                loss,
            );
            for (acc, &v) in g[c0..c1].iter_mut().zip(&gq) {
                *acc += v;
            }
        }
    }
    linalg::axpy(lam, w, &mut g);
    g
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{Grid, Partitioned, SyntheticDense};
    use crate::util::rng::Xoshiro;

    fn setup() -> (Partitioned, Vec<f32>) {
        let ds = SyntheticDense::paper_part1(3, 2, 30, 20, 0.1, 1).build();
        let part = Partitioned::split(&ds, Grid::new(3, 2));
        let mut r = Xoshiro::new(2);
        let w: Vec<f32> = (0..ds.m()).map(|_| r.range_f32(-0.5, 0.5)).collect();
        (part, w)
    }

    #[test]
    fn margins_match_unpartitioned() {
        let ds = SyntheticDense::paper_part1(3, 2, 30, 20, 0.1, 1).build();
        let (part, w) = setup();
        let mg = full_margins(&part, &w);
        let mut direct = vec![0.0; ds.n()];
        ds.x.margins_into(&w, &mut direct);
        for i in 0..ds.n() {
            assert!((mg[i] - direct[i]).abs() < 1e-4);
        }
    }

    #[test]
    fn gradient_matches_finite_differences() {
        let (part, w) = setup();
        for loss in [Loss::Hinge, Loss::Logistic, Loss::Squared] {
            let lam = 0.05f32;
            let g = full_gradient(&part, &w, loss, lam);
            let mut r = Xoshiro::new(3);
            for _ in 0..6 {
                let k = r.below(part.m);
                let h = 1e-3f32;
                let mut wp = w.clone();
                wp[k] += h;
                let mut wm = w.clone();
                wm[k] -= h;
                let num = (primal_objective(&part, &wp, loss, lam)
                    - primal_objective(&part, &wm, loss, lam))
                    / (2.0 * h as f64);
                assert!(
                    (num - g[k] as f64).abs() < 2e-2,
                    "{loss:?} coord {k}: fd {num} vs {}",
                    g[k]
                );
            }
        }
    }

    #[test]
    fn weak_duality_holds() {
        let (part, _) = setup();
        let lam = 0.1f32;
        let mut r = Xoshiro::new(4);
        // any feasible dual point: a_i y_i in [0,1]
        let alpha: Vec<f32> = part.y.iter().map(|&y| y * r.f32()).collect();
        let w = primal_from_dual(&part, &alpha, lam);
        let f = primal_objective(&part, &w, Loss::Hinge, lam);
        let d = dual_objective(&part, &alpha, lam);
        assert!(f >= d - 1e-6, "F={f} < D={d}");
    }

    #[test]
    fn zero_dual_gives_zero_primal() {
        let (part, _) = setup();
        let w = primal_from_dual(&part, &vec![0.0; part.n], 0.1);
        assert!(w.iter().all(|&v| v == 0.0));
        assert_eq!(dual_objective(&part, &vec![0.0; part.n], 0.1), 0.0);
    }

    #[test]
    fn partitioning_invariance_of_objective() {
        // F(w) must not depend on the grid.
        let ds = SyntheticDense::paper_part1(4, 3, 12, 10, 0.1, 5).build();
        let mut r = Xoshiro::new(6);
        let w: Vec<f32> = (0..ds.m()).map(|_| r.range_f32(-1.0, 1.0)).collect();
        let f1 = primal_objective(
            &Partitioned::split(&ds, Grid::new(1, 1)),
            &w,
            Loss::Hinge,
            0.1,
        );
        let f2 = primal_objective(
            &Partitioned::split(&ds, Grid::new(4, 3)),
            &w,
            Loss::Hinge,
            0.1,
        );
        let f3 = primal_objective(
            &Partitioned::split(&ds, Grid::new(2, 2)),
            &w,
            Loss::Hinge,
            0.1,
        );
        assert!((f1 - f2).abs() < 1e-6, "{f1} vs {f2}");
        assert!((f1 - f3).abs() < 1e-6, "{f1} vs {f3}");
    }
}
