//! The exact reference solver producing `f*` — the paper's "optimal
//! objective function value obtained by running an algorithm for a very
//! long time".
//!
//! Hinge: single-node SDCA (Q=1) run until the duality gap certifies
//! optimality.  Logistic/squared: deterministic full gradient descent with
//! Armijo backtracking (F is λ-strongly convex, so this converges
//! linearly).  Results are cached under `data_cache/` keyed by
//! (dataset, n, m, loss, λ) so experiment harnesses do not recompute.

use crate::data::{Dataset, Grid, Partitioned};
use crate::linalg;
use crate::loss::Loss;
use crate::solvers::{self, objective};
use crate::util::json::Json;
use crate::util::rng::Xoshiro;
use std::path::PathBuf;

/// The certified reference solution.
#[derive(Clone, Debug)]
pub struct Reference {
    pub fstar: f64,
    pub w: Vec<f32>,
    /// Relative duality gap (hinge) or gradient norm (smooth) at exit.
    pub certificate: f64,
    pub from_cache: bool,
}

fn cache_path(ds: &Dataset, loss: Loss, lam: f32) -> PathBuf {
    PathBuf::from("data_cache").join(format!(
        "fstar_{}_{}x{}_{:016x}_{}_{:.3e}.json",
        ds.name.replace('/', "_"),
        ds.n(),
        ds.m(),
        ds.fingerprint(),
        loss.name(),
        lam
    ))
}

fn load_cache(path: &PathBuf) -> Option<(f64, f64)> {
    let text = std::fs::read_to_string(path).ok()?;
    let v = Json::parse(&text).ok()?;
    Some((v.get("fstar")?.as_f64()?, v.get("certificate")?.as_f64()?))
}

fn store_cache(path: &PathBuf, fstar: f64, cert: f64) {
    if let Some(dir) = path.parent() {
        let _ = std::fs::create_dir_all(dir);
    }
    let j = Json::obj(vec![
        ("fstar", Json::num(fstar)),
        ("certificate", Json::num(cert)),
    ]);
    let _ = std::fs::write(path, j.to_string());
}

/// Compute (or fetch from cache) the reference optimum for `(ds, loss, λ)`.
/// `tol` is the relative certificate target (e.g. 1e-7).
pub fn reference_optimum(ds: &Dataset, loss: Loss, lam: f32, tol: f64) -> Reference {
    let path = cache_path(ds, loss, lam);
    if let Some((fstar, cert)) = load_cache(&path) {
        return Reference { fstar, w: Vec::new(), certificate: cert, from_cache: true };
    }
    let r = match loss {
        Loss::Hinge => solve_hinge_sdca(ds, lam, tol),
        _ => solve_smooth_gd(ds, loss, lam, tol),
    };
    store_cache(&path, r.fstar, r.certificate);
    r
}

fn solve_hinge_sdca(ds: &Dataset, lam: f32, tol: f64) -> Reference {
    let part = Partitioned::split(ds, Grid::new(1, 1));
    let n = ds.n();
    let lamn = lam * n as f32;
    let mut alpha = vec![0.0f32; n];
    let mut w = vec![0.0f32; ds.m()];
    let norms = solvers::row_norms(&ds.x);
    let mut rng = Xoshiro::new(0xF57A).substream(n as u64, ds.m() as u64, 0);
    let max_epochs = 4000usize;
    let mut cert = f64::INFINITY;
    let mut fstar = f64::INFINITY;
    for epoch in 0..max_epochs {
        let idx = rng.index_stream(n, n);
        let da = solvers::sdca_epoch(&ds.x, &ds.y, &norms, &alpha, &w, &idx, n, lamn, 1.0, 0.0);
        for (a, d) in alpha.iter_mut().zip(&da) {
            *a += d;
        }
        // exact primal from the dual map (avoids drift of the local w)
        w = objective::primal_from_dual(&part, &alpha, lam);
        if epoch % 5 == 4 || epoch == max_epochs - 1 {
            let f = objective::primal_objective(&part, &w, Loss::Hinge, lam);
            let d = objective::dual_objective(&part, &alpha, lam);
            fstar = f;
            cert = (f - d) / f.abs().max(1e-12);
            if cert < tol {
                break;
            }
        }
    }
    Reference { fstar, w, certificate: cert, from_cache: false }
}

fn solve_smooth_gd(ds: &Dataset, loss: Loss, lam: f32, tol: f64) -> Reference {
    let part = Partitioned::split(ds, Grid::new(1, 1));
    let mut w = vec![0.0f32; ds.m()];
    let mut f = objective::primal_objective(&part, &w, loss, lam);
    let mut step = 1.0f32;
    let mut gnorm = f64::INFINITY;
    for _it in 0..5000 {
        let g = objective::full_gradient(&part, &w, loss, lam);
        gnorm = (linalg::nrm2_sq(&g) as f64).sqrt();
        if gnorm < tol * (1.0 + f.abs()) {
            break;
        }
        // Armijo backtracking
        let g2 = linalg::nrm2_sq(&g) as f64;
        let mut t = (step * 2.0).min(1e3);
        loop {
            let mut w_try = w.clone();
            linalg::axpy(-t, &g, &mut w_try);
            let f_try = objective::primal_objective(&part, &w_try, loss, lam);
            if f_try <= f - 0.5 * t as f64 * g2 || t < 1e-10 {
                w = w_try;
                f = f_try;
                step = t;
                break;
            }
            t *= 0.5;
        }
    }
    Reference { fstar: f, w, certificate: gnorm, from_cache: false }
}

/// Relative optimality difference (f - f*) / f*, the paper's Fig. 3/4
/// y-axis metric.
pub fn relative_gap(f: f64, fstar: f64) -> f64 {
    (f - fstar) / fstar.abs().max(1e-300)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::SyntheticDense;

    #[test]
    fn hinge_reference_certifies() {
        let ds = SyntheticDense::paper_part1(1, 1, 80, 20, 0.1, 11).build();
        let r = solve_hinge_sdca(&ds, 0.1, 1e-6);
        assert!(r.certificate < 1e-6, "gap {}", r.certificate);
        assert!(r.fstar > 0.0);
    }

    #[test]
    fn smooth_reference_certifies() {
        let ds = SyntheticDense::paper_part1(1, 1, 60, 15, 0.1, 13).build();
        let r = solve_smooth_gd(&ds, Loss::Logistic, 0.1, 1e-6);
        assert!(r.certificate < 1e-4, "gnorm {}", r.certificate);
        // logistic loss at w=0 is ln2; the optimum must be below that
        assert!(r.fstar < 0.694);
    }

    #[test]
    fn hinge_beats_any_feasible_dual() {
        let ds = SyntheticDense::paper_part1(1, 1, 50, 10, 0.1, 17).build();
        let part = Partitioned::split(&ds, Grid::new(1, 1));
        let r = solve_hinge_sdca(&ds, 0.2, 1e-7);
        // f* upper-bounds every dual value
        let mut rng = Xoshiro::new(1);
        for _ in 0..5 {
            let a: Vec<f32> = ds.y.iter().map(|&y| y * rng.f32()).collect();
            let d = objective::dual_objective(&part, &a, 0.2);
            assert!(r.fstar >= d - 1e-6);
        }
    }

    #[test]
    fn cache_roundtrip() {
        let ds = SyntheticDense::paper_part1(1, 1, 30, 8, 0.1, 19).build();
        let path = cache_path(&ds, Loss::Hinge, 0.3);
        let _ = std::fs::remove_file(&path);
        let a = reference_optimum(&ds, Loss::Hinge, 0.3, 1e-6);
        assert!(!a.from_cache);
        let b = reference_optimum(&ds, Loss::Hinge, 0.3, 1e-6);
        assert!(b.from_cache);
        assert!((a.fstar - b.fstar).abs() < 1e-12);
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn relative_gap_definition() {
        assert!((relative_gap(1.1, 1.0) - 0.1).abs() < 1e-12);
        assert_eq!(relative_gap(1.0, 1.0), 0.0);
    }
}
