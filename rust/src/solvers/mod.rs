//! Native (pure-rust) implementations of the per-partition compute ops.
//!
//! These mirror the XLA artifacts' semantics exactly (same update
//! equations, same index-stream protocol) so the two backends are
//! interchangeable behind [`crate::runtime::Backend`] and cross-checked in
//! the integration tests.  They also serve the sparse experiments and the
//! exact reference solver that produces `f*`.

pub mod exact;
pub mod objective;
pub mod sdca;
pub mod svrg;

pub use objective::{
    dual_objective, full_gradient, full_margins, grad_from_margins,
    grad_from_margins_into, primal_from_dual, primal_objective,
};
pub use sdca::{row_norms, sdca_epoch, sdca_epoch_into};
pub use svrg::{svrg_block, svrg_block_win};
