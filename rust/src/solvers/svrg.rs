//! Native RADiSA inner loop — Algorithm 3 steps 6-10 with the margin
//! bookkeeping of DESIGN.md (snapshot margins shipped by the coordinator).
//!
//! Matches `python/compile/kernels/svrg.py`: the XLA kernel works on a
//! full-width w with a 0/1 sub-block mask; this native version takes the
//! sub-block as a `[lo, hi)` window for speed.  Integration tests verify
//! the two agree.

use crate::data::{Block, SubblockIndex};
use crate::loss::Loss;

/// Run `l` SVRG steps on the sub-block window `[lo, hi)` of the local
/// feature slice.
///
/// * `w` — local primal block (length m_q), updated in place on `[lo, hi)`.
/// * `wt` — snapshot w̃ block (length m_q); w must equal wt off-window.
/// * `mu` — ∇F(w̃) restricted to the window (length hi−lo), including the
///   λ w̃ regularizer term.
/// * `mt` — snapshot margins X w̃ for this row partition (length n_p).
/// * `idx` — visit order from the coordinator's seeded stream (wraps).
#[allow(clippy::too_many_arguments)]
pub fn svrg_block(
    loss: Loss,
    x: &Block,
    y: &[f32],
    w: &mut [f32],
    wt: &[f32],
    mu: &[f32],
    lo: usize,
    hi: usize,
    mt: &[f32],
    idx: &[i32],
    l: usize,
    eta: f32,
    lam: f32,
) {
    let mut delta_buf = Vec::new();
    svrg_block_win(
        loss, x, y, w, wt, mu, lo, hi, mt, idx, l, eta, lam, None, &mut delta_buf,
    );
}

/// [`svrg_block`] with caller-owned delta scratch and an optional cached
/// window index: when `x` is sparse and `win = Some((index, span))` the
/// per-step window dot/axpy walk exactly the CSR value range of the
/// window (positions precomputed by [`SubblockIndex`], O(nnz in window))
/// instead of scanning every stored entry of the row for an in-window
/// column (O(nnz in row)).  Identical terms in identical order, so the
/// iterates are bit-identical; dense blocks ignore `win`.
#[allow(clippy::too_many_arguments)]
pub fn svrg_block_win(
    loss: Loss,
    x: &Block,
    y: &[f32],
    w: &mut [f32],
    wt: &[f32],
    mu: &[f32],
    lo: usize,
    hi: usize,
    mt: &[f32],
    idx: &[i32],
    l: usize,
    eta: f32,
    lam: f32,
    win: Option<(&SubblockIndex, (usize, usize))>,
    delta_buf: &mut Vec<f32>,
) {
    let n = x.rows();
    debug_assert_eq!(y.len(), n);
    debug_assert_eq!(mt.len(), n);
    debug_assert_eq!(w.len(), wt.len());
    debug_assert_eq!(mu.len(), hi - lo);
    // delta = w - wt on the window (zero elsewhere by contract); the
    // caller-owned buffer reaches its high-water capacity after warmup, so
    // steady-state refills are allocation-free
    delta_buf.clear();
    delta_buf.extend(w[lo..hi].iter().zip(&wt[lo..hi]).map(|(a, b)| a - b));
    let delta = &mut delta_buf[..];
    let sparse_win = x.as_sparse().and_then(|s| win.map(|(ix, span)| (s, ix, span)));
    // The loop maintains only delta = w − wt (w is delta + wt by the
    // off-window contract), so each step is one window pass + one data-row
    // pass; w is materialized once afterwards (§Perf iteration 3).
    for t in 0..l {
        let j = idx[t % idx.len()] as usize;
        debug_assert!(j < n);
        let yj = y[j];
        // full margin via the snapshot identity (w-wt is zero off-window)
        let m_cur = mt[j]
            + match sparse_win {
                Some((s, ix, span)) => {
                    let (a, b) = ix.row_range(j, span);
                    s.range_dot_rebased(a, b, delta, lo)
                }
                None => x.row_dot_window_offset(j, delta, lo, hi),
            };
        let g_cur = loss.slope(m_cur, yj);
        let g_snap = loss.slope(mt[j], yj);
        crate::linalg::svrg_delta(delta, mu, eta, lam);
        if g_cur != g_snap {
            let coeff = -eta * (g_cur - g_snap);
            match sparse_win {
                Some((s, ix, span)) => {
                    let (a, b) = ix.row_range(j, span);
                    s.range_axpy_rebased(a, b, coeff, delta, lo);
                }
                None => x.row_axpy_window_offset(j, coeff, delta, lo, hi),
            }
        }
    }
    for ((wv, &tv), &dv) in w[lo..hi].iter_mut().zip(&wt[lo..hi]).zip(delta.iter()) {
        *wv = tv + dv;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{DenseMatrix, SparseMatrix};
    use crate::util::rng::Xoshiro;

    fn setup(n: usize, m: usize, seed: u64) -> (Block, Vec<f32>, Vec<f32>) {
        let mut r = Xoshiro::new(seed);
        let x = DenseMatrix::from_fn(n, m, |_, _| r.range_f32(-1.0, 1.0));
        let y: Vec<f32> = (0..n)
            .map(|_| if r.coin(0.5) { 1.0 } else { -1.0 })
            .collect();
        let wt: Vec<f32> = (0..m).map(|_| r.range_f32(-0.2, 0.2)).collect();
        (Block::dense(x), y, wt)
    }

    fn snapshot(x: &Block, y: &[f32], wt: &[f32], lo: usize, hi: usize,
                lam: f32, loss: Loss) -> (Vec<f32>, Vec<f32>) {
        let n = x.rows();
        let mut mt = vec![0.0; n];
        x.margins_into(wt, &mut mt);
        let mut psi: Vec<f32> = (0..n)
            .map(|i| loss.slope(mt[i], y[i]) / n as f32)
            .collect();
        let mut g = vec![0.0; x.cols()];
        x.atx_into(&mut psi, &mut g);
        let mu: Vec<f32> = (lo..hi).map(|k| g[k] + lam * wt[k]).collect();
        (mt, mu)
    }

    #[test]
    fn only_window_changes() {
        let (x, y, wt) = setup(20, 12, 1);
        let (lo, hi) = (3, 8);
        let (mt, mu) = snapshot(&x, &y, &wt, lo, hi, 0.1, Loss::Hinge);
        let mut w = wt.clone();
        let mut rng = Xoshiro::new(2);
        let idx = rng.index_stream(20, 20);
        svrg_block(Loss::Hinge, &x, &y, &mut w, &wt, &mu, lo, hi, &mt, &idx,
                   20, 0.05, 0.1);
        for k in 0..12 {
            if k < lo || k >= hi {
                assert_eq!(w[k], wt[k], "coord {k} moved");
            }
        }
        assert!(w[lo..hi].iter().zip(&wt[lo..hi]).any(|(a, b)| a != b));
    }

    #[test]
    fn zero_steps_is_identity() {
        let (x, y, wt) = setup(10, 6, 3);
        let (mt, mu) = snapshot(&x, &y, &wt, 0, 6, 0.1, Loss::Hinge);
        let mut w = wt.clone();
        svrg_block(Loss::Hinge, &x, &y, &mut w, &wt, &mu, 0, 6, &mt, &[0], 0,
                   0.1, 0.1);
        assert_eq!(w, wt);
    }

    #[test]
    fn dense_and_sparse_agree() {
        let (xb, y, wt) = setup(15, 10, 5);
        let xs = Block::sparse(SparseMatrix::from_dense(xb.as_dense().unwrap()));
        let (lo, hi) = (2, 9);
        let (mt, mu) = snapshot(&xb, &y, &wt, lo, hi, 0.2, Loss::Logistic);
        let mut rng = Xoshiro::new(6);
        let idx = rng.index_stream(15, 30);
        let mut wd = wt.clone();
        let mut ws = wt.clone();
        svrg_block(Loss::Logistic, &xb, &y, &mut wd, &wt, &mu, lo, hi, &mt,
                   &idx, 30, 0.05, 0.2);
        svrg_block(Loss::Logistic, &xs, &y, &mut ws, &wt, &mu, lo, hi, &mt,
                   &idx, 30, 0.05, 0.2);
        for k in 0..10 {
            assert!((wd[k] - ws[k]).abs() < 1e-4, "coord {k}");
        }
    }

    #[test]
    fn cached_window_positions_match_scan_bitwise() {
        let mut r = Xoshiro::new(21);
        let (n, m) = (25, 18);
        let mut triplets = Vec::new();
        for i in 0..n {
            for j in 0..m {
                if r.coin(0.25) {
                    triplets.push((i, j, r.range_f32(-1.0, 1.0)));
                }
            }
        }
        let sm = SparseMatrix::from_triplets(n, m, triplets);
        let bounds = vec![0, 6, 12, 18];
        let ix = SubblockIndex::new(&sm, &bounds);
        let x = Block::sparse(sm);
        let y: Vec<f32> = (0..n).map(|_| if r.coin(0.5) { 1.0 } else { -1.0 }).collect();
        let wt: Vec<f32> = (0..m).map(|_| r.range_f32(-0.3, 0.3)).collect();
        let mut mt = vec![0.0; n];
        x.margins_into(&wt, &mut mt);
        let idx = r.index_stream(n, 40);
        for (lo, hi) in [(0, 6), (6, 12), (12, 18), (0, 18)] {
            let mu: Vec<f32> = (lo..hi).map(|k| 0.01 * k as f32).collect();
            let mut w_scan = wt.clone();
            let mut w_fast = wt.clone();
            svrg_block(
                Loss::Hinge, &x, &y, &mut w_scan, &wt, &mu, lo, hi, &mt, &idx, 40, 0.05, 0.1,
            );
            let span = ix.span(lo, hi).unwrap();
            let mut buf = Vec::new();
            svrg_block_win(
                Loss::Hinge, &x, &y, &mut w_fast, &wt, &mu, lo, hi, &mt, &idx, 40, 0.05,
                0.1, Some((&ix, span)), &mut buf,
            );
            for k in 0..m {
                assert_eq!(w_scan[k].to_bits(), w_fast[k].to_bits(), "coord {k} [{lo},{hi})");
            }
        }
    }

    #[test]
    fn single_partition_svrg_descends() {
        // One partition, full window: plain SVRG must reduce F on average.
        let (x, y, _) = setup(80, 10, 7);
        let lam = 0.1f32;
        let loss = Loss::Hinge;
        let wt = vec![0.0f32; 10];
        let (mt, mu) = snapshot(&x, &y, &wt, 0, 10, lam, loss);
        let mut w = wt.clone();
        let mut rng = Xoshiro::new(8);
        let idx = rng.index_stream(80, 160);
        svrg_block(loss, &x, &y, &mut w, &wt, &mu, 0, 10, &mt, &idx, 160,
                   0.1, lam);
        let f = |wv: &[f32]| {
            let mut mg = vec![0.0; 80];
            x.margins_into(wv, &mut mg);
            let loss_sum: f32 = (0..80).map(|i| loss.value(mg[i], y[i])).sum();
            loss_sum / 80.0 + 0.5 * lam * crate::linalg::nrm2_sq(wv)
        };
        assert!(f(&w) < f(&wt), "{} !< {}", f(&w), f(&wt));
    }
}
