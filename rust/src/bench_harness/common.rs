//! Shared plumbing for the experiment harnesses: building method suites,
//! running one experiment cell, and formatting results.

use crate::cluster::{ClusterConfig, ClusterScenario, CostModel};
use crate::coordinator::{
    Admm, AdmmConfig, BetaSchedule, D3ca, D3caConfig, Driver, Optimizer,
    Radisa, RadisaConfig, RunResult,
};
use crate::data::{Dataset, Grid, Partitioned};
use crate::loss::Loss;
use crate::runtime::Backend;
use crate::solvers::exact::reference_optimum;
use anyhow::Result;

/// Which optimizer to instantiate for a cell.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Method {
    Radisa,
    RadisaAvg,
    D3ca,
    Admm,
}

impl Method {
    pub fn all() -> [Method; 4] {
        [Method::Radisa, Method::RadisaAvg, Method::D3ca, Method::Admm]
    }

    pub fn name(&self) -> &'static str {
        match self {
            Method::Radisa => "radisa",
            Method::RadisaAvg => "radisa-avg",
            Method::D3ca => "d3ca",
            Method::Admm => "admm",
        }
    }

    pub fn parse(s: &str) -> Option<Method> {
        match s {
            "radisa" => Some(Method::Radisa),
            "radisa-avg" | "radisa_avg" => Some(Method::RadisaAvg),
            "d3ca" => Some(Method::D3ca),
            "admm" => Some(Method::Admm),
            _ => None,
        }
    }
}

/// One experiment cell: dataset + grid + method + hyper-parameters.
#[derive(Clone, Debug)]
pub struct Cell {
    pub method: Method,
    pub lambda: f32,
    pub gamma: f32,
    pub iterations: usize,
    /// Simulated executor slots (the cost model's K).
    pub cores: usize,
    /// Host worker threads driving the superstep engine.  Defaults to 1:
    /// the figure harnesses charge `CostModel::Measured` per-task times
    /// to the simulated clock, and sequential measurement keeps those
    /// times free of sibling-task cache/bandwidth contention.  Raise it
    /// (or switch to `CostModel::Fixed`) when host wall time is what is
    /// being studied — e.g. the hotpath superstep bench.
    pub threads: usize,
    pub seed: u64,
    pub target_gap: Option<f64>,
    pub batch: usize,
    /// How per-task compute cost is charged (`Fixed` for reproducible
    /// clocks, e.g. the scenario sweeps; `Measured` for fidelity runs).
    pub cost: CostModel,
    /// Cluster-condition scenario (ideal unless the harness sweeps them).
    pub scenario: ClusterScenario,
}

impl Default for Cell {
    fn default() -> Self {
        Cell {
            method: Method::Radisa,
            lambda: 1e-3,
            gamma: 0.0,
            iterations: 30,
            cores: 8,
            threads: 1,
            seed: 1,
            target_gap: None,
            batch: 0,
            cost: CostModel::Measured,
            scenario: ClusterScenario::ideal(),
        }
    }
}

pub fn make_optimizer(cell: &Cell) -> Box<dyn Optimizer> {
    match cell.method {
        Method::Radisa | Method::RadisaAvg => Box::new(Radisa::new(RadisaConfig {
            lambda: cell.lambda,
            loss: Loss::Hinge,
            gamma: cell.gamma,
            batch: cell.batch,
            average: cell.method == Method::RadisaAvg,
            grad_refresh: 1,
            seed: cell.seed,
        })),
        Method::D3ca => Box::new(D3ca::new(D3caConfig {
            lambda: cell.lambda,
            local_epochs: 1.0,
            beta: BetaSchedule::RowNorm,
            seed: cell.seed,
            ..Default::default()
        })),
        Method::Admm => Box::new(Admm::new(AdmmConfig {
            lambda: cell.lambda,
            rho: cell.lambda, // paper: ρ = λ
        })),
    }
}

/// Run one cell on a pre-partitioned dataset with a known f*.
pub fn run_cell(
    part: &Partitioned,
    backend: &Backend,
    cell: &Cell,
    fstar: f64,
) -> Result<RunResult> {
    let mut opt = make_optimizer(cell);
    let mut cluster = ClusterConfig::with_cores(cell.cores)
        .with_threads(cell.threads)
        .with_scenario(cell.scenario.clone());
    cluster.cost = cell.cost;
    let mut driver = Driver::new(part, backend)?
        .iterations(cell.iterations)
        .cluster(cluster)
        .fstar(fstar);
    if let Some(g) = cell.target_gap {
        driver = driver.target_gap(g);
    }
    driver.run(opt.as_mut())
}

/// Compute (cached) f* for a dataset at λ.
pub fn fstar_for(ds: &Dataset, lambda: f32) -> f64 {
    reference_optimum(ds, Loss::Hinge, lambda, 1e-8).fstar
}

/// Partition a dataset over a grid.
pub fn partition(ds: &Dataset, p: usize, q: usize) -> Partitioned {
    Partitioned::split(ds, Grid::new(p, q))
}

/// `results/` output root (created on demand).
pub fn out_dir() -> std::path::PathBuf {
    let d = std::path::PathBuf::from("results");
    std::fs::create_dir_all(&d).ok();
    d
}

/// Format a gap in scientific notation for table rows.
pub fn fmt_gap(g: f64) -> String {
    if g.is_finite() {
        format!("{g:.3e}")
    } else {
        "—".into()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::SyntheticDense;

    #[test]
    fn method_parse_roundtrip() {
        for m in Method::all() {
            assert_eq!(Method::parse(m.name()), Some(m));
        }
        assert_eq!(Method::parse("sgd"), None);
    }

    #[test]
    fn run_cell_native_smoke() {
        let ds = SyntheticDense::paper_part1(2, 2, 30, 20, 0.1, 5).build();
        let part = partition(&ds, 2, 2);
        let backend = Backend::native();
        let fstar = fstar_for(&ds, 0.1);
        for method in Method::all() {
            let cell = Cell {
                method,
                lambda: 0.1,
                iterations: 5,
                gamma: 0.05,
                ..Default::default()
            };
            let r = run_cell(&part, &backend, &cell, fstar).unwrap();
            assert_eq!(r.history.records.len(), 5, "{method:?}");
            assert!(r.sim_time > 0.0, "{method:?}");
        }
    }
}
