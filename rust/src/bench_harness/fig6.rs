//! Figure 6 — weak scaling: efficiency  t₁^{Q,r} / t_P^{Q,r} × 100%  as P
//! grows with per-partition workload fixed, for Q ∈ {2,3,4} and sparsity
//! r ∈ {1%, 5%}, termination at a 5% relative optimality difference.
//!
//! Paper shapes: neither method scales linearly; RADiSA flattens for
//! large Q·P; D3CA's efficiency curves are close across Q; higher
//! sparsity (r) hurts both.  Paper λ: 0.1 (RADiSA), 1.0 (D3CA).

use super::common::{self, Cell, Method};
use super::Scale;
use crate::data::SyntheticSparse;
use crate::metrics::markdown_table;
use anyhow::Result;

/// Per-partition workload.  The paper uses 40,000 × 5,000; `Scale::Paper`
/// here is a 1/5 linear scale (8,000 × 1,000) so the P=7, Q=4, r=5% cell
/// stays within a single-host run — EXPERIMENTS.md documents the scale.
fn per_partition(scale: Scale) -> (usize, usize) {
    match scale {
        Scale::Paper => (8_000, 1_000),
        Scale::Small => (1_000, 250),
    }
}

fn p_values(scale: Scale) -> Vec<usize> {
    match scale {
        Scale::Paper => vec![1, 2, 3, 4, 5, 6, 7],
        Scale::Small => vec![1, 2, 3, 4],
    }
}

fn q_values(scale: Scale) -> Vec<usize> {
    match scale {
        Scale::Paper => vec![2, 3, 4],
        Scale::Small => vec![2, 3],
    }
}

pub fn run(scale: Scale) -> Result<()> {
    let backend = crate::runtime::Backend::native();
    let target = 0.05; // the paper's 5% termination criterion
    let (n_per, m_per) = per_partition(scale);
    for method in [Method::Radisa, Method::D3ca] {
        let lam = match method {
            Method::Radisa => 0.1f32, // paper's λ for RADiSA
            _ => 1.0,                 // paper's λ for D3CA
        };
        for r_sparsity in [0.01f64, 0.05] {
            let mut rows = Vec::new();
            for q in q_values(scale) {
                let mut t1: Option<f64> = None;
                for p in p_values(scale) {
                    // grow the instance with P so per-partition work is fixed
                    let ds = SyntheticSparse::new(
                        &format!("weak-r{}", (r_sparsity * 100.0) as u32),
                        n_per * p,
                        m_per * q,
                        r_sparsity,
                        11,
                    )
                    .build();
                    let part = common::partition(&ds, p, q);
                    let fstar = common::fstar_for(&ds, lam);
                    let cell = Cell {
                        method,
                        lambda: lam,
                        gamma: 0.1,
                        iterations: 150,
                        cores: p * q,
                        target_gap: Some(target),
                        ..Default::default()
                    };
                    let run = common::run_cell(&part, &backend, &cell, fstar)?;
                    let tp = run
                        .history
                        .time_to_gap(target)
                        .unwrap_or(run.sim_time * 2.0); // censored
                    if p == 1 {
                        t1 = Some(tp);
                    }
                    let eff = t1.map(|t| 100.0 * t / tp).unwrap_or(f64::NAN);
                    rows.push(vec![
                        format!("{q}"),
                        format!("{p}"),
                        format!("{tp:.3}"),
                        format!("{eff:.1}%"),
                    ]);
                }
            }
            let table = markdown_table(&["Q", "P", "sim time (s)", "efficiency"], &rows);
            println!(
                "\n# Fig6  {}  r={:.0}%  λ={lam}",
                method.name(),
                r_sparsity * 100.0
            );
            println!("{table}");
            std::fs::write(
                common::out_dir().join(format!(
                    "fig6_{}_r{}.md",
                    method.name(),
                    (r_sparsity * 100.0) as u32
                )),
                table,
            )?;
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_scale_matches_paper_dims() {
        // 1/5 linear scale of the paper's 40,000 × 5,000 partitions
        assert_eq!(per_partition(Scale::Paper), (8_000, 1_000));
        assert_eq!(p_values(Scale::Paper), vec![1, 2, 3, 4, 5, 6, 7]);
        assert_eq!(q_values(Scale::Paper), vec![2, 3, 4]);
    }
}
