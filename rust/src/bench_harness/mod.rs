//! Bench harness: one module per table/figure of the paper's evaluation.
//!
//! Each module exposes `run(scale, out_dir)` printing the paper's
//! rows/series and writing CSV/JSON under `results/`.  Invoked from the
//! CLI (`ddopt exp <id>`) and from `cargo bench` (custom harness bins in
//! `rust/benches/`).

pub mod ablations;
pub mod common;
pub mod fig3;
pub mod fig4;
pub mod fig5;
pub mod fig6;
pub mod perf;
pub mod stragglers;
pub mod table1;

/// Experiment scale: `Small` finishes in seconds on a laptop core,
/// `Paper` uses the paper's dimensions (documented in EXPERIMENTS.md).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Scale {
    Small,
    Paper,
}

impl Scale {
    pub fn parse(s: &str) -> Option<Scale> {
        match s {
            "small" => Some(Scale::Small),
            "paper" => Some(Scale::Paper),
            _ => None,
        }
    }
}
