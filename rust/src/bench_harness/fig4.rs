//! Figure 4 — relative optimality difference vs *iteration count* (50
//! iterations, 4×2 instance): the per-iteration progress comparison that
//! shows ADMM "needs a much larger number of iterations".

use super::common::{self, Cell, Method};
use super::{table1, Scale};
use crate::metrics::{markdown_table, write_json_report};
use anyhow::Result;

pub fn run(scale: Scale) -> Result<()> {
    let (n_per, m_per) = table1::partition_dims(scale);
    let (p, q) = (4, 2);
    // paper plots 1e-4; we use 1e-3 at paper scale so the certified f*
    // (SDCA to 1e-8 gap on the 48M-entry instance) is computable within
    // the testbed budget — the qualitative per-iteration ordering is
    // unaffected (see EXPERIMENTS.md)
    let lam = match scale {
        Scale::Paper => 1e-3,
        Scale::Small => 1e-1,
    };
    let ds = crate::data::SyntheticDense::paper_part1(p, q, n_per, m_per, 0.1, 42).build();
    let part = common::partition(&ds, p, q);
    let backend = crate::runtime::Backend::native();
    let fstar = common::fstar_for(&ds, lam);
    println!("\n# Fig4  {p}x{q}  lambda={lam:.0e}  50 iterations");
    let mut runs = Vec::new();
    for method in Method::all() {
        let cell = Cell {
            method,
            lambda: lam,
            gamma: 0.0, // auto step-size rule
            iterations: 50,
            cores: p * q,
            ..Default::default()
        };
        let r = common::run_cell(&part, &backend, &cell, fstar)?;
        runs.push((method.name().to_string(), r));
    }
    // print the gap at checkpoints — the figure's series
    let checkpoints = [1usize, 5, 10, 20, 30, 40, 50];
    let mut rows = Vec::new();
    for (name, r) in &runs {
        let mut row = vec![name.clone()];
        for &cp in &checkpoints {
            let g = r
                .history
                .records
                .iter()
                .find(|x| x.iter == cp)
                .map(|x| common::fmt_gap(x.rel_gap))
                .unwrap_or_else(|| "—".into());
            row.push(g);
        }
        rows.push(row);
    }
    let headers: Vec<String> = std::iter::once("method".to_string())
        .chain(checkpoints.iter().map(|c| format!("it{c}")))
        .collect();
    let hdr_refs: Vec<&str> = headers.iter().map(|s| s.as_str()).collect();
    let table = markdown_table(&hdr_refs, &rows);
    println!("{table}");
    std::fs::write(common::out_dir().join("fig4.md"), &table)?;
    let refs: Vec<(String, &crate::metrics::Recorder)> =
        runs.iter().map(|(n, r)| (n.clone(), &r.history)).collect();
    write_json_report("fig4", &refs, &common::out_dir().join("fig4.json"))?;

    // the paper's qualitative claim, asserted mechanically
    let gap_of = |name: &str| {
        runs.iter()
            .find(|(n, _)| n == name)
            .unwrap()
            .1
            .history
            .best_gap()
    };
    if gap_of("radisa") < gap_of("admm") && gap_of("d3ca") < gap_of("admm") {
        println!("shape-check OK: RADiSA and D3CA ahead of ADMM at 50 iterations");
    } else {
        println!("shape-check FAILED: ADMM not behind at 50 iterations");
    }
    Ok(())
}
