//! Figure 5 — strong scaling: simulated time to a 1% relative optimality
//! difference for increasing K over the (P,Q) configurations of each K,
//! on the real-sim-like and news20-like sparse data sets.
//!
//! Paper shapes to check: RADiSA scales consistently and prefers P > Q;
//! D3CA is mixed (helped on the larger set when P > Q, hurt on the small
//! set) and prefers Q > P; the P<Q vs P>Q difference shrinks as K grows.
//! Paper hyper-parameters: λ = 1e-3 (RADiSA), 1e-2 (D3CA); ours are per
//! scale below (the stand-in datasets are smaller — see DESIGN.md).

use super::common::{self, Cell, Method};
use super::Scale;
use crate::data::SyntheticSparse;
use crate::metrics::markdown_table;
use anyhow::Result;

/// The paper's K → [(P,Q)] ladder (Fig. 5's x-axis groups).
pub fn configs() -> Vec<(usize, Vec<(usize, usize)>)> {
    vec![
        (4, vec![(4, 1), (2, 2), (1, 4)]),
        (8, vec![(8, 1), (4, 2), (2, 4), (1, 8)]),
        (16, vec![(8, 2), (4, 4), (2, 8)]),
    ]
}

fn datasets(scale: Scale) -> Vec<SyntheticSparse> {
    match scale {
        // DESIGN.md substitutions: shape/sparsity-matched stand-ins
        Scale::Paper => vec![
            SyntheticSparse::realsim_like(7),
            SyntheticSparse::news20_like(7),
        ],
        Scale::Small => vec![
            SyntheticSparse::new("realsim-mini", 2048, 640, 0.01, 7),
            SyntheticSparse::new("news20-mini", 1024, 4096, 0.003, 7),
        ],
    }
}

pub fn run(scale: Scale) -> Result<()> {
    let backend = crate::runtime::Backend::native();
    let target = 0.01; // 1% relative optimality difference
    for gen in datasets(scale) {
        let ds = gen.build();
        println!(
            "\n# Fig5  {}  ({}x{}, sparsity {:.3}%)",
            ds.name,
            ds.n(),
            ds.m(),
            100.0 * ds.sparsity()
        );
        for method in [Method::Radisa, Method::D3ca] {
            // per-method λ in the spirit of the paper's (1e-3, 1e-2) split
            let lam = match method {
                Method::Radisa => 0.03f32,
                _ => 0.1,
            };
            let fstar = common::fstar_for(&ds, lam);
            let mut rows = Vec::new();
            for (k, grids) in configs() {
                for (p, q) in grids {
                    if p > ds.n() || q > ds.m() {
                        continue;
                    }
                    let part = common::partition(&ds, p, q);
                    let cell = Cell {
                        method,
                        lambda: lam,
                        gamma: 0.0, // auto rule = paper's P-aware adjustment
                        iterations: 120,
                        cores: k,
                        target_gap: Some(target),
                        // paper: "we keep the overall number of data points
                        // processed constant as we increase K" → L = n/K
                        batch: (ds.n() / (p * q)).max(1),
                        ..Default::default()
                    };
                    let r = common::run_cell(&part, &backend, &cell, fstar)?;
                    let t = r.history.time_to_gap(target);
                    rows.push(vec![
                        format!("{k}"),
                        format!("({p},{q})"),
                        t.map(|v| format!("{v:.3}"))
                            .unwrap_or_else(|| format!(">{:.3}", r.sim_time)),
                        common::fmt_gap(r.history.best_gap()),
                    ]);
                }
            }
            let table = markdown_table(
                &["K", "(P,Q)", "sim time to 1% (s)", "best gap"],
                &rows,
            );
            println!("\n## {} (λ={lam:.0e})", method.name());
            println!("{table}");
            std::fs::write(
                common::out_dir().join(format!("fig5_{}_{}.md", ds.name, method.name())),
                table,
            )?;
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn config_ladder_matches_paper_axis() {
        let c = configs();
        assert_eq!(c[0].0, 4);
        assert!(c[1].1.contains(&(4, 2)));
        // every (p,q) multiplies to its K
        for (k, grids) in c {
            for (p, q) in grids {
                assert_eq!(p * q, k);
            }
        }
    }
}
