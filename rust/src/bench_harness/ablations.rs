//! Ablation studies on the design choices DESIGN.md calls out, plus the
//! paper's §V extensions implemented in this repo:
//!
//! * D3CA dual-averaging factor: the paper's 1/(P·Q) vs plain 1/Q.
//! * D3CA β step-size schedules in the small-λ regime.
//! * D3CA local epochs H (communication/computation trade-off).
//! * D3CA primal recovery: full recompute vs the exact incremental
//!   update (paper §V's "bottleneck of the primal vector computation").
//! * RADiSA batch size L.
//! * RADiSA delayed gradient refresh (paper §V's "delaying the gradient
//!   updates", practical-SVRG style).
//!
//! `ddopt exp ablations [--scale small|paper]`.

use super::common;
use super::Scale;
use crate::cluster::ClusterConfig;
use crate::coordinator::{
    BetaSchedule, D3ca, D3caConfig, Driver, Optimizer, Radisa, RadisaConfig,
};
use crate::data::{Partitioned, SyntheticDense};
use crate::metrics::markdown_table;
use crate::runtime::Backend;
use anyhow::Result;

fn run_one(
    part: &Partitioned,
    backend: &Backend,
    opt: &mut dyn Optimizer,
    iters: usize,
    fstar: f64,
) -> Result<(f64, f64, f64)> {
    let t = crate::util::timer::Timer::start();
    let r = Driver::new(part, backend)?
        .iterations(iters)
        // threads=1: Measured-cost sim times stay contention-free and
        // comparable across ablation cells (see bench_harness::common)
        .cluster(ClusterConfig::with_cores(part.grid.k()).with_threads(1))
        .fstar(fstar)
        .run(opt)?;
    Ok((r.history.best_gap(), r.sim_time, t.secs()))
}

pub fn run(scale: Scale) -> Result<()> {
    let (n_per, m_per) = match scale {
        Scale::Paper => (1000, 800),
        Scale::Small => (150, 100),
    };
    let (p, q) = (3, 2);
    let iters = 25;
    let ds = SyntheticDense::paper_part1(p, q, n_per, m_per, 0.1, 77).build();
    let part = common::partition(&ds, p, q);
    let backend = Backend::native();
    let lam = 0.1f32;
    let fstar = common::fstar_for(&ds, lam);
    println!(
        "# Ablations on {} ({}x{}, grid {p}x{q}, λ={lam}, {iters} iters)\n",
        ds.name,
        ds.n(),
        ds.m()
    );
    let mut sections: Vec<(String, String)> = Vec::new();

    // ---- D3CA averaging factor ---------------------------------------
    let mut rows = Vec::new();
    for (label, avg_pq) in [("1/(P·Q) (paper)", true), ("1/Q", false)] {
        let mut opt = D3ca::new(D3caConfig { lambda: lam, avg_pq, ..Default::default() });
        let (gap, sim, _) = run_one(&part, &backend, &mut opt, iters, fstar)?;
        rows.push(vec![label.into(), common::fmt_gap(gap), format!("{sim:.4}")]);
    }
    sections.push((
        "D3CA dual-averaging factor".into(),
        markdown_table(&["factor", "best gap", "sim time (s)"], &rows),
    ));

    // ---- D3CA beta schedules at small λ --------------------------------
    let lam_small = 1e-3f32;
    let fstar_small = common::fstar_for(&ds, lam_small);
    let mut rows = Vec::new();
    for (label, beta) in [
        ("‖x_i‖² (vanilla)", BetaSchedule::RowNorm),
        ("const E‖x‖²", BetaSchedule::Const(m_per as f32 * q as f32)),
        ("λn/t (paper-style)", BetaSchedule::LambdaNOverT),
    ] {
        let mut opt = D3ca::new(D3caConfig { lambda: lam_small, beta, ..Default::default() });
        let (gap, _, _) = run_one(&part, &backend, &mut opt, iters, fstar_small)?;
        rows.push(vec![label.into(), common::fmt_gap(gap)]);
    }
    sections.push((
        format!("D3CA β schedule at λ={lam_small:.0e} (the erratic regime)"),
        markdown_table(&["β", "best gap"], &rows),
    ));

    // ---- D3CA local epochs ---------------------------------------------
    let mut rows = Vec::new();
    for h in [0.25f32, 0.5, 1.0, 2.0] {
        let mut opt = D3ca::new(D3caConfig { lambda: lam, local_epochs: h, ..Default::default() });
        let (gap, sim, _) = run_one(&part, &backend, &mut opt, iters, fstar)?;
        rows.push(vec![format!("{h}"), common::fmt_gap(gap), format!("{sim:.4}")]);
    }
    sections.push((
        "D3CA local epochs H/n_p (compute per round vs rounds)".into(),
        markdown_table(&["H/n_p", "best gap", "sim time (s)"], &rows),
    ));

    // ---- D3CA primal recovery (§V extension) ---------------------------
    let mut rows = Vec::new();
    for (label, inc) in [("full recompute", false), ("incremental (§V)", true)] {
        let mut opt = D3ca::new(D3caConfig {
            lambda: lam,
            local_epochs: 0.25, // sparse Δα — where incremental pays off
            incremental_primal: inc,
            ..Default::default()
        });
        let (gap, sim, wall) = run_one(&part, &backend, &mut opt, iters, fstar)?;
        rows.push(vec![
            label.into(),
            common::fmt_gap(gap),
            format!("{sim:.4}"),
            format!("{wall:.4}"),
        ]);
    }
    sections.push((
        "D3CA primal recovery at H = n_p/4".into(),
        markdown_table(&["mode", "best gap", "sim time (s)", "wall (s)"], &rows),
    ));

    // ---- RADiSA batch size ----------------------------------------------
    let n_p = part.n_p(0);
    let mut rows = Vec::new();
    for (label, batch) in [("n_p/4", n_p / 4), ("n_p", 0), ("2·n_p", 2 * n_p)] {
        let mut opt = Radisa::new(RadisaConfig { lambda: lam, batch, ..Default::default() });
        let (gap, sim, _) = run_one(&part, &backend, &mut opt, iters, fstar)?;
        rows.push(vec![label.into(), common::fmt_gap(gap), format!("{sim:.4}")]);
    }
    sections.push((
        "RADiSA batch size L".into(),
        markdown_table(&["L", "best gap", "sim time (s)"], &rows),
    ));

    // ---- RADiSA delayed gradient (§V extension) -------------------------
    let mut rows = Vec::new();
    for k in [1usize, 2, 4] {
        let mut opt = Radisa::new(RadisaConfig {
            lambda: lam,
            grad_refresh: k,
            ..Default::default()
        });
        // keep total inner work comparable: fewer outer iterations
        let outer = (iters / k).max(1);
        let (gap, sim, _) = run_one(&part, &backend, &mut opt, outer, fstar)?;
        rows.push(vec![
            format!("{k}"),
            format!("{outer}"),
            common::fmt_gap(gap),
            format!("{sim:.4}"),
        ]);
    }
    sections.push((
        "RADiSA gradient refresh interval (rounds per snapshot)".into(),
        markdown_table(&["rounds", "outer iters", "best gap", "sim time (s)"], &rows),
    ));

    let mut doc = String::new();
    for (title, table) in sections {
        println!("## {title}\n{table}");
        doc.push_str(&format!("## {title}\n{table}\n"));
    }
    std::fs::write(common::out_dir().join("ablations.md"), doc)?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ablations_small_runs() {
        run(Scale::Small).unwrap();
        assert!(std::path::Path::new("results/ablations.md").exists());
    }
}
