//! Figure 3 — relative optimality difference vs elapsed (simulated
//! cluster) time, for the three Part-1 data sets × two regularization
//! values, methods RADiSA / RADiSA-avg / D3CA / ADMM.
//!
//! Prints one series block per (grid, λ) and writes
//! `results/fig3_<PxQ>_<lam>.{csv,json}` for plotting.  Paper shape to
//! check: RADiSA-avg best, RADiSA close second, both ahead of D3CA, all
//! far ahead of ADMM.

use super::common::{self, Cell, Method};
use super::{table1, Scale};
use crate::metrics::{write_csv, write_json_report};
use anyhow::Result;

pub fn lambdas(scale: Scale) -> Vec<f32> {
    match scale {
        // the paper plots 1e-3 / 1e-4 (and 1e-5 on the largest set)
        Scale::Paper => vec![1e-3, 1e-4],
        // scaled-down instances need proportionally larger λ to stay in
        // the regime where all four methods make progress
        Scale::Small => vec![1e-1, 3e-2],
    }
}

fn iterations(scale: Scale, method: Method) -> usize {
    let base = match scale {
        Scale::Paper => 30,
        Scale::Small => 30,
    };
    match method {
        Method::Admm => base * 4, // ADMM needs far more iterations (paper Fig. 4)
        _ => base,
    }
}

/// γ: the auto rule (0.0 → P·Q/E‖x‖²) replaces the paper's per-instance
/// hand tuning at both scales.
fn gamma(_scale: Scale) -> f32 {
    0.0
}

pub fn run(scale: Scale) -> Result<()> {
    let (n_per, m_per) = table1::partition_dims(scale);
    let backend = crate::runtime::Backend::native();
    for (p, q) in table1::GRIDS {
        let ds = crate::data::SyntheticDense::paper_part1(p, q, n_per, m_per, 0.1, 42).build();
        let part = common::partition(&ds, p, q);
        for lam in lambdas(scale) {
            let fstar = common::fstar_for(&ds, lam);
            println!("\n# Fig3  {p}x{q}  lambda={lam:.0e}  (f* = {fstar:.6})");
            println!("{:<12} {:>10} {:>12} {:>12}", "method", "iters", "final gap", "sim time s");
            let mut runs = Vec::new();
            for method in Method::all() {
                let cell = Cell {
                    method,
                    lambda: lam,
                    gamma: gamma(scale),
                    iterations: iterations(scale, method),
                    cores: p * q,
                    ..Default::default()
                };
                let r = common::run_cell(&part, &backend, &cell, fstar)?;
                println!(
                    "{:<12} {:>10} {:>12} {:>12.4}",
                    method.name(),
                    r.history.records.len(),
                    common::fmt_gap(r.history.best_gap()),
                    r.sim_time
                );
                let csv = common::out_dir()
                    .join(format!("fig3_{p}x{q}_{lam:.0e}_{}.csv", method.name()));
                write_csv(&r.history, &csv)?;
                runs.push((method.name().to_string(), r));
            }
            let refs: Vec<(String, &crate::metrics::Recorder)> =
                runs.iter().map(|(n, r)| (n.clone(), &r.history)).collect();
            write_json_report(
                &format!("fig3_{p}x{q}_{lam:.0e}"),
                &refs,
                &common::out_dir().join(format!("fig3_{p}x{q}_{lam:.0e}.json")),
            )?;
        }
        if scale == Scale::Small {
            // keep the small run quick: one grid is enough for shape checks
            break;
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lambda_sets_nonempty() {
        assert_eq!(lambdas(Scale::Paper).len(), 2);
        assert!(iterations(Scale::Small, Method::Admm) > iterations(Scale::Small, Method::Radisa));
    }
}
