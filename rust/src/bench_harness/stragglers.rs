//! Cluster-scenario sweep — the paper's qualitative straggler claim, made
//! measurable.
//!
//! RADiSA-avg exists precisely because "the coordinator does not wait for
//! stragglers" (paper §IV): its combine is an average of full-block
//! partial solutions, so transient tail events don't extend its
//! supersteps, while D3CA / plain RADiSA / ADMM concatenate or reduce and
//! must wait.  This harness sweeps [`ClusterScenario`]s (ideal, straggler
//! tails of increasing severity, speculative re-execution, heterogeneous
//! slots, task failures) across all four methods under
//! [`CostModel::Fixed`], so every simulated clock is bit-reproducible:
//! same scenario seed → identical JSON, any `--threads` → identical
//! everything.  The headline table reports RADiSA-avg's sim-time speedup
//! over plain RADiSA per scenario.

use super::common::{self, Cell, Method};
use super::Scale;
use crate::cluster::{ClusterScenario, CostModel};
use crate::data::SyntheticDense;
use crate::metrics::markdown_table;
use crate::util::json::Json;
use anyhow::Result;
use std::collections::BTreeMap;

/// The swept scenarios; `seed` keys every injection draw.
pub fn scenarios(seed: u64) -> Vec<(&'static str, ClusterScenario)> {
    let seeded = |spec: &str| -> ClusterScenario {
        let mut sc = ClusterScenario::parse(spec).expect("static scenario spec");
        sc.seed = seed;
        sc
    };
    vec![
        ("ideal", ClusterScenario::ideal()),
        ("stragglers-mild", seeded("stragglers:p=0.1,slow=4x")),
        ("stragglers-heavy", seeded("stragglers:p=0.3,slow=10x")),
        ("stragglers-spec", seeded("stragglers:p=0.3,slow=10x,spec")),
        ("hetero", seeded("hetero:frac=0.25,speed=0.25")),
        ("failures", seeded("failures:p=0.1,retries=3")),
    ]
}

/// One (scenario, method) measurement.
#[derive(Clone, Debug)]
pub struct SweepRow {
    pub scenario: String,
    pub method: &'static str,
    pub sim_time: f64,
    pub comm_bytes: usize,
    pub messages: usize,
    pub stragglers: usize,
    pub failures: usize,
    pub best_gap: f64,
}

/// Run the sweep and return every row (the CLI entry point prints and
/// writes JSON; tests call this directly).
pub fn sweep(scale: Scale, seed: u64) -> Result<Vec<SweepRow>> {
    let backend = crate::runtime::Backend::native();
    let (n_per, m_per, iters) = match scale {
        Scale::Paper => (240usize, 160usize, 20usize),
        Scale::Small => (40, 24, 6),
    };
    let (p, q) = (4usize, 2usize);
    let ds = SyntheticDense::paper_part1(p, q, n_per, m_per, 0.1, 7).build();
    let part = common::partition(&ds, p, q);
    let lam = 0.1f32;
    let fstar = common::fstar_for(&ds, lam);
    let mut rows = Vec::new();
    for (label, scenario) in scenarios(seed) {
        for method in Method::all() {
            let cell = Cell {
                method,
                lambda: lam,
                gamma: 0.05,
                iterations: iters,
                cores: p * q,
                cost: CostModel::Fixed(1e-3),
                scenario: scenario.clone(),
                ..Default::default()
            };
            let r = common::run_cell(&part, &backend, &cell, fstar)?;
            rows.push(SweepRow {
                scenario: label.to_string(),
                method: method.name(),
                sim_time: r.sim_time,
                comm_bytes: r.comm_bytes,
                messages: r.messages,
                stragglers: r.stragglers,
                failures: r.failures,
                best_gap: r.history.best_gap(),
            });
        }
    }
    Ok(rows)
}

pub fn run(scale: Scale, seed: u64) -> Result<()> {
    println!("\n# Stragglers  grid 4x2  λ=1e-1  CostModel::Fixed(1ms)  scenario seed {seed}");
    let rows = sweep(scale, seed)?;

    let table_rows: Vec<Vec<String>> = rows
        .iter()
        .map(|r| {
            vec![
                r.scenario.clone(),
                r.method.to_string(),
                format!("{:.4}", r.sim_time),
                r.stragglers.to_string(),
                r.failures.to_string(),
                common::fmt_gap(r.best_gap),
            ]
        })
        .collect();
    let table = markdown_table(
        &["scenario", "method", "sim time (s)", "stragglers", "failures", "best gap"],
        &table_rows,
    );
    println!("{table}");

    // headline: RADiSA-avg's non-waiting combine vs plain RADiSA
    let sim: BTreeMap<(&str, &str), f64> = rows
        .iter()
        .map(|r| ((r.scenario.as_str(), r.method), r.sim_time))
        .collect();
    println!("## radisa-avg sim-time speedup over radisa");
    for (label, _) in scenarios(seed) {
        if let (Some(&plain), Some(&avg)) =
            (sim.get(&(label, "radisa")), sim.get(&(label, "radisa-avg")))
        {
            println!("{label:<18} {:>6.2}x", plain / avg);
        }
    }

    let doc = Json::obj(vec![
        ("experiment", Json::str("stragglers")),
        ("seed", Json::from(seed as usize)),
        (
            "scale",
            Json::str(if scale == Scale::Paper { "paper" } else { "small" }),
        ),
        (
            "rows",
            Json::arr(rows.iter().map(|r| {
                Json::obj(vec![
                    ("scenario", Json::str(&r.scenario)),
                    ("method", Json::str(r.method)),
                    ("sim_time", Json::num(r.sim_time)),
                    ("comm_bytes", Json::from(r.comm_bytes)),
                    ("messages", Json::from(r.messages)),
                    ("stragglers", Json::from(r.stragglers)),
                    ("failures", Json::from(r.failures)),
                    ("best_gap", Json::num(r.best_gap)),
                ])
            })),
        ),
    ]);
    let path = common::out_dir().join(format!("stragglers_seed{seed}.json"));
    std::fs::write(&path, doc.to_string())?;
    println!("\nrows -> {}", path.display());
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scenario_suite_covers_the_claims() {
        let sc = scenarios(3);
        assert_eq!(sc[0].1, ClusterScenario::ideal());
        assert!(sc.iter().any(|(l, s)| l.starts_with("stragglers") && s.straggler_p > 0.0));
        assert!(sc.iter().any(|(_, s)| s.hetero_frac > 0.0));
        assert!(sc.iter().any(|(_, s)| s.failure_p > 0.0));
        assert!(sc.iter().any(|(_, s)| s.speculative));
        // every non-ideal scenario carries the sweep seed
        for (label, s) in &sc {
            if *label != "ideal" {
                assert_eq!(s.seed, 3, "{label}");
            }
        }
    }
}
