//! Table I — "Datasets for Numerical Experiments (Part 1)".
//!
//! Regenerates the paper's dataset-size table for the three Part-1 grids:
//! nonzero entries and cores used per (P,Q), built with the paper's
//! generator.  At `Scale::Paper` the partitions are the paper's dense
//! 2,000×3,000 (nonzeros 48M/90M/168M); at `Scale::Small` a 1/10 linear
//! scale keeps CI fast while preserving the ratios.

use super::{common, Scale};
use crate::data::SyntheticDense;
use crate::metrics::markdown_table;
use anyhow::Result;

pub const GRIDS: [(usize, usize); 3] = [(4, 2), (5, 3), (7, 4)];

pub fn partition_dims(scale: Scale) -> (usize, usize) {
    match scale {
        Scale::Paper => (2000, 3000),
        Scale::Small => (200, 300),
    }
}

pub fn run(scale: Scale) -> Result<()> {
    let (n_per, m_per) = partition_dims(scale);
    let mut rows = Vec::new();
    for (p, q) in GRIDS {
        let gen = SyntheticDense::paper_part1(p, q, n_per, m_per, 0.1, 42);
        let ds = gen.build();
        let nnz = ds.x.nnz();
        rows.push(vec![
            format!("{p}x{q}"),
            format!("{}x{}", ds.n(), ds.m()),
            format!("{:.1}M", nnz as f64 / 1e6),
            format!("{}", p * q),
        ]);
    }
    let table = markdown_table(
        &["P x Q", "instance", "nonzero entries", "cores used"],
        &rows,
    );
    println!("Table I (scale {scale:?}; paper: 48M / 90M / 168M nonzeros)");
    println!("{table}");
    std::fs::write(common::out_dir().join("table1.md"), table)?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_scale_dims_match_paper() {
        let (n_per, m_per) = partition_dims(Scale::Paper);
        // 4x2 grid -> 8,000 x 6,000 = 48M dense entries, as in Table I
        assert_eq!(4 * n_per * 2 * m_per, 48_000_000);
        assert_eq!(5 * n_per * 3 * m_per, 90_000_000);
        assert_eq!(7 * n_per * 4 * m_per, 168_000_000);
    }

    #[test]
    fn small_scale_run_prints() {
        run(Scale::Small).unwrap();
        assert!(std::path::Path::new("results/table1.md").exists());
    }
}
