//! §Perf — the whole-stack profiling harness behind EXPERIMENTS.md §Perf.
//!
//! L3: native kernel throughput (GFLOP/s for margins/atx, steps/s for
//! SDCA/SVRG) + coordinator overhead (iteration time minus kernel time).
//! L2/XLA: per-op execute times through the PJRT engine, compile cost,
//! staging footprint.
//! L1: analytic VMEM/MXU estimates for the Pallas BlockSpecs (interpret
//! mode gives no real TPU timing — see DESIGN.md §Hardware-Adaptation).

use super::common;
use super::Scale;
use crate::cluster::ClusterConfig;
use crate::coordinator::{D3ca, D3caConfig, Driver, Radisa, RadisaConfig};
use crate::data::{Grid, Partitioned, SyntheticDense};
use crate::metrics::markdown_table;
use crate::runtime::Backend;
use crate::util::timer::Timer;
use anyhow::Result;

fn gflops(flops: f64, secs: f64) -> f64 {
    flops / secs / 1e9
}

/// Native kernel micro-benchmarks.
pub fn native_kernels(n: usize, m: usize, reps: usize) -> Vec<(String, f64)> {
    let ds = SyntheticDense::paper_part1(1, 1, n, m, 0.1, 3).build();
    let mut rng = crate::util::rng::Xoshiro::new(1);
    let w: Vec<f32> = (0..m).map(|_| rng.range_f32(-1.0, 1.0)).collect();
    let v: Vec<f32> = (0..n).map(|_| rng.range_f32(-1.0, 1.0)).collect();
    let mut out_n = vec![0.0f32; n];
    let mut out_m = vec![0.0f32; m];
    let mut results = Vec::new();

    let t = Timer::start();
    for _ in 0..reps {
        ds.x.margins_into(&w, &mut out_n);
    }
    results.push((
        "margins GFLOP/s".into(),
        gflops(2.0 * (n * m * reps) as f64, t.secs()),
    ));

    let t = Timer::start();
    for _ in 0..reps {
        ds.x.atx_into(&v, &mut out_m);
    }
    results.push((
        "atx GFLOP/s".into(),
        gflops(2.0 * (n * m * reps) as f64, t.secs()),
    ));

    let lamn = 0.1 * n as f32;
    let alpha = vec![0.0f32; n];
    let norms = crate::solvers::row_norms(&ds.x);
    let idx = rng.index_stream(n, n);
    let t = Timer::start();
    for _ in 0..reps {
        let _ = crate::solvers::sdca_epoch(&ds.x, &ds.y, &norms, &alpha, &w, &idx, n, lamn, 1.0, 0.0);
    }
    results.push((
        "sdca Msteps/s".into(),
        (n * reps) as f64 / t.secs() / 1e6,
    ));

    let wt = w.clone();
    let mut mt = vec![0.0f32; n];
    ds.x.margins_into(&wt, &mut mt);
    let mu = vec![0.0f32; m];
    let t = Timer::start();
    for _ in 0..reps {
        let mut wrun = wt.clone();
        crate::solvers::svrg_block(
            crate::loss::Loss::Hinge,
            &ds.x,
            &ds.y,
            &mut wrun,
            &wt,
            &mu,
            0,
            m,
            &mt,
            &idx,
            n,
            0.01,
            0.1,
        );
    }
    results.push((
        "svrg Msteps/s".into(),
        (n * reps) as f64 / t.secs() / 1e6,
    ));
    results
}

/// Coordinator overhead: share of an iteration spent outside the compute
/// kernels (aggregation, scheduling, allocation).
pub fn coordinator_overhead() -> Result<Vec<(String, f64)>> {
    let ds = SyntheticDense::paper_part1(4, 2, 256, 192, 0.1, 5).build();
    let part = Partitioned::split(&ds, Grid::new(4, 2));
    let backend = Backend::native();
    let mut out = Vec::new();
    for method in ["d3ca", "radisa"] {
        let t = Timer::start();
        let r = match method {
            "d3ca" => {
                let mut opt = D3ca::new(D3caConfig { lambda: 0.1, ..Default::default() });
                Driver::new(&part, &backend)?
                    .iterations(10)
                    .eval_every(usize::MAX) // exclude evaluation cost
                    // threads=1 so sim compute ≈ host kernel time
                    .cluster(ClusterConfig::with_cores(8).with_threads(1))
                    .run(&mut opt)?
            }
            _ => {
                let mut opt = Radisa::new(RadisaConfig { lambda: 0.1, gamma: 0.05, ..Default::default() });
                Driver::new(&part, &backend)?
                    .iterations(10)
                    .eval_every(usize::MAX)
                    .cluster(ClusterConfig::with_cores(8).with_threads(1))
                    .run(&mut opt)?
            }
        };
        let wall = t.secs();
        out.push((format!("{method} wall s/10it"), wall));
        out.push((format!("{method} overhead frac"), (wall - r.sim_time).max(0.0) / wall));
    }
    Ok(out)
}

/// XLA engine op timings at a bucket (empty when the crate is built
/// without the `xla` feature or the artifacts are absent).
#[cfg(not(feature = "xla"))]
pub fn xla_op_times(_bucket: (usize, usize)) -> Result<Vec<(String, f64)>> {
    Ok(vec![])
}

/// XLA engine op timings at a bucket.
#[cfg(feature = "xla")]
pub fn xla_op_times(bucket: (usize, usize)) -> Result<Vec<(String, f64)>> {
    let dir = std::path::Path::new("artifacts");
    if !dir.join("manifest.json").exists() {
        return Ok(vec![]);
    }
    let backend = Backend::xla(dir)?;
    let (n, m) = (bucket.0.min(512), bucket.1.min(512));
    let ds = SyntheticDense::paper_part1(1, 1, n, m, 0.1, 9).build();
    let part = Partitioned::split(&ds, Grid::new(1, 1));
    let staged = backend.stage(&part)?;
    let mut rng = crate::util::rng::Xoshiro::new(2);
    let w: Vec<f32> = (0..m).map(|_| rng.range_f32(-1.0, 1.0)).collect();
    let idx = rng.index_stream(n, n);
    let alpha = vec![0.0f32; n];
    let mut out = Vec::new();

    // warm (compile) then time
    let reps = 20;
    let _ = staged.margins(0, 0, &w)?;
    let t = Timer::start();
    for _ in 0..reps {
        let _ = staged.margins(0, 0, &w)?;
    }
    out.push(("xla margins ms".into(), t.secs() / reps as f64 * 1e3));

    let _ = staged.sdca_epoch(0, 0, &alpha, &w, &idx, n, 0.1 * n as f32, 1.0, 0.0)?;
    let t = Timer::start();
    for _ in 0..reps {
        let _ = staged.sdca_epoch(0, 0, &alpha, &w, &idx, n, 0.1 * n as f32, 1.0, 0.0)?;
    }
    out.push(("xla sdca_epoch ms".into(), t.secs() / reps as f64 * 1e3));

    let mt = staged.margins(0, 0, &w)?;
    let mu = vec![0.0f32; m];
    let _ = staged.svrg_block(
        crate::loss::Loss::Hinge, 0, 0, &w, &w, &mu, (0, m), &mt, &idx, n, 0.01, 0.1,
    )?;
    let t = Timer::start();
    for _ in 0..reps {
        let _ = staged.svrg_block(
            crate::loss::Loss::Hinge, 0, 0, &w, &w, &mu, (0, m), &mt, &idx, n, 0.01, 0.1,
        )?;
    }
    out.push(("xla svrg_block ms".into(), t.secs() / reps as f64 * 1e3));
    out.push((
        "xla staged MiB".into(),
        staged.staged_bytes() as f64 / (1 << 20) as f64,
    ));
    if let Backend::Xla(engine) = &backend {
        let st = engine.stats();
        out.push(("xla compiles".into(), st.compiles as f64));
        out.push(("xla compile s".into(), st.compile_secs));
    }
    Ok(out)
}

/// Analytic L1 estimates for the Pallas BlockSpecs (see DESIGN.md).
pub fn l1_estimates() -> Vec<(String, f64)> {
    // L bucket: 2048x3072 f32; margins kernel tiles (128, M) + w resident.
    let tile_rows = 128.0;
    let m = 3072.0;
    let vmem_tile_mib = (tile_rows * m + m) * 4.0 / (1 << 20) as f64;
    // MXU does 128x128 f32 tiles; a (128, M) x (M,) matvec uses 1/128 of
    // the systolic array's columns → low MXU util by design (vector op);
    // the batched margins over 16 row-tiles is VPU/memory bound.
    let flops_per_tile = 2.0 * tile_rows * m;
    let bytes_per_tile = (tile_rows * m) * 4.0;
    vec![
        ("L1 margins VMEM MiB/tile".into(), vmem_tile_mib),
        ("L1 margins arithmetic intensity".into(), flops_per_tile / bytes_per_tile),
        // sequential kernels keep X resident: the L bucket would need
        // 24 MiB > 16 MiB VMEM → row-gather DMA streaming on real TPU
        ("L1 sdca X resident MiB (L bucket)".into(), 2048.0 * 3072.0 * 4.0 / (1 << 20) as f64),
    ]
}

pub fn run(_scale: Scale) -> Result<()> {
    let mut rows: Vec<Vec<String>> = Vec::new();
    let fmt = |v: f64| format!("{v:.4}");

    println!("# §Perf profile\n");
    for (k, v) in native_kernels(512, 512, 20) {
        rows.push(vec!["L3-native".into(), k, fmt(v)]);
    }
    for (k, v) in coordinator_overhead()? {
        rows.push(vec!["L3-coord".into(), k, fmt(v)]);
    }
    for (k, v) in xla_op_times((512, 512))? {
        rows.push(vec!["L2-xla".into(), k, fmt(v)]);
    }
    for (k, v) in l1_estimates() {
        rows.push(vec!["L1-pallas".into(), k, fmt(v)]);
    }
    let table = markdown_table(&["layer", "metric", "value"], &rows);
    println!("{table}");
    std::fs::write(common::out_dir().join("perf.md"), table)?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn native_kernel_bench_reports_positive_rates() {
        let r = native_kernels(64, 64, 2);
        assert_eq!(r.len(), 4);
        for (k, v) in r {
            assert!(v > 0.0, "{k}");
        }
    }

    #[test]
    fn l1_estimates_flag_the_vmem_pressure() {
        let est = l1_estimates();
        let resident = est
            .iter()
            .find(|(k, _)| k.contains("resident"))
            .unwrap()
            .1;
        assert!(resident > 16.0, "L bucket must exceed 16 MiB VMEM");
    }
}
