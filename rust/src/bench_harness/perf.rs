//! §Perf — the whole-stack profiling harness behind EXPERIMENTS.md §Perf.
//!
//! L3: native kernel throughput (GFLOP/s for margins/atx, steps/s for
//! SDCA/SVRG) + coordinator overhead (iteration time minus kernel time)
//! + sparse before/after microbenches (CSC mirror vs CSR scatter,
//! window-indexed vs scanning windowed ops) + superstep dispatch
//! overhead (per-superstep scoped spawns vs the persistent worker pool)
//! + steady-state allocations/iteration at `threads ∈ {1, 2, 4}` under
//! the `bench-alloc` counting allocator.
//! L2/XLA: per-op execute times through the PJRT engine, compile cost,
//! staging footprint.
//! L1: analytic VMEM/MXU estimates for the Pallas BlockSpecs (interpret
//! mode gives no real TPU timing — see DESIGN.md §Hardware-Adaptation).
//!
//! Besides the human-readable table (`results/perf.md`), `run` writes the
//! machine-readable **`BENCH_perf.json` at the repo root** — the recorded
//! perf trajectory this and future PRs regress against.  "Before" numbers
//! (the pre-PR kernels and the boxed-superstep pipeline) are measured in
//! the same run from the retained baseline code paths, so the file always
//! carries a same-host before/after pair.

use super::common;
use super::Scale;
use crate::cluster::{ClusterBackend, ClusterConfig, SimBackend, SimCluster, StepPlan};
use crate::coordinator::{
    Admm, AdmmConfig, D3ca, D3caConfig, Driver, Optimizer, Radisa, RadisaConfig,
};
use crate::data::{
    balanced_ranges, Grid, Partitioned, SubblockIndex, SyntheticDense, SyntheticSparse,
};
use crate::metrics::markdown_table;
use crate::runtime::{Backend, StagedGrid};
use crate::util::json::Json;
use crate::util::rng::Xoshiro;
use crate::util::timer::Timer;
use anyhow::Result;
use std::path::{Path, PathBuf};

fn gflops(flops: f64, secs: f64) -> f64 {
    flops / secs / 1e9
}

/// Native kernel micro-benchmarks.
pub fn native_kernels(n: usize, m: usize, reps: usize) -> Vec<(String, f64)> {
    let ds = SyntheticDense::paper_part1(1, 1, n, m, 0.1, 3).build();
    let mut rng = crate::util::rng::Xoshiro::new(1);
    let w: Vec<f32> = (0..m).map(|_| rng.range_f32(-1.0, 1.0)).collect();
    let v: Vec<f32> = (0..n).map(|_| rng.range_f32(-1.0, 1.0)).collect();
    let mut out_n = vec![0.0f32; n];
    let mut out_m = vec![0.0f32; m];
    let mut results = Vec::new();

    let t = Timer::start();
    for _ in 0..reps {
        ds.x.margins_into(&w, &mut out_n);
    }
    results.push((
        "margins GFLOP/s".into(),
        gflops(2.0 * (n * m * reps) as f64, t.secs()),
    ));

    let t = Timer::start();
    for _ in 0..reps {
        ds.x.atx_into(&v, &mut out_m);
    }
    results.push((
        "atx GFLOP/s".into(),
        gflops(2.0 * (n * m * reps) as f64, t.secs()),
    ));

    let lamn = 0.1 * n as f32;
    let alpha = vec![0.0f32; n];
    let norms = crate::solvers::row_norms(&ds.x);
    let idx = rng.index_stream(n, n);
    let mut da = vec![0.0f32; n];
    let mut a_buf = vec![0.0f32; n];
    let mut w_buf = vec![0.0f32; m];
    let t = Timer::start();
    for _ in 0..reps {
        crate::solvers::sdca_epoch_into(
            &ds.x, &ds.y, &norms, &alpha, &w, &idx, n, lamn, 1.0, 0.0, &mut da,
            &mut a_buf, &mut w_buf,
        );
    }
    results.push((
        "sdca Msteps/s".into(),
        (n * reps) as f64 / t.secs() / 1e6,
    ));

    let wt = w.clone();
    let mut mt = vec![0.0f32; n];
    ds.x.margins_into(&wt, &mut mt);
    let mu = vec![0.0f32; m];
    let mut wrun = vec![0.0f32; m];
    let mut delta_buf = Vec::new();
    let t = Timer::start();
    for _ in 0..reps {
        wrun.copy_from_slice(&wt);
        crate::solvers::svrg_block_win(
            crate::loss::Loss::Hinge,
            &ds.x,
            &ds.y,
            &mut wrun,
            &wt,
            &mu,
            0,
            m,
            &mt,
            &idx,
            n,
            0.01,
            0.1,
            None,
            &mut delta_buf,
        );
    }
    results.push((
        "svrg Msteps/s".into(),
        (n * reps) as f64 / t.secs() / 1e6,
    ));
    results
}

/// Register-tiled dispatch layer: GFLOP/s for every kernel in the
/// [`KernelDispatch`](crate::linalg::KernelDispatch) table, run through
/// both static tables — the baseline ("scalar", what
/// `DDOPT_KERNELS=scalar` selects) and the runtime-detected one
/// ("dispatched", AVX2+FMA where the CPU has it).  Both tables execute
/// the identical arithmetic in the identical order, so any gap is pure
/// codegen width; the perf gate pins absolute floors on the dispatched
/// side (`kernels_min` in ci/perf_thresholds.json).
pub fn kernel_dispatch(n: usize, m: usize, reps: usize) -> Vec<(String, f64)> {
    use crate::linalg::{detected, scalar_table};
    let tables = [("scalar", scalar_table()), ("dispatched", detected())];
    let mut rng = Xoshiro::new(3);
    let a: Vec<f32> = (0..n * m).map(|_| rng.range_f32(-1.0, 1.0)).collect();
    let x: Vec<f32> = (0..m).map(|_| rng.range_f32(-1.0, 1.0)).collect();
    let v: Vec<f32> = (0..n).map(|_| rng.range_f32(-1.0, 1.0)).collect();
    let len = n * m;
    let b: Vec<f32> = (0..len).map(|_| rng.range_f32(-1.0, 1.0)).collect();
    let mut acc_buf = vec![0.0f32; len];
    let mut out_n = vec![0.0f32; n];
    let mut out_m = vec![0.0f32; m];
    // CSC mirror at text-classification density for the sparse transpose
    let ds = SyntheticSparse::new("perf-dispatch", n, m, 0.003, 13).build();
    let mut sm = ds.x.as_sparse().expect("sparse generator yields CSR").clone();
    sm.build_csc();
    let nnz = sm.nnz();

    let mut results = Vec::new();
    for (label, kd) in tables {
        let kd = std::hint::black_box(kd);
        let t = Timer::start();
        let mut s = 0.0f32;
        for _ in 0..reps {
            s += (kd.dot)(&a, &b);
        }
        std::hint::black_box(s);
        results.push((
            format!("dot GFLOP/s ({label})"),
            gflops(2.0 * (len * reps) as f64, t.secs()),
        ));
    }
    for (label, kd) in tables {
        let kd = std::hint::black_box(kd);
        let t = Timer::start();
        for _ in 0..reps {
            (kd.axpy)(0.5, &b, &mut acc_buf);
        }
        std::hint::black_box(acc_buf[0]);
        results.push((
            format!("axpy GFLOP/s ({label})"),
            gflops(2.0 * (len * reps) as f64, t.secs()),
        ));
    }
    for (label, kd) in tables {
        let kd = std::hint::black_box(kd);
        let t = Timer::start();
        for _ in 0..reps {
            (kd.gemv)(&a, n, m, &x, &mut out_n);
        }
        std::hint::black_box(out_n[0]);
        results.push((
            format!("gemv GFLOP/s ({label})"),
            gflops(2.0 * (n * m * reps) as f64, t.secs()),
        ));
    }
    for (label, kd) in tables {
        let kd = std::hint::black_box(kd);
        let t = Timer::start();
        for _ in 0..reps {
            (kd.gemv_t)(&a, n, m, &v, &mut out_m);
        }
        std::hint::black_box(out_m[0]);
        results.push((
            format!("gemv_t GFLOP/s ({label})"),
            gflops(2.0 * (n * m * reps) as f64, t.secs()),
        ));
    }
    for (label, kd) in tables {
        let kd = std::hint::black_box(kd);
        let t = Timer::start();
        for _ in 0..reps {
            sm.gemv_t_into_with(kd, &v, &mut out_m);
        }
        std::hint::black_box(out_m[0]);
        results.push((
            format!("csc gemv_t GFLOP/s ({label})"),
            gflops(2.0 * (nnz * reps) as f64, t.secs()),
        ));
    }
    for (label, kd) in tables {
        let kd = std::hint::black_box(kd);
        let t = Timer::start();
        for _ in 0..reps {
            (kd.svrg_delta)(&mut acc_buf, &b, 1e-3, 0.1);
        }
        std::hint::black_box(acc_buf[0]);
        results.push((
            format!("svrg_delta GFLOP/s ({label})"),
            gflops(4.0 * (len * reps) as f64, t.secs()),
        ));
    }
    results
}

/// Sparse kernel before/after microbenches at text-classification
/// density: the CSC-mirror transpose product vs the pre-PR CSR scatter,
/// and the window-indexed sub-block ops vs the pre-PR per-row scans.
/// GFLOP/s counts *useful* flops (2·nnz per full pass / per full window
/// sweep), so the indexed variants show their real advantage: they touch
/// only the entries that contribute.
pub fn sparse_kernels(n: usize, m: usize, density: f64, reps: usize) -> Vec<(String, f64)> {
    let ds = SyntheticSparse::new("perf-sparse", n, m, density, 5).build();
    let mut sm = ds.x.as_sparse().expect("sparse generator yields CSR").clone();
    sm.build_csc(); // bench the mirror path partition blocks use
    let sm = &sm;
    let nnz = sm.nnz();
    let mut rng = Xoshiro::new(2);
    let v: Vec<f32> = (0..n).map(|_| rng.range_f32(-1.0, 1.0)).collect();
    let w: Vec<f32> = (0..m).map(|_| rng.range_f32(-1.0, 1.0)).collect();
    let mut out_m = vec![0.0f32; m];
    let mut results: Vec<(String, f64)> = vec![("sparse nnz".into(), nnz as f64)];
    let pass_flops = (2 * nnz * reps) as f64;

    let t = Timer::start();
    for _ in 0..reps {
        sm.gemv_t_scatter_into(&v, &mut out_m);
    }
    results.push(("atx scatter GFLOP/s (before)".into(), gflops(pass_flops, t.secs())));

    let t = Timer::start();
    for _ in 0..reps {
        sm.gemv_t_into(&v, &mut out_m);
    }
    results.push(("atx csc GFLOP/s (after)".into(), gflops(pass_flops, t.secs())));

    // windowed ops over an 8-way sub-block grid (RADiSA's shape)
    let nw = 8usize.min(m);
    let ranges = balanced_ranges(m, nw);
    let mut bounds = Vec::with_capacity(nw + 1);
    bounds.push(0);
    bounds.extend(ranges.iter().map(|&(_, e)| e));
    let ix = SubblockIndex::new(sm, &bounds);
    let wins: Vec<Vec<f32>> = ranges.iter().map(|&(lo, hi)| w[lo..hi].to_vec()).collect();

    let t = Timer::start();
    let mut acc = 0.0f32;
    for _ in 0..reps {
        for (s, &(lo, hi)) in ranges.iter().enumerate() {
            for i in 0..n {
                // pre-PR path: scans every stored entry of the row and
                // filters on the column window
                acc += ds.x.row_dot_window_offset(i, &wins[s], lo, hi);
            }
        }
    }
    std::hint::black_box(acc);
    results.push((
        "window dot scan GFLOP/s (before)".into(),
        gflops(pass_flops, t.secs()),
    ));

    let t = Timer::start();
    let mut acc = 0.0f32;
    for _ in 0..reps {
        for (s, &(lo, hi)) in ranges.iter().enumerate() {
            let span = ix.span(lo, hi).expect("window is a cached boundary pair");
            for i in 0..n {
                let (a, b) = ix.row_range(i, span);
                acc += sm.range_dot_rebased(a, b, &wins[s], lo);
            }
        }
    }
    std::hint::black_box(acc);
    results.push((
        "window dot indexed GFLOP/s (after)".into(),
        gflops(pass_flops, t.secs()),
    ));
    results
}

/// Coordinator overhead: share of an iteration spent outside the compute
/// kernels (aggregation, scheduling, allocation).
pub fn coordinator_overhead() -> Result<Vec<(String, f64)>> {
    let ds = SyntheticDense::paper_part1(4, 2, 256, 192, 0.1, 5).build();
    let part = Partitioned::split(&ds, Grid::new(4, 2));
    let backend = Backend::native();
    let mut out = Vec::new();
    for method in ["d3ca", "radisa"] {
        let t = Timer::start();
        let r = match method {
            "d3ca" => {
                let mut opt = D3ca::new(D3caConfig { lambda: 0.1, ..Default::default() });
                Driver::new(&part, &backend)?
                    .iterations(10)
                    .eval_every(usize::MAX) // exclude evaluation cost
                    // threads=1 so sim compute ≈ host kernel time
                    .cluster(ClusterConfig::with_cores(8).with_threads(1))
                    .run(&mut opt)?
            }
            _ => {
                let mut opt = Radisa::new(RadisaConfig { lambda: 0.1, gamma: 0.05, ..Default::default() });
                Driver::new(&part, &backend)?
                    .iterations(10)
                    .eval_every(usize::MAX)
                    .cluster(ClusterConfig::with_cores(8).with_threads(1))
                    .run(&mut opt)?
            }
        };
        let wall = t.secs();
        out.push((format!("{method} wall s/10it"), wall));
        out.push((format!("{method} overhead frac"), (wall - r.sim_time).max(0.0) / wall));
    }
    Ok(out)
}

/// Persistent-pool dispatch overhead: µs per superstep of `n_tasks`
/// trivial tasks at `threads`, for the retained per-superstep scoped
/// spawn path ("before") and the persistent worker runtime ("after").
/// Tasks are empty, so the measured time is almost pure dispatch — the
/// per-round overhead the real systems (Spark executors held across
/// stages) never pay and the persistent pool eliminates.
#[cfg(not(feature = "xla"))]
pub fn spawn_overhead(threads: usize, n_tasks: usize, reps: usize) -> Vec<(String, f64)> {
    use crate::cluster::pool::run_indexed_scoped;
    use crate::cluster::WorkerPool;
    let pool = WorkerPool::new(threads);
    pool.warm_up();
    let mut times = vec![0.0f64; n_tasks];
    let mut scratch = vec![0u64; threads];
    let trivial = |i: usize, s: &mut u64| -> Result<()> {
        *s = s.wrapping_add(i as u64);
        Ok(())
    };
    // one warm pass each so neither side pays first-touch costs
    run_indexed_scoped(n_tasks, &mut scratch, &mut times, trivial).unwrap();
    pool.run_indexed(n_tasks, &mut scratch, &mut times, trivial).unwrap();

    let t = Timer::start();
    for _ in 0..reps {
        run_indexed_scoped(n_tasks, &mut scratch, &mut times, trivial).unwrap();
    }
    let before = t.secs() / reps as f64 * 1e6;

    let t = Timer::start();
    for _ in 0..reps {
        pool.run_indexed(n_tasks, &mut scratch, &mut times, trivial).unwrap();
    }
    let after = t.secs() / reps as f64 * 1e6;
    vec![
        ("superstep spawn overhead us (before)".into(), before),
        ("superstep spawn overhead us (after)".into(), after),
    ]
}

/// The `xla` build runs every superstep inline — no pool dispatch to
/// measure.
#[cfg(feature = "xla")]
pub fn spawn_overhead(_threads: usize, _n_tasks: usize, _reps: usize) -> Vec<(String, f64)> {
    Vec::new()
}

/// Measured distributed wire traffic at 3 loopback executors, in both
/// wire modes: per-op and aggregate bytes on the wire per superstep plus
/// mean exchange round-trip.  "broadcast" is the full-payload baseline
/// (`--dist-wire broadcast`: no sliced scatter, no gather folding,
/// round-robin ownership); "sliced" is the negotiated default.  Final
/// weights are bit-identical across the two (and to the sim backend) —
/// only the byte counts move, which is exactly what this section tracks.
pub fn wire_profile() -> Result<Vec<(String, f64)>> {
    use crate::cluster::{dist, ClusterMode, CostModel, WireMode};
    use std::collections::BTreeMap;

    fn spawn_executors(
        n: usize,
        threads: usize,
    ) -> Result<(Vec<String>, Vec<std::thread::JoinHandle<Result<()>>>)> {
        let mut addrs = Vec::new();
        let mut handles = Vec::new();
        for _ in 0..n {
            let listener = std::net::TcpListener::bind("127.0.0.1:0")?;
            addrs.push(listener.local_addr()?.to_string());
            handles.push(std::thread::spawn(move || {
                dist::serve_listener(listener, threads, true)
            }));
        }
        Ok((addrs, handles))
    }

    let backend = Backend::native();
    let ds = SyntheticDense::paper_part1(2, 2, 160, 120, 0.1, 11).build();
    let part = Partitioned::split(&ds, Grid::new(2, 2));
    let mut out: Vec<(String, f64)> = Vec::new();
    let mut agg_out = [0.0f64; 2];
    let (mut retries, mut rejoins, mut degraded) = (0usize, 0usize, 0usize);
    let (mut spec_launched, mut spec_won) = (0usize, 0usize);
    for (mi, (mode, label)) in
        [(WireMode::Broadcast, "broadcast"), (WireMode::Sliced, "sliced")]
            .into_iter()
            .enumerate()
    {
        // (supersteps, bytes out, bytes in) per op kind, plus totals
        let mut per_op: BTreeMap<&'static str, (usize, usize, usize)> = BTreeMap::new();
        let (mut steps, mut bytes_out, mut bytes_in, mut wall) = (0usize, 0usize, 0usize, 0.0f64);
        for method in ["d3ca", "radisa"] {
            // fresh single-session executors per run; `Driver::run`'s
            // shutdown lets each `serve_listener(.., once=true)` return
            let (addrs, handles) = spawn_executors(3, 2)?;
            let cfg = ClusterConfig {
                mode: ClusterMode::Dist(addrs),
                cores: 4,
                threads: 2,
                cost: CostModel::Fixed(1e-3),
                wire: mode,
                ..Default::default()
            };
            let mut opt: Box<dyn Optimizer> = match method {
                "d3ca" => Box::new(D3ca::new(D3caConfig { lambda: 0.1, ..Default::default() })),
                _ => Box::new(Radisa::new(RadisaConfig {
                    lambda: 0.1,
                    gamma: 0.05,
                    ..Default::default()
                })),
            };
            let r = Driver::new(&part, &backend)?
                .iterations(4)
                .eval_every(usize::MAX)
                .cluster(cfg)
                .run(opt.as_mut())?;
            for rec in &r.wire {
                // recovery and speculation counters land on every record
                // (including staging); on this clean loopback fleet they
                // must all stay 0 — the perf gate pins that
                retries += rec.retries;
                rejoins += rec.rejoins;
                degraded = degraded.max(rec.degraded_executors);
                spec_launched += rec.spec_launched;
                spec_won += rec.spec_won;
                if rec.op == "stage" || rec.op == "prepare-admm" {
                    continue;
                }
                steps += 1;
                bytes_out += rec.bytes_out;
                bytes_in += rec.bytes_in;
                wall += rec.wall_secs;
                let e = per_op.entry(rec.op).or_insert((0, 0, 0));
                e.0 += 1;
                e.1 += rec.bytes_out;
                e.2 += rec.bytes_in;
            }
            for h in handles {
                h.join()
                    .map_err(|_| anyhow::anyhow!("executor thread panicked"))??;
            }
        }
        for (op, (n, o, i)) in &per_op {
            out.push((format!("{label} {op} bytes_out/step"), *o as f64 / *n as f64));
            out.push((format!("{label} {op} bytes_in/step"), *i as f64 / *n as f64));
        }
        agg_out[mi] = bytes_out as f64 / steps.max(1) as f64;
        out.push((format!("{label} bytes_out/superstep"), agg_out[mi]));
        out.push((
            format!("{label} bytes_in/superstep"),
            bytes_in as f64 / steps.max(1) as f64,
        ));
        out.push((format!("{label} step rtt ms"), wall / steps.max(1) as f64 * 1e3));
    }
    if agg_out[1] > 0.0 {
        out.push(("scatter reduction (broadcast/sliced)".into(), agg_out[0] / agg_out[1]));
    }
    // fault-tolerance counters, summed across both wire modes: all five
    // must read 0 on this clean loopback fleet, and the perf gate
    // (wire_zero_keys) fails the run otherwise — recovery or speculation
    // firing during the bench means the transport itself got flaky
    out.push(("recovery retries".into(), retries as f64));
    out.push(("recovery rejoins".into(), rejoins as f64));
    out.push(("degraded executors".into(), degraded as f64));
    out.push(("spec launched".into(), spec_launched as f64));
    out.push(("spec won".into(), spec_won as f64));
    Ok(out)
}

/// Run `step(t)` for `warmup` iterations, then measure the allocator
/// call count across `iters` further iterations.  `None` without the
/// `bench-alloc` feature.
fn probe_alloc(
    warmup: usize,
    iters: usize,
    mut step: impl FnMut(usize) -> Result<()>,
) -> Result<Option<f64>> {
    for t in 1..=warmup {
        step(t)?;
    }
    let before = crate::util::alloc::alloc_count();
    for t in warmup + 1..=warmup + iters {
        step(t)?;
    }
    let after = crate::util::alloc::alloc_count();
    Ok(match (before, after) {
        (Some(b), Some(a)) => Some((a - b) as f64 / iters as f64),
        _ => None,
    })
}

/// The pre-PR superstep pipeline shape, retained as the "before" side of
/// the allocation baseline: boxed per-task closures, per-task `Vec`
/// returns, fresh index streams, and vector-of-vectors tree reduces.
fn legacy_boxed_allocs(
    staged: &StagedGrid<'_>,
    warmup: usize,
    iters: usize,
) -> Result<Option<f64>> {
    let part = staged.part;
    let (pp, qq) = (part.grid.p, part.grid.q);
    let lamn = 0.1 * part.n as f32;
    let invq = 1.0 / qq as f32;
    let mut cluster = SimCluster::new(ClusterConfig::with_cores(8).with_threads(1));
    let mut alpha = vec![0.0f32; part.n];
    let mut w = vec![0.0f32; part.m];
    let root = Xoshiro::new(1).substream(0xD3CA, 0, 0);
    probe_alloc(warmup, iters, move |t| {
        let deltas = {
            let (alpha_r, w_r) = (&alpha, &w);
            let mut plan = StepPlan::with_capacity(pp * qq);
            for p in 0..pp {
                let (r0, r1) = part.row_ranges[p];
                for q in 0..qq {
                    let (c0, c1) = part.col_ranges[q];
                    let n_p = r1 - r0;
                    let mut rng = root.substream(p as u64, q as u64, t as u64);
                    let idx = rng.index_stream(n_p, n_p);
                    let a_p = &alpha_r[r0..r1];
                    let w_q = &w_r[c0..c1];
                    plan.task(move || {
                        staged.sdca_epoch(p, q, a_p, w_q, &idx, n_p, lamn, invq, 0.0)
                    });
                }
            }
            cluster.grid_step(plan)?
        };
        let upd = cluster.reduce_over_q(deltas, pp, qq);
        let scale = 1.0 / (pp * qq) as f32;
        for (p, sum) in upd.iter().enumerate() {
            let (r0, _) = part.row_ranges[p];
            for (k, &d) in sum.iter().enumerate() {
                alpha[r0 + k] += scale * d;
            }
        }
        let contribs = {
            let alpha_r = &alpha;
            let mut plan = StepPlan::with_capacity(pp * qq);
            for p in 0..pp {
                let (r0, r1) = part.row_ranges[p];
                for q in 0..qq {
                    let a_p = &alpha_r[r0..r1];
                    plan.task(move || staged.atx(p, q, a_p));
                }
            }
            cluster.grid_step(plan)?
        };
        let sums = cluster.reduce_over_p(contribs, pp, qq);
        for (q, sum) in sums.into_iter().enumerate() {
            let (c0, _) = part.col_ranges[q];
            for (k, s) in sum.into_iter().enumerate() {
                w[c0 + k] = s / lamn;
            }
        }
        Ok(())
    })
}

/// Steady-state allocations/iteration for the three coordinators on the
/// zero-allocation workspace path at `threads ∈ {1, 2, 4}` (the
/// persistent worker pool extends the zero-alloc guarantee to the
/// parallel path: after the one-time pool bring-up — absorbed here by
/// the warmup iterations — parallel supersteps are a pointer handoff,
/// not a spawn), plus an aggregated `parallel steady allocs/iter`
/// (worst coordinator at threads = 4) and the retained legacy
/// boxed-superstep pipeline as the "before" number.  `None` entries mean
/// the binary was built without `bench-alloc`.
pub fn steady_state_allocs() -> Result<Vec<(String, Option<f64>)>> {
    let ds = SyntheticDense::paper_part1(4, 2, 192, 128, 0.1, 7).build();
    let part = Partitioned::split(&ds, Grid::new(4, 2));
    let backend = Backend::native();
    let staged = backend.stage(&part)?;
    let (warmup, iters) = (2usize, 5usize);
    let mut out = Vec::new();
    let mut parallel_worst: Option<f64> = None;
    for method in ["d3ca", "radisa", "admm"] {
        for threads in [1usize, 2, 4] {
            let mut opt: Box<dyn Optimizer> = match method {
                "d3ca" => Box::new(D3ca::new(D3caConfig { lambda: 0.1, ..Default::default() })),
                "radisa" => Box::new(Radisa::new(RadisaConfig {
                    lambda: 0.1,
                    gamma: 0.05,
                    ..Default::default()
                })),
                _ => Box::new(Admm::new(AdmmConfig { lambda: 0.1, rho: 0.1 })),
            };
            let mut cluster =
                SimBackend::new(ClusterConfig::with_cores(8).with_threads(threads));
            cluster.prepare(&staged)?;
            opt.init(&staged, &mut cluster)?;
            let measured =
                probe_alloc(warmup, iters, |t| opt.iterate(t, &staged, &mut cluster))?;
            let key = if threads == 1 {
                format!("{method} steady allocs/iter")
            } else {
                format!("{method} steady allocs/iter (threads={threads})")
            };
            if threads == 4 {
                parallel_worst = match (parallel_worst, measured) {
                    (Some(a), Some(b)) => Some(a.max(b)),
                    (None, Some(b)) => Some(b),
                    (a, None) => a,
                };
            }
            out.push((key, measured));
        }
    }
    out.push(("parallel steady allocs/iter".into(), parallel_worst));
    // tracing-on holds the same contract: once the intern table and the
    // span rings are warm, recording is stores into preallocated
    // buffers, so the traced steady state must also read exactly 0
    for method in ["d3ca", "radisa", "admm"] {
        let mut opt: Box<dyn Optimizer> = match method {
            "d3ca" => Box::new(D3ca::new(D3caConfig { lambda: 0.1, ..Default::default() })),
            "radisa" => Box::new(Radisa::new(RadisaConfig {
                lambda: 0.1,
                gamma: 0.05,
                ..Default::default()
            })),
            _ => Box::new(Admm::new(AdmmConfig { lambda: 0.1, rho: 0.1 })),
        };
        let mut cluster = SimBackend::new(ClusterConfig::with_cores(8).with_threads(2));
        cluster.set_trace(true);
        cluster.prepare(&staged)?;
        opt.init(&staged, &mut cluster)?;
        let measured = probe_alloc(warmup, iters, |t| opt.iterate(t, &staged, &mut cluster))?;
        out.push((format!("{method} steady allocs/iter (traced)"), measured));
    }
    out.push((
        "legacy boxed-superstep allocs/iter (before)".into(),
        legacy_boxed_allocs(&staged, warmup, iters)?,
    ));
    Ok(out)
}

/// Tracing overhead: wall time of identical fixed-cost sim runs with
/// the span recorder off vs on, min-of-`reps` each so scheduler noise
/// cannot fake a regression.  The reported `trace overhead frac` is
/// what `ci/check_perf.py` gates at ≤ `trace_max_overhead` — the
/// subsystem's "low-overhead" claim, held as a number.
pub fn trace_overhead(iters: usize, reps: usize) -> Result<Vec<(String, f64)>> {
    let ds = SyntheticDense::paper_part1(4, 2, 192, 128, 0.1, 7).build();
    let part = Partitioned::split(&ds, Grid::new(4, 2));
    let backend = Backend::native();
    let mut best = [f64::INFINITY; 2];
    let mut spans = 0usize;
    for (i, traced) in [(0usize, false), (1, true)] {
        for _ in 0..reps {
            let mut opt = D3ca::new(D3caConfig { lambda: 0.1, ..Default::default() });
            let t = Timer::start();
            let r = Driver::new(&part, &backend)?
                .iterations(iters)
                .eval_every(usize::MAX)
                .trace(traced)
                .cluster(ClusterConfig::with_cores(8).with_threads(1))
                .run(&mut opt)?;
            best[i] = best[i].min(t.secs());
            if let Some(log) = &r.trace {
                spans = log.len();
            }
        }
    }
    let overhead = (best[1] - best[0]).max(0.0) / best[0];
    Ok(vec![
        (format!("untraced wall s/{iters}it"), best[0]),
        (format!("traced wall s/{iters}it"), best[1]),
        ("trace overhead frac".into(), overhead),
        ("trace spans/iter".into(), spans as f64 / iters.max(1) as f64),
    ])
}

/// XLA engine op timings at a bucket (empty when the crate is built
/// without the `xla` feature or the artifacts are absent).
#[cfg(not(feature = "xla"))]
pub fn xla_op_times(_bucket: (usize, usize)) -> Result<Vec<(String, f64)>> {
    Ok(vec![])
}

/// XLA engine op timings at a bucket.
#[cfg(feature = "xla")]
pub fn xla_op_times(bucket: (usize, usize)) -> Result<Vec<(String, f64)>> {
    let dir = std::path::Path::new("artifacts");
    if !dir.join("manifest.json").exists() {
        return Ok(vec![]);
    }
    let backend = Backend::xla(dir)?;
    let (n, m) = (bucket.0.min(512), bucket.1.min(512));
    let ds = SyntheticDense::paper_part1(1, 1, n, m, 0.1, 9).build();
    let part = Partitioned::split(&ds, Grid::new(1, 1));
    let staged = backend.stage(&part)?;
    let mut rng = crate::util::rng::Xoshiro::new(2);
    let w: Vec<f32> = (0..m).map(|_| rng.range_f32(-1.0, 1.0)).collect();
    let idx = rng.index_stream(n, n);
    let alpha = vec![0.0f32; n];
    let mut out = Vec::new();

    // warm (compile) then time
    let reps = 20;
    let _ = staged.margins(0, 0, &w)?;
    let t = Timer::start();
    for _ in 0..reps {
        let _ = staged.margins(0, 0, &w)?;
    }
    out.push(("xla margins ms".into(), t.secs() / reps as f64 * 1e3));

    let _ = staged.sdca_epoch(0, 0, &alpha, &w, &idx, n, 0.1 * n as f32, 1.0, 0.0)?;
    let t = Timer::start();
    for _ in 0..reps {
        let _ = staged.sdca_epoch(0, 0, &alpha, &w, &idx, n, 0.1 * n as f32, 1.0, 0.0)?;
    }
    out.push(("xla sdca_epoch ms".into(), t.secs() / reps as f64 * 1e3));

    let mt = staged.margins(0, 0, &w)?;
    let mu = vec![0.0f32; m];
    let _ = staged.svrg_block(
        crate::loss::Loss::Hinge, 0, 0, &w, &w, &mu, (0, m), &mt, &idx, n, 0.01, 0.1,
    )?;
    let t = Timer::start();
    for _ in 0..reps {
        let _ = staged.svrg_block(
            crate::loss::Loss::Hinge, 0, 0, &w, &w, &mu, (0, m), &mt, &idx, n, 0.01, 0.1,
        )?;
    }
    out.push(("xla svrg_block ms".into(), t.secs() / reps as f64 * 1e3));
    out.push((
        "xla staged MiB".into(),
        staged.staged_bytes() as f64 / (1 << 20) as f64,
    ));
    if let Backend::Xla(engine) = &backend {
        let st = engine.stats();
        out.push(("xla compiles".into(), st.compiles as f64));
        out.push(("xla compile s".into(), st.compile_secs));
    }
    Ok(out)
}

/// Analytic L1 estimates for the Pallas BlockSpecs (see DESIGN.md).
pub fn l1_estimates() -> Vec<(String, f64)> {
    // L bucket: 2048x3072 f32; margins kernel tiles (128, M) + w resident.
    let tile_rows = 128.0;
    let m = 3072.0;
    let vmem_tile_mib = (tile_rows * m + m) * 4.0 / (1 << 20) as f64;
    // MXU does 128x128 f32 tiles; a (128, M) x (M,) matvec uses 1/128 of
    // the systolic array's columns → low MXU util by design (vector op);
    // the batched margins over 16 row-tiles is VPU/memory bound.
    let flops_per_tile = 2.0 * tile_rows * m;
    let bytes_per_tile = (tile_rows * m) * 4.0;
    vec![
        ("L1 margins VMEM MiB/tile".into(), vmem_tile_mib),
        ("L1 margins arithmetic intensity".into(), flops_per_tile / bytes_per_tile),
        // sequential kernels keep X resident: the L bucket would need
        // 24 MiB > 16 MiB VMEM → row-gather DMA streaming on real TPU
        ("L1 sdca X resident MiB (L bucket)".into(), 2048.0 * 3072.0 * 4.0 / (1 << 20) as f64),
    ]
}

/// Repo root (one level above the crate's manifest) — where
/// `BENCH_perf.json` lives so the perf trajectory is versioned alongside
/// the code rather than buried in `results/`.
fn repo_root() -> PathBuf {
    Path::new(env!("CARGO_MANIFEST_DIR"))
        .parent()
        .map(|p| p.to_path_buf())
        .unwrap_or_else(|| PathBuf::from("."))
}

fn json_section(rows: &[(String, f64)]) -> Json {
    Json::Obj(
        rows.iter()
            .map(|(k, v)| (k.clone(), Json::Num(*v)))
            .collect(),
    )
}

pub fn run(scale: Scale) -> Result<()> {
    let mut rows: Vec<Vec<String>> = Vec::new();
    let fmt = |v: f64| format!("{v:.4}");

    let (sp_n, sp_m, sp_reps) = match scale {
        Scale::Small => (4096usize, 2048usize, 10usize),
        Scale::Paper => (20_000, 10_000, 20),
    };

    println!("# §Perf profile\n");
    let kernels = native_kernels(512, 512, 20);
    for (k, v) in &kernels {
        rows.push(vec!["L3-native".into(), k.clone(), fmt(*v)]);
    }
    // register-tiled dispatch table: scalar vs detected, per kernel
    let disp = kernel_dispatch(512, 512, 40);
    for (k, v) in &disp {
        rows.push(vec!["L3-dispatch".into(), k.clone(), fmt(*v)]);
    }
    // news20-ish density: the windowed-op regime the sub-block index targets
    let sparse = sparse_kernels(sp_n, sp_m, 0.003, sp_reps);
    for (k, v) in &sparse {
        rows.push(vec!["L3-sparse".into(), k.clone(), fmt(*v)]);
    }
    let coord = coordinator_overhead()?;
    for (k, v) in &coord {
        rows.push(vec!["L3-coord".into(), k.clone(), fmt(*v)]);
    }
    // superstep dispatch: scoped spawns (before) vs the persistent pool
    let pool = spawn_overhead(4, 8, 200);
    for (k, v) in &pool {
        rows.push(vec!["L3-pool".into(), k.clone(), fmt(*v)]);
    }
    let allocs = steady_state_allocs()?;
    for (k, v) in &allocs {
        rows.push(vec![
            "L3-alloc".into(),
            k.clone(),
            v.map(fmt).unwrap_or_else(|| "n/a (build with --features bench-alloc)".into()),
        ]);
    }
    // distributed transport: bytes/superstep + RTT, broadcast vs sliced
    let wire = wire_profile()?;
    for (k, v) in &wire {
        rows.push(vec!["L3-wire".into(), k.clone(), fmt(*v)]);
    }
    // span recorder cost: traced vs untraced wall time of the same run
    let trace = trace_overhead(30, 5)?;
    for (k, v) in &trace {
        rows.push(vec!["L3-trace".into(), k.clone(), fmt(*v)]);
    }
    let xla = xla_op_times((512, 512))?;
    for (k, v) in &xla {
        rows.push(vec!["L2-xla".into(), k.clone(), fmt(*v)]);
    }
    let l1 = l1_estimates();
    for (k, v) in &l1 {
        rows.push(vec!["L1-pallas".into(), k.clone(), fmt(*v)]);
    }
    let table = markdown_table(&["layer", "metric", "value"], &rows);
    println!("{table}");
    std::fs::write(common::out_dir().join("perf.md"), &table)?;

    // machine-readable perf baseline at the repo root
    let alloc_json = Json::Obj(
        allocs
            .iter()
            .map(|(k, v)| (k.clone(), v.map(Json::Num).unwrap_or(Json::Null)))
            .collect(),
    );
    let doc = Json::obj(vec![
        ("schema", Json::str("ddopt-perf/6")),
        ("generated_by", Json::str("ddopt exp perf")),
        (
            "kernel_isa",
            Json::str(crate::linalg::detected().isa.name()),
        ),
        (
            "provenance",
            // alloc data is the gated half of the baseline: only a
            // counting-allocator build produces a fully measured snapshot
            Json::str(if crate::util::alloc::counting_enabled() {
                "measured"
            } else {
                "measured (throughput only — rebuilt without bench-alloc, alloc entries null)"
            }),
        ),
        (
            "scale",
            Json::str(match scale {
                Scale::Small => "small",
                Scale::Paper => "paper",
            }),
        ),
        (
            "alloc_counting_enabled",
            Json::Bool(crate::util::alloc::counting_enabled()),
        ),
        ("native_kernels", json_section(&kernels)),
        ("kernels", json_section(&disp)),
        ("sparse_kernels", json_section(&sparse)),
        ("coordinator", json_section(&coord)),
        ("pool", json_section(&pool)),
        ("wire", json_section(&wire)),
        ("trace", json_section(&trace)),
        ("steady_state_allocs", alloc_json),
        ("xla", json_section(&xla)),
        ("l1_estimates", json_section(&l1)),
    ]);
    let bench_path = repo_root().join("BENCH_perf.json");
    std::fs::write(&bench_path, format!("{doc}\n"))?;
    println!("\nperf baseline -> {}", bench_path.display());
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn native_kernel_bench_reports_positive_rates() {
        let r = native_kernels(64, 64, 2);
        assert_eq!(r.len(), 4);
        for (k, v) in r {
            assert!(v > 0.0, "{k}");
        }
    }

    #[test]
    fn kernel_dispatch_bench_covers_both_tables() {
        let r = kernel_dispatch(48, 33, 2);
        // 7-entry dispatch table minus `scale` (covered transitively by
        // axpy codegen) = 6 kernels × {scalar, dispatched}
        assert_eq!(r.len(), 12);
        for pair in r.chunks(2) {
            assert!(pair[0].0.contains("(scalar)"), "{}", pair[0].0);
            assert!(pair[1].0.contains("(dispatched)"), "{}", pair[1].0);
            assert!(pair[0].1 > 0.0 && pair[1].1 > 0.0, "{}", pair[0].0);
        }
    }

    #[test]
    fn sparse_kernel_bench_reports_positive_rates() {
        let r = sparse_kernels(256, 128, 0.05, 2);
        assert_eq!(r.len(), 5);
        for (k, v) in r {
            assert!(v > 0.0, "{k}");
        }
    }

    #[test]
    fn steady_state_alloc_probe_runs_on_any_build() {
        // With bench-alloc: every workspace-path coordinator must be at
        // (or extremely near) zero; the boxed baseline must not be.
        // Without: probes report None and the harness still runs.
        let rows = steady_state_allocs().unwrap();
        // 3 coordinators × threads {1, 2, 4} + parallel aggregate
        // + 3 traced coordinators + legacy
        assert_eq!(rows.len(), 14);
        for (k, v) in &rows {
            if crate::util::alloc::counting_enabled() {
                assert!(v.is_some(), "{k}");
            } else {
                assert!(v.is_none(), "{k}");
            }
        }
        if crate::util::alloc::counting_enabled() {
            let legacy = rows.last().unwrap().1.unwrap();
            assert!(legacy > 0.0, "boxed pipeline should allocate");
        }
    }

    #[test]
    fn trace_overhead_probe_reports_both_sides_and_records_spans() {
        let rows = trace_overhead(2, 1).unwrap();
        assert_eq!(rows.len(), 4);
        let get = |key: &str| {
            rows.iter()
                .find(|(k, _)| k == key)
                .unwrap_or_else(|| panic!("missing row {key}"))
                .1
        };
        assert!(get("untraced wall s/2it") > 0.0);
        assert!(get("traced wall s/2it") > 0.0);
        assert!(get("trace overhead frac") >= 0.0);
        assert!(get("trace spans/iter") > 0.0, "traced run must record spans");
    }

    #[cfg(not(feature = "xla"))]
    #[test]
    fn spawn_overhead_probe_reports_both_sides() {
        let rows = spawn_overhead(2, 4, 3);
        assert_eq!(rows.len(), 2);
        assert!(rows[0].0.contains("(before)"));
        assert!(rows[1].0.contains("(after)"));
        for (k, v) in &rows {
            assert!(*v > 0.0, "{k} = {v}");
        }
    }

    #[test]
    fn wire_profile_shows_sliced_shrinks_scatter() {
        let rows = wire_profile().unwrap();
        let get = |key: &str| {
            rows.iter()
                .find(|(k, _)| k == key)
                .unwrap_or_else(|| panic!("missing row {key}"))
                .1
        };
        let broadcast = get("broadcast bytes_out/superstep");
        let sliced = get("sliced bytes_out/superstep");
        assert!(broadcast > 0.0 && sliced > 0.0);
        assert!(
            sliced < broadcast,
            "sliced scatter ({sliced}) should ship fewer bytes than broadcast ({broadcast})"
        );
        assert!(get("scatter reduction (broadcast/sliced)") > 1.0);
        // folded gather must not grow the reply side either
        assert!(get("sliced bytes_in/superstep") <= get("broadcast bytes_in/superstep"));
    }

    #[test]
    fn l1_estimates_flag_the_vmem_pressure() {
        let est = l1_estimates();
        let resident = est
            .iter()
            .find(|(k, _)| k.contains("resident"))
            .unwrap()
            .1;
        assert!(resident > 16.0, "L bucket must exceed 16 MiB VMEM");
    }
}
