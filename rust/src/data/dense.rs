//! Row-major dense `f32` matrix.

use crate::linalg;

#[derive(Clone, Debug, PartialEq)]
pub struct DenseMatrix {
    pub rows: usize,
    pub cols: usize,
    pub data: Vec<f32>,
}

impl DenseMatrix {
    pub fn zeros(rows: usize, cols: usize) -> Self {
        DenseMatrix { rows, cols, data: vec![0.0; rows * cols] }
    }

    pub fn from_fn(rows: usize, cols: usize, mut f: impl FnMut(usize, usize) -> f32) -> Self {
        let mut data = Vec::with_capacity(rows * cols);
        for i in 0..rows {
            for j in 0..cols {
                data.push(f(i, j));
            }
        }
        DenseMatrix { rows, cols, data }
    }

    pub fn from_vec(rows: usize, cols: usize, data: Vec<f32>) -> Self {
        assert_eq!(data.len(), rows * cols);
        DenseMatrix { rows, cols, data }
    }

    #[inline]
    pub fn get(&self, i: usize, j: usize) -> f32 {
        self.data[i * self.cols + j]
    }

    #[inline]
    pub fn set(&mut self, i: usize, j: usize, v: f32) {
        self.data[i * self.cols + j] = v;
    }

    #[inline]
    pub fn row(&self, i: usize) -> &[f32] {
        &self.data[i * self.cols..(i + 1) * self.cols]
    }

    pub fn gemv_into(&self, x: &[f32], out: &mut [f32]) {
        self.gemv_into_with(linalg::kernels(), x, out);
    }

    pub fn gemv_t_into(&self, x: &[f32], out: &mut [f32]) {
        self.gemv_t_into_with(linalg::kernels(), x, out);
    }

    /// [`Self::gemv_into`] through an explicit dispatch table.
    pub fn gemv_into_with(&self, kd: &linalg::KernelDispatch, x: &[f32], out: &mut [f32]) {
        (kd.gemv)(&self.data, self.rows, self.cols, x, out);
    }

    /// [`Self::gemv_t_into`] through an explicit dispatch table.
    pub fn gemv_t_into_with(&self, kd: &linalg::KernelDispatch, x: &[f32], out: &mut [f32]) {
        (kd.gemv_t)(&self.data, self.rows, self.cols, x, out);
    }

    /// Copy of the sub-matrix `[r0, r1) x [c0, c1)`.
    pub fn slice(&self, r0: usize, r1: usize, c0: usize, c1: usize) -> DenseMatrix {
        assert!(r1 <= self.rows && c1 <= self.cols && r0 <= r1 && c0 <= c1);
        let mut out = DenseMatrix::zeros(r1 - r0, c1 - c0);
        for i in r0..r1 {
            let dst = (i - r0) * out.cols;
            out.data[dst..dst + out.cols]
                .copy_from_slice(&self.row(i)[c0..c1]);
        }
        out
    }

    /// Scale every column to unit variance (population), matching the
    /// paper's "features were standardized to have unit variance".
    /// Zero-variance columns are left unscaled.
    pub fn standardize_columns(&mut self) {
        let n = self.rows as f64;
        for j in 0..self.cols {
            let mut sum = 0.0f64;
            let mut sq = 0.0f64;
            for i in 0..self.rows {
                let v = self.get(i, j) as f64;
                sum += v;
                sq += v * v;
            }
            let mean = sum / n;
            let var = (sq / n - mean * mean).max(0.0);
            if var > 1e-12 {
                let inv = (1.0 / var.sqrt()) as f32;
                for i in 0..self.rows {
                    self.data[i * self.cols + j] *= inv;
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn indexing_and_rows() {
        let m = DenseMatrix::from_fn(3, 4, |i, j| (i * 10 + j) as f32);
        assert_eq!(m.get(2, 3), 23.0);
        assert_eq!(m.row(1), &[10.0, 11.0, 12.0, 13.0]);
    }

    #[test]
    fn slice_extracts_submatrix() {
        let m = DenseMatrix::from_fn(4, 5, |i, j| (i * 10 + j) as f32);
        let s = m.slice(1, 3, 2, 5);
        assert_eq!(s.rows, 2);
        assert_eq!(s.cols, 3);
        assert_eq!(s.row(0), &[12.0, 13.0, 14.0]);
        assert_eq!(s.row(1), &[22.0, 23.0, 24.0]);
    }

    #[test]
    fn standardize_gives_unit_variance() {
        let mut m = DenseMatrix::from_fn(100, 3, |i, j| {
            (i as f32 * 0.1 + j as f32) * (j as f32 + 0.5)
        });
        m.standardize_columns();
        for j in 0..3 {
            let mean: f64 = (0..100).map(|i| m.get(i, j) as f64).sum::<f64>() / 100.0;
            let var: f64 = (0..100)
                .map(|i| {
                    let d = m.get(i, j) as f64 - mean;
                    d * d
                })
                .sum::<f64>()
                / 100.0;
            assert!((var - 1.0).abs() < 1e-3, "col {j} var {var}");
        }
    }

    #[test]
    fn standardize_leaves_constant_columns() {
        let mut m = DenseMatrix::from_fn(10, 1, |_, _| 3.0);
        m.standardize_columns();
        assert_eq!(m.get(0, 0), 3.0);
    }
}
