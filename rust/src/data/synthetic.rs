//! Synthetic dataset generators.
//!
//! [`SyntheticDense`] reproduces the paper's Part-1 procedure (from Zhang,
//! Lee & Shin 2012): x_i and the ground-truth w sampled U[-1,1],
//! y_i = sgn(w.x_i) with each sign flipped w.p. 0.1, features standardized
//! to unit variance.  Partition size is (n_per x m_per); the full instance
//! is (P*n_per) x (Q*m_per) — e.g. the paper's 4x2 instance is dense
//! 8,000 x 6,000 built from 2,000 x 3,000 partitions.
//!
//! [`SyntheticSparse`] stands in for the LIBSVM data the offline
//! environment cannot download (real-sim, news20): CSR with a power-law
//! column-popularity profile (text-corpus-like), values U[-1,1], labels
//! from a sparse ground-truth hyperplane with 10% flips.

use super::dense::DenseMatrix;
use super::sparse::SparseMatrix;
use super::{Block, Dataset};
use crate::util::rng::Xoshiro;

/// Builder for the paper's Part-1 dense instances.
#[derive(Clone, Debug)]
pub struct SyntheticDense {
    pub p: usize,
    pub q: usize,
    pub n_per: usize,
    pub m_per: usize,
    pub flip_prob: f64,
    pub seed: u64,
    pub standardize: bool,
}

impl SyntheticDense {
    pub fn paper_part1(
        p: usize,
        q: usize,
        n_per: usize,
        m_per: usize,
        flip_prob: f64,
        seed: u64,
    ) -> Self {
        SyntheticDense { p, q, n_per, m_per, flip_prob, seed, standardize: true }
    }

    pub fn n(&self) -> usize {
        self.p * self.n_per
    }

    pub fn m(&self) -> usize {
        self.q * self.m_per
    }

    pub fn build(&self) -> Dataset {
        let (n, m) = (self.n(), self.m());
        let mut rng = Xoshiro::new(self.seed).substream(0xDA7A, n as u64, m as u64);
        let w_true: Vec<f32> = (0..m).map(|_| rng.range_f32(-1.0, 1.0)).collect();
        let mut x = DenseMatrix::zeros(n, m);
        let mut y = Vec::with_capacity(n);
        for i in 0..n {
            let row = &mut x.data[i * m..(i + 1) * m];
            for v in row.iter_mut() {
                *v = rng.range_f32(-1.0, 1.0);
            }
            let marg = crate::linalg::dot(row, &w_true);
            let mut label = if marg >= 0.0 { 1.0 } else { -1.0 };
            if rng.coin(self.flip_prob) {
                label = -label;
            }
            y.push(label);
        }
        if self.standardize {
            x.standardize_columns();
        }
        Dataset {
            name: format!("synth-dense-{}x{}", n, m),
            x: Block::dense(x),
            y,
        }
    }
}

/// Builder for sparse text-like stand-ins (see DESIGN.md §Substitutions).
#[derive(Clone, Debug)]
pub struct SyntheticSparse {
    pub n: usize,
    pub m: usize,
    /// Target density in (0, 1], e.g. 0.0024 for the real-sim stand-in.
    pub density: f64,
    pub flip_prob: f64,
    pub seed: u64,
    pub name: String,
}

impl SyntheticSparse {
    pub fn new(name: &str, n: usize, m: usize, density: f64, seed: u64) -> Self {
        SyntheticSparse {
            n,
            m,
            density,
            flip_prob: 0.1,
            seed,
            name: name.to_string(),
        }
    }

    /// real-sim stand-in at 1/5 linear scale (see DESIGN.md).
    pub fn realsim_like(seed: u64) -> Self {
        Self::new("realsim-like", 14_462, 4_192, 0.0024, seed)
    }

    /// news20 stand-in with features scaled 1/20 (see DESIGN.md).
    pub fn news20_like(seed: u64) -> Self {
        Self::new("news20-like", 19_996, 67_760, 0.0003, seed)
    }

    pub fn build(&self) -> Dataset {
        let mut rng =
            Xoshiro::new(self.seed).substream(0x5BA5, self.n as u64, self.m as u64);
        // Power-law column popularity: feature j drawn with weight ~ 1/(j+1)^0.8,
        // matching the head-heavy profile of bag-of-words corpora.
        let weights: Vec<f64> =
            (0..self.m).map(|j| 1.0 / ((j + 1) as f64).powf(0.8)).collect();
        let cum: Vec<f64> = weights
            .iter()
            .scan(0.0, |acc, w| {
                *acc += w;
                Some(*acc)
            })
            .collect();
        let total = *cum.last().unwrap();

        // Ground-truth hyperplane supported on the popular features.
        let w_support = (self.m / 10).max(8).min(self.m);
        let w_true: Vec<f32> =
            (0..w_support).map(|_| rng.range_f32(-1.0, 1.0)).collect();

        let nnz_per_row = ((self.m as f64 * self.density).round() as usize).max(1);
        let mut triplets = Vec::with_capacity(self.n * nnz_per_row);
        let mut y = Vec::with_capacity(self.n);
        let mut row_cols: Vec<usize> = Vec::with_capacity(nnz_per_row);
        for i in 0..self.n {
            row_cols.clear();
            while row_cols.len() < nnz_per_row {
                let u = rng.f64() * total;
                let j = match cum.binary_search_by(|c| c.partial_cmp(&u).unwrap()) {
                    Ok(k) | Err(k) => k.min(self.m - 1),
                };
                if !row_cols.contains(&j) {
                    row_cols.push(j);
                }
            }
            let mut marg = 0.0f32;
            for &j in row_cols.iter() {
                let v = rng.range_f32(-1.0, 1.0);
                triplets.push((i, j, v));
                if j < w_support {
                    marg += v * w_true[j];
                }
            }
            let mut label = if marg >= 0.0 { 1.0 } else { -1.0 };
            if rng.coin(self.flip_prob) {
                label = -label;
            }
            y.push(label);
        }
        Dataset {
            name: self.name.clone(),
            x: Block::sparse(SparseMatrix::from_triplets(self.n, self.m, triplets)),
            y,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dense_builder_shapes_and_labels() {
        let ds = SyntheticDense::paper_part1(2, 3, 50, 40, 0.1, 7).build();
        assert_eq!(ds.n(), 100);
        assert_eq!(ds.m(), 120);
        assert!(ds.y.iter().all(|&l| l == 1.0 || l == -1.0));
        // roughly balanced labels (uniform x, uniform w)
        let pos = ds.y.iter().filter(|&&l| l > 0.0).count();
        assert!(pos > 20 && pos < 80, "pos {pos}");
    }

    #[test]
    fn dense_builder_is_deterministic() {
        let a = SyntheticDense::paper_part1(2, 2, 20, 20, 0.1, 3).build();
        let b = SyntheticDense::paper_part1(2, 2, 20, 20, 0.1, 3).build();
        match (a.x.as_dense(), b.x.as_dense()) {
            (Some(ma), Some(mb)) => assert_eq!(ma, mb),
            _ => panic!(),
        }
        assert_eq!(a.y, b.y);
    }

    #[test]
    fn dense_standardized_unit_variance() {
        let ds = SyntheticDense::paper_part1(4, 1, 100, 10, 0.1, 5).build();
        if let Some(x) = ds.x.as_dense() {
            for j in 0..x.cols {
                let mean: f64 =
                    (0..x.rows).map(|i| x.get(i, j) as f64).sum::<f64>() / x.rows as f64;
                let var: f64 = (0..x.rows)
                    .map(|i| {
                        let d = x.get(i, j) as f64 - mean;
                        d * d
                    })
                    .sum::<f64>()
                    / x.rows as f64;
                assert!((var - 1.0).abs() < 1e-2, "col {j} var {var}");
            }
        } else {
            panic!()
        }
    }

    #[test]
    fn sparse_builder_hits_density() {
        let g = SyntheticSparse::new("t", 500, 400, 0.01, 11);
        let ds = g.build();
        assert_eq!(ds.n(), 500);
        assert_eq!(ds.m(), 400);
        let d = ds.sparsity();
        assert!((d - 0.01).abs() < 0.003, "density {d}");
    }

    #[test]
    fn sparse_builder_deterministic() {
        let a = SyntheticSparse::new("t", 100, 200, 0.02, 13).build();
        let b = SyntheticSparse::new("t", 100, 200, 0.02, 13).build();
        match (a.x.as_sparse(), b.x.as_sparse()) {
            (Some(ma), Some(mb)) => assert_eq!(ma, mb),
            _ => panic!(),
        }
    }

    #[test]
    fn sparse_labels_correlate_with_popular_features() {
        // sanity: the generated task is learnable (labels not pure noise):
        // a weight vector fit on the popular block should beat chance.
        let ds = SyntheticSparse::new("t", 400, 300, 0.05, 17).build();
        let pos = ds.y.iter().filter(|&&l| l > 0.0).count();
        assert!(pos > 100 && pos < 300, "pos {pos}");
    }
}
