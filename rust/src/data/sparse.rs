//! CSR sparse `f32` matrix — backs the paper's Part-2 experiments
//! (real-sim at 0.24% and news20 at 0.03% density).

use super::dense::DenseMatrix;

#[derive(Clone, Debug, PartialEq)]
pub struct SparseMatrix {
    pub rows: usize,
    pub cols: usize,
    /// Row start offsets, length rows+1.
    pub indptr: Vec<usize>,
    /// Column indices per stored value (strictly increasing within a row).
    pub indices: Vec<u32>,
    pub values: Vec<f32>,
}

impl SparseMatrix {
    pub fn from_triplets(
        rows: usize,
        cols: usize,
        mut triplets: Vec<(usize, usize, f32)>,
    ) -> Self {
        triplets.sort_unstable_by_key(|t| (t.0, t.1));
        triplets.dedup_by(|a, b| {
            if a.0 == b.0 && a.1 == b.1 {
                b.2 += a.2; // accumulate duplicates into the kept entry
                true
            } else {
                false
            }
        });
        let mut indptr = vec![0usize; rows + 1];
        let mut indices = Vec::with_capacity(triplets.len());
        let mut values = Vec::with_capacity(triplets.len());
        for (i, j, v) in triplets {
            assert!(i < rows && j < cols, "triplet ({i},{j}) out of bounds");
            indptr[i + 1] += 1;
            indices.push(j as u32);
            values.push(v);
        }
        for i in 0..rows {
            indptr[i + 1] += indptr[i];
        }
        SparseMatrix { rows, cols, indptr, indices, values }
    }

    pub fn from_dense(d: &DenseMatrix) -> Self {
        let mut triplets = Vec::new();
        for i in 0..d.rows {
            for j in 0..d.cols {
                let v = d.get(i, j);
                if v != 0.0 {
                    triplets.push((i, j, v));
                }
            }
        }
        Self::from_triplets(d.rows, d.cols, triplets)
    }

    pub fn nnz(&self) -> usize {
        self.values.len()
    }

    /// Iterate (col, value) of row i.
    pub fn row_iter(&self, i: usize) -> impl Iterator<Item = (usize, f32)> + '_ {
        let (s, e) = (self.indptr[i], self.indptr[i + 1]);
        self.indices[s..e]
            .iter()
            .zip(&self.values[s..e])
            .map(|(&j, &v)| (j as usize, v))
    }

    pub fn gemv_into(&self, x: &[f32], out: &mut [f32]) {
        debug_assert_eq!(x.len(), self.cols);
        debug_assert_eq!(out.len(), self.rows);
        for i in 0..self.rows {
            out[i] = self.row_dot(i, x);
        }
    }

    pub fn gemv_t_into(&self, x: &[f32], out: &mut [f32]) {
        debug_assert_eq!(x.len(), self.rows);
        debug_assert_eq!(out.len(), self.cols);
        out.fill(0.0);
        for i in 0..self.rows {
            let xi = x[i];
            if xi != 0.0 {
                let (s, e) = (self.indptr[i], self.indptr[i + 1]);
                for k in s..e {
                    out[self.indices[k] as usize] += xi * self.values[k];
                }
            }
        }
    }

    #[inline]
    pub fn row_dot(&self, i: usize, w: &[f32]) -> f32 {
        let (s, e) = (self.indptr[i], self.indptr[i + 1]);
        let mut acc = 0.0f32;
        for k in s..e {
            acc += self.values[k] * w[self.indices[k] as usize];
        }
        acc
    }

    pub fn row_dot_window(&self, i: usize, w: &[f32], lo: usize, hi: usize) -> f32 {
        let (s, e) = (self.indptr[i], self.indptr[i + 1]);
        let mut acc = 0.0f32;
        for k in s..e {
            let j = self.indices[k] as usize;
            if j >= lo && j < hi {
                acc += self.values[k] * w[j];
            }
        }
        acc
    }

    #[inline]
    pub fn row_norm_sq(&self, i: usize) -> f32 {
        let (s, e) = (self.indptr[i], self.indptr[i + 1]);
        self.values[s..e].iter().map(|v| v * v).sum()
    }

    pub fn row_axpy(&self, i: usize, a: f32, w: &mut [f32]) {
        let (s, e) = (self.indptr[i], self.indptr[i + 1]);
        for k in s..e {
            w[self.indices[k] as usize] += a * self.values[k];
        }
    }

    pub fn row_axpy_window(&self, i: usize, a: f32, w: &mut [f32], lo: usize, hi: usize) {
        let (s, e) = (self.indptr[i], self.indptr[i + 1]);
        for k in s..e {
            let j = self.indices[k] as usize;
            if j >= lo && j < hi {
                w[j] += a * self.values[k];
            }
        }
    }

    /// Copy of the sub-matrix `[r0, r1) x [c0, c1)` with re-based columns.
    pub fn slice(&self, r0: usize, r1: usize, c0: usize, c1: usize) -> SparseMatrix {
        assert!(r1 <= self.rows && c1 <= self.cols && r0 <= r1 && c0 <= c1);
        let mut triplets = Vec::new();
        for i in r0..r1 {
            for (j, v) in self.row_iter(i) {
                if j >= c0 && j < c1 {
                    triplets.push((i - r0, j - c0, v));
                }
            }
        }
        SparseMatrix::from_triplets(r1 - r0, c1 - c0, triplets)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn example() -> SparseMatrix {
        // [[1, 0, 2],
        //  [0, 0, 0],
        //  [0, 3, 4]]
        SparseMatrix::from_triplets(3, 3, vec![(0, 0, 1.0), (0, 2, 2.0), (2, 1, 3.0), (2, 2, 4.0)])
    }

    #[test]
    fn csr_layout() {
        let m = example();
        assert_eq!(m.indptr, vec![0, 2, 2, 4]);
        assert_eq!(m.nnz(), 4);
        assert_eq!(m.row_iter(2).collect::<Vec<_>>(), vec![(1, 3.0), (2, 4.0)]);
    }

    #[test]
    fn duplicate_triplets_accumulate() {
        let m = SparseMatrix::from_triplets(1, 2, vec![(0, 1, 1.0), (0, 1, 2.5)]);
        assert_eq!(m.nnz(), 1);
        assert_eq!(m.row_iter(0).collect::<Vec<_>>(), vec![(1, 3.5)]);
    }

    #[test]
    fn gemv_matches_dense() {
        let m = example();
        let w = vec![1.0, 10.0, 100.0];
        let mut out = vec![0.0; 3];
        m.gemv_into(&w, &mut out);
        assert_eq!(out, vec![201.0, 0.0, 430.0]);
        let v = vec![1.0, 2.0, 3.0];
        let mut out_t = vec![0.0; 3];
        m.gemv_t_into(&v, &mut out_t);
        assert_eq!(out_t, vec![1.0, 9.0, 14.0]);
    }

    #[test]
    fn slice_rebases() {
        let m = example();
        let s = m.slice(0, 3, 1, 3);
        assert_eq!(s.rows, 3);
        assert_eq!(s.cols, 2);
        assert_eq!(s.row_iter(0).collect::<Vec<_>>(), vec![(1, 2.0)]);
        assert_eq!(s.row_iter(2).collect::<Vec<_>>(), vec![(0, 3.0), (1, 4.0)]);
    }

    #[test]
    fn empty_rows_are_fine() {
        let m = SparseMatrix::from_triplets(4, 2, vec![]);
        assert_eq!(m.nnz(), 0);
        let mut out = vec![9.0; 4];
        m.gemv_into(&[1.0, 1.0], &mut out);
        assert_eq!(out, vec![0.0; 4]);
    }
}
