//! CSR sparse `f32` matrix — backs the paper's Part-2 experiments
//! (real-sim at 0.24% and news20 at 0.03% density).
//!
//! A matrix can additionally carry a CSC mirror ([`SparseMatrix::build_csc`],
//! one counting sort): the transpose products (`gemv_t_into`, D3CA's
//! primal recovery) then stream whole columns into sequential output
//! slots instead of scatter-writing through the CSR rows — §V's "primal
//! vector computation bottleneck" engineered down the way CoCoA keeps
//! resident per-worker state.  The partitioner builds the mirror for
//! every per-partition block (the compute hot path); whole-dataset
//! matrices skip it (their transpose product is cold, and mirroring
//! news20-scale data would double load-time memory) and fall back to the
//! scatter kernel.  For RADiSA's sub-block windows a
//! [`SubblockIndex`] caches, per row, the CSR value positions of every
//! window boundary (via `partition_point` on the sorted column indices),
//! so windowed dots/axpys touch O(nnz in window) entries instead of
//! scanning O(nnz in row) — a large win at news20's 0.03% density split
//! over Q feature blocks.

use super::dense::DenseMatrix;

#[derive(Clone, Debug)]
pub struct SparseMatrix {
    pub rows: usize,
    pub cols: usize,
    /// Row start offsets, length rows+1.
    pub indptr: Vec<usize>,
    /// Column indices per stored value (strictly increasing within a row).
    pub indices: Vec<u32>,
    pub values: Vec<f32>,
    /// CSC mirror: column start offsets, length cols+1 once built
    /// ([`SparseMatrix::build_csc`]), empty otherwise.
    csc_indptr: Vec<usize>,
    /// Row indices per CSC-stored value (strictly increasing in a column).
    csc_rows: Vec<u32>,
    csc_vals: Vec<f32>,
}

/// Equality is defined on the CSR content only — the CSC mirror is
/// derived data and whether it has been built is not part of the value.
impl PartialEq for SparseMatrix {
    fn eq(&self, other: &Self) -> bool {
        self.rows == other.rows
            && self.cols == other.cols
            && self.indptr == other.indptr
            && self.indices == other.indices
            && self.values == other.values
    }
}

impl SparseMatrix {
    pub fn from_triplets(
        rows: usize,
        cols: usize,
        mut triplets: Vec<(usize, usize, f32)>,
    ) -> Self {
        // `slice()` / `from_dense()` / the generators all emit triplets
        // already in (row, col) order — detect that and skip the
        // O(nnz log nnz) sort entirely (partition time is dominated by
        // this path).  Non-decreasing is enough: duplicates still
        // accumulate below, in the same first-to-last order as the sorted
        // path's dedup.
        let sorted = triplets
            .windows(2)
            .all(|w| (w[0].0, w[0].1) <= (w[1].0, w[1].1));
        if !sorted {
            triplets.sort_unstable_by_key(|t| (t.0, t.1));
        }
        let mut indptr = vec![0usize; rows + 1];
        let mut indices: Vec<u32> = Vec::with_capacity(triplets.len());
        let mut values: Vec<f32> = Vec::with_capacity(triplets.len());
        let mut last: Option<(usize, usize)> = None;
        for (i, j, v) in triplets {
            assert!(i < rows && j < cols, "triplet ({i},{j}) out of bounds");
            if last == Some((i, j)) {
                // accumulate duplicates into the kept entry
                *values.last_mut().unwrap() += v;
            } else {
                indptr[i + 1] += 1;
                indices.push(j as u32);
                values.push(v);
                last = Some((i, j));
            }
        }
        for i in 0..rows {
            indptr[i + 1] += indptr[i];
        }
        SparseMatrix {
            rows,
            cols,
            indptr,
            indices,
            values,
            csc_indptr: Vec::new(),
            csc_rows: Vec::new(),
            csc_vals: Vec::new(),
        }
    }

    /// Assemble from raw CSR arrays (the partition-block deserializer's
    /// entry point); validates the CSR invariants so a corrupt or
    /// truncated frame cannot build a matrix whose kernels later index
    /// out of bounds.  No CSC mirror — call [`SparseMatrix::build_csc`]
    /// after if the source block carried one.
    pub fn from_csr(
        rows: usize,
        cols: usize,
        indptr: Vec<usize>,
        indices: Vec<u32>,
        values: Vec<f32>,
    ) -> anyhow::Result<SparseMatrix> {
        use anyhow::bail;
        if indptr.len() != rows + 1 {
            bail!("CSR indptr length {} != rows + 1 = {}", indptr.len(), rows + 1);
        }
        if indptr.first() != Some(&0) || *indptr.last().unwrap() != values.len() {
            bail!("CSR indptr endpoints do not bracket the {} values", values.len());
        }
        if indices.len() != values.len() {
            bail!("CSR indices/values length mismatch: {} vs {}", indices.len(), values.len());
        }
        for i in 0..rows {
            if indptr[i] > indptr[i + 1] {
                bail!("CSR indptr decreases at row {i}");
            }
            for k in indptr[i]..indptr[i + 1] {
                let j = indices[k] as usize;
                if j >= cols {
                    bail!("CSR column {j} out of bounds (cols {cols}) at row {i}");
                }
                if k > indptr[i] && indices[k - 1] >= indices[k] {
                    bail!("CSR columns not strictly increasing within row {i}");
                }
            }
        }
        Ok(SparseMatrix {
            rows,
            cols,
            indptr,
            indices,
            values,
            csc_indptr: Vec::new(),
            csc_rows: Vec::new(),
            csc_vals: Vec::new(),
        })
    }

    /// Whether the CSC mirror has been built.
    pub fn has_csc(&self) -> bool {
        self.csc_indptr.len() == self.cols + 1
    }

    /// Counting-sort the CSR entries into the CSC mirror (idempotent).
    /// Walking the rows in order means each column's entries land in
    /// ascending row order, so a column stream visits exactly the terms
    /// the row-major scatter would, in the same order (bit-identical
    /// accumulation).  Costs one O(nnz) pass plus ~8 bytes/nnz of
    /// resident memory — the partitioner pays it for every per-partition
    /// block; whole-dataset matrices skip it.
    pub fn build_csc(&mut self) {
        if self.has_csc() {
            return;
        }
        let nnz = self.values.len();
        let mut colptr = vec![0usize; self.cols + 1];
        for &j in &self.indices {
            colptr[j as usize + 1] += 1;
        }
        for j in 0..self.cols {
            colptr[j + 1] += colptr[j];
        }
        let mut csc_rows = vec![0u32; nnz];
        let mut csc_vals = vec![0.0f32; nnz];
        let mut cursor = colptr.clone();
        for i in 0..self.rows {
            for k in self.indptr[i]..self.indptr[i + 1] {
                let j = self.indices[k] as usize;
                let dst = cursor[j];
                csc_rows[dst] = i as u32;
                csc_vals[dst] = self.values[k];
                cursor[j] += 1;
            }
        }
        self.csc_indptr = colptr;
        self.csc_rows = csc_rows;
        self.csc_vals = csc_vals;
    }

    pub fn from_dense(d: &DenseMatrix) -> Self {
        let mut triplets = Vec::new();
        for i in 0..d.rows {
            for j in 0..d.cols {
                let v = d.get(i, j);
                if v != 0.0 {
                    triplets.push((i, j, v));
                }
            }
        }
        Self::from_triplets(d.rows, d.cols, triplets)
    }

    pub fn nnz(&self) -> usize {
        self.values.len()
    }

    /// Iterate (col, value) of row i.
    pub fn row_iter(&self, i: usize) -> impl Iterator<Item = (usize, f32)> + '_ {
        let (s, e) = (self.indptr[i], self.indptr[i + 1]);
        self.indices[s..e]
            .iter()
            .zip(&self.values[s..e])
            .map(|(&j, &v)| (j as usize, v))
    }

    pub fn gemv_into(&self, x: &[f32], out: &mut [f32]) {
        debug_assert_eq!(x.len(), self.cols);
        debug_assert_eq!(out.len(), self.rows);
        for i in 0..self.rows {
            out[i] = self.row_dot(i, x);
        }
    }

    /// out = Xᵀ x.  With the CSC mirror built, each output slot is
    /// written once, sequentially, instead of being scattered into from
    /// every row; terms per slot match [`gemv_t_scatter_into`] in value
    /// and order (ascending row, zero inputs skipped), so the two are
    /// bit-identical.  Without the mirror this falls back to the scatter
    /// kernel.  Runs the active dispatch table's block-column strip
    /// kernel (`spmv_t_csc`, 4 columns in lockstep).
    pub fn gemv_t_into(&self, x: &[f32], out: &mut [f32]) {
        self.gemv_t_into_with(crate::linalg::kernels(), x, out)
    }

    /// [`gemv_t_into`] through an explicit dispatch table — the variant
    /// `GridOp::exec_task` plumbs its per-scratch handle into.
    pub fn gemv_t_into_with(
        &self,
        kd: &crate::linalg::KernelDispatch,
        x: &[f32],
        out: &mut [f32],
    ) {
        debug_assert_eq!(x.len(), self.rows);
        debug_assert_eq!(out.len(), self.cols);
        if !self.has_csc() {
            return self.gemv_t_scatter_into(x, out);
        }
        (kd.spmv_t_csc)(&self.csc_indptr, &self.csc_rows, &self.csc_vals, x, out)
    }

    /// out = Xᵀ x via CSR row scatter — the pre-CSC implementation, kept
    /// as the parity/throughput baseline for the §Perf harness.
    pub fn gemv_t_scatter_into(&self, x: &[f32], out: &mut [f32]) {
        debug_assert_eq!(x.len(), self.rows);
        debug_assert_eq!(out.len(), self.cols);
        out.fill(0.0);
        for i in 0..self.rows {
            let xi = x[i];
            if xi != 0.0 {
                let (s, e) = (self.indptr[i], self.indptr[i + 1]);
                for k in s..e {
                    out[self.indices[k] as usize] += xi * self.values[k];
                }
            }
        }
    }

    #[inline]
    pub fn row_dot(&self, i: usize, w: &[f32]) -> f32 {
        let (s, e) = (self.indptr[i], self.indptr[i + 1]);
        let mut acc = 0.0f32;
        for k in s..e {
            acc += self.values[k] * w[self.indices[k] as usize];
        }
        acc
    }

    pub fn row_dot_window(&self, i: usize, w: &[f32], lo: usize, hi: usize) -> f32 {
        let (s, e) = (self.indptr[i], self.indptr[i + 1]);
        let mut acc = 0.0f32;
        for k in s..e {
            let j = self.indices[k] as usize;
            if j >= lo && j < hi {
                acc += self.values[k] * w[j];
            }
        }
        acc
    }

    #[inline]
    pub fn row_norm_sq(&self, i: usize) -> f32 {
        let (s, e) = (self.indptr[i], self.indptr[i + 1]);
        self.values[s..e].iter().map(|v| v * v).sum()
    }

    pub fn row_axpy(&self, i: usize, a: f32, w: &mut [f32]) {
        let (s, e) = (self.indptr[i], self.indptr[i + 1]);
        for k in s..e {
            w[self.indices[k] as usize] += a * self.values[k];
        }
    }

    pub fn row_axpy_window(&self, i: usize, a: f32, w: &mut [f32], lo: usize, hi: usize) {
        let (s, e) = (self.indptr[i], self.indptr[i + 1]);
        for k in s..e {
            let j = self.indices[k] as usize;
            if j >= lo && j < hi {
                w[j] += a * self.values[k];
            }
        }
    }

    /// x_i[lo..·] · d over the CSR value range `[s, e)`, with `d` re-based
    /// to the window (`d[c - lo]` pairs with column `c`).  `[s, e)` comes
    /// from a [`SubblockIndex`], so only the in-window entries are
    /// touched — no per-entry column filtering.
    #[inline]
    pub fn range_dot_rebased(&self, s: usize, e: usize, d: &[f32], lo: usize) -> f32 {
        let mut acc = 0.0f32;
        for k in s..e {
            acc += self.values[k] * d[self.indices[k] as usize - lo];
        }
        acc
    }

    /// out[c - lo] += a * x_i[c] over the CSR value range `[s, e)` — the
    /// windowed axpy with a re-based output, positions from a
    /// [`SubblockIndex`].
    #[inline]
    pub fn range_axpy_rebased(&self, s: usize, e: usize, a: f32, out: &mut [f32], lo: usize) {
        for k in s..e {
            out[self.indices[k] as usize - lo] += a * self.values[k];
        }
    }

    /// Copy of the sub-matrix `[r0, r1) x [c0, c1)` with re-based columns.
    pub fn slice(&self, r0: usize, r1: usize, c0: usize, c1: usize) -> SparseMatrix {
        assert!(r1 <= self.rows && c1 <= self.cols && r0 <= r1 && c0 <= c1);
        let mut triplets = Vec::new();
        for i in r0..r1 {
            for (j, v) in self.row_iter(i) {
                if j >= c0 && j < c1 {
                    triplets.push((i - r0, j - c0, v));
                }
            }
        }
        SparseMatrix::from_triplets(r1 - r0, c1 - c0, triplets)
    }
}

/// Cached per-row CSR positions of a fixed set of column-window
/// boundaries — RADiSA's sub-block grid over one `[p,q]` block.
///
/// `bounds` is a non-decreasing boundary list starting at 0 and ending at
/// `cols` (the sub-block tiling of the local feature slice, plus the full
/// window as the span `[0, nb]`).  For row `i` and boundary `b`,
/// `pos[i * (nb+1) + b]` is the index of the first stored entry of row
/// `i` whose column is ≥ `bounds[b]` — found once with `partition_point`
/// on the sorted column indices, then reused by every SVRG step of every
/// iteration.
#[derive(Clone, Debug)]
pub struct SubblockIndex {
    bounds: Vec<usize>,
    /// Row stride = bounds.len().
    pos: Vec<u32>,
}

impl SubblockIndex {
    pub fn new(m: &SparseMatrix, bounds: &[usize]) -> SubblockIndex {
        debug_assert!(bounds.windows(2).all(|w| w[0] <= w[1]));
        debug_assert_eq!(bounds.first().copied(), Some(0));
        debug_assert_eq!(bounds.last().copied(), Some(m.cols));
        let nb1 = bounds.len();
        let mut pos = vec![0u32; m.rows * nb1];
        for i in 0..m.rows {
            let (s, e) = (m.indptr[i], m.indptr[i + 1]);
            let row = &m.indices[s..e];
            for (b, &bound) in bounds.iter().enumerate() {
                let off = row.partition_point(|&j| (j as usize) < bound);
                pos[i * nb1 + b] = (s + off) as u32;
            }
        }
        SubblockIndex { bounds: bounds.to_vec(), pos }
    }

    /// Boundary-slot span matching the column window `[lo, hi)`, if both
    /// edges are cached boundaries (the full window `[0, cols)` always
    /// matches as `(0, nb)`).
    pub fn span(&self, lo: usize, hi: usize) -> Option<(usize, usize)> {
        let s0 = self.bounds.partition_point(|&b| b < lo);
        let s1 = self.bounds.partition_point(|&b| b < hi);
        if self.bounds.get(s0) == Some(&lo) && self.bounds.get(s1) == Some(&hi) {
            Some((s0, s1))
        } else {
            None
        }
    }

    /// CSR value range of row `i` within the boundary span `(s0, s1)`.
    #[inline]
    pub fn row_range(&self, i: usize, span: (usize, usize)) -> (usize, usize) {
        let nb1 = self.bounds.len();
        (
            self.pos[i * nb1 + span.0] as usize,
            self.pos[i * nb1 + span.1] as usize,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn example() -> SparseMatrix {
        // [[1, 0, 2],
        //  [0, 0, 0],
        //  [0, 3, 4]]
        SparseMatrix::from_triplets(3, 3, vec![(0, 0, 1.0), (0, 2, 2.0), (2, 1, 3.0), (2, 2, 4.0)])
    }

    #[test]
    fn csr_layout() {
        let m = example();
        assert_eq!(m.indptr, vec![0, 2, 2, 4]);
        assert_eq!(m.nnz(), 4);
        assert_eq!(m.row_iter(2).collect::<Vec<_>>(), vec![(1, 3.0), (2, 4.0)]);
    }

    #[test]
    fn duplicate_triplets_accumulate() {
        let m = SparseMatrix::from_triplets(1, 2, vec![(0, 1, 1.0), (0, 1, 2.5)]);
        assert_eq!(m.nnz(), 1);
        assert_eq!(m.row_iter(0).collect::<Vec<_>>(), vec![(1, 3.5)]);
    }

    #[test]
    fn unsorted_triplets_match_sorted() {
        let sorted = vec![(0, 0, 1.0), (0, 2, 2.0), (1, 1, 5.0), (2, 1, 3.0)];
        let mut shuffled = sorted.clone();
        shuffled.reverse();
        let a = SparseMatrix::from_triplets(3, 3, sorted);
        let b = SparseMatrix::from_triplets(3, 3, shuffled);
        assert_eq!(a, b);
    }

    #[test]
    fn unsorted_duplicates_still_accumulate() {
        let m = SparseMatrix::from_triplets(
            2,
            2,
            vec![(1, 0, 4.0), (0, 1, 1.0), (1, 0, 0.5), (0, 1, 2.5)],
        );
        assert_eq!(m.nnz(), 2);
        assert_eq!(m.row_iter(0).collect::<Vec<_>>(), vec![(1, 3.5)]);
        assert_eq!(m.row_iter(1).collect::<Vec<_>>(), vec![(0, 4.5)]);
    }

    #[test]
    fn gemv_matches_dense() {
        let m = example();
        let w = vec![1.0, 10.0, 100.0];
        let mut out = vec![0.0; 3];
        m.gemv_into(&w, &mut out);
        assert_eq!(out, vec![201.0, 0.0, 430.0]);
        let v = vec![1.0, 2.0, 3.0];
        let mut out_t = vec![0.0; 3];
        m.gemv_t_into(&v, &mut out_t);
        assert_eq!(out_t, vec![1.0, 9.0, 14.0]);
    }

    #[test]
    fn csc_mirror_matches_scatter_bitwise() {
        let mut r = crate::util::rng::Xoshiro::new(11);
        for (n, m, density) in [(13, 9, 0.4), (40, 25, 0.08), (7, 30, 1.0)] {
            let mut triplets = Vec::new();
            for i in 0..n {
                for j in 0..m {
                    if r.coin(density) {
                        triplets.push((i, j, r.range_f32(-2.0, 2.0)));
                    }
                }
            }
            let mut sm = SparseMatrix::from_triplets(n, m, triplets);
            assert!(!sm.has_csc(), "mirror is opt-in");
            sm.build_csc();
            assert!(sm.has_csc());
            let mut v: Vec<f32> = (0..n).map(|_| r.range_f32(-1.0, 1.0)).collect();
            v[0] = 0.0; // exercise the zero-input skip on both paths
            let mut a = vec![0.0f32; m];
            let mut b = vec![0.0f32; m];
            sm.gemv_t_into(&v, &mut a);
            sm.gemv_t_scatter_into(&v, &mut b);
            for j in 0..m {
                assert_eq!(a[j].to_bits(), b[j].to_bits(), "col {j}");
            }
        }
    }

    #[test]
    fn slice_rebases() {
        let m = example();
        let s = m.slice(0, 3, 1, 3);
        assert_eq!(s.rows, 3);
        assert_eq!(s.cols, 2);
        assert_eq!(s.row_iter(0).collect::<Vec<_>>(), vec![(1, 2.0)]);
        assert_eq!(s.row_iter(2).collect::<Vec<_>>(), vec![(0, 3.0), (1, 4.0)]);
    }

    #[test]
    fn empty_rows_are_fine() {
        let m = SparseMatrix::from_triplets(4, 2, vec![]);
        assert_eq!(m.nnz(), 0);
        let mut out = vec![9.0; 4];
        m.gemv_into(&[1.0, 1.0], &mut out);
        assert_eq!(out, vec![0.0; 4]);
    }

    #[test]
    fn subblock_index_matches_scan_ops() {
        let mut r = crate::util::rng::Xoshiro::new(5);
        let (n, cols) = (20, 17);
        let mut triplets = Vec::new();
        for i in 0..n {
            for j in 0..cols {
                if r.coin(0.3) {
                    triplets.push((i, j, r.range_f32(-1.0, 1.0)));
                }
            }
        }
        let m = SparseMatrix::from_triplets(n, cols, triplets);
        let bounds = vec![0, 5, 11, 17];
        let ix = SubblockIndex::new(&m, &bounds);
        for (lo, hi) in [(0, 5), (5, 11), (11, 17), (0, 17), (5, 17)] {
            let span = ix.span(lo, hi).unwrap();
            let w: Vec<f32> = (0..cols).map(|_| r.range_f32(-1.0, 1.0)).collect();
            let d: Vec<f32> = w[lo..hi].to_vec();
            for i in 0..n {
                let (s, e) = ix.row_range(i, span);
                // dot
                let fast = m.range_dot_rebased(s, e, &d, lo);
                let mut slow = 0.0f32;
                for (j, v) in m.row_iter(i) {
                    if j >= lo && j < hi {
                        slow += v * d[j - lo];
                    }
                }
                assert_eq!(fast.to_bits(), slow.to_bits(), "row {i} [{lo},{hi})");
                // axpy
                let mut fa = vec![0.25f32; hi - lo];
                let mut sa = fa.clone();
                m.range_axpy_rebased(s, e, 0.5, &mut fa, lo);
                for (j, v) in m.row_iter(i) {
                    if j >= lo && j < hi {
                        sa[j - lo] += 0.5 * v;
                    }
                }
                assert_eq!(fa, sa, "row {i} [{lo},{hi})");
            }
        }
        assert_eq!(ix.span(1, 5), None, "unaligned lo is not cached");
        assert_eq!(ix.span(0, 6), None, "unaligned hi is not cached");
    }
}
