//! Data substrate: matrices, generators, the LIBSVM reader, and the P×Q
//! doubly-distributed partitioner.
//!
//! The central abstraction is [`Block`], a dense-or-CSR matrix fragment
//! holding partition `[p,q]`'s slice of the design matrix.  Dense blocks
//! feed the XLA artifacts (padded to shape buckets); sparse blocks are
//! consumed by the native backend (the paper's Part-2 experiments are
//! 0.03%-0.24% sparse, where a dense buffer would be pathological).

mod dense;
mod libsvm;
mod partition;
mod sparse;
mod synthetic;

pub use dense::DenseMatrix;
pub use libsvm::{read_libsvm, write_libsvm};
pub use partition::{
    balanced_ranges, decode_block, encode_block, Grid, Partitioned, SubBlocks,
};
pub use sparse::{SparseMatrix, SubblockIndex};
pub use synthetic::{SyntheticDense, SyntheticSparse};

/// The storage behind a [`Block`].
#[derive(Clone, Debug)]
pub enum BlockRepr {
    Dense(DenseMatrix),
    Sparse(SparseMatrix),
}

/// A matrix fragment — one `[p,q]` partition's feature slice.
///
/// The non-zero count is computed once at construction (it feeds the
/// scenario cost estimates every superstep; recounting a dense buffer per
/// call was an O(n·m) tax).
#[derive(Clone, Debug)]
pub struct Block {
    repr: BlockRepr,
    nnz: usize,
}

impl Block {
    pub fn dense(m: DenseMatrix) -> Block {
        let nnz = m.data.iter().filter(|v| **v != 0.0).count();
        Block { repr: BlockRepr::Dense(m), nnz }
    }

    pub fn sparse(m: SparseMatrix) -> Block {
        let nnz = m.nnz();
        Block { repr: BlockRepr::Sparse(m), nnz }
    }

    pub fn repr(&self) -> &BlockRepr {
        &self.repr
    }

    pub fn as_dense(&self) -> Option<&DenseMatrix> {
        match &self.repr {
            BlockRepr::Dense(m) => Some(m),
            BlockRepr::Sparse(_) => None,
        }
    }

    pub fn as_sparse(&self) -> Option<&SparseMatrix> {
        match &self.repr {
            BlockRepr::Sparse(m) => Some(m),
            BlockRepr::Dense(_) => None,
        }
    }

    pub fn rows(&self) -> usize {
        match &self.repr {
            BlockRepr::Dense(m) => m.rows,
            BlockRepr::Sparse(m) => m.rows,
        }
    }

    pub fn cols(&self) -> usize {
        match &self.repr {
            BlockRepr::Dense(m) => m.cols,
            BlockRepr::Sparse(m) => m.cols,
        }
    }

    /// Stored non-zeros — cached at construction, O(1).
    pub fn nnz(&self) -> usize {
        self.nnz
    }

    /// out = X w
    pub fn margins_into(&self, w: &[f32], out: &mut [f32]) {
        self.margins_into_with(crate::linalg::kernels(), w, out)
    }

    /// [`Self::margins_into`] through an explicit dispatch table (the
    /// handle `GridOp::exec_task` plumbs down from its `OpScratch`).
    pub fn margins_into_with(
        &self,
        kd: &crate::linalg::KernelDispatch,
        w: &[f32],
        out: &mut [f32],
    ) {
        match &self.repr {
            BlockRepr::Dense(m) => m.gemv_into_with(kd, w, out),
            BlockRepr::Sparse(m) => m.gemv_into(w, out),
        }
    }

    /// out = X^T v (sparse blocks stream the CSC mirror when it is built
    /// — the partitioner builds it for every per-partition block; without
    /// it the CSR scatter kernel runs).
    pub fn atx_into(&self, v: &[f32], out: &mut [f32]) {
        self.atx_into_with(crate::linalg::kernels(), v, out)
    }

    /// [`Self::atx_into`] through an explicit dispatch table.
    pub fn atx_into_with(&self, kd: &crate::linalg::KernelDispatch, v: &[f32], out: &mut [f32]) {
        match &self.repr {
            BlockRepr::Dense(m) => m.gemv_t_into_with(kd, v, out),
            BlockRepr::Sparse(m) => m.gemv_t_into_with(kd, v, out),
        }
    }

    /// x_i · w for a single row.
    pub fn row_dot(&self, i: usize, w: &[f32]) -> f32 {
        match &self.repr {
            BlockRepr::Dense(m) => crate::linalg::dot(m.row(i), w),
            BlockRepr::Sparse(m) => m.row_dot(i, w),
        }
    }

    /// x_i · w restricted to a masked coordinate window [lo, hi).
    pub fn row_dot_window(&self, i: usize, w: &[f32], lo: usize, hi: usize) -> f32 {
        match &self.repr {
            BlockRepr::Dense(m) => crate::linalg::dot(&m.row(i)[lo..hi], &w[lo..hi]),
            BlockRepr::Sparse(m) => m.row_dot_window(i, w, lo, hi),
        }
    }

    /// ||x_i||^2
    pub fn row_norm_sq(&self, i: usize) -> f32 {
        match &self.repr {
            BlockRepr::Dense(m) => crate::linalg::nrm2_sq(m.row(i)),
            BlockRepr::Sparse(m) => m.row_norm_sq(i),
        }
    }

    /// w += a * x_i
    pub fn row_axpy(&self, i: usize, a: f32, w: &mut [f32]) {
        match &self.repr {
            BlockRepr::Dense(m) => crate::linalg::axpy(a, m.row(i), w),
            BlockRepr::Sparse(m) => m.row_axpy(i, a, w),
        }
    }

    /// w[lo..hi] += a * x_i[lo..hi]
    pub fn row_axpy_window(&self, i: usize, a: f32, w: &mut [f32], lo: usize, hi: usize) {
        match &self.repr {
            BlockRepr::Dense(m) => crate::linalg::axpy(a, &m.row(i)[lo..hi], &mut w[lo..hi]),
            BlockRepr::Sparse(m) => m.row_axpy_window(i, a, w, lo, hi),
        }
    }

    /// out[k - lo] += a * x_i[k] for k in [lo, hi) — window op with a
    /// re-based output, the allocation-free primitive the SVRG hot loop
    /// uses (out has length hi - lo).
    pub fn row_axpy_window_offset(&self, i: usize, a: f32, out: &mut [f32], lo: usize, hi: usize) {
        debug_assert_eq!(out.len(), hi - lo);
        match &self.repr {
            BlockRepr::Dense(m) => crate::linalg::axpy(a, &m.row(i)[lo..hi], out),
            BlockRepr::Sparse(m) => {
                for (j, v) in m.row_iter(i) {
                    if j >= lo && j < hi {
                        out[j - lo] += a * v;
                    }
                }
            }
        }
    }

    /// x_i[lo..hi] · d where d is re-based to the window (length hi - lo).
    pub fn row_dot_window_offset(&self, i: usize, d: &[f32], lo: usize, hi: usize) -> f32 {
        debug_assert_eq!(d.len(), hi - lo);
        match &self.repr {
            BlockRepr::Dense(m) => crate::linalg::dot(&m.row(i)[lo..hi], d),
            BlockRepr::Sparse(m) => {
                let mut acc = 0.0f32;
                for (j, v) in m.row_iter(i) {
                    if j >= lo && j < hi {
                        acc += v * d[j - lo];
                    }
                }
                acc
            }
        }
    }

    /// Materialize as a dense row-major buffer padded to `(n_cap, m_cap)` —
    /// the XLA backend's bucket protocol (real data top-left, zeros
    /// elsewhere).
    pub fn to_padded_dense(&self, n_cap: usize, m_cap: usize) -> Vec<f32> {
        assert!(self.rows() <= n_cap && self.cols() <= m_cap,
                "block {}x{} exceeds bucket {}x{}",
                self.rows(), self.cols(), n_cap, m_cap);
        let mut out = vec![0.0f32; n_cap * m_cap];
        match &self.repr {
            BlockRepr::Dense(m) => {
                for i in 0..m.rows {
                    out[i * m_cap..i * m_cap + m.cols].copy_from_slice(m.row(i));
                }
            }
            BlockRepr::Sparse(m) => {
                for i in 0..m.rows {
                    for (j, v) in m.row_iter(i) {
                        out[i * m_cap + j] = v;
                    }
                }
            }
        }
        out
    }
}

/// A whole labelled training set (before partitioning).
#[derive(Clone, Debug)]
pub struct Dataset {
    pub name: String,
    pub x: Block,
    pub y: Vec<f32>,
}

impl Dataset {
    pub fn n(&self) -> usize {
        self.x.rows()
    }

    pub fn m(&self) -> usize {
        self.x.cols()
    }

    pub fn sparsity(&self) -> f64 {
        self.x.nnz() as f64 / (self.n() * self.m()) as f64
    }

    /// Content fingerprint (FNV-1a over labels and a sample of the matrix)
    /// — distinguishes same-shape datasets from different seeds, e.g. for
    /// the f* cache key.
    pub fn fingerprint(&self) -> u64 {
        let mut h = 0xcbf29ce484222325u64;
        let mut mix = |v: u32| {
            for b in v.to_le_bytes() {
                h ^= b as u64;
                h = h.wrapping_mul(0x100000001b3);
            }
        };
        mix(self.n() as u32);
        mix(self.m() as u32);
        for &y in self.y.iter().take(256) {
            mix(y.to_bits());
        }
        let sample = |i: usize| -> f32 {
            match self.x.repr() {
                BlockRepr::Dense(d) => d.data[i % d.data.len()],
                BlockRepr::Sparse(s) => {
                    if s.values.is_empty() {
                        0.0
                    } else {
                        s.values[i % s.values.len()]
                    }
                }
            }
        };
        for k in 0..256 {
            mix(sample(k * 97 + 13).to_bits());
        }
        h
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Xoshiro;

    fn random_dense(n: usize, m: usize, seed: u64) -> DenseMatrix {
        let mut r = Xoshiro::new(seed);
        DenseMatrix::from_fn(n, m, |_, _| r.range_f32(-1.0, 1.0))
    }

    #[test]
    fn dense_and_sparse_blocks_agree() {
        let d = random_dense(13, 9, 1);
        let s = SparseMatrix::from_dense(&d);
        let bd = Block::dense(d);
        let bs = Block::sparse(s);
        let mut r = Xoshiro::new(2);
        let w: Vec<f32> = (0..9).map(|_| r.range_f32(-1.0, 1.0)).collect();
        let v: Vec<f32> = (0..13).map(|_| r.range_f32(-1.0, 1.0)).collect();
        let (mut md, mut ms) = (vec![0.0; 13], vec![0.0; 13]);
        bd.margins_into(&w, &mut md);
        bs.margins_into(&w, &mut ms);
        for i in 0..13 {
            assert!((md[i] - ms[i]).abs() < 1e-5);
            assert!((bd.row_dot(i, &w) - bs.row_dot(i, &w)).abs() < 1e-5);
            assert!((bd.row_norm_sq(i) - bs.row_norm_sq(i)).abs() < 1e-4);
        }
        let (mut ad, mut as_) = (vec![0.0; 9], vec![0.0; 9]);
        bd.atx_into(&v, &mut ad);
        bs.atx_into(&v, &mut as_);
        for j in 0..9 {
            assert!((ad[j] - as_[j]).abs() < 1e-4);
        }
    }

    #[test]
    fn nnz_cached_at_construction() {
        let mut d = DenseMatrix::zeros(3, 3);
        d.set(0, 0, 1.0);
        d.set(2, 1, -2.0);
        let b = Block::dense(d);
        assert_eq!(b.nnz(), 2);
        let s = SparseMatrix::from_triplets(2, 2, vec![(0, 1, 1.0), (1, 0, 3.0)]);
        assert_eq!(Block::sparse(s).nnz(), 2);
    }

    #[test]
    fn padded_dense_protocol() {
        let d = random_dense(3, 2, 3);
        let b = Block::dense(d.clone());
        let pad = b.to_padded_dense(4, 5);
        assert_eq!(pad.len(), 20);
        for i in 0..3 {
            for j in 0..2 {
                assert_eq!(pad[i * 5 + j], d.get(i, j));
            }
        }
        assert_eq!(pad[0 * 5 + 4], 0.0);
        assert_eq!(pad[3 * 5..].iter().map(|v| v.abs()).sum::<f32>(), 0.0);
    }

    #[test]
    #[should_panic]
    fn padded_dense_rejects_oversize() {
        let b = Block::dense(random_dense(5, 5, 4));
        let _ = b.to_padded_dense(4, 8);
    }

    #[test]
    fn window_ops_match_full_on_slice() {
        let d = random_dense(6, 10, 5);
        let s = Block::sparse(SparseMatrix::from_dense(&d));
        let b = Block::dense(d);
        let mut r = Xoshiro::new(6);
        let w: Vec<f32> = (0..10).map(|_| r.range_f32(-1.0, 1.0)).collect();
        for i in 0..6 {
            let full: f32 = b.row_dot_window(i, &w, 2, 7);
            let sp: f32 = s.row_dot_window(i, &w, 2, 7);
            assert!((full - sp).abs() < 1e-5, "row {i}: {full} vs {sp}");
        }
        let mut wd = w.clone();
        let mut ws = w.clone();
        b.row_axpy_window(2, 0.5, &mut wd, 3, 8);
        s.row_axpy_window(2, 0.5, &mut ws, 3, 8);
        for j in 0..10 {
            assert!((wd[j] - ws[j]).abs() < 1e-5);
        }
        // outside the window unchanged
        assert_eq!(wd[0], w[0]);
        assert_eq!(wd[9], w[9]);
    }
}
