//! The P×Q doubly-distributed grid partitioner (paper Fig. 1).
//!
//! Observations split into P row ranges, features into Q column ranges;
//! partition [p,q] holds `x[p-rows, q-cols]` plus the labels `y[p]` of its
//! row range.  Partitions sharing a row range share the dual variables
//! alpha[p, .]; partitions sharing a column range share the primal block
//! w[., q] — the aggregation structure D3CA/RADiSA coordinate over.

use super::{Block, BlockRepr, Dataset};

/// The partition grid dimensions.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Grid {
    pub p: usize,
    pub q: usize,
}

impl Grid {
    pub fn new(p: usize, q: usize) -> Grid {
        assert!(p > 0 && q > 0, "grid must be positive");
        Grid { p, q }
    }

    /// Total partitions K = P·Q.
    pub fn k(&self) -> usize {
        self.p * self.q
    }

    /// Flat index of partition [p,q].
    #[inline]
    pub fn idx(&self, p: usize, q: usize) -> usize {
        debug_assert!(p < self.p && q < self.q);
        p * self.q + q
    }
}

/// Split `0..n` into `parts` contiguous near-equal ranges (remainder spread
/// over the leading ranges, matching Spark's partitioning).
pub fn balanced_ranges(n: usize, parts: usize) -> Vec<(usize, usize)> {
    assert!(parts > 0);
    let base = n / parts;
    let extra = n % parts;
    let mut out = Vec::with_capacity(parts);
    let mut start = 0;
    for i in 0..parts {
        let len = base + usize::from(i < extra);
        out.push((start, start + len));
        start += len;
    }
    out
}

/// A dataset split across the P×Q grid.
#[derive(Clone, Debug)]
pub struct Partitioned {
    pub grid: Grid,
    pub n: usize,
    pub m: usize,
    pub row_ranges: Vec<(usize, usize)>,
    pub col_ranges: Vec<(usize, usize)>,
    /// Blocks in row-major grid order: `blocks[grid.idx(p, q)]`.
    pub blocks: Vec<Block>,
    /// Full label vector; partition p sees `y[row_ranges[p]]`.
    pub y: Vec<f32>,
    pub name: String,
}

impl Partitioned {
    pub fn split(ds: &Dataset, grid: Grid) -> Partitioned {
        let (n, m) = (ds.n(), ds.m());
        assert!(grid.p <= n, "more row partitions than rows");
        assert!(grid.q <= m, "more col partitions than cols");
        let row_ranges = balanced_ranges(n, grid.p);
        let col_ranges = balanced_ranges(m, grid.q);
        let mut blocks = Vec::with_capacity(grid.k());
        for &(r0, r1) in &row_ranges {
            for &(c0, c1) in &col_ranges {
                let b = match ds.x.repr() {
                    BlockRepr::Dense(d) => Block::dense(d.slice(r0, r1, c0, c1)),
                    BlockRepr::Sparse(s) => {
                        let mut sliced = s.slice(r0, r1, c0, c1);
                        // partition blocks are the compute hot path: give
                        // them the CSC mirror so transpose products
                        // stream columns (the parent matrix skips it)
                        sliced.build_csc();
                        Block::sparse(sliced)
                    }
                };
                blocks.push(b);
            }
        }
        Partitioned {
            grid,
            n,
            m,
            row_ranges,
            col_ranges,
            blocks,
            y: ds.y.clone(),
            name: ds.name.clone(),
        }
    }

    pub fn block(&self, p: usize, q: usize) -> &Block {
        &self.blocks[self.grid.idx(p, q)]
    }

    /// Rows in observation partition p.
    pub fn n_p(&self, p: usize) -> usize {
        let (a, b) = self.row_ranges[p];
        b - a
    }

    /// Columns in feature partition q.
    pub fn m_q(&self, q: usize) -> usize {
        let (a, b) = self.col_ranges[q];
        b - a
    }

    /// Labels of observation partition p.
    pub fn labels(&self, p: usize) -> &[f32] {
        let (a, b) = self.row_ranges[p];
        &self.y[a..b]
    }

    /// Largest (n_p, m_q) over the grid — what the XLA bucket must fit.
    pub fn max_block_dims(&self) -> (usize, usize) {
        let np = (0..self.grid.p).map(|p| self.n_p(p)).max().unwrap();
        let mq = (0..self.grid.q).map(|q| self.m_q(q)).max().unwrap();
        (np, mq)
    }
}

/// RADiSA's static sub-block structure: each feature partition's m_q local
/// columns are split into P contiguous sub-blocks; the random
/// *non-overlapping exchange* of sub-blocks between iterations is handled
/// by `coordinator::schedule` on top of these fixed ranges (Algorithm 3's
/// "partition each [.,q] into P blocks").
#[derive(Clone, Debug)]
pub struct SubBlocks {
    /// `ranges[q][s]` = local (lo, hi) column window of sub-block s in
    /// feature partition q.
    pub ranges: Vec<Vec<(usize, usize)>>,
}

impl SubBlocks {
    pub fn split(part: &Partitioned) -> SubBlocks {
        let p = part.grid.p;
        let ranges = (0..part.grid.q)
            .map(|q| balanced_ranges(part.m_q(q), p))
            .collect();
        SubBlocks { ranges }
    }

    pub fn range(&self, q: usize, s: usize) -> (usize, usize) {
        self.ranges[q][s]
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{SyntheticDense, SyntheticSparse};

    #[test]
    fn balanced_ranges_cover_and_balance() {
        for (n, parts) in [(10, 3), (7, 7), (100, 1), (5, 2)] {
            let rs = balanced_ranges(n, parts);
            assert_eq!(rs.len(), parts);
            assert_eq!(rs[0].0, 0);
            assert_eq!(rs[parts - 1].1, n);
            for w in rs.windows(2) {
                assert_eq!(w[0].1, w[1].0, "contiguous");
            }
            let sizes: Vec<usize> = rs.iter().map(|(a, b)| b - a).collect();
            let (mn, mx) = (sizes.iter().min().unwrap(), sizes.iter().max().unwrap());
            assert!(mx - mn <= 1, "balanced: {sizes:?}");
        }
    }

    #[test]
    fn split_reassembles_margins() {
        // X w computed per block and summed over q must equal the full X w.
        let ds = SyntheticDense::paper_part1(3, 2, 20, 15, 0.1, 9).build();
        let grid = Grid::new(3, 2);
        let part = Partitioned::split(&ds, grid);
        let mut rng = crate::util::rng::Xoshiro::new(1);
        let w: Vec<f32> = (0..ds.m()).map(|_| rng.range_f32(-1.0, 1.0)).collect();
        let mut full = vec![0.0; ds.n()];
        ds.x.margins_into(&w, &mut full);
        for p in 0..3 {
            let (r0, r1) = part.row_ranges[p];
            let mut acc = vec![0.0f32; r1 - r0];
            for q in 0..2 {
                let (c0, c1) = part.col_ranges[q];
                let mut local = vec![0.0f32; r1 - r0];
                part.block(p, q).margins_into(&w[c0..c1], &mut local);
                for (a, l) in acc.iter_mut().zip(&local) {
                    *a += l;
                }
            }
            for (i, a) in acc.iter().enumerate() {
                assert!((a - full[r0 + i]).abs() < 1e-4);
            }
        }
    }

    #[test]
    fn sparse_split_preserves_nnz() {
        let ds = SyntheticSparse::new("t", 60, 50, 0.1, 21).build();
        let part = Partitioned::split(&ds, Grid::new(4, 3));
        let total: usize = part.blocks.iter().map(|b| b.nnz()).sum();
        assert_eq!(total, ds.x.nnz());
    }

    #[test]
    fn labels_align_with_row_ranges() {
        let ds = SyntheticDense::paper_part1(4, 1, 10, 5, 0.1, 2).build();
        let part = Partitioned::split(&ds, Grid::new(4, 1));
        let mut collected = Vec::new();
        for p in 0..4 {
            collected.extend_from_slice(part.labels(p));
        }
        assert_eq!(collected, ds.y);
    }

    #[test]
    fn subblocks_tile_each_feature_partition() {
        let ds = SyntheticDense::paper_part1(3, 2, 8, 11, 0.1, 4).build();
        let part = Partitioned::split(&ds, Grid::new(3, 2));
        let sb = SubBlocks::split(&part);
        for q in 0..2 {
            let rs = &sb.ranges[q];
            assert_eq!(rs.len(), 3);
            assert_eq!(rs[0].0, 0);
            assert_eq!(rs[2].1, part.m_q(q));
            for w in rs.windows(2) {
                assert_eq!(w[0].1, w[1].0);
            }
        }
    }

    #[test]
    fn grid_flat_index() {
        let g = Grid::new(3, 4);
        assert_eq!(g.k(), 12);
        assert_eq!(g.idx(0, 0), 0);
        assert_eq!(g.idx(2, 3), 11);
        assert_eq!(g.idx(1, 2), 6);
    }
}
