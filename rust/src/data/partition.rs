//! The P×Q doubly-distributed grid partitioner (paper Fig. 1).
//!
//! Observations split into P row ranges, features into Q column ranges;
//! partition [p,q] holds `x[p-rows, q-cols]` plus the labels `y[p]` of its
//! row range.  Partitions sharing a row range share the dual variables
//! alpha[p, .]; partitions sharing a column range share the primal block
//! w[., q] — the aggregation structure D3CA/RADiSA coordinate over.

use super::{Block, BlockRepr, Dataset, DenseMatrix, SparseMatrix};
use crate::util::bytes::{self, ByteReader};
use anyhow::{bail, Result};

/// The partition grid dimensions.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Grid {
    pub p: usize,
    pub q: usize,
}

impl Grid {
    pub fn new(p: usize, q: usize) -> Grid {
        assert!(p > 0 && q > 0, "grid must be positive");
        Grid { p, q }
    }

    /// Total partitions K = P·Q.
    pub fn k(&self) -> usize {
        self.p * self.q
    }

    /// Flat index of partition [p,q].
    #[inline]
    pub fn idx(&self, p: usize, q: usize) -> usize {
        debug_assert!(p < self.p && q < self.q);
        p * self.q + q
    }
}

/// Split `0..n` into `parts` contiguous near-equal ranges (remainder spread
/// over the leading ranges, matching Spark's partitioning).
pub fn balanced_ranges(n: usize, parts: usize) -> Vec<(usize, usize)> {
    assert!(parts > 0);
    let base = n / parts;
    let extra = n % parts;
    let mut out = Vec::with_capacity(parts);
    let mut start = 0;
    for i in 0..parts {
        let len = base + usize::from(i < extra);
        out.push((start, start + len));
        start += len;
    }
    out
}

/// A dataset split across the P×Q grid.
#[derive(Clone, Debug)]
pub struct Partitioned {
    pub grid: Grid,
    pub n: usize,
    pub m: usize,
    pub row_ranges: Vec<(usize, usize)>,
    pub col_ranges: Vec<(usize, usize)>,
    /// Blocks in row-major grid order: `blocks[grid.idx(p, q)]`.
    pub blocks: Vec<Block>,
    /// Full label vector; partition p sees `y[row_ranges[p]]`.
    pub y: Vec<f32>,
    pub name: String,
}

impl Partitioned {
    pub fn split(ds: &Dataset, grid: Grid) -> Partitioned {
        let (n, m) = (ds.n(), ds.m());
        assert!(grid.p <= n, "more row partitions than rows");
        assert!(grid.q <= m, "more col partitions than cols");
        let row_ranges = balanced_ranges(n, grid.p);
        let col_ranges = balanced_ranges(m, grid.q);
        let mut blocks = Vec::with_capacity(grid.k());
        for &(r0, r1) in &row_ranges {
            for &(c0, c1) in &col_ranges {
                let b = match ds.x.repr() {
                    BlockRepr::Dense(d) => Block::dense(d.slice(r0, r1, c0, c1)),
                    BlockRepr::Sparse(s) => {
                        let mut sliced = s.slice(r0, r1, c0, c1);
                        // partition blocks are the compute hot path: give
                        // them the CSC mirror so transpose products
                        // stream columns (the parent matrix skips it)
                        sliced.build_csc();
                        Block::sparse(sliced)
                    }
                };
                blocks.push(b);
            }
        }
        Partitioned {
            grid,
            n,
            m,
            row_ranges,
            col_ranges,
            blocks,
            y: ds.y.clone(),
            name: ds.name.clone(),
        }
    }

    pub fn block(&self, p: usize, q: usize) -> &Block {
        &self.blocks[self.grid.idx(p, q)]
    }

    /// Rows in observation partition p.
    pub fn n_p(&self, p: usize) -> usize {
        let (a, b) = self.row_ranges[p];
        b - a
    }

    /// Columns in feature partition q.
    pub fn m_q(&self, q: usize) -> usize {
        let (a, b) = self.col_ranges[q];
        b - a
    }

    /// Labels of observation partition p.
    pub fn labels(&self, p: usize) -> &[f32] {
        let (a, b) = self.row_ranges[p];
        &self.y[a..b]
    }

    /// Largest (n_p, m_q) over the grid — what the XLA bucket must fit.
    pub fn max_block_dims(&self) -> (usize, usize) {
        let np = (0..self.grid.p).map(|p| self.n_p(p)).max().unwrap();
        let mq = (0..self.grid.q).map(|q| self.m_q(q)).max().unwrap();
        (np, mq)
    }

    // ------------------------------------------------------------ ser/de
    //
    // Binary framing for the distributed runtime (same little-endian
    // [`crate::util::bytes`] vocabulary as the wire protocol): the driver
    // ships every executor the *metadata* — grid shape, ranges, labels —
    // plus only the [`encode_block`] payloads of the cells that executor
    // owns; [`Partitioned::decode_meta`] reconstructs the grid with
    // dimension-correct empty placeholders for the cells it never sees.

    /// Serialize everything except the blocks.
    pub fn encode_meta(&self, buf: &mut Vec<u8>) {
        bytes::put_usize(buf, self.grid.p);
        bytes::put_usize(buf, self.grid.q);
        bytes::put_usize(buf, self.n);
        bytes::put_usize(buf, self.m);
        bytes::put_pairs(buf, &self.row_ranges);
        bytes::put_pairs(buf, &self.col_ranges);
        bytes::put_f32s(buf, &self.y);
        bytes::put_str(buf, &self.name);
    }

    /// Rebuild a grid from [`Partitioned::encode_meta`] output, with
    /// zero-nnz (but dimension-correct) placeholder blocks everywhere;
    /// the caller installs the shipped blocks with
    /// [`Partitioned::set_block`].
    pub fn decode_meta(r: &mut ByteReader<'_>) -> Result<Partitioned> {
        let p = r.usize()?;
        let q = r.usize()?;
        let n = r.usize()?;
        let m = r.usize()?;
        if p == 0 || q == 0 {
            bail!("partition meta has an empty grid ({p}x{q})");
        }
        let row_ranges = r.pairs()?;
        let col_ranges = r.pairs()?;
        let y = r.f32s()?;
        let name = r.str()?;
        if row_ranges.len() != p || col_ranges.len() != q {
            bail!(
                "partition meta ranges ({}, {}) do not match the {p}x{q} grid",
                row_ranges.len(),
                col_ranges.len()
            );
        }
        check_ranges(&row_ranges, n, "row")?;
        check_ranges(&col_ranges, m, "col")?;
        if y.len() != n {
            bail!("partition meta labels length {} != n = {n}", y.len());
        }
        let grid = Grid::new(p, q);
        let mut blocks = Vec::with_capacity(grid.k());
        for &(r0, r1) in &row_ranges {
            for &(c0, c1) in &col_ranges {
                // an empty CSR block keeps the (n_p, m_q) dims without
                // allocating n_p·m_q zeros
                let placeholder = SparseMatrix::from_csr(
                    r1 - r0,
                    c1 - c0,
                    vec![0; r1 - r0 + 1],
                    Vec::new(),
                    Vec::new(),
                )
                .expect("empty CSR is always valid");
                blocks.push(Block::sparse(placeholder));
            }
        }
        Ok(Partitioned { grid, n, m, row_ranges, col_ranges, blocks, y, name })
    }

    /// Install a shipped block at flat grid cell `cell`, verifying its
    /// dimensions against the grid ranges.
    pub fn set_block(&mut self, cell: usize, b: Block) -> Result<()> {
        if cell >= self.grid.k() {
            bail!("block cell {cell} out of range (grid has {} cells)", self.grid.k());
        }
        let (p, q) = (cell / self.grid.q, cell % self.grid.q);
        let (n_p, m_q) = (self.n_p(p), self.m_q(q));
        if b.rows() != n_p || b.cols() != m_q {
            bail!(
                "block for cell ({p},{q}) is {}x{}, grid wants {n_p}x{m_q}",
                b.rows(),
                b.cols()
            );
        }
        self.blocks[cell] = b;
        Ok(())
    }
}

fn check_ranges(ranges: &[(usize, usize)], total: usize, what: &str) -> Result<()> {
    let mut cursor = 0usize;
    for &(a, b) in ranges {
        if a != cursor || b < a {
            bail!("partition meta {what} ranges are not contiguous from 0");
        }
        cursor = b;
    }
    if cursor != total {
        bail!("partition meta {what} ranges cover {cursor}, want {total}");
    }
    Ok(())
}

/// Block payload tags.
const BLOCK_DENSE: u8 = 0;
const BLOCK_SPARSE: u8 = 1;

/// Serialize one grid block (dense or CSR, flagged with whether the
/// source carried a CSC mirror so the receiver rebuilds it and transpose
/// products stay on the streaming kernel).
pub fn encode_block(b: &Block, buf: &mut Vec<u8>) {
    match b.repr() {
        BlockRepr::Dense(d) => {
            bytes::put_u8(buf, BLOCK_DENSE);
            bytes::put_usize(buf, d.rows);
            bytes::put_usize(buf, d.cols);
            bytes::put_f32s(buf, &d.data);
        }
        BlockRepr::Sparse(s) => {
            bytes::put_u8(buf, BLOCK_SPARSE);
            bytes::put_usize(buf, s.rows);
            bytes::put_usize(buf, s.cols);
            bytes::put_usizes(buf, &s.indptr);
            bytes::put_u32s(buf, &s.indices);
            bytes::put_f32s(buf, &s.values);
            bytes::put_u8(buf, u8::from(s.has_csc()));
        }
    }
}

/// Deserialize one grid block ([`encode_block`]'s inverse — value bits,
/// nnz, and CSC presence all round-trip exactly).
pub fn decode_block(r: &mut ByteReader<'_>) -> Result<Block> {
    match r.u8()? {
        BLOCK_DENSE => {
            let rows = r.usize()?;
            let cols = r.usize()?;
            let data = r.f32s()?;
            if data.len() != rows * cols {
                bail!("dense block payload {} != {rows}x{cols}", data.len());
            }
            Ok(Block::dense(DenseMatrix::from_vec(rows, cols, data)))
        }
        BLOCK_SPARSE => {
            let rows = r.usize()?;
            let cols = r.usize()?;
            let indptr = r.usizes()?;
            let indices = r.u32s()?;
            let values = r.f32s()?;
            let has_csc = r.u8()? != 0;
            let mut m = SparseMatrix::from_csr(rows, cols, indptr, indices, values)?;
            if has_csc {
                m.build_csc();
            }
            Ok(Block::sparse(m))
        }
        other => bail!("unknown block tag {other}"),
    }
}

/// RADiSA's static sub-block structure: each feature partition's m_q local
/// columns are split into P contiguous sub-blocks; the random
/// *non-overlapping exchange* of sub-blocks between iterations is handled
/// by `coordinator::schedule` on top of these fixed ranges (Algorithm 3's
/// "partition each [.,q] into P blocks").
#[derive(Clone, Debug)]
pub struct SubBlocks {
    /// `ranges[q][s]` = local (lo, hi) column window of sub-block s in
    /// feature partition q.
    pub ranges: Vec<Vec<(usize, usize)>>,
}

impl SubBlocks {
    pub fn split(part: &Partitioned) -> SubBlocks {
        let p = part.grid.p;
        let ranges = (0..part.grid.q)
            .map(|q| balanced_ranges(part.m_q(q), p))
            .collect();
        SubBlocks { ranges }
    }

    pub fn range(&self, q: usize, s: usize) -> (usize, usize) {
        self.ranges[q][s]
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{SyntheticDense, SyntheticSparse};

    #[test]
    fn balanced_ranges_cover_and_balance() {
        for (n, parts) in [(10, 3), (7, 7), (100, 1), (5, 2)] {
            let rs = balanced_ranges(n, parts);
            assert_eq!(rs.len(), parts);
            assert_eq!(rs[0].0, 0);
            assert_eq!(rs[parts - 1].1, n);
            for w in rs.windows(2) {
                assert_eq!(w[0].1, w[1].0, "contiguous");
            }
            let sizes: Vec<usize> = rs.iter().map(|(a, b)| b - a).collect();
            let (mn, mx) = (sizes.iter().min().unwrap(), sizes.iter().max().unwrap());
            assert!(mx - mn <= 1, "balanced: {sizes:?}");
        }
    }

    #[test]
    fn split_reassembles_margins() {
        // X w computed per block and summed over q must equal the full X w.
        let ds = SyntheticDense::paper_part1(3, 2, 20, 15, 0.1, 9).build();
        let grid = Grid::new(3, 2);
        let part = Partitioned::split(&ds, grid);
        let mut rng = crate::util::rng::Xoshiro::new(1);
        let w: Vec<f32> = (0..ds.m()).map(|_| rng.range_f32(-1.0, 1.0)).collect();
        let mut full = vec![0.0; ds.n()];
        ds.x.margins_into(&w, &mut full);
        for p in 0..3 {
            let (r0, r1) = part.row_ranges[p];
            let mut acc = vec![0.0f32; r1 - r0];
            for q in 0..2 {
                let (c0, c1) = part.col_ranges[q];
                let mut local = vec![0.0f32; r1 - r0];
                part.block(p, q).margins_into(&w[c0..c1], &mut local);
                for (a, l) in acc.iter_mut().zip(&local) {
                    *a += l;
                }
            }
            for (i, a) in acc.iter().enumerate() {
                assert!((a - full[r0 + i]).abs() < 1e-4);
            }
        }
    }

    #[test]
    fn sparse_split_preserves_nnz() {
        let ds = SyntheticSparse::new("t", 60, 50, 0.1, 21).build();
        let part = Partitioned::split(&ds, Grid::new(4, 3));
        let total: usize = part.blocks.iter().map(|b| b.nnz()).sum();
        assert_eq!(total, ds.x.nnz());
    }

    #[test]
    fn labels_align_with_row_ranges() {
        let ds = SyntheticDense::paper_part1(4, 1, 10, 5, 0.1, 2).build();
        let part = Partitioned::split(&ds, Grid::new(4, 1));
        let mut collected = Vec::new();
        for p in 0..4 {
            collected.extend_from_slice(part.labels(p));
        }
        assert_eq!(collected, ds.y);
    }

    #[test]
    fn subblocks_tile_each_feature_partition() {
        let ds = SyntheticDense::paper_part1(3, 2, 8, 11, 0.1, 4).build();
        let part = Partitioned::split(&ds, Grid::new(3, 2));
        let sb = SubBlocks::split(&part);
        for q in 0..2 {
            let rs = &sb.ranges[q];
            assert_eq!(rs.len(), 3);
            assert_eq!(rs[0].0, 0);
            assert_eq!(rs[2].1, part.m_q(q));
            for w in rs.windows(2) {
                assert_eq!(w[0].1, w[1].0);
            }
        }
    }

    #[test]
    fn block_ser_de_round_trips_bitwise() {
        // one dense and one sparse dataset, partitioned, every block
        // encoded and decoded; margins products must match bit for bit
        for sparse in [false, true] {
            let ds = if sparse {
                SyntheticSparse::new("t", 40, 30, 0.15, 11).build()
            } else {
                SyntheticDense::paper_part1(2, 2, 12, 9, 0.1, 11).build()
            };
            let part = Partitioned::split(&ds, Grid::new(2, 2));
            for (cell, b) in part.blocks.iter().enumerate() {
                let mut buf = Vec::new();
                encode_block(b, &mut buf);
                let mut r = ByteReader::new(&buf);
                let back = decode_block(&mut r).unwrap();
                assert!(r.is_empty(), "cell {cell}: trailing bytes");
                assert_eq!(b.rows(), back.rows());
                assert_eq!(b.cols(), back.cols());
                assert_eq!(b.nnz(), back.nnz());
                if let (Some(s0), Some(s1)) = (b.as_sparse(), back.as_sparse()) {
                    assert_eq!(s0, s1, "cell {cell}: CSR content");
                    assert_eq!(s0.has_csc(), s1.has_csc(), "cell {cell}: CSC mirror");
                }
                let w: Vec<f32> = (0..b.cols()).map(|j| (j as f32).sin()).collect();
                let mut m0 = vec![0.0f32; b.rows()];
                let mut m1 = vec![0.0f32; b.rows()];
                b.margins_into(&w, &mut m0);
                back.margins_into(&w, &mut m1);
                for (a, z) in m0.iter().zip(&m1) {
                    assert_eq!(a.to_bits(), z.to_bits(), "cell {cell}");
                }
            }
        }
    }

    #[test]
    fn meta_ser_de_round_trips_with_placeholders() {
        let ds = SyntheticSparse::new("meta", 33, 21, 0.2, 3).build();
        let part = Partitioned::split(&ds, Grid::new(3, 2));
        let mut buf = Vec::new();
        part.encode_meta(&mut buf);
        let mut r = ByteReader::new(&buf);
        let mut back = Partitioned::decode_meta(&mut r).unwrap();
        assert!(r.is_empty());
        assert_eq!(back.grid, part.grid);
        assert_eq!(back.row_ranges, part.row_ranges);
        assert_eq!(back.col_ranges, part.col_ranges);
        assert_eq!(back.y, part.y);
        assert_eq!(back.name, part.name);
        // placeholders are dimension-correct and empty
        for p in 0..3 {
            for q in 0..2 {
                let b = back.block(p, q);
                assert_eq!(b.rows(), part.n_p(p));
                assert_eq!(b.cols(), part.m_q(q));
                assert_eq!(b.nnz(), 0);
            }
        }
        // installing a shipped block replaces the placeholder
        let mut bbuf = Vec::new();
        encode_block(part.block(1, 1), &mut bbuf);
        let blk = decode_block(&mut ByteReader::new(&bbuf)).unwrap();
        back.set_block(back.grid.idx(1, 1), blk).unwrap();
        assert_eq!(back.block(1, 1).nnz(), part.block(1, 1).nnz());
        // dimension mismatch is rejected: cell (0,0) is 11x11 while the
        // shipped block (1,1) is 11x10
        let bad = decode_block(&mut ByteReader::new(&bbuf)).unwrap();
        assert!(back.set_block(0, bad).is_err());
    }

    #[test]
    fn grid_flat_index() {
        let g = Grid::new(3, 4);
        assert_eq!(g.k(), 12);
        assert_eq!(g.idx(0, 0), 0);
        assert_eq!(g.idx(2, 3), 11);
        assert_eq!(g.idx(1, 2), 6);
    }
}
