//! LIBSVM-format reader/writer.
//!
//! Format: one observation per line, `label idx:val idx:val ...` with
//! 1-based, strictly increasing indices.  The paper's Part-2 data sets
//! (real-sim, news20) ship in this format; the offline environment
//! substitutes [`super::SyntheticSparse`] instances written through
//! [`write_libsvm`] and re-read here, so the parser path is exercised
//! end-to-end and real files drop in unchanged.

use super::sparse::SparseMatrix;
use super::{Block, Dataset};
use anyhow::{bail, Context, Result};
use std::io::{BufRead, BufReader, BufWriter, Write};
use std::path::Path;

/// Read a LIBSVM file.  `m_hint` (if nonzero) fixes the feature count;
/// otherwise it is inferred from the maximum index seen.
pub fn read_libsvm(path: &Path, m_hint: usize) -> Result<Dataset> {
    let f = std::fs::File::open(path)
        .with_context(|| format!("open {}", path.display()))?;
    let reader = BufReader::new(f);
    let mut triplets = Vec::new();
    let mut y = Vec::new();
    let mut max_col = 0usize;
    for (lineno, line) in reader.lines().enumerate() {
        let line = line?;
        let line = line.split('#').next().unwrap_or("").trim();
        if line.is_empty() {
            continue;
        }
        let mut parts = line.split_whitespace();
        let label: f32 = parts
            .next()
            .unwrap()
            .parse()
            .with_context(|| format!("line {}: bad label", lineno + 1))?;
        let row = y.len();
        let mut prev = 0usize;
        for tok in parts {
            let (idx, val) = tok
                .split_once(':')
                .with_context(|| format!("line {}: token '{tok}'", lineno + 1))?;
            let idx: usize = idx
                .parse()
                .with_context(|| format!("line {}: bad index", lineno + 1))?;
            let val: f32 = val
                .parse()
                .with_context(|| format!("line {}: bad value", lineno + 1))?;
            if idx == 0 {
                bail!("line {}: LIBSVM indices are 1-based", lineno + 1);
            }
            if idx <= prev {
                bail!("line {}: indices not strictly increasing", lineno + 1);
            }
            prev = idx;
            max_col = max_col.max(idx);
            triplets.push((row, idx - 1, val));
        }
        y.push(if label > 0.0 { 1.0 } else { -1.0 });
    }
    let m = if m_hint > 0 { m_hint } else { max_col };
    if max_col > m {
        bail!("feature index {max_col} exceeds m_hint {m}");
    }
    let n = y.len();
    Ok(Dataset {
        name: path
            .file_stem()
            .map(|s| s.to_string_lossy().into_owned())
            .unwrap_or_else(|| "libsvm".into()),
        x: Block::sparse(SparseMatrix::from_triplets(n, m, triplets)),
        y,
    })
}

/// Write a dataset in LIBSVM format (sparse blocks only).
pub fn write_libsvm(ds: &Dataset, path: &Path) -> Result<()> {
    let sp = match ds.x.as_sparse() {
        Some(s) => s,
        None => bail!("write_libsvm expects a sparse dataset"),
    };
    let f = std::fs::File::create(path)
        .with_context(|| format!("create {}", path.display()))?;
    let mut w = BufWriter::new(f);
    for i in 0..sp.rows {
        write!(w, "{}", if ds.y[i] > 0.0 { "+1" } else { "-1" })?;
        for (j, v) in sp.row_iter(i) {
            write!(w, " {}:{}", j + 1, v)?;
        }
        writeln!(w)?;
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::SyntheticSparse;

    #[test]
    fn roundtrip_synthetic() {
        let ds = SyntheticSparse::new("rt", 50, 80, 0.05, 3).build();
        let dir = std::env::temp_dir().join("ddopt_libsvm_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("rt.libsvm");
        write_libsvm(&ds, &path).unwrap();
        let back = read_libsvm(&path, 80).unwrap();
        assert_eq!(back.n(), ds.n());
        assert_eq!(back.m(), 80);
        assert_eq!(back.y, ds.y);
        match (ds.x.as_sparse(), back.x.as_sparse()) {
            (Some(a), Some(b)) => {
                assert_eq!(a.indptr, b.indptr);
                assert_eq!(a.indices, b.indices);
                for (va, vb) in a.values.iter().zip(&b.values) {
                    assert!((va - vb).abs() < 1e-6);
                }
            }
            _ => panic!(),
        }
    }

    #[test]
    fn parses_comments_and_blank_lines() {
        let dir = std::env::temp_dir().join("ddopt_libsvm_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("c.libsvm");
        std::fs::write(&path, "# header\n\n+1 1:0.5 3:1.5\n-1 2:2.0 # tail\n")
            .unwrap();
        let ds = read_libsvm(&path, 0).unwrap();
        assert_eq!(ds.n(), 2);
        assert_eq!(ds.m(), 3);
        assert_eq!(ds.y, vec![1.0, -1.0]);
    }

    #[test]
    fn rejects_zero_and_decreasing_indices() {
        let dir = std::env::temp_dir().join("ddopt_libsvm_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p0 = dir.join("z.libsvm");
        std::fs::write(&p0, "+1 0:1.0\n").unwrap();
        assert!(read_libsvm(&p0, 0).is_err());
        let p1 = dir.join("d.libsvm");
        std::fs::write(&p1, "+1 3:1.0 2:1.0\n").unwrap();
        assert!(read_libsvm(&p1, 0).is_err());
    }
}
