//! # ddopt — doubly-distributed optimization
//!
//! A reproduction of *Optimization for Large-Scale Machine Learning with
//! Distributed Features and Observations* (Nathan & Klabjan, 2016) as a
//! three-layer Rust + JAX + Pallas stack:
//!
//! * **L3 (this crate)** — the coordination contribution: a P×Q
//!   doubly-partitioned cluster runtime with the paper's three optimizers
//!   (D3CA, RADiSA/RADiSA-avg, block-splitting ADMM), treeAggregate
//!   communication, a simulated parallel clock, and the bench harness that
//!   regenerates every table and figure in the paper's evaluation.
//! * **L2/L1 (python/, build-time only)** — per-partition compute programs
//!   (JAX) built on Pallas kernels, AOT-lowered once to `artifacts/*.hlo.txt`
//!   and executed here through the PJRT C API ([`runtime`]).
//!
//! Quick tour:
//! * [`data`] — dense/CSR matrices, the paper's synthetic generators, the
//!   LIBSVM reader, and the P×Q grid partitioner.
//! * [`loss`] — hinge / logistic / squared losses with conjugates.
//! * [`solvers`] — native SDCA/SVRG/gradient/objective kernels + the exact
//!   reference solver that produces `f*`.
//! * [`cluster`] — the simulated cluster substrate and superstep engine
//!   (worker pool, typed superstep plans, grouped tree reductions,
//!   simulated time + communication model).
//! * [`runtime`] — the PJRT engine and the [`runtime::Backend`] seam
//!   (native rust vs. AOT XLA artifacts).
//! * [`coordinator`] — the paper's algorithms 1-3 plus the ADMM baseline.
//! * [`bench_harness`] — one module per paper table/figure.
//!
//! ```no_run
//! use ddopt::prelude::*;
//!
//! let ds = SyntheticDense::paper_part1(2, 2, 200, 150, 0.1, 42).build();
//! let part = Partitioned::split(&ds, Grid::new(2, 2));
//! let backend = Backend::native();
//! let mut opt = Radisa::new(RadisaConfig::default());
//! let run = Driver::new(&part, &backend)
//!     .unwrap()
//!     .iterations(30)
//!     .run(&mut opt)
//!     .unwrap();
//! println!("final gap: {:?}", run.history.last());
//! ```

/// With `--features bench-alloc` the whole crate (binary, tests, benches)
/// runs on a counting allocator so the perf harness can report steady-state
/// allocations/iteration — see [`util::alloc`].
#[cfg(feature = "bench-alloc")]
#[global_allocator]
static GLOBAL_COUNTING_ALLOC: util::alloc::CountingAlloc = util::alloc::CountingAlloc;

pub mod bench_harness;
pub mod cluster;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod linalg;
pub mod loss;
pub mod metrics;
pub mod obs;
pub mod runtime;
pub mod solvers;
pub mod testkit;
pub mod util;

/// Convenient re-exports of the types most programs need.
pub mod prelude {
    pub use crate::cluster::{
        host_threads, ClusterBackend, ClusterConfig, ClusterMode, ClusterScenario,
        CostModel, DistCluster, GridOp, SimBackend, SimCluster, StepPlan,
    };
    pub use crate::config::ExperimentConfig;
    pub use crate::coordinator::{
        Admm, AdmmConfig, D3ca, D3caConfig, Driver, Optimizer, Radisa,
        RadisaConfig, RunResult,
    };
    pub use crate::data::{
        Dataset, DenseMatrix, Grid, Partitioned, SparseMatrix, SyntheticDense,
        SyntheticSparse,
    };
    pub use crate::loss::Loss;
    pub use crate::metrics::Recorder;
    pub use crate::runtime::Backend;
    pub use crate::solvers::exact::reference_optimum;
    pub use crate::util::rng::Xoshiro;
}
