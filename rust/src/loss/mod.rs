//! Loss functions for objective (1):  F(w) = (1/n) Σ f_i(x_i·w) + (λ/2)‖w‖².
//!
//! Conventions (shared with `python/compile/model.py` — keep in sync):
//! the regularizer is (λ/2)‖w‖², the form the paper's dual (2) and
//! primal-dual map (3) are consistent with (its eq. (1) prints λ‖w‖², but
//! its SDCA update and w(α) match the λ/2 convention of CoCoA/SDCA).
//!
//! For each loss, `value`/`slope` parametrize by the margin z = x_i·w and
//! label y ∈ {−1, +1} (hinge/logistic) or y ∈ ℝ (squared).  `conj_*` give
//! what the dual methods need: hinge's conjugate box and linear part.

/// Supported losses.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Loss {
    /// f(z) = max(0, 1 − y z) — the paper's experimental model (linear SVM).
    Hinge,
    /// f(z) = log(1 + exp(−y z)).
    Logistic,
    /// f(z) = (z − y)² / 2 (least squares).
    Squared,
}

impl Loss {
    pub fn name(&self) -> &'static str {
        match self {
            Loss::Hinge => "hinge",
            Loss::Logistic => "logistic",
            Loss::Squared => "squared",
        }
    }

    pub fn parse(s: &str) -> Option<Loss> {
        match s {
            "hinge" | "svm" => Some(Loss::Hinge),
            "logistic" | "logreg" => Some(Loss::Logistic),
            "squared" | "ls" => Some(Loss::Squared),
            _ => None,
        }
    }

    /// f_i(z).
    #[inline]
    pub fn value(&self, z: f32, y: f32) -> f32 {
        match self {
            Loss::Hinge => (1.0 - y * z).max(0.0),
            Loss::Logistic => {
                // stable log(1 + exp(-yz))
                let t = -y * z;
                if t > 0.0 {
                    t + (-t).exp().ln_1p()
                } else {
                    t.exp().ln_1p()
                }
            }
            Loss::Squared => {
                let d = z - y;
                0.5 * d * d
            }
        }
    }

    /// d f_i / d z — the per-observation slope used by gradient methods.
    #[inline]
    pub fn slope(&self, z: f32, y: f32) -> f32 {
        match self {
            Loss::Hinge => {
                if y * z < 1.0 {
                    -y
                } else {
                    0.0
                }
            }
            Loss::Logistic => -y * sigmoid(-y * z),
            Loss::Squared => z - y,
        }
    }

    /// Whether the dual coordinate method (D3CA) supports this loss.
    /// (The paper's D3CA experiments are hinge-only; logistic would need an
    /// inner Newton solve in the closed-form step.)
    pub fn has_sdca_closed_form(&self) -> bool {
        matches!(self, Loss::Hinge)
    }

    /// −φ*_i(−a): the dual objective's per-observation linear part.
    /// Hinge: a·y on the box 0 ≤ a·y ≤ 1 (∞ outside — callers must keep
    /// iterates feasible, which the SDCA step does by construction).
    #[inline]
    pub fn dual_linear(&self, a: f32, y: f32) -> f32 {
        match self {
            Loss::Hinge => a * y,
            _ => f32::NAN, // dual path is hinge-only
        }
    }

    /// Is `a` inside the conjugate's domain box (hinge)?
    #[inline]
    pub fn dual_feasible(&self, a: f32, y: f32, tol: f32) -> bool {
        match self {
            Loss::Hinge => {
                let t = a * y;
                t >= -tol && t <= 1.0 + tol
            }
            _ => true,
        }
    }
}

#[inline]
pub fn sigmoid(t: f32) -> f32 {
    if t >= 0.0 {
        1.0 / (1.0 + (-t).exp())
    } else {
        let e = t.exp();
        e / (1.0 + e)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn hinge_values_and_slope() {
        let l = Loss::Hinge;
        assert_eq!(l.value(2.0, 1.0), 0.0);
        assert_eq!(l.value(0.0, 1.0), 1.0);
        assert_eq!(l.value(-1.0, 1.0), 2.0);
        assert_eq!(l.slope(0.5, 1.0), -1.0);
        assert_eq!(l.slope(1.5, 1.0), 0.0);
        assert_eq!(l.slope(-0.5, -1.0), 1.0);
    }

    #[test]
    fn logistic_matches_reference_values() {
        let l = Loss::Logistic;
        // log(1+exp(0)) = ln 2
        assert!((l.value(0.0, 1.0) - 0.693147).abs() < 1e-5);
        // slope at 0 is -y/2
        assert!((l.slope(0.0, 1.0) + 0.5).abs() < 1e-6);
        // stability at extreme margins
        assert!(l.value(100.0, -1.0) > 99.0);
        assert!(l.value(100.0, 1.0) < 1e-6);
        assert!(l.slope(1000.0, 1.0).abs() < 1e-6);
    }

    #[test]
    fn slope_is_derivative_numerically() {
        for loss in [Loss::Hinge, Loss::Logistic, Loss::Squared] {
            for &(z, y) in &[(0.3f32, 1.0f32), (-0.7, -1.0), (1.4, 1.0), (2.0, -1.0)] {
                let h = 1e-3;
                let num = (loss.value(z + h, y) - loss.value(z - h, y)) / (2.0 * h);
                let ana = loss.slope(z, y);
                assert!(
                    (num - ana).abs() < 1e-2,
                    "{loss:?} z={z} y={y}: {num} vs {ana}"
                );
            }
        }
    }

    #[test]
    fn slope_matches_finite_difference_on_a_margin_sweep() {
        // dense sweep away from the hinge kink (z*y = 1, where the
        // subgradient makes the central difference meaningless)
        let h = 1e-3f32;
        for loss in [Loss::Hinge, Loss::Logistic, Loss::Squared] {
            for y in [-1.0f32, 1.0] {
                let mut z = -3.0f32;
                while z <= 3.0 {
                    if loss != Loss::Hinge || (z * y - 1.0).abs() > 10.0 * h {
                        let num =
                            (loss.value(z + h, y) - loss.value(z - h, y)) / (2.0 * h);
                        let ana = loss.slope(z, y);
                        assert!(
                            (num - ana).abs() < 5e-3,
                            "{loss:?} z={z} y={y}: fd {num} vs slope {ana}"
                        );
                    }
                    z += 0.37;
                }
            }
        }
    }

    #[test]
    fn hinge_dual_box() {
        let l = Loss::Hinge;
        assert!(l.dual_feasible(0.5, 1.0, 0.0));
        assert!(l.dual_feasible(-0.5, -1.0, 0.0));
        assert!(!l.dual_feasible(-0.1, 1.0, 1e-6));
        assert!(!l.dual_feasible(1.1, 1.0, 1e-6));
        assert_eq!(l.dual_linear(0.7, 1.0), 0.7);
    }

    #[test]
    fn sdca_closed_form_is_hinge_only() {
        assert!(Loss::Hinge.has_sdca_closed_form());
        assert!(!Loss::Logistic.has_sdca_closed_form());
        assert!(!Loss::Squared.has_sdca_closed_form());
    }

    #[test]
    fn dual_linear_is_bilinear_for_hinge_and_nan_elsewhere() {
        let l = Loss::Hinge;
        // a·y on the box, including the boundary and negative labels
        assert_eq!(l.dual_linear(0.0, 1.0), 0.0);
        assert_eq!(l.dual_linear(1.0, 1.0), 1.0);
        assert_eq!(l.dual_linear(-1.0, -1.0), 1.0);
        assert_eq!(l.dual_linear(-0.25, -1.0), 0.25);
        // the dual path is hinge-only: other losses must loudly NaN
        assert!(Loss::Logistic.dual_linear(0.5, 1.0).is_nan());
        assert!(Loss::Squared.dual_linear(0.5, 1.0).is_nan());
    }

    #[test]
    fn dual_feasible_box_edges_and_tolerance() {
        let l = Loss::Hinge;
        // exact box edges are feasible at zero tolerance
        assert!(l.dual_feasible(0.0, 1.0, 0.0));
        assert!(l.dual_feasible(1.0, 1.0, 0.0));
        assert!(l.dual_feasible(-1.0, -1.0, 0.0));
        // tolerance admits small excursions, and only small ones
        assert!(l.dual_feasible(1.05, 1.0, 0.1));
        assert!(l.dual_feasible(-0.05, 1.0, 0.1));
        assert!(!l.dual_feasible(1.2, 1.0, 0.1));
        // sign matters: a and y must agree for a·y to be in [0, 1]
        assert!(!l.dual_feasible(0.5, -1.0, 1e-6));
        // non-hinge losses have no box: everything is feasible
        assert!(Loss::Logistic.dual_feasible(42.0, 1.0, 0.0));
        assert!(Loss::Squared.dual_feasible(-42.0, 1.0, 0.0));
    }

    #[test]
    fn parse_names() {
        assert_eq!(Loss::parse("hinge"), Some(Loss::Hinge));
        assert_eq!(Loss::parse("svm"), Some(Loss::Hinge));
        assert_eq!(Loss::parse("logreg"), Some(Loss::Logistic));
        assert_eq!(Loss::parse("nope"), None);
    }

    #[test]
    fn sigmoid_stable() {
        assert!((sigmoid(0.0) - 0.5).abs() < 1e-6);
        assert!(sigmoid(-100.0) >= 0.0);
        assert!(sigmoid(100.0) <= 1.0);
        assert!((sigmoid(100.0) - 1.0).abs() < 1e-6);
    }
}
