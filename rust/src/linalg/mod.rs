//! Dense BLAS-1/2 kernels on `f32` slices — the native backend's hot path.
//!
//! Written to auto-vectorize: straight-line loops over exact-length slice
//! pairs (the `[..n]` re-slicing pattern lets LLVM drop bounds checks and
//! emit packed SIMD).  No allocation inside any kernel.

/// x · y
#[inline]
pub fn dot(x: &[f32], y: &[f32]) -> f32 {
    debug_assert_eq!(x.len(), y.len());
    let n = x.len();
    let (x, y) = (&x[..n], &y[..n]);
    // 8 independent accumulators: breaks the fp-add dependence chain so
    // LLVM can keep two 8-lane fma pipes busy (§Perf iteration 1: 4→8
    // accumulators lifted margins from 5.6 to ~8 GFLOP/s on this host).
    let mut acc = [0.0f32; 8];
    let chunks = n / 8;
    for i in 0..chunks {
        let b = i * 8;
        acc[0] += x[b] * y[b];
        acc[1] += x[b + 1] * y[b + 1];
        acc[2] += x[b + 2] * y[b + 2];
        acc[3] += x[b + 3] * y[b + 3];
        acc[4] += x[b + 4] * y[b + 4];
        acc[5] += x[b + 5] * y[b + 5];
        acc[6] += x[b + 6] * y[b + 6];
        acc[7] += x[b + 7] * y[b + 7];
    }
    let mut s = ((acc[0] + acc[1]) + (acc[2] + acc[3]))
        + ((acc[4] + acc[5]) + (acc[6] + acc[7]));
    for i in chunks * 8..n {
        s += x[i] * y[i];
    }
    s
}

/// y += a * x
#[inline]
pub fn axpy(a: f32, x: &[f32], y: &mut [f32]) {
    debug_assert_eq!(x.len(), y.len());
    let n = x.len();
    let (x, y) = (&x[..n], &mut y[..n]);
    for i in 0..n {
        y[i] += a * x[i];
    }
}

/// x *= a
#[inline]
pub fn scale(a: f32, x: &mut [f32]) {
    for v in x.iter_mut() {
        *v *= a;
    }
}

/// ||x||^2
#[inline]
pub fn nrm2_sq(x: &[f32]) -> f32 {
    dot(x, x)
}

/// out = A x   (A row-major [n, m]).  Rows are processed four at a time so
/// each load of x[j] feeds four fmas (§Perf iteration 2).
pub fn gemv(a: &[f32], n: usize, m: usize, x: &[f32], out: &mut [f32]) {
    debug_assert_eq!(a.len(), n * m);
    debug_assert_eq!(x.len(), m);
    debug_assert_eq!(out.len(), n);
    let mut i = 0;
    while i + 4 <= n {
        let r0 = &a[i * m..(i + 1) * m];
        let r1 = &a[(i + 1) * m..(i + 2) * m];
        let r2 = &a[(i + 2) * m..(i + 3) * m];
        let r3 = &a[(i + 3) * m..(i + 4) * m];
        let (mut s0, mut s1, mut s2, mut s3) = (0.0f32, 0.0f32, 0.0f32, 0.0f32);
        for j in 0..m {
            let xj = x[j];
            s0 += r0[j] * xj;
            s1 += r1[j] * xj;
            s2 += r2[j] * xj;
            s3 += r3[j] * xj;
        }
        out[i] = s0;
        out[i + 1] = s1;
        out[i + 2] = s2;
        out[i + 3] = s3;
        i += 4;
    }
    for k in i..n {
        out[k] = dot(&a[k * m..(k + 1) * m], x);
    }
}

/// out = A^T x   (A row-major [n, m]); accumulated row-wise so the matrix is
/// streamed once in memory order rather than strided per column.
pub fn gemv_t(a: &[f32], n: usize, m: usize, x: &[f32], out: &mut [f32]) {
    debug_assert_eq!(a.len(), n * m);
    debug_assert_eq!(x.len(), n);
    debug_assert_eq!(out.len(), m);
    out.fill(0.0);
    for i in 0..n {
        let xi = x[i];
        if xi != 0.0 {
            axpy(xi, &a[i * m..(i + 1) * m], out);
        }
    }
}

/// In-place Cholesky of a symmetric positive-definite row-major [n, n]
/// matrix; lower triangle holds L on return, upper is zeroed.
/// Used by the native ADMM path (the XLA path uses the `admm_factor`
/// artifact instead).
pub fn cholesky_in_place(a: &mut [f32], n: usize) -> Result<(), String> {
    debug_assert_eq!(a.len(), n * n);
    for j in 0..n {
        // Split rows j.. at row j so we can read row j while writing rows >j.
        let mut d = a[j * n + j] as f64;
        for k in 0..j {
            let v = a[j * n + k] as f64;
            d -= v * v;
        }
        if d <= 0.0 {
            return Err(format!("matrix not SPD at pivot {j} (d={d})"));
        }
        let ljj = d.sqrt();
        a[j * n + j] = ljj as f32;
        let (head, tail) = a.split_at_mut((j + 1) * n);
        let row_j = &head[j * n..j * n + j + 1];
        for (r, chunk) in tail.chunks_exact_mut(n).enumerate() {
            let i = j + 1 + r;
            let _ = i;
            let mut s = chunk[j] as f64;
            for k in 0..j {
                s -= chunk[k] as f64 * row_j[k] as f64;
            }
            chunk[j] = (s / ljj) as f32;
        }
        for k in j + 1..n {
            a[j * n + k] = 0.0;
        }
    }
    Ok(())
}

/// Solve L y = b (forward) then L^T x = y (backward); `l` is row-major
/// lower-triangular [n, n], `b` is overwritten with x.
pub fn cho_solve(l: &[f32], n: usize, b: &mut [f32]) {
    debug_assert_eq!(l.len(), n * n);
    debug_assert_eq!(b.len(), n);
    // forward: L y = b
    for i in 0..n {
        let s = dot(&l[i * n..i * n + i], &b[..i]);
        b[i] = (b[i] - s) / l[i * n + i];
    }
    // backward: L^T x = y
    for i in (0..n).rev() {
        let mut s = b[i];
        for k in i + 1..n {
            s -= l[k * n + i] * b[k];
        }
        b[i] = s / l[i * n + i];
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Xoshiro;

    #[test]
    fn dot_matches_naive() {
        let mut r = Xoshiro::new(1);
        for n in [0, 1, 3, 4, 7, 64, 129] {
            let x: Vec<f32> = (0..n).map(|_| r.range_f32(-1.0, 1.0)).collect();
            let y: Vec<f32> = (0..n).map(|_| r.range_f32(-1.0, 1.0)).collect();
            let naive: f32 = x.iter().zip(&y).map(|(a, b)| a * b).sum();
            assert!((dot(&x, &y) - naive).abs() < 1e-4, "n={n}");
        }
    }

    #[test]
    fn axpy_and_scale() {
        let x = vec![1.0, 2.0, 3.0];
        let mut y = vec![10.0, 20.0, 30.0];
        axpy(2.0, &x, &mut y);
        assert_eq!(y, vec![12.0, 24.0, 36.0]);
        scale(0.5, &mut y);
        assert_eq!(y, vec![6.0, 12.0, 18.0]);
    }

    #[test]
    fn gemv_pair_adjoint_identity() {
        // <Ax, y> == <x, A^T y> for random matrices.
        let mut r = Xoshiro::new(2);
        let (n, m) = (13, 9);
        let a: Vec<f32> = (0..n * m).map(|_| r.range_f32(-1.0, 1.0)).collect();
        let x: Vec<f32> = (0..m).map(|_| r.range_f32(-1.0, 1.0)).collect();
        let y: Vec<f32> = (0..n).map(|_| r.range_f32(-1.0, 1.0)).collect();
        let mut ax = vec![0.0; n];
        gemv(&a, n, m, &x, &mut ax);
        let mut aty = vec![0.0; m];
        gemv_t(&a, n, m, &y, &mut aty);
        assert!((dot(&ax, &y) - dot(&x, &aty)).abs() < 1e-3);
    }

    #[test]
    fn cholesky_roundtrip() {
        let mut r = Xoshiro::new(3);
        let n = 24;
        let m = 16;
        // SPD: I + B B^T
        let b: Vec<f32> = (0..n * m).map(|_| r.range_f32(-1.0, 1.0)).collect();
        let mut a = vec![0.0f32; n * n];
        for i in 0..n {
            for j in 0..n {
                a[i * n + j] = dot(&b[i * m..(i + 1) * m], &b[j * m..(j + 1) * m]);
            }
            a[i * n + i] += 1.0;
        }
        let orig = a.clone();
        cholesky_in_place(&mut a, n).unwrap();
        // check L L^T == orig
        for i in 0..n {
            for j in 0..n {
                let mut s = 0.0f64;
                for k in 0..=i.min(j) {
                    s += a[i * n + k] as f64 * a[j * n + k] as f64;
                }
                assert!(
                    (s - orig[i * n + j] as f64).abs() < 1e-3,
                    "({i},{j}): {s} vs {}",
                    orig[i * n + j]
                );
            }
        }
        // solve against a known vector
        let x_true: Vec<f32> = (0..n).map(|_| r.range_f32(-1.0, 1.0)).collect();
        let mut rhs = vec![0.0f32; n];
        gemv(&orig, n, n, &x_true, &mut rhs);
        cho_solve(&a, n, &mut rhs);
        for i in 0..n {
            assert!((rhs[i] - x_true[i]).abs() < 1e-2, "{i}");
        }
    }

    #[test]
    fn cholesky_rejects_non_spd() {
        let mut a = vec![1.0, 2.0, 2.0, 1.0]; // eigenvalues 3, -1
        assert!(cholesky_in_place(&mut a, 2).is_err());
    }
}
