//! Dense BLAS-1/2 kernels on `f32` slices — the native backend's hot path.
//!
//! Layered like kubecl's matmul stack, minus the GPU DSL:
//!
//! * [`kernels`](self::kernels) (private) — the register-tiled kernel
//!   *bodies*, written once with a fixed accumulator blocking and
//!   reduction order, compiled per-ISA via `#[target_feature]` wrappers.
//! * [`dispatch`] — the runtime selection seam: static
//!   [`KernelDispatch`] tables (baseline + AVX2/FMA on x86_64, NEON
//!   label on aarch64), chosen once per process by feature detection,
//!   overridable with `DDOPT_KERNELS=scalar|simd`.
//! * [`factor`](self::factor) — Cholesky + triangular solves (cold
//!   path, not dispatched).
//! * this module — the convenience API (`dot`, `gemv`, …) that routes
//!   through the active table; callers that already hold a table (e.g.
//!   `GridOp::exec_task` via `OpScratch`) call through it directly.
//!
//! Determinism contract: every table computes bit-identical results in
//! a fixed, lane-count-independent reduction order — across runs,
//! `--threads`, sim-vs-dist, and `DDOPT_KERNELS=scalar` vs `simd`.
//! No allocation inside any kernel.

pub mod dispatch;
mod factor;
mod kernels;

pub use dispatch::{detected, kernels, scalar_table, Isa, KernelDispatch};
pub use factor::{cho_solve, cholesky_in_place};

/// x · y (via the active dispatch table).
#[inline]
pub fn dot(x: &[f32], y: &[f32]) -> f32 {
    (kernels().dot)(x, y)
}

/// y += a * x
#[inline]
pub fn axpy(a: f32, x: &[f32], y: &mut [f32]) {
    (kernels().axpy)(a, x, y)
}

/// x *= a
#[inline]
pub fn scale(a: f32, x: &mut [f32]) {
    (kernels().scale)(a, x)
}

/// ||x||^2
#[inline]
pub fn nrm2_sq(x: &[f32]) -> f32 {
    (kernels().dot)(x, x)
}

/// out = A x   (A row-major [n, m]).
#[inline]
pub fn gemv(a: &[f32], n: usize, m: usize, x: &[f32], out: &mut [f32]) {
    (kernels().gemv)(a, n, m, x, out)
}

/// out = A^T x   (A row-major [n, m]).
#[inline]
pub fn gemv_t(a: &[f32], n: usize, m: usize, x: &[f32], out: &mut [f32]) {
    (kernels().gemv_t)(a, n, m, x, out)
}

/// delta[i] -= eta * (lam * delta[i] + mu[i]) — SVRG window update.
#[inline]
pub fn svrg_delta(delta: &mut [f32], mu: &[f32], eta: f32, lam: f32) {
    (kernels().svrg_delta)(delta, mu, eta, lam)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Xoshiro;

    #[test]
    fn dot_matches_naive() {
        let mut r = Xoshiro::new(1);
        for n in [0, 1, 3, 4, 7, 64, 129] {
            let x: Vec<f32> = (0..n).map(|_| r.range_f32(-1.0, 1.0)).collect();
            let y: Vec<f32> = (0..n).map(|_| r.range_f32(-1.0, 1.0)).collect();
            let naive: f32 = x.iter().zip(&y).map(|(a, b)| a * b).sum();
            assert!((dot(&x, &y) - naive).abs() < 1e-4, "n={n}");
        }
    }

    #[test]
    fn axpy_and_scale() {
        let x = vec![1.0, 2.0, 3.0];
        let mut y = vec![10.0, 20.0, 30.0];
        axpy(2.0, &x, &mut y);
        assert_eq!(y, vec![12.0, 24.0, 36.0]);
        scale(0.5, &mut y);
        assert_eq!(y, vec![6.0, 12.0, 18.0]);
    }

    #[test]
    fn gemv_pair_adjoint_identity() {
        // <Ax, y> == <x, A^T y> for random matrices.
        let mut r = Xoshiro::new(2);
        let (n, m) = (13, 9);
        let a: Vec<f32> = (0..n * m).map(|_| r.range_f32(-1.0, 1.0)).collect();
        let x: Vec<f32> = (0..m).map(|_| r.range_f32(-1.0, 1.0)).collect();
        let y: Vec<f32> = (0..n).map(|_| r.range_f32(-1.0, 1.0)).collect();
        let mut ax = vec![0.0; n];
        gemv(&a, n, m, &x, &mut ax);
        let mut aty = vec![0.0; m];
        gemv_t(&a, n, m, &y, &mut aty);
        assert!((dot(&ax, &y) - dot(&x, &aty)).abs() < 1e-3);
    }

    #[test]
    fn gemv_rows_match_dot_bitwise() {
        // The gemv register tile must preserve the per-row `dot` order
        // exactly — coordinators mix whole-block margins with per-row
        // dots and the results must agree to the bit.
        let mut r = Xoshiro::new(7);
        for (n, m) in [(1, 1), (4, 8), (5, 9), (13, 40), (16, 17)] {
            let a: Vec<f32> = (0..n * m).map(|_| r.range_f32(-1.0, 1.0)).collect();
            let x: Vec<f32> = (0..m).map(|_| r.range_f32(-1.0, 1.0)).collect();
            let mut out = vec![0.0; n];
            gemv(&a, n, m, &x, &mut out);
            for i in 0..n {
                let d = dot(&a[i * m..(i + 1) * m], &x);
                assert_eq!(out[i].to_bits(), d.to_bits(), "({n},{m}) row {i}");
            }
        }
    }

    #[test]
    fn cholesky_roundtrip() {
        let mut r = Xoshiro::new(3);
        let n = 24;
        let m = 16;
        // SPD: I + B B^T
        let b: Vec<f32> = (0..n * m).map(|_| r.range_f32(-1.0, 1.0)).collect();
        let mut a = vec![0.0f32; n * n];
        for i in 0..n {
            for j in 0..n {
                a[i * n + j] = dot(&b[i * m..(i + 1) * m], &b[j * m..(j + 1) * m]);
            }
            a[i * n + i] += 1.0;
        }
        let orig = a.clone();
        cholesky_in_place(&mut a, n).unwrap();
        // upper triangle fully zeroed
        for i in 0..n {
            for k in i + 1..n {
                assert_eq!(a[i * n + k], 0.0, "upper ({i},{k})");
            }
        }
        // check L L^T == orig
        for i in 0..n {
            for j in 0..n {
                let mut s = 0.0f64;
                for k in 0..=i.min(j) {
                    s += a[i * n + k] as f64 * a[j * n + k] as f64;
                }
                assert!(
                    (s - orig[i * n + j] as f64).abs() < 1e-3,
                    "({i},{j}): {s} vs {}",
                    orig[i * n + j]
                );
            }
        }
        // solve against a known vector
        let x_true: Vec<f32> = (0..n).map(|_| r.range_f32(-1.0, 1.0)).collect();
        let mut rhs = vec![0.0f32; n];
        gemv(&orig, n, n, &x_true, &mut rhs);
        cho_solve(&a, n, &mut rhs);
        for i in 0..n {
            assert!((rhs[i] - x_true[i]).abs() < 1e-2, "{i}");
        }
    }

    #[test]
    fn cholesky_rejects_non_spd_with_dimension() {
        let mut a = vec![1.0, 2.0, 2.0, 1.0]; // eigenvalues 3, -1
        let err = cholesky_in_place(&mut a, 2).unwrap_err();
        assert!(err.contains("pivot 1"), "{err}");
        assert!(err.contains("2x2"), "{err}");
    }
}
