//! Runtime kernel dispatch — one table selected once per process.
//!
//! Structured like kubecl's matmul stack in miniature: the *bodies*
//! (`kernels.rs`) own the register-tiling scheme, this module owns the
//! global selection seam.  A [`KernelDispatch`] is a plain table of fn
//! pointers; two static tables exist per build (baseline "scalar" and,
//! on x86_64, AVX2+FMA), and [`kernels()`] picks one on first use:
//!
//! * `DDOPT_KERNELS` unset or `simd`  → feature detection
//!   (`is_x86_feature_detected!` AVX2+FMA on x86_64; aarch64's baseline
//!   already includes NEON, so detection is a no-op there).
//! * `DDOPT_KERNELS=scalar` → the baseline table, regardless of CPU.
//! * anything else → panic with the accepted values (a typo silently
//!   benchmarking the wrong path would be worse).
//!
//! Both tables execute the identical arithmetic (see `kernels.rs`), so
//! the env var changes throughput, never results — CI runs the whole
//! test suite under both settings to keep that true.

use std::sync::OnceLock;

use super::kernels as k;

/// Which instruction set a [`KernelDispatch`] was compiled for.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Isa {
    /// Baseline codegen (SSE2 on x86_64 — "scalar" means no
    /// runtime-detected features, not no vector unit).
    Scalar,
    /// 256-bit AVX2 + FMA codegen (x86_64, runtime-detected).
    Avx2Fma,
    /// NEON baseline (aarch64 — always available, same table entries as
    /// Scalar but labelled for reporting).
    Neon,
}

impl Isa {
    pub fn name(&self) -> &'static str {
        match self {
            Isa::Scalar => "scalar",
            Isa::Avx2Fma => "avx2+fma",
            Isa::Neon => "neon",
        }
    }
}

/// The dispatch table: every hot kernel the solvers/supersteps call,
/// as plain fn pointers (const-constructible, `'static`, no vtable).
pub struct KernelDispatch {
    pub isa: Isa,
    /// x · y
    pub dot: fn(&[f32], &[f32]) -> f32,
    /// y += a x
    pub axpy: fn(f32, &[f32], &mut [f32]),
    /// x *= a
    pub scale: fn(f32, &mut [f32]),
    /// out = A x, A row-major [n, m]
    pub gemv: fn(&[f32], usize, usize, &[f32], &mut [f32]),
    /// out = Aᵀ x, A row-major [n, m]
    pub gemv_t: fn(&[f32], usize, usize, &[f32], &mut [f32]),
    /// out[j] = Σ column-j CSC entries · x (indptr, rows, vals, x, out)
    pub spmv_t_csc: fn(&[usize], &[u32], &[f32], &[f32], &mut [f32]),
    /// delta[i] -= eta (lam delta[i] + mu[i])
    pub svrg_delta: fn(&mut [f32], &[f32], f32, f32),
}

static SCALAR: KernelDispatch = KernelDispatch {
    isa: Isa::Scalar,
    dot: k::dot_scalar,
    axpy: k::axpy_scalar,
    scale: k::scale_scalar,
    gemv: k::gemv_scalar,
    gemv_t: k::gemv_t_scalar,
    spmv_t_csc: k::spmv_t_csc_scalar,
    svrg_delta: k::svrg_delta_scalar,
};

#[cfg(target_arch = "x86_64")]
static AVX2_FMA: KernelDispatch = KernelDispatch {
    isa: Isa::Avx2Fma,
    dot: k::dot_avx2,
    axpy: k::axpy_avx2,
    scale: k::scale_avx2,
    gemv: k::gemv_avx2,
    gemv_t: k::gemv_t_avx2,
    spmv_t_csc: k::spmv_t_csc_avx2,
    svrg_delta: k::svrg_delta_avx2,
};

#[cfg(target_arch = "aarch64")]
static NEON: KernelDispatch = KernelDispatch {
    isa: Isa::Neon,
    // aarch64's ABI baseline includes NEON, so the baseline entries ARE
    // the NEON entries; the separate table only re-labels the ISA.
    dot: k::dot_scalar,
    axpy: k::axpy_scalar,
    scale: k::scale_scalar,
    gemv: k::gemv_scalar,
    gemv_t: k::gemv_t_scalar,
    spmv_t_csc: k::spmv_t_csc_scalar,
    svrg_delta: k::svrg_delta_scalar,
};

/// The baseline table — what `DDOPT_KERNELS=scalar` runs, and the
/// reference side of every parity assertion.
pub fn scalar_table() -> &'static KernelDispatch {
    &SCALAR
}

/// The best table this CPU supports, by feature detection (ignores the
/// env override — used by the perf harness to report both paths and by
/// parity tests to exercise the SIMD entries even under
/// `DDOPT_KERNELS=scalar`).
#[cfg(target_arch = "x86_64")]
pub fn detected() -> &'static KernelDispatch {
    if is_x86_feature_detected!("avx2") && is_x86_feature_detected!("fma") {
        &AVX2_FMA
    } else {
        &SCALAR
    }
}

/// aarch64: NEON is part of the platform baseline, nothing to detect.
#[cfg(target_arch = "aarch64")]
pub fn detected() -> &'static KernelDispatch {
    &NEON
}

/// Other architectures: baseline codegen only.
#[cfg(not(any(target_arch = "x86_64", target_arch = "aarch64")))]
pub fn detected() -> &'static KernelDispatch {
    &SCALAR
}

static ACTIVE: OnceLock<&'static KernelDispatch> = OnceLock::new();

/// The process-wide active table — selected once on first call from
/// `DDOPT_KERNELS` + feature detection, then a single atomic load.
pub fn kernels() -> &'static KernelDispatch {
    ACTIVE.get_or_init(|| match std::env::var("DDOPT_KERNELS") {
        Err(_) => detected(),
        Ok(v) if v == "simd" => detected(),
        Ok(v) if v == "scalar" => &SCALAR,
        Ok(v) => panic!("DDOPT_KERNELS={v:?} not recognized (expected \"scalar\" or \"simd\")"),
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scalar_table_is_baseline() {
        assert_eq!(scalar_table().isa, Isa::Scalar);
    }

    #[test]
    #[cfg(any(target_arch = "x86_64", target_arch = "aarch64"))]
    fn detected_table_matches_cpu() {
        let t = detected();
        #[cfg(target_arch = "x86_64")]
        {
            let want = if is_x86_feature_detected!("avx2") && is_x86_feature_detected!("fma") {
                Isa::Avx2Fma
            } else {
                Isa::Scalar
            };
            assert_eq!(t.isa, want);
        }
        #[cfg(target_arch = "aarch64")]
        assert_eq!(t.isa, Isa::Neon);
    }

    #[test]
    fn active_table_honors_env() {
        // The process env is set (or not) before any test runs; whatever
        // it says, the active table must be one of the two valid picks.
        let active = kernels();
        match std::env::var("DDOPT_KERNELS").as_deref() {
            Ok("scalar") => assert_eq!(active.isa, Isa::Scalar),
            _ => assert_eq!(active.isa, detected().isa),
        }
    }
}
