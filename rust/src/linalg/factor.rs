//! Dense factorization: in-place Cholesky + triangular solves.
//!
//! Used by the native ADMM path (the XLA path uses the `admm_factor`
//! artifact instead).  Accumulation is f64 for the pivot recurrences —
//! these are O(n³) over a small n (the per-partition feature count), so
//! the extra precision is free and keeps the factor stable.

/// In-place Cholesky of a symmetric positive-definite row-major [n, n]
/// matrix; lower triangle holds L on return, upper is zeroed.
pub fn cholesky_in_place(a: &mut [f32], n: usize) -> Result<(), String> {
    debug_assert_eq!(a.len(), n * n);
    for j in 0..n {
        let mut d = a[j * n + j] as f64;
        for k in 0..j {
            let v = a[j * n + k] as f64;
            d -= v * v;
        }
        if d <= 0.0 {
            return Err(format!("matrix not SPD at pivot {j} of {n}x{n} (d={d})"));
        }
        let ljj = d.sqrt();
        a[j * n + j] = ljj as f32;
        // Split rows j.. at row j so we can read row j while writing rows >j.
        let (head, tail) = a.split_at_mut((j + 1) * n);
        let row_j = &head[j * n..j * n + j + 1];
        for chunk in tail.chunks_exact_mut(n) {
            let mut s = chunk[j] as f64;
            for k in 0..j {
                s -= chunk[k] as f64 * row_j[k] as f64;
            }
            chunk[j] = (s / ljj) as f32;
        }
    }
    // Zero the strict upper triangle in one pass after the pivot loop
    // (doing it inside the loop re-touched every row n times).
    for i in 0..n {
        for k in i + 1..n {
            a[i * n + k] = 0.0;
        }
    }
    Ok(())
}

/// Solve L y = b (forward) then L^T x = y (backward); `l` is row-major
/// lower-triangular [n, n], `b` is overwritten with x.
pub fn cho_solve(l: &[f32], n: usize, b: &mut [f32]) {
    debug_assert_eq!(l.len(), n * n);
    debug_assert_eq!(b.len(), n);
    // forward: L y = b
    for i in 0..n {
        let s = super::dot(&l[i * n..i * n + i], &b[..i]);
        b[i] = (b[i] - s) / l[i * n + i];
    }
    // backward: L^T x = y
    for i in (0..n).rev() {
        let mut s = b[i];
        for k in i + 1..n {
            s -= l[k * n + i] * b[k];
        }
        b[i] = s / l[i * n + i];
    }
}
