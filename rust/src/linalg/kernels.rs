//! Canonical kernel bodies and their per-ISA entry points.
//!
//! Every kernel is written ONCE as an `#[inline(always)]` body with a
//! fixed register-tiling scheme (accumulator count, combine order, tail
//! handling).  Each ISA variant is a thin `#[target_feature]` wrapper
//! that calls the same body, so the only thing that differs between the
//! scalar and SIMD entries is *codegen width* — never the arithmetic:
//!
//! * rustc emits `fmul`/`fadd` without the fast-math `contract` flag, so
//!   FMA-capable codegen does not fuse `a*b + c` into a single rounded
//!   fma — results match the scalar entry bit for bit.
//! * The accumulator blocks are fixed-size arrays (`[f32; 8]`); LLVM's
//!   SLP vectorizer maps lane `l` of the array to lane `l` of a vector
//!   register and never reassociates across lanes, so the combine order
//!   written below is the combine order executed on every ISA.
//!
//! This is what makes `DDOPT_KERNELS=scalar` vs the dispatched path
//! bitwise identical (asserted kernel-by-kernel in
//! `tests/kernel_parity.rs` and end-to-end by running the whole test
//! suite under both settings in CI).
//!
//! Tiling schemes (see README §Perf for the narrative version):
//!
//! | kernel       | tile                | reduction order                  |
//! |--------------|---------------------|----------------------------------|
//! | `dot`        | 8 accumulators      | pairwise `((0+1)+(2+3))+((4+5)+(6+7))`, sequential tail |
//! | `gemv`       | 4 rows x 8 accs     | per row, identical to `dot`      |
//! | `gemv_t`     | row-axpy stream     | sequential over rows (zero-skip) |
//! | `spmv_t_csc` | 4-column lockstep   | per column, sequential ascending |
//! | `axpy`/`scale`/`svrg_delta` | elementwise | n/a (no reduction)      |

/// Fixed pairwise combine of an 8-lane accumulator block — the single
/// canonical reduction order shared by `dot` and every kernel that must
/// agree with it bitwise.
#[inline(always)]
fn combine8(acc: &[f32; 8]) -> f32 {
    ((acc[0] + acc[1]) + (acc[2] + acc[3])) + ((acc[4] + acc[5]) + (acc[6] + acc[7]))
}

/// x · y — 8 independent accumulators (breaks the fp-add dependence
/// chain; §Perf iteration 1 lifted margins from 5.6 to ~8 GFLOP/s when
/// going 4→8), pairwise combine, sequential scalar tail.
#[inline(always)]
fn dot_body(x: &[f32], y: &[f32]) -> f32 {
    debug_assert_eq!(x.len(), y.len());
    let n = x.len();
    let (x, y) = (&x[..n], &y[..n]);
    let mut acc = [0.0f32; 8];
    let chunks = n / 8;
    for i in 0..chunks {
        let b = i * 8;
        for l in 0..8 {
            acc[l] += x[b + l] * y[b + l];
        }
    }
    let mut s = combine8(&acc);
    for i in chunks * 8..n {
        s += x[i] * y[i];
    }
    s
}

/// y += a * x
#[inline(always)]
fn axpy_body(a: f32, x: &[f32], y: &mut [f32]) {
    debug_assert_eq!(x.len(), y.len());
    let n = x.len();
    let (x, y) = (&x[..n], &mut y[..n]);
    for i in 0..n {
        y[i] += a * x[i];
    }
}

/// x *= a
#[inline(always)]
fn scale_body(a: f32, x: &mut [f32]) {
    for v in x.iter_mut() {
        *v *= a;
    }
}

/// out = A x (A row-major [n, m]).  Register tile: 4 rows x 8
/// accumulators each, interleaved in one inner loop so every load of
/// `x[j]` feeds four multiply-adds while each row keeps the exact `dot`
/// accumulation order — the invariant `gemv(A, x)[i] == dot(row_i, x)`
/// holds bitwise (pinned in tests), so per-row and whole-block margins
/// paths agree no matter which one a coordinator takes.
#[inline(always)]
fn gemv_body(a: &[f32], n: usize, m: usize, x: &[f32], out: &mut [f32]) {
    debug_assert_eq!(a.len(), n * m);
    debug_assert_eq!(x.len(), m);
    debug_assert_eq!(out.len(), n);
    let mut i = 0;
    while i + 4 <= n {
        let r0 = &a[i * m..(i + 1) * m];
        let r1 = &a[(i + 1) * m..(i + 2) * m];
        let r2 = &a[(i + 2) * m..(i + 3) * m];
        let r3 = &a[(i + 3) * m..(i + 4) * m];
        let mut acc0 = [0.0f32; 8];
        let mut acc1 = [0.0f32; 8];
        let mut acc2 = [0.0f32; 8];
        let mut acc3 = [0.0f32; 8];
        let chunks = m / 8;
        for c in 0..chunks {
            let b = c * 8;
            for l in 0..8 {
                let xl = x[b + l];
                acc0[l] += r0[b + l] * xl;
                acc1[l] += r1[b + l] * xl;
                acc2[l] += r2[b + l] * xl;
                acc3[l] += r3[b + l] * xl;
            }
        }
        let mut s0 = combine8(&acc0);
        let mut s1 = combine8(&acc1);
        let mut s2 = combine8(&acc2);
        let mut s3 = combine8(&acc3);
        for j in chunks * 8..m {
            let xj = x[j];
            s0 += r0[j] * xj;
            s1 += r1[j] * xj;
            s2 += r2[j] * xj;
            s3 += r3[j] * xj;
        }
        out[i] = s0;
        out[i + 1] = s1;
        out[i + 2] = s2;
        out[i + 3] = s3;
        i += 4;
    }
    for k in i..n {
        out[k] = dot_body(&a[k * m..(k + 1) * m], x);
    }
}

/// out = A^T x (A row-major [n, m]); accumulated row-wise so the matrix
/// is streamed once in memory order rather than strided per column.
/// Rows with `x[i] == 0` are skipped entirely (bitwise contract with
/// the sparse scatter path, which never visits them).
#[inline(always)]
fn gemv_t_body(a: &[f32], n: usize, m: usize, x: &[f32], out: &mut [f32]) {
    debug_assert_eq!(a.len(), n * m);
    debug_assert_eq!(x.len(), n);
    debug_assert_eq!(out.len(), m);
    out.fill(0.0);
    for i in 0..n {
        let xi = x[i];
        if xi != 0.0 {
            axpy_body(xi, &a[i * m..(i + 1) * m], out);
        }
    }
}

/// out[j] = Σ_k x[rows[k]] * vals[k] over column j's CSC slice — the
/// block-column Aᵀx kernel.  Columns are tiled in strips of 4; the
/// strip walks the four column slices in lockstep (one independent
/// accumulator per column, so the four gather→multiply→add chains
/// overlap instead of serializing on one accumulator), then finishes
/// each column's tail sequentially.  Entries within a column are always
/// consumed in ascending-row order with the `x[row] == 0` skip, i.e. in
/// EXACTLY the order the plain one-column-at-a-time loop uses — which
/// keeps the CSC mirror bitwise identical to the CSR scatter kernel
/// (`csc_mirror_matches_scatter_bitwise`) and the strip kernel bitwise
/// identical to the scalar entry.
#[inline(always)]
fn spmv_t_csc_body(indptr: &[usize], rows: &[u32], vals: &[f32], x: &[f32], out: &mut [f32]) {
    let ncols = out.len();
    debug_assert_eq!(indptr.len(), ncols + 1);
    debug_assert_eq!(rows.len(), vals.len());
    #[inline(always)]
    fn col_partial(rows: &[u32], vals: &[f32], x: &[f32], s: usize, e: usize, mut acc: f32) -> f32 {
        for k in s..e {
            let xi = x[rows[k] as usize];
            if xi != 0.0 {
                acc += xi * vals[k];
            }
        }
        acc
    }
    let mut j = 0;
    while j + 4 <= ncols {
        let s0 = indptr[j];
        let e0 = indptr[j + 1];
        let s1 = e0;
        let e1 = indptr[j + 2];
        let s2 = e1;
        let e2 = indptr[j + 3];
        let s3 = e2;
        let e3 = indptr[j + 4];
        let lmin = (e0 - s0).min(e1 - s1).min(e2 - s2).min(e3 - s3);
        let mut a0 = 0.0f32;
        let mut a1 = 0.0f32;
        let mut a2 = 0.0f32;
        let mut a3 = 0.0f32;
        for k in 0..lmin {
            let x0 = x[rows[s0 + k] as usize];
            if x0 != 0.0 {
                a0 += x0 * vals[s0 + k];
            }
            let x1 = x[rows[s1 + k] as usize];
            if x1 != 0.0 {
                a1 += x1 * vals[s1 + k];
            }
            let x2 = x[rows[s2 + k] as usize];
            if x2 != 0.0 {
                a2 += x2 * vals[s2 + k];
            }
            let x3 = x[rows[s3 + k] as usize];
            if x3 != 0.0 {
                a3 += x3 * vals[s3 + k];
            }
        }
        out[j] = col_partial(rows, vals, x, s0 + lmin, e0, a0);
        out[j + 1] = col_partial(rows, vals, x, s1 + lmin, e1, a1);
        out[j + 2] = col_partial(rows, vals, x, s2 + lmin, e2, a2);
        out[j + 3] = col_partial(rows, vals, x, s3 + lmin, e3, a3);
        j += 4;
    }
    while j < ncols {
        out[j] = col_partial(rows, vals, x, indptr[j], indptr[j + 1], 0.0);
        j += 1;
    }
}

/// delta[i] -= eta * (lam * delta[i] + mu[i]) — the SVRG window update,
/// elementwise (no reduction, so no ordering contract beyond matching
/// the scalar expression term-for-term).
#[inline(always)]
fn svrg_delta_body(delta: &mut [f32], mu: &[f32], eta: f32, lam: f32) {
    debug_assert_eq!(delta.len(), mu.len());
    let n = delta.len();
    let (delta, mu) = (&mut delta[..n], &mu[..n]);
    for i in 0..n {
        delta[i] -= eta * (lam * delta[i] + mu[i]);
    }
}

// ---------------------------------------------------------------------
// Scalar entries: the bodies compiled at the crate's baseline feature
// level (SSE2 on x86_64, NEON on aarch64 — both baselines are part of
// the platform ABI, so "scalar" here means "no runtime-detected
// features", not "no vector unit").
// ---------------------------------------------------------------------

pub fn dot_scalar(x: &[f32], y: &[f32]) -> f32 {
    dot_body(x, y)
}

pub fn axpy_scalar(a: f32, x: &[f32], y: &mut [f32]) {
    axpy_body(a, x, y)
}

pub fn scale_scalar(a: f32, x: &mut [f32]) {
    scale_body(a, x)
}

pub fn gemv_scalar(a: &[f32], n: usize, m: usize, x: &[f32], out: &mut [f32]) {
    gemv_body(a, n, m, x, out)
}

pub fn gemv_t_scalar(a: &[f32], n: usize, m: usize, x: &[f32], out: &mut [f32]) {
    gemv_t_body(a, n, m, x, out)
}

pub fn spmv_t_csc_scalar(indptr: &[usize], rows: &[u32], vals: &[f32], x: &[f32], out: &mut [f32]) {
    spmv_t_csc_body(indptr, rows, vals, x, out)
}

pub fn svrg_delta_scalar(delta: &mut [f32], mu: &[f32], eta: f32, lam: f32) {
    svrg_delta_body(delta, mu, eta, lam)
}

// ---------------------------------------------------------------------
// AVX2+FMA entries (x86_64): the SAME bodies recompiled with 256-bit
// codegen.  The `#[target_feature]` fns are unsafe to call on hardware
// without the features; the safe wrappers below are only ever installed
// into a dispatch table after `is_x86_feature_detected!` confirms both.
// ---------------------------------------------------------------------

#[cfg(target_arch = "x86_64")]
mod avx2 {
    use super::*;

    #[target_feature(enable = "avx2", enable = "fma")]
    unsafe fn dot_tf(x: &[f32], y: &[f32]) -> f32 {
        dot_body(x, y)
    }

    #[target_feature(enable = "avx2", enable = "fma")]
    unsafe fn axpy_tf(a: f32, x: &[f32], y: &mut [f32]) {
        axpy_body(a, x, y)
    }

    #[target_feature(enable = "avx2", enable = "fma")]
    unsafe fn scale_tf(a: f32, x: &mut [f32]) {
        scale_body(a, x)
    }

    #[target_feature(enable = "avx2", enable = "fma")]
    unsafe fn gemv_tf(a: &[f32], n: usize, m: usize, x: &[f32], out: &mut [f32]) {
        gemv_body(a, n, m, x, out)
    }

    #[target_feature(enable = "avx2", enable = "fma")]
    unsafe fn gemv_t_tf(a: &[f32], n: usize, m: usize, x: &[f32], out: &mut [f32]) {
        gemv_t_body(a, n, m, x, out)
    }

    #[target_feature(enable = "avx2", enable = "fma")]
    unsafe fn spmv_t_csc_tf(
        indptr: &[usize],
        rows: &[u32],
        vals: &[f32],
        x: &[f32],
        out: &mut [f32],
    ) {
        spmv_t_csc_body(indptr, rows, vals, x, out)
    }

    #[target_feature(enable = "avx2", enable = "fma")]
    unsafe fn svrg_delta_tf(delta: &mut [f32], mu: &[f32], eta: f32, lam: f32) {
        svrg_delta_body(delta, mu, eta, lam)
    }

    // SAFETY (all of the below): these wrappers reach the dispatch table
    // only through `dispatch::detected()`, which installs them strictly
    // after `is_x86_feature_detected!("avx2") && ("fma")` returns true on
    // the running CPU.

    pub fn dot_avx2(x: &[f32], y: &[f32]) -> f32 {
        unsafe { dot_tf(x, y) }
    }

    pub fn axpy_avx2(a: f32, x: &[f32], y: &mut [f32]) {
        unsafe { axpy_tf(a, x, y) }
    }

    pub fn scale_avx2(a: f32, x: &mut [f32]) {
        unsafe { scale_tf(a, x) }
    }

    pub fn gemv_avx2(a: &[f32], n: usize, m: usize, x: &[f32], out: &mut [f32]) {
        unsafe { gemv_tf(a, n, m, x, out) }
    }

    pub fn gemv_t_avx2(a: &[f32], n: usize, m: usize, x: &[f32], out: &mut [f32]) {
        unsafe { gemv_t_tf(a, n, m, x, out) }
    }

    pub fn spmv_t_csc_avx2(
        indptr: &[usize],
        rows: &[u32],
        vals: &[f32],
        x: &[f32],
        out: &mut [f32],
    ) {
        unsafe { spmv_t_csc_tf(indptr, rows, vals, x, out) }
    }

    pub fn svrg_delta_avx2(delta: &mut [f32], mu: &[f32], eta: f32, lam: f32) {
        unsafe { svrg_delta_tf(delta, mu, eta, lam) }
    }
}

#[cfg(target_arch = "x86_64")]
pub use avx2::*;
