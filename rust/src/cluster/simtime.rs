//! The simulated parallel clock.
//!
//! Per-partition compute times are measured for real on this host (each
//! task individually, wherever the persistent worker pool ran it), then
//! scheduled onto `cores` simulated executor slots with the LPT
//! (longest-processing-time-first) heuristic — the makespan is what a
//! Spark stage of that superstep would take.  Under a
//! [`ClusterScenario`](super::ClusterScenario) the slots may be
//! heterogeneous (per-slot speed factors) and per-task costs may carry
//! injected straggler/failure charges.  Communication time comes from the
//! [`super::comm`] cost model.

use super::comm::CommStats;

/// Clamp a task duration for the scheduler: non-finite or negative
/// durations (a pathological cost model, a clock glitch) are treated as
/// free rather than poisoning — or panicking — the schedule.
#[inline]
fn sane_duration(d: f64) -> f64 {
    if d.is_finite() && d > 0.0 {
        d
    } else {
        0.0
    }
}

/// Clamp a slot speed factor: non-finite or non-positive speeds fall back
/// to full speed.
#[inline]
pub(crate) fn sane_speed(s: f64) -> f64 {
    if s.is_finite() && s > 0.0 {
        s
    } else {
        1.0
    }
}

/// LPT makespan of `durations` over `slots` identical machines.
pub fn lpt_makespan(durations: &[f64], slots: usize) -> f64 {
    lpt_makespan_hetero(durations, &vec![1.0; slots.max(1)])
}

/// LPT makespan of `durations` over heterogeneous machines: slot `k`
/// processes work at `speeds[k]` (a task of duration `d` occupies it for
/// `d / speeds[k]`).  Tasks are taken longest-first and greedily assigned
/// to the slot that would finish them earliest.
///
/// With all speeds equal to 1 this is bit-identical to [`lpt_makespan`]
/// (same sort, same tie-breaking, `d / 1.0 == d`).  Non-finite or
/// negative durations are clamped to 0 and non-finite or non-positive
/// speeds to 1, so the result is always finite and the sort never sees a
/// NaN (`f64::total_cmp` is used regardless, so no ordering can panic).
pub fn lpt_makespan_hetero(durations: &[f64], speeds: &[f64]) -> f64 {
    let speeds: Vec<f64> = if speeds.is_empty() {
        vec![1.0]
    } else {
        speeds.iter().map(|&s| sane_speed(s)).collect()
    };
    lpt_makespan_hetero_with(&mut LptScratch::default(), durations, &speeds)
}

/// Reusable working memory for [`lpt_makespan_hetero_with`] — lets the
/// per-superstep schedule run without allocating once warmed up.
#[derive(Debug, Default)]
pub struct LptScratch {
    sorted: Vec<f64>,
    loads: Vec<f64>,
}

/// [`lpt_makespan_hetero`] with caller-owned scratch and *pre-sanitized*
/// speeds (non-empty, every entry finite and positive — the caller clamps
/// once with `sane_speed`; `SimCluster` caches that).  Bit-identical to
/// [`lpt_makespan_hetero`]: same greedy assignment, same tie-breaking, and
/// the unstable sort only permutes equal durations, which cannot change
/// any load sum.
pub fn lpt_makespan_hetero_with(
    scratch: &mut LptScratch,
    durations: &[f64],
    speeds: &[f64],
) -> f64 {
    if durations.is_empty() {
        return 0.0;
    }
    if speeds.is_empty() {
        // stay total like lpt_makespan_hetero: no slots = one unit slot
        return lpt_makespan_hetero_with(scratch, durations, &[1.0]);
    }
    debug_assert!(speeds.iter().all(|&s| s.is_finite() && s > 0.0));
    scratch.sorted.clear();
    scratch.sorted.extend(durations.iter().map(|&d| sane_duration(d)));
    scratch.sorted.sort_unstable_by(|a, b| b.total_cmp(a));
    scratch.loads.clear();
    scratch.loads.resize(speeds.len(), 0.0);
    let loads = &mut scratch.loads;
    for &d in &scratch.sorted {
        // assign to the slot with the earliest finish time for this task
        let (k, _) = loads
            .iter()
            .zip(speeds)
            .map(|(&load, &speed)| load + d / speed)
            .enumerate()
            .min_by(|a, b| a.1.total_cmp(&b.1))
            .unwrap();
        loads[k] += d / speeds[k];
    }
    loads.iter().fold(0.0, |m, &l| f64::max(m, l))
}

/// Accumulated simulated time, split by source.
#[derive(Clone, Debug, Default)]
pub struct SimClock {
    compute: f64,
    comm_time: f64,
    comm_bytes: usize,
    messages: usize,
    supersteps: usize,
    stragglers: usize,
    failures: usize,
}

impl SimClock {
    pub fn new() -> SimClock {
        SimClock::default()
    }

    pub fn add_compute(&mut self, makespan: f64) {
        self.compute += makespan;
        self.supersteps += 1;
    }

    pub fn add_comm(&mut self, stats: CommStats) {
        self.comm_time += stats.time;
        self.comm_bytes += stats.bytes;
        self.messages += stats.messages;
    }

    /// Record scenario injections observed in one superstep.
    pub fn add_injections(&mut self, stragglers: usize, failures: usize) {
        self.stragglers += stragglers;
        self.failures += failures;
    }

    /// Total simulated wall time.
    pub fn now(&self) -> f64 {
        self.compute + self.comm_time
    }

    pub fn compute_time(&self) -> f64 {
        self.compute
    }

    pub fn comm_time(&self) -> f64 {
        self.comm_time
    }

    pub fn comm_bytes(&self) -> usize {
        self.comm_bytes
    }

    pub fn messages(&self) -> usize {
        self.messages
    }

    pub fn supersteps(&self) -> usize {
        self.supersteps
    }

    /// Straggler events injected by the active scenario.
    pub fn stragglers(&self) -> usize {
        self.stragglers
    }

    /// Failed task attempts injected by the active scenario.
    pub fn failures(&self) -> usize {
        self.failures
    }

    /// Serialize the clock for a checkpoint.  Times go out by f64 bit
    /// pattern so a resumed run's clock is *bitwise* identical to an
    /// unbroken one, not merely close.
    pub fn encode(&self, buf: &mut Vec<u8>) {
        crate::util::bytes::put_u64(buf, self.compute.to_bits());
        crate::util::bytes::put_u64(buf, self.comm_time.to_bits());
        crate::util::bytes::put_usize(buf, self.comm_bytes);
        crate::util::bytes::put_usize(buf, self.messages);
        crate::util::bytes::put_usize(buf, self.supersteps);
        crate::util::bytes::put_usize(buf, self.stragglers);
        crate::util::bytes::put_usize(buf, self.failures);
    }

    /// Inverse of [`SimClock::encode`]; errors (never panics) on a
    /// truncated buffer.
    pub fn decode(r: &mut crate::util::bytes::ByteReader<'_>) -> anyhow::Result<SimClock> {
        Ok(SimClock {
            compute: f64::from_bits(r.u64()?),
            comm_time: f64::from_bits(r.u64()?),
            comm_bytes: r.usize()?,
            messages: r.usize()?,
            supersteps: r.usize()?,
            stragglers: r.usize()?,
            failures: r.usize()?,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn makespan_single_slot_is_sum() {
        let d = [1.0, 2.0, 3.0];
        assert!((lpt_makespan(&d, 1) - 6.0).abs() < 1e-12);
    }

    #[test]
    fn makespan_enough_slots_is_max() {
        let d = [1.0, 2.0, 3.0];
        assert!((lpt_makespan(&d, 3) - 3.0).abs() < 1e-12);
        assert!((lpt_makespan(&d, 10) - 3.0).abs() < 1e-12);
    }

    #[test]
    fn makespan_lpt_packs_well() {
        // LPT on {3,3,2,2,2} over 2 slots gives 7 (vs optimal 6 — the
        // classic 7/6 ratio witness); on {4,3,3,2,2} it is optimal (7).
        let d = [3.0, 3.0, 2.0, 2.0, 2.0];
        assert!((lpt_makespan(&d, 2) - 7.0).abs() < 1e-12);
        let d2 = [5.0, 4.0, 3.0];
        assert!((lpt_makespan(&d2, 2) - 7.0).abs() < 1e-12);
    }

    #[test]
    fn makespan_monotone_in_slots() {
        let d = [0.5, 1.0, 0.7, 0.3, 0.9, 1.1];
        let mut prev = f64::INFINITY;
        for slots in 1..8 {
            let m = lpt_makespan(&d, slots);
            assert!(m <= prev + 1e-12, "slots {slots}");
            prev = m;
        }
    }

    #[test]
    fn empty_makespan_is_zero() {
        assert_eq!(lpt_makespan(&[], 4), 0.0);
    }

    #[test]
    fn non_finite_durations_do_not_panic_or_poison() {
        // the seed version sorted with partial_cmp().unwrap(): a single
        // NaN paniced the whole simulation
        let d = [1.0, f64::NAN, 2.0, f64::INFINITY, -3.0];
        let m = lpt_makespan(&d, 2);
        assert!(m.is_finite());
        // NaN/inf/negatives clamp to 0: schedule is {1, 2} over 2 slots
        assert!((m - 2.0).abs() < 1e-12);
        let mh = lpt_makespan_hetero(&d, &[f64::NAN, 0.0, -2.0]);
        assert!(mh.is_finite());
    }

    #[test]
    fn hetero_uniform_speeds_match_uniform_lpt() {
        let d = [0.5, 1.0, 0.7, 0.3, 0.9, 1.1, 0.2];
        for slots in 1..6 {
            let a = lpt_makespan(&d, slots);
            let b = lpt_makespan_hetero(&d, &vec![1.0; slots]);
            assert_eq!(a.to_bits(), b.to_bits(), "slots {slots}");
        }
    }

    #[test]
    fn hetero_slow_slot_stretches_single_task() {
        // one task on one half-speed slot takes twice as long
        assert!((lpt_makespan_hetero(&[3.0], &[0.5]) - 6.0).abs() < 1e-12);
        // but with a full-speed slot available, the task goes there
        assert!((lpt_makespan_hetero(&[3.0], &[0.5, 1.0]) - 3.0).abs() < 1e-12);
    }

    #[test]
    fn hetero_prefers_fast_slots() {
        // 4 equal tasks over {1, 0.25}: putting any task on the slow slot
        // costs 4; LPT instead stacks all four on the fast slot (cost 4,
        // tie) — makespan must not exceed the all-fast bound
        let m = lpt_makespan_hetero(&[1.0, 1.0, 1.0, 1.0], &[1.0, 0.25]);
        assert!(m <= 4.0 + 1e-12, "makespan {m}");
        // 2 tasks over {1, 0.5}: one each (1.0 vs 2.0) or both fast (2.0)
        let m2 = lpt_makespan_hetero(&[1.0, 1.0], &[1.0, 0.5]);
        assert!((m2 - 2.0).abs() < 1e-12);
    }

    #[test]
    fn hetero_lower_bounds_hold() {
        let d = [2.0, 1.0, 0.5, 3.0, 0.25];
        let speeds = [1.0, 0.5, 0.25];
        let m = lpt_makespan_hetero(&d, &speeds);
        let smax = 1.0f64;
        let total_d: f64 = d.iter().sum();
        let total_s: f64 = speeds.iter().sum();
        assert!(m >= 3.0 / smax - 1e-12, "max scaled duration bound");
        assert!(m >= total_d / total_s - 1e-12, "total work / total speed bound");
    }

    #[test]
    fn scratch_variant_matches_allocating_lpt() {
        let d = [2.0, 1.0, 0.5, 3.0, 0.25, 0.5, 1.0];
        let mut scratch = LptScratch::default();
        for speeds in [vec![1.0, 1.0], vec![1.0, 0.5, 0.25], vec![0.7]] {
            let a = lpt_makespan_hetero(&d, &speeds);
            let b = lpt_makespan_hetero_with(&mut scratch, &d, &speeds);
            assert_eq!(a.to_bits(), b.to_bits(), "{speeds:?}");
        }
        // reuse across calls with different sizes must not leak state
        let b = lpt_makespan_hetero_with(&mut scratch, &[1.0], &[1.0]);
        assert_eq!(b, 1.0);
    }

    #[test]
    fn clock_accumulates() {
        let mut c = SimClock::new();
        c.add_compute(1.5);
        c.add_compute(0.5);
        c.add_comm(CommStats { time: 0.25, bytes: 100, messages: 3 });
        c.add_injections(2, 1);
        c.add_injections(0, 3);
        assert!((c.now() - 2.25).abs() < 1e-12);
        assert_eq!(c.supersteps(), 2);
        assert_eq!(c.comm_bytes(), 100);
        assert_eq!(c.messages(), 3);
        assert_eq!(c.stragglers(), 2);
        assert_eq!(c.failures(), 4);
    }

    #[test]
    fn clock_round_trips_bitwise() {
        let mut c = SimClock::new();
        c.add_compute(0.1 + 0.2); // a value with an inexact decimal tail
        c.add_comm(CommStats { time: 1.0 / 3.0, bytes: 7, messages: 2 });
        c.add_injections(1, 5);
        let mut buf = Vec::new();
        c.encode(&mut buf);
        let mut r = crate::util::bytes::ByteReader::new(&buf);
        let d = SimClock::decode(&mut r).unwrap();
        assert!(r.is_empty());
        assert_eq!(c.now().to_bits(), d.now().to_bits());
        assert_eq!(c.compute_time().to_bits(), d.compute_time().to_bits());
        assert_eq!(c.comm_time().to_bits(), d.comm_time().to_bits());
        assert_eq!(c.comm_bytes(), d.comm_bytes());
        assert_eq!(c.messages(), d.messages());
        assert_eq!(c.supersteps(), d.supersteps());
        assert_eq!(c.stragglers(), d.stragglers());
        assert_eq!(c.failures(), d.failures());
        // truncated buffers error instead of panicking
        let mut r2 = crate::util::bytes::ByteReader::new(&buf[..buf.len() - 1]);
        assert!(SimClock::decode(&mut r2).is_err());
    }
}
