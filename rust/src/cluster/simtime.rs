//! The simulated parallel clock.
//!
//! Per-partition compute times are measured for real on this host, then
//! scheduled onto `cores` simulated executor slots with the LPT
//! (longest-processing-time-first) heuristic — the makespan is what a
//! Spark stage of that superstep would take.  Communication time comes
//! from the [`super::comm`] cost model.

use super::comm::CommStats;

/// LPT makespan of `durations` over `slots` identical machines.
pub fn lpt_makespan(durations: &[f64], slots: usize) -> f64 {
    if durations.is_empty() {
        return 0.0;
    }
    let slots = slots.max(1);
    let mut sorted = durations.to_vec();
    sorted.sort_by(|a, b| b.partial_cmp(a).unwrap());
    let mut loads = vec![0.0f64; slots.min(sorted.len()).max(1)];
    for d in sorted {
        // assign to least-loaded slot
        let (k, _) = loads
            .iter()
            .enumerate()
            .min_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .unwrap();
        loads[k] += d;
    }
    loads.into_iter().fold(0.0, f64::max)
}

/// Accumulated simulated time, split by source.
#[derive(Clone, Debug, Default)]
pub struct SimClock {
    compute: f64,
    comm_time: f64,
    comm_bytes: usize,
    messages: usize,
    supersteps: usize,
}

impl SimClock {
    pub fn new() -> SimClock {
        SimClock::default()
    }

    pub fn add_compute(&mut self, makespan: f64) {
        self.compute += makespan;
        self.supersteps += 1;
    }

    pub fn add_comm(&mut self, stats: CommStats) {
        self.comm_time += stats.time;
        self.comm_bytes += stats.bytes;
        self.messages += stats.messages;
    }

    /// Total simulated wall time.
    pub fn now(&self) -> f64 {
        self.compute + self.comm_time
    }

    pub fn compute_time(&self) -> f64 {
        self.compute
    }

    pub fn comm_time(&self) -> f64 {
        self.comm_time
    }

    pub fn comm_bytes(&self) -> usize {
        self.comm_bytes
    }

    pub fn messages(&self) -> usize {
        self.messages
    }

    pub fn supersteps(&self) -> usize {
        self.supersteps
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn makespan_single_slot_is_sum() {
        let d = [1.0, 2.0, 3.0];
        assert!((lpt_makespan(&d, 1) - 6.0).abs() < 1e-12);
    }

    #[test]
    fn makespan_enough_slots_is_max() {
        let d = [1.0, 2.0, 3.0];
        assert!((lpt_makespan(&d, 3) - 3.0).abs() < 1e-12);
        assert!((lpt_makespan(&d, 10) - 3.0).abs() < 1e-12);
    }

    #[test]
    fn makespan_lpt_packs_well() {
        // LPT on {3,3,2,2,2} over 2 slots gives 7 (vs optimal 6 — the
        // classic 7/6 ratio witness); on {4,3,3,2,2} it is optimal (7).
        let d = [3.0, 3.0, 2.0, 2.0, 2.0];
        assert!((lpt_makespan(&d, 2) - 7.0).abs() < 1e-12);
        let d2 = [5.0, 4.0, 3.0];
        assert!((lpt_makespan(&d2, 2) - 7.0).abs() < 1e-12);
    }

    #[test]
    fn makespan_monotone_in_slots() {
        let d = [0.5, 1.0, 0.7, 0.3, 0.9, 1.1];
        let mut prev = f64::INFINITY;
        for slots in 1..8 {
            let m = lpt_makespan(&d, slots);
            assert!(m <= prev + 1e-12, "slots {slots}");
            prev = m;
        }
    }

    #[test]
    fn empty_makespan_is_zero() {
        assert_eq!(lpt_makespan(&[], 4), 0.0);
    }

    #[test]
    fn clock_accumulates() {
        let mut c = SimClock::new();
        c.add_compute(1.5);
        c.add_compute(0.5);
        c.add_comm(CommStats { time: 0.25, bytes: 100, messages: 3 });
        assert!((c.now() - 2.25).abs() < 1e-12);
        assert_eq!(c.supersteps(), 2);
        assert_eq!(c.comm_bytes(), 100);
        assert_eq!(c.messages(), 3);
    }
}
