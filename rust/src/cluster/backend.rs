//! The cluster-backend seam: typed superstep op descriptors and the
//! [`ClusterBackend`] trait that lets the coordinators run unchanged on
//! either substrate — the in-process simulated cluster ([`SimBackend`])
//! or the real multi-process TCP runtime
//! ([`DistCluster`](super::dist::DistCluster)).
//!
//! A [`GridOp`] is a *shippable* description of one superstep: which
//! per-partition kernel to run plus the small state payloads it needs
//! (iterates, index streams, sub-block windows).  The training data is
//! **not** part of an op — both substrates keep the staged grid resident
//! (in-process here, cached on the executor processes there), which is
//! the CoCoA/Spark design point the paper builds on.  Where each task
//! writes is a pure function of the task index and the partition
//! geometry ([`GridOp::out_span`]), never of the schedule, so results
//! are bit-identical across thread counts, backends, and executor
//! layouts.
//!
//! The interpreter ([`GridOp::exec_task`]) is the *single* definition of
//! every superstep body: `SimBackend` runs it on the local worker pool
//! through [`SimCluster::grid_step_into`], and the executor server runs
//! the very same function against its cached blocks — sim/dist parity is
//! structural, not coincidental.

use super::{ClusterConfig, SimClock, SimCluster, TaskSlab};
use crate::data::Partitioned;
use crate::loss::Loss;
use crate::metrics::WireRecord;
use crate::obs::{self, Phase, SpanRing, TraceLog};
use crate::runtime::{FactorHandle, StagedGrid};
use anyhow::{anyhow, Result};

/// One superstep, described as data: the kernel to run per grid cell and
/// the (borrowed) driver-side state it consumes.  See the module docs
/// for the layout/determinism contract.
pub enum GridOp<'a> {
    /// D3CA steps 2-4: one local SDCA run per `(p, q)` cell, Δα into the
    /// `qq·n` delta slab.  Task order `(p, q)`.
    Sdca {
        /// Global dual α, length n.
        alpha: &'a [f32],
        /// Global primal w, length m.
        w: &'a [f32],
        /// Concatenated per-task visit streams.
        idx: &'a [i32],
        /// `(start, len)` of task t's stream in `idx`.
        idx_off: &'a [(usize, usize)],
        /// Local SDCA step count per task.
        h: &'a [usize],
        lamn: f32,
        invq: f32,
        beta: f32,
    },
    /// x[p,q]ᵀ·v per cell into the `pp·m` contribution slab (D3CA primal
    /// recovery; `v` is α or the scaled dual update).  Task order `(p, q)`.
    Atx {
        /// Row-space vector, length n.
        v: &'a [f32],
    },
    /// x[p,q]·w_q per cell into the `qq·n` margin slab (RADiSA snapshot
    /// margins).  Task order `(p, q)`.
    Margins {
        /// Global primal w, length m.
        w: &'a [f32],
    },
    /// Loss-gradient pass from margins into the `pp·m` gradient slab
    /// (RADiSA full gradient).  Task order `(p, q)`.
    Grad {
        loss: Loss,
        /// Reduced snapshot margins m̃, length n.
        mt: &'a [f32],
    },
    /// RADiSA steps 4-11: local SVRG on the assigned sub-block window,
    /// updated w_q into the `pp·m` result slab.  Task order `(q, p)`.
    Svrg {
        loss: Loss,
        /// Snapshot w̃, length m (both the start iterate and the anchor).
        w: &'a [f32],
        /// Full snapshot gradient μ̃ (+ λw̃), length m.
        mu: &'a [f32],
        /// Reduced snapshot margins m̃, length n.
        mt: &'a [f32],
        /// Local column window of task t (within its feature partition).
        windows: &'a [(usize, usize)],
        /// Concatenated per-task visit streams.
        idx: &'a [i32],
        /// `(start, len)` of task t's stream in `idx`.
        idx_off: &'a [(usize, usize)],
        /// Inner steps L (0 → one pass over the local rows).
        batch: usize,
        eta: f32,
        lam: f32,
        /// RADiSA-avg's averaging combine "does not wait for stragglers".
        tolerant: bool,
    },
    /// ADMM step 1: graph projection per cell through the cached Cholesky
    /// factor; w_pq into the `pp·m` slab, z_pq into the `qq·n` slab
    /// (the one two-output op).  Task order `(p, q)`.
    AdmmProject {
        /// ŵ inputs, `pp·m` slab layout.
        w_hat: &'a [f32],
        /// ẑ inputs, `qq·n` slab layout.
        z_hat: &'a [f32],
    },
    /// ADMM step 3: hinge prox per *row partition* (pp tasks, not pp·qq)
    /// into the length-n v slab.
    ProxHinge {
        /// Reduced share totals Σ_q c_pq, length n.
        c: &'a [f32],
        rho: f32,
        inv_n: f32,
    },
}

impl<'a> GridOp<'a> {
    /// Short kind label (wire + metrics).
    pub fn name(&self) -> &'static str {
        match self {
            GridOp::Sdca { .. } => "sdca",
            GridOp::Atx { .. } => "atx",
            GridOp::Margins { .. } => "margins",
            GridOp::Grad { .. } => "grad",
            GridOp::Svrg { .. } => "svrg",
            GridOp::AdmmProject { .. } => "admm-project",
            GridOp::ProxHinge { .. } => "prox-hinge",
        }
    }

    /// Tasks in this superstep.
    pub fn n_tasks(&self, part: &Partitioned) -> usize {
        match self {
            GridOp::ProxHinge { .. } => part.grid.p,
            _ => part.grid.p * part.grid.q,
        }
    }

    /// Whether the superstep's combine admits stragglers (see
    /// [`StepPlan::mark_tolerant`](super::StepPlan::mark_tolerant)).
    pub fn tolerant(&self) -> bool {
        matches!(self, GridOp::Svrg { tolerant: true, .. })
    }

    /// Flat grid cell a task index maps to (`p·qq + q`); for
    /// [`GridOp::ProxHinge`] — which has no single cell — the first cell
    /// of its row partition.  This is what executor ownership is keyed on.
    pub fn cell(&self, part: &Partitioned, task: usize) -> usize {
        let (pp, qq) = (part.grid.p, part.grid.q);
        match self {
            GridOp::Svrg { .. } => {
                let (q, p) = (task / pp, task % pp);
                p * qq + q
            }
            GridOp::ProxHinge { .. } => task * qq,
            _ => task,
        }
    }

    /// Which of `n_execs` executors runs task `task`.  Keyed on the flat
    /// grid cell ([`GridOp::cell`]) through the active [`Ownership`]
    /// layout, so an executor always owns the blocks its tasks touch.
    /// Under [`Ownership::RoundRobin`], [`GridOp::ProxHinge`] tasks only
    /// need the labels — which every executor holds — so they round-robin
    /// over the row index directly for balance (the legacy keying the
    /// full-broadcast wire mode keeps).
    pub fn owner(
        &self,
        part: &Partitioned,
        task: usize,
        n_execs: usize,
        ownership: Ownership,
    ) -> usize {
        let n = n_execs.max(1);
        match (self, ownership) {
            (GridOp::ProxHinge { .. }, Ownership::RoundRobin) => task % n,
            _ => ownership.owner(self.cell(part, task), part.grid.k(), n),
        }
    }

    /// Which axis of the segment-combine tree this op's gathered slab is
    /// reduced over ([`ClusterBackend::reduce_segments`] call sites in the
    /// coordinators) — what decides whether executors may pre-fold their
    /// locally-owned subtrees before replying.  ADMM's projection outputs
    /// are reduced only after driver-side modification (ŵ = w_loc + u),
    /// so they carry no fold axis.
    pub fn fold_axis(&self) -> FoldAxis {
        match self {
            GridOp::Sdca { .. } | GridOp::Margins { .. } => FoldAxis::OverQ,
            GridOp::Atx { .. } | GridOp::Grad { .. } => FoldAxis::OverP,
            _ => FoldAxis::None,
        }
    }

    /// Reduce-tree geometry of `task`'s combine group, for ops with a
    /// [`FoldAxis`]: the group's `reduce_segments(slab, base, stride,
    /// count, len)` arguments plus which leaf of that group the task is
    /// and the task-index stride between adjacent leaves.
    pub fn fold_group(&self, part: &Partitioned, task: usize) -> Option<FoldGroup> {
        let (pp, qq) = (part.grid.p, part.grid.q);
        let (p, q) = (task / qq, task % qq);
        match self.fold_axis() {
            FoldAxis::OverQ => {
                let (r0, r1) = part.row_ranges[p];
                Some(FoldGroup {
                    base: qq * r0,
                    stride: r1 - r0,
                    count: qq,
                    len: r1 - r0,
                    leaf: q,
                    task_stride: 1,
                })
            }
            FoldAxis::OverP => {
                let (c0, c1) = part.col_ranges[q];
                Some(FoldGroup {
                    base: c0,
                    stride: part.m,
                    count: pp,
                    len: c1 - c0,
                    leaf: p,
                    task_stride: qq,
                })
            }
            FoldAxis::None => None,
        }
    }

    /// Coalesced global *row* ranges the given tasks read row-indexed
    /// state from (Sdca `alpha`, Atx `v`, Grad/Svrg `mt`, ProxHinge `c`)
    /// — what the sliced scatter ships instead of the full vector.
    pub fn read_row_ranges(&self, part: &Partitioned, tasks: &[usize]) -> Vec<(usize, usize)> {
        let qq = part.grid.q;
        let mut marked = vec![false; part.grid.p];
        for &t in tasks {
            marked[self.cell(part, t) / qq] = true;
        }
        coalesce_marked(&marked, &part.row_ranges)
    }

    /// Coalesced global *column* ranges the given tasks read col-indexed
    /// state from (Sdca/Margins/Svrg `w`, Svrg `mu`).
    pub fn read_col_ranges(&self, part: &Partitioned, tasks: &[usize]) -> Vec<(usize, usize)> {
        let qq = part.grid.q;
        let mut marked = vec![false; qq];
        for &t in tasks {
            marked[self.cell(part, t) % qq] = true;
        }
        coalesce_marked(&marked, &part.col_ranges)
    }

    /// Coalesced `(start, len)` ranges of the given (ascending) tasks'
    /// primary-output spans — the slices of a slab-shaped *input* an
    /// executor needs when the op reads where it writes (AdmmProject's
    /// ŵ).
    pub fn out_span_ranges(&self, part: &Partitioned, tasks: &[usize]) -> Vec<(usize, usize)> {
        coalesce_spans(tasks.iter().map(|&t| self.out_span(part, t)))
    }

    /// Like [`GridOp::out_span_ranges`] for the secondary output slab
    /// (AdmmProject's ẑ).
    pub fn out2_span_ranges(&self, part: &Partitioned, tasks: &[usize]) -> Vec<(usize, usize)> {
        coalesce_spans(tasks.iter().map(|&t| self.out2_span(part, t)))
    }

    /// Total primary-output slab length.
    pub fn out_len(&self, part: &Partitioned) -> usize {
        match self {
            GridOp::Sdca { .. } | GridOp::Margins { .. } => part.grid.q * part.n,
            GridOp::Atx { .. }
            | GridOp::Grad { .. }
            | GridOp::Svrg { .. }
            | GridOp::AdmmProject { .. } => part.grid.p * part.m,
            GridOp::ProxHinge { .. } => part.n,
        }
    }

    /// Total secondary-output slab length (0 for single-output ops).
    pub fn out2_len(&self, part: &Partitioned) -> usize {
        match self {
            GridOp::AdmmProject { .. } => part.grid.q * part.n,
            _ => 0,
        }
    }

    /// `(start, len)` of task `task`'s segment in the primary output
    /// slab.  Derived from the task index and partition geometry alone.
    pub fn out_span(&self, part: &Partitioned, task: usize) -> (usize, usize) {
        let (pp, qq) = (part.grid.p, part.grid.q);
        let m = part.m;
        match self {
            GridOp::Sdca { .. } | GridOp::Margins { .. } => {
                let (p, q) = (task / qq, task % qq);
                let (r0, r1) = part.row_ranges[p];
                // Σ_{p'<p} qq·n_p' = qq·r0: group p starts at qq·r0
                (qq * r0 + q * (r1 - r0), r1 - r0)
            }
            GridOp::Atx { .. } | GridOp::Grad { .. } | GridOp::AdmmProject { .. } => {
                let (p, q) = (task / qq, task % qq);
                let (c0, c1) = part.col_ranges[q];
                (p * m + c0, c1 - c0)
            }
            GridOp::Svrg { .. } => {
                let (q, p) = (task / pp, task % pp);
                let (c0, c1) = part.col_ranges[q];
                (pp * c0 + p * (c1 - c0), c1 - c0)
            }
            GridOp::ProxHinge { .. } => {
                let (r0, r1) = part.row_ranges[task];
                (r0, r1 - r0)
            }
        }
    }

    /// `(start, len)` of task `task`'s segment in the secondary output
    /// slab (`(0, 0)` for single-output ops).
    pub fn out2_span(&self, part: &Partitioned, task: usize) -> (usize, usize) {
        match self {
            GridOp::AdmmProject { .. } => {
                let qq = part.grid.q;
                let (p, q) = (task / qq, task % qq);
                let (r0, r1) = part.row_ranges[p];
                (qq * r0 + q * (r1 - r0), r1 - r0)
            }
            _ => (0, 0),
        }
    }

    /// Run one task of this op against the staged grid, writing into the
    /// task's output span(s).  Both substrates call exactly this.
    ///
    /// # Safety contract
    /// `out`/`out2` must be slabs of at least [`GridOp::out_len`] /
    /// [`GridOp::out2_len`] elements; span disjointness across tasks is
    /// guaranteed by the layout functions above.
    #[allow(clippy::too_many_arguments)]
    pub fn exec_task(
        &self,
        staged: &StagedGrid<'_>,
        factors: &[Option<FactorHandle>],
        task: usize,
        sc: &mut OpScratch,
        out: &TaskSlab<'_, f32>,
        out2: &TaskSlab<'_, f32>,
    ) -> Result<()> {
        let part = staged.part;
        let (pp, qq) = (part.grid.p, part.grid.q);
        let m = part.m;
        let (start, len) = self.out_span(part, task);
        match self {
            GridOp::Sdca { alpha, w, idx, idx_off, h, lamn, invq, beta } => {
                let (p, q) = (task / qq, task % qq);
                let (r0, r1) = part.row_ranges[p];
                let (c0, c1) = part.col_ranges[q];
                let (s, l) = idx_off[task];
                // SAFETY: span derived from the task index alone; spans of
                // distinct tasks are disjoint by construction of out_span.
                let da = unsafe { out.segment(start, len) };
                staged.sdca_epoch_into(
                    p,
                    q,
                    &alpha[r0..r1],
                    &w[c0..c1],
                    &idx[s..s + l],
                    h[task],
                    *lamn,
                    *invq,
                    *beta,
                    da,
                    &mut sc.a,
                    &mut sc.w,
                )
            }
            GridOp::Atx { v } => {
                let (p, q) = (task / qq, task % qq);
                let (r0, r1) = part.row_ranges[p];
                // SAFETY: disjoint spans, see out_span.
                let o = unsafe { out.segment(start, len) };
                staged.atx_into(sc.kernels, p, q, &v[r0..r1], o)
            }
            GridOp::Margins { w } => {
                let (p, q) = (task / qq, task % qq);
                let (c0, c1) = part.col_ranges[q];
                // SAFETY: disjoint spans, see out_span.
                let o = unsafe { out.segment(start, len) };
                staged.margins_into(sc.kernels, p, q, &w[c0..c1], o)
            }
            GridOp::Grad { loss, mt } => {
                let (p, q) = (task / qq, task % qq);
                let (r0, r1) = part.row_ranges[p];
                // SAFETY: disjoint spans, see out_span.
                let o = unsafe { out.segment(start, len) };
                staged.grad_into(*loss, p, q, &mt[r0..r1], part.n, o, &mut sc.psi)
            }
            GridOp::Svrg {
                loss,
                w,
                mu,
                mt,
                windows,
                idx,
                idx_off,
                batch,
                eta,
                lam,
                tolerant: _,
            } => {
                let (q, p) = (task / pp, task % pp);
                let (r0, r1) = part.row_ranges[p];
                let (c0, c1) = part.col_ranges[q];
                let n_p = r1 - r0;
                let l = if *batch == 0 { n_p } else { *batch };
                let window = windows[task];
                let (s, sl) = idx_off[task];
                let wt_q = &w[c0..c1];
                let mu_win = &mu[c0 + window.0..c0 + window.1];
                // SAFETY: disjoint spans, see out_span.
                let o = unsafe { out.segment(start, len) };
                staged.svrg_block_into(
                    *loss,
                    p,
                    q,
                    wt_q,
                    wt_q,
                    mu_win,
                    window,
                    &mt[r0..r1],
                    &idx[s..s + sl],
                    l,
                    *eta,
                    *lam,
                    o,
                    &mut sc.delta,
                )
            }
            GridOp::AdmmProject { w_hat, z_hat } => {
                let (p, q) = (task / qq, task % qq);
                let (c0, c1) = part.col_ranges[q];
                let (z0, zl) = self.out2_span(part, task);
                let factor = factors
                    .get(task)
                    .and_then(|f| f.as_ref())
                    .ok_or_else(|| {
                        anyhow!("admm factor for cell {task} missing (prepare_admm not run?)")
                    })?;
                let wh = &w_hat[p * m + c0..p * m + c1];
                let zh = &z_hat[z0..z0 + zl];
                // SAFETY: both spans derive from the task index alone and
                // are disjoint across tasks.
                let wo = unsafe { out.segment(start, len) };
                let zo = unsafe { out2.segment(z0, zl) };
                staged.admm_project_into(p, q, factor, wh, zh, wo, zo, &mut sc.t)
            }
            GridOp::ProxHinge { c, rho, inv_n } => {
                let p = task;
                let (r0, r1) = part.row_ranges[p];
                // SAFETY: row ranges are disjoint per task.
                let o = unsafe { out.segment(start, len) };
                staged.prox_hinge_into(p, &c[r0..r1], *rho, *inv_n, o)
            }
        }
    }
}

/// How grid cells (and thus tasks) are laid out across executors.
///
/// Round-robin is the legacy layout and the full-broadcast wire mode's
/// default; contiguous ranges are what the folded-gather optimization
/// negotiates (`CAP_CONTIG_FOLD`): an executor's leaves within any one
/// reduce group form a contiguous run, so it can pre-combine aligned
/// subtrees of the segment-combine tree locally before replying.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum Ownership {
    /// `item % n_execs` — interleaved, the PR-5 wire layout.
    #[default]
    RoundRobin,
    /// Balanced contiguous ranges, identical to
    /// [`balanced_ranges`](crate::data::balanced_ranges)`(k, n_execs)`:
    /// the first `k % n` executors own `⌈k/n⌉` items, the rest `⌊k/n⌋`.
    Contiguous,
}

impl Ownership {
    /// Owner of item `i` among `k` items over `n` executors (O(1)).
    pub fn owner(&self, i: usize, k: usize, n: usize) -> usize {
        let n = n.max(1);
        match self {
            Ownership::RoundRobin => i % n,
            Ownership::Contiguous => {
                let big = k % n;
                let small = k / n;
                let threshold = big * (small + 1);
                if i < threshold {
                    i / (small + 1)
                } else {
                    big + (i - threshold) / small.max(1)
                }
            }
        }
    }

    /// Wire encoding of the mode byte carried in the Stage frame.
    pub fn to_u8(self) -> u8 {
        match self {
            Ownership::RoundRobin => 0,
            Ownership::Contiguous => 1,
        }
    }

    pub fn from_u8(v: u8) -> Result<Ownership> {
        Ok(match v {
            0 => Ownership::RoundRobin,
            1 => Ownership::Contiguous,
            other => anyhow::bail!("unknown ownership mode byte {other}"),
        })
    }
}

/// Explicit, rewritable cell→executor-slot placement (wire revision 4).
///
/// [`Ownership`] is a pure *function* of the cell index — perfect while
/// the fleet is static, useless the moment an executor dies for good.
/// A `CellMap` is the same placement reified as a table the driver can
/// rewrite and re-negotiate over the wire (`CellMap` frame): degrade
/// onto the survivors when a peer misses its rejoin budget, rebalance
/// back toward the pure layout when it returns.  Because every
/// [`GridOp`] task output is a pure function of the op and the block
/// data, re-placement never changes results — only who computes them.
///
/// Maps are only used with [`Ownership::Contiguous`] (the negotiated
/// sliced-wire default), where `Ownership::owner` gives the cell owner
/// for *every* op kind, so a pure map is exactly interchangeable with
/// the functional form.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct CellMap {
    slots: Vec<u32>,
}

impl CellMap {
    /// The map matching `ownership` exactly: slot of cell `i` is
    /// `ownership.owner(i, k, n)`.
    pub fn pure(ownership: Ownership, k: usize, n: usize) -> CellMap {
        CellMap { slots: (0..k).map(|i| ownership.owner(i, k, n) as u32).collect() }
    }

    /// The pure layout with every dead slot's cells re-dealt round-robin
    /// (in ascending cell order) across the surviving slots.  With no
    /// dead slots this *is* the pure map.
    pub fn rebalanced(ownership: Ownership, k: usize, n: usize, dead: &[bool]) -> CellMap {
        let mut map = CellMap::pure(ownership, k, n);
        let alive: Vec<u32> =
            (0..n).filter(|&e| !dead.get(e).copied().unwrap_or(false)).map(|e| e as u32).collect();
        if alive.is_empty() || alive.len() == n {
            return map;
        }
        let mut r = 0usize;
        for slot in map.slots.iter_mut() {
            if dead.get(*slot as usize).copied().unwrap_or(false) {
                *slot = alive[r % alive.len()];
                r += 1;
            }
        }
        map
    }

    /// Executor slot owning `cell`.
    pub fn slot(&self, cell: usize) -> usize {
        self.slots[cell] as usize
    }

    /// Number of cells covered.
    pub fn k(&self) -> usize {
        self.slots.len()
    }

    /// True iff this map equals the pure layout for `ownership`.
    pub fn is_pure(&self, ownership: Ownership, n: usize) -> bool {
        let k = self.slots.len();
        self.slots.iter().enumerate().all(|(i, &s)| s as usize == ownership.owner(i, k, n))
    }

    /// Append the slot table to a wire body (`[k: u32][slot: u32]*k`).
    pub fn encode(&self, buf: &mut Vec<u8>) {
        crate::util::bytes::put_u32(buf, self.slots.len() as u32);
        for &s in &self.slots {
            crate::util::bytes::put_u32(buf, s);
        }
    }

    /// Read a slot table written by [`CellMap::encode`]; every slot must
    /// be below `n_execs`.
    pub fn decode(r: &mut crate::util::bytes::ByteReader<'_>, n_execs: usize) -> Result<CellMap> {
        let k = r.u32()? as usize;
        if k > (1 << 24) {
            anyhow::bail!("corrupt cell map: {k} cells is implausible");
        }
        let mut slots = Vec::with_capacity(k);
        for cell in 0..k {
            let s = r.u32()?;
            if s as usize >= n_execs.max(1) {
                anyhow::bail!("corrupt cell map: cell {cell} -> slot {s} of {n_execs} executors");
            }
            slots.push(s);
        }
        Ok(CellMap { slots })
    }
}

/// Which grid axis an op's gathered slab is reduced over (see
/// [`GridOp::fold_axis`]).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FoldAxis {
    /// No segment-combine follows this op's gather.
    None,
    /// Per row partition p, the qq per-cell segments are summed.
    OverQ,
    /// Per feature partition q, the pp per-cell segments are summed.
    OverP,
}

/// One task's position in its segment-combine group (see
/// [`GridOp::fold_group`]).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct FoldGroup {
    /// `reduce_segments` base offset of the group in the output slab.
    pub base: usize,
    /// Element stride between adjacent leaves.
    pub stride: usize,
    /// Leaves in the group.
    pub count: usize,
    /// Elements per leaf segment.
    pub len: usize,
    /// This task's leaf index within the group.
    pub leaf: usize,
    /// Task-index distance between adjacent leaves of the group.
    pub task_stride: usize,
}

/// Merge the ranges of marked partitions into maximal contiguous runs.
fn coalesce_marked(marked: &[bool], ranges: &[(usize, usize)]) -> Vec<(usize, usize)> {
    coalesce_spans(
        marked
            .iter()
            .zip(ranges)
            .filter(|(&m, _)| m)
            .map(|(_, &(a, b))| (a, b - a)),
    )
}

/// Merge an ascending sequence of `(start, len)` spans, joining spans
/// that touch end-to-start.
fn coalesce_spans(spans: impl Iterator<Item = (usize, usize)>) -> Vec<(usize, usize)> {
    let mut out: Vec<(usize, usize)> = Vec::new();
    for (s, l) in spans {
        if l == 0 {
            continue;
        }
        match out.last_mut() {
            Some((ps, pl)) if *ps + *pl == s => *pl += l,
            _ => out.push((s, l)),
        }
    }
    out
}

/// Unified per-worker scratch for every [`GridOp`] kernel — one cell per
/// worker thread, sized once to the largest partition so steady-state
/// supersteps allocate nothing.
pub struct OpScratch {
    /// SDCA local α copy (len max n_p).
    a: Vec<f32>,
    /// SDCA local w copy (len max m_q).
    w: Vec<f32>,
    /// Gradient-pass ψ buffer (capacity max n_p).
    psi: Vec<f32>,
    /// SVRG window δ buffer (capacity max m_q).
    delta: Vec<f32>,
    /// ADMM Cholesky-solve RHS (len max n_p).
    t: Vec<f32>,
    /// Dispatch table for the dense/CSC kernels — resolved once when the
    /// scratch is built (one env/cpuid check per worker, not per task)
    /// and plumbed into every whole-block kernel `exec_task` runs.
    kernels: &'static crate::linalg::KernelDispatch,
    /// Per-worker span recorder, disabled (capacity 0) until a traced
    /// superstep arms it — the tracing-off hot path is one branch.
    spans: SpanRing,
}

impl OpScratch {
    pub fn for_part(part: &Partitioned) -> OpScratch {
        let max_np = (0..part.grid.p).map(|p| part.n_p(p)).max().unwrap_or(0);
        let max_mq = (0..part.grid.q).map(|q| part.m_q(q)).max().unwrap_or(0);
        OpScratch {
            a: vec![0.0; max_np],
            w: vec![0.0; max_mq],
            psi: Vec::with_capacity(max_np),
            delta: Vec::with_capacity(max_mq),
            t: vec![0.0; max_np],
            kernels: crate::linalg::kernels(),
            spans: SpanRing::disabled(),
        }
    }

    /// Arm this worker's span ring (idempotent: a ring that is already
    /// on keeps its storage and identity).
    pub fn enable_tracing(&mut self, cap: usize, slot: u16, worker: u16) {
        if !self.spans.on() {
            self.spans = SpanRing::with_capacity(cap, slot, worker);
        }
    }

    /// Stamp the superstep ordinal subsequent spans belong to.
    pub fn set_trace_step(&mut self, step: u32) {
        self.spans.set_step(step);
    }

    /// Whether the span ring is armed.
    pub fn spans_on(&self) -> bool {
        self.spans.on()
    }

    pub fn spans_mut(&mut self) -> &mut SpanRing {
        &mut self.spans
    }
}

/// The substrate the coordinators program against: typed superstep
/// execution plus the collective/cost surface of the simulated cluster.
///
/// Implementations: [`SimBackend`] (everything in-process, the cluster
/// merely simulated) and [`DistCluster`](super::dist::DistCluster) (real
/// executor processes over TCP; the simulated clock still runs beside
/// the real one so both can be reported).
pub trait ClusterBackend {
    /// "sim" or "dist" — for logs and reports.
    fn label(&self) -> &'static str;

    /// Host worker threads behind `grid_exec` (driver-side for sim).
    fn threads(&self) -> usize;

    /// Bring any lazily-spawned machinery up now, off the clock.
    fn warm_up(&mut self);

    /// One-time sizing of per-worker scratch (and, for the distributed
    /// backend, nothing — executors size theirs when blocks arrive).
    fn prepare(&mut self, staged: &StagedGrid<'_>) -> Result<()>;

    /// Build (or ship the request to build) the cached per-cell ADMM
    /// factorizations — off the clock, mirroring the paper's accounting.
    fn prepare_admm(&mut self, staged: &StagedGrid<'_>) -> Result<()>;

    /// Execute one superstep op; task outputs land in `out`/`out2` at
    /// [`GridOp::out_span`]/[`GridOp::out2_span`].  Advances the simulated
    /// clock exactly like [`SimCluster::grid_step_into`].
    fn grid_exec(
        &mut self,
        staged: &StagedGrid<'_>,
        op: GridOp<'_>,
        out: &mut [f32],
        out2: &mut [f32],
    ) -> Result<()>;

    /// In-place grouped treeAggregate (see [`SimCluster::reduce_segments`]).
    fn reduce_segments(
        &mut self,
        slab: &mut [f32],
        base: usize,
        stride: usize,
        count: usize,
        len: usize,
    );

    /// Data-free reduce charge (see [`SimCluster::reduce_cost`]).
    fn reduce_cost(&mut self, leaves: usize, bytes_per_leaf: usize);

    /// Broadcast charge (see [`SimCluster::broadcast_cost`]).
    fn broadcast_cost(&mut self, bytes: usize, fanout: usize);

    /// The simulated parallel clock (both substrates keep one).
    fn clock(&self) -> &SimClock;

    /// Mutable access to the simulated clock — the checkpoint resume
    /// path restores the saved clock so a resumed run's time accounting
    /// is bitwise identical to an unbroken one.
    fn clock_mut(&mut self) -> &mut SimClock;

    /// Real host seconds since this backend was created.
    fn host_secs(&self) -> f64;

    /// Drain the per-superstep wire log (empty on the sim substrate).
    fn take_wire_log(&mut self) -> Vec<WireRecord> {
        Vec::new()
    }

    /// Turn span tracing on (or off) for subsequent supersteps.  The
    /// default substrate records nothing.
    fn set_trace(&mut self, _enabled: bool) {}

    /// Hand over the accumulated trace log (`None` when tracing was
    /// never enabled).
    fn take_trace(&mut self) -> Option<TraceLog> {
        None
    }

    /// Current values of every registered metric, sorted by name
    /// (histograms surface as `_count`/`_sum` pairs).
    fn metrics_snapshot(&self) -> Vec<(String, f64)> {
        Vec::new()
    }

    /// Orderly teardown (the distributed backend releases its executors).
    fn shutdown(&mut self) -> Result<()> {
        Ok(())
    }
}

/// The in-process substrate: [`SimCluster`] execution with the unified
/// [`OpScratch`] cells and the cached ADMM factors the op interpreter
/// needs.  This is the exact execution the coordinators ran before the
/// backend seam existed — same pool, same clock, same combine order.
pub struct SimBackend {
    pub cluster: SimCluster,
    scratch: Vec<OpScratch>,
    factors: Vec<Option<FactorHandle>>,
    trace: Option<TraceLog>,
}

impl SimBackend {
    pub fn new(config: ClusterConfig) -> SimBackend {
        SimBackend {
            cluster: SimCluster::new(config),
            scratch: Vec::new(),
            factors: Vec::new(),
            trace: None,
        }
    }
}

impl ClusterBackend for SimBackend {
    fn label(&self) -> &'static str {
        "sim"
    }

    fn threads(&self) -> usize {
        self.cluster.threads()
    }

    fn warm_up(&mut self) {
        self.cluster.warm_up();
    }

    fn prepare(&mut self, staged: &StagedGrid<'_>) -> Result<()> {
        let t0 = obs::now_ns();
        let want = self.cluster.threads().max(1);
        self.scratch.clear();
        for _ in 0..want {
            self.scratch.push(OpScratch::for_part(staged.part));
        }
        if self.trace.is_some() {
            // sim records as the driver process (slot 0), one thread row
            // per pool worker
            for (w, sc) in self.scratch.iter_mut().enumerate() {
                sc.enable_tracing(obs::SPAN_RING_CAPACITY, 0, w as u16);
            }
        }
        if let Some(log) = self.trace.as_mut() {
            log.span("prepare", Phase::Stage, 0, 0, 0, 0, t0, obs::now_ns());
        }
        Ok(())
    }

    fn prepare_admm(&mut self, staged: &StagedGrid<'_>) -> Result<()> {
        let part = staged.part;
        self.factors.clear();
        for p in 0..part.grid.p {
            for q in 0..part.grid.q {
                self.factors.push(Some(staged.admm_factor(p, q)?));
            }
        }
        Ok(())
    }

    fn grid_exec(
        &mut self,
        staged: &StagedGrid<'_>,
        op: GridOp<'_>,
        out: &mut [f32],
        out2: &mut [f32],
    ) -> Result<()> {
        let part = staged.part;
        let n = op.n_tasks(part);
        if n > 0 && self.scratch.is_empty() {
            // fail here with a name, not deep in the pool's scratch assert
            return Err(anyhow!("SimBackend::grid_exec before prepare() sized the scratch"));
        }
        debug_assert!(out.len() >= op.out_len(part));
        debug_assert!(out2.len() >= op.out2_len(part));
        let SimBackend { cluster, scratch, factors, trace } = self;
        let tracing = trace.is_some();
        if tracing {
            let step = cluster.clock.supersteps() as u32;
            for sc in scratch.iter_mut() {
                sc.set_trace_step(step);
            }
        }
        let out_slab = TaskSlab::new(out);
        let out2_slab = TaskSlab::new(out2);
        let op_ref = &op;
        let factors_ref: &[Option<FactorHandle>] = factors;
        cluster.grid_step_into(n, op.tolerant(), scratch, |task, sc| {
            let t0 = if tracing { obs::now_ns() } else { 0 };
            let r = op_ref.exec_task(staged, factors_ref, task, sc, &out_slab, &out2_slab);
            if tracing {
                let t1 = obs::now_ns();
                sc.spans_mut().push_span(
                    op_ref.name(),
                    Phase::Exec,
                    task as u32,
                    task as u32 + 1,
                    t0,
                    t1,
                );
            }
            r
        })?;
        if let Some(log) = trace.as_mut() {
            for sc in scratch.iter_mut() {
                log.absorb(sc.spans_mut());
            }
        }
        Ok(())
    }

    fn reduce_segments(
        &mut self,
        slab: &mut [f32],
        base: usize,
        stride: usize,
        count: usize,
        len: usize,
    ) {
        let t0 = if self.trace.is_some() { obs::now_ns() } else { 0 };
        self.cluster.reduce_segments(slab, base, stride, count, len);
        if let Some(log) = self.trace.as_mut() {
            let step = self.cluster.clock.supersteps() as u32;
            log.span("reduce", Phase::Combine, step, 0, 0, count as u32, t0, obs::now_ns());
        }
    }

    fn reduce_cost(&mut self, leaves: usize, bytes_per_leaf: usize) {
        self.cluster.reduce_cost(leaves, bytes_per_leaf);
    }

    fn broadcast_cost(&mut self, bytes: usize, fanout: usize) {
        self.cluster.broadcast_cost(bytes, fanout);
    }

    fn clock(&self) -> &SimClock {
        &self.cluster.clock
    }

    fn clock_mut(&mut self) -> &mut SimClock {
        &mut self.cluster.clock
    }

    fn host_secs(&self) -> f64 {
        self.cluster.host_secs()
    }

    fn set_trace(&mut self, enabled: bool) {
        if enabled {
            if self.trace.is_none() {
                self.trace = Some(TraceLog::with_capacity(obs::TRACE_LOG_CAPACITY));
            }
            // scratch may already be sized (set_trace after prepare):
            // arm whatever rings exist now; prepare arms any rebuilt ones
            for (w, sc) in self.scratch.iter_mut().enumerate() {
                sc.enable_tracing(obs::SPAN_RING_CAPACITY, 0, w as u16);
            }
        } else {
            self.trace = None;
        }
    }

    fn take_trace(&mut self) -> Option<TraceLog> {
        self.trace.take()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{Grid, SyntheticDense};
    use crate::runtime::Backend;

    fn fixture() -> (crate::data::Dataset, Grid) {
        (SyntheticDense::paper_part1(2, 3, 14, 9, 0.1, 5).build(), Grid::new(2, 3))
    }

    #[test]
    fn pure_cell_map_matches_functional_ownership() {
        for own in [Ownership::RoundRobin, Ownership::Contiguous] {
            for (k, n) in [(6usize, 3usize), (7, 3), (4, 1), (5, 8)] {
                let map = CellMap::pure(own, k, n);
                assert_eq!(map.k(), k);
                assert!(map.is_pure(own, n));
                for i in 0..k {
                    assert_eq!(map.slot(i), own.owner(i, k, n), "{own:?} k={k} n={n} cell {i}");
                }
            }
        }
    }

    #[test]
    fn rebalanced_map_redeal_is_balanced_and_survivor_only() {
        let own = Ownership::Contiguous;
        let (k, n) = (8usize, 4usize);
        let dead = vec![false, true, false, true];
        let map = CellMap::rebalanced(own, k, n, &dead);
        assert!(!map.is_pure(own, n));
        let mut counts = vec![0usize; n];
        for i in 0..k {
            let s = map.slot(i);
            assert!(!dead[s], "cell {i} mapped to dead slot {s}");
            counts[s] += 1;
            // surviving owners keep their pure cells untouched
            if !dead[own.owner(i, k, n)] {
                assert_eq!(s, own.owner(i, k, n));
            }
        }
        // 4 orphans re-dealt round-robin over the 2 survivors: 2 each
        assert_eq!(counts, vec![4, 0, 4, 0]);
        // no dead slots => exactly the pure map
        assert_eq!(CellMap::rebalanced(own, k, n, &[false; 4]), CellMap::pure(own, k, n));
    }

    #[test]
    fn cell_map_round_trips_and_rejects_bad_slots() {
        let map = CellMap::rebalanced(Ownership::Contiguous, 7, 3, &[false, true, false]);
        let mut buf = Vec::new();
        map.encode(&mut buf);
        let back =
            CellMap::decode(&mut crate::util::bytes::ByteReader::new(&buf), 3).unwrap();
        assert_eq!(back, map);
        // a slot at or past n_execs must be rejected
        assert!(CellMap::decode(&mut crate::util::bytes::ByteReader::new(&buf), 2).is_err());
        // truncated table
        assert!(
            CellMap::decode(&mut crate::util::bytes::ByteReader::new(&buf[..buf.len() - 2]), 3)
                .is_err()
        );
    }

    #[test]
    fn spans_tile_the_slabs_disjointly_for_every_op() {
        // the out_span/out2_span disjointness asserted here is the whole
        // safety argument for the unsafe concurrent TaskSlab writes in
        // exec_task — every op layout must be covered, on more than one
        // (uneven) grid shape
        for (p, q, n_per, m_per) in [(2usize, 3usize, 14usize, 9usize), (3, 2, 11, 7)] {
            let ds = SyntheticDense::paper_part1(p, q, n_per, m_per, 0.1, 5).build();
            let part = Partitioned::split(&ds, Grid::new(p, q));
            let w = vec![0.0f32; part.m];
            let v = vec![0.0f32; part.n];
            let pairs: Vec<(usize, usize)> = vec![(0, 0); part.grid.k()];
            let h = vec![0usize; part.grid.k()];
            let ops: Vec<GridOp<'_>> = vec![
                GridOp::Sdca {
                    alpha: &v,
                    w: &w,
                    idx: &[],
                    idx_off: &pairs,
                    h: &h,
                    lamn: 1.0,
                    invq: 1.0,
                    beta: 0.0,
                },
                GridOp::Atx { v: &v },
                GridOp::Margins { w: &w },
                GridOp::Grad { loss: Loss::Hinge, mt: &v },
                GridOp::Svrg {
                    loss: Loss::Hinge,
                    w: &w,
                    mu: &w,
                    mt: &v,
                    windows: &pairs,
                    idx: &[],
                    idx_off: &pairs,
                    batch: 1,
                    eta: 0.1,
                    lam: 0.1,
                    tolerant: false,
                },
                GridOp::AdmmProject { w_hat: &w, z_hat: &v },
                GridOp::ProxHinge { c: &v, rho: 0.1, inv_n: 1.0 },
            ];
            for op in &ops {
                let n = op.n_tasks(&part);
                for (which, total) in
                    [("out", op.out_len(&part)), ("out2", op.out2_len(&part))]
                {
                    if total == 0 {
                        continue;
                    }
                    let mut covered = vec![false; total];
                    for task in 0..n {
                        let (s, l) = if which == "out" {
                            op.out_span(&part, task)
                        } else {
                            op.out2_span(&part, task)
                        };
                        assert!(
                            s + l <= total,
                            "{}x{} {} {which} task {task}",
                            p,
                            q,
                            op.name()
                        );
                        for c in &mut covered[s..s + l] {
                            assert!(
                                !*c,
                                "{}x{} {} {which} task {task}: overlapping span",
                                p,
                                q,
                                op.name()
                            );
                            *c = true;
                        }
                    }
                    // every layout tiles its slab completely
                    assert!(
                        covered.iter().all(|&c| c),
                        "{}x{} {} {which}: slab not tiled",
                        p,
                        q,
                        op.name()
                    );
                }
            }
        }
    }

    #[test]
    fn contiguous_ownership_matches_balanced_ranges() {
        use crate::data::balanced_ranges;
        for (k, n) in [(4usize, 3usize), (6, 4), (9, 3), (5, 1), (2, 5), (7, 7)] {
            let ranges = balanced_ranges(k, n);
            for (e, (a, b)) in ranges.iter().enumerate() {
                for i in *a..*b {
                    assert_eq!(
                        Ownership::Contiguous.owner(i, k, n),
                        e,
                        "k={k} n={n} item {i}"
                    );
                }
            }
            for i in 0..k {
                assert_eq!(Ownership::RoundRobin.owner(i, k, n), i % n);
            }
        }
    }

    #[test]
    fn ownership_wire_byte_round_trips() {
        for o in [Ownership::RoundRobin, Ownership::Contiguous] {
            assert_eq!(Ownership::from_u8(o.to_u8()).unwrap(), o);
        }
        assert!(Ownership::from_u8(9).is_err());
    }

    #[test]
    fn contiguous_owners_make_fold_leaves_contiguous() {
        // the folded-gather precondition: under contiguous ownership, the
        // leaves an executor owns within any one combine group form a
        // contiguous run — for both fold axes, on an uneven grid
        for (p, q) in [(2usize, 3usize), (3, 2), (4, 4)] {
            let ds = SyntheticDense::paper_part1(p, q, 7, 5, 0.1, 5).build();
            let part = Partitioned::split(&ds, Grid::new(p, q));
            let v = vec![0.0f32; part.n];
            let w = vec![0.0f32; part.m];
            for op in [GridOp::Atx { v: &v }, GridOp::Margins { w: &w }] {
                for n_execs in 1..=p * q {
                    let n_tasks = op.n_tasks(&part);
                    for e in 0..n_execs {
                        // group leaves owned by e, per group key
                        let mut per_group: std::collections::HashMap<usize, Vec<usize>> =
                            Default::default();
                        for t in 0..n_tasks {
                            if op.owner(&part, t, n_execs, Ownership::Contiguous) == e {
                                let g = op.fold_group(&part, t).unwrap();
                                per_group.entry(g.base).or_default().push(g.leaf);
                            }
                        }
                        for (base, leaves) in per_group {
                            for pair in leaves.windows(2) {
                                assert_eq!(
                                    pair[1],
                                    pair[0] + 1,
                                    "{}x{} {} execs={n_execs} e={e} base={base}",
                                    p,
                                    q,
                                    op.name()
                                );
                            }
                        }
                    }
                }
            }
        }
    }

    #[test]
    fn fold_group_geometry_matches_reduce_call_sites() {
        let (ds, grid) = fixture();
        let part = Partitioned::split(&ds, grid);
        let v = vec![0.0f32; part.n];
        let w = vec![0.0f32; part.m];
        let qq = part.grid.q;
        // OverQ (Sdca/Margins): per p, reduce_segments(base=qq*r0,
        // stride=n_p, count=qq, len=n_p) — see d3ca.rs / radisa.rs
        let op = GridOp::Margins { w: &w };
        for task in 0..op.n_tasks(&part) {
            let (p, q) = (task / qq, task % qq);
            let (r0, r1) = part.row_ranges[p];
            let g = op.fold_group(&part, task).unwrap();
            assert_eq!(
                (g.base, g.stride, g.count, g.len, g.leaf, g.task_stride),
                (qq * r0, r1 - r0, qq, r1 - r0, q, 1)
            );
            // the group's leaf spans are exactly the member tasks' out spans
            assert_eq!(op.out_span(&part, task), (g.base + g.leaf * g.stride, g.len));
        }
        // OverP (Atx/Grad): per q, reduce_segments(base=c0, stride=m,
        // count=pp, len=c1-c0)
        let op = GridOp::Atx { v: &v };
        for task in 0..op.n_tasks(&part) {
            let (p, q) = (task / qq, task % qq);
            let (c0, c1) = part.col_ranges[q];
            let g = op.fold_group(&part, task).unwrap();
            assert_eq!(
                (g.base, g.stride, g.count, g.len, g.leaf, g.task_stride),
                (c0, part.m, part.grid.p, c1 - c0, p, qq)
            );
            assert_eq!(op.out_span(&part, task), (g.base + g.leaf * g.stride, g.len));
        }
        // no fold axis for driver-modified or fold-free ops
        assert_eq!(GridOp::AdmmProject { w_hat: &w, z_hat: &v }.fold_axis(), FoldAxis::None);
        assert_eq!(
            GridOp::ProxHinge { c: &v, rho: 0.1, inv_n: 1.0 }.fold_axis(),
            FoldAxis::None
        );
    }

    #[test]
    fn read_ranges_coalesce_and_cover() {
        let (ds, grid) = fixture();
        let part = Partitioned::split(&ds, grid);
        let v = vec![0.0f32; part.n];
        let w = vec![0.0f32; part.m];
        let op = GridOp::Margins { w: &w };
        // all tasks → one full-span range per axis
        let all: Vec<usize> = (0..op.n_tasks(&part)).collect();
        assert_eq!(op.read_col_ranges(&part, &all), vec![(0, part.m)]);
        assert_eq!(op.read_row_ranges(&part, &all), vec![(0, part.n)]);
        // a single task → exactly its blocks' ranges
        let op = GridOp::Atx { v: &v };
        let t = part.grid.q + 1; // (p=1, q=1) on the 2x3 grid
        let (r0, r1) = part.row_ranges[1];
        assert_eq!(op.read_row_ranges(&part, &[t]), vec![(r0, r1 - r0)]);
        // non-adjacent column partitions stay split
        let op = GridOp::Margins { w: &w };
        let (c0, c1) = part.col_ranges[0];
        let (e0, e1) = part.col_ranges[2];
        assert_eq!(
            op.read_col_ranges(&part, &[0, 2]),
            vec![(c0, c1 - c0), (e0, e1 - e0)]
        );
        // AdmmProject ships its own out spans back in as inputs
        let op = GridOp::AdmmProject { w_hat: &w, z_hat: &v };
        for task in 0..op.n_tasks(&part) {
            let (s, l) = op.out_span(&part, task);
            assert_eq!(op.out_span_ranges(&part, &[task]), vec![(s, l)]);
            let (s2, l2) = op.out2_span(&part, task);
            assert_eq!(op.out2_span_ranges(&part, &[task]), vec![(s2, l2)]);
        }
        // adjacent out spans coalesce (tasks 0..k ascending tile the slab)
        assert_eq!(
            op.out_span_ranges(&part, &all),
            vec![(0, part.grid.p * part.m)]
        );
        assert_eq!(
            op.out2_span_ranges(&part, &all),
            vec![(0, part.grid.q * part.n)]
        );
    }

    #[test]
    fn svrg_task_order_is_q_major() {
        let (ds, grid) = fixture();
        let part = Partitioned::split(&ds, grid);
        let w = vec![0.0f32; part.m];
        let windows = vec![(0usize, 0usize); part.grid.k()];
        let idx_off = vec![(0usize, 0usize); part.grid.k()];
        let op = GridOp::Svrg {
            loss: Loss::Hinge,
            w: &w,
            mu: &w,
            mt: &[],
            windows: &windows,
            idx: &[],
            idx_off: &idx_off,
            batch: 1,
            eta: 0.1,
            lam: 0.1,
            tolerant: false,
        };
        // task 1 is (q=0, p=1): cell p*qq + q = 1*3 + 0 = 3
        assert_eq!(op.cell(&part, 1), 3);
        let (s, _) = op.out_span(&part, 1);
        // p=1's segment within column block 0: pp*c0 + 1*m_q = 0 + m_q
        assert_eq!(s, part.m_q(0));
        assert!(!op.tolerant());
    }

    #[test]
    fn sim_backend_margins_match_staged_grid() {
        let (ds, grid) = fixture();
        let part = Partitioned::split(&ds, grid);
        let backend = Backend::native();
        let staged = backend.stage(&part).unwrap();
        let mut rng = crate::util::rng::Xoshiro::new(3);
        let w: Vec<f32> = (0..part.m).map(|_| rng.range_f32(-1.0, 1.0)).collect();

        let mut sim = SimBackend::new(ClusterConfig::with_cores(4).with_threads(2));
        sim.prepare(&staged).unwrap();
        let op = GridOp::Margins { w: &w };
        let mut out = vec![0.0f32; op.out_len(&part)];
        sim.grid_exec(&staged, GridOp::Margins { w: &w }, &mut out, &mut []).unwrap();
        assert_eq!(sim.clock().supersteps(), 1);

        for p in 0..part.grid.p {
            for q in 0..part.grid.q {
                let (c0, c1) = part.col_ranges[q];
                let expect = staged.margins(p, q, &w[c0..c1]).unwrap();
                let (r0, r1) = part.row_ranges[p];
                let n_p = r1 - r0;
                let s = part.grid.q * r0 + q * n_p;
                for (i, &e) in expect.iter().enumerate() {
                    assert_eq!(e.to_bits(), out[s + i].to_bits(), "p={p} q={q} i={i}");
                }
            }
        }
    }

    #[test]
    fn admm_requires_prepare() {
        let (ds, grid) = fixture();
        let part = Partitioned::split(&ds, grid);
        let backend = Backend::native();
        let staged = backend.stage(&part).unwrap();
        let mut sim = SimBackend::new(ClusterConfig::with_cores(2).with_threads(1));
        sim.prepare(&staged).unwrap();
        let w_hat = vec![0.0f32; part.grid.p * part.m];
        let z_hat = vec![0.0f32; part.grid.q * part.n];
        let op = GridOp::AdmmProject { w_hat: &w_hat, z_hat: &z_hat };
        let mut out = vec![0.0f32; op.out_len(&part)];
        let mut out2 = vec![0.0f32; op.out2_len(&part)];
        let err = sim
            .grid_exec(
                &staged,
                GridOp::AdmmProject { w_hat: &w_hat, z_hat: &z_hat },
                &mut out,
                &mut out2,
            )
            .unwrap_err();
        assert!(err.to_string().contains("prepare_admm"), "{err}");
        sim.prepare_admm(&staged).unwrap();
        sim.grid_exec(
            &staged,
            GridOp::AdmmProject { w_hat: &w_hat, z_hat: &z_hat },
            &mut out,
            &mut out2,
        )
        .unwrap();
    }
}
