//! The superstep plan — the typed unit of work the coordinators hand to
//! [`super::SimCluster::grid_step`].
//!
//! A plan is an ordered list of independent per-partition tasks (one per
//! `(p, q)` cell, usually).  Tasks borrow the staged dataset and the
//! coordinator's current iterate (`'env` closures — no cloning of the
//! training data), return `Result<V>`, and are combined strictly in task
//! order afterwards, which is what keeps runs bit-reproducible regardless
//! of how many worker threads execute them.
//!
//! Thread-safety seam: with the default (native) feature set, tasks are
//! `Send` and the pool runs them on its persistent worker threads (see
//! [`super::pool`] for the epoch handoff and its safety argument).  The
//! `xla` build drops the `Send` bound — PJRT literals and the engine's
//! executable cache are thread-confined — and every plan degrades to
//! inline execution on the driver thread (same results, same simulated
//! clock, no host-level parallelism).

use anyhow::Result;
use std::cell::UnsafeCell;

/// A preallocated output slab shared across the tasks of one superstep.
///
/// The zero-allocation hot path (`SimCluster::grid_step_into`) hands every
/// task a *disjoint* mutable segment of one coordinator-owned buffer
/// instead of letting tasks return freshly allocated vectors.  Because the
/// task closure is a shared `Fn` called concurrently from worker threads,
/// the segments are carved out through interior mutability; disjointness
/// is the caller's contract (`segment` is `unsafe`), and every call site
/// derives its segment purely from the task index, which the pool claims
/// exactly once.
pub struct TaskSlab<'a, T> {
    cells: &'a [UnsafeCell<T>],
}

// SAFETY: a TaskSlab only hands out segments under the caller's
// disjointness contract; with disjoint segments this is exactly
// `&mut [T]` split across threads, which is Sync for T: Send.
unsafe impl<'a, T: Send> Sync for TaskSlab<'a, T> {}

impl<'a, T> TaskSlab<'a, T> {
    pub fn new(buf: &'a mut [T]) -> TaskSlab<'a, T> {
        let len = buf.len();
        // SAFETY: UnsafeCell<T> has the same layout as T, and the unique
        // borrow of `buf` is held by this slab for 'a.
        let cells =
            unsafe { std::slice::from_raw_parts(buf.as_mut_ptr() as *const UnsafeCell<T>, len) };
        TaskSlab { cells }
    }

    pub fn len(&self) -> usize {
        self.cells.len()
    }

    pub fn is_empty(&self) -> bool {
        self.cells.is_empty()
    }

    /// Exclusive view of `[start, start + len)`.
    ///
    /// # Safety
    /// The range must be in bounds and must not overlap any segment (or
    /// `write`) used by a concurrently running task; each task must derive
    /// its ranges from its own task index only.
    #[allow(clippy::mut_from_ref)]
    pub unsafe fn segment(&self, start: usize, len: usize) -> &'a mut [T] {
        debug_assert!(start + len <= self.cells.len());
        std::slice::from_raw_parts_mut(self.cells.as_ptr().add(start) as *mut T, len)
    }

    /// Write one element.
    ///
    /// # Safety
    /// Same disjointness contract as [`TaskSlab::segment`] for index `i`.
    pub unsafe fn write(&self, i: usize, v: T) {
        debug_assert!(i < self.cells.len());
        *self.cells[i].get() = v;
    }
}

/// A boxed superstep task.  `Send` on the default feature set (parallel
/// native execution); `!Send` under `--features xla` (inline fallback).
#[cfg(not(feature = "xla"))]
pub type PlanTask<'env, T> = Box<dyn FnOnce() -> T + Send + 'env>;
#[cfg(feature = "xla")]
pub type PlanTask<'env, T> = Box<dyn FnOnce() -> T + 'env>;

/// How a task's simulated compute cost is determined.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub enum CostModel {
    /// Charge each task its measured host compute time (default) — the
    /// fidelity mode behind the paper-figure scaling curves.
    #[default]
    Measured,
    /// Charge each task a fixed synthetic duration in seconds — makes the
    /// simulated clock bit-identical across `threads` settings and hosts
    /// (used by the determinism tests and reproducible CI runs).
    Fixed(f64),
}

/// One bulk-synchronous superstep: independent fallible tasks whose
/// results come back in task order.
pub struct StepPlan<'env, V> {
    tasks: Vec<PlanTask<'env, Result<V>>>,
    tolerant: bool,
}

impl<'env, V: Send> StepPlan<'env, V> {
    pub fn new() -> Self {
        StepPlan { tasks: Vec::new(), tolerant: false }
    }

    pub fn with_capacity(n: usize) -> Self {
        StepPlan { tasks: Vec::with_capacity(n), tolerant: false }
    }

    /// Mark this superstep straggler-tolerant: its combine admits partial
    /// or slightly-stale contributions (an average, not a concatenation),
    /// so the coordinator "does not wait for stragglers" — under a
    /// [`ClusterScenario`](super::ClusterScenario) the step's makespan
    /// ignores injected straggler delays and failure re-charges (permanent
    /// slot heterogeneity still applies).  A no-op on the ideal scenario.
    pub fn mark_tolerant(&mut self) {
        self.tolerant = true;
    }

    /// Whether this superstep waits for injected stragglers.
    pub fn is_tolerant(&self) -> bool {
        self.tolerant
    }

    /// Append one per-partition task.
    #[cfg(not(feature = "xla"))]
    pub fn task<F>(&mut self, f: F)
    where
        F: FnOnce() -> Result<V> + Send + 'env,
    {
        self.tasks.push(Box::new(f));
    }

    /// Append one per-partition task (inline-execution build).
    #[cfg(feature = "xla")]
    pub fn task<F>(&mut self, f: F)
    where
        F: FnOnce() -> Result<V> + 'env,
    {
        self.tasks.push(Box::new(f));
    }

    pub fn len(&self) -> usize {
        self.tasks.len()
    }

    pub fn is_empty(&self) -> bool {
        self.tasks.is_empty()
    }

    pub(crate) fn into_tasks(self) -> Vec<PlanTask<'env, Result<V>>> {
        self.tasks
    }
}

impl<'env, V: Send> Default for StepPlan<'env, V> {
    fn default() -> Self {
        StepPlan::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn plan_collects_tasks_in_order() {
        let mut plan: StepPlan<'_, usize> = StepPlan::with_capacity(4);
        assert!(plan.is_empty());
        for i in 0..4usize {
            plan.task(move || Ok(i * 10));
        }
        assert_eq!(plan.len(), 4);
        let out: Vec<usize> = plan
            .into_tasks()
            .into_iter()
            .map(|t| t().unwrap())
            .collect();
        assert_eq!(out, vec![0, 10, 20, 30]);
    }

    #[test]
    fn plan_tasks_may_borrow_the_environment() {
        let data = vec![1.0f32, 2.0, 3.0];
        let mut plan: StepPlan<'_, f32> = StepPlan::new();
        for k in 0..3 {
            let d = &data;
            plan.task(move || Ok(d[k] * 2.0));
        }
        let out: Vec<f32> = plan.into_tasks().into_iter().map(|t| t().unwrap()).collect();
        assert_eq!(out, vec![2.0, 4.0, 6.0]);
        assert_eq!(data.len(), 3); // still borrowed-alive
    }

    #[test]
    fn cost_model_default_is_measured() {
        assert_eq!(CostModel::default(), CostModel::Measured);
    }

    #[test]
    fn task_slab_hands_out_disjoint_segments() {
        let mut buf = vec![0.0f32; 12];
        {
            let slab = TaskSlab::new(&mut buf);
            assert_eq!(slab.len(), 12);
            // SAFETY: segments [0,4), [4,8), [8,12) are disjoint.
            let a = unsafe { slab.segment(0, 4) };
            let b = unsafe { slab.segment(4, 4) };
            let c = unsafe { slab.segment(8, 4) };
            a.fill(1.0);
            b.fill(2.0);
            c.fill(3.0);
            unsafe { slab.write(0, 9.0) };
        }
        assert_eq!(buf[0], 9.0);
        assert_eq!(&buf[1..4], &[1.0; 3]);
        assert_eq!(&buf[4..8], &[2.0; 4]);
        assert_eq!(&buf[8..], &[3.0; 4]);
    }

    #[test]
    fn plans_are_blocking_unless_marked() {
        let mut plan: StepPlan<'_, ()> = StepPlan::new();
        assert!(!plan.is_tolerant());
        plan.mark_tolerant();
        assert!(plan.is_tolerant());
    }
}
