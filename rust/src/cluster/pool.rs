//! Worker-thread pool: the "nodes" of the simulated cluster.
//!
//! `threads = 1` executes tasks inline on the caller thread (fully
//! deterministic, the default on this single-core host); `threads > 1`
//! spawns long-lived workers fed over channels.  Either way each task's
//! compute time is measured individually so the simulated clock can
//! schedule them onto the configured executor slots.

use std::sync::mpsc;
use std::sync::{Arc, Mutex};
use std::time::Instant;

type Job = Box<dyn FnOnce() + Send>;

/// A fixed pool of worker threads (possibly zero).
pub struct WorkerPool {
    threads: usize,
    tx: Option<mpsc::Sender<Job>>,
    handles: Vec<std::thread::JoinHandle<()>>,
}

impl WorkerPool {
    pub fn new(threads: usize) -> WorkerPool {
        if threads <= 1 {
            return WorkerPool { threads: 1, tx: None, handles: Vec::new() };
        }
        let (tx, rx) = mpsc::channel::<Job>();
        let rx = Arc::new(Mutex::new(rx));
        let handles = (0..threads)
            .map(|i| {
                let rx = Arc::clone(&rx);
                std::thread::Builder::new()
                    .name(format!("ddopt-worker-{i}"))
                    .spawn(move || loop {
                        let job = {
                            let guard = rx.lock().unwrap();
                            guard.recv()
                        };
                        match job {
                            Ok(job) => job(),
                            Err(_) => break, // sender dropped: shut down
                        }
                    })
                    .expect("spawn worker")
            })
            .collect();
        WorkerPool { threads, tx: Some(tx), handles }
    }

    pub fn threads(&self) -> usize {
        self.threads
    }

    /// Run all tasks; returns `(result, seconds)` per task, in task order.
    pub fn run<T: Send + 'static>(
        &self,
        tasks: Vec<Box<dyn FnOnce() -> T + Send>>,
    ) -> Vec<(T, f64)> {
        let n = tasks.len();
        if self.tx.is_none() || n <= 1 {
            // inline execution
            return tasks
                .into_iter()
                .map(|t| {
                    let t0 = Instant::now();
                    let v = t();
                    (v, t0.elapsed().as_secs_f64())
                })
                .collect();
        }
        let (rtx, rrx) = mpsc::channel::<(usize, T, f64)>();
        for (i, task) in tasks.into_iter().enumerate() {
            let rtx = rtx.clone();
            let job: Job = Box::new(move || {
                let t0 = Instant::now();
                let v = task();
                let dt = t0.elapsed().as_secs_f64();
                let _ = rtx.send((i, v, dt));
            });
            self.tx.as_ref().unwrap().send(job).expect("pool send");
        }
        drop(rtx);
        let mut out: Vec<Option<(T, f64)>> = (0..n).map(|_| None).collect();
        for _ in 0..n {
            let (i, v, dt) = rrx.recv().expect("pool recv");
            out[i] = Some((v, dt));
        }
        out.into_iter().map(|o| o.unwrap()).collect()
    }
}

impl Drop for WorkerPool {
    fn drop(&mut self) {
        self.tx.take(); // close the channel; workers exit their loop
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn inline_pool_runs_in_order() {
        let pool = WorkerPool::new(1);
        let tasks: Vec<Box<dyn FnOnce() -> i32 + Send>> =
            (0..5).map(|i| Box::new(move || i) as _).collect();
        let out = pool.run(tasks);
        assert_eq!(out.iter().map(|(v, _)| *v).collect::<Vec<_>>(), vec![0, 1, 2, 3, 4]);
    }

    #[test]
    fn threaded_pool_preserves_order_and_results() {
        let pool = WorkerPool::new(3);
        let tasks: Vec<Box<dyn FnOnce() -> usize + Send>> = (0..32)
            .map(|i| {
                Box::new(move || {
                    // vary work so completion order scrambles
                    let mut acc = 0usize;
                    for k in 0..(i % 7) * 1000 {
                        acc = acc.wrapping_add(k);
                    }
                    let _ = acc;
                    i * 2
                }) as _
            })
            .collect();
        let out = pool.run(tasks);
        for (i, (v, d)) in out.iter().enumerate() {
            assert_eq!(*v, i * 2);
            assert!(*d >= 0.0);
        }
    }

    #[test]
    fn pool_is_reusable() {
        let pool = WorkerPool::new(2);
        for round in 0..3 {
            let tasks: Vec<Box<dyn FnOnce() -> usize + Send>> =
                (0..4).map(|i| Box::new(move || i + round) as _).collect();
            let out = pool.run(tasks);
            assert_eq!(out.len(), 4);
            assert_eq!(out[0].0, round);
        }
    }

    #[test]
    fn drop_joins_cleanly() {
        let pool = WorkerPool::new(4);
        let tasks: Vec<Box<dyn FnOnce() -> () + Send>> =
            (0..8).map(|_| Box::new(|| ()) as _).collect();
        let _ = pool.run(tasks);
        drop(pool); // must not hang
    }
}
