//! Worker-thread pool: the "nodes" of the simulated cluster — a
//! **persistent worker runtime**.
//!
//! The pool owns long-lived OS worker threads, created once (lazily, on
//! the first parallel superstep, or eagerly via [`WorkerPool::warm_up`])
//! and reused for every superstep until the pool is dropped.  The real
//! systems the simulation models (Spark executors, parameter servers)
//! keep their workers resident across rounds; spawning fresh threads per
//! superstep — what this module did before — charged the hot path a
//! per-round overhead those systems never pay, and broke the
//! zero-allocation steady-state guarantee at `threads > 1`.
//!
//! # The epoch handoff (and why it is safe)
//!
//! Superstep tasks borrow the staged dataset and the coordinator's
//! current iterate (`'env` closures), but persistent workers are
//! `'static` threads, so the borrow cannot be expressed in the type
//! system the way `std::thread::scope` expresses it.  Instead the pool
//! hands work over through a type-erased raw-pointer job fenced by an
//! epoch barrier:
//!
//! 1. The coordinator builds a job struct **on its own stack** holding
//!    shared references to the task closure, the scratch cells, the
//!    timing slab, and the claim counter, and publishes it as a
//!    `(*const (), unsafe fn(*const (), usize))` pair under the pool's
//!    state mutex, bumping the epoch and waking the parked workers.
//! 2. Workers observe the new epoch under the same mutex (so the job
//!    write happens-before any worker's read), run the job — claiming
//!    task indices from a shared atomic counter exactly as the scoped
//!    version did — and decrement a `remaining` latch when done.
//! 3. The coordinator participates as executor slot 0, then **blocks
//!    until `remaining` hits zero** before returning.
//!
//! Step 3 is the whole safety argument: the raw pointer and everything it
//! references outlive the epoch because the publishing call cannot return
//! (or unwind — see the panic paragraph) while any worker may still
//! dereference it, exactly the guarantee `std::thread::scope` provides by
//! joining.  Shareability across threads is enforced at the only two
//! construction sites by the same bounds the scoped version needed
//! (`F: Sync`, `S: Send`, `T: Send`); no `transmute` is involved —
//! lifetime erasure happens through `*const ()` and a monomorphized shim.
//!
//! Steady-state parallel supersteps therefore allocate **nothing**: the
//! handoff is a pointer write + futex wake, not a channel send, and the
//! only allocations the pool ever makes are the one-time bring-up (thread
//! stacks, the shared-state `Arc`) — asserted by
//! `rust/tests/alloc_regression.rs` at `threads ∈ {2, 4}`.
//!
//! Panics do not deadlock the latch: every task runs under
//! `catch_unwind`, workers keep draining the epoch, and the payload with
//! the lowest task index is re-raised on the coordinator thread after the
//! barrier — so a panicking task aborts the run cleanly and the workers
//! stay parked, healthy, and reusable for subsequent supersteps
//! (`rust/tests/pool_lifecycle.rs`).  Dropping the pool flips a shutdown
//! flag and joins the workers.
//!
//! `threads = 1` (or a single task) executes inline on the caller thread
//! with no workers spawned.  Either way each task's compute time is
//! measured individually so the simulated clock can schedule the
//! superstep onto the configured executor slots, and results land at
//! positions derived from the task index alone, so downstream combining
//! is deterministic regardless of scheduling.
//!
//! Under `--features xla` the task type is not `Send` (PJRT literals are
//! thread-confined) and every superstep runs inline — see
//! [`super::superstep::PlanTask`].

use super::superstep::{PlanTask, TaskSlab};
use anyhow::Result;
use std::time::Instant;

#[cfg(not(feature = "xla"))]
use std::any::Any;
#[cfg(not(feature = "xla"))]
use std::cell::UnsafeCell;
#[cfg(not(feature = "xla"))]
use std::panic::{catch_unwind, resume_unwind, AssertUnwindSafe};
#[cfg(not(feature = "xla"))]
use std::sync::atomic::{AtomicUsize, Ordering};
#[cfg(not(feature = "xla"))]
use std::sync::{Arc, Condvar, Mutex, MutexGuard, PoisonError};

/// A fixed-width pool of persistent worker threads (`threads - 1` OS
/// threads plus the calling thread, which always participates as
/// executor slot 0).
pub struct WorkerPool {
    threads: usize,
    #[cfg(not(feature = "xla"))]
    runtime: Runtime,
}

impl WorkerPool {
    pub fn new(threads: usize) -> WorkerPool {
        let threads = threads.max(1);
        WorkerPool {
            threads,
            #[cfg(not(feature = "xla"))]
            runtime: Runtime::new(threads),
        }
    }

    pub fn threads(&self) -> usize {
        self.threads
    }

    /// OS worker threads spawned so far (0 until the first parallel
    /// superstep or [`WorkerPool::warm_up`]; at most `threads - 1` for
    /// the lifetime of the pool — the lifecycle tests assert workers are
    /// never re-spawned).
    pub fn os_threads_spawned(&self) -> usize {
        #[cfg(not(feature = "xla"))]
        {
            self.runtime.spawned.load(Ordering::Relaxed)
        }
        #[cfg(feature = "xla")]
        {
            0
        }
    }

    /// Bring the persistent workers up now (they otherwise spawn lazily
    /// on the first parallel superstep), so a timed run pays the one-time
    /// bring-up — the only allocation the parallel steady state is
    /// allowed — before measurement starts.  No-op at `threads = 1` and
    /// on the inline-only `xla` build.
    pub fn warm_up(&self) {
        #[cfg(not(feature = "xla"))]
        self.runtime.ensure_spawned();
    }

    /// Run all tasks; returns `(result, seconds)` per task, in task order.
    pub fn run<'env, T: Send>(&self, tasks: Vec<PlanTask<'env, T>>) -> Vec<(T, f64)> {
        #[cfg(not(feature = "xla"))]
        {
            let workers = self.threads.min(tasks.len());
            if workers > 1 {
                return self.run_boxed_parallel(tasks, workers);
            }
        }
        tasks
            .into_iter()
            .map(|t| {
                let t0 = Instant::now();
                let v = t();
                (v, t0.elapsed().as_secs_f64())
            })
            .collect()
    }

    /// Zero-allocation fan-out: calls `f(i, scratch)` for every `i` in
    /// `0..n`, writing each task's measured seconds into `times[i]`.
    ///
    /// Unlike [`WorkerPool::run`] there is nothing to box and nothing to
    /// collect — tasks write their outputs into caller-owned slabs (see
    /// [`TaskSlab`]) and each executor reuses one caller-owned scratch
    /// cell.  All `n` tasks run even if one errors (matching `run`'s
    /// collect-then-fail semantics, so the simulated clock charges the
    /// same superstep either way); the error of the lowest task index is
    /// returned, which keeps failure reporting deterministic at any
    /// thread count.  A panicking task likewise lets the epoch finish,
    /// then re-raises the lowest-index payload on this thread.
    ///
    /// `scratch` needs at least `min(threads, n)` cells (one per executor
    /// actually used; the inline path uses only `scratch[0]`).
    #[cfg(not(feature = "xla"))]
    pub fn run_indexed<S: Send>(
        &self,
        n: usize,
        scratch: &mut [S],
        times: &mut [f64],
        f: impl Fn(usize, &mut S) -> Result<()> + Sync,
    ) -> Result<()> {
        assert!(times.len() >= n, "times buffer too small");
        if n == 0 {
            return Ok(());
        }
        assert!(!scratch.is_empty(), "need at least one scratch cell");
        let workers = self.threads.min(n).min(scratch.len());
        if workers > 1 {
            return self.run_indexed_parallel(n, &mut scratch[..workers], times, f);
        }
        run_indexed_inline(n, &mut scratch[0], times, f)
    }

    /// Inline-only `run_indexed` (the `xla` build is thread-confined, so
    /// the `Sync` bound drops away and every superstep runs on the caller
    /// thread).
    #[cfg(feature = "xla")]
    pub fn run_indexed<S: Send>(
        &self,
        n: usize,
        scratch: &mut [S],
        times: &mut [f64],
        f: impl Fn(usize, &mut S) -> Result<()>,
    ) -> Result<()> {
        assert!(times.len() >= n, "times buffer too small");
        if n == 0 {
            return Ok(());
        }
        assert!(!scratch.is_empty(), "need at least one scratch cell");
        run_indexed_inline(n, &mut scratch[0], times, f)
    }

    /// Persistent-worker fan-out for [`WorkerPool::run_indexed`]: each
    /// executor slot owns one scratch cell and claims task indices from a
    /// shared atomic counter.  Allocation-free at steady state.
    #[cfg(not(feature = "xla"))]
    fn run_indexed_parallel<S, F>(
        &self,
        n: usize,
        scratch: &mut [S],
        times: &mut [f64],
        f: F,
    ) -> Result<()>
    where
        S: Send,
        F: Fn(usize, &mut S) -> Result<()> + Sync,
    {
        let workers = scratch.len();
        let next = AtomicUsize::new(0);
        let first_err: Mutex<Option<(usize, anyhow::Error)>> = Mutex::new(None);
        {
            let times_slab = TaskSlab::new(times);
            let job = IndexedJob {
                next: &next,
                n,
                f: &f,
                scratch: scratch.as_mut_ptr(),
                times: &times_slab,
                first_err: &first_err,
                panics: &self.runtime.shared.panics,
            };
            let raw = RawJob {
                data: (&job as *const IndexedJob<'_, S, F>).cast(),
                run: run_indexed_slot::<S, F>,
            };
            // SAFETY: `job` and everything it borrows live on this stack
            // frame and stay valid until `run_epoch` returns, which it
            // only does after every participating worker has drained the
            // epoch (or unwinds after that same barrier).  Cross-thread
            // sharing is sound: `F: Sync`, the scratch cells are `Send`
            // and each executor slot touches only its own cell, and the
            // timing slab hands out disjoint per-index slots.
            unsafe { self.runtime.run_epoch(workers - 1, raw) };
        }
        match first_err.into_inner().unwrap_or_else(PoisonError::into_inner) {
            Some((_, e)) => Err(e),
            None => Ok(()),
        }
    }

    /// Persistent-worker fan-out for [`WorkerPool::run`]: boxed tasks and
    /// their `(result, seconds)` slots are claimed by index exactly once.
    #[cfg(not(feature = "xla"))]
    fn run_boxed_parallel<'env, T: Send>(
        &self,
        tasks: Vec<PlanTask<'env, T>>,
        workers: usize,
    ) -> Vec<(T, f64)> {
        let n = tasks.len();
        let mut cells: Vec<Option<PlanTask<'env, T>>> = tasks.into_iter().map(Some).collect();
        let mut out: Vec<Option<(T, f64)>> = Vec::with_capacity(n);
        out.resize_with(n, || None);
        let next = AtomicUsize::new(0);
        {
            let tasks_slab = TaskSlab::new(&mut cells);
            let out_slab = TaskSlab::new(&mut out);
            let job = BoxedJob {
                next: &next,
                n,
                tasks: &tasks_slab,
                out: &out_slab,
                panics: &self.runtime.shared.panics,
            };
            let raw = RawJob {
                data: (&job as *const BoxedJob<'_, 'env, T>).cast(),
                run: run_boxed_slot::<T>,
            };
            // SAFETY: same epoch barrier as `run_indexed_parallel`; the
            // task and output slabs are `Sync` because their payloads are
            // `Send` (`PlanTask` is `Send + 'env`, `T: Send`), and every
            // index is claimed exactly once via the atomic counter.
            unsafe { self.runtime.run_epoch(workers - 1, raw) };
        }
        out.into_iter().map(|s| s.expect("every task completed")).collect()
    }
}

#[cfg(not(feature = "xla"))]
impl Drop for WorkerPool {
    fn drop(&mut self) {
        self.runtime.shutdown();
    }
}

/// Sequential fallback shared by both feature sets: run every task on the
/// caller thread with one scratch cell, recording per-task seconds and
/// keeping the first (lowest-index) error.
fn run_indexed_inline<S>(
    n: usize,
    scratch: &mut S,
    times: &mut [f64],
    f: impl Fn(usize, &mut S) -> Result<()>,
) -> Result<()> {
    let mut first_err = None;
    for (i, t) in times.iter_mut().take(n).enumerate() {
        let t0 = Instant::now();
        let r = f(i, scratch);
        *t = t0.elapsed().as_secs_f64();
        if let Err(e) = r {
            if first_err.is_none() {
                first_err = Some(e);
            }
        }
    }
    match first_err {
        Some(e) => Err(e),
        None => Ok(()),
    }
}

/// The pre-PR per-superstep scoped fan-out, retained as the "before" side
/// of the spawn-overhead baseline (`ddopt exp perf`): spawns
/// `scratch.len()` fresh OS threads via `std::thread::scope` on *every*
/// call.  Task semantics match [`WorkerPool::run_indexed`] — atomic index
/// claims, per-task timing, lowest-index error — so the before/after pair
/// differs only in dispatch cost.
#[cfg(not(feature = "xla"))]
pub fn run_indexed_scoped<S: Send>(
    n: usize,
    scratch: &mut [S],
    times: &mut [f64],
    f: impl Fn(usize, &mut S) -> Result<()> + Sync,
) -> Result<()> {
    assert!(times.len() >= n, "times buffer too small");
    if n == 0 {
        return Ok(());
    }
    assert!(!scratch.is_empty(), "need at least one scratch cell");
    let workers = n.min(scratch.len());
    if workers <= 1 {
        return run_indexed_inline(n, &mut scratch[0], times, f);
    }
    let next = AtomicUsize::new(0);
    let times_slab = TaskSlab::new(times);
    let first_err: Mutex<Option<(usize, anyhow::Error)>> = Mutex::new(None);
    {
        let (next, times_slab, first_err, f) = (&next, &times_slab, &first_err, &f);
        std::thread::scope(|scope| {
            for s in scratch[..workers].iter_mut() {
                scope.spawn(move || loop {
                    let i = next.fetch_add(1, Ordering::Relaxed);
                    if i >= n {
                        break;
                    }
                    let t0 = Instant::now();
                    let r = f(i, s);
                    // SAFETY: index i was claimed exactly once via the
                    // atomic counter, so no other worker touches slot i.
                    unsafe { times_slab.write(i, t0.elapsed().as_secs_f64()) };
                    if let Err(e) = r {
                        record_lowest(first_err, i, e);
                    }
                });
            }
        });
    }
    match first_err.into_inner().unwrap_or_else(PoisonError::into_inner) {
        Some((_, e)) => Err(e),
        None => Ok(()),
    }
}

// ---------------------------------------------------------------------------
// Persistent runtime internals (native feature set only).
// ---------------------------------------------------------------------------

/// Lowest-task-index panic payload of the epoch in flight.
#[cfg(not(feature = "xla"))]
type PanicSlot = Mutex<Option<(usize, Box<dyn Any + Send>)>>;

/// Keep the entry whose task index is lowest — deterministic propagation
/// (of errors and of panic payloads) at any thread count.
#[cfg(not(feature = "xla"))]
fn record_lowest<T>(slot: &Mutex<Option<(usize, T)>>, i: usize, v: T) {
    let mut s = slot.lock().unwrap_or_else(PoisonError::into_inner);
    let lowest = match s.as_ref() {
        None => true,
        Some((j, _)) => i < *j,
    };
    if lowest {
        *s = Some((i, v));
    }
}

/// A published superstep job: a type-erased pointer to a stack-allocated
/// job struct plus the monomorphized shim that knows its real type.  Valid
/// from epoch publish until the `remaining` latch drains (the coordinator
/// blocks for exactly that window — see the module docs).
#[cfg(not(feature = "xla"))]
#[derive(Clone, Copy)]
struct RawJob {
    data: *const (),
    /// `run(data, slot)` — `slot` is the executor index (caller = 0,
    /// persistent worker w = w + 1) selecting the scratch cell.
    run: unsafe fn(*const (), usize),
}

#[cfg(not(feature = "xla"))]
impl RawJob {
    const NOOP: RawJob = RawJob { data: std::ptr::null(), run: noop_slot };
}

#[cfg(not(feature = "xla"))]
unsafe fn noop_slot(_data: *const (), _slot: usize) {}

/// Epoch + participation + shutdown, guarded by one mutex so a worker can
/// never miss a wakeup.
#[cfg(not(feature = "xla"))]
struct State {
    epoch: u64,
    /// Persistent workers participating in the current epoch; worker `w`
    /// takes part iff `w < active` (executor slot `w + 1`).
    active: usize,
    shutdown: bool,
}

#[cfg(not(feature = "xla"))]
struct Shared {
    state: Mutex<State>,
    /// Workers park here between supersteps.
    start: Condvar,
    /// Participating workers still running the epoch in flight.
    remaining: Mutex<usize>,
    done: Condvar,
    /// The job of the epoch in flight.
    job: UnsafeCell<RawJob>,
    panics: PanicSlot,
    /// Serializes concurrent `run`/`run_indexed` callers (one epoch at a
    /// time; `SimCluster` already guarantees this via `&mut self`, the
    /// lock makes the pool itself sound under bare `&self` use).
    session: Mutex<()>,
}

// SAFETY: the only non-Sync field is the `job` slot.  It is written by at
// most one coordinator at a time (the `session` lock serializes epochs)
// strictly before the epoch bump, under the `state` mutex, and read by
// workers only after observing that bump under the same mutex; the
// coordinator then blocks until the `remaining` latch drains, so reads
// never overlap the next write.  The pointers inside are valid and their
// pointees shareable for exactly that window (bounds at the two
// construction sites: `F: Sync`, `S: Send`, `T: Send`).
#[cfg(not(feature = "xla"))]
unsafe impl Send for Shared {}
#[cfg(not(feature = "xla"))]
unsafe impl Sync for Shared {}

#[cfg(not(feature = "xla"))]
fn lock<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    // A panicking *task* never poisons (it is caught in the shim), but a
    // re-raised payload can poison `session` while unwinding out of
    // `run_epoch`; subsequent supersteps must not care.
    m.lock().unwrap_or_else(PoisonError::into_inner)
}

#[cfg(not(feature = "xla"))]
struct Runtime {
    threads: usize,
    shared: Arc<Shared>,
    handles: Mutex<Vec<std::thread::JoinHandle<()>>>,
    /// Total OS threads ever spawned by this pool (== `threads - 1` after
    /// bring-up, forever — the lifecycle tests pin "no re-spawn").
    spawned: AtomicUsize,
}

#[cfg(not(feature = "xla"))]
impl Runtime {
    fn new(threads: usize) -> Runtime {
        Runtime {
            threads,
            shared: Arc::new(Shared {
                state: Mutex::new(State { epoch: 0, active: 0, shutdown: false }),
                start: Condvar::new(),
                remaining: Mutex::new(0),
                done: Condvar::new(),
                job: UnsafeCell::new(RawJob::NOOP),
                panics: Mutex::new(None),
                session: Mutex::new(()),
            }),
            handles: Mutex::new(Vec::new()),
            spawned: AtomicUsize::new(0),
        }
    }

    /// Spawn the `threads - 1` persistent workers if not yet running.
    fn ensure_spawned(&self) {
        let mut handles = lock(&self.handles);
        if !handles.is_empty() || self.threads <= 1 {
            return;
        }
        handles.reserve(self.threads - 1);
        for w in 0..self.threads - 1 {
            let shared = Arc::clone(&self.shared);
            let handle = std::thread::Builder::new()
                .name(format!("ddopt-worker-{w}"))
                .spawn(move || worker_loop(&shared, w))
                .expect("spawn persistent pool worker");
            handles.push(handle);
            self.spawned.fetch_add(1, Ordering::Relaxed);
        }
    }

    /// Publish `job`, run one epoch across `extra_workers` persistent
    /// workers plus the calling thread (slot 0), and block until every
    /// participant is done.  Re-raises the lowest-index task panic, if
    /// any, after the barrier.
    ///
    /// # Safety
    /// `job.data` must point to a job struct that stays valid — and whose
    /// borrowed contents stay shareable across threads — until this call
    /// returns or unwinds; both happen strictly after the barrier.
    unsafe fn run_epoch(&self, extra_workers: usize, job: RawJob) {
        let _session = lock(&self.shared.session);
        self.ensure_spawned();
        // Never wait on more workers than actually came up: if bring-up
        // partially failed (thread spawn limit) the epoch degrades to
        // fewer participants — less parallelism, never a hung latch.
        // The claim loop covers every task at any participant count.
        let extra_workers = extra_workers.min(self.spawned.load(Ordering::Relaxed));
        *lock(&self.shared.remaining) = extra_workers;
        {
            let mut st = lock(&self.shared.state);
            // Publish before bumping the epoch: workers read the slot
            // only after observing the bump under this same mutex.
            unsafe { *self.shared.job.get() = job };
            st.epoch += 1;
            st.active = extra_workers;
            self.shared.start.notify_all();
        }
        // The caller is executor slot 0 — it does its share of the
        // claiming instead of blocking idle.  The shim catches task
        // panics, so this call never unwinds past the barrier below.
        unsafe { (job.run)(job.data, 0) };
        let mut rem = lock(&self.shared.remaining);
        while *rem > 0 {
            rem = self.shared.done.wait(rem).unwrap_or_else(PoisonError::into_inner);
        }
        drop(rem);
        let payload = lock(&self.shared.panics).take();
        if let Some((_, payload)) = payload {
            resume_unwind(payload);
        }
    }

    fn shutdown(&self) {
        {
            let mut st = lock(&self.shared.state);
            st.shutdown = true;
            self.shared.start.notify_all();
        }
        for handle in lock(&self.handles).drain(..) {
            let _ = handle.join();
        }
    }
}

/// The persistent worker body: park on the epoch condvar, run every epoch
/// this worker participates in, decrement the latch, repeat until
/// shutdown.
#[cfg(not(feature = "xla"))]
fn worker_loop(shared: &Shared, w: usize) {
    let mut seen = 0u64;
    loop {
        let job = {
            let mut st = lock(&shared.state);
            loop {
                if st.shutdown {
                    return;
                }
                if st.epoch != seen {
                    break;
                }
                st = shared.start.wait(st).unwrap_or_else(PoisonError::into_inner);
            }
            seen = st.epoch;
            if w >= st.active {
                // Not part of this superstep (fewer tasks than workers);
                // back to the condvar without touching the job or latch.
                continue;
            }
            // SAFETY: the epoch in flight was observed under the state
            // mutex, so the job slot write happens-before this read, and
            // the coordinator keeps the pointee alive until this worker
            // decrements `remaining` below.
            unsafe { *shared.job.get() }
        };
        // SAFETY: per the run_epoch contract the job data is valid and
        // shareable for the whole epoch; slot w + 1 is unique to this
        // worker (slot 0 is the coordinator).
        unsafe { (job.run)(job.data, w + 1) };
        let mut rem = lock(&shared.remaining);
        *rem -= 1;
        if *rem == 0 {
            shared.done.notify_one();
        }
    }
}

/// `run_indexed`'s stack-published job: shared closure, per-slot scratch,
/// disjoint timing slots, claim counter, and the error/panic sinks.
#[cfg(not(feature = "xla"))]
struct IndexedJob<'a, S, F> {
    next: &'a AtomicUsize,
    n: usize,
    f: &'a F,
    /// Base of the scratch cells; executor slot `k` owns cell `k`.
    scratch: *mut S,
    times: &'a TaskSlab<'a, f64>,
    first_err: &'a Mutex<Option<(usize, anyhow::Error)>>,
    panics: &'a PanicSlot,
}

/// Monomorphized shim executed by every participant of a `run_indexed`
/// epoch.
///
/// # Safety
/// `data` must point to a live `IndexedJob<S, F>` for the duration of the
/// call, and `slot` must be a unique executor index within
/// `0..scratch-cell count` for this epoch.
#[cfg(not(feature = "xla"))]
unsafe fn run_indexed_slot<S, F>(data: *const (), slot: usize)
where
    S: Send,
    F: Fn(usize, &mut S) -> Result<()> + Sync,
{
    let job = unsafe { &*data.cast::<IndexedJob<'_, S, F>>() };
    // SAFETY: executor slot `slot` owns scratch cell `slot` exclusively
    // for the whole epoch (caller = 0, persistent worker w = w + 1).
    let scratch = unsafe { &mut *job.scratch.add(slot) };
    loop {
        let i = job.next.fetch_add(1, Ordering::Relaxed);
        if i >= job.n {
            break;
        }
        let t0 = Instant::now();
        let r = catch_unwind(AssertUnwindSafe(|| (job.f)(i, &mut *scratch)));
        // SAFETY: index i was claimed exactly once via the atomic
        // counter, so no other executor touches timing slot i.
        unsafe { job.times.write(i, t0.elapsed().as_secs_f64()) };
        match r {
            Ok(Ok(())) => {}
            Ok(Err(e)) => record_lowest(job.first_err, i, e),
            Err(payload) => record_lowest(job.panics, i, payload),
        }
    }
}

/// `run`'s stack-published job: boxed tasks consumed by claimed index,
/// results written to the matching output slot.
#[cfg(not(feature = "xla"))]
struct BoxedJob<'a, 'env, T> {
    next: &'a AtomicUsize,
    n: usize,
    tasks: &'a TaskSlab<'a, Option<PlanTask<'env, T>>>,
    out: &'a TaskSlab<'a, Option<(T, f64)>>,
    panics: &'a PanicSlot,
}

/// Monomorphized shim executed by every participant of a `run` epoch.
///
/// # Safety
/// `data` must point to a live `BoxedJob<T>` for the duration of the call.
#[cfg(not(feature = "xla"))]
unsafe fn run_boxed_slot<T: Send>(data: *const (), _slot: usize) {
    let job = unsafe { &*data.cast::<BoxedJob<'_, '_, T>>() };
    loop {
        let i = job.next.fetch_add(1, Ordering::Relaxed);
        if i >= job.n {
            break;
        }
        // SAFETY: index i was claimed exactly once via the atomic
        // counter, so this executor has exclusive access to task cell i
        // and output slot i.
        let task = unsafe { job.tasks.segment(i, 1) }[0].take().expect("task claimed once");
        let t0 = Instant::now();
        match catch_unwind(AssertUnwindSafe(move || task())) {
            Ok(v) => unsafe { job.out.write(i, Some((v, t0.elapsed().as_secs_f64()))) },
            Err(payload) => record_lowest(job.panics, i, payload),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn boxed<T, F>(fs: Vec<F>) -> Vec<PlanTask<'static, T>>
    where
        T: Send + 'static,
        F: FnOnce() -> T + Send + 'static,
    {
        fs.into_iter()
            .map(|f| Box::new(f) as PlanTask<'static, T>)
            .collect()
    }

    #[test]
    fn inline_pool_runs_in_order() {
        let pool = WorkerPool::new(1);
        let out = pool.run(boxed((0..5).map(|i| move || i).collect::<Vec<_>>()));
        assert_eq!(out.iter().map(|(v, _)| *v).collect::<Vec<_>>(), vec![0, 1, 2, 3, 4]);
    }

    #[test]
    fn threaded_pool_preserves_order_and_results() {
        let pool = WorkerPool::new(3);
        let tasks = (0..32usize)
            .map(|i| {
                move || {
                    // vary work so completion order scrambles
                    let mut acc = 0usize;
                    for k in 0..(i % 7) * 1000 {
                        acc = acc.wrapping_add(k);
                    }
                    let _ = acc;
                    i * 2
                }
            })
            .collect::<Vec<_>>();
        let out = pool.run(boxed(tasks));
        for (i, (v, d)) in out.iter().enumerate() {
            assert_eq!(*v, i * 2);
            assert!(*d >= 0.0);
        }
    }

    #[test]
    fn tasks_may_borrow_caller_state() {
        let data: Vec<usize> = (0..16).collect();
        let pool = WorkerPool::new(4);
        let tasks: Vec<PlanTask<'_, usize>> = data
            .iter()
            .map(|v| Box::new(move || *v + 1) as PlanTask<'_, usize>)
            .collect();
        let out = pool.run(tasks);
        for (i, (v, _)) in out.iter().enumerate() {
            assert_eq!(*v, data[i] + 1);
        }
    }

    #[test]
    fn pool_is_reusable() {
        let pool = WorkerPool::new(2);
        for round in 0..3usize {
            let tasks = (0..4usize).map(|i| move || i + round).collect::<Vec<_>>();
            let out = pool.run(boxed(tasks));
            assert_eq!(out.len(), 4);
            assert_eq!(out[0].0, round);
        }
    }

    #[test]
    fn run_indexed_writes_disjoint_slabs_at_any_width() {
        for threads in [1usize, 3, 8] {
            let pool = WorkerPool::new(threads);
            let n = 17usize;
            let seg = 4usize;
            let mut out = vec![0.0f32; n * seg];
            let mut times = vec![0.0f64; n];
            let mut scratch: Vec<Vec<f32>> = (0..pool.threads()).map(|_| vec![0.0; seg]).collect();
            {
                let slab = TaskSlab::new(&mut out);
                pool.run_indexed(n, &mut scratch, &mut times, |i, s: &mut Vec<f32>| {
                    for (k, v) in s.iter_mut().enumerate() {
                        *v = (i * seg + k) as f32;
                    }
                    // SAFETY: segment i is owned by task i alone.
                    let dst = unsafe { slab.segment(i * seg, seg) };
                    dst.copy_from_slice(s);
                    Ok(())
                })
                .unwrap();
            }
            for (k, v) in out.iter().enumerate() {
                assert_eq!(*v, k as f32, "threads={threads} slot {k}");
            }
            assert!(times.iter().all(|&t| t >= 0.0));
        }
    }

    #[test]
    fn run_indexed_reports_lowest_index_error_and_runs_all() {
        let pool = WorkerPool::new(4);
        let n = 9usize;
        let mut done = vec![0u8; n];
        let mut times = vec![0.0f64; n];
        let mut scratch = vec![(); 4];
        let err = {
            let slab = TaskSlab::new(&mut done);
            pool.run_indexed(n, &mut scratch, &mut times, |i, _s| {
                unsafe { slab.write(i, 1) };
                if i == 3 || i == 6 {
                    anyhow::bail!("task {i} exploded");
                }
                Ok(())
            })
            .unwrap_err()
        };
        assert!(err.to_string().contains("task 3"), "{err}");
        assert!(done.iter().all(|&d| d == 1), "all tasks still ran");
    }

    #[test]
    fn zero_threads_clamps_to_inline() {
        let pool = WorkerPool::new(0);
        assert_eq!(pool.threads(), 1);
        let out = pool.run(boxed(vec![|| 42]));
        assert_eq!(out[0].0, 42);
        assert_eq!(pool.os_threads_spawned(), 0);
    }

    #[cfg(not(feature = "xla"))]
    #[test]
    fn scoped_baseline_matches_run_indexed() {
        let n = 13usize;
        let seg = 3usize;
        let pool = WorkerPool::new(4);
        let fill = |out: &mut Vec<f32>, via_pool: bool| {
            let mut times = vec![0.0f64; n];
            let mut scratch = vec![(); 4];
            let slab = TaskSlab::new(out);
            let f = |i: usize, _s: &mut ()| {
                // SAFETY: segment i is owned by task i alone.
                let dst = unsafe { slab.segment(i * seg, seg) };
                for (k, v) in dst.iter_mut().enumerate() {
                    *v = (i * seg + k) as f32;
                }
                Ok(())
            };
            if via_pool {
                pool.run_indexed(n, &mut scratch, &mut times, f).unwrap();
            } else {
                run_indexed_scoped(n, &mut scratch, &mut times, f).unwrap();
            }
        };
        let mut a = vec![0.0f32; n * seg];
        let mut b = vec![0.0f32; n * seg];
        fill(&mut a, true);
        fill(&mut b, false);
        assert_eq!(a, b);
    }
}
