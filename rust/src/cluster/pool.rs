//! Worker-thread pool: the "nodes" of the simulated cluster.
//!
//! Superstep tasks borrow the staged dataset and the coordinator's
//! current iterate, so the pool executes them on *scoped* threads
//! (`std::thread::scope`) instead of long-lived channel workers — scoped
//! spawns are the only safe way to run non-`'static` closures in
//! parallel without cloning the training data into every task.
//!
//! `threads = 1` (or a single task) executes inline on the caller thread;
//! `threads > 1` pulls tasks from a shared queue onto up to `threads`
//! scoped workers.  Either way each task's compute time is measured
//! individually so the simulated clock can schedule the superstep onto
//! the configured executor slots, and results are returned in task order
//! so downstream combining is deterministic regardless of scheduling.
//!
//! Under `--features xla` the task type is not `Send` (PJRT literals are
//! thread-confined) and every superstep runs inline — see
//! [`super::superstep::PlanTask`].

use super::superstep::PlanTask;
use std::time::Instant;

/// A fixed-width pool of scoped worker threads.
pub struct WorkerPool {
    threads: usize,
}

impl WorkerPool {
    pub fn new(threads: usize) -> WorkerPool {
        WorkerPool { threads: threads.max(1) }
    }

    pub fn threads(&self) -> usize {
        self.threads
    }

    /// Run all tasks; returns `(result, seconds)` per task, in task order.
    pub fn run<'env, T: Send>(&self, tasks: Vec<PlanTask<'env, T>>) -> Vec<(T, f64)> {
        #[cfg(not(feature = "xla"))]
        {
            let workers = self.threads.min(tasks.len());
            if workers > 1 {
                return run_parallel(tasks, workers);
            }
        }
        tasks
            .into_iter()
            .map(|t| {
                let t0 = Instant::now();
                let v = t();
                (v, t0.elapsed().as_secs_f64())
            })
            .collect()
    }
}

/// Scoped fan-out: `workers` threads drain a shared FIFO of indexed
/// tasks; each result lands in its task's slot.
#[cfg(not(feature = "xla"))]
fn run_parallel<'env, T: Send>(
    tasks: Vec<PlanTask<'env, T>>,
    workers: usize,
) -> Vec<(T, f64)> {
    use std::collections::VecDeque;
    use std::sync::Mutex;

    let n = tasks.len();
    let queue: Mutex<VecDeque<(usize, PlanTask<'env, T>)>> =
        Mutex::new(tasks.into_iter().enumerate().collect());
    let slots: Vec<Mutex<Option<(T, f64)>>> = (0..n).map(|_| Mutex::new(None)).collect();
    std::thread::scope(|s| {
        for _ in 0..workers {
            s.spawn(|| loop {
                let job = queue.lock().unwrap().pop_front();
                let Some((i, task)) = job else { break };
                let t0 = Instant::now();
                let v = task();
                let dt = t0.elapsed().as_secs_f64();
                *slots[i].lock().unwrap() = Some((v, dt));
            });
        }
    });
    slots
        .into_iter()
        .map(|m| m.into_inner().unwrap().expect("worker completed task"))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn boxed<T, F>(fs: Vec<F>) -> Vec<PlanTask<'static, T>>
    where
        T: Send + 'static,
        F: FnOnce() -> T + Send + 'static,
    {
        fs.into_iter()
            .map(|f| Box::new(f) as PlanTask<'static, T>)
            .collect()
    }

    #[test]
    fn inline_pool_runs_in_order() {
        let pool = WorkerPool::new(1);
        let out = pool.run(boxed((0..5).map(|i| move || i).collect::<Vec<_>>()));
        assert_eq!(out.iter().map(|(v, _)| *v).collect::<Vec<_>>(), vec![0, 1, 2, 3, 4]);
    }

    #[test]
    fn threaded_pool_preserves_order_and_results() {
        let pool = WorkerPool::new(3);
        let tasks = (0..32usize)
            .map(|i| {
                move || {
                    // vary work so completion order scrambles
                    let mut acc = 0usize;
                    for k in 0..(i % 7) * 1000 {
                        acc = acc.wrapping_add(k);
                    }
                    let _ = acc;
                    i * 2
                }
            })
            .collect::<Vec<_>>();
        let out = pool.run(boxed(tasks));
        for (i, (v, d)) in out.iter().enumerate() {
            assert_eq!(*v, i * 2);
            assert!(*d >= 0.0);
        }
    }

    #[test]
    fn tasks_may_borrow_caller_state() {
        let data: Vec<usize> = (0..16).collect();
        let pool = WorkerPool::new(4);
        let tasks: Vec<PlanTask<'_, usize>> = data
            .iter()
            .map(|v| Box::new(move || *v + 1) as PlanTask<'_, usize>)
            .collect();
        let out = pool.run(tasks);
        for (i, (v, _)) in out.iter().enumerate() {
            assert_eq!(*v, data[i] + 1);
        }
    }

    #[test]
    fn pool_is_reusable() {
        let pool = WorkerPool::new(2);
        for round in 0..3usize {
            let tasks = (0..4usize).map(|i| move || i + round).collect::<Vec<_>>();
            let out = pool.run(boxed(tasks));
            assert_eq!(out.len(), 4);
            assert_eq!(out[0].0, round);
        }
    }

    #[test]
    fn zero_threads_clamps_to_inline() {
        let pool = WorkerPool::new(0);
        assert_eq!(pool.threads(), 1);
        let out = pool.run(boxed(vec![|| 42]));
        assert_eq!(out[0].0, 42);
    }
}
