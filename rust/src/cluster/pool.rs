//! Worker-thread pool: the "nodes" of the simulated cluster.
//!
//! Superstep tasks borrow the staged dataset and the coordinator's
//! current iterate, so the pool executes them on *scoped* threads
//! (`std::thread::scope`) instead of long-lived channel workers — scoped
//! spawns are the only safe way to run non-`'static` closures in
//! parallel without cloning the training data into every task.
//!
//! `threads = 1` (or a single task) executes inline on the caller thread;
//! `threads > 1` pulls tasks from a shared queue onto up to `threads`
//! scoped workers.  Either way each task's compute time is measured
//! individually so the simulated clock can schedule the superstep onto
//! the configured executor slots, and results are returned in task order
//! so downstream combining is deterministic regardless of scheduling.
//!
//! Under `--features xla` the task type is not `Send` (PJRT literals are
//! thread-confined) and every superstep runs inline — see
//! [`super::superstep::PlanTask`].

use super::superstep::{PlanTask, TaskSlab};
use anyhow::Result;
use std::time::Instant;

/// A fixed-width pool of scoped worker threads.
pub struct WorkerPool {
    threads: usize,
}

impl WorkerPool {
    pub fn new(threads: usize) -> WorkerPool {
        WorkerPool { threads: threads.max(1) }
    }

    pub fn threads(&self) -> usize {
        self.threads
    }

    /// Run all tasks; returns `(result, seconds)` per task, in task order.
    pub fn run<'env, T: Send>(&self, tasks: Vec<PlanTask<'env, T>>) -> Vec<(T, f64)> {
        #[cfg(not(feature = "xla"))]
        {
            let workers = self.threads.min(tasks.len());
            if workers > 1 {
                return run_parallel(tasks, workers);
            }
        }
        tasks
            .into_iter()
            .map(|t| {
                let t0 = Instant::now();
                let v = t();
                (v, t0.elapsed().as_secs_f64())
            })
            .collect()
    }

    /// Zero-allocation fan-out: calls `f(i, scratch)` for every `i` in
    /// `0..n`, writing each task's measured seconds into `times[i]`.
    ///
    /// Unlike [`WorkerPool::run`] there is nothing to box and nothing to
    /// collect — tasks write their outputs into caller-owned slabs (see
    /// [`TaskSlab`]) and each worker thread reuses one caller-owned
    /// scratch cell.  All `n` tasks run even if one errors (matching
    /// `run`'s collect-then-fail semantics, so the simulated clock charges
    /// the same superstep either way); the error of the lowest task index
    /// is returned, which keeps failure reporting deterministic at any
    /// thread count.
    ///
    /// `scratch` needs at least `min(threads, n)` cells (one per worker
    /// actually used; the inline path uses only `scratch[0]`).
    #[cfg(not(feature = "xla"))]
    pub fn run_indexed<S: Send>(
        &self,
        n: usize,
        scratch: &mut [S],
        times: &mut [f64],
        f: impl Fn(usize, &mut S) -> Result<()> + Sync,
    ) -> Result<()> {
        assert!(times.len() >= n, "times buffer too small");
        if n == 0 {
            return Ok(());
        }
        assert!(!scratch.is_empty(), "need at least one scratch cell");
        let workers = self.threads.min(n).min(scratch.len());
        if workers > 1 {
            return run_indexed_parallel(n, &mut scratch[..workers], times, f);
        }
        run_indexed_inline(n, &mut scratch[0], times, f)
    }

    /// Inline-only `run_indexed` (the `xla` build is thread-confined, so
    /// the `Sync` bound drops away and every superstep runs on the caller
    /// thread).
    #[cfg(feature = "xla")]
    pub fn run_indexed<S: Send>(
        &self,
        n: usize,
        scratch: &mut [S],
        times: &mut [f64],
        f: impl Fn(usize, &mut S) -> Result<()>,
    ) -> Result<()> {
        assert!(times.len() >= n, "times buffer too small");
        if n == 0 {
            return Ok(());
        }
        assert!(!scratch.is_empty(), "need at least one scratch cell");
        run_indexed_inline(n, &mut scratch[0], times, f)
    }
}

/// Sequential fallback shared by both feature sets: run every task on the
/// caller thread with one scratch cell, recording per-task seconds and
/// keeping the first (lowest-index) error.
fn run_indexed_inline<S>(
    n: usize,
    scratch: &mut S,
    times: &mut [f64],
    f: impl Fn(usize, &mut S) -> Result<()>,
) -> Result<()> {
    let mut first_err = None;
    for (i, t) in times.iter_mut().take(n).enumerate() {
        let t0 = Instant::now();
        let r = f(i, scratch);
        *t = t0.elapsed().as_secs_f64();
        if let Err(e) = r {
            if first_err.is_none() {
                first_err = Some(e);
            }
        }
    }
    match first_err {
        Some(e) => Err(e),
        None => Ok(()),
    }
}

/// Scoped fan-out for [`WorkerPool::run_indexed`]: each worker owns one
/// scratch cell and claims task indices from a shared atomic counter.
#[cfg(not(feature = "xla"))]
fn run_indexed_parallel<S: Send>(
    n: usize,
    scratch: &mut [S],
    times: &mut [f64],
    f: impl Fn(usize, &mut S) -> Result<()> + Sync,
) -> Result<()> {
    use std::sync::atomic::{AtomicUsize, Ordering};
    use std::sync::Mutex;

    let next = AtomicUsize::new(0);
    let times_slab = TaskSlab::new(times);
    let first_err: Mutex<Option<(usize, anyhow::Error)>> = Mutex::new(None);
    {
        let (next, times_slab, first_err, f) = (&next, &times_slab, &first_err, &f);
        std::thread::scope(|scope| {
            for s in scratch.iter_mut() {
                scope.spawn(move || loop {
                    let i = next.fetch_add(1, Ordering::Relaxed);
                    if i >= n {
                        break;
                    }
                    let t0 = Instant::now();
                    let r = f(i, s);
                    // SAFETY: index i was claimed exactly once via the
                    // atomic counter, so no other worker touches slot i.
                    unsafe { times_slab.write(i, t0.elapsed().as_secs_f64()) };
                    if let Err(e) = r {
                        let mut slot = first_err.lock().unwrap();
                        let lowest_so_far = match slot.as_ref() {
                            None => true,
                            Some((j, _)) => i < *j,
                        };
                        if lowest_so_far {
                            *slot = Some((i, e));
                        }
                    }
                });
            }
        });
    }
    match first_err.into_inner().unwrap() {
        Some((_, e)) => Err(e),
        None => Ok(()),
    }
}

/// Scoped fan-out: `workers` threads drain a shared FIFO of indexed
/// tasks; each result lands in its task's slot.
#[cfg(not(feature = "xla"))]
fn run_parallel<'env, T: Send>(
    tasks: Vec<PlanTask<'env, T>>,
    workers: usize,
) -> Vec<(T, f64)> {
    use std::collections::VecDeque;
    use std::sync::Mutex;

    let n = tasks.len();
    let queue: Mutex<VecDeque<(usize, PlanTask<'env, T>)>> =
        Mutex::new(tasks.into_iter().enumerate().collect());
    let slots: Vec<Mutex<Option<(T, f64)>>> = (0..n).map(|_| Mutex::new(None)).collect();
    std::thread::scope(|s| {
        for _ in 0..workers {
            s.spawn(|| loop {
                let job = queue.lock().unwrap().pop_front();
                let Some((i, task)) = job else { break };
                let t0 = Instant::now();
                let v = task();
                let dt = t0.elapsed().as_secs_f64();
                *slots[i].lock().unwrap() = Some((v, dt));
            });
        }
    });
    slots
        .into_iter()
        .map(|m| m.into_inner().unwrap().expect("worker completed task"))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn boxed<T, F>(fs: Vec<F>) -> Vec<PlanTask<'static, T>>
    where
        T: Send + 'static,
        F: FnOnce() -> T + Send + 'static,
    {
        fs.into_iter()
            .map(|f| Box::new(f) as PlanTask<'static, T>)
            .collect()
    }

    #[test]
    fn inline_pool_runs_in_order() {
        let pool = WorkerPool::new(1);
        let out = pool.run(boxed((0..5).map(|i| move || i).collect::<Vec<_>>()));
        assert_eq!(out.iter().map(|(v, _)| *v).collect::<Vec<_>>(), vec![0, 1, 2, 3, 4]);
    }

    #[test]
    fn threaded_pool_preserves_order_and_results() {
        let pool = WorkerPool::new(3);
        let tasks = (0..32usize)
            .map(|i| {
                move || {
                    // vary work so completion order scrambles
                    let mut acc = 0usize;
                    for k in 0..(i % 7) * 1000 {
                        acc = acc.wrapping_add(k);
                    }
                    let _ = acc;
                    i * 2
                }
            })
            .collect::<Vec<_>>();
        let out = pool.run(boxed(tasks));
        for (i, (v, d)) in out.iter().enumerate() {
            assert_eq!(*v, i * 2);
            assert!(*d >= 0.0);
        }
    }

    #[test]
    fn tasks_may_borrow_caller_state() {
        let data: Vec<usize> = (0..16).collect();
        let pool = WorkerPool::new(4);
        let tasks: Vec<PlanTask<'_, usize>> = data
            .iter()
            .map(|v| Box::new(move || *v + 1) as PlanTask<'_, usize>)
            .collect();
        let out = pool.run(tasks);
        for (i, (v, _)) in out.iter().enumerate() {
            assert_eq!(*v, data[i] + 1);
        }
    }

    #[test]
    fn pool_is_reusable() {
        let pool = WorkerPool::new(2);
        for round in 0..3usize {
            let tasks = (0..4usize).map(|i| move || i + round).collect::<Vec<_>>();
            let out = pool.run(boxed(tasks));
            assert_eq!(out.len(), 4);
            assert_eq!(out[0].0, round);
        }
    }

    #[test]
    fn run_indexed_writes_disjoint_slabs_at_any_width() {
        for threads in [1usize, 3, 8] {
            let pool = WorkerPool::new(threads);
            let n = 17usize;
            let seg = 4usize;
            let mut out = vec![0.0f32; n * seg];
            let mut times = vec![0.0f64; n];
            let mut scratch: Vec<Vec<f32>> = (0..pool.threads()).map(|_| vec![0.0; seg]).collect();
            {
                let slab = TaskSlab::new(&mut out);
                pool.run_indexed(n, &mut scratch, &mut times, |i, s: &mut Vec<f32>| {
                    for (k, v) in s.iter_mut().enumerate() {
                        *v = (i * seg + k) as f32;
                    }
                    // SAFETY: segment i is owned by task i alone.
                    let dst = unsafe { slab.segment(i * seg, seg) };
                    dst.copy_from_slice(s);
                    Ok(())
                })
                .unwrap();
            }
            for (k, v) in out.iter().enumerate() {
                assert_eq!(*v, k as f32, "threads={threads} slot {k}");
            }
            assert!(times.iter().all(|&t| t >= 0.0));
        }
    }

    #[test]
    fn run_indexed_reports_lowest_index_error_and_runs_all() {
        let pool = WorkerPool::new(4);
        let n = 9usize;
        let mut done = vec![0u8; n];
        let mut times = vec![0.0f64; n];
        let mut scratch = vec![(); 4];
        let err = {
            let slab = TaskSlab::new(&mut done);
            pool.run_indexed(n, &mut scratch, &mut times, |i, _s| {
                unsafe { slab.write(i, 1) };
                if i == 3 || i == 6 {
                    anyhow::bail!("task {i} exploded");
                }
                Ok(())
            })
            .unwrap_err()
        };
        assert!(err.to_string().contains("task 3"), "{err}");
        assert!(done.iter().all(|&d| d == 1), "all tasks still ran");
    }

    #[test]
    fn zero_threads_clamps_to_inline() {
        let pool = WorkerPool::new(0);
        assert_eq!(pool.threads(), 1);
        let out = pool.run(boxed(vec![|| 42]));
        assert_eq!(out[0].0, 42);
    }
}
