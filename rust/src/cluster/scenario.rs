//! Cluster scenarios: heterogeneous executors, stragglers, and failures.
//!
//! The default [`SimClock`](super::SimClock) models a perfect cluster —
//! identical executor slots, lossless tasks.  A [`ClusterScenario`] turns
//! that one fixed cluster into a family of them:
//!
//! * **heterogeneous slots** — a fraction of the simulated executor slots
//!   run at a reduced speed factor; the superstep makespan is computed by
//!   speed-aware LPT ([`super::simtime::lpt_makespan_hetero`]);
//! * **stragglers** — each task independently straggles with probability
//!   `straggler_p`; a straggling task's simulated cost is multiplied by
//!   `straggler_slow`, optionally further inflated by a Pareto tail
//!   (`straggler_shape > 0`) — the transient tail-latency events
//!   RADiSA-avg's "do not wait for stragglers" design targets;
//! * **failures** — each task independently fails and is re-executed from
//!   scratch (Spark-style lineage recompute), re-charging its full cost
//!   per attempt, capped at `max_retries` extra attempts.  With
//!   `burst=executor` the failure is *correlated*: any task whose i.i.d.
//!   coin fails marks its whole executor slot as dying for that
//!   superstep, and every task scheduled on that slot re-runs (a dying
//!   node fails all its tasks, not a random subset) — so at the same
//!   seed and rate, burst mode never injects fewer failures than the
//!   i.i.d. coins do;
//! * **speculative execution** — optional Spark-style backup copies,
//!   modelling the same quantile trigger the dist driver runs for real
//!   (`--dist-spec` / [`ClusterScenario::spec_quantile`]): speculation
//!   arms once the fastest `spec_quantile` fraction of the superstep's
//!   tasks have finished (at `t_arm`, the k-th smallest perturbed
//!   duration); every task still running then gets up to `spec_copies`
//!   backup attempts whose durations are drawn from a dedicated seeded
//!   substream (fresh straggler tail + failure-retry coins per attempt,
//!   same distributions as the primary), and the task completes at
//!   `min(original, t_arm + fastest backup)`.  Straggler/failure
//!   *counters* are untouched — speculation changes simulated time, not
//!   which events fired ([`ClusterScenario::speculate`]).
//!
//! Everything is deterministic from the scenario `seed`: injections are
//! drawn from [`Xoshiro`] substreams keyed by `(tag, superstep, task)`,
//! never by schedule or worker thread — so scenarios are orthogonal to
//! `--threads` (host results stay bit-identical; only the simulated clock
//! changes) and repeat runs with the same seed reproduce the clock bit
//! for bit.
//!
//! Straggler-*tolerant* supersteps (see
//! [`StepPlan::mark_tolerant`](super::StepPlan::mark_tolerant)) model the
//! paper's RADiSA-avg coordinator, which averages whatever partial
//! solutions are available instead of waiting: injected straggler delays
//! and failure re-charges do not extend the step's makespan (permanent
//! hardware heterogeneity still applies — it is not a transient event a
//! non-waiting coordinator can dodge).

use crate::util::rng::Xoshiro;
use anyhow::{bail, Result};

/// Substream tag for straggler draws.
const TAG_STRAGGLER: u64 = 0x57A6;
/// Substream tag for failure draws.
const TAG_FAILURE: u64 = 0xFA11;
/// Substream tag for speculative backup-copy draws — separate from the
/// primary streams so arming speculation never shifts the straggler or
/// failure coins of any task.
const TAG_SPEC: u64 = 0x5BEC;

/// What the scenario did to one task.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct TaskFate {
    /// Simulated duration actually charged to the clock.
    pub duration: f64,
    /// Whether a straggler event was injected.
    pub straggled: bool,
    /// Extra (failed) attempts injected, 0 for a clean task.
    pub extra_attempts: usize,
}

/// How a perturbation call learns which tasks share an executor slot
/// (only burst-mode failures care).
enum BurstCtx<'a> {
    /// No slot information: failures stay i.i.d. per task.
    Iid,
    /// Slot peers recomputed on the fly (tests / one-off calls).
    Grid { n_tasks: usize, cores: usize },
    /// Per-slot worst coins precomputed once per superstep
    /// ([`ClusterScenario::burst_slots_into`]) — the hot-loop path.
    Slots { cores: usize, slots: &'a [usize] },
}

/// A deterministic cluster-condition scenario (see module docs).
#[derive(Clone, Debug, PartialEq)]
pub struct ClusterScenario {
    /// Fraction of executor slots that are slow (0 = homogeneous).
    pub hetero_frac: f64,
    /// Speed factor of the slow slots (1 = full speed).
    pub hetero_speed: f64,
    /// Per-task straggler probability.
    pub straggler_p: f64,
    /// Straggler cost multiplier (≥ 1).
    pub straggler_slow: f64,
    /// Pareto tail shape for the straggler multiplier; 0 = deterministic
    /// multiplier `straggler_slow`, > 0 draws `slow / (1-u)^(1/shape)`.
    pub straggler_shape: f64,
    /// Per-attempt task failure probability.
    pub failure_p: f64,
    /// Maximum extra attempts charged per task.
    pub max_retries: usize,
    /// Correlated failures (`failures:...,burst=executor`): a failing
    /// task takes its whole executor slot down for the superstep, so
    /// every task on that slot fails too.  `false` = i.i.d. per-task
    /// coins (the default).
    pub failure_burst: bool,
    /// Spark-style speculative re-execution (see module docs).
    pub speculative: bool,
    /// Gather-completion quantile that arms the speculation trigger
    /// (sim cost model and the dist driver's `--dist-spec` both read it).
    pub spec_quantile: f64,
    /// Maximum backup copies per lagging task/executor.
    pub spec_copies: usize,
    /// Scenario seed — injections are a pure function of
    /// `(seed, superstep, task)`.
    pub seed: u64,
}

impl Default for ClusterScenario {
    fn default() -> Self {
        ClusterScenario {
            hetero_frac: 0.0,
            hetero_speed: 1.0,
            straggler_p: 0.0,
            straggler_slow: 1.0,
            straggler_shape: 0.0,
            failure_p: 0.0,
            max_retries: 3,
            failure_burst: false,
            speculative: false,
            spec_quantile: 0.75,
            spec_copies: 1,
            seed: 0,
        }
    }
}

impl ClusterScenario {
    /// The perfect cluster (no heterogeneity, no injections).
    pub fn ideal() -> ClusterScenario {
        ClusterScenario::default()
    }

    /// True when this scenario never perturbs anything.
    pub fn is_ideal(&self) -> bool {
        (self.hetero_frac <= 0.0 || self.hetero_speed >= 1.0)
            && self.straggler_p <= 0.0
            && self.failure_p <= 0.0
    }

    /// Parse a CLI/JSON scenario spec.  Clauses are joined with `+`:
    ///
    /// ```text
    /// ideal
    /// stragglers:p=0.1,slow=10x[,shape=1.5][,seed=7][,spec]
    /// hetero:frac=0.25,speed=0.5
    /// failures:p=0.05[,retries=3][,burst=executor][,seed=7][,spec]
    /// stragglers:p=0.1,slow=4x+failures:p=0.02
    /// ```
    pub fn parse(spec: &str) -> Result<ClusterScenario> {
        let mut sc = ClusterScenario::default();
        for clause in spec.split('+') {
            let clause = clause.trim();
            if clause.is_empty() || clause == "ideal" {
                continue;
            }
            let (kind, params) = match clause.split_once(':') {
                Some((k, p)) => (k, p),
                None => (clause, ""),
            };
            match kind {
                "stragglers" => {
                    // defaults match the flag's documented example
                    sc.straggler_p = 0.1;
                    sc.straggler_slow = 10.0;
                    for (key, val) in parse_params(params) {
                        match key {
                            "p" => sc.straggler_p = parse_prob(val, "stragglers.p")?,
                            "slow" => {
                                let v: f64 = val
                                    .trim_end_matches('x')
                                    .parse()
                                    .map_err(|_| bad(key, val))?;
                                if !v.is_finite() || v < 1.0 {
                                    bail!("stragglers.slow must be a finite multiplier >= 1, got '{val}'");
                                }
                                sc.straggler_slow = v;
                            }
                            "shape" => {
                                let v: f64 = val.parse().map_err(|_| bad(key, val))?;
                                if !v.is_finite() || v < 0.0 {
                                    bail!("stragglers.shape must be finite and >= 0, got '{val}'");
                                }
                                sc.straggler_shape = v;
                            }
                            "seed" => sc.seed = val.parse().map_err(|_| bad(key, val))?,
                            "spec" => sc.speculative = parse_switch(val)?,
                            "spec_quantile" => {
                                sc.spec_quantile =
                                    parse_quantile(val, "stragglers.spec_quantile")?
                            }
                            "spec_copies" => {
                                sc.spec_copies = parse_copies(val, "stragglers.spec_copies")?
                            }
                            other => bail!("unknown stragglers parameter '{other}'"),
                        }
                    }
                }
                "hetero" => {
                    sc.hetero_frac = 0.25;
                    sc.hetero_speed = 0.5;
                    for (key, val) in parse_params(params) {
                        match key {
                            "frac" => sc.hetero_frac = parse_prob(val, "hetero.frac")?,
                            "speed" => {
                                let v: f64 = val.parse().map_err(|_| bad(key, val))?;
                                if v.is_nan() || v <= 0.0 || v > 1.0 {
                                    bail!("hetero.speed must be in (0, 1], got '{val}'");
                                }
                                sc.hetero_speed = v;
                            }
                            other => bail!("unknown hetero parameter '{other}'"),
                        }
                    }
                }
                "failures" => {
                    sc.failure_p = 0.05;
                    for (key, val) in parse_params(params) {
                        match key {
                            "p" => sc.failure_p = parse_prob(val, "failures.p")?,
                            "retries" => {
                                let v: usize = val.parse().map_err(|_| bad(key, val))?;
                                if v > 16 {
                                    bail!("failures.retries must be <= 16, got '{val}'");
                                }
                                sc.max_retries = v;
                            }
                            "burst" => {
                                sc.failure_burst = match val {
                                    "executor" => true,
                                    "iid" | "" => false,
                                    other => bail!(
                                        "failures.burst must be 'executor' or 'iid', got '{other}'"
                                    ),
                                };
                            }
                            "seed" => sc.seed = val.parse().map_err(|_| bad(key, val))?,
                            "spec" => sc.speculative = parse_switch(val)?,
                            "spec_quantile" => {
                                sc.spec_quantile = parse_quantile(val, "failures.spec_quantile")?
                            }
                            "spec_copies" => {
                                sc.spec_copies = parse_copies(val, "failures.spec_copies")?
                            }
                            other => bail!("unknown failures parameter '{other}'"),
                        }
                    }
                }
                other => bail!(
                    "unknown scenario '{other}'; valid forms are `ideal`, \
                     `stragglers:p=P,slow=Nx[,shape=S][,seed=K][,spec]`, \
                     `hetero:frac=F,speed=S`, \
                     `failures:p=P[,retries=R][,burst=executor][,seed=K][,spec]`, \
                     joined with `+`"
                ),
            }
        }
        Ok(sc)
    }

    /// Human-readable label (round-trips the active clauses).
    pub fn label(&self) -> String {
        if self.is_ideal() {
            return "ideal".into();
        }
        let mut parts = Vec::new();
        if self.hetero_frac > 0.0 && self.hetero_speed < 1.0 {
            parts.push(format!(
                "hetero:frac={},speed={}",
                self.hetero_frac, self.hetero_speed
            ));
        }
        if self.straggler_p > 0.0 {
            let mut s = format!(
                "stragglers:p={},slow={}x",
                self.straggler_p, self.straggler_slow
            );
            if self.straggler_shape > 0.0 {
                s.push_str(&format!(",shape={}", self.straggler_shape));
            }
            if self.speculative {
                s.push_str(",spec");
                self.push_spec_knobs(&mut s);
            }
            parts.push(s);
        }
        if self.failure_p > 0.0 {
            let mut s = format!(
                "failures:p={},retries={}",
                self.failure_p, self.max_retries
            );
            if self.failure_burst {
                s.push_str(",burst=executor");
            }
            // `spec` is a per-scenario switch; emit it once, in whichever
            // clause comes first, so the label re-parses to the same value
            if self.speculative && self.straggler_p <= 0.0 {
                s.push_str(",spec");
                self.push_spec_knobs(&mut s);
            }
            parts.push(s);
        }
        let mut out = parts.join("+");
        if self.seed != 0 {
            out.push_str(&format!(" (seed {})", self.seed));
        }
        out
    }

    /// Append non-default speculation knobs next to a `,spec` emission so
    /// the label re-parses to the same scenario.
    fn push_spec_knobs(&self, s: &mut String) {
        if (self.spec_quantile - 0.75).abs() > f64::EPSILON {
            s.push_str(&format!(",spec_quantile={}", self.spec_quantile));
        }
        if self.spec_copies != 1 {
            s.push_str(&format!(",spec_copies={}", self.spec_copies));
        }
    }

    /// Per-slot speed factors for `cores` executor slots.  The slow slots
    /// (⌈frac·cores⌉ of them) come first; slot identity is irrelevant to
    /// the LPT makespan, so no seeding is needed here.
    pub fn speeds(&self, cores: usize) -> Vec<f64> {
        let cores = cores.max(1);
        let mut speeds = vec![1.0f64; cores];
        if self.hetero_frac > 0.0 && self.hetero_speed < 1.0 {
            let slow = ((self.hetero_frac * cores as f64).ceil() as usize).min(cores);
            for s in speeds.iter_mut().take(slow) {
                *s = self.hetero_speed;
            }
        }
        speeds
    }

    /// Perturb one task's base simulated cost.  Deterministic in
    /// `(seed, step, task)`; `tolerant` supersteps keep the base duration
    /// (injections are counted but not waited for — see module docs).
    ///
    /// This entry always uses i.i.d. per-task failure coins; the grid
    /// paths call [`ClusterScenario::perturb_grid`], which additionally
    /// honors `burst=executor` by correlating the coins across the tasks
    /// of one executor slot.
    ///
    /// Non-finite or negative base costs are clamped to 0 (see
    /// [`super::simtime::lpt_makespan_hetero`] for the same policy on the
    /// scheduler side).
    pub fn perturb(&self, step: usize, task: usize, base: f64, tolerant: bool) -> TaskFate {
        self.perturb_impl(step, task, BurstCtx::Iid, base, tolerant)
    }

    /// [`ClusterScenario::perturb`] with the superstep's grid context
    /// (`n_tasks` tasks round-robined over `cores` executor slots), which
    /// `burst=executor` needs to know which tasks share a slot.  Without
    /// burst mode this is bit-identical to `perturb`.
    ///
    /// Recomputes the slot's peer coins per call (O(n_tasks / cores)) —
    /// convenient for tests; the per-superstep hot loops precompute the
    /// slot table once with [`ClusterScenario::burst_slots_into`] and use
    /// [`ClusterScenario::perturb_slotted`] instead.
    pub fn perturb_grid(
        &self,
        step: usize,
        task: usize,
        n_tasks: usize,
        cores: usize,
        base: f64,
        tolerant: bool,
    ) -> TaskFate {
        self.perturb_impl(step, task, BurstCtx::Grid { n_tasks, cores }, base, tolerant)
    }

    /// Precompute burst mode's per-slot worst i.i.d. coin for one
    /// superstep: `out[slot] = max over tasks on slot of iid attempts`.
    /// One O(n_tasks) pass, so a whole superstep's perturbation stays
    /// O(n_tasks) instead of O(n_tasks² / cores).  Leaves `out` empty
    /// when burst failures are off (the i.i.d. fast path).
    pub fn burst_slots_into(
        &self,
        step: usize,
        n_tasks: usize,
        cores: usize,
        out: &mut Vec<usize>,
    ) {
        out.clear();
        if !self.failure_burst || self.failure_p <= 0.0 || n_tasks == 0 {
            return;
        }
        let cores = cores.max(1);
        out.resize(cores, 0);
        for task in 0..n_tasks {
            let slot = task % cores;
            out[slot] = out[slot].max(self.iid_attempts(step, task));
        }
    }

    /// [`ClusterScenario::perturb_grid`] with the per-slot burst table
    /// precomputed by [`ClusterScenario::burst_slots_into`] (an empty
    /// table means no burst — plain i.i.d. coins).  Bit-identical fates
    /// to `perturb_grid` at the same `(step, n_tasks, cores)`.
    pub fn perturb_slotted(
        &self,
        step: usize,
        task: usize,
        cores: usize,
        slots: &[usize],
        base: f64,
        tolerant: bool,
    ) -> TaskFate {
        if slots.is_empty() {
            self.perturb_impl(step, task, BurstCtx::Iid, base, tolerant)
        } else {
            self.perturb_impl(step, task, BurstCtx::Slots { cores, slots }, base, tolerant)
        }
    }

    /// Extra attempts of one task's i.i.d. failure coin sequence.
    fn iid_attempts(&self, step: usize, task: usize) -> usize {
        let root = Xoshiro::new(self.seed);
        let mut rng = root.substream(TAG_FAILURE, step as u64, task as u64);
        let mut extra = 0usize;
        while extra < self.max_retries && rng.f64() < self.failure_p {
            extra += 1;
        }
        extra
    }

    fn perturb_impl(
        &self,
        step: usize,
        task: usize,
        burst: BurstCtx<'_>,
        base: f64,
        tolerant: bool,
    ) -> TaskFate {
        let base = if base.is_finite() && base > 0.0 { base } else { 0.0 };
        let mut duration = base;
        let mut straggled = false;
        let mut extra = 0usize;
        let root = Xoshiro::new(self.seed);

        if self.straggler_p > 0.0 {
            let mut rng = root.substream(TAG_STRAGGLER, step as u64, task as u64);
            // one uniform decides *whether*, a second decides *how much*:
            // for a fixed seed the straggler set grows with p and the
            // multiplier grows with slow — the monotonicity the property
            // tests pin down.
            let hit = rng.f64() < self.straggler_p;
            let tail_u = rng.f64();
            if hit {
                straggled = true;
                let mut mult = self.straggler_slow.max(1.0);
                if self.straggler_shape > 0.0 {
                    mult *= (1.0 - tail_u.min(1.0 - 1e-12)).powf(-1.0 / self.straggler_shape);
                }
                if !tolerant {
                    duration *= mult;
                }
            }
        }

        if self.failure_p > 0.0 {
            // a dying executor fails *all* its tasks: in burst mode every
            // task on a slot (round-robin task % cores) inherits the
            // worst i.i.d. coin of the slot, so the burst fate is a
            // per-slot superset of the i.i.d. fates — never fewer
            // injected failures at the same seed and rate (pinned by a
            // property test).
            extra = match burst {
                BurstCtx::Slots { cores, slots } if self.failure_burst => {
                    // the table already folds this task's own coin in —
                    // no per-task coin walk on the precomputed path
                    slots[task % cores.max(1)]
                }
                BurstCtx::Grid { n_tasks, cores } if self.failure_burst => {
                    let cores = cores.max(1);
                    let mut worst = self.iid_attempts(step, task);
                    let mut peer = task % cores;
                    while peer < n_tasks {
                        worst = worst.max(self.iid_attempts(step, peer));
                        peer += cores;
                    }
                    worst
                }
                _ => self.iid_attempts(step, task),
            };
            if !tolerant {
                // each failed attempt re-ran the (possibly straggling)
                // task from scratch before the attempt that succeeded;
                // rescue by a backup copy is a *superstep-level* effect,
                // applied afterwards by [`ClusterScenario::speculate`]
                duration *= (1 + extra) as f64;
            }
        }

        TaskFate { duration, straggled, extra_attempts: extra }
    }

    /// Apply the speculative-execution cost model to one superstep's
    /// perturbed task durations — the sim mirror of the dist driver's
    /// quantile-triggered backup launches, so the sim clock *predicts*
    /// dist speculation instead of approximating it with a flat cap.
    ///
    /// Model: the driver arms speculation once the fastest
    /// `spec_quantile` fraction of the step's tasks (k = ⌈q·n⌉) have
    /// gathered, i.e. at `t_arm`, the k-th smallest perturbed duration.
    /// Every task still running at `t_arm` gets `spec_copies` backup
    /// attempts, drawn from the dedicated `TAG_SPEC` substream keyed
    /// `(step, task)` — each attempt re-rolls a straggler coin + tail
    /// and a failure-retry walk on the task's clean `base` cost, exactly
    /// the distributions the primary attempt was drawn from.  The task
    /// then completes at `min(original, t_arm + fastest backup)`.
    ///
    /// * `durations` — perturbed per-task durations (from
    ///   [`ClusterScenario::perturb_slotted`]), rewritten in place.
    /// * `bases` — the same tasks' clean base costs (backup copies rerun
    ///   from scratch, so they draw on the base, not the perturbed cost).
    /// * `scratch` — caller-owned sort buffer (the hot loop reuses it;
    ///   no allocation at steady state).
    /// * `tolerant` — straggler-tolerant supersteps never wait on
    ///   laggards, so there is nothing for speculation to rescue.
    ///
    /// Straggled/extra-attempt counters are left to the perturb pass:
    /// speculation changes *time*, not which events fired.  With
    /// `spec_quantile = 1.0` the trigger waits for every task — a valid
    /// (never-arming) configuration.
    pub fn speculate(
        &self,
        step: usize,
        durations: &mut [f64],
        bases: &[f64],
        scratch: &mut Vec<f64>,
        tolerant: bool,
    ) {
        if !self.speculative || tolerant || durations.is_empty() {
            return;
        }
        debug_assert_eq!(durations.len(), bases.len());
        let n = durations.len();
        let k = ((self.spec_quantile * n as f64).ceil() as usize).clamp(1, n);
        scratch.clear();
        scratch.extend_from_slice(durations);
        scratch.sort_unstable_by(f64::total_cmp);
        let t_arm = scratch[k - 1];
        let root = Xoshiro::new(self.seed);
        for (task, d) in durations.iter_mut().enumerate() {
            if *d <= t_arm {
                continue;
            }
            let base = bases[task];
            let base = if base.is_finite() && base > 0.0 { base } else { 0.0 };
            let mut rng = root.substream(TAG_SPEC, step as u64, task as u64);
            let mut best = f64::INFINITY;
            for _ in 0..self.spec_copies.max(1) {
                // fixed draw order per attempt (straggler coin, tail,
                // failure walk) so the clock is a pure function of
                // (seed, step, task) — same discipline as perturb_impl
                let mut mult = 1.0f64;
                if self.straggler_p > 0.0 {
                    let hit = rng.f64() < self.straggler_p;
                    let tail_u = rng.f64();
                    if hit {
                        mult = self.straggler_slow.max(1.0);
                        if self.straggler_shape > 0.0 {
                            mult *=
                                (1.0 - tail_u.min(1.0 - 1e-12)).powf(-1.0 / self.straggler_shape);
                        }
                    }
                }
                let mut extra = 0usize;
                if self.failure_p > 0.0 {
                    while extra < self.max_retries && rng.f64() < self.failure_p {
                        extra += 1;
                    }
                }
                best = best.min(base * mult * (1 + extra) as f64);
            }
            let rescued = t_arm + best;
            if rescued < *d {
                *d = rescued;
            }
        }
    }
}

fn bad(key: &str, val: &str) -> anyhow::Error {
    anyhow::anyhow!("bad scenario parameter {key}='{val}'")
}

fn parse_prob(val: &str, what: &str) -> Result<f64> {
    let v: f64 = val
        .parse()
        .map_err(|_| anyhow::anyhow!("bad scenario parameter {what}='{val}'"))?;
    if !(0.0..=1.0).contains(&v) {
        bail!("{what} must be in [0, 1], got '{val}'");
    }
    Ok(v)
}

/// The speculation trigger quantile: (0, 1].  0 (or less) would arm the
/// trigger before any task finished; values above 1 could never arm it
/// at all.  Exactly 1.0 is valid — "wait for everyone", a deliberate
/// never-arming configuration.
fn parse_quantile(val: &str, clause: &str) -> Result<f64> {
    let v: f64 = val
        .parse()
        .map_err(|_| anyhow::anyhow!("bad scenario parameter {clause}='{val}'"))?;
    if !v.is_finite() || v <= 0.0 || v > 1.0 {
        bail!("{clause} must be in (0, 1], got '{val}'");
    }
    Ok(v)
}

/// Backup copies per laggard: 1..=8.  0 copies would be a trigger that
/// fires and then launches nothing; more than a handful just burns the
/// idle fleet (each copy is a full re-execution).
fn parse_copies(val: &str, clause: &str) -> Result<usize> {
    let v: usize = val
        .parse()
        .map_err(|_| anyhow::anyhow!("bad scenario parameter {clause}='{val}'"))?;
    if v == 0 || v > 8 {
        bail!("{clause} must be in 1..=8, got '{val}'");
    }
    Ok(v)
}

fn parse_switch(val: &str) -> Result<bool> {
    match val {
        "" | "true" | "1" => Ok(true),
        "false" | "0" => Ok(false),
        other => bail!("bad scenario switch value '{other}'"),
    }
}

/// Split `k=v,k=v,flag` parameter lists; bare keys get an empty value.
fn parse_params(params: &str) -> Vec<(&str, &str)> {
    let mut out = Vec::new();
    for item in params.split(',') {
        let item = item.trim();
        if item.is_empty() {
            continue;
        }
        match item.split_once('=') {
            Some((k, v)) => out.push((k.trim(), v.trim())),
            None => out.push((item, "")),
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_is_ideal_noop() {
        let sc = ClusterScenario::ideal();
        assert!(sc.is_ideal());
        assert_eq!(sc.speeds(8), vec![1.0; 8]);
        let fate = sc.perturb(0, 0, 2.5, false);
        assert_eq!(fate, TaskFate { duration: 2.5, straggled: false, extra_attempts: 0 });
    }

    #[test]
    fn parse_stragglers_spec() {
        let sc = ClusterScenario::parse("stragglers:p=0.2,slow=8x,seed=9").unwrap();
        assert_eq!(sc.straggler_p, 0.2);
        assert_eq!(sc.straggler_slow, 8.0);
        assert_eq!(sc.seed, 9);
        assert!(!sc.is_ideal());
        // defaults when parameters are omitted
        let d = ClusterScenario::parse("stragglers").unwrap();
        assert_eq!(d.straggler_p, 0.1);
        assert_eq!(d.straggler_slow, 10.0);
    }

    #[test]
    fn parse_hetero_and_failures_and_combined() {
        let sc = ClusterScenario::parse("hetero:frac=0.5,speed=0.25").unwrap();
        assert_eq!(sc.hetero_frac, 0.5);
        assert_eq!(sc.hetero_speed, 0.25);
        let sc = ClusterScenario::parse("failures:p=0.1,retries=2,spec").unwrap();
        assert_eq!(sc.failure_p, 0.1);
        assert_eq!(sc.max_retries, 2);
        assert!(sc.speculative);
        let sc =
            ClusterScenario::parse("stragglers:p=0.1,slow=4x+failures:p=0.02,seed=3").unwrap();
        assert_eq!(sc.straggler_p, 0.1);
        assert_eq!(sc.failure_p, 0.02);
        assert_eq!(sc.seed, 3);
        assert_eq!(ClusterScenario::parse("ideal").unwrap(), ClusterScenario::ideal());
    }

    #[test]
    fn parse_rejects_bad_specs() {
        assert!(ClusterScenario::parse("warp:x=1").is_err());
        assert!(ClusterScenario::parse("stragglers:p=1.5").is_err());
        assert!(ClusterScenario::parse("stragglers:slow=0.5x").is_err());
        assert!(ClusterScenario::parse("hetero:speed=0").is_err());
        assert!(ClusterScenario::parse("hetero:speed=2").is_err());
        assert!(ClusterScenario::parse("failures:retries=99").is_err());
        assert!(ClusterScenario::parse("stragglers:wat=1").is_err());
    }

    #[test]
    fn parse_rejects_bad_speculation_knobs() {
        // quantile outside (0, 1] is a hard error naming clause + value
        for spec in [
            "stragglers:spec,spec_quantile=0",
            "stragglers:spec,spec_quantile=-0.5",
            "stragglers:spec,spec_quantile=1.5",
            "stragglers:spec,spec_quantile=nan",
        ] {
            let err = ClusterScenario::parse(spec).unwrap_err().to_string();
            assert!(err.contains("stragglers.spec_quantile"), "{spec}: {err}");
        }
        let err = ClusterScenario::parse("failures:spec,spec_quantile=2")
            .unwrap_err()
            .to_string();
        assert!(err.contains("failures.spec_quantile"), "{err}");
        assert!(err.contains("(0, 1]"), "{err}");
        assert!(err.contains("'2'"), "{err}");
        // copies = 0 is a trigger that fires and launches nothing
        let err = ClusterScenario::parse("failures:spec,spec_copies=0")
            .unwrap_err()
            .to_string();
        assert!(err.contains("failures.spec_copies"), "{err}");
        assert!(err.contains("1..=8"), "{err}");
        assert!(ClusterScenario::parse("stragglers:spec,spec_copies=9").is_err());
        // the boundary values stay valid
        assert_eq!(
            ClusterScenario::parse("stragglers:spec,spec_quantile=1.0")
                .unwrap()
                .spec_quantile,
            1.0
        );
        assert_eq!(
            ClusterScenario::parse("stragglers:spec,spec_copies=8").unwrap().spec_copies,
            8
        );
    }

    #[test]
    fn speeds_mark_leading_slots_slow() {
        let sc = ClusterScenario::parse("hetero:frac=0.25,speed=0.5").unwrap();
        let sp = sc.speeds(8);
        assert_eq!(sp, vec![0.5, 0.5, 1.0, 1.0, 1.0, 1.0, 1.0, 1.0]);
        // ceil: 25% of 2 slots -> 1 slow slot
        assert_eq!(sc.speeds(2), vec![0.5, 1.0]);
    }

    #[test]
    fn perturb_is_deterministic_and_seed_sensitive() {
        let sc = ClusterScenario::parse("stragglers:p=0.5,slow=4x,seed=1+failures:p=0.3").unwrap();
        for step in 0..4 {
            for task in 0..6 {
                let a = sc.perturb(step, task, 1.0, false);
                let b = sc.perturb(step, task, 1.0, false);
                assert_eq!(a, b);
            }
        }
        let other = ClusterScenario { seed: 2, ..sc.clone() };
        let fates_a: Vec<TaskFate> = (0..64).map(|i| sc.perturb(0, i, 1.0, false)).collect();
        let fates_b: Vec<TaskFate> = (0..64).map(|i| other.perturb(0, i, 1.0, false)).collect();
        assert_ne!(fates_a, fates_b);
    }

    #[test]
    fn straggler_multiplier_applies_only_when_blocking() {
        let sc = ClusterScenario::parse("stragglers:p=1,slow=6x,seed=5").unwrap();
        let blocking = sc.perturb(3, 1, 2.0, false);
        assert!(blocking.straggled);
        assert_eq!(blocking.duration, 12.0);
        let tolerant = sc.perturb(3, 1, 2.0, true);
        assert!(tolerant.straggled, "injection is counted either way");
        assert_eq!(tolerant.duration, 2.0, "but a tolerant step does not wait");
    }

    #[test]
    fn failures_recharge_full_attempts() {
        let sc = ClusterScenario::parse("failures:p=1,retries=3,seed=2").unwrap();
        let fate = sc.perturb(0, 0, 1.5, false);
        assert_eq!(fate.extra_attempts, 3);
        assert_eq!(fate.duration, 1.5 * 4.0);
        let tolerant = sc.perturb(0, 0, 1.5, true);
        assert_eq!(tolerant.extra_attempts, 3);
        assert_eq!(tolerant.duration, 1.5);
    }

    #[test]
    fn speculation_no_longer_caps_per_task_perturbation() {
        // the per-task pass charges the full straggler/failure cost;
        // rescue is a superstep-level effect (speculate), not a cap
        let spec =
            ClusterScenario::parse("stragglers:p=1,slow=10x,spec+failures:p=1,retries=3").unwrap();
        let plain = ClusterScenario { speculative: false, ..spec.clone() };
        let f_spec = spec.perturb(0, 0, 1.0, false);
        let f_plain = plain.perturb(0, 0, 1.0, false);
        assert_eq!(f_spec, f_plain);
        assert_eq!(f_spec.duration, 10.0 * 4.0);
    }

    #[test]
    fn speculate_rescues_only_tasks_past_the_arm_quantile() {
        let sc = ClusterScenario::parse(
            "stragglers:p=0.4,slow=20x,seed=4,spec,spec_quantile=0.5,spec_copies=2",
        )
        .unwrap();
        let mut scratch = Vec::new();
        let mut rescued_any = false;
        for step in 0..12 {
            let n = 8usize;
            let bases = vec![1.0f64; n];
            let raw: Vec<f64> =
                (0..n).map(|t| sc.perturb(step, t, 1.0, false).duration).collect();
            // k = ceil(0.5 * 8) = 4 → t_arm is the 4th smallest duration
            let mut sorted = raw.clone();
            sorted.sort_unstable_by(f64::total_cmp);
            let t_arm = sorted[3];
            let mut durs = raw.clone();
            sc.speculate(step, &mut durs, &bases, &mut scratch, false);
            for t in 0..n {
                assert!(durs[t] <= raw[t], "step {step} task {t}: rescue never slows a task");
                if raw[t] <= t_arm {
                    assert_eq!(durs[t], raw[t], "step {step} task {t}: finished before arming");
                } else {
                    assert!(
                        durs[t] >= t_arm,
                        "step {step} task {t}: a backup cannot finish before the trigger armed"
                    );
                    if durs[t] < raw[t] {
                        rescued_any = true;
                    }
                }
            }
            // deterministic: same inputs → bit-identical clock
            let mut again = raw.clone();
            sc.speculate(step, &mut again, &bases, &mut scratch, false);
            assert_eq!(durs, again);
        }
        assert!(rescued_any, "a 20x straggler tail at p=0.4 should get some rescues");
    }

    #[test]
    fn speculate_quantile_one_and_tolerant_are_noops() {
        let base = ClusterScenario::parse(
            "stragglers:p=0.6,slow=12x,seed=6,spec+failures:p=0.3,retries=2",
        )
        .unwrap();
        let q1 = ClusterScenario { spec_quantile: 1.0, ..base.clone() };
        let mut scratch = Vec::new();
        let raw: Vec<f64> = (0..10).map(|t| base.perturb(1, t, 1.0, false).duration).collect();
        let bases = vec![1.0f64; 10];
        // q = 1.0: the trigger waits for every task — nothing to rescue
        let mut durs = raw.clone();
        q1.speculate(1, &mut durs, &bases, &mut scratch, false);
        assert_eq!(durs, raw);
        // tolerant steps never wait on laggards, so nothing is rescued
        let mut durs = raw.clone();
        base.speculate(1, &mut durs, &bases, &mut scratch, true);
        assert_eq!(durs, raw);
        // and a non-speculative scenario is untouched by construction
        let plain = ClusterScenario { speculative: false, ..base };
        let mut durs = raw.clone();
        plain.speculate(1, &mut durs, &bases, &mut scratch, false);
        assert_eq!(durs, raw);
    }

    #[test]
    fn monotone_in_probability_and_severity_per_task() {
        let mk = |p: f64, slow: f64| ClusterScenario {
            straggler_p: p,
            straggler_slow: slow,
            seed: 11,
            ..Default::default()
        };
        for task in 0..32 {
            let mut prev = 0.0f64;
            for p in [0.0, 0.1, 0.3, 0.6, 1.0] {
                let d = mk(p, 5.0).perturb(2, task, 1.0, false).duration;
                assert!(d >= prev, "task {task}: p={p}: {d} < {prev}");
                prev = d;
            }
            let mut prev = 0.0f64;
            for slow in [1.0, 2.0, 4.0, 16.0] {
                let d = mk(0.5, slow).perturb(2, task, 1.0, false).duration;
                assert!(d >= prev, "task {task}: slow={slow}: {d} < {prev}");
                prev = d;
            }
        }
    }

    #[test]
    fn pareto_tail_inflates_beyond_slow() {
        let sc = ClusterScenario::parse("stragglers:p=1,slow=2x,shape=1.0,seed=3").unwrap();
        let mut any_above = false;
        for task in 0..64 {
            let d = sc.perturb(0, task, 1.0, false).duration;
            assert!(d >= 2.0 - 1e-12, "tail never deflates below slow: {d}");
            if d > 2.5 {
                any_above = true;
            }
        }
        assert!(any_above, "a Pareto tail should produce some heavy draws");
    }

    #[test]
    fn burst_parses_and_rejects_bad_values() {
        let sc = ClusterScenario::parse("failures:p=0.2,burst=executor").unwrap();
        assert!(sc.failure_burst);
        let sc = ClusterScenario::parse("failures:p=0.2,burst=iid").unwrap();
        assert!(!sc.failure_burst);
        assert!(ClusterScenario::parse("failures:p=0.2,burst=rack").is_err());
    }

    #[test]
    fn burst_fails_whole_executor_slots() {
        let iid = ClusterScenario::parse("failures:p=0.4,retries=2,seed=7").unwrap();
        let burst =
            ClusterScenario::parse("failures:p=0.4,retries=2,burst=executor,seed=7").unwrap();
        let (n_tasks, cores) = (12usize, 4usize);
        for step in 0..6 {
            // every slot's tasks share one fate: the worst i.i.d. coin
            for slot in 0..cores {
                let mut worst = 0usize;
                let mut t = slot;
                while t < n_tasks {
                    worst = worst.max(iid.perturb(step, t, 1.0, false).extra_attempts);
                    t += cores;
                }
                let mut t = slot;
                while t < n_tasks {
                    let fate = burst.perturb_grid(step, t, n_tasks, cores, 1.0, false);
                    assert_eq!(fate.extra_attempts, worst, "step {step} task {t}");
                    t += cores;
                }
            }
        }
    }

    #[test]
    fn slotted_burst_matches_on_the_fly_burst() {
        // the O(n_tasks) precomputed slot table must produce exactly the
        // fates the per-task peer walk does — for burst and non-burst
        for spec in [
            "failures:p=0.35,retries=3,burst=executor,seed=9",
            "failures:p=0.35,retries=3,seed=9",
            "failures:p=0.5,burst=executor,seed=2+stragglers:p=0.3,slow=4x",
        ] {
            let sc = ClusterScenario::parse(spec).unwrap();
            for (n_tasks, cores) in [(1usize, 1usize), (9, 4), (12, 5), (6, 8)] {
                let mut slots = Vec::new();
                for step in 0..3 {
                    sc.burst_slots_into(step, n_tasks, cores, &mut slots);
                    for task in 0..n_tasks {
                        let a = sc.perturb_grid(step, task, n_tasks, cores, 1.0, false);
                        let b = sc.perturb_slotted(step, task, cores, &slots, 1.0, false);
                        assert_eq!(a, b, "{spec} step={step} task={task}");
                    }
                }
            }
        }
    }

    #[test]
    fn burst_without_grid_context_degrades_to_iid() {
        let burst =
            ClusterScenario::parse("failures:p=0.5,burst=executor,seed=3").unwrap();
        let iid = ClusterScenario { failure_burst: false, ..burst.clone() };
        for task in 0..32 {
            assert_eq!(
                burst.perturb(1, task, 1.0, false),
                iid.perturb(1, task, 1.0, false)
            );
        }
    }

    #[test]
    fn non_finite_base_is_clamped() {
        let sc = ClusterScenario::parse("stragglers:p=1,slow=10x").unwrap();
        assert_eq!(sc.perturb(0, 0, f64::NAN, false).duration, 0.0);
        assert_eq!(sc.perturb(0, 0, f64::INFINITY, false).duration, 0.0);
        assert_eq!(sc.perturb(0, 0, -1.0, false).duration, 0.0);
    }

    #[test]
    fn label_round_trips_through_parse() {
        for spec in [
            "stragglers:p=0.1,slow=10x",
            "stragglers:p=0.3,slow=10x,spec",
            "hetero:frac=0.25,speed=0.5",
            "failures:p=0.05",
            "failures:p=0.05,spec",
            "failures:p=0.1,burst=executor",
            "stragglers:p=0.2,slow=4x+failures:p=0.1",
        ] {
            let sc = ClusterScenario::parse(spec).unwrap();
            let relabeled = ClusterScenario::parse(
                sc.label().split(" (seed").next().unwrap(),
            )
            .unwrap();
            assert_eq!(sc, relabeled, "{spec}");
        }
        assert_eq!(ClusterScenario::ideal().label(), "ideal");
    }
}
