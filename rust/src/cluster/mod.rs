//! The cluster substrates and the superstep execution engine.
//!
//! The paper's testbed is a 4-node × 8-core Spark/Hadoop cluster.  Two
//! substrates run its superstep contract here, selected by
//! [`ClusterMode`] and abstracted behind the [`ClusterBackend`] trait so
//! `coordinator/{d3ca,radisa,admm}` are substrate-blind:
//!
//! * **sim** ([`SimBackend`]/[`SimCluster`], the default) — everything
//!   in-process: the *cost model* is simulated while the *work* is real
//!   (DESIGN.md §Substitutions);
//! * **dist** ([`dist::DistCluster`] + `ddopt executor`) — a real
//!   multi-process runtime: executor processes cache their grid blocks
//!   once, then execute typed [`GridOp`] superstep descriptors shipped
//!   over a length-prefixed TCP protocol ([`dist::wire`]), reporting
//!   measured per-task seconds back into the *same* simulated-clock
//!   accounting, plus real wall-clock and bytes-on-wire per superstep
//!   ([`crate::metrics::WireRecord`]).  Final weights are bit-identical
//!   to the sim backend at the same seed (`tests/dist_parity.rs`).
//!
//! The shared machinery:
//!
//! * [`backend::GridOp`] — the typed, shippable superstep descriptor:
//!   which per-partition kernel to run plus the small state payloads it
//!   borrows; task output positions are a pure function of the task
//!   index and grid geometry, which is what makes runs bit-reproducible
//!   across thread counts *and* substrates.
//! * [`superstep::StepPlan`] + [`SimCluster::grid_step`] — the boxed
//!   closure superstep API (tests, benches, and the legacy baseline):
//!   one independent task per partition, executed for real on the worker
//!   pool, combined in task order.
//! * [`pool::WorkerPool`] — a persistent worker runtime: long-lived OS
//!   worker threads (spawned once, parked between supersteps) execute
//!   the per-partition tasks of each superstep via an epoch-fenced
//!   raw-pointer handoff (parallel when `threads > 1`, inline otherwise
//!   — identical results either way, and zero steady-state allocations
//!   at any thread count).
//! * [`SimClock`] — the simulated parallel clock: each superstep
//!   contributes the *makespan* of its per-task compute costs scheduled
//!   LPT onto `cores` executor slots, not the host wall time.
//! * [`comm`] — `tree_aggregate`, Spark's reduction pattern: log₂-depth
//!   binary combining with a latency + bandwidth cost model, plus
//!   data-free variants ([`SimCluster::reduce_cost`],
//!   [`SimCluster::broadcast_cost`]) for collectives whose payload never
//!   materializes in the simulation.
//! * [`scenario::ClusterScenario`] — cluster-condition injection:
//!   heterogeneous executor speeds, seeded stragglers, and task
//!   failure/retry, all deterministic from a scenario seed and strictly
//!   cost-side (iterates are never perturbed).
//!
//! Every reported "time" in the scaling experiments (Figs. 5-6) is
//! simulated cluster time = Σ superstep makespans + modeled communication;
//! host wall time is reported separately and is what `threads` (or, on
//! the dist substrate, the executor fleet) improves.

pub mod backend;
pub mod comm;
pub mod dist;
pub mod pool;
pub mod scenario;
pub mod simtime;
pub mod superstep;

pub use backend::{
    CellMap, ClusterBackend, FoldAxis, FoldGroup, GridOp, OpScratch, Ownership, SimBackend,
};
pub use comm::{tree_aggregate, tree_aggregate_f32, CommStats};
pub use dist::DistCluster;
pub use pool::WorkerPool;
pub use scenario::{ClusterScenario, TaskFate};
pub use simtime::{
    lpt_makespan, lpt_makespan_hetero, lpt_makespan_hetero_with, LptScratch, SimClock,
};
pub use superstep::{CostModel, PlanTask, StepPlan, TaskSlab};

use anyhow::Result;

/// Which substrate executes supersteps: everything in-process against the
/// simulated cluster, or real executor processes over TCP.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub enum ClusterMode {
    /// In-process execution, simulated cluster cost model (the default).
    #[default]
    Sim,
    /// Real driver/executor processes: one TCP address per executor.
    Dist(Vec<String>),
}

impl ClusterMode {
    /// Parse a `--cluster` spec.  Valid forms:
    ///
    /// ```text
    /// sim
    /// dist:host:port[,host:port...]
    /// ```
    pub fn parse(s: &str) -> Result<ClusterMode> {
        let s = s.trim();
        if s == "sim" {
            return Ok(ClusterMode::Sim);
        }
        if let Some(rest) = s.strip_prefix("dist:") {
            let addrs: Vec<String> = rest
                .split(',')
                .map(|a| a.trim().to_string())
                .filter(|a| !a.is_empty())
                .collect();
            if addrs.is_empty() {
                anyhow::bail!(
                    "--cluster dist wants at least one executor address; valid forms are \
                     `sim` or `dist:host:port[,host:port...]`"
                );
            }
            for a in &addrs {
                if !a.contains(':') {
                    anyhow::bail!(
                        "bad executor address '{a}' (want host:port); valid forms are \
                         `sim` or `dist:host:port[,host:port...]`"
                    );
                }
            }
            return Ok(ClusterMode::Dist(addrs));
        }
        anyhow::bail!(
            "unknown cluster mode '{s}'; valid forms are `sim` or \
             `dist:host:port[,host:port...]`"
        )
    }

    /// Human-readable label that round-trips through [`ClusterMode::parse`].
    pub fn label(&self) -> String {
        match self {
            ClusterMode::Sim => "sim".into(),
            ClusterMode::Dist(addrs) => format!("dist:{}", addrs.join(",")),
        }
    }
}

/// Number of hardware threads on this host (the `threads` default).
pub fn host_threads() -> usize {
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
}

/// What the dist driver puts in each executor's Step frame.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum WireMode {
    /// Per-executor frames carry only the state slices and index streams
    /// that executor's owned tasks read, with contiguous ownership and
    /// executor-side gather folding when the whole fleet supports them
    /// (the default).
    #[default]
    Sliced,
    /// Every executor receives the identical full op payload (the
    /// pre-slicing wire behavior); no capabilities are offered in the
    /// handshake, so ownership stays round-robin and gathers unfolded.
    Broadcast,
}

impl WireMode {
    /// Parse a `--dist-wire` spec (`sliced` or `broadcast`).
    pub fn parse(s: &str) -> Result<WireMode> {
        match s.trim() {
            "sliced" => Ok(WireMode::Sliced),
            "broadcast" | "full" => Ok(WireMode::Broadcast),
            other => anyhow::bail!(
                "unknown dist wire mode '{other}'; valid forms are `sliced` or `broadcast`"
            ),
        }
    }

    pub fn label(&self) -> &'static str {
        match self {
            WireMode::Sliced => "sliced",
            WireMode::Broadcast => "broadcast",
        }
    }
}

/// One executor-side pre-fold recorded during a distributed gather: the
/// aligned leaf block `leaf .. leaf + folded` of the combine group whose
/// [`SimCluster::reduce_segments`] geometry is (`base`, `stride`,
/// `count`, `len`) was already summed into leaf `leaf` — in the global
/// tree's own pairing order — before the executor replied.
/// [`SimCluster::reduce_segments_folded`] skips exactly those pairs while
/// charging the unchanged collective cost.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct FoldEntry {
    pub base: usize,
    pub stride: usize,
    pub count: usize,
    pub len: usize,
    /// Root leaf of the pre-folded aligned block (`leaf % folded == 0`).
    pub leaf: usize,
    /// Leaves folded into the root (a power of two ≥ 2).
    pub folded: usize,
}

/// Cluster topology and cost-model parameters.
#[derive(Clone, Debug)]
pub struct ClusterConfig {
    /// Execution substrate: in-process sim (default) or TCP executors.
    pub mode: ClusterMode,
    /// Simulated executor slots (the paper's K = up to 28 cores).
    pub cores: usize,
    /// Real worker threads used to execute tasks on this host
    /// (defaults to the host's hardware parallelism).
    pub threads: usize,
    /// One-way message latency per tree hop (seconds).
    pub latency: f64,
    /// Link bandwidth (bytes/second).
    pub bandwidth: f64,
    /// How per-task compute cost is charged to the simulated clock.
    pub cost: CostModel,
    /// Cluster-condition scenario: heterogeneous slots, stragglers,
    /// failures.  Default: the ideal (perfect) cluster.
    pub scenario: ClusterScenario,
    /// Dist-substrate wire strategy (ignored by the sim substrate).
    pub wire: WireMode,
    /// Dist-substrate speculative re-execution (`--dist-spec`): gather
    /// stalls dispatch backup copies of lagging tasks to idle executors,
    /// tuned by `scenario.spec_quantile` / `scenario.spec_copies`.
    pub dist_spec: bool,
}

impl Default for ClusterConfig {
    fn default() -> Self {
        // Latency/bandwidth defaults approximate a commodity GbE cluster
        // of the paper's era: 200 µs hop latency, ~1 Gb/s effective.
        ClusterConfig {
            mode: ClusterMode::Sim,
            cores: 8,
            threads: host_threads(),
            latency: 200e-6,
            bandwidth: 125e6,
            cost: CostModel::Measured,
            scenario: ClusterScenario::ideal(),
            wire: WireMode::Sliced,
            dist_spec: false,
        }
    }
}

/// Parse the `--dist-spec` parameter string
/// (`quantile=0.75,copies=1`, any subset — an empty string takes both
/// defaults).  Returns `(spec_quantile, spec_copies)`.
pub fn parse_dist_spec(spec: &str) -> anyhow::Result<(f64, usize)> {
    let mut quantile = 0.75f64;
    let mut copies = 1usize;
    for kv in spec.split(',').filter(|s| !s.trim().is_empty()) {
        let (key, val) = kv.split_once('=').unwrap_or((kv, ""));
        let (key, val) = (key.trim(), val.trim());
        match key {
            "quantile" => {
                let v: f64 = val
                    .parse()
                    .map_err(|_| anyhow::anyhow!("bad --dist-spec quantile='{val}'"))?;
                // 1.0 is valid ("wait for everyone" — a never-arming
                // trigger); 0 or below would arm before any task finished
                if !v.is_finite() || v <= 0.0 || v > 1.0 {
                    anyhow::bail!("--dist-spec quantile must be in (0, 1], got '{val}'");
                }
                quantile = v;
            }
            "copies" => {
                let v: usize = val
                    .parse()
                    .map_err(|_| anyhow::anyhow!("bad --dist-spec copies='{val}'"))?;
                // 0 copies would be a trigger that fires and launches
                // nothing — reject it at parse time
                if v == 0 || v > 8 {
                    anyhow::bail!("--dist-spec copies must be in 1..=8, got '{val}'");
                }
                copies = v;
            }
            other => anyhow::bail!(
                "unknown --dist-spec parameter '{other}' (expected quantile/copies)"
            ),
        }
    }
    Ok((quantile, copies))
}

impl ClusterConfig {
    pub fn with_cores(cores: usize) -> Self {
        ClusterConfig { cores, ..Default::default() }
    }

    pub fn with_threads(mut self, threads: usize) -> Self {
        self.threads = threads;
        self
    }

    pub fn with_scenario(mut self, scenario: ClusterScenario) -> Self {
        self.scenario = scenario;
        self
    }
}

/// A simulated cluster: task execution + clock + communication accounting.
pub struct SimCluster {
    pub config: ClusterConfig,
    pub clock: SimClock,
    pool: WorkerPool,
    born: std::time::Instant,
    /// Sanitized per-slot speed factors, cached because computing them per
    /// superstep was pure allocator churn; `speeds_key` tracks the
    /// `(cores, hetero)` inputs so a caller mutating the pub `config`
    /// after construction still takes effect on the next superstep.
    speeds: Vec<f64>,
    speeds_key: (usize, u64, u64),
    /// Per-task durations of the superstep in flight (reused).
    dur_buf: Vec<f64>,
    /// Clean (unperturbed) per-task base costs of the superstep in
    /// flight — the speculation model draws backup-copy durations from
    /// these (reused; parallel to `dur_buf`).
    base_buf: Vec<f64>,
    /// Sort scratch for the speculation arm-quantile (reused).
    spec_buf: Vec<f64>,
    /// Burst-failure per-slot worst coins of the superstep in flight
    /// (reused; empty unless the scenario has `failures:burst=executor`).
    burst_buf: Vec<usize>,
    /// LPT scheduler working memory (reused).
    lpt: LptScratch,
}

impl SimCluster {
    pub fn new(config: ClusterConfig) -> Self {
        let pool = WorkerPool::new(config.threads);
        let mut cluster = SimCluster {
            config,
            clock: SimClock::new(),
            pool,
            born: std::time::Instant::now(),
            speeds: Vec::new(),
            speeds_key: (usize::MAX, 0, 0),
            dur_buf: Vec::new(),
            base_buf: Vec::new(),
            spec_buf: Vec::new(),
            burst_buf: Vec::new(),
            lpt: LptScratch::default(),
        };
        cluster.refresh_speeds();
        cluster
    }

    /// Precompute the superstep's burst-failure slot table (empty —
    /// and allocation-free — unless the scenario runs
    /// `failures:burst=executor`): one O(n_tasks) pass here keeps the
    /// per-task perturbation O(1) instead of re-walking slot peers.
    fn refresh_burst(&mut self, step: usize, n_tasks: usize) {
        self.config.scenario.burst_slots_into(
            step,
            n_tasks,
            self.config.cores,
            &mut self.burst_buf,
        );
    }

    /// Key of the inputs `speeds` was computed from.
    fn current_speeds_key(&self) -> (usize, u64, u64) {
        (
            self.config.cores,
            self.config.scenario.hetero_frac.to_bits(),
            self.config.scenario.hetero_speed.to_bits(),
        )
    }

    /// Recompute the cached sanitized slot speeds if `config` changed —
    /// three compares per superstep at steady state, an allocation only
    /// when a caller actually mutates `cores`/the hetero scenario.
    fn refresh_speeds(&mut self) {
        let key = self.current_speeds_key();
        if key != self.speeds_key {
            self.speeds = self
                .config
                .scenario
                .speeds(self.config.cores)
                .into_iter()
                .map(simtime::sane_speed)
                .collect();
            self.speeds_key = key;
        }
    }

    /// Host worker threads actually in use.
    pub fn threads(&self) -> usize {
        self.pool.threads()
    }

    /// Bring the persistent worker pool up now (it otherwise spawns its
    /// workers lazily on the first parallel superstep) — lets timed runs
    /// pay the one-time pool bring-up, the only allocation the parallel
    /// steady state is allowed, before measurement starts.
    pub fn warm_up(&self) {
        self.pool.warm_up();
    }

    /// Host wall-clock seconds since this cluster was created — the
    /// *real* elapsed time `threads` improves, as opposed to the
    /// simulated [`SimClock`] time the cost model produces.
    pub fn host_secs(&self) -> f64 {
        self.born.elapsed().as_secs_f64()
    }

    /// Execute one superstep plan of independent per-partition tasks on
    /// the worker pool; returns results in task order (never completion
    /// order, so downstream combining is bit-deterministic).
    ///
    /// Advances the simulated clock by the LPT makespan of the per-task
    /// costs over `cores` slots.  The active [`ClusterScenario`] perturbs
    /// the *costs only* — per-task straggler/failure charges keyed by
    /// `(scenario seed, superstep index, task index)` and per-slot speed
    /// factors in the scheduler — so results and iterates stay bit
    /// identical across scenarios and `threads` settings.  The first task
    /// error aborts the step.
    pub fn grid_step<'env, V: Send>(&mut self, plan: StepPlan<'env, V>) -> Result<Vec<V>> {
        if plan.is_empty() {
            return Ok(Vec::new());
        }
        let tolerant = plan.is_tolerant();
        self.refresh_speeds();
        let step = self.clock.supersteps();
        let timed = self.pool.run(plan.into_tasks());
        let n_tasks = timed.len();
        self.refresh_burst(step, n_tasks);
        self.dur_buf.clear();
        self.base_buf.clear();
        let mut out = Vec::with_capacity(timed.len());
        let mut first_err = None;
        let (mut stragglers, mut failures) = (0usize, 0usize);
        for (task, (result, measured)) in timed.into_iter().enumerate() {
            let base = match self.config.cost {
                CostModel::Measured => measured,
                CostModel::Fixed(s) => s,
            };
            let fate = self.config.scenario.perturb_slotted(
                step,
                task,
                self.config.cores,
                &self.burst_buf,
                base,
                tolerant,
            );
            self.dur_buf.push(fate.duration);
            self.base_buf.push(base);
            stragglers += usize::from(fate.straggled);
            failures += fate.extra_attempts;
            match result {
                Ok(v) => out.push(v),
                Err(e) => {
                    if first_err.is_none() {
                        first_err = Some(e);
                    }
                }
            }
        }
        // superstep-level speculation: rescue laggards past the arm
        // quantile with seeded backup-copy draws (no-op unless the
        // scenario is speculative — see ClusterScenario::speculate)
        self.config.scenario.speculate(
            step,
            &mut self.dur_buf,
            &self.base_buf,
            &mut self.spec_buf,
            tolerant,
        );
        let makespan = lpt_makespan_hetero_with(&mut self.lpt, &self.dur_buf, &self.speeds);
        self.clock.add_compute(makespan);
        self.clock.add_injections(stragglers, failures);
        match first_err {
            Some(e) => Err(e),
            None => Ok(out),
        }
    }

    /// The zero-allocation superstep: `f(task, scratch)` runs once per
    /// task index in `0..n_tasks` on the worker pool, writing its output
    /// into a caller-owned [`TaskSlab`] segment instead of returning a
    /// vector, and reusing one caller-owned scratch cell per worker
    /// thread.  Steady-state iterations built on this path (plus
    /// [`SimCluster::reduce_segments`]) allocate nothing.
    ///
    /// Clock, scenario and determinism semantics are identical to
    /// [`SimCluster::grid_step`]: per-task costs (measured or fixed) are
    /// perturbed by the active scenario keyed on `(seed, superstep,
    /// task)`, the LPT makespan over the cached slot speeds advances the
    /// simulated clock even when a task errors, and outputs land at
    /// positions derived from the task index alone — never the schedule —
    /// so results are bit-identical at any `threads`.  The error with the
    /// lowest task index wins, mirroring `grid_step`'s first-error rule.
    #[cfg(not(feature = "xla"))]
    pub fn grid_step_into<S: Send>(
        &mut self,
        n_tasks: usize,
        tolerant: bool,
        scratch: &mut [S],
        f: impl Fn(usize, &mut S) -> Result<()> + Sync,
    ) -> Result<()> {
        if n_tasks == 0 {
            return Ok(());
        }
        self.refresh_speeds();
        let step = self.clock.supersteps();
        self.dur_buf.clear();
        self.dur_buf.resize(n_tasks, 0.0);
        let ran = self.pool.run_indexed(n_tasks, scratch, &mut self.dur_buf, f);
        self.charge_superstep(step, n_tasks, tolerant);
        ran
    }

    /// [`SimCluster::grid_step_into`] for the thread-confined `xla` build:
    /// same semantics, inline execution, no `Sync` bound.
    #[cfg(feature = "xla")]
    pub fn grid_step_into<S: Send>(
        &mut self,
        n_tasks: usize,
        tolerant: bool,
        scratch: &mut [S],
        f: impl Fn(usize, &mut S) -> Result<()>,
    ) -> Result<()> {
        if n_tasks == 0 {
            return Ok(());
        }
        self.refresh_speeds();
        let step = self.clock.supersteps();
        self.dur_buf.clear();
        self.dur_buf.resize(n_tasks, 0.0);
        let ran = self.pool.run_indexed(n_tasks, scratch, &mut self.dur_buf, f);
        self.charge_superstep(step, n_tasks, tolerant);
        ran
    }

    /// Shared clock/scenario accounting of one `grid_step_into` superstep:
    /// perturb the measured durations in `dur_buf`, schedule them LPT over
    /// the cached slot speeds, and advance the clock.
    fn charge_superstep(&mut self, step: usize, n_tasks: usize, tolerant: bool) {
        self.refresh_burst(step, n_tasks);
        self.base_buf.clear();
        let (mut stragglers, mut failures) = (0usize, 0usize);
        for task in 0..n_tasks {
            let base = match self.config.cost {
                CostModel::Measured => self.dur_buf[task],
                CostModel::Fixed(s) => s,
            };
            let fate = self.config.scenario.perturb_slotted(
                step,
                task,
                self.config.cores,
                &self.burst_buf,
                base,
                tolerant,
            );
            self.dur_buf[task] = fate.duration;
            self.base_buf.push(base);
            stragglers += usize::from(fate.straggled);
            failures += fate.extra_attempts;
        }
        // superstep-level speculation on the perturbed durations — the
        // same model the dist clock flows through via charge_measured,
        // which is what keeps sim and dist speculation clocks in step
        self.config.scenario.speculate(
            step,
            &mut self.dur_buf,
            &self.base_buf,
            &mut self.spec_buf,
            tolerant,
        );
        let makespan = lpt_makespan_hetero_with(&mut self.lpt, &self.dur_buf, &self.speeds);
        self.clock.add_compute(makespan);
        self.clock.add_injections(stragglers, failures);
    }

    /// Charge one superstep whose per-task durations were measured
    /// *elsewhere* (the distributed backend's executors report real task
    /// times over the wire): identical scenario perturbation, LPT
    /// scheduling and clock accounting as [`SimCluster::grid_step_into`].
    pub(crate) fn charge_measured(&mut self, durations: &[f64], tolerant: bool) {
        if durations.is_empty() {
            return;
        }
        self.refresh_speeds();
        let step = self.clock.supersteps();
        self.dur_buf.clear();
        self.dur_buf.extend_from_slice(durations);
        self.charge_superstep(step, durations.len(), tolerant);
    }

    /// In-place grouped treeAggregate over a workspace slab: segment `k`
    /// (of `count`, each `len` long) starts at `slab[base + k * stride]`;
    /// the sum lands in segment 0.
    ///
    /// Combining follows exactly the binary-tree pairing of
    /// [`tree_aggregate_f32`] — level by level, adjacent survivors, `dst
    /// += src` element-wise — so the result bits and the charged
    /// [`CommStats`] (time, bytes, messages) match what
    /// [`SimCluster::reduce_sum`] would produce for the same `count`
    /// equal-length vectors, without materializing them.
    pub fn reduce_segments(
        &mut self,
        slab: &mut [f32],
        base: usize,
        stride: usize,
        count: usize,
        len: usize,
    ) {
        self.reduce_segments_folded(slab, base, stride, count, len, &[]);
    }

    /// [`SimCluster::reduce_segments`] for a gather whose executors
    /// pre-folded some aligned subtrees (see [`FoldEntry`]): pairs fully
    /// inside a logged block are *skipped* — their `dst += src` already
    /// happened executor-side, in this exact pairing order — but every
    /// pair is still *charged*, because the modeled collective cost
    /// depends on the tree layout, not on where each add physically ran;
    /// the sim and dist clocks must stay bit-identical.
    pub fn reduce_segments_folded(
        &mut self,
        slab: &mut [f32],
        base: usize,
        stride: usize,
        count: usize,
        len: usize,
        fold_log: &[FoldEntry],
    ) {
        assert!(len <= stride || count <= 1, "segments must not overlap");
        if count <= 1 {
            return; // single leaf is free, like reduce_sum
        }
        assert!(base + (count - 1) * stride + len <= slab.len());
        // full-geometry match so a log holding entries for *other* groups
        // of the same gather (other p's delta group, other q's column
        // group) can never suppress a pair of this one
        let prefolded = |i: usize, j: usize| {
            fold_log.iter().any(|e| {
                e.base == base
                    && e.stride == stride
                    && e.count == count
                    && e.len == len
                    && e.leaf <= i
                    && j < e.leaf + e.folded
            })
        };
        let mut stats = CommStats::default();
        let mut gap = 1usize;
        while gap < count {
            let mut pairs = 0usize;
            let mut i = 0usize;
            while i + gap < count {
                if !prefolded(i, i + gap) {
                    let dst = base + i * stride;
                    let src = base + (i + gap) * stride;
                    let (head, tail) = slab.split_at_mut(src);
                    let d = &mut head[dst..dst + len];
                    let s = &tail[..len];
                    for (dv, &sv) in d.iter_mut().zip(s) {
                        *dv += sv;
                    }
                }
                pairs += 1;
                i += 2 * gap;
            }
            let level_bytes = pairs * len * std::mem::size_of::<f32>();
            // bit-identical to tree_aggregate's per-level charge
            stats.time += self.config.latency
                + level_bytes as f64 / self.config.bandwidth / (pairs.max(1) as f64);
            stats.bytes += level_bytes;
            stats.messages += pairs;
            gap *= 2;
        }
        self.clock.add_comm(stats);
    }

    /// Aggregate per-partition f32 vectors by summation over a binary tree,
    /// charging the communication model (`parts.len()` = leaves).
    pub fn reduce_sum(&mut self, mut parts: Vec<Vec<f32>>) -> Vec<f32> {
        let stats = tree_aggregate_f32(&mut parts, self.config.latency, self.config.bandwidth);
        self.clock.add_comm(stats);
        parts.into_iter().next().unwrap_or_default()
    }

    /// Reduce grid results over the feature axis: `parts` holds one vector
    /// per `(p, q)` cell in row-major order (`parts[p*qq + q]`); returns
    /// one tree-aggregated vector per observation partition `p`.
    ///
    /// This is the collective behind D3CA's dual averaging and RADiSA's
    /// margin assembly (`m[p] = Σ_q x[p,q] w[·,q]`).
    pub fn reduce_over_q(
        &mut self,
        parts: Vec<Vec<f32>>,
        pp: usize,
        qq: usize,
    ) -> Vec<Vec<f32>> {
        assert_eq!(parts.len(), pp * qq, "grid results must cover the P×Q grid");
        let mut it = parts.into_iter();
        (0..pp)
            .map(|_| {
                let group: Vec<Vec<f32>> = it.by_ref().take(qq).collect();
                self.reduce_sum(group)
            })
            .collect()
    }

    /// Reduce grid results over the observation axis: `parts` holds one
    /// vector per `(p, q)` cell in row-major order (`parts[p*qq + q]`);
    /// returns one tree-aggregated vector per feature partition `q`.
    ///
    /// This is the collective behind D3CA's primal recovery
    /// (`w[·,q] = (λn)⁻¹ Σ_p x[p,q]ᵀ α[p,·]`) and RADiSA's full gradient.
    pub fn reduce_over_p(
        &mut self,
        parts: Vec<Vec<f32>>,
        pp: usize,
        qq: usize,
    ) -> Vec<Vec<f32>> {
        assert_eq!(parts.len(), pp * qq, "grid results must cover the P×Q grid");
        let mut parts: Vec<Option<Vec<f32>>> = parts.into_iter().map(Some).collect();
        (0..qq)
            .map(|q| {
                let group: Vec<Vec<f32>> = (0..pp)
                    .map(|p| parts[p * qq + q].take().expect("cell consumed once"))
                    .collect();
                self.reduce_sum(group)
            })
            .collect()
    }

    /// Charge the cost of tree-aggregating `leaves` equal payloads of
    /// `bytes_per_leaf` bytes *without* moving any data — for collectives
    /// whose payload is implicit in the shared-memory simulation.  Charges
    /// exactly what [`SimCluster::reduce_sum`] would for equal-length
    /// vectors (same time, bytes and message count).
    pub fn reduce_cost(&mut self, leaves: usize, bytes_per_leaf: usize) {
        let mut stats = CommStats::default();
        let mut k = leaves;
        while k > 1 {
            let pairs = k / 2;
            let level_bytes = pairs * bytes_per_leaf;
            // bit-identical to tree_aggregate's per-level charge
            stats.time += self.config.latency
                + level_bytes as f64 / self.config.bandwidth / (pairs.max(1) as f64);
            stats.bytes += level_bytes;
            stats.messages += pairs;
            k -= pairs;
        }
        self.clock.add_comm(stats);
    }

    /// Charge a broadcast of `bytes` from the leader to `fanout` nodes
    /// (tree-structured, like Spark's torrent broadcast).
    pub fn broadcast_cost(&mut self, bytes: usize, fanout: usize) {
        let depth = (fanout.max(1) as f64).log2().ceil().max(1.0);
        let t = depth * (self.config.latency + bytes as f64 / self.config.bandwidth);
        self.clock.add_comm(CommStats { time: t, bytes: bytes * fanout.max(1), messages: fanout });
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg(threads: usize, cores: usize) -> ClusterConfig {
        ClusterConfig { threads, cores, ..Default::default() }
    }

    #[test]
    fn grid_step_returns_in_order_and_advances_clock() {
        let mut c = SimCluster::new(cfg(2, 4));
        let mut plan: StepPlan<'_, usize> = StepPlan::with_capacity(8);
        for i in 0..8usize {
            plan.task(move || Ok(i * i));
        }
        let out = c.grid_step(plan).unwrap();
        assert_eq!(out, vec![0, 1, 4, 9, 16, 25, 36, 49]);
        assert!(c.clock.compute_time() > 0.0);
        assert_eq!(c.clock.supersteps(), 1);
    }

    #[test]
    fn grid_step_tasks_borrow_shared_state() {
        let weights: Vec<f32> = (0..64).map(|i| i as f32).collect();
        let mut c = SimCluster::new(cfg(4, 4));
        let mut plan: StepPlan<'_, f32> = StepPlan::new();
        for k in 0..8 {
            let w = &weights;
            plan.task(move || Ok(w[k * 8..(k + 1) * 8].iter().sum()));
        }
        let out = c.grid_step(plan).unwrap();
        let total: f32 = out.iter().sum();
        assert_eq!(total, weights.iter().sum());
    }

    #[test]
    fn grid_step_propagates_task_errors() {
        let mut c = SimCluster::new(cfg(1, 4));
        let mut plan: StepPlan<'_, usize> = StepPlan::new();
        plan.task(|| Ok(1));
        plan.task(|| anyhow::bail!("partition exploded"));
        plan.task(|| Ok(3));
        let err = c.grid_step(plan).unwrap_err();
        assert!(err.to_string().contains("partition exploded"));
    }

    #[test]
    fn empty_plan_is_free() {
        let mut c = SimCluster::new(cfg(2, 4));
        let plan: StepPlan<'_, usize> = StepPlan::new();
        let out = c.grid_step(plan).unwrap();
        assert!(out.is_empty());
        assert_eq!(c.clock.supersteps(), 0);
        assert_eq!(c.clock.now(), 0.0);
    }

    #[test]
    fn fixed_cost_model_is_thread_invariant() {
        let run = |threads: usize| -> f64 {
            let mut config = cfg(threads, 4);
            config.cost = CostModel::Fixed(1e-3);
            let mut c = SimCluster::new(config);
            let mut plan: StepPlan<'_, u64> = StepPlan::new();
            for i in 0..9u64 {
                plan.task(move || Ok(i.wrapping_mul(0x9E3779B9)));
            }
            let _ = c.grid_step(plan).unwrap();
            c.clock.now()
        };
        let t1 = run(1);
        let t4 = run(4);
        assert_eq!(t1, t4);
        // 9 tasks of 1 ms over 4 slots: LPT packs 3 per slot
        assert!((t1 - 3e-3).abs() < 1e-12);
    }

    #[test]
    fn scenario_inflates_blocking_steps_only() {
        let run = |tolerant: bool| -> (f64, usize) {
            let mut config = cfg(1, 4);
            config.cost = CostModel::Fixed(1e-3);
            config.scenario = ClusterScenario::parse("stragglers:p=1,slow=4x,seed=2").unwrap();
            let mut c = SimCluster::new(config);
            let mut plan: StepPlan<'_, usize> = StepPlan::new();
            for i in 0..4usize {
                plan.task(move || Ok(i));
            }
            if tolerant {
                plan.mark_tolerant();
            }
            let _ = c.grid_step(plan).unwrap();
            (c.clock.compute_time(), c.clock.stragglers())
        };
        let (blocking, hits_b) = run(false);
        let (tolerant, hits_t) = run(true);
        // p=1: every task straggles 4x; 4 tasks over 4 slots
        assert!((blocking - 4e-3).abs() < 1e-12, "blocking {blocking}");
        assert!((tolerant - 1e-3).abs() < 1e-12, "tolerant {tolerant}");
        assert_eq!(hits_b, 4);
        assert_eq!(hits_t, 4, "injections are counted either way");
    }

    #[test]
    fn hetero_scenario_slows_the_clock() {
        let run = |spec: &str| -> f64 {
            let mut config = cfg(1, 2);
            config.cost = CostModel::Fixed(1e-3);
            config.scenario = ClusterScenario::parse(spec).unwrap();
            let mut c = SimCluster::new(config);
            let mut plan: StepPlan<'_, usize> = StepPlan::new();
            for i in 0..4usize {
                plan.task(move || Ok(i));
            }
            let _ = c.grid_step(plan).unwrap();
            c.clock.compute_time()
        };
        let ideal = run("ideal");
        let hetero = run("hetero:frac=0.5,speed=0.5");
        assert!((ideal - 2e-3).abs() < 1e-12);
        assert!(hetero > ideal, "hetero {hetero} vs ideal {ideal}");
    }

    #[test]
    fn failure_scenario_recharges_tasks() {
        let mut config = cfg(1, 1);
        config.cost = CostModel::Fixed(1e-3);
        config.scenario = ClusterScenario::parse("failures:p=1,retries=2,seed=3").unwrap();
        let mut c = SimCluster::new(config);
        let mut plan: StepPlan<'_, usize> = StepPlan::new();
        plan.task(|| Ok(7));
        let out = c.grid_step(plan).unwrap();
        assert_eq!(out, vec![7], "results are never perturbed");
        // p=1, retries=2: 2 extra attempts, 3 charges of 1 ms on one slot
        assert!((c.clock.compute_time() - 3e-3).abs() < 1e-12);
        assert_eq!(c.clock.failures(), 2);
    }

    #[test]
    fn grid_step_into_matches_grid_step_clock_and_results() {
        let run_boxed = |threads: usize| {
            let mut config = cfg(threads, 4);
            config.cost = CostModel::Fixed(2e-3);
            config.scenario = ClusterScenario::parse("stragglers:p=0.5,slow=3x,seed=9").unwrap();
            let mut c = SimCluster::new(config);
            let mut plan: StepPlan<'_, f32> = StepPlan::new();
            for i in 0..10usize {
                plan.task(move || Ok((i * i) as f32));
            }
            let out = c.grid_step(plan).unwrap();
            (out, c.clock.now(), c.clock.stragglers())
        };
        let run_into = |threads: usize| {
            let mut config = cfg(threads, 4);
            config.cost = CostModel::Fixed(2e-3);
            config.scenario = ClusterScenario::parse("stragglers:p=0.5,slow=3x,seed=9").unwrap();
            let mut c = SimCluster::new(config);
            let mut out = vec![0.0f32; 10];
            let mut scratch = vec![(); c.threads()];
            {
                let slab = TaskSlab::new(&mut out);
                c.grid_step_into(10, false, &mut scratch, |i, _s| {
                    unsafe { slab.write(i, (i * i) as f32) };
                    Ok(())
                })
                .unwrap();
            }
            (out, c.clock.now(), c.clock.stragglers())
        };
        let (ob, tb, sb) = run_boxed(1);
        for threads in [1usize, 4] {
            let (oi, ti, si) = run_into(threads);
            assert_eq!(ob, oi, "threads {threads}");
            assert_eq!(tb.to_bits(), ti.to_bits(), "threads {threads}");
            assert_eq!(sb, si, "threads {threads}");
        }
    }

    #[test]
    fn grid_step_into_still_charges_clock_on_error() {
        let mut config = cfg(1, 2);
        config.cost = CostModel::Fixed(1e-3);
        let mut c = SimCluster::new(config);
        let mut scratch = vec![(); 1];
        let err = c
            .grid_step_into(4, false, &mut scratch, |i, _s| {
                if i >= 2 {
                    anyhow::bail!("partition {i} exploded");
                }
                Ok(())
            })
            .unwrap_err();
        assert!(err.to_string().contains("partition 2"));
        assert_eq!(c.clock.supersteps(), 1);
        assert!((c.clock.compute_time() - 2e-3).abs() < 1e-12);
    }

    #[test]
    fn mutating_config_after_construction_takes_effect() {
        let mut config = cfg(1, 2);
        config.cost = CostModel::Fixed(1e-3);
        let mut c = SimCluster::new(config);
        // the cached speeds must refresh when a caller mutates the pub
        // config between supersteps
        c.config.scenario = ClusterScenario::parse("hetero:frac=1.0,speed=0.5").unwrap();
        let mut plan: StepPlan<'_, usize> = StepPlan::new();
        for i in 0..2usize {
            plan.task(move || Ok(i));
        }
        let _ = c.grid_step(plan).unwrap();
        // both slots half speed: 2 tasks of 1 ms over 2 slots -> 2 ms
        assert!((c.clock.compute_time() - 2e-3).abs() < 1e-12, "{}", c.clock.compute_time());
    }

    #[test]
    fn reduce_segments_matches_reduce_sum_bitwise() {
        for count in [1usize, 2, 3, 5, 6, 8, 13] {
            let len = 7usize;
            let stride = 9usize; // padded layout exercises stride > len
            let mut rng = crate::util::rng::Xoshiro::new(count as u64);
            let parts: Vec<Vec<f32>> = (0..count)
                .map(|_| (0..len).map(|_| rng.range_f32(-1.0, 1.0)).collect())
                .collect();
            let mut real = SimCluster::new(ClusterConfig::default());
            let expect = real.reduce_sum(parts.clone());

            let mut slab = vec![0.0f32; 3 + count * stride];
            for (k, part) in parts.iter().enumerate() {
                slab[3 + k * stride..3 + k * stride + len].copy_from_slice(part);
            }
            let mut inplace = SimCluster::new(ClusterConfig::default());
            inplace.reduce_segments(&mut slab, 3, stride, count, len);
            for e in 0..len {
                assert_eq!(
                    expect[e].to_bits(),
                    slab[3 + e].to_bits(),
                    "count={count} elem={e}"
                );
            }
            assert_eq!(real.clock.comm_time(), inplace.clock.comm_time(), "count={count}");
            assert_eq!(real.clock.comm_bytes(), inplace.clock.comm_bytes(), "count={count}");
            assert_eq!(real.clock.messages(), inplace.clock.messages(), "count={count}");
        }
    }

    #[test]
    fn reduce_segments_folded_matches_with_prefolded_blocks() {
        // an executor owning leaves [2,6) of a 7-leaf group pre-folds the
        // aligned blocks {2,3} and {4,5} exactly like the global tree
        // would; the driver-side folded reduce must then produce a
        // bit-identical slab and charge the identical collective cost
        let (count, len) = (7usize, 5usize);
        let (base, stride) = (2usize, len);
        let mut rng = crate::util::rng::Xoshiro::new(42);
        let mut slab = vec![0.0f32; base + count * stride];
        for v in slab.iter_mut() {
            *v = rng.range_f32(-1.0, 1.0);
        }
        let mut plain = slab.clone();
        let mut a = SimCluster::new(ClusterConfig::default());
        a.reduce_segments(&mut plain, base, stride, count, len);

        let mut log = Vec::new();
        for root in [2usize, 4] {
            let (d0, s0) = (base + root * stride, base + (root + 1) * stride);
            for e in 0..len {
                slab[d0 + e] += slab[s0 + e];
            }
            log.push(FoldEntry { base, stride, count, len, leaf: root, folded: 2 });
        }
        // entries for a *different* group must not suppress anything here
        log.push(FoldEntry { base: 99, stride, count, len, leaf: 0, folded: 4 });
        let mut b = SimCluster::new(ClusterConfig::default());
        b.reduce_segments_folded(&mut slab, base, stride, count, len, &log);
        for (i, (x, y)) in plain.iter().zip(&slab).enumerate() {
            assert_eq!(x.to_bits(), y.to_bits(), "slab[{i}]");
        }
        assert_eq!(a.clock.comm_time(), b.clock.comm_time());
        assert_eq!(a.clock.comm_bytes(), b.clock.comm_bytes());
        assert_eq!(a.clock.messages(), b.clock.messages());
    }

    #[test]
    fn reduce_segments_folded_fully_prefolded_group_is_a_charged_noop() {
        // one executor owned every leaf and folded the whole 4-leaf group:
        // the driver does zero arithmetic but charges the full tree
        let (count, len, base) = (4usize, 3usize, 0usize);
        let stride = len;
        let mut rng = crate::util::rng::Xoshiro::new(7);
        let mut slab = vec![0.0f32; count * stride];
        for v in slab.iter_mut() {
            *v = rng.range_f32(-1.0, 1.0);
        }
        let mut plain = slab.clone();
        let mut a = SimCluster::new(ClusterConfig::default());
        a.reduce_segments(&mut plain, base, stride, count, len);
        // executor-side fold, in the global tree's own order
        for (dst, src) in [(0usize, 1usize), (2, 3), (0, 2)] {
            for e in 0..len {
                slab[dst * stride + e] += slab[src * stride + e];
            }
        }
        let log = [FoldEntry { base, stride, count, len, leaf: 0, folded: 4 }];
        let before = slab.clone();
        let mut b = SimCluster::new(ClusterConfig::default());
        b.reduce_segments_folded(&mut slab, base, stride, count, len, &log);
        assert_eq!(slab, before, "every pair must be skipped");
        for e in 0..len {
            assert_eq!(plain[e].to_bits(), slab[e].to_bits(), "root segment elem {e}");
        }
        assert_eq!(a.clock.comm_time(), b.clock.comm_time());
        assert_eq!(a.clock.comm_bytes(), b.clock.comm_bytes());
        assert_eq!(a.clock.messages(), b.clock.messages());
    }

    #[test]
    fn wire_mode_parses_and_defaults_to_sliced() {
        assert_eq!(WireMode::parse("sliced").unwrap(), WireMode::Sliced);
        assert_eq!(WireMode::parse("broadcast").unwrap(), WireMode::Broadcast);
        assert_eq!(WireMode::parse("full").unwrap(), WireMode::Broadcast);
        assert!(WireMode::parse("carrier-pigeon").is_err());
        assert_eq!(WireMode::default(), WireMode::Sliced);
        assert_eq!(ClusterConfig::default().wire, WireMode::Sliced);
        for m in [WireMode::Sliced, WireMode::Broadcast] {
            assert_eq!(WireMode::parse(m.label()).unwrap(), m);
        }
    }

    #[test]
    fn reduce_sum_sums() {
        let mut c = SimCluster::new(ClusterConfig::default());
        let parts = vec![vec![1.0, 2.0], vec![10.0, 20.0], vec![100.0, 200.0]];
        let s = c.reduce_sum(parts);
        assert_eq!(s, vec![111.0, 222.0]);
        assert!(c.clock.comm_time() > 0.0);
        assert!(c.clock.comm_bytes() > 0);
    }

    #[test]
    fn reduce_over_q_groups_rows() {
        let mut c = SimCluster::new(ClusterConfig::default());
        // 2x3 grid: row p contributes [p+1] from each of 3 cells
        let parts: Vec<Vec<f32>> = (0..2)
            .flat_map(|p| (0..3).map(move |_| vec![(p + 1) as f32]))
            .collect();
        let rows = c.reduce_over_q(parts, 2, 3);
        assert_eq!(rows, vec![vec![3.0], vec![6.0]]);
    }

    #[test]
    fn reduce_over_p_groups_columns() {
        let mut c = SimCluster::new(ClusterConfig::default());
        // 3x2 grid row-major: cell (p,q) holds [10*p + q]
        let parts: Vec<Vec<f32>> = (0..3)
            .flat_map(|p| (0..2).map(move |q| vec![(10 * p + q) as f32]))
            .collect();
        let cols = c.reduce_over_p(parts, 3, 2);
        assert_eq!(cols, vec![vec![30.0], vec![33.0]]);
    }

    #[test]
    fn reduce_cost_matches_real_reduce() {
        let dim = 37usize;
        for leaves in [2usize, 3, 5, 6, 8, 13, 16] {
            let mut real = SimCluster::new(ClusterConfig::default());
            let _ = real.reduce_sum(vec![vec![0.0f32; dim]; leaves]);
            let mut pure = SimCluster::new(ClusterConfig::default());
            pure.reduce_cost(leaves, dim * std::mem::size_of::<f32>());
            assert_eq!(real.clock.comm_time(), pure.clock.comm_time(), "leaves={leaves}");
            assert_eq!(real.clock.comm_bytes(), pure.clock.comm_bytes(), "leaves={leaves}");
            assert_eq!(real.clock.messages(), pure.clock.messages(), "leaves={leaves}");
        }
    }

    #[test]
    fn reduce_cost_single_leaf_is_free() {
        let mut c = SimCluster::new(ClusterConfig::default());
        c.reduce_cost(1, 1024);
        c.reduce_cost(0, 1024);
        assert_eq!(c.clock.comm_time(), 0.0);
        assert_eq!(c.clock.comm_bytes(), 0);
    }

    #[test]
    fn broadcast_charges_more_for_more_nodes() {
        let mut a = SimCluster::new(ClusterConfig::default());
        let mut b = SimCluster::new(ClusterConfig::default());
        a.broadcast_cost(1000, 2);
        b.broadcast_cost(1000, 16);
        assert!(b.clock.comm_time() > a.clock.comm_time());
    }

    #[test]
    fn empty_reduce_is_empty() {
        let mut c = SimCluster::new(ClusterConfig::default());
        let s = c.reduce_sum(vec![]);
        assert!(s.is_empty());
    }

    #[test]
    fn cluster_mode_parses_and_round_trips() {
        assert_eq!(ClusterMode::parse("sim").unwrap(), ClusterMode::Sim);
        let m = ClusterMode::parse("dist:127.0.0.1:7001,127.0.0.1:7002").unwrap();
        assert_eq!(
            m,
            ClusterMode::Dist(vec!["127.0.0.1:7001".into(), "127.0.0.1:7002".into()])
        );
        assert_eq!(ClusterMode::parse(&m.label()).unwrap(), m);
        assert_eq!(ClusterMode::default(), ClusterMode::Sim);
    }

    #[test]
    fn cluster_mode_rejects_bad_specs_with_valid_forms() {
        for bad in ["spark", "dist:", "dist:nohostport", "distant:1:2"] {
            let err = ClusterMode::parse(bad).unwrap_err().to_string();
            assert!(err.contains("dist:host:port"), "{bad}: {err}");
        }
    }

    #[test]
    fn burst_failures_charge_at_least_iid() {
        // the grid paths feed (n_tasks, cores) context to the scenario, so
        // burst=executor must inflate (never deflate) the failure count
        let run = |spec: &str| -> usize {
            let mut config = cfg(1, 3);
            config.cost = CostModel::Fixed(1e-3);
            config.scenario = ClusterScenario::parse(spec).unwrap();
            let mut c = SimCluster::new(config);
            let mut plan: StepPlan<'_, usize> = StepPlan::new();
            for i in 0..9usize {
                plan.task(move || Ok(i));
            }
            let _ = c.grid_step(plan).unwrap();
            c.clock.failures()
        };
        let iid = run("failures:p=0.5,retries=2,seed=4");
        let burst = run("failures:p=0.5,retries=2,burst=executor,seed=4");
        assert!(burst >= iid, "burst {burst} < iid {iid}");
    }
}
