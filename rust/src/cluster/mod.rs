//! The simulated cluster substrate.
//!
//! The paper's testbed is a 4-node × 8-core Spark/Hadoop cluster; this host
//! has one core, so the cluster is *simulated* (DESIGN.md §Substitutions):
//!
//! * [`pool::WorkerPool`] — real OS worker threads + channels execute the
//!   per-partition tasks of each superstep (parallel when the host allows,
//!   sequential-deterministic otherwise).
//! * [`SimClock`] — the simulated parallel clock: each superstep
//!   contributes the *makespan* of its measured per-task compute times
//!   scheduled LPT onto `cores` executor slots, not the host wall time.
//! * [`comm`] — `tree_aggregate`, Spark's reduction pattern: log₂-depth
//!   binary combining with a latency + bandwidth cost model.
//!
//! Every reported "time" in the scaling experiments (Figs. 5-6) is
//! simulated cluster time = Σ superstep makespans + modeled communication;
//! EXPERIMENTS.md reports both sim and host wall time.

pub mod comm;
pub mod pool;
pub mod simtime;

pub use comm::{tree_aggregate, tree_aggregate_f32, CommStats};
pub use pool::WorkerPool;
pub use simtime::{lpt_makespan, SimClock};

/// Cluster topology and cost-model parameters.
#[derive(Clone, Debug)]
pub struct ClusterConfig {
    /// Simulated executor slots (the paper's K = up to 28 cores).
    pub cores: usize,
    /// Real worker threads used to execute tasks on this host.
    pub threads: usize,
    /// One-way message latency per tree hop (seconds).
    pub latency: f64,
    /// Link bandwidth (bytes/second).
    pub bandwidth: f64,
}

impl Default for ClusterConfig {
    fn default() -> Self {
        // Latency/bandwidth defaults approximate a commodity GbE cluster
        // of the paper's era: 200 µs hop latency, ~1 Gb/s effective.
        ClusterConfig {
            cores: 8,
            threads: 1,
            latency: 200e-6,
            bandwidth: 125e6,
        }
    }
}

impl ClusterConfig {
    pub fn with_cores(cores: usize) -> Self {
        ClusterConfig { cores, ..Default::default() }
    }
}

/// A simulated cluster: task execution + clock + communication accounting.
pub struct SimCluster {
    pub config: ClusterConfig,
    pub clock: SimClock,
    pool: WorkerPool,
}

impl SimCluster {
    pub fn new(config: ClusterConfig) -> Self {
        let pool = WorkerPool::new(config.threads);
        SimCluster { config, clock: SimClock::new(), pool }
    }

    /// Execute one superstep of independent per-partition tasks; returns
    /// results in task order.  Advances the simulated clock by the LPT
    /// makespan of the measured per-task times over `cores` slots.
    pub fn superstep<T: Send + 'static>(
        &mut self,
        tasks: Vec<Box<dyn FnOnce() -> T + Send>>,
    ) -> Vec<T> {
        let timed = self.pool.run(tasks);
        let durations: Vec<f64> = timed.iter().map(|(_, d)| *d).collect();
        let makespan = lpt_makespan(&durations, self.config.cores);
        self.clock.add_compute(makespan);
        timed.into_iter().map(|(v, _)| v).collect()
    }

    /// Aggregate per-partition f32 vectors by summation over a binary tree,
    /// charging the communication model (`parts.len()` = leaves).
    pub fn reduce_sum(&mut self, mut parts: Vec<Vec<f32>>) -> Vec<f32> {
        let stats = tree_aggregate_f32(&mut parts, self.config.latency, self.config.bandwidth);
        self.clock.add_comm(stats);
        parts.into_iter().next().unwrap_or_default()
    }

    /// Charge a broadcast of `bytes` from the leader to `fanout` nodes
    /// (tree-structured, like Spark's torrent broadcast).
    pub fn broadcast_cost(&mut self, bytes: usize, fanout: usize) {
        let depth = (fanout.max(1) as f64).log2().ceil().max(1.0);
        let t = depth * (self.config.latency + bytes as f64 / self.config.bandwidth);
        self.clock.add_comm(CommStats { time: t, bytes: bytes * fanout.max(1), messages: fanout });
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn superstep_returns_in_order_and_advances_clock() {
        let mut c = SimCluster::new(ClusterConfig { threads: 2, cores: 4, ..Default::default() });
        let tasks: Vec<Box<dyn FnOnce() -> usize + Send>> = (0..8usize)
            .map(|i| Box::new(move || i * i) as Box<dyn FnOnce() -> usize + Send>)
            .collect();
        let out = c.superstep(tasks);
        assert_eq!(out, vec![0, 1, 4, 9, 16, 25, 36, 49]);
        assert!(c.clock.compute_time() > 0.0);
    }

    #[test]
    fn reduce_sum_sums() {
        let mut c = SimCluster::new(ClusterConfig::default());
        let parts = vec![vec![1.0, 2.0], vec![10.0, 20.0], vec![100.0, 200.0]];
        let s = c.reduce_sum(parts);
        assert_eq!(s, vec![111.0, 222.0]);
        assert!(c.clock.comm_time() > 0.0);
        assert!(c.clock.comm_bytes() > 0);
    }

    #[test]
    fn broadcast_charges_more_for_more_nodes() {
        let mut a = SimCluster::new(ClusterConfig::default());
        let mut b = SimCluster::new(ClusterConfig::default());
        a.broadcast_cost(1000, 2);
        b.broadcast_cost(1000, 16);
        assert!(b.clock.comm_time() > a.clock.comm_time());
    }

    #[test]
    fn empty_reduce_is_empty() {
        let mut c = SimCluster::new(ClusterConfig::default());
        let s = c.reduce_sum(vec![]);
        assert!(s.is_empty());
    }
}
