//! treeAggregate — Spark's reduction pattern, with a cost model.
//!
//! Combining happens pairwise over a binary tree (depth ⌈log₂ leaves⌉).
//! Each level moves one payload per surviving pair over the network, so
//! the modeled time is `depth * (latency + bytes/bandwidth)` — the same
//! asymptotic the paper leans on when it prefers treeAggregate over plain
//! reduce.  The combine itself is executed for real.

/// Communication accounting for one collective.
#[derive(Clone, Copy, Debug, Default)]
pub struct CommStats {
    /// Modeled seconds.
    pub time: f64,
    /// Total payload bytes moved (all levels).
    pub bytes: usize,
    /// Messages sent.
    pub messages: usize,
}

/// Generic binary-tree aggregation: repeatedly combines adjacent pairs
/// with `combine(dst, src)` until one item remains (in `parts[0]`).
/// `payload_bytes(item)` sizes each transfer for the cost model.
pub fn tree_aggregate<T>(
    parts: &mut Vec<T>,
    latency: f64,
    bandwidth: f64,
    payload_bytes: impl Fn(&T) -> usize,
    mut combine: impl FnMut(&mut T, T),
) -> CommStats {
    let mut stats = CommStats::default();
    if parts.len() <= 1 {
        return stats;
    }
    while parts.len() > 1 {
        let mut level_bytes = 0usize;
        let pairs = parts.len() / 2;
        // drain from the tail so pairing is (0,1), (2,3), ...
        let mut next: Vec<T> = Vec::with_capacity(parts.len() - pairs);
        let mut it = parts.drain(..);
        while let Some(mut a) = it.next() {
            if let Some(b) = it.next() {
                level_bytes += payload_bytes(&b);
                stats.messages += 1;
                combine(&mut a, b);
            }
            next.push(a);
        }
        drop(it);
        *parts = next;
        stats.time += latency + level_bytes as f64 / bandwidth / (pairs.max(1) as f64);
        stats.bytes += level_bytes;
    }
    stats
}

/// treeAggregate specialized to element-wise f32 vector sums — the
/// collective both D3CA (Δα, w recovery) and RADiSA (full gradient,
/// margins) are built on.
pub fn tree_aggregate_f32(
    parts: &mut Vec<Vec<f32>>,
    latency: f64,
    bandwidth: f64,
) -> CommStats {
    tree_aggregate(
        parts,
        latency,
        bandwidth,
        |v| v.len() * std::mem::size_of::<f32>(),
        |dst, src| {
            debug_assert_eq!(dst.len(), src.len());
            for (d, s) in dst.iter_mut().zip(src) {
                *d += s;
            }
        },
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn aggregates_to_total_sum() {
        let mut parts: Vec<Vec<f32>> =
            (0..7).map(|i| vec![i as f32, 2.0 * i as f32]).collect();
        let stats = tree_aggregate_f32(&mut parts, 1e-4, 1e9);
        assert_eq!(parts.len(), 1);
        assert_eq!(parts[0], vec![21.0, 42.0]);
        assert!(stats.messages >= 6); // n-1 combines
        assert!(stats.time > 0.0);
    }

    #[test]
    fn tree_depth_drives_latency() {
        // 16 leaves -> 4 levels; 2 leaves -> 1 level.
        let mk = |k: usize| -> f64 {
            let mut parts: Vec<Vec<f32>> = (0..k).map(|_| vec![0.0; 1]).collect();
            tree_aggregate_f32(&mut parts, 1.0, f64::INFINITY).time
        };
        assert!((mk(2) - 1.0).abs() < 1e-9);
        assert!((mk(16) - 4.0).abs() < 1e-9);
        assert!((mk(5) - 3.0).abs() < 1e-9); // ceil(log2 5) = 3
    }

    #[test]
    fn single_part_is_free() {
        let mut parts = vec![vec![1.0f32, 2.0]];
        let stats = tree_aggregate_f32(&mut parts, 1.0, 1.0);
        assert_eq!(stats.time, 0.0);
        assert_eq!(stats.bytes, 0);
        assert_eq!(parts[0], vec![1.0, 2.0]);
    }

    #[test]
    fn generic_combine_with_scalars() {
        let mut parts = vec![1u64, 2, 3, 4, 5];
        let stats = tree_aggregate(
            &mut parts,
            0.0,
            1.0,
            |_| 8,
            |a, b| *a += b,
        );
        assert_eq!(parts[0], 15);
        assert_eq!(stats.messages, 4);
    }

    #[test]
    fn matches_sequential_sum_for_many_sizes() {
        for k in 1..20 {
            let mut parts: Vec<Vec<f32>> = (0..k).map(|i| vec![(i + 1) as f32]).collect();
            tree_aggregate_f32(&mut parts, 0.0, 1e9);
            let expect = (k * (k + 1) / 2) as f32;
            assert_eq!(parts[0][0], expect, "k={k}");
        }
    }
}
