//! Ser/de between [`GridOp`] descriptors and wire bytes.
//!
//! The driver encodes an op (kind byte + scalars + the borrowed state
//! payloads) straight out of the coordinator's workspaces; the executor
//! decodes into a reusable [`OpBuf`] — owned buffers that live across
//! supersteps — and re-borrows it as a [`GridOp`] for the shared
//! interpreter ([`GridOp::exec_task`]).  Payloads are f32/i32 arrays
//! that round-trip by bit pattern, which is half of the dist-vs-sim
//! bitwise-parity guarantee (the other half is the task-index output
//! layout).
//!
//! Two encodings share the kind codes: [`encode_op`] ships every payload
//! whole (the broadcast wire mode), while [`encode_op_sliced`] ships, per
//! executor, only the ranges of each vector and the per-task streams its
//! owned tasks actually read ([`GridOp::read_row_ranges`] etc.).  A
//! sliced vector decodes into a buffer *resized to the full length*, with
//! only the shipped ranges filled — the interpreter never reads outside
//! an owned task's slices, so the unfilled remainder (zeros, or stale
//! bytes from the previous superstep) is provably never observed.

use crate::cluster::GridOp;
use crate::data::Partitioned;
use crate::loss::Loss;
use crate::util::bytes::{self, ByteReader};
use anyhow::{bail, Result};

/// Ceiling on the declared *full* element count of a sliced payload —
/// a corrupt prefix must not trigger a giant allocation (mirrors the
/// byte-level `MAX_FRAME` guard one layer down).
const MAX_SLICED_TOTAL: usize = 1 << 28;

const OP_SDCA: u8 = 1;
const OP_ATX: u8 = 2;
const OP_MARGINS: u8 = 3;
const OP_GRAD: u8 = 4;
const OP_SVRG: u8 = 5;
const OP_ADMM_PROJECT: u8 = 6;
const OP_PROX_HINGE: u8 = 7;

fn loss_to_u8(l: Loss) -> u8 {
    match l {
        Loss::Hinge => 0,
        Loss::Logistic => 1,
        Loss::Squared => 2,
    }
}

fn loss_from_u8(v: u8) -> Result<Loss> {
    Ok(match v {
        0 => Loss::Hinge,
        1 => Loss::Logistic,
        2 => Loss::Squared,
        other => bail!("unknown loss code {other}"),
    })
}

/// Serialize one op descriptor (everything [`OpBuf::decode_into`] needs
/// to reconstruct a [`GridOp`] borrow on the far side).
pub fn encode_op(op: &GridOp<'_>, buf: &mut Vec<u8>) {
    match op {
        GridOp::Sdca { alpha, w, idx, idx_off, h, lamn, invq, beta } => {
            bytes::put_u8(buf, OP_SDCA);
            bytes::put_f32(buf, *lamn);
            bytes::put_f32(buf, *invq);
            bytes::put_f32(buf, *beta);
            bytes::put_f32s(buf, alpha);
            bytes::put_f32s(buf, w);
            bytes::put_i32s(buf, idx);
            bytes::put_pairs(buf, idx_off);
            bytes::put_usizes(buf, h);
        }
        GridOp::Atx { v } => {
            bytes::put_u8(buf, OP_ATX);
            bytes::put_f32s(buf, v);
        }
        GridOp::Margins { w } => {
            bytes::put_u8(buf, OP_MARGINS);
            bytes::put_f32s(buf, w);
        }
        GridOp::Grad { loss, mt } => {
            bytes::put_u8(buf, OP_GRAD);
            bytes::put_u8(buf, loss_to_u8(*loss));
            bytes::put_f32s(buf, mt);
        }
        GridOp::Svrg {
            loss,
            w,
            mu,
            mt,
            windows,
            idx,
            idx_off,
            batch,
            eta,
            lam,
            tolerant,
        } => {
            bytes::put_u8(buf, OP_SVRG);
            bytes::put_u8(buf, loss_to_u8(*loss));
            bytes::put_u8(buf, u8::from(*tolerant));
            bytes::put_usize(buf, *batch);
            bytes::put_f32(buf, *eta);
            bytes::put_f32(buf, *lam);
            bytes::put_f32s(buf, w);
            bytes::put_f32s(buf, mu);
            bytes::put_f32s(buf, mt);
            bytes::put_pairs(buf, windows);
            bytes::put_i32s(buf, idx);
            bytes::put_pairs(buf, idx_off);
        }
        GridOp::AdmmProject { w_hat, z_hat } => {
            bytes::put_u8(buf, OP_ADMM_PROJECT);
            bytes::put_f32s(buf, w_hat);
            bytes::put_f32s(buf, z_hat);
        }
        GridOp::ProxHinge { c, rho, inv_n } => {
            bytes::put_u8(buf, OP_PROX_HINGE);
            bytes::put_f32(buf, *rho);
            bytes::put_f32(buf, *inv_n);
            bytes::put_f32s(buf, c);
        }
    }
}

// ------------------------------------------------------- sliced payloads

/// `[full_len: u64][n_ranges: u32]` then per range `[start: u64]` + a
/// length-prefixed f32 run — a vector of which the receiver only needs
/// `ranges`.
fn put_f32_slices(buf: &mut Vec<u8>, full: &[f32], ranges: &[(usize, usize)]) {
    bytes::put_usize(buf, full.len());
    bytes::put_u32(buf, ranges.len() as u32);
    for &(start, len) in ranges {
        bytes::put_usize(buf, start);
        bytes::put_f32s(buf, &full[start..start + len]);
    }
}

/// Decode a [`put_f32_slices`] payload: resize `out` to the full length
/// and fill the shipped ranges (the rest stays unread by contract).
fn read_f32_slices(r: &mut ByteReader<'_>, out: &mut Vec<f32>) -> Result<()> {
    let total = r.usize()?;
    if total > MAX_SLICED_TOTAL {
        bail!("corrupt sliced payload: full length {total} is implausible");
    }
    out.resize(total, 0.0);
    let n = r.u32()? as usize;
    for _ in 0..n {
        let start = r.usize()?;
        let len = r.usize()?;
        if start.checked_add(len).map(|e| e > total).unwrap_or(true) {
            bail!("corrupt sliced payload: range {start}+{len} exceeds full length {total}");
        }
        r.fill_f32s(&mut out[start..start + len])?;
    }
    Ok(())
}

/// `[n_entries_total: u64][n_shipped: u32]` then `[task: u32][a: u64]
/// [b: u64]` per shipped task — a per-task pair table of which the
/// receiver only needs its owned rows.
fn put_sparse_pairs(buf: &mut Vec<u8>, full: &[(usize, usize)], tasks: &[usize]) {
    bytes::put_usize(buf, full.len());
    bytes::put_u32(buf, tasks.len() as u32);
    for &t in tasks {
        bytes::put_u32(buf, t as u32);
        bytes::put_usize(buf, full[t].0);
        bytes::put_usize(buf, full[t].1);
    }
}

/// Decode a [`put_sparse_pairs`] payload; unshipped entries are zeroed
/// (explicitly clearing any stale previous-superstep values).
fn read_sparse_pairs(r: &mut ByteReader<'_>, out: &mut Vec<(usize, usize)>) -> Result<()> {
    let total = r.usize()?;
    if total > MAX_SLICED_TOTAL {
        bail!("corrupt sparse pair table: {total} entries is implausible");
    }
    out.clear();
    out.resize(total, (0, 0));
    let n = r.u32()? as usize;
    for _ in 0..n {
        let t = r.u32()? as usize;
        if t >= total {
            bail!("corrupt sparse pair table: task {t} out of {total}");
        }
        out[t] = (r.usize()?, r.usize()?);
    }
    Ok(())
}

/// Like [`put_sparse_pairs`] for a per-task usize table (SDCA's `h`).
fn put_sparse_usizes(buf: &mut Vec<u8>, full: &[usize], tasks: &[usize]) {
    bytes::put_usize(buf, full.len());
    bytes::put_u32(buf, tasks.len() as u32);
    for &t in tasks {
        bytes::put_u32(buf, t as u32);
        bytes::put_usize(buf, full[t]);
    }
}

fn read_sparse_usizes(r: &mut ByteReader<'_>, out: &mut Vec<usize>) -> Result<()> {
    let total = r.usize()?;
    if total > MAX_SLICED_TOTAL {
        bail!("corrupt sparse usize table: {total} entries is implausible");
    }
    out.clear();
    out.resize(total, 0);
    let n = r.u32()? as usize;
    for _ in 0..n {
        let t = r.u32()? as usize;
        if t >= total {
            bail!("corrupt sparse usize table: task {t} out of {total}");
        }
        out[t] = r.usize()?;
    }
    Ok(())
}

/// `[n_tasks: u64][n_shipped: u32]` then `[task: u32]` + a
/// length-prefixed i32 run per shipped task: only the owned tasks' visit
/// streams, re-concatenated on the receiver with a rebuilt offset table.
fn put_sliced_idx(
    buf: &mut Vec<u8>,
    idx: &[i32],
    idx_off: &[(usize, usize)],
    tasks: &[usize],
) {
    bytes::put_usize(buf, idx_off.len());
    bytes::put_u32(buf, tasks.len() as u32);
    for &t in tasks {
        let (s, l) = idx_off[t];
        bytes::put_u32(buf, t as u32);
        bytes::put_i32s(buf, &idx[s..s + l]);
    }
}

fn read_sliced_idx(
    r: &mut ByteReader<'_>,
    idx: &mut Vec<i32>,
    idx_off: &mut Vec<(usize, usize)>,
) -> Result<()> {
    let total = r.usize()?;
    if total > MAX_SLICED_TOTAL {
        bail!("corrupt sliced index stream: {total} tasks is implausible");
    }
    idx.clear();
    idx_off.clear();
    idx_off.resize(total, (0, 0));
    let n = r.u32()? as usize;
    for _ in 0..n {
        let t = r.u32()? as usize;
        if t >= total {
            bail!("corrupt sliced index stream: task {t} out of {total}");
        }
        let l = r.usize()?;
        if l > r.remaining() / 4 {
            bail!("corrupt sliced index stream: {l} elements exceeds remaining bytes");
        }
        idx_off[t] = (idx.len(), l);
        r.i32s_append(idx, l)?;
    }
    Ok(())
}

/// Serialize one op descriptor for a *specific executor*: same kind
/// codes and scalar fields as [`encode_op`], but every vector payload is
/// cut down to the ranges (and every per-task table to the entries) that
/// `tasks` — the receiver's owned tasks, ascending — actually read.
/// Decode with [`OpBuf::decode_sliced_into`].
pub fn encode_op_sliced(
    op: &GridOp<'_>,
    part: &Partitioned,
    tasks: &[usize],
    buf: &mut Vec<u8>,
) {
    match op {
        GridOp::Sdca { alpha, w, idx, idx_off, h, lamn, invq, beta } => {
            bytes::put_u8(buf, OP_SDCA);
            bytes::put_f32(buf, *lamn);
            bytes::put_f32(buf, *invq);
            bytes::put_f32(buf, *beta);
            put_f32_slices(buf, alpha, &op.read_row_ranges(part, tasks));
            put_f32_slices(buf, w, &op.read_col_ranges(part, tasks));
            put_sliced_idx(buf, idx, idx_off, tasks);
            put_sparse_usizes(buf, h, tasks);
        }
        GridOp::Atx { v } => {
            bytes::put_u8(buf, OP_ATX);
            put_f32_slices(buf, v, &op.read_row_ranges(part, tasks));
        }
        GridOp::Margins { w } => {
            bytes::put_u8(buf, OP_MARGINS);
            put_f32_slices(buf, w, &op.read_col_ranges(part, tasks));
        }
        GridOp::Grad { loss, mt } => {
            bytes::put_u8(buf, OP_GRAD);
            bytes::put_u8(buf, loss_to_u8(*loss));
            put_f32_slices(buf, mt, &op.read_row_ranges(part, tasks));
        }
        GridOp::Svrg {
            loss,
            w,
            mu,
            mt,
            windows,
            idx,
            idx_off,
            batch,
            eta,
            lam,
            tolerant,
        } => {
            bytes::put_u8(buf, OP_SVRG);
            bytes::put_u8(buf, loss_to_u8(*loss));
            bytes::put_u8(buf, u8::from(*tolerant));
            bytes::put_usize(buf, *batch);
            bytes::put_f32(buf, *eta);
            bytes::put_f32(buf, *lam);
            let cols = op.read_col_ranges(part, tasks);
            put_f32_slices(buf, w, &cols);
            put_f32_slices(buf, mu, &cols);
            put_f32_slices(buf, mt, &op.read_row_ranges(part, tasks));
            put_sparse_pairs(buf, windows, tasks);
            put_sliced_idx(buf, idx, idx_off, tasks);
        }
        GridOp::AdmmProject { w_hat, z_hat } => {
            bytes::put_u8(buf, OP_ADMM_PROJECT);
            put_f32_slices(buf, w_hat, &op.out_span_ranges(part, tasks));
            put_f32_slices(buf, z_hat, &op.out2_span_ranges(part, tasks));
        }
        GridOp::ProxHinge { c, rho, inv_n } => {
            bytes::put_u8(buf, OP_PROX_HINGE);
            bytes::put_f32(buf, *rho);
            bytes::put_f32(buf, *inv_n);
            put_f32_slices(buf, c, &op.read_row_ranges(part, tasks));
        }
    }
}

/// Executor-side owned storage for a decoded op — reused across
/// supersteps so the serve loop's steady state reallocates only when a
/// payload grows.
pub struct OpBuf {
    kind: u8,
    loss: Loss,
    tolerant: bool,
    batch: usize,
    s1: f32,
    s2: f32,
    s3: f32,
    f1: Vec<f32>,
    f2: Vec<f32>,
    f3: Vec<f32>,
    idx: Vec<i32>,
    idx_off: Vec<(usize, usize)>,
    h: Vec<usize>,
    windows: Vec<(usize, usize)>,
}

impl Default for OpBuf {
    fn default() -> Self {
        OpBuf {
            kind: 0,
            loss: Loss::Hinge,
            tolerant: false,
            batch: 0,
            s1: 0.0,
            s2: 0.0,
            s3: 0.0,
            f1: Vec::new(),
            f2: Vec::new(),
            f3: Vec::new(),
            idx: Vec::new(),
            idx_off: Vec::new(),
            h: Vec::new(),
            windows: Vec::new(),
        }
    }
}

impl OpBuf {
    pub fn new() -> OpBuf {
        OpBuf::default()
    }

    /// Decode one [`encode_op`] payload into this buffer.
    pub fn decode_into(&mut self, r: &mut ByteReader<'_>) -> Result<()> {
        self.kind = r.u8()?;
        match self.kind {
            OP_SDCA => {
                self.s1 = r.f32()?; // lamn
                self.s2 = r.f32()?; // invq
                self.s3 = r.f32()?; // beta
                r.f32s_into(&mut self.f1)?; // alpha
                r.f32s_into(&mut self.f2)?; // w
                r.i32s_into(&mut self.idx)?;
                r.pairs_into(&mut self.idx_off)?;
                r.usizes_into(&mut self.h)?;
            }
            OP_ATX | OP_MARGINS => {
                r.f32s_into(&mut self.f1)?;
            }
            OP_GRAD => {
                self.loss = loss_from_u8(r.u8()?)?;
                r.f32s_into(&mut self.f1)?; // mt
            }
            OP_SVRG => {
                self.loss = loss_from_u8(r.u8()?)?;
                self.tolerant = r.u8()? != 0;
                self.batch = r.usize()?;
                self.s1 = r.f32()?; // eta
                self.s2 = r.f32()?; // lam
                r.f32s_into(&mut self.f1)?; // w
                r.f32s_into(&mut self.f2)?; // mu
                r.f32s_into(&mut self.f3)?; // mt
                r.pairs_into(&mut self.windows)?;
                r.i32s_into(&mut self.idx)?;
                r.pairs_into(&mut self.idx_off)?;
            }
            OP_ADMM_PROJECT => {
                r.f32s_into(&mut self.f1)?; // w_hat
                r.f32s_into(&mut self.f2)?; // z_hat
            }
            OP_PROX_HINGE => {
                self.s1 = r.f32()?; // rho
                self.s2 = r.f32()?; // inv_n
                r.f32s_into(&mut self.f1)?; // c
            }
            other => bail!("unknown grid-op code {other}"),
        }
        Ok(())
    }

    /// Decode one [`encode_op_sliced`] payload into this buffer.  Vectors
    /// come back at their *full* lengths with only the shipped ranges
    /// filled; per-task tables at their full entry counts with only the
    /// owned rows populated — exactly what the interpreter's owned tasks
    /// will read.
    pub fn decode_sliced_into(&mut self, r: &mut ByteReader<'_>) -> Result<()> {
        self.kind = r.u8()?;
        match self.kind {
            OP_SDCA => {
                self.s1 = r.f32()?; // lamn
                self.s2 = r.f32()?; // invq
                self.s3 = r.f32()?; // beta
                read_f32_slices(r, &mut self.f1)?; // alpha
                read_f32_slices(r, &mut self.f2)?; // w
                read_sliced_idx(r, &mut self.idx, &mut self.idx_off)?;
                read_sparse_usizes(r, &mut self.h)?;
            }
            OP_ATX | OP_MARGINS => {
                read_f32_slices(r, &mut self.f1)?;
            }
            OP_GRAD => {
                self.loss = loss_from_u8(r.u8()?)?;
                read_f32_slices(r, &mut self.f1)?; // mt
            }
            OP_SVRG => {
                self.loss = loss_from_u8(r.u8()?)?;
                self.tolerant = r.u8()? != 0;
                self.batch = r.usize()?;
                self.s1 = r.f32()?; // eta
                self.s2 = r.f32()?; // lam
                read_f32_slices(r, &mut self.f1)?; // w
                read_f32_slices(r, &mut self.f2)?; // mu
                read_f32_slices(r, &mut self.f3)?; // mt
                read_sparse_pairs(r, &mut self.windows)?;
                read_sliced_idx(r, &mut self.idx, &mut self.idx_off)?;
            }
            OP_ADMM_PROJECT => {
                read_f32_slices(r, &mut self.f1)?; // w_hat
                read_f32_slices(r, &mut self.f2)?; // z_hat
            }
            OP_PROX_HINGE => {
                self.s1 = r.f32()?; // rho
                self.s2 = r.f32()?; // inv_n
                read_f32_slices(r, &mut self.f1)?; // c
            }
            other => bail!("unknown grid-op code {other}"),
        }
        Ok(())
    }

    /// Re-borrow the decoded payloads as the [`GridOp`] the interpreter
    /// runs.
    pub fn as_op(&self) -> Result<GridOp<'_>> {
        Ok(match self.kind {
            OP_SDCA => GridOp::Sdca {
                alpha: &self.f1,
                w: &self.f2,
                idx: &self.idx,
                idx_off: &self.idx_off,
                h: &self.h,
                lamn: self.s1,
                invq: self.s2,
                beta: self.s3,
            },
            OP_ATX => GridOp::Atx { v: &self.f1 },
            OP_MARGINS => GridOp::Margins { w: &self.f1 },
            OP_GRAD => GridOp::Grad { loss: self.loss, mt: &self.f1 },
            OP_SVRG => GridOp::Svrg {
                loss: self.loss,
                w: &self.f1,
                mu: &self.f2,
                mt: &self.f3,
                windows: &self.windows,
                idx: &self.idx,
                idx_off: &self.idx_off,
                batch: self.batch,
                eta: self.s1,
                lam: self.s2,
                tolerant: self.tolerant,
            },
            OP_ADMM_PROJECT => GridOp::AdmmProject { w_hat: &self.f1, z_hat: &self.f2 },
            OP_PROX_HINGE => {
                GridOp::ProxHinge { c: &self.f1, rho: self.s1, inv_n: self.s2 }
            }
            other => bail!("unknown grid-op code {other} (decode first)"),
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn round_trip(op: &GridOp<'_>) -> OpBuf {
        let mut buf = Vec::new();
        encode_op(op, &mut buf);
        let mut ob = OpBuf::new();
        let mut r = ByteReader::new(&buf);
        ob.decode_into(&mut r).unwrap();
        assert!(r.is_empty(), "trailing bytes after {}", op.name());
        ob
    }

    #[test]
    fn sdca_round_trips() {
        let alpha = vec![1.0f32, -2.5];
        let w = vec![0.25f32; 3];
        let idx = vec![0i32, 1, 0];
        let idx_off = vec![(0usize, 2usize), (2, 1)];
        let h = vec![4usize, 7];
        let op = GridOp::Sdca {
            alpha: &alpha,
            w: &w,
            idx: &idx,
            idx_off: &idx_off,
            h: &h,
            lamn: 0.5,
            invq: 0.25,
            beta: 1.5,
        };
        let ob = round_trip(&op);
        match ob.as_op().unwrap() {
            GridOp::Sdca { alpha: a, w: ww, idx: i, idx_off: io, h: hh, lamn, invq, beta } => {
                assert_eq!(a, &alpha[..]);
                assert_eq!(ww, &w[..]);
                assert_eq!(i, &idx[..]);
                assert_eq!(io, &idx_off[..]);
                assert_eq!(hh, &h[..]);
                assert_eq!((lamn, invq, beta), (0.5, 0.25, 1.5));
            }
            _ => panic!("wrong kind"),
        }
    }

    #[test]
    fn svrg_round_trips_with_flags() {
        let w = vec![1.0f32; 4];
        let mu = vec![2.0f32; 4];
        let mt = vec![3.0f32; 2];
        let windows = vec![(0usize, 2usize), (2, 4)];
        let idx = vec![1i32];
        let idx_off = vec![(0usize, 1usize), (0, 1)];
        let op = GridOp::Svrg {
            loss: Loss::Logistic,
            w: &w,
            mu: &mu,
            mt: &mt,
            windows: &windows,
            idx: &idx,
            idx_off: &idx_off,
            batch: 9,
            eta: 0.1,
            lam: 0.01,
            tolerant: true,
        };
        let ob = round_trip(&op);
        match ob.as_op().unwrap() {
            GridOp::Svrg { loss, batch, tolerant, windows: ws, .. } => {
                assert_eq!(loss, Loss::Logistic);
                assert_eq!(batch, 9);
                assert!(tolerant);
                assert_eq!(ws, &windows[..]);
            }
            _ => panic!("wrong kind"),
        }
    }

    #[test]
    fn single_payload_ops_round_trip() {
        let v = vec![0.5f32, -0.5, f32::MIN_POSITIVE];
        for (op, want) in [
            (GridOp::Atx { v: &v }, "atx"),
            (GridOp::Margins { w: &v }, "margins"),
            (GridOp::Grad { loss: Loss::Hinge, mt: &v }, "grad"),
            (GridOp::ProxHinge { c: &v, rho: 0.2, inv_n: 0.1 }, "prox-hinge"),
        ] {
            let ob = round_trip(&op);
            let back = ob.as_op().unwrap();
            assert_eq!(back.name(), want);
        }
        let wh = vec![1.0f32; 2];
        let zh = vec![2.0f32; 3];
        let ob = round_trip(&GridOp::AdmmProject { w_hat: &wh, z_hat: &zh });
        match ob.as_op().unwrap() {
            GridOp::AdmmProject { w_hat, z_hat } => {
                assert_eq!(w_hat, &wh[..]);
                assert_eq!(z_hat, &zh[..]);
            }
            _ => panic!("wrong kind"),
        }
    }

    #[test]
    fn garbage_kind_rejected() {
        let mut ob = OpBuf::new();
        let mut r = ByteReader::new(&[42u8]);
        assert!(ob.decode_into(&mut r).is_err());
        assert!(OpBuf::new().as_op().is_err());
        let mut r2 = ByteReader::new(&[42u8]);
        assert!(OpBuf::new().decode_sliced_into(&mut r2).is_err());
    }

    fn sliced_fixture() -> Partitioned {
        let ds = crate::data::SyntheticDense::paper_part1(2, 2, 10, 6, 0.1, 3).build();
        Partitioned::split(&ds, crate::data::Grid::new(2, 2))
    }

    #[test]
    fn sliced_sdca_reproduces_owned_reads_and_shrinks() {
        let part = sliced_fixture();
        let mut rng = crate::util::rng::Xoshiro::new(5);
        let alpha: Vec<f32> = (0..part.n).map(|_| rng.range_f32(-1.0, 1.0)).collect();
        let w: Vec<f32> = (0..part.m).map(|_| rng.range_f32(-1.0, 1.0)).collect();
        let k = part.grid.k();
        let mut idx = Vec::new();
        let mut idx_off = Vec::new();
        for t in 0..k {
            let start = idx.len();
            for j in 0..3 + t {
                idx.push(((t * 7 + j) % 5) as i32);
            }
            idx_off.push((start, 3 + t));
        }
        let h: Vec<usize> = (0..k).map(|t| t + 2).collect();
        let op = GridOp::Sdca {
            alpha: &alpha,
            w: &w,
            idx: &idx,
            idx_off: &idx_off,
            h: &h,
            lamn: 0.5,
            invq: 0.25,
            beta: 1.5,
        };
        // executor owning tasks {0, 1} = row partition 0 only
        let tasks = [0usize, 1];
        let mut sliced = Vec::new();
        encode_op_sliced(&op, &part, &tasks, &mut sliced);
        let mut full = Vec::new();
        encode_op(&op, &mut full);
        assert!(sliced.len() < full.len(), "sliced {} vs full {}", sliced.len(), full.len());

        let mut ob = OpBuf::new();
        // dirty the buffers first: stale state from a previous superstep
        // must never leak into owned reads
        ob.f1 = vec![9.0; 64];
        ob.h = vec![77; 9];
        let mut r = ByteReader::new(&sliced);
        ob.decode_sliced_into(&mut r).unwrap();
        assert!(r.is_empty(), "trailing bytes");
        match ob.as_op().unwrap() {
            GridOp::Sdca { alpha: a, w: ww, idx: i, idx_off: io, h: hh, lamn, .. } => {
                assert_eq!(a.len(), part.n);
                assert_eq!(ww.len(), part.m);
                assert_eq!(io.len(), k);
                assert_eq!(hh.len(), k);
                assert_eq!(lamn, 0.5);
                let qq = part.grid.q;
                for &t in &tasks {
                    let (p, q) = (t / qq, t % qq);
                    let (r0, r1) = part.row_ranges[p];
                    let (c0, c1) = part.col_ranges[q];
                    for e in r0..r1 {
                        assert_eq!(a[e].to_bits(), alpha[e].to_bits(), "alpha[{e}]");
                    }
                    for e in c0..c1 {
                        assert_eq!(ww[e].to_bits(), w[e].to_bits(), "w[{e}]");
                    }
                    let (s, l) = io[t];
                    let (os, ol) = idx_off[t];
                    assert_eq!(l, ol);
                    assert_eq!(&i[s..s + l], &idx[os..os + ol], "idx stream of task {t}");
                    assert_eq!(hh[t], h[t]);
                }
                // unowned per-task rows were explicitly cleared, not stale
                assert_eq!(hh[3], 0);
                assert_eq!(io[3], (0, 0));
            }
            _ => panic!("wrong kind"),
        }
    }

    #[test]
    fn sliced_admm_ships_only_owned_spans() {
        let part = sliced_fixture();
        let op0 = GridOp::AdmmProject { w_hat: &[], z_hat: &[] };
        let w_hat: Vec<f32> = (0..op0.out_len(&part)).map(|i| i as f32).collect();
        let z_hat: Vec<f32> = (0..op0.out2_len(&part)).map(|i| -(i as f32)).collect();
        let op = GridOp::AdmmProject { w_hat: &w_hat, z_hat: &z_hat };
        let tasks = [2usize, 3];
        let mut sliced = Vec::new();
        encode_op_sliced(&op, &part, &tasks, &mut sliced);
        let mut ob = OpBuf::new();
        let mut r = ByteReader::new(&sliced);
        ob.decode_sliced_into(&mut r).unwrap();
        assert!(r.is_empty());
        match ob.as_op().unwrap() {
            GridOp::AdmmProject { w_hat: wh, z_hat: zh } => {
                assert_eq!(wh.len(), w_hat.len());
                assert_eq!(zh.len(), z_hat.len());
                for &t in &tasks {
                    let (s, l) = op.out_span(&part, t);
                    assert_eq!(
                        wh[s..s + l]
                            .iter()
                            .zip(&w_hat[s..s + l])
                            .filter(|(a, b)| a.to_bits() != b.to_bits())
                            .count(),
                        0
                    );
                    let (s2, l2) = op.out2_span(&part, t);
                    assert_eq!(
                        zh[s2..s2 + l2]
                            .iter()
                            .zip(&z_hat[s2..s2 + l2])
                            .filter(|(a, b)| a.to_bits() != b.to_bits())
                            .count(),
                        0
                    );
                }
                // unowned spans were not shipped
                let (s, l) = op.out_span(&part, 0);
                assert!(wh[s..s + l].iter().all(|&v| v == 0.0));
            }
            _ => panic!("wrong kind"),
        }
    }

    #[test]
    fn corrupt_sliced_ranges_rejected() {
        let part = sliced_fixture();
        let v: Vec<f32> = vec![1.0; part.n];
        let op = GridOp::Atx { v: &v };
        let mut buf = Vec::new();
        encode_op_sliced(&op, &part, &[0, 1], &mut buf);
        // out-of-bounds range start: kind byte, then corrupt the first
        // range's start offset (full_len u64 + n_ranges u32 precede it)
        let start_off = 1 + 8 + 4;
        let mut bad = buf.clone();
        bad[start_off..start_off + 8].copy_from_slice(&(u64::MAX).to_le_bytes());
        let mut ob = OpBuf::new();
        assert!(ob.decode_sliced_into(&mut ByteReader::new(&bad)).is_err());
        // implausible full length
        let mut bad2 = buf.clone();
        bad2[1..9].copy_from_slice(&(u64::MAX / 2).to_le_bytes());
        assert!(ob.decode_sliced_into(&mut ByteReader::new(&bad2)).is_err());
        // every strict prefix must error, never panic or succeed
        for cut in 0..buf.len() {
            assert!(
                ob.decode_sliced_into(&mut ByteReader::new(&buf[..cut])).is_err(),
                "prefix of {cut} bytes decoded"
            );
        }
    }
}
