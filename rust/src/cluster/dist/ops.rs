//! Ser/de between [`GridOp`] descriptors and wire bytes.
//!
//! The driver encodes an op (kind byte + scalars + the borrowed state
//! payloads) straight out of the coordinator's workspaces; the executor
//! decodes into a reusable [`OpBuf`] — owned buffers that live across
//! supersteps — and re-borrows it as a [`GridOp`] for the shared
//! interpreter ([`GridOp::exec_task`]).  Payloads are f32/i32 arrays
//! that round-trip by bit pattern, which is half of the dist-vs-sim
//! bitwise-parity guarantee (the other half is the task-index output
//! layout).

use crate::cluster::GridOp;
use crate::loss::Loss;
use crate::util::bytes::{self, ByteReader};
use anyhow::{bail, Result};

const OP_SDCA: u8 = 1;
const OP_ATX: u8 = 2;
const OP_MARGINS: u8 = 3;
const OP_GRAD: u8 = 4;
const OP_SVRG: u8 = 5;
const OP_ADMM_PROJECT: u8 = 6;
const OP_PROX_HINGE: u8 = 7;

fn loss_to_u8(l: Loss) -> u8 {
    match l {
        Loss::Hinge => 0,
        Loss::Logistic => 1,
        Loss::Squared => 2,
    }
}

fn loss_from_u8(v: u8) -> Result<Loss> {
    Ok(match v {
        0 => Loss::Hinge,
        1 => Loss::Logistic,
        2 => Loss::Squared,
        other => bail!("unknown loss code {other}"),
    })
}

/// Serialize one op descriptor (everything [`OpBuf::decode_into`] needs
/// to reconstruct a [`GridOp`] borrow on the far side).
pub fn encode_op(op: &GridOp<'_>, buf: &mut Vec<u8>) {
    match op {
        GridOp::Sdca { alpha, w, idx, idx_off, h, lamn, invq, beta } => {
            bytes::put_u8(buf, OP_SDCA);
            bytes::put_f32(buf, *lamn);
            bytes::put_f32(buf, *invq);
            bytes::put_f32(buf, *beta);
            bytes::put_f32s(buf, alpha);
            bytes::put_f32s(buf, w);
            bytes::put_i32s(buf, idx);
            bytes::put_pairs(buf, idx_off);
            bytes::put_usizes(buf, h);
        }
        GridOp::Atx { v } => {
            bytes::put_u8(buf, OP_ATX);
            bytes::put_f32s(buf, v);
        }
        GridOp::Margins { w } => {
            bytes::put_u8(buf, OP_MARGINS);
            bytes::put_f32s(buf, w);
        }
        GridOp::Grad { loss, mt } => {
            bytes::put_u8(buf, OP_GRAD);
            bytes::put_u8(buf, loss_to_u8(*loss));
            bytes::put_f32s(buf, mt);
        }
        GridOp::Svrg {
            loss,
            w,
            mu,
            mt,
            windows,
            idx,
            idx_off,
            batch,
            eta,
            lam,
            tolerant,
        } => {
            bytes::put_u8(buf, OP_SVRG);
            bytes::put_u8(buf, loss_to_u8(*loss));
            bytes::put_u8(buf, u8::from(*tolerant));
            bytes::put_usize(buf, *batch);
            bytes::put_f32(buf, *eta);
            bytes::put_f32(buf, *lam);
            bytes::put_f32s(buf, w);
            bytes::put_f32s(buf, mu);
            bytes::put_f32s(buf, mt);
            bytes::put_pairs(buf, windows);
            bytes::put_i32s(buf, idx);
            bytes::put_pairs(buf, idx_off);
        }
        GridOp::AdmmProject { w_hat, z_hat } => {
            bytes::put_u8(buf, OP_ADMM_PROJECT);
            bytes::put_f32s(buf, w_hat);
            bytes::put_f32s(buf, z_hat);
        }
        GridOp::ProxHinge { c, rho, inv_n } => {
            bytes::put_u8(buf, OP_PROX_HINGE);
            bytes::put_f32(buf, *rho);
            bytes::put_f32(buf, *inv_n);
            bytes::put_f32s(buf, c);
        }
    }
}

/// Executor-side owned storage for a decoded op — reused across
/// supersteps so the serve loop's steady state reallocates only when a
/// payload grows.
pub struct OpBuf {
    kind: u8,
    loss: Loss,
    tolerant: bool,
    batch: usize,
    s1: f32,
    s2: f32,
    s3: f32,
    f1: Vec<f32>,
    f2: Vec<f32>,
    f3: Vec<f32>,
    idx: Vec<i32>,
    idx_off: Vec<(usize, usize)>,
    h: Vec<usize>,
    windows: Vec<(usize, usize)>,
}

impl Default for OpBuf {
    fn default() -> Self {
        OpBuf {
            kind: 0,
            loss: Loss::Hinge,
            tolerant: false,
            batch: 0,
            s1: 0.0,
            s2: 0.0,
            s3: 0.0,
            f1: Vec::new(),
            f2: Vec::new(),
            f3: Vec::new(),
            idx: Vec::new(),
            idx_off: Vec::new(),
            h: Vec::new(),
            windows: Vec::new(),
        }
    }
}

impl OpBuf {
    pub fn new() -> OpBuf {
        OpBuf::default()
    }

    /// Decode one [`encode_op`] payload into this buffer.
    pub fn decode_into(&mut self, r: &mut ByteReader<'_>) -> Result<()> {
        self.kind = r.u8()?;
        match self.kind {
            OP_SDCA => {
                self.s1 = r.f32()?; // lamn
                self.s2 = r.f32()?; // invq
                self.s3 = r.f32()?; // beta
                r.f32s_into(&mut self.f1)?; // alpha
                r.f32s_into(&mut self.f2)?; // w
                r.i32s_into(&mut self.idx)?;
                r.pairs_into(&mut self.idx_off)?;
                r.usizes_into(&mut self.h)?;
            }
            OP_ATX | OP_MARGINS => {
                r.f32s_into(&mut self.f1)?;
            }
            OP_GRAD => {
                self.loss = loss_from_u8(r.u8()?)?;
                r.f32s_into(&mut self.f1)?; // mt
            }
            OP_SVRG => {
                self.loss = loss_from_u8(r.u8()?)?;
                self.tolerant = r.u8()? != 0;
                self.batch = r.usize()?;
                self.s1 = r.f32()?; // eta
                self.s2 = r.f32()?; // lam
                r.f32s_into(&mut self.f1)?; // w
                r.f32s_into(&mut self.f2)?; // mu
                r.f32s_into(&mut self.f3)?; // mt
                r.pairs_into(&mut self.windows)?;
                r.i32s_into(&mut self.idx)?;
                r.pairs_into(&mut self.idx_off)?;
            }
            OP_ADMM_PROJECT => {
                r.f32s_into(&mut self.f1)?; // w_hat
                r.f32s_into(&mut self.f2)?; // z_hat
            }
            OP_PROX_HINGE => {
                self.s1 = r.f32()?; // rho
                self.s2 = r.f32()?; // inv_n
                r.f32s_into(&mut self.f1)?; // c
            }
            other => bail!("unknown grid-op code {other}"),
        }
        Ok(())
    }

    /// Re-borrow the decoded payloads as the [`GridOp`] the interpreter
    /// runs.
    pub fn as_op(&self) -> Result<GridOp<'_>> {
        Ok(match self.kind {
            OP_SDCA => GridOp::Sdca {
                alpha: &self.f1,
                w: &self.f2,
                idx: &self.idx,
                idx_off: &self.idx_off,
                h: &self.h,
                lamn: self.s1,
                invq: self.s2,
                beta: self.s3,
            },
            OP_ATX => GridOp::Atx { v: &self.f1 },
            OP_MARGINS => GridOp::Margins { w: &self.f1 },
            OP_GRAD => GridOp::Grad { loss: self.loss, mt: &self.f1 },
            OP_SVRG => GridOp::Svrg {
                loss: self.loss,
                w: &self.f1,
                mu: &self.f2,
                mt: &self.f3,
                windows: &self.windows,
                idx: &self.idx,
                idx_off: &self.idx_off,
                batch: self.batch,
                eta: self.s1,
                lam: self.s2,
                tolerant: self.tolerant,
            },
            OP_ADMM_PROJECT => GridOp::AdmmProject { w_hat: &self.f1, z_hat: &self.f2 },
            OP_PROX_HINGE => {
                GridOp::ProxHinge { c: &self.f1, rho: self.s1, inv_n: self.s2 }
            }
            other => bail!("unknown grid-op code {other} (decode first)"),
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn round_trip(op: &GridOp<'_>) -> OpBuf {
        let mut buf = Vec::new();
        encode_op(op, &mut buf);
        let mut ob = OpBuf::new();
        let mut r = ByteReader::new(&buf);
        ob.decode_into(&mut r).unwrap();
        assert!(r.is_empty(), "trailing bytes after {}", op.name());
        ob
    }

    #[test]
    fn sdca_round_trips() {
        let alpha = vec![1.0f32, -2.5];
        let w = vec![0.25f32; 3];
        let idx = vec![0i32, 1, 0];
        let idx_off = vec![(0usize, 2usize), (2, 1)];
        let h = vec![4usize, 7];
        let op = GridOp::Sdca {
            alpha: &alpha,
            w: &w,
            idx: &idx,
            idx_off: &idx_off,
            h: &h,
            lamn: 0.5,
            invq: 0.25,
            beta: 1.5,
        };
        let ob = round_trip(&op);
        match ob.as_op().unwrap() {
            GridOp::Sdca { alpha: a, w: ww, idx: i, idx_off: io, h: hh, lamn, invq, beta } => {
                assert_eq!(a, &alpha[..]);
                assert_eq!(ww, &w[..]);
                assert_eq!(i, &idx[..]);
                assert_eq!(io, &idx_off[..]);
                assert_eq!(hh, &h[..]);
                assert_eq!((lamn, invq, beta), (0.5, 0.25, 1.5));
            }
            _ => panic!("wrong kind"),
        }
    }

    #[test]
    fn svrg_round_trips_with_flags() {
        let w = vec![1.0f32; 4];
        let mu = vec![2.0f32; 4];
        let mt = vec![3.0f32; 2];
        let windows = vec![(0usize, 2usize), (2, 4)];
        let idx = vec![1i32];
        let idx_off = vec![(0usize, 1usize), (0, 1)];
        let op = GridOp::Svrg {
            loss: Loss::Logistic,
            w: &w,
            mu: &mu,
            mt: &mt,
            windows: &windows,
            idx: &idx,
            idx_off: &idx_off,
            batch: 9,
            eta: 0.1,
            lam: 0.01,
            tolerant: true,
        };
        let ob = round_trip(&op);
        match ob.as_op().unwrap() {
            GridOp::Svrg { loss, batch, tolerant, windows: ws, .. } => {
                assert_eq!(loss, Loss::Logistic);
                assert_eq!(batch, 9);
                assert!(tolerant);
                assert_eq!(ws, &windows[..]);
            }
            _ => panic!("wrong kind"),
        }
    }

    #[test]
    fn single_payload_ops_round_trip() {
        let v = vec![0.5f32, -0.5, f32::MIN_POSITIVE];
        for (op, want) in [
            (GridOp::Atx { v: &v }, "atx"),
            (GridOp::Margins { w: &v }, "margins"),
            (GridOp::Grad { loss: Loss::Hinge, mt: &v }, "grad"),
            (GridOp::ProxHinge { c: &v, rho: 0.2, inv_n: 0.1 }, "prox-hinge"),
        ] {
            let ob = round_trip(&op);
            let back = ob.as_op().unwrap();
            assert_eq!(back.name(), want);
        }
        let wh = vec![1.0f32; 2];
        let zh = vec![2.0f32; 3];
        let ob = round_trip(&GridOp::AdmmProject { w_hat: &wh, z_hat: &zh });
        match ob.as_op().unwrap() {
            GridOp::AdmmProject { w_hat, z_hat } => {
                assert_eq!(w_hat, &wh[..]);
                assert_eq!(z_hat, &zh[..]);
            }
            _ => panic!("wrong kind"),
        }
    }

    #[test]
    fn garbage_kind_rejected() {
        let mut ob = OpBuf::new();
        let mut r = ByteReader::new(&[42u8]);
        assert!(ob.decode_into(&mut r).is_err());
        assert!(OpBuf::new().as_op().is_err());
    }
}
