//! Seeded network-fault injection for the dist wire path.
//!
//! Chaos lives on the *sending* side of an executor (or in the
//! [`chaosproxy`] TCP forwarder) and perturbs outgoing frames
//! deterministically: the same `seed=` produces the same fault schedule
//! run after run, which is what lets `tests/dist_recovery.rs` assert
//! exact recovery counters instead of "it usually survives".
//!
//! Fault classes (all optional, composable):
//!
//! * `delay=MS` — sleep before each eligible frame (a trickling link).
//! * `drop=P`  — with probability P, shut the connection down instead
//!   of writing (a crash/reset as the driver sees it).
//! * `trunc=P` — with probability P, write a deliberately truncated
//!   frame and then shut down (a mid-frame cut).
//! * `partition=P` — with probability P, flip into a *one-way*
//!   partition: every later outgoing frame is silently swallowed while
//!   the inbound direction keeps flowing (the classic half-open link).
//!
//! `after=N` skips the first N frames (faults only make sense once the
//! session is up), and `window=W` limits eligibility to frames
//! `[after, after+W)` so a test can rig exactly one faulty frame.
//!
//! The shim is plumbed as an `Option<&Mutex<ChaosState>>` — `None`
//! everywhere in production, so the healthy wire path pays one pointer
//! test per frame.

use super::wire::{self, Tag};
use crate::util::rng::Xoshiro;
use anyhow::{bail, Context, Result};
use std::io::Write;
use std::net::{Shutdown, TcpListener, TcpStream};
use std::sync::Mutex;

/// Parsed `--chaos` parameters (see the module docs for semantics).
#[derive(Clone, Debug, PartialEq)]
pub struct ChaosConfig {
    /// RNG seed: the whole fault schedule is a pure function of it.
    pub seed: u64,
    /// Frames to pass through untouched before faults become eligible.
    pub after: u64,
    /// Number of eligible frames from `after` on (default: unbounded).
    pub window: u64,
    /// Per-frame delay in milliseconds.
    pub delay_ms: u64,
    /// Probability of dropping the connection instead of writing.
    pub drop: f64,
    /// Probability of writing a truncated frame, then dropping.
    pub trunc: f64,
    /// Probability of flipping into a persistent one-way partition.
    pub partition: f64,
}

impl Default for ChaosConfig {
    fn default() -> Self {
        ChaosConfig {
            seed: 1,
            after: 0,
            window: u64::MAX,
            delay_ms: 0,
            drop: 0.0,
            trunc: 0.0,
            partition: 0.0,
        }
    }
}

fn parse_prob(key: &str, val: &str) -> Result<f64> {
    let p: f64 = val.parse().map_err(|_| anyhow::anyhow!("bad chaos parameter {key}='{val}'"))?;
    if !(0.0..=1.0).contains(&p) {
        bail!("chaos {key} must be in [0, 1], got '{val}'");
    }
    Ok(p)
}

impl ChaosConfig {
    /// Parse a `seed=N,delay=MS,drop=P,trunc=P,partition=P,after=N,window=W`
    /// list (any subset, any order).
    pub fn parse(spec: &str) -> Result<ChaosConfig> {
        let mut cfg = ChaosConfig::default();
        for kv in spec.split(',').filter(|s| !s.trim().is_empty()) {
            let (key, val) = kv.split_once('=').unwrap_or((kv, ""));
            let (key, val) = (key.trim(), val.trim());
            let bad = || anyhow::anyhow!("bad chaos parameter {key}='{val}'");
            match key {
                "seed" => cfg.seed = val.parse().map_err(|_| bad())?,
                "after" => cfg.after = val.parse().map_err(|_| bad())?,
                "window" => cfg.window = val.parse().map_err(|_| bad())?,
                "delay" | "delay_ms" => cfg.delay_ms = val.parse().map_err(|_| bad())?,
                "drop" => cfg.drop = parse_prob(key, val)?,
                "trunc" => cfg.trunc = parse_prob(key, val)?,
                "partition" => cfg.partition = parse_prob(key, val)?,
                other => bail!(
                    "unknown chaos parameter '{other}' \
                     (expected seed/after/window/delay/drop/trunc/partition)"
                ),
            }
        }
        Ok(cfg)
    }
}

/// Per-process chaos state: the config plus the deterministic frame
/// counter and RNG it drives.  Shared across connections (behind a
/// `Mutex`) so the schedule spans reconnects — frame N is frame N no
/// matter how many sessions it took to get there.
#[derive(Debug)]
pub struct ChaosState {
    cfg: ChaosConfig,
    rng: Xoshiro,
    frames: u64,
    partitioned: bool,
}

impl ChaosState {
    pub fn new(cfg: ChaosConfig) -> ChaosState {
        let rng = Xoshiro::new(cfg.seed);
        ChaosState { cfg, rng, frames: 0, partitioned: false }
    }
}

/// What `chaos_write` decided to do to one frame.
enum Fault {
    Clean,
    Delay(u64),
    Swallow,
    Drop,
    Trunc { delay_ms: u64 },
}

/// Shorthand for the optional shim threaded through the executor.
pub type Chaos<'a> = Option<&'a Mutex<ChaosState>>;

/// Write one frame through the chaos shim.  With `chaos == None` this
/// is exactly [`wire::write_frame`].  Returns the bytes the *peer
/// believes* were sent (header + full body) even when the frame was
/// swallowed by a partition, so byte accounting stays consistent on the
/// healthy side.
pub fn chaos_write(
    stream: &mut TcpStream,
    tag: Tag,
    body: &[u8],
    chaos: Chaos<'_>,
) -> Result<usize> {
    let Some(state) = chaos else {
        return wire::write_frame(stream, tag, body);
    };
    // decide under the lock, act (sleep/write) outside it
    let fault = {
        let mut st = state.lock().unwrap_or_else(|e| e.into_inner());
        let idx = st.frames;
        st.frames += 1;
        if st.partitioned {
            Fault::Swallow
        } else if idx < st.cfg.after {
            Fault::Clean
        } else {
            let eligible = idx - st.cfg.after < st.cfg.window;
            // one uniform per knob, always consumed, so the schedule of
            // later frames does not depend on which faults are enabled
            let (u_part, u_drop, u_trunc) = (st.rng.f64(), st.rng.f64(), st.rng.f64());
            if eligible && u_part < st.cfg.partition {
                st.partitioned = true;
                Fault::Swallow
            } else if eligible && u_drop < st.cfg.drop {
                Fault::Drop
            } else if eligible && u_trunc < st.cfg.trunc {
                Fault::Trunc { delay_ms: st.cfg.delay_ms }
            } else {
                Fault::Delay(st.cfg.delay_ms)
            }
        }
    };
    match fault {
        Fault::Clean => wire::write_frame(stream, tag, body),
        Fault::Delay(0) => wire::write_frame(stream, tag, body),
        Fault::Delay(ms) => {
            std::thread::sleep(std::time::Duration::from_millis(ms));
            wire::write_frame(stream, tag, body)
        }
        Fault::Swallow => {
            // one-way partition: outbound silently vanishes, inbound
            // (handled elsewhere) keeps flowing
            Ok(5 + body.len())
        }
        Fault::Drop => {
            stream.shutdown(Shutdown::Both).ok();
            bail!("chaos: dropped connection before {tag:?} frame");
        }
        Fault::Trunc { delay_ms } => {
            if delay_ms > 0 {
                std::thread::sleep(std::time::Duration::from_millis(delay_ms));
            }
            let mut header = [0u8; 5];
            header[..4].copy_from_slice(&(body.len() as u32).to_le_bytes());
            header[4] = tag as u8;
            // cut mid-body (or mid-header for tiny frames): the peer
            // sees a clean EOF partway through a promised frame
            if body.len() >= 2 {
                stream.write_all(&header).context("chaos trunc header")?;
                stream.write_all(&body[..body.len() / 2]).context("chaos trunc body")?;
            } else {
                stream.write_all(&header[..3]).context("chaos trunc header")?;
            }
            stream.flush().ok();
            stream.shutdown(Shutdown::Both).ok();
            bail!("chaos: truncated {tag:?} frame");
        }
    }
}

/// A standalone chaos TCP forwarder: `ddopt chaosproxy LISTEN CONNECT
/// --chaos ...`.  Driver→executor bytes are pumped through verbatim;
/// executor→driver traffic is re-framed and pushed through the same
/// [`chaos_write`] shim as an in-executor `--chaos`, so faults can be
/// injected in front of an *unmodified* executor binary.
pub fn chaosproxy(listen: &str, connect: &str, cfg: ChaosConfig) -> Result<()> {
    let listener =
        TcpListener::bind(listen).with_context(|| format!("chaosproxy bind {listen}"))?;
    println!("chaosproxy listening on {} -> {}", listener.local_addr()?, connect);
    let state = std::sync::Arc::new(Mutex::new(ChaosState::new(cfg)));
    for conn in listener.incoming() {
        let down = match conn {
            Ok(s) => s,
            Err(e) => {
                eprintln!("chaosproxy accept error: {e}");
                continue;
            }
        };
        let up = match TcpStream::connect(connect) {
            Ok(s) => s,
            Err(e) => {
                eprintln!("chaosproxy: upstream {connect} unreachable: {e}");
                continue;
            }
        };
        down.set_nodelay(true).ok();
        up.set_nodelay(true).ok();
        // driver -> executor: raw byte pump, no faults
        {
            let (mut from, to) = (down.try_clone()?, up.try_clone()?);
            std::thread::spawn(move || {
                let mut to = to;
                let _ = std::io::copy(&mut from, &mut to);
                to.shutdown(Shutdown::Write).ok();
            });
        }
        // executor -> driver: frame-level pump through the chaos shim
        {
            let state = state.clone();
            let (mut from, mut to) = (up, down);
            std::thread::spawn(move || {
                let mut buf = Vec::new();
                loop {
                    let tag = match wire::read_frame(&mut from, &mut buf) {
                        Ok((tag, _)) => tag,
                        Err(_) => break,
                    };
                    if chaos_write(&mut to, tag, &buf, Some(&state)).is_err() {
                        break;
                    }
                }
                to.shutdown(Shutdown::Write).ok();
                from.shutdown(Shutdown::Both).ok();
            });
        }
    }
    Ok(())
}

/// Parse the optional `--chaos` flag value into the shared state the
/// executor's write path consumes.
pub fn state_from_flag(spec: Option<&str>) -> Result<Option<Mutex<ChaosState>>> {
    Ok(match spec {
        Some(s) => Some(Mutex::new(ChaosState::new(ChaosConfig::parse(s)?))),
        None => None,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Read;

    fn loopback_pair() -> (TcpStream, TcpStream) {
        let l = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = l.local_addr().unwrap();
        let a = TcpStream::connect(addr).unwrap();
        let (b, _) = l.accept().unwrap();
        (a, b)
    }

    #[test]
    fn parse_round_trips_and_rejects_garbage() {
        let cfg = ChaosConfig::parse("seed=7,delay=250,drop=0.5,trunc=0.25,partition=1,after=3,window=2")
            .unwrap();
        assert_eq!(
            cfg,
            ChaosConfig {
                seed: 7,
                after: 3,
                window: 2,
                delay_ms: 250,
                drop: 0.5,
                trunc: 0.25,
                partition: 1.0,
            }
        );
        assert_eq!(ChaosConfig::parse("").unwrap(), ChaosConfig::default());
        assert!(ChaosConfig::parse("drop=1.5").is_err());
        assert!(ChaosConfig::parse("seed=abc").is_err());
        assert!(ChaosConfig::parse("bogus=1").is_err());
    }

    #[test]
    fn clean_frames_pass_through_and_schedule_is_deterministic() {
        let (mut tx, mut rx) = loopback_pair();
        let state = Mutex::new(ChaosState::new(ChaosConfig::parse("seed=5").unwrap()));
        let n = chaos_write(&mut tx, Tag::StageAck, b"xyz", Some(&state)).unwrap();
        assert_eq!(n, 8);
        let mut body = Vec::new();
        let (tag, _) = wire::read_frame(&mut rx, &mut body).unwrap();
        assert_eq!(tag, Tag::StageAck);
        assert_eq!(body, b"xyz");

        // same seed, same per-frame fault decisions across two states
        let cfg = ChaosConfig::parse("seed=9,drop=0.5").unwrap();
        let decisions = |cfg: ChaosConfig| {
            let st = Mutex::new(ChaosState::new(cfg));
            (0..32)
                .map(|_| {
                    let (mut tx, _rx) = loopback_pair();
                    chaos_write(&mut tx, Tag::Bye, b"", Some(&st)).is_ok()
                })
                .collect::<Vec<bool>>()
        };
        let a = decisions(cfg.clone());
        assert_eq!(a, decisions(cfg));
        assert!(a.contains(&true) && a.contains(&false), "p=0.5 over 32 frames: {a:?}");
    }

    #[test]
    fn partition_swallows_writes_forever_after_tripping() {
        let (mut tx, mut rx) = loopback_pair();
        let state =
            Mutex::new(ChaosState::new(ChaosConfig::parse("partition=1,after=1").unwrap()));
        // frame 0: before `after`, delivered
        chaos_write(&mut tx, Tag::Bye, b"", Some(&state)).unwrap();
        // frame 1 trips the partition; it and everything after report
        // success but never hit the wire
        assert_eq!(chaos_write(&mut tx, Tag::Bye, b"abcd", Some(&state)).unwrap(), 9);
        chaos_write(&mut tx, Tag::Bye, b"", Some(&state)).unwrap();
        drop(tx);
        let mut all = Vec::new();
        rx.read_to_end(&mut all).unwrap();
        assert_eq!(all.len(), 5, "only the pre-partition frame arrived: {all:?}");
    }

    #[test]
    fn truncation_cuts_the_frame_and_kills_the_stream() {
        let (mut tx, mut rx) = loopback_pair();
        let state =
            Mutex::new(ChaosState::new(ChaosConfig::parse("trunc=1,window=1").unwrap()));
        let err = chaos_write(&mut tx, Tag::StepResult, &[0u8; 64], Some(&state)).unwrap_err();
        assert!(err.to_string().contains("chaos"), "{err}");
        let mut all = Vec::new();
        rx.read_to_end(&mut all).unwrap();
        assert_eq!(all.len(), 5 + 32, "header + half the body");
        // the reader sees a hard error, not a short success
        let mut cur = std::io::Cursor::new(all);
        let mut body = Vec::new();
        assert!(wire::read_frame(&mut cur, &mut body).is_err());
    }

    #[test]
    fn window_limits_eligibility() {
        // trunc=1 but window=1 starting at frame 2: frames 0,1 and 3+ clean
        let state = Mutex::new(ChaosState::new(
            ChaosConfig::parse("trunc=1,after=2,window=1").unwrap(),
        ));
        for i in 0..5 {
            let (mut tx, _rx) = loopback_pair();
            let r = chaos_write(&mut tx, Tag::Bye, b"hello!", Some(&state));
            assert_eq!(r.is_err(), i == 2, "frame {i}: {r:?}");
        }
    }
}
