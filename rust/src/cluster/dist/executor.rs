//! The executor server — `ddopt executor --bind ADDR`.
//!
//! One executor process serves one driver connection at a time (and then
//! the next: the accept loop is long-lived, so a single `ddopt executor`
//! can back many training runs).  Per connection it:
//!
//! 1. answers the versioned handshake ([`wire::Tag::Hello`]), acking the
//!    subset of the driver's offered capability bits this build
//!    implements ([`wire::CAPS_SUPPORTED`]);
//! 2. receives the partition *metadata* plus exactly the grid blocks it
//!    owns under the Stage frame's ownership layout (round-robin or
//!    contiguous — the same [`Ownership`] keying [`GridOp::owner`] uses
//!    driver-side), installs them into a local [`Partitioned`], and
//!    stages it on the native backend — the data is now resident for the
//!    whole session, like a Spark executor's cached RDD partitions;
//! 3. loops on superstep frames: decode the op (full or sliced, per the
//!    frame's flags byte), run its owned tasks on the local
//!    [`WorkerPool`] through the shared interpreter ([`GridOp::exec_task`]
//!    — the very function the sim backend runs), optionally pre-fold its
//!    locally-owned aligned subtrees of the segment-combine tree (in
//!    exactly the global tree's pairing order, so the driver-side
//!    [`reduce_segments_folded`](crate::cluster::SimCluster::reduce_segments_folded)
//!    stays bit-identical), and reply with each task's measured seconds
//!    and output segment — or, for leaves absorbed by a fold, just the
//!    absorbed marker.
//!
//! Wire revision 4 adds two more frames to the superstep loop:
//!
//! * [`wire::Tag::CellMap`] re-negotiates cell placement mid-session (an
//!   elastic degrade or a rebalance back), shipping along whichever
//!   newly required blocks — orphans of a dead peer, or speculation
//!   replicas — this executor has not staged yet.  The explicit map then
//!   overrides the functional [`Ownership`] for task/fold/factor
//!   ownership.
//! * [`wire::Tag::SpecStep`] runs a *backup copy* of another executor's
//!   task list (carried explicitly in the frame) against the local
//!   replicas — same interpreter, never folded, same reply format.
//!
//! Task errors are per-task data in the reply (the driver reproduces the
//! sim backend's lowest-task-index-wins rule across executors); protocol
//! errors tear down the connection with a [`wire::Tag::Fatal`] frame
//! where possible.
//!
//! Every outgoing frame goes through [`chaos::chaos_write`]: `--chaos`
//! turns the executor into its own deterministic network adversary
//! (delays, drops, truncations, one-way partitions), and without the
//! flag the shim is a single pointer test per frame.

use super::chaos::{self, Chaos, ChaosConfig, ChaosState};
use super::ops::OpBuf;
use super::wire::{self, Tag};
use crate::cluster::{CellMap, GridOp, OpScratch, Ownership, TaskSlab, WorkerPool};
use crate::data::{decode_block, Block, Partitioned};
use crate::obs::{self, Counter, MetricsRegistry, Phase, SpanEvent};
use crate::runtime::{Backend, FactorHandle, StagedGrid};
use crate::util::bytes::{self, ByteReader};
use anyhow::{bail, Context, Result};
use std::io::Write as _;
use std::net::{TcpListener, TcpStream};
use std::sync::{Arc, Mutex};

/// `ddopt executor` settings.
pub struct ExecutorConfig {
    /// `host:port` to listen on (port 0 = OS-assigned; the chosen
    /// address is printed as `executor listening on ADDR`).
    pub bind: String,
    /// Local worker threads for superstep tasks.
    pub threads: usize,
    /// Serve a single driver connection, then exit (tests/CI).
    pub once: bool,
    /// Chaos harness: abort the process (as if SIGKILLed) upon receiving
    /// the Nth Step frame across the executor's lifetime; 0 disables.
    /// Lets the fault-recovery tests kill an executor mid-superstep at a
    /// deterministic point.
    pub chaos_abort_step: u64,
    /// Seeded network-fault injection on every outgoing frame
    /// (`--chaos seed=N,delay=MS,drop=P,trunc=P,partition=P,...`).
    pub chaos: Option<ChaosConfig>,
    /// `host:port` to serve Prometheus-text metrics on (`GET /metrics`);
    /// `None` disables the endpoint.  The chosen address is printed as
    /// `executor metrics on ADDR`.
    pub metrics_addr: Option<String>,
}

/// Executor-lifetime counters, served over `--metrics-addr` and bumped
/// from the accept/superstep loops.  All handles point into one shared
/// [`MetricsRegistry`].
struct ExecMetrics {
    connections: Counter,
    steps: Counter,
    spec_steps: Counter,
    task_errors: Counter,
}

impl ExecMetrics {
    fn new(reg: &MetricsRegistry) -> Self {
        ExecMetrics {
            connections: reg.counter(
                "ddopt_executor_connections_total",
                "Driver connections accepted by this executor process",
            ),
            steps: reg.counter(
                "ddopt_executor_steps_total",
                "Primary Step frames served",
            ),
            spec_steps: reg.counter(
                "ddopt_executor_spec_steps_total",
                "Speculative backup SpecStep frames served",
            ),
            task_errors: reg.counter(
                "ddopt_executor_task_errors_total",
                "Per-task kernel errors reported in StepResult replies",
            ),
        }
    }
}

/// One staged driver session, kept across connections (keyed by the
/// driver's session token) so a driver that lost its connection — not
/// the executor process — can `Rejoin` without re-shipping blocks.  A
/// clean `Shutdown` drops it.
struct CachedSession {
    token: u64,
    my_index: usize,
    n_execs: usize,
    ownership: Ownership,
    part: Partitioned,
    /// Explicit placement installed by a `CellMap` frame; overrides the
    /// functional `ownership` while the fleet runs degraded (or carries
    /// speculation replicas).  Survives reconnects with the session.
    map: Option<CellMap>,
}

/// Run the executor server (blocks forever unless `once`).
pub fn serve(cfg: &ExecutorConfig) -> Result<()> {
    let listener = TcpListener::bind(&cfg.bind)
        .with_context(|| format!("bind executor on {}", cfg.bind))?;
    let local = listener.local_addr()?;
    // the one line tooling parses: tests and the loopback quickstart
    // discover OS-assigned ports from it
    println!("executor listening on {local}");
    std::io::stdout().flush().ok();
    let registry = Arc::new(MetricsRegistry::new());
    if let Some(addr) = &cfg.metrics_addr {
        let bound = obs::serve_metrics(addr, Arc::clone(&registry))?;
        println!("executor metrics on {bound}");
        std::io::stdout().flush().ok();
    }
    let metrics = ExecMetrics::new(&registry);
    let chaos_state = cfg.chaos.clone().map(|c| Mutex::new(ChaosState::new(c)));
    serve_listener_full(
        listener,
        cfg.threads,
        cfg.once,
        cfg.chaos_abort_step,
        chaos_state.as_ref(),
        Some(&metrics),
    )
}

/// The accept loop behind [`serve`], on an already-bound listener — lets
/// in-process harnesses (the perf wire bench, the checkpoint parity
/// test) run loopback executors on OS-assigned ports without spawning
/// child processes.
pub fn serve_listener(listener: TcpListener, threads: usize, once: bool) -> Result<()> {
    serve_listener_chaos(listener, threads, once, 0, None)
}

/// [`serve_listener`] plus the abort knob (see
/// [`ExecutorConfig::chaos_abort_step`]).
pub fn serve_listener_with(
    listener: TcpListener,
    threads: usize,
    once: bool,
    chaos_abort_step: u64,
) -> Result<()> {
    serve_listener_chaos(listener, threads, once, chaos_abort_step, None)
}

/// The full accept loop: abort knob plus the seeded outgoing-frame chaos
/// shim (shared across connections, so the fault schedule spans
/// reconnects).
pub fn serve_listener_chaos(
    listener: TcpListener,
    threads: usize,
    once: bool,
    chaos_abort_step: u64,
    chaos: Chaos<'_>,
) -> Result<()> {
    serve_listener_full(listener, threads, once, chaos_abort_step, chaos, None)
}

/// [`serve_listener_chaos`] plus the process-lifetime metrics handles
/// (`None` when no registry is wired up, as in the in-process harnesses).
fn serve_listener_full(
    listener: TcpListener,
    threads: usize,
    once: bool,
    chaos_abort_step: u64,
    chaos: Chaos<'_>,
    metrics: Option<&ExecMetrics>,
) -> Result<()> {
    let mut cache: Option<CachedSession> = None;
    let mut steps_served: u64 = 0;
    loop {
        let (stream, peer) = listener.accept().context("accept driver connection")?;
        eprintln!("executor: serving driver at {peer}");
        if let Some(m) = metrics {
            m.connections.inc();
        }
        match serve_conn(
            stream,
            threads,
            &mut cache,
            chaos_abort_step,
            &mut steps_served,
            chaos,
            metrics,
        ) {
            Ok(()) => eprintln!("executor: driver at {peer} finished cleanly"),
            // keep the cached session: a dropped connection is exactly
            // what a driver-side failure (or our own chaos abort on a
            // *different* executor) looks like, and the driver may
            // Rejoin on the next connection
            Err(e) => eprintln!("executor: session with {peer} ended: {e:#}"),
        }
        if once {
            return Ok(());
        }
    }
}

/// How one [`serve_session`] call ended.
enum SessionOutcome {
    /// Clean `Shutdown`: drop the cached session.
    Clean,
    /// A `CellMap` frame arrived: install the new placement (and its
    /// shipped blocks) into the cached session, ack, and re-enter the
    /// superstep loop.  Surfaced as an outcome because installing blocks
    /// mutates the partition the staged grid borrows.
    Remap { map: CellMap, blocks: Vec<(usize, Block)> },
}

/// Serve one driver connection until `Shutdown` or EOF.  The first frame
/// is either `Hello` (fresh session: handshake + Stage) or `Rejoin`
/// (re-attach to the cached session, restaging only if the cache is
/// gone).
#[allow(clippy::too_many_arguments)]
fn serve_conn(
    mut stream: TcpStream,
    threads: usize,
    cache: &mut Option<CachedSession>,
    chaos_abort_step: u64,
    steps_served: &mut u64,
    chaos: Chaos<'_>,
    metrics: Option<&ExecMetrics>,
) -> Result<()> {
    stream.set_nodelay(true).ok();
    let mut buf = Vec::new();
    let (tag, _) = wire::read_frame(&mut stream, &mut buf)?;
    let caps = match tag {
        Tag::Hello => hello_session(&mut stream, &mut buf, threads, cache, chaos)?,
        Tag::Rejoin => rejoin_session(&mut stream, &mut buf, threads, cache, chaos)?,
        other => bail!("protocol violation: first frame was {other:?}, not Hello or Rejoin"),
    };
    loop {
        let sess = cache.as_mut().expect("handshake established a session");
        let outcome = serve_session(
            &mut stream,
            threads,
            sess,
            caps,
            chaos_abort_step,
            steps_served,
            &mut buf,
            chaos,
            metrics,
        )?;
        match outcome {
            SessionOutcome::Clean => {
                *cache = None;
                return Ok(());
            }
            SessionOutcome::Remap { map, blocks } => {
                let n_new = blocks.len();
                for (cell, b) in blocks {
                    sess.part
                        .set_block(cell, b)
                        .with_context(|| format!("install remapped block for cell {cell}"))?;
                }
                eprintln!(
                    "executor {}/{}: installed new cell map (+{n_new} blocks)",
                    sess.my_index, sess.n_execs
                );
                sess.map = Some(map);
                chaos::chaos_write(&mut stream, Tag::CellMapAck, &[], chaos)?;
            }
        }
    }
}

/// The `Hello` handshake + initial Stage of a fresh session.  Returns
/// the acked capability mask and installs the session in `cache`.
fn hello_session(
    stream: &mut TcpStream,
    buf: &mut Vec<u8>,
    threads: usize,
    cache: &mut Option<CachedSession>,
    chaos: Chaos<'_>,
) -> Result<u32> {
    let mut r = ByteReader::new(buf);
    let magic = r.u32()?;
    if magic != wire::PROTO_MAGIC {
        bail!("handshake magic mismatch: got {magic:#x}");
    }
    let version = r.u32()?;
    if version != wire::PROTO_VERSION {
        let mut body = Vec::new();
        bytes::put_str(
            &mut body,
            &format!(
                "protocol version mismatch: driver speaks v{version}, executor v{}",
                wire::PROTO_VERSION
            ),
        );
        let _ = chaos::chaos_write(stream, Tag::Fatal, &body, chaos);
        bail!("protocol version mismatch (driver v{version})");
    }
    let my_index = r.u32()? as usize;
    let n_execs = r.u32()? as usize;
    let offered = r.u32()?;
    // wire revision 3 appends a session token; a v2 driver sends none
    // (token 0 then simply never matches a Rejoin)
    let token = if r.remaining() >= 8 { r.u64()? } else { 0 };
    if n_execs == 0 || my_index >= n_execs {
        bail!("bad handshake: executor {my_index} of {n_execs}");
    }
    // ack the intersection of what the driver offered and what this
    // build implements; the driver runs the fleet at the AND of all acks
    let caps = offered & wire::CAPS_SUPPORTED;
    let mut ack = Vec::new();
    bytes::put_u32(&mut ack, wire::PROTO_MAGIC);
    bytes::put_u32(&mut ack, wire::PROTO_VERSION);
    bytes::put_u32(&mut ack, threads as u32);
    bytes::put_u32(&mut ack, caps);
    // wire revision 5: trailing monotonic tick for the driver's
    // RTT-midpoint clock-offset estimate (old drivers ignore the tail)
    bytes::put_u64(&mut ack, obs::now_ns());
    chaos::chaos_write(stream, Tag::HelloAck, &ack, chaos)?;

    let (ownership, part) = receive_stage(stream, buf, caps, my_index, n_execs, threads, chaos)?;
    *cache = Some(CachedSession { token, my_index, n_execs, ownership, part, map: None });
    Ok(caps)
}

/// The `Rejoin` handshake (wire revision 3): re-attach a driver to the
/// cached session, restaging only when the cache is gone (process was
/// restarted) or belongs to a different run (token mismatch).
fn rejoin_session(
    stream: &mut TcpStream,
    buf: &mut Vec<u8>,
    threads: usize,
    cache: &mut Option<CachedSession>,
    chaos: Chaos<'_>,
) -> Result<u32> {
    let mut r = ByteReader::new(buf);
    let magic = r.u32()?;
    if magic != wire::PROTO_MAGIC {
        bail!("rejoin magic mismatch: got {magic:#x}");
    }
    let token = r.u64()?;
    let my_index = r.u32()? as usize;
    let n_execs = r.u32()? as usize;
    let step_id = r.u64()?;
    let offered = r.u32()?;
    if n_execs == 0 || my_index >= n_execs {
        bail!("bad rejoin: executor {my_index} of {n_execs}");
    }
    let caps = offered & wire::CAPS_SUPPORTED;
    let have = cache
        .as_ref()
        .map_or(false, |s| s.token == token && s.my_index == my_index && s.n_execs == n_execs);
    if !have {
        // a cached session from some other run is useless here
        *cache = None;
    }
    let mut ack = Vec::new();
    bytes::put_u32(&mut ack, wire::PROTO_MAGIC);
    bytes::put_u32(&mut ack, threads as u32);
    bytes::put_u32(&mut ack, caps);
    bytes::put_u8(&mut ack, if have { 1 } else { 0 });
    // wire revision 5: trailing tick, same role as in HelloAck
    bytes::put_u64(&mut ack, obs::now_ns());
    chaos::chaos_write(stream, Tag::RejoinAck, &ack, chaos)?;
    eprintln!(
        "executor {my_index}/{n_execs}: rejoin for superstep {step_id} ({})",
        if have { "blocks still cached" } else { "restaging" }
    );
    if !have {
        let (ownership, part) =
            receive_stage(stream, buf, caps, my_index, n_execs, threads, chaos)?;
        *cache = Some(CachedSession { token, my_index, n_execs, ownership, part, map: None });
    }
    Ok(caps)
}

/// Receive and decode one Stage frame: partition metadata plus exactly
/// this executor's owned blocks, acked once installed.
fn receive_stage(
    stream: &mut TcpStream,
    buf: &mut Vec<u8>,
    caps: u32,
    my_index: usize,
    n_execs: usize,
    threads: usize,
    chaos: Chaos<'_>,
) -> Result<(Ownership, Partitioned)> {
    let (tag, _) = wire::read_frame(stream, buf)?;
    if tag != Tag::Stage {
        bail!("protocol violation: wanted Stage, got {tag:?}");
    }
    let mut r = ByteReader::new(buf);
    let ownership = Ownership::from_u8(r.u8()?)?;
    if ownership == Ownership::Contiguous && caps & wire::CAP_CONTIG_FOLD == 0 {
        bail!("driver staged contiguous ownership without the negotiated capability");
    }
    let mut part = Partitioned::decode_meta(&mut r)?;
    let n_blocks = r.u32()? as usize;
    for _ in 0..n_blocks {
        let cell = r.usize()?;
        if ownership.owner(cell, part.grid.k(), n_execs) != my_index {
            bail!("staged block for cell {cell} does not belong to executor {my_index}/{n_execs}");
        }
        let block = decode_block(&mut r)?;
        part.set_block(cell, block)?;
    }
    if !r.is_empty() {
        bail!("trailing bytes after Stage payload");
    }
    eprintln!(
        "executor {my_index}/{n_execs}: cached {n_blocks} blocks of a {}x{} grid \
         ({} threads, {ownership:?} ownership)",
        part.grid.p, part.grid.q, threads
    );
    chaos::chaos_write(stream, Tag::StageAck, &[], chaos)?;
    Ok((ownership, part))
}

/// Decode one `CellMap` frame: the new placement plus the blocks this
/// executor must newly install.  The install itself happens in
/// [`serve_conn`], outside the staged-grid borrow.
fn decode_cell_map(
    buf: &[u8],
    n_execs: usize,
    caps: u32,
) -> Result<(CellMap, Vec<(usize, Block)>)> {
    if caps & wire::CAP_ELASTIC == 0 {
        bail!("driver sent a CellMap frame without the negotiated capability");
    }
    let mut r = ByteReader::new(buf);
    let magic = r.u32()?;
    if magic != wire::PROTO_MAGIC {
        bail!("cell map magic mismatch: got {magic:#x}");
    }
    let step_id = r.u64()?;
    let n = r.u32()? as usize;
    if n != n_execs {
        bail!("cell map sized for {n} executors, session has {n_execs}");
    }
    let map = CellMap::decode(&mut r, n_execs)?;
    let n_blocks = r.u32()? as usize;
    let mut blocks = Vec::with_capacity(n_blocks);
    for _ in 0..n_blocks {
        let cell = r.usize()?;
        blocks.push((cell, decode_block(&mut r)?));
    }
    if !r.is_empty() {
        bail!("trailing bytes after CellMap payload (superstep {step_id})");
    }
    Ok((map, blocks))
}

/// The superstep loop of one staged session.  Returns on a clean
/// `Shutdown` or a `CellMap` remap (see [`SessionOutcome`]) — any other
/// exit is an error, which keeps the cache for a possible Rejoin.
#[allow(clippy::too_many_arguments)]
fn serve_session(
    stream: &mut TcpStream,
    threads: usize,
    sess: &CachedSession,
    caps: u32,
    chaos_abort_step: u64,
    steps_served: &mut u64,
    buf: &mut Vec<u8>,
    chaos: Chaos<'_>,
    metrics: Option<&ExecMetrics>,
) -> Result<SessionOutcome> {
    let part = &sess.part;
    let map = sess.map.as_ref();
    let (my_index, n_execs, ownership) = (sess.my_index, sess.n_execs, sess.ownership);
    let backend = Backend::native();
    let staged = backend.stage(part)?;
    let pool = WorkerPool::new(threads);
    pool.warm_up();
    let mut scratch: Vec<OpScratch> =
        (0..threads.max(1)).map(|_| OpScratch::for_part(part)).collect();
    let mut factors: Vec<Option<FactorHandle>> = Vec::new();

    // -- superstep loop ----------------------------------------------
    let mut opbuf = OpBuf::new();
    let mut owned: Vec<usize> = Vec::new();
    let mut times: Vec<f64> = Vec::new();
    let mut out: Vec<f32> = Vec::new();
    let mut out2: Vec<f32> = Vec::new();
    let mut reply: Vec<u8> = Vec::new();
    let mut span_buf: Vec<SpanEvent> = Vec::new();
    loop {
        let (tag, _) = wire::read_frame(stream, buf)?;
        match tag {
            Tag::PrepareAdmm => {
                // factor the owned cells only, off the clock (the paper
                // excludes this one-time cost from reported times);
                // "owned" follows the explicit map while degraded
                factors.clear();
                for cell in 0..part.grid.k() {
                    let mine = match map {
                        Some(m) => m.slot(cell) == my_index,
                        None => ownership.owner(cell, part.grid.k(), n_execs) == my_index,
                    };
                    if mine {
                        let (p, q) = (cell / part.grid.q, cell % part.grid.q);
                        factors.push(Some(staged.admm_factor(p, q)?));
                    } else {
                        factors.push(None);
                    }
                }
                chaos::chaos_write(stream, Tag::PrepareAdmmAck, &[], chaos)?;
            }
            Tag::Step | Tag::SpecStep => {
                let forced = tag == Tag::SpecStep;
                if forced && caps & wire::CAP_SPEC == 0 {
                    bail!("driver sent a SpecStep without the negotiated capability");
                }
                if !forced {
                    // the abort knob counts *primary* Step frames only,
                    // so a test's "die on step N" stays deterministic
                    // whether or not speculation is on
                    *steps_served += 1;
                    if chaos_abort_step != 0 && *steps_served == chaos_abort_step {
                        // die like a SIGKILLed process: no Fatal frame,
                        // no unwinding, the driver just sees the socket
                        // drop mid-superstep
                        eprintln!(
                            "executor {my_index}: chaos abort on step frame {steps_served}"
                        );
                        std::process::abort();
                    }
                }
                if let Some(m) = metrics {
                    if forced { m.spec_steps.inc() } else { m.steps.inc() }
                }
                let outcome = run_step(
                    &staged,
                    &pool,
                    &mut scratch,
                    &factors,
                    &mut opbuf,
                    buf,
                    my_index,
                    n_execs,
                    ownership,
                    map,
                    caps,
                    forced,
                    &mut owned,
                    &mut times,
                    &mut out,
                    &mut out2,
                    &mut reply,
                    &mut span_buf,
                );
                match outcome {
                    Ok(n_task_errs) => {
                        if let (Some(m), true) = (metrics, n_task_errs > 0) {
                            m.task_errors.add(n_task_errs as u64);
                        }
                        chaos::chaos_write(stream, Tag::StepResult, &reply, chaos)?;
                    }
                    Err(e) => {
                        // protocol-level failure (bad frame, unknown op):
                        // tell the driver before tearing down
                        let mut body = Vec::new();
                        bytes::put_str(&mut body, &format!("{e:#}"));
                        let _ = chaos::chaos_write(stream, Tag::Fatal, &body, chaos);
                        return Err(e);
                    }
                }
            }
            Tag::CellMap => {
                let (new_map, blocks) = decode_cell_map(buf, n_execs, caps)?;
                // the blocks must be installed into the partition the
                // staged grid currently borrows: hand the remap up
                return Ok(SessionOutcome::Remap { map: new_map, blocks });
            }
            Tag::Shutdown => {
                chaos::chaos_write(stream, Tag::Bye, &[], chaos)?;
                return Ok(SessionOutcome::Clean);
            }
            Tag::Fatal => {
                let msg = ByteReader::new(buf).str().unwrap_or_default();
                bail!("driver reported fatal error: {msg}");
            }
            other => bail!("protocol violation: unexpected {other:?} frame"),
        }
    }
}

/// Decode one Step (or SpecStep) frame, run the owned tasks, optionally
/// pre-fold the locally-owned aligned combine subtrees, and build the
/// StepResult body in `reply`.  Per-task kernel errors become per-task
/// reply entries — only frame/op decoding problems are `Err` here; the
/// `Ok` value is the number of per-task errors (for the metrics counter).
///
/// With `forced` (a SpecStep), the task list rides in the frame instead
/// of being derived from ownership: the executor is running a backup
/// copy of *another* executor's tasks against its local replicas.
#[allow(clippy::too_many_arguments)]
fn run_step(
    staged: &StagedGrid<'_>,
    pool: &WorkerPool,
    scratch: &mut [OpScratch],
    factors: &[Option<FactorHandle>],
    opbuf: &mut OpBuf,
    frame: &[u8],
    my_index: usize,
    n_execs: usize,
    ownership: Ownership,
    map: Option<&CellMap>,
    caps: u32,
    forced: bool,
    owned: &mut Vec<usize>,
    times: &mut Vec<f64>,
    out: &mut Vec<f32>,
    out2: &mut Vec<f32>,
    reply: &mut Vec<u8>,
    span_buf: &mut Vec<SpanEvent>,
) -> Result<usize> {
    let part = staged.part;
    let mut r = ByteReader::new(frame);
    let step_id = r.u64()?;
    let flags = r.u8()?;
    if flags & wire::STEP_FLAG_SLICED != 0 && caps & wire::CAP_SLICED == 0 {
        bail!("driver sent a sliced Step without the negotiated capability");
    }
    if flags & wire::STEP_FLAG_FOLD != 0 && caps & wire::CAP_CONTIG_FOLD == 0 {
        bail!("driver requested gather folding without the negotiated capability");
    }
    let trace = flags & wire::STEP_FLAG_TRACE != 0;
    if trace && caps & wire::CAP_TRACE == 0 {
        bail!("driver requested span tracing without the negotiated capability");
    }
    if forced {
        // a backup copy: explicit task list, sliced payload, never folded
        // (the replica holder's fold subtrees are not the laggard's)
        if flags & wire::STEP_FLAG_SLICED == 0 {
            bail!("SpecStep without the sliced flag");
        }
        if flags & wire::STEP_FLAG_FOLD != 0 {
            bail!("SpecStep requested gather folding");
        }
        if trace {
            bail!("SpecStep requested span tracing");
        }
        let count = r.u32()? as usize;
        owned.clear();
        for _ in 0..count {
            owned.push(r.u32()? as usize);
        }
    }
    if flags & wire::STEP_FLAG_SLICED != 0 {
        opbuf.decode_sliced_into(&mut r)?;
    } else {
        opbuf.decode_into(&mut r)?;
    }
    if !r.is_empty() {
        bail!("trailing bytes after Step payload");
    }
    let op: GridOp<'_> = opbuf.as_op()?;

    let n_tasks = op.n_tasks(part);
    if forced {
        for &task in owned.iter() {
            if task >= n_tasks {
                bail!("SpecStep task {task} out of range ({n_tasks} tasks)");
            }
        }
    } else {
        owned.clear();
        for task in 0..n_tasks {
            let owner = match map {
                Some(m) => m.slot(op.cell(part, task)),
                None => op.owner(part, task, n_execs, ownership),
            };
            if owner == my_index {
                owned.push(task);
            }
        }
    }
    // grow-only slabs, never re-zeroed: exec_task fully overwrites every
    // owned span before it is shipped, and unowned/stale regions are
    // never serialized — so the memset would be wasted work proportional
    // to the whole model, not this executor's share
    let out_len = op.out_len(part);
    if out.len() < out_len {
        out.resize(out_len, 0.0);
    }
    let out2_len = op.out2_len(part);
    if out2.len() < out2_len {
        out2.resize(out2_len, 0.0);
    }
    times.clear();
    times.resize(owned.len(), 0.0);

    if trace {
        // lazily arm the per-worker rings (idempotent after the first
        // traced step) and stamp the superstep ordinal; rings stay armed
        // but spans are only recorded on steps that carry the trace bit
        for (w, sc) in scratch.iter_mut().enumerate() {
            sc.enable_tracing(obs::SPAN_RING_CAPACITY, (my_index + 1) as u16, w as u16);
            sc.set_trace_step(step_id as u32);
        }
    }

    // kernel errors are collected per task (the epoch always drains, so
    // every owned task still reports a measured duration)
    let errs: Mutex<Vec<(usize, String)>> = Mutex::new(Vec::new());
    {
        let out_slab = TaskSlab::new(out);
        let out2_slab = TaskSlab::new(out2);
        let owned_ref: &[usize] = owned;
        let op_ref = &op;
        let errs_ref = &errs;
        pool.run_indexed(owned_ref.len(), scratch, times, |i, sc| {
            let task = owned_ref[i];
            let t0 = if trace { obs::now_ns() } else { 0 };
            if let Err(e) =
                op_ref.exec_task(staged, factors, task, sc, &out_slab, &out2_slab)
            {
                errs_ref.lock().unwrap().push((task, format!("{e:#}")));
            }
            if trace {
                let t1 = obs::now_ns();
                sc.spans_mut().push_span(
                    op_ref.name(),
                    Phase::Exec,
                    task as u32,
                    task as u32 + 1,
                    t0,
                    t1,
                );
            }
            Ok(())
        })?;
    }
    let errs = errs.into_inner().unwrap();

    // locally-owned subtree pre-fold: fold_counts[i] = leaves folded into
    // owned[i]'s segment (1 = shipped unfolded, 0 = absorbed by a root)
    let mut fold_counts: Vec<usize> = vec![1; owned.len()];
    if flags & wire::STEP_FLAG_FOLD != 0 && errs.is_empty() {
        let t0 = if trace { obs::now_ns() } else { 0 };
        fold_owned_subtrees(&op, part, owned, out, &mut fold_counts);
        if trace {
            scratch[0].spans_mut().push_span(
                "fold",
                Phase::Fold,
                0,
                owned.len() as u32,
                t0,
                obs::now_ns(),
            );
        }
    }

    reply.clear();
    bytes::put_u64(reply, step_id);
    bytes::put_u32(reply, owned.len() as u32);
    for (i, &task) in owned.iter().enumerate() {
        bytes::put_u32(reply, task as u32);
        bytes::put_f64(reply, times[i]);
        if let Some((_, msg)) = errs.iter().find(|(t, _)| *t == task) {
            bytes::put_u8(reply, 1);
            bytes::put_str(reply, msg);
        } else if fold_counts[i] == 0 {
            // absorbed: its data already rode in its fold root's segment
            bytes::put_u8(reply, 2);
        } else {
            bytes::put_u8(reply, 0);
            bytes::put_u32(reply, fold_counts[i] as u32);
            let (s, l) = op.out_span(part, task);
            bytes::put_f32s(reply, &out[s..s + l]);
            let (s2, l2) = op.out2_span(part, task);
            bytes::put_f32s(reply, &out2[s2..s2 + l2]);
        }
    }
    if trace {
        // piggyback the drained span table after the task entries (the
        // driver decodes it iff it set the trace bit; older drivers never
        // set the bit, so they never see trailing bytes)
        span_buf.clear();
        let mut dropped: u64 = 0;
        for sc in scratch.iter_mut() {
            dropped += sc.spans_mut().drain(|ev| span_buf.push(*ev));
        }
        obs::encode_trace_frame(span_buf, dropped, reply)?;
    }
    Ok(errs.len())
}

/// Pre-combine the aligned power-of-two subtrees of each combine group
/// whose leaves this executor owns, element-wise in the *global*
/// [`reduce_segments`](crate::cluster::SimCluster::reduce_segments)
/// pairing order — an aligned block's internal pairs are exactly the
/// global tree's pairs restricted to that block, so the partial sums are
/// bit-identical to what the driver would have computed.  Marks each
/// block's root with the folded leaf count and its other leaves as
/// absorbed.
fn fold_owned_subtrees(
    op: &GridOp<'_>,
    part: &Partitioned,
    owned: &[usize],
    out: &mut [f32],
    fold_counts: &mut [usize],
) {
    // group the owned leaves by combine group (keyed by the group's slab
    // base — unique per group within one op); leaf lists come out
    // ascending because `owned` is ascending and leaf index is monotone
    // in task index on both fold axes
    let mut groups: std::collections::BTreeMap<usize, Vec<(usize, usize)>> =
        Default::default();
    for (i, &task) in owned.iter().enumerate() {
        if let Some(g) = op.fold_group(part, task) {
            groups.entry(g.base).or_default().push((g.leaf, i));
        }
    }
    for (_, leaves) in groups {
        let geom = op
            .fold_group(part, owned[leaves[0].1])
            .expect("grouped task must have fold geometry");
        // maximal consecutive runs (contiguous ownership guarantees one
        // run per group; round-robin would just yield length-1 runs)
        let mut run_start = 0usize;
        for k in 0..leaves.len() {
            let run_ends = k + 1 == leaves.len() || leaves[k + 1].0 != leaves[k].0 + 1;
            if !run_ends {
                continue;
            }
            let (a, b) = (leaves[run_start].0, leaves[k].0 + 1);
            run_start = k + 1;
            // decompose [a, b) into maximal aligned power-of-two blocks
            let mut x = a;
            while x < b {
                let mut size = 1usize;
                while x % (size * 2) == 0 && x + size * 2 <= b {
                    size *= 2;
                }
                if size > 1 {
                    fold_block(out, &geom, x, size);
                }
                for (leaf, i) in &leaves[..] {
                    if *leaf > x && *leaf < x + size {
                        fold_counts[*i] = 0;
                    } else if *leaf == x {
                        fold_counts[*i] = size;
                    }
                }
                x += size;
            }
        }
    }
}

/// Sum the aligned leaf block `[x, x + size)` of one combine group into
/// its root leaf `x`, level by level with the global tree's own pairing
/// (`gap = 1, 2, 4, ...`; adjacent survivors; `dst += src`).
fn fold_block(out: &mut [f32], g: &crate::cluster::FoldGroup, x: usize, size: usize) {
    let mut gap = 1usize;
    while gap < size {
        let mut y = x;
        while y + gap < x + size {
            let dst = g.base + y * g.stride;
            let src = g.base + (y + gap) * g.stride;
            let (head, tail) = out.split_at_mut(src);
            let d = &mut head[dst..dst + g.len];
            let s = &tail[..g.len];
            for (dv, &sv) in d.iter_mut().zip(s) {
                *dv += sv;
            }
            y += 2 * gap;
        }
        gap *= 2;
    }
}
