//! [`DistCluster`] — the driver-side transport: a [`ClusterBackend`]
//! whose supersteps execute on real executor processes over TCP.
//!
//! Per superstep the driver encodes the [`GridOp`] descriptor once
//! (iterates, index streams — kilobytes, never the training data),
//! broadcasts it to every executor, and gathers each task's result
//! segment back into the coordinator's output slab at the position
//! [`GridOp::out_span`] dictates.  Combining then happens through the
//! *identical* [`reduce_segments`](crate::cluster::SimCluster::reduce_segments)
//! code as the sim backend — level-by-level adjacent-survivor pairing,
//! `dst += src` — so the physical gather is rooted at the driver while
//! the arithmetic reuses [`tree_aggregate`](crate::cluster::comm::tree_aggregate)'s
//! combine order exactly: final weights are bit-identical to `--cluster
//! sim` at the same seed (asserted by `tests/dist_parity.rs`).
//!
//! Accounting is double-entry: executors report *measured* per-task
//! seconds, which feed the same scenario/LPT simulated-clock charge as
//! the sim backend ([`SimCluster::charge_measured`]), while every
//! exchange also lands in a [`WireRecord`] — real wall seconds, bytes
//! out, bytes in — so `ddopt train --wire-out` can put the cost model
//! and the measured transport side by side in one report.
//!
//! Failure semantics: per-task kernel errors reproduce the sim backend's
//! lowest-task-index-wins rule across executors (the superstep still
//! charges the clock); a dead or misbehaving executor (connection reset,
//! protocol violation, read timeout) surfaces as a clean `Err` naming
//! the executor — the driver never hangs on a killed peer.

use super::ops;
use super::wire::{self, Tag};
use crate::cluster::{ClusterBackend, ClusterConfig, GridOp, SimClock, SimCluster};
use crate::data::{encode_block, Partitioned};
use crate::metrics::WireRecord;
use crate::runtime::StagedGrid;
use crate::util::bytes::{self, ByteReader};
use anyhow::{bail, Context, Result};
use std::net::TcpStream;
use std::time::{Duration, Instant};

/// Default per-read socket timeout — generous for loopback supersteps,
/// small enough that a wedged executor fails the run instead of hanging
/// CI.  Workloads whose single superstep legitimately computes longer
/// (big datasets, few executor threads) raise it with
/// `DDOPT_DIST_READ_TIMEOUT_SECS` (`0` disables the timeout entirely).
const DEFAULT_READ_TIMEOUT_SECS: u64 = 60;

fn read_timeout() -> Option<Duration> {
    let secs = std::env::var("DDOPT_DIST_READ_TIMEOUT_SECS")
        .ok()
        .and_then(|v| v.trim().parse::<u64>().ok())
        .unwrap_or(DEFAULT_READ_TIMEOUT_SECS);
    (secs > 0).then(|| Duration::from_secs(secs))
}

struct ExecConn {
    stream: TcpStream,
    addr: String,
    threads: usize,
}

/// The distributed cluster backend (see module docs).
pub struct DistCluster {
    /// Simulated clock + collective cost model + in-place combine — the
    /// exact code the sim backend runs, fed with measured durations.
    sim: SimCluster,
    conns: Vec<ExecConn>,
    wire_log: Vec<WireRecord>,
    step_id: u64,
    send_buf: Vec<u8>,
    recv_buf: Vec<u8>,
    /// Per-task measured durations of the superstep in flight.
    durs: Vec<f64>,
    seen: Vec<bool>,
}

impl DistCluster {
    /// Connect to the executors, run the versioned handshake, and ship
    /// each its owned grid blocks (round-robin by flat cell index — the
    /// same keying [`GridOp::owner`] uses per superstep).
    pub fn connect(
        config: ClusterConfig,
        addrs: &[String],
        part: &Partitioned,
    ) -> Result<DistCluster> {
        if addrs.is_empty() {
            bail!("--cluster dist wants at least one executor address");
        }
        let n_execs = addrs.len();
        let t0 = Instant::now();
        let (mut bytes_out, mut bytes_in) = (0usize, 0usize);
        let mut recv_buf = Vec::new();
        let mut conns = Vec::with_capacity(n_execs);
        for (i, addr) in addrs.iter().enumerate() {
            let mut stream = TcpStream::connect(addr)
                .with_context(|| format!("connect to executor {i} at {addr}"))?;
            stream.set_nodelay(true).ok();
            stream.set_read_timeout(read_timeout()).ok();
            let mut hello = Vec::new();
            bytes::put_u32(&mut hello, wire::PROTO_MAGIC);
            bytes::put_u32(&mut hello, wire::PROTO_VERSION);
            bytes::put_u32(&mut hello, i as u32);
            bytes::put_u32(&mut hello, n_execs as u32);
            bytes_out += wire::write_frame(&mut stream, Tag::Hello, &hello)?;
            bytes_in += wire::expect_frame(&mut stream, &mut recv_buf, Tag::HelloAck)
                .with_context(|| format!("handshake with executor {i} at {addr}"))?;
            let mut r = ByteReader::new(&recv_buf);
            let magic = r.u32()?;
            let version = r.u32()?;
            if magic != wire::PROTO_MAGIC || version != wire::PROTO_VERSION {
                bail!(
                    "executor {i} at {addr} speaks protocol v{version} \
                     (driver v{}); rebuild the executor binary",
                    wire::PROTO_VERSION
                );
            }
            let threads = r.u32()? as usize;
            conns.push(ExecConn { stream, addr: addr.clone(), threads });
        }

        // stage: metadata to everyone, each block to its one owner
        for (i, conn) in conns.iter_mut().enumerate() {
            let mut body = Vec::new();
            part.encode_meta(&mut body);
            let owned: Vec<usize> =
                (0..part.grid.k()).filter(|cell| cell % n_execs == i).collect();
            bytes::put_u32(&mut body, owned.len() as u32);
            for &cell in &owned {
                bytes::put_usize(&mut body, cell);
                encode_block(&part.blocks[cell], &mut body);
            }
            bytes_out += wire::write_frame(&mut conn.stream, Tag::Stage, &body)
                .with_context(|| format!("stage blocks on executor {i} at {}", conn.addr))?;
            bytes_in += wire::expect_frame(&mut conn.stream, &mut recv_buf, Tag::StageAck)
                .with_context(|| format!("stage ack from executor {i} at {}", conn.addr))?;
        }

        let wire_log = vec![WireRecord {
            step: 0,
            op: "stage",
            wall_secs: t0.elapsed().as_secs_f64(),
            bytes_out,
            bytes_in,
            sim_secs: 0.0,
        }];
        Ok(DistCluster {
            sim: SimCluster::new(config),
            conns,
            wire_log,
            step_id: 0,
            send_buf: Vec::new(),
            recv_buf,
            durs: Vec::new(),
            seen: Vec::new(),
        })
    }

    /// Total executor worker threads (display only).
    pub fn executor_threads(&self) -> usize {
        self.conns.iter().map(|c| c.threads).sum()
    }

    pub fn n_executors(&self) -> usize {
        self.conns.len()
    }
}

impl ClusterBackend for DistCluster {
    fn label(&self) -> &'static str {
        "dist"
    }

    fn threads(&self) -> usize {
        self.executor_threads().max(1)
    }

    fn warm_up(&mut self) {
        // executors spawned their pools at staging time; nothing to do
    }

    fn prepare(&mut self, _staged: &StagedGrid<'_>) -> Result<()> {
        // per-worker scratch lives executor-side, sized when blocks land
        Ok(())
    }

    fn prepare_admm(&mut self, _staged: &StagedGrid<'_>) -> Result<()> {
        let t0 = Instant::now();
        // consume a step ordinal so wire records stay uniquely keyed by
        // `step` (staging alone owns 0); superstep records simply skip
        // this number
        self.step_id += 1;
        let (mut bytes_out, mut bytes_in) = (0usize, 0usize);
        for (i, conn) in self.conns.iter_mut().enumerate() {
            bytes_out += wire::write_frame(&mut conn.stream, Tag::PrepareAdmm, &[])?;
            bytes_in +=
                wire::expect_frame(&mut conn.stream, &mut self.recv_buf, Tag::PrepareAdmmAck)
                    .with_context(|| {
                        format!("admm factorization on executor {i} at {}", conn.addr)
                    })?;
        }
        self.wire_log.push(WireRecord {
            step: self.step_id as usize,
            op: "prepare-admm",
            wall_secs: t0.elapsed().as_secs_f64(),
            bytes_out,
            bytes_in,
            sim_secs: 0.0,
        });
        Ok(())
    }

    fn grid_exec(
        &mut self,
        staged: &StagedGrid<'_>,
        op: GridOp<'_>,
        out: &mut [f32],
        out2: &mut [f32],
    ) -> Result<()> {
        let part = staged.part;
        let n_tasks = op.n_tasks(part);
        if n_tasks == 0 {
            return Ok(());
        }
        debug_assert!(out.len() >= op.out_len(part));
        debug_assert!(out2.len() >= op.out2_len(part));
        let t0 = Instant::now();
        self.step_id += 1;
        let step_id = self.step_id;
        let n_execs = self.conns.len();

        // one encoding, N sends
        self.send_buf.clear();
        bytes::put_u64(&mut self.send_buf, step_id);
        ops::encode_op(&op, &mut self.send_buf);
        let (mut bytes_out, mut bytes_in) = (0usize, 0usize);
        for (i, conn) in self.conns.iter_mut().enumerate() {
            bytes_out += wire::write_frame(&mut conn.stream, Tag::Step, &self.send_buf)
                .with_context(|| {
                    format!("send superstep {step_id} to executor {i} at {}", conn.addr)
                })?;
        }

        // gather: every task's duration + result segment, exactly once
        self.durs.clear();
        self.durs.resize(n_tasks, 0.0);
        self.seen.clear();
        self.seen.resize(n_tasks, false);
        let mut first_err: Option<(usize, anyhow::Error)> = None;
        for (i, conn) in self.conns.iter_mut().enumerate() {
            let (tag, nread) = wire::read_frame(&mut conn.stream, &mut self.recv_buf)
                .with_context(|| {
                    format!(
                        "superstep {step_id} reply from executor {i} at {} \
                         (killed or wedged executor?)",
                        conn.addr
                    )
                })?;
            bytes_in += nread;
            match tag {
                Tag::StepResult => {}
                Tag::Fatal => {
                    let msg = ByteReader::new(&self.recv_buf).str().unwrap_or_default();
                    bail!("executor {i} at {} failed: {msg}", conn.addr);
                }
                other => bail!(
                    "executor {i} at {}: wanted StepResult, got {other:?}",
                    conn.addr
                ),
            }
            let mut r = ByteReader::new(&self.recv_buf);
            let sid = r.u64()?;
            if sid != step_id {
                bail!(
                    "executor {i} at {} answered superstep {sid}, expected {step_id}",
                    conn.addr
                );
            }
            let count = r.u32()? as usize;
            for _ in 0..count {
                let task = r.u32()? as usize;
                if task >= n_tasks {
                    bail!("executor {i}: task {task} out of range ({n_tasks} tasks)");
                }
                if self.seen[task] {
                    bail!("executor {i}: task {task} reported twice");
                }
                self.seen[task] = true;
                self.durs[task] = r.f64()?;
                let status = r.u8()?;
                if status == 0 {
                    let (s, l) = op.out_span(part, task);
                    read_segment(&mut r, &mut out[s..s + l], task, "out")?;
                    let (s2, l2) = op.out2_span(part, task);
                    read_segment(&mut r, &mut out2[s2..s2 + l2], task, "out2")?;
                } else {
                    let msg = r.str()?;
                    let err = anyhow::anyhow!("partition task {task}: {msg}");
                    if first_err.as_ref().map(|(t, _)| task < *t).unwrap_or(true) {
                        first_err = Some((task, err));
                    }
                }
            }
        }
        if let Some(missing) = self.seen.iter().position(|&s| !s) {
            bail!(
                "superstep {step_id}: no executor owned task {missing} \
                 ({n_execs} executors, {n_tasks} tasks)"
            );
        }

        // the simulated clock advances exactly like the sim backend's,
        // fed with the *measured* executor durations (or the Fixed cost)
        let sim_before = self.sim.clock.now();
        self.sim.charge_measured(&self.durs, op.tolerant());
        self.wire_log.push(WireRecord {
            step: step_id as usize,
            op: op.name(),
            wall_secs: t0.elapsed().as_secs_f64(),
            bytes_out,
            bytes_in,
            sim_secs: self.sim.clock.now() - sim_before,
        });
        match first_err {
            Some((_, e)) => Err(e),
            None => Ok(()),
        }
    }

    fn reduce_segments(
        &mut self,
        slab: &mut [f32],
        base: usize,
        stride: usize,
        count: usize,
        len: usize,
    ) {
        // results were already gathered to the driver; the combine (and
        // its comm charge) is bit-identical to the sim backend's
        self.sim.reduce_segments(slab, base, stride, count, len);
    }

    fn reduce_cost(&mut self, leaves: usize, bytes_per_leaf: usize) {
        self.sim.reduce_cost(leaves, bytes_per_leaf);
    }

    fn broadcast_cost(&mut self, bytes: usize, fanout: usize) {
        self.sim.broadcast_cost(bytes, fanout);
    }

    fn clock(&self) -> &SimClock {
        &self.sim.clock
    }

    fn host_secs(&self) -> f64 {
        self.sim.host_secs()
    }

    fn take_wire_log(&mut self) -> Vec<WireRecord> {
        std::mem::take(&mut self.wire_log)
    }

    fn shutdown(&mut self) -> Result<()> {
        // orderly release: executors return to their accept loop; errors
        // are ignored (the executor may already be gone, which is fine)
        for conn in &mut self.conns {
            if wire::write_frame(&mut conn.stream, Tag::Shutdown, &[]).is_ok() {
                let _ = wire::expect_frame(&mut conn.stream, &mut self.recv_buf, Tag::Bye);
            }
        }
        self.conns.clear();
        Ok(())
    }
}

/// Read one length-prefixed f32 array straight into a slab segment,
/// insisting the length matches the span exactly.
fn read_segment(
    r: &mut ByteReader<'_>,
    dst: &mut [f32],
    task: usize,
    what: &str,
) -> Result<()> {
    let n = r.u64()? as usize;
    if n != dst.len() {
        bail!(
            "task {task}: {what} segment length {n} != expected {}",
            dst.len()
        );
    }
    r.fill_f32s(dst)
}
