//! [`DistCluster`] — the driver-side transport: a [`ClusterBackend`]
//! whose supersteps execute on real executor processes over TCP.
//!
//! Per superstep the driver encodes the [`GridOp`] descriptor (iterates,
//! index streams — kilobytes, never the training data) and exchanges it
//! with the fleet:
//!
//! * **sliced scatter** (negotiated via [`wire::CAP_SLICED`]) — each
//!   executor's Step frame carries only the state ranges and per-task
//!   streams its owned tasks read ([`ops::encode_op_sliced`]); without
//!   the capability every executor receives the identical full payload.
//! * **pipelined, readiness-ordered fan-out** — all per-executor frames
//!   are written with nonblocking I/O before any reply is awaited, and
//!   replies are consumed in *arrival* order, so one slow executor never
//!   serializes the whole exchange.  The sim backend's
//!   lowest-task-index-wins error rule is order-independent, so arrival
//!   order changes nothing observable.
//! * **folded gather** (negotiated via [`wire::CAP_CONTIG_FOLD`], which
//!   also switches cell ownership to contiguous ranges) — executors
//!   pre-combine their locally-owned aligned subtrees of the
//!   segment-combine tree before replying; the driver validates each
//!   fold against [`GridOp::fold_group`] geometry, logs it as a
//!   [`FoldEntry`], and later skips exactly those pairs in
//!   [`SimCluster::reduce_segments_folded`] — same pairing order, same
//!   bits, fewer bytes and adds.
//!
//! Gathered segments land in the coordinator's output slab at the
//! position [`GridOp::out_span`] dictates, and combining reuses
//! [`tree_aggregate`](crate::cluster::comm::tree_aggregate)'s order
//! exactly: final weights are bit-identical to `--cluster sim` at the
//! same seed, in both wire modes (asserted by `tests/dist_parity.rs`).
//!
//! Accounting is double-entry: executors report *measured* per-task
//! seconds, which feed the same scenario/LPT simulated-clock charge as
//! the sim backend ([`SimCluster::charge_measured`]), while every
//! exchange also lands in a [`WireRecord`] — real wall seconds plus
//! per-executor scatter/gather byte splits — so `ddopt train --wire-out`
//! can put the cost model and the measured transport side by side.
//!
//! Failure semantics: per-task kernel errors reproduce the sim backend's
//! lowest-task-index-wins rule across executors (the superstep still
//! charges the clock); a misbehaving executor (protocol violation, fold
//! that fails validation) surfaces as a clean `Err` naming the executor
//! — the driver never hangs on a killed peer.
//!
//! **Fault recovery** (wire revision 3, negotiated via
//! [`wire::CAP_REJOIN`]): when a superstep *exchange* fails on an I/O
//! error — connection reset, EOF, exchange deadline — the driver tears
//! down every connection and rejoins the fleet: each executor is
//! re-dialed with capped exponential backoff (budget:
//! `DDOPT_DIST_REJOIN_TIMEOUT_SECS`, default 10s), sent a `Rejoin` frame
//! carrying the session token, and — if it lost its cached session (a
//! restarted process) — restaged from the Stage body saved at connect
//! time; a surviving executor acks `have_blocks` and skips the block
//! transfer.  ADMM factorizations are replayed when the session had
//! prepared them.  The failed superstep is then retried under the *same*
//! step id: every op is a pure function of driver-side state, so the
//! replay recomputes bit-identical segments and the run loses at most
//! one superstep per failure.  Reply *parse* errors stay fatal (retrying
//! a lying executor is not recovery), and without the negotiated
//! capability (a v2 peer, or `--dist-wire broadcast`) failures keep the
//! pre-v3 fail-fast behavior.  Recovery counters land in the superstep's
//! [`WireRecord`].
//!
//! **Elastic degraded mode** (wire revision 4, negotiated via
//! [`wire::CAP_ELASTIC`]): when an executor misses the rejoin budget
//! *entirely*, the driver degrades instead of dying.  Cell placement is
//! reified as an explicit [`CellMap`] table, re-dealt over the survivors
//! ([`CellMap::rebalanced`]), and re-negotiated with the fleet through
//! `CellMap` frames that also carry the orphaned blocks each survivor
//! must newly stage (encoded from the driver's partition, the same bytes
//! the original Stage frame shipped).  The interrupted superstep is then
//! replayed under the new placement: ops are pure functions of the op
//! descriptor and the block data, so *where* a task runs never changes
//! its bits — the run continues bitwise-identically on N−1 executors.
//! The degrade is symmetric: every superstep entry gives dead peers one
//! cheap (250ms) readmission attempt, and a returning executor is
//! restaged and the map rebalanced back toward the pure layout at that
//! superstep boundary.  `degraded_executors` in each [`WireRecord`]
//! tracks the fleet's health over time.
//!
//! **Speculative re-execution** (`--dist-spec`, negotiated via
//! [`wire::CAP_SPEC`]): the driver watches each gather; once a quantile
//! of the fleet has replied and the laggards have overstayed a multiple
//! of the slowest finisher's time, it dispatches `SpecStep` backup
//! copies of the lagging executors' task lists to idle finishers chosen
//! by per-executor, per-op-kind latency EWMAs.  First valid result wins:
//! a backup that beats its primary has its reply adopted wholesale and
//! the primary's eventual duplicate is drained and discarded (the
//! connection stays frame-aligned); a primary that finishes first makes
//! the backup's reply the duplicate.  Backups run on the block replicas
//! the `CellMap` negotiation pre-staged (each cell is mirrored on the
//! next alive slot), so speculation costs no block movement at dispatch
//! time.  `spec_launched`/`spec_won` land in the superstep's
//! [`WireRecord`].

use super::ops;
use super::wire::{self, Tag};
use crate::cluster::{
    CellMap, ClusterBackend, ClusterConfig, FoldAxis, FoldEntry, GridOp, Ownership,
    SimClock, SimCluster, WireMode,
};
use crate::data::{encode_block, Partitioned};
use crate::metrics::WireRecord;
use crate::obs::{self, Counter, Gauge, MetricsRegistry, Phase, TraceEvent, TraceLog};
use crate::runtime::StagedGrid;
use crate::util::bytes::{self, ByteReader};
use anyhow::{bail, Context, Result};
use std::collections::HashMap;
use std::io::{Read, Write};
use std::net::{Shutdown, TcpStream, ToSocketAddrs};
use std::time::{Duration, Instant};

/// Default per-read socket timeout — generous for loopback supersteps,
/// small enough that a wedged executor fails the run instead of hanging
/// CI.  Workloads whose single superstep legitimately computes longer
/// (big datasets, few executor threads) raise it with
/// `DDOPT_DIST_READ_TIMEOUT_SECS` (`0` disables the timeout entirely).
/// The pipelined exchange applies the same budget as its whole-superstep
/// deadline.
const DEFAULT_READ_TIMEOUT_SECS: u64 = 60;

/// Read a whole-seconds knob from the environment.  An *absent* variable
/// means the default; a *present but unparseable* one is a hard error —
/// silently running with the default after the operator set
/// `DDOPT_DIST_READ_TIMEOUT_SECS=1O` (a typo'd `10`) cost real debugging
/// time, so the misconfiguration now fails the run at startup, naming
/// the variable and the value.
fn env_secs(var: &'static str, default: u64) -> Result<u64> {
    match std::env::var(var) {
        Err(std::env::VarError::NotPresent) => Ok(default),
        Err(std::env::VarError::NotUnicode(v)) => {
            bail!("invalid {var}={v:?}: not valid unicode (want whole seconds, 0 to disable)")
        }
        Ok(v) => v.trim().parse::<u64>().map_err(|_| {
            anyhow::anyhow!(
                "invalid {var}={v:?}: want whole seconds (0 to disable)"
            )
        }),
    }
}

fn read_timeout() -> Result<Option<Duration>> {
    let secs = env_secs("DDOPT_DIST_READ_TIMEOUT_SECS", DEFAULT_READ_TIMEOUT_SECS)?;
    Ok((secs > 0).then(|| Duration::from_secs(secs)))
}

/// Total budget for rejoining the fleet after an exchange failure —
/// reconnect attempts back off exponentially (50ms doubling, capped at
/// 1s) until an executor answers or this budget runs out.  `0` disables
/// recovery even when the capability was negotiated.
const DEFAULT_REJOIN_TIMEOUT_SECS: u64 = 10;

fn rejoin_timeout() -> Result<Option<Duration>> {
    let secs = env_secs("DDOPT_DIST_REJOIN_TIMEOUT_SECS", DEFAULT_REJOIN_TIMEOUT_SECS)?;
    Ok((secs > 0).then(|| Duration::from_secs(secs)))
}

/// Superstep retry ceiling per `grid_exec` call: recovery guarantees "at
/// most one superstep lost per failure", and repeated failures of the
/// *same* superstep get this many chances before the run gives up.
const MAX_STEP_RETRIES: u32 = 2;

/// Per-superstep readmission budget for a degraded peer: one cheap
/// bounded attempt, so a peer that is still down costs milliseconds per
/// superstep, not a rejoin budget.
const READMIT_ATTEMPT: Duration = Duration::from_millis(250);

/// Floor on the speculation trigger: never second-guess a laggard that
/// has been outstanding for less than this many seconds (loopback noise
/// territory).
const SPEC_MIN_STALL_SECS: f64 = 0.050;

/// The driver's fault-tolerance counters, unified in one
/// [`MetricsRegistry`] — the run totals behind the per-step values in
/// each [`WireRecord`].  The train summary and `exp perf` read them
/// through [`ClusterBackend::metrics_snapshot`], so every consumer sees
/// the same source.
struct FtMetrics {
    registry: MetricsRegistry,
    retries: Counter,
    rejoins: Counter,
    degraded: Gauge,
    spec_launched: Counter,
    spec_won: Counter,
}

impl FtMetrics {
    fn new() -> FtMetrics {
        let registry = MetricsRegistry::new();
        let retries = registry.counter(
            "ddopt_step_retries_total",
            "Supersteps retried after a recovered exchange failure",
        );
        let rejoins = registry.counter(
            "ddopt_rejoins_total",
            "Rejoin handshakes performed across all recoveries",
        );
        let degraded = registry.gauge(
            "ddopt_degraded_executors",
            "Executors currently degraded (cells re-dealt to survivors)",
        );
        let spec_launched = registry.counter(
            "ddopt_spec_launched_total",
            "Speculative backup dispatches across the run",
        );
        let spec_won = registry.counter(
            "ddopt_spec_won_total",
            "Speculative backup results adopted across the run",
        );
        FtMetrics { registry, retries, rejoins, degraded, spec_launched, spec_won }
    }
}

struct ExecConn {
    stream: TcpStream,
    addr: String,
    threads: usize,
    /// False once the peer missed its rejoin budget and its cells were
    /// re-dealt to the survivors; flips back on readmission.  Dead
    /// connections stay in the vec (slot indices are wire-visible) but
    /// are never written to or read from.
    alive: bool,
}

/// The distributed cluster backend (see module docs).
pub struct DistCluster {
    /// Simulated clock + collective cost model + in-place combine — the
    /// exact code the sim backend runs, fed with measured durations.
    sim: SimCluster,
    conns: Vec<ExecConn>,
    /// Effective capability mask: offered by the driver's [`WireMode`],
    /// ANDed over every executor's ack.
    caps: u32,
    /// Cell→executor layout the whole session runs under (the pure,
    /// functional form; `cell_map` overrides it while degraded).
    ownership: Ownership,
    wire_log: Vec<WireRecord>,
    step_id: u64,
    /// Shared full-payload Step body (broadcast mode).
    send_buf: Vec<u8>,
    /// Per-executor sliced Step bodies.
    send_bufs: Vec<Vec<u8>>,
    /// Per-executor reply bodies (pipelined gather).
    recv_bufs: Vec<Vec<u8>>,
    /// Control-plane reply scratch (handshake, acks, shutdown).
    recv_buf: Vec<u8>,
    /// Per-executor owned task lists of the superstep in flight.
    owned_lists: Vec<Vec<usize>>,
    /// Per-task measured durations of the superstep in flight.
    durs: Vec<f64>,
    seen: Vec<bool>,
    /// Tasks absorbed by a validated executor-side fold this superstep.
    folded_away: Vec<bool>,
    /// Validated folds of the last superstep, consumed by
    /// [`ClusterBackend::reduce_segments`].
    fold_log: Vec<FoldEntry>,
    /// Executor addresses in fleet order (rejoin re-dials these).
    addrs: Vec<String>,
    /// Capability mask the driver offered in `Hello` (re-offered on
    /// rejoin; the fleet caps stay the negotiated AND).
    offered: u32,
    /// Session token: lets an executor prove its cached blocks belong to
    /// *this* run when the driver rejoins after a failure.
    token: u64,
    /// The exact Stage body shipped to each executor at connect time,
    /// kept so a restarted executor can be restaged without the driver
    /// re-deriving anything.
    stage_bodies: Vec<Vec<u8>>,
    /// Whether `prepare_admm` ran this session (replayed on rejoin).
    admm_prepared: bool,
    /// Run-total fault-tolerance counters (one registry, surfaced via
    /// [`ClusterBackend::metrics_snapshot`]; per-step deltas stay on the
    /// [`WireRecord`]).
    metrics: FtMetrics,
    /// Explicit placement while it diverges from the pure layout
    /// (`None` = pure: [`GridOp::owner`] is authoritative).
    cell_map: Option<CellMap>,
    /// Per-executor set of cells known staged on that peer (grows as
    /// `CellMap` frames ship blocks; reset to the pure-owned set when a
    /// restarted peer is restaged).
    staged_cells: Vec<Vec<bool>>,
    /// Whether the fleet has ever negotiated a `CellMap` (once true,
    /// every recovery re-syncs the layout, even back to pure).
    map_active: bool,
    /// Speculative re-execution enabled (`--dist-spec` plus the
    /// capability superset it needs).
    spec: bool,
    /// Gather-completion quantile that arms the speculation trigger.
    spec_quantile: f64,
    /// Maximum backup copies in flight per lagging executor.
    spec_copies: usize,
    /// Per-(executor, op-kind) gather-latency EWMA, used to pick the
    /// historically fastest idle peer as the backup.
    spec_ewma: HashMap<(usize, &'static str), f64>,
    /// Fleet-wide span log while tracing is on (`None` = off: the hot
    /// path pays one branch per superstep).  Driver spans land at slot
    /// 0; executor span tables are merged in with their slot stamped
    /// from connection identity.
    trace: Option<TraceLog>,
    /// Per-executor clock-offset estimate in ns (`exec_tick − driver
    /// RTT midpoint` from the handshake): `driver_ns = exec_ns −
    /// offset`.  Zero for pre-v5 executors that send no tick.
    clock_offsets: Vec<i64>,
    /// Connect-time bounds of the staging phase, replayed into the
    /// trace log when tracing is enabled after connect.
    stage_t0_ns: u64,
    stage_t1_ns: u64,
}

impl DistCluster {
    /// Connect to the executors, run the versioned capability handshake,
    /// and ship each its owned grid blocks under the negotiated
    /// [`Ownership`] layout — the same keying [`GridOp::owner`] uses per
    /// superstep.
    pub fn connect(
        config: ClusterConfig,
        addrs: &[String],
        part: &Partitioned,
    ) -> Result<DistCluster> {
        if addrs.is_empty() {
            bail!("--cluster dist wants at least one executor address");
        }
        // validate both timeout knobs eagerly: a typo'd env var must
        // fail the run at startup, not mid-recovery
        let read_to = read_timeout()?;
        rejoin_timeout()?;
        let n_execs = addrs.len();
        let offered = match config.wire {
            WireMode::Sliced => wire::CAPS_SUPPORTED,
            WireMode::Broadcast => 0,
        };
        let t0 = Instant::now();
        let stage_t0_ns = obs::now_ns();
        let mut scatter = vec![0usize; n_execs];
        let mut gather = vec![0usize; n_execs];
        let mut recv_buf = Vec::new();
        let mut conns = Vec::with_capacity(n_execs);
        let mut clock_offsets = Vec::with_capacity(n_execs);
        let mut caps = offered;
        // Session token: unique enough that an executor recycled by a
        // different run cannot satisfy this run's Rejoin with stale
        // blocks.  A v2 executor ignores the trailing token in Hello.
        let token = session_token(addrs);
        for (i, addr) in addrs.iter().enumerate() {
            let mut stream = TcpStream::connect(addr)
                .with_context(|| format!("connect to executor {i} at {addr}"))?;
            stream.set_nodelay(true).ok();
            stream
                .set_read_timeout(read_to)
                .with_context(|| format!("set read timeout on executor {i} at {addr}"))?;
            let mut hello = Vec::new();
            bytes::put_u32(&mut hello, wire::PROTO_MAGIC);
            bytes::put_u32(&mut hello, wire::PROTO_VERSION);
            bytes::put_u32(&mut hello, i as u32);
            bytes::put_u32(&mut hello, n_execs as u32);
            bytes::put_u32(&mut hello, offered);
            bytes::put_u64(&mut hello, token);
            let t_send = obs::now_ns();
            scatter[i] += wire::write_frame(&mut stream, Tag::Hello, &hello)?;
            gather[i] += wire::expect_frame(&mut stream, &mut recv_buf, Tag::HelloAck)
                .with_context(|| format!("handshake with executor {i} at {addr}"))?;
            let t_recv = obs::now_ns();
            let mut r = ByteReader::new(&recv_buf);
            let magic = r.u32()?;
            let version = r.u32()?;
            if magic != wire::PROTO_MAGIC || version != wire::PROTO_VERSION {
                bail!(
                    "executor {i} at {addr} speaks protocol v{version} \
                     (driver v{}); rebuild the executor binary",
                    wire::PROTO_VERSION
                );
            }
            let threads = r.u32()? as usize;
            let acked = r.u32()?;
            if acked & !offered != 0 {
                bail!(
                    "executor {i} at {addr} acked capabilities {acked:#x} \
                     it was never offered ({offered:#x})"
                );
            }
            // the fleet runs at the AND of every ack: one stale executor
            // downgrades the session instead of breaking it
            caps &= acked;
            // wire revision 5: trailing monotonic executor tick.  The
            // offset estimate is exec_tick minus the RTT midpoint of the
            // handshake round trip; a pre-v5 executor sends no tail and
            // gets offset 0 (its spans never arrive either).
            let offset = if r.remaining() >= 8 {
                r.u64()? as i64 - ((t_send + t_recv) / 2) as i64
            } else {
                0
            };
            clock_offsets.push(offset);
            conns.push(ExecConn { stream, addr: addr.clone(), threads, alive: true });
        }
        let ownership = if caps & wire::CAP_CONTIG_FOLD != 0 {
            Ownership::Contiguous
        } else {
            Ownership::RoundRobin
        };

        // stage: metadata to everyone, each block to its one owner —
        // pipelined (all frames written before any ack is awaited).  The
        // bodies are kept verbatim: a rejoin after an executor restart
        // re-ships exactly these bytes, no re-derivation.
        let mut stage_bodies: Vec<Vec<u8>> = Vec::with_capacity(n_execs);
        let mut staged_cells: Vec<Vec<bool>> = Vec::with_capacity(n_execs);
        for (i, conn) in conns.iter_mut().enumerate() {
            let mut body = Vec::new();
            bytes::put_u8(&mut body, ownership.to_u8());
            part.encode_meta(&mut body);
            let owned: Vec<usize> = (0..part.grid.k())
                .filter(|&cell| ownership.owner(cell, part.grid.k(), n_execs) == i)
                .collect();
            bytes::put_u32(&mut body, owned.len() as u32);
            let mut staged = vec![false; part.grid.k()];
            for &cell in &owned {
                bytes::put_usize(&mut body, cell);
                encode_block(&part.blocks[cell], &mut body);
                staged[cell] = true;
            }
            scatter[i] += wire::write_frame(&mut conn.stream, Tag::Stage, &body)
                .with_context(|| format!("stage blocks on executor {i} at {}", conn.addr))?;
            stage_bodies.push(body);
            staged_cells.push(staged);
        }
        for (i, conn) in conns.iter_mut().enumerate() {
            gather[i] += wire::expect_frame(&mut conn.stream, &mut recv_buf, Tag::StageAck)
                .with_context(|| format!("stage ack from executor {i} at {}", conn.addr))?;
        }

        // speculation wants the whole v4 surface: sliced per-executor
        // payloads (a backup copy is a sliced frame), contiguous cell
        // ownership, CellMap replica staging, and the SpecStep frame
        let spec_caps = wire::CAP_SLICED
            | wire::CAP_CONTIG_FOLD
            | wire::CAP_ELASTIC
            | wire::CAP_SPEC;
        let spec = config.dist_spec && n_execs > 1 && caps & spec_caps == spec_caps;
        let spec_quantile = config.scenario.spec_quantile;
        let spec_copies = config.scenario.spec_copies;

        let wire_log = vec![WireRecord {
            step: 0,
            op: "stage",
            wall_secs: t0.elapsed().as_secs_f64(),
            bytes_out: scatter.iter().sum(),
            bytes_in: gather.iter().sum(),
            sim_secs: 0.0,
            scatter,
            gather,
            retries: 0,
            rejoins: 0,
            degraded_executors: 0,
            spec_launched: 0,
            spec_won: 0,
        }];
        let mut cluster = DistCluster {
            sim: SimCluster::new(config),
            conns,
            caps,
            ownership,
            wire_log,
            step_id: 0,
            send_buf: Vec::new(),
            send_bufs: vec![Vec::new(); n_execs],
            recv_bufs: vec![Vec::new(); n_execs],
            recv_buf,
            owned_lists: vec![Vec::new(); n_execs],
            durs: Vec::new(),
            seen: Vec::new(),
            folded_away: Vec::new(),
            fold_log: Vec::new(),
            addrs: addrs.to_vec(),
            offered,
            token,
            stage_bodies,
            admm_prepared: false,
            metrics: FtMetrics::new(),
            cell_map: None,
            staged_cells,
            map_active: false,
            spec,
            spec_quantile,
            spec_copies,
            spec_ewma: HashMap::new(),
            trace: None,
            clock_offsets,
            stage_t0_ns,
            stage_t1_ns: obs::now_ns(),
        };
        if cluster.spec {
            // pre-stage the block replicas speculation dispatches
            // against (each cell mirrored on the next alive slot): paid
            // once at connect time, not on the critical gather path
            cluster.sync_layout(part)?;
        }
        Ok(cluster)
    }

    /// Total executor worker threads (display only).
    pub fn executor_threads(&self) -> usize {
        self.conns.iter().map(|c| c.threads).sum()
    }

    pub fn n_executors(&self) -> usize {
        self.conns.len()
    }

    /// The negotiated capability mask (AND over every executor's ack).
    pub fn capabilities(&self) -> u32 {
        self.caps
    }

    /// The session's cell→executor layout.
    pub fn ownership(&self) -> Ownership {
        self.ownership
    }

    /// Executors currently running degraded (cells re-dealt to the
    /// survivors).
    pub fn degraded_executors(&self) -> usize {
        self.conns.iter().filter(|c| !c.alive).count()
    }

    /// Whether the fleet can degrade onto survivors at all: the elastic
    /// capability was negotiated and the session runs the contiguous
    /// cell layout a [`CellMap`] reifies.
    fn elastic(&self) -> bool {
        self.caps & wire::CAP_ELASTIC != 0 && self.ownership == Ownership::Contiguous
    }

    /// The pure-owned cell set of executor `i` — what a freshly restaged
    /// peer holds.
    fn pure_staged(&self, i: usize, k: usize) -> Vec<bool> {
        let n = self.conns.len();
        (0..k).map(|cell| self.ownership.owner(cell, k, n) == i).collect()
    }

    /// Re-negotiate the cell placement with the live fleet: compute the
    /// rebalanced [`CellMap`] for the current dead set, ship it to every
    /// live executor in a `CellMap` frame together with whichever newly
    /// required blocks that executor has not staged yet (orphans of dead
    /// peers, or speculation replicas), and await the acks — pipelined,
    /// like staging.  Layout traffic is control-plane: it is not charged
    /// to any superstep's byte accounting.
    fn sync_layout(&mut self, part: &Partitioned) -> Result<()> {
        if !self.elastic() {
            return Ok(());
        }
        let n = self.conns.len();
        let k = part.grid.k();
        let dead: Vec<bool> = self.conns.iter().map(|c| !c.alive).collect();
        let map = CellMap::rebalanced(self.ownership, k, n, &dead);
        // required[i][cell]: what executor i must hold under the new map
        // — its mapped-owned cells, plus (with speculation) a replica of
        // each cell on the next alive slot so a backup copy can run
        // without block movement at dispatch time
        let mut required = vec![vec![false; k]; n];
        for cell in 0..k {
            let owner = map.slot(cell);
            required[owner][cell] = true;
            if self.spec {
                if let Some(rep) = next_alive(&dead, owner) {
                    required[rep][cell] = true;
                }
            }
        }
        let mut bodies: Vec<Option<Vec<u8>>> = Vec::with_capacity(n);
        for i in 0..n {
            if dead[i] {
                bodies.push(None);
                continue;
            }
            let mut body = Vec::new();
            bytes::put_u32(&mut body, wire::PROTO_MAGIC);
            bytes::put_u64(&mut body, self.step_id);
            bytes::put_u32(&mut body, n as u32);
            map.encode(&mut body);
            let missing: Vec<usize> = (0..k)
                .filter(|&cell| required[i][cell] && !self.staged_cells[i][cell])
                .collect();
            bytes::put_u32(&mut body, missing.len() as u32);
            for &cell in &missing {
                bytes::put_usize(&mut body, cell);
                encode_block(&part.blocks[cell], &mut body);
            }
            bodies.push(Some(body));
        }
        for (i, body) in bodies.iter().enumerate() {
            if let Some(body) = body {
                let conn = &mut self.conns[i];
                wire::write_frame(&mut conn.stream, Tag::CellMap, body).with_context(|| {
                    format!("ship cell map to executor {i} at {}", conn.addr)
                })?;
            }
        }
        for (i, conn) in self.conns.iter_mut().enumerate() {
            if bodies[i].is_none() {
                continue;
            }
            wire::expect_frame(&mut conn.stream, &mut self.recv_buf, Tag::CellMapAck)
                .with_context(|| {
                    format!("cell map ack from executor {i} at {}", conn.addr)
                })?;
        }
        // staged sets grow only: a survivor keeps blocks it staged under
        // older maps (harmless — it computes only its mapped tasks)
        for i in 0..n {
            if dead[i] {
                continue;
            }
            for cell in 0..k {
                if required[i][cell] {
                    self.staged_cells[i][cell] = true;
                }
            }
        }
        self.map_active = true;
        self.cell_map = if map.is_pure(self.ownership, n) { None } else { Some(map) };
        Ok(())
    }

    /// Replay ADMM factorizations on the live fleet (pipelined like
    /// `prepare_admm`) — called after any recovery or layout change once
    /// the session has prepared them, since a restaged or re-mapped
    /// executor factors its *current* cells.
    fn replay_admm(&mut self) -> Result<()> {
        for (i, conn) in self.conns.iter_mut().enumerate() {
            if !conn.alive {
                continue;
            }
            wire::write_frame(&mut conn.stream, Tag::PrepareAdmm, &[]).with_context(|| {
                format!("replay admm factorization on executor {i} at {}", conn.addr)
            })?;
        }
        for (i, conn) in self.conns.iter_mut().enumerate() {
            if !conn.alive {
                continue;
            }
            wire::expect_frame(&mut conn.stream, &mut self.recv_buf, Tag::PrepareAdmmAck)
                .with_context(|| {
                    format!("replay admm factorization on executor {i} at {}", conn.addr)
                })?;
        }
        Ok(())
    }

    /// Give every degraded peer one cheap, bounded readmission attempt
    /// (250ms each, errors swallowed — the peer is probably still down).
    /// Any admission re-syncs the layout back toward the pure map and
    /// replays ADMM factorizations.  Returns completed handshakes.
    fn try_readmit(&mut self, part: &Partitioned) -> Result<u64> {
        let n_execs = self.conns.len();
        let mut admitted = 0u64;
        for i in 0..n_execs {
            if self.conns[i].alive {
                continue;
            }
            match rejoin_one(
                &self.addrs[i],
                i,
                n_execs,
                self.token,
                self.offered,
                self.caps,
                &self.stage_bodies[i],
                self.step_id,
                &mut self.recv_buf,
                Some(READMIT_ATTEMPT),
            ) {
                Ok((conn, restaged, offset)) => {
                    if restaged {
                        self.staged_cells[i] = self.pure_staged(i, part.grid.k());
                    }
                    self.conns[i] = conn;
                    self.clock_offsets[i] = offset;
                    admitted += 1;
                }
                Err(_) => {} // still down; stay degraded, try next superstep
            }
        }
        if admitted > 0 {
            self.sync_layout(part)?;
            if self.admm_prepared {
                self.replay_admm()?;
            }
        }
        Ok(admitted)
    }

    /// Tear down and rebuild the executor connections after a failed
    /// exchange.  Slots are swept round-robin (one bounded attempt per
    /// slot per sweep, capped backoff between sweeps) so a single dead
    /// peer cannot monopolize the `DDOPT_DIST_REJOIN_TIMEOUT_SECS`
    /// budget while its neighbors wait to rejoin.  A peer that misses
    /// the budget is left degraded — its cells re-dealt to the survivors
    /// via [`DistCluster::sync_layout`] — provided the elastic
    /// capability was negotiated and at least one peer survives;
    /// otherwise the recovery fails like pre-v4 code did.  Returns
    /// completed handshakes.
    fn recover_fleet(&mut self, part: &Partitioned, step_id: u64) -> Result<u64> {
        let budget = rejoin_timeout()?.ok_or_else(|| {
            anyhow::anyhow!("rejoin disabled (DDOPT_DIST_REJOIN_TIMEOUT_SECS=0)")
        })?;
        let deadline = Instant::now() + budget;
        let n_execs = self.conns.len();
        // drop every old connection first: executors notice the hangup
        // and return to their accept loop, keeping the cached session
        for conn in self.conns.iter_mut() {
            conn.alive = false;
            let _ = conn.stream.shutdown(Shutdown::Both);
        }
        let mut joined: Vec<Option<(ExecConn, bool, i64)>> =
            (0..n_execs).map(|_| None).collect();
        let mut handshakes = 0u64;
        let mut delay = Duration::from_millis(50);
        let mut last_err: Option<anyhow::Error> = None;
        loop {
            for i in 0..n_execs {
                if joined[i].is_some() {
                    continue;
                }
                let remaining = deadline.saturating_duration_since(Instant::now());
                if remaining.is_zero() {
                    break;
                }
                // cap each attempt so one unreachable peer cannot eat
                // the whole budget inside a single connect or read
                let limit = remaining.min(Duration::from_secs(1));
                match rejoin_one(
                    &self.addrs[i],
                    i,
                    n_execs,
                    self.token,
                    self.offered,
                    self.caps,
                    &self.stage_bodies[i],
                    step_id,
                    &mut self.recv_buf,
                    Some(limit),
                ) {
                    Ok(c) => {
                        handshakes += 1;
                        joined[i] = Some(c);
                    }
                    Err(e) => last_err = Some(e),
                }
            }
            if joined.iter().all(|j| j.is_some()) || Instant::now() >= deadline {
                break;
            }
            let nap = delay.min(deadline.saturating_duration_since(Instant::now()));
            if !nap.is_zero() {
                std::thread::sleep(nap);
            }
            delay = (delay * 2).min(Duration::from_secs(1));
        }
        let missing: Vec<usize> = (0..n_execs).filter(|&i| joined[i].is_none()).collect();
        if !missing.is_empty() {
            if !self.elastic() {
                let (i, addr) = (missing[0], &self.addrs[missing[0]]);
                let base = last_err.unwrap_or_else(|| anyhow::anyhow!("no response"));
                return Err(base).context(format!(
                    "rejoin executor {i} at {addr} within {budget:?} \
                     (no elastic capability to degrade onto survivors; \
                     raise DDOPT_DIST_REJOIN_TIMEOUT_SECS?)"
                ));
            }
            if missing.len() == n_execs {
                let base = last_err.unwrap_or_else(|| anyhow::anyhow!("no response"));
                return Err(base).context(format!(
                    "no executor rejoined within {budget:?} \
                     (raise DDOPT_DIST_REJOIN_TIMEOUT_SECS?)"
                ));
            }
        }
        for (i, j) in joined.into_iter().enumerate() {
            if let Some((conn, restaged, offset)) = j {
                if restaged {
                    // a restarted process was restaged from the saved
                    // Stage body: it holds exactly its pure-owned cells
                    self.staged_cells[i] = self.pure_staged(i, part.grid.k());
                }
                self.conns[i] = conn;
                self.clock_offsets[i] = offset;
            }
        }
        // degraded (someone missing) or previously re-mapped: the fleet
        // needs the authoritative placement before the replay
        if !missing.is_empty() || self.map_active {
            self.sync_layout(part)?;
        }
        if self.admm_prepared {
            self.replay_admm()?;
        }
        Ok(handshakes)
    }
}

/// First alive slot after `from` in cyclic order (the speculation
/// replica holder); `None` when `from` is the only survivor.
fn next_alive(dead: &[bool], from: usize) -> Option<usize> {
    let n = dead.len();
    (1..n)
        .map(|d| (from + d) % n)
        .find(|&j| !dead.get(j).copied().unwrap_or(true))
}

impl ClusterBackend for DistCluster {
    fn label(&self) -> &'static str {
        "dist"
    }

    fn threads(&self) -> usize {
        self.executor_threads().max(1)
    }

    fn warm_up(&mut self) {
        // executors spawned their pools at staging time; nothing to do
    }

    fn prepare(&mut self, _staged: &StagedGrid<'_>) -> Result<()> {
        // per-worker scratch lives executor-side, sized when blocks land
        Ok(())
    }

    fn prepare_admm(&mut self, _staged: &StagedGrid<'_>) -> Result<()> {
        let t0 = Instant::now();
        let t0_ns = if self.trace.is_some() { obs::now_ns() } else { 0 };
        // consume a step ordinal so wire records stay uniquely keyed by
        // `step` (staging alone owns 0); superstep records simply skip
        // this number
        self.step_id += 1;
        let n = self.conns.len();
        let mut scatter = vec![0usize; n];
        let mut gather = vec![0usize; n];
        // pipelined: every request is on the wire before the first —
        // possibly expensive — factorization is awaited, so the fleet
        // factors in parallel instead of N serialized round-trips
        for (i, conn) in self.conns.iter_mut().enumerate() {
            if !conn.alive {
                continue;
            }
            scatter[i] += wire::write_frame(&mut conn.stream, Tag::PrepareAdmm, &[])
                .with_context(|| {
                    format!("request admm factorization on executor {i} at {}", conn.addr)
                })?;
        }
        for (i, conn) in self.conns.iter_mut().enumerate() {
            if !conn.alive {
                continue;
            }
            gather[i] +=
                wire::expect_frame(&mut conn.stream, &mut self.recv_buf, Tag::PrepareAdmmAck)
                    .with_context(|| {
                        format!("admm factorization on executor {i} at {}", conn.addr)
                    })?;
        }
        self.admm_prepared = true;
        if let Some(log) = self.trace.as_mut() {
            log.span(
                "prepare-admm", Phase::Stage, self.step_id as u32, 0,
                0, 0, t0_ns, obs::now_ns(),
            );
        }
        self.wire_log.push(WireRecord {
            step: self.step_id as usize,
            op: "prepare-admm",
            wall_secs: t0.elapsed().as_secs_f64(),
            bytes_out: scatter.iter().sum(),
            bytes_in: gather.iter().sum(),
            sim_secs: 0.0,
            scatter,
            gather,
            retries: 0,
            rejoins: 0,
            degraded_executors: self.degraded_executors(),
            spec_launched: 0,
            spec_won: 0,
        });
        Ok(())
    }

    fn grid_exec(
        &mut self,
        staged: &StagedGrid<'_>,
        op: GridOp<'_>,
        out: &mut [f32],
        out2: &mut [f32],
    ) -> Result<()> {
        let part = staged.part;
        let n_tasks = op.n_tasks(part);
        self.fold_log.clear();
        if n_tasks == 0 {
            return Ok(());
        }
        debug_assert!(out.len() >= op.out_len(part));
        debug_assert!(out2.len() >= op.out2_len(part));
        let t0 = Instant::now();
        self.step_id += 1;
        let step_id = self.step_id;
        let n_execs = self.conns.len();
        let sliced = self.caps & wire::CAP_SLICED != 0;
        let fold = self.caps & wire::CAP_CONTIG_FOLD != 0 && op.fold_axis() != FoldAxis::None;
        // ask executors for span tables only when the driver is tracing
        // AND the whole fleet acked the capability; driver-side spans
        // alone still work against a pre-v5 fleet
        let trace_req = self.trace.is_some() && self.caps & wire::CAP_TRACE != 0;
        let flags = (if sliced { wire::STEP_FLAG_SLICED } else { 0 })
            | (if fold { wire::STEP_FLAG_FOLD } else { 0 })
            | (if trace_req { wire::STEP_FLAG_TRACE } else { 0 });

        let mut step_retries = 0u64;
        let mut step_rejoins = 0u64;
        let mut step_spec_launched = 0usize;
        let mut step_spec_won = 0usize;

        // elastic readmission: a degraded peer gets one cheap attempt at
        // each superstep boundary; success rebalances the map back
        if self.conns.iter().any(|c| !c.alive) {
            match self.try_readmit(part) {
                Ok(got) => step_rejoins += got,
                // a readmission that half-applied (say, a survivor died
                // during the layout sync) leaves the fleet unusable:
                // fall back to a full recovery, which rebuilds every
                // connection and re-syncs the layout from scratch
                Err(e) => {
                    if self.caps & wire::CAP_REJOIN == 0
                        || !matches!(rejoin_timeout(), Ok(Some(_)))
                    {
                        return Err(e);
                    }
                    let rt0 = obs::now_ns();
                    let got = self
                        .recover_fleet(part, step_id)
                        .map_err(|re| e.context(format!("fleet rejoin also failed: {re:#}")))?;
                    step_rejoins += got;
                    if let Some(log) = self.trace.as_mut() {
                        log.span(
                            "recover", Phase::Recover, step_id as u32, 0,
                            0, 0, rt0, obs::now_ns(),
                        );
                    }
                }
            }
        }

        // pipelined scatter + readiness-ordered gather, with fault
        // recovery: an I/O failure (dead executor, exchange deadline)
        // rejoins the fleet — degrading onto the survivors if a peer
        // misses the budget — and replays the superstep under the same
        // step id: the op is a pure function of driver-side state, so
        // the retry recomputes bit-identical segments.  Reply *parse*
        // errors below stay fatal: retrying a lying executor is not
        // recovery.  Owned lists and bodies are recomputed per attempt
        // because a recovery can rewrite the cell map.
        let mut exchange = loop {
            // per-executor owned task lists (ascending by construction):
            // the explicit map while degraded, the pure function otherwise
            for list in self.owned_lists.iter_mut() {
                list.clear();
            }
            for task in 0..n_tasks {
                let owner = match &self.cell_map {
                    Some(m) => m.slot(op.cell(part, task)),
                    None => op.owner(part, task, n_execs, self.ownership),
                };
                self.owned_lists[owner].push(task);
            }

            // encode: one shared body (broadcast) or one per executor
            if sliced {
                for (e, buf) in self.send_bufs.iter_mut().enumerate() {
                    buf.clear();
                    if !self.conns[e].alive {
                        continue;
                    }
                    bytes::put_u64(buf, step_id);
                    bytes::put_u8(buf, flags);
                    ops::encode_op_sliced(&op, part, &self.owned_lists[e], buf);
                }
            } else {
                self.send_buf.clear();
                bytes::put_u64(&mut self.send_buf, step_id);
                bytes::put_u8(&mut self.send_buf, flags);
                ops::encode_op(&op, &mut self.send_buf);
            }

            // the block scopes every borrow the exchange needs, so the
            // recovery path below can take `&mut self` again
            let attempt = {
                let bodies: Vec<&[u8]> = if sliced {
                    self.send_bufs.iter().map(|b| b.as_slice()).collect()
                } else {
                    vec![self.send_buf.as_slice(); n_execs]
                };
                let mut spec_ctx = if self.spec {
                    Some(SpecCtx {
                        op: &op,
                        part,
                        owned: &self.owned_lists,
                        staged: &self.staged_cells,
                        ewma: &mut self.spec_ewma,
                        quantile: self.spec_quantile,
                        copies: self.spec_copies,
                    })
                } else {
                    None
                };
                pipelined_exchange(
                    &mut self.conns,
                    &bodies,
                    &mut self.recv_bufs,
                    step_id,
                    spec_ctx.as_mut(),
                )
            };
            match attempt {
                Ok(ex) => break ex,
                Err(e) => {
                    let recoverable = self.caps & wire::CAP_REJOIN != 0
                        && step_retries < MAX_STEP_RETRIES as u64
                        && matches!(rejoin_timeout(), Ok(Some(_)));
                    if !recoverable {
                        return Err(e);
                    }
                    let rt0 = obs::now_ns();
                    let got = self
                        .recover_fleet(part, step_id)
                        .map_err(|re| e.context(format!("fleet rejoin also failed: {re:#}")))?;
                    step_retries += 1;
                    step_rejoins += got;
                    if let Some(log) = self.trace.as_mut() {
                        log.span(
                            "recover", Phase::Recover, step_id as u32, 0,
                            0, 0, rt0, obs::now_ns(),
                        );
                    }
                }
            }
        };
        self.metrics.retries.add(step_retries);
        self.metrics.rejoins.add(step_rejoins);
        step_spec_launched += exchange.spec_launched;
        step_spec_won += exchange.spec_won;
        self.metrics.spec_launched.add(exchange.spec_launched as u64);
        self.metrics.spec_won.add(exchange.spec_won as u64);
        if let Some(log) = self.trace.as_mut() {
            // driver-side halves of the superstep: the wire phases at
            // slot 0 (scatter ends when the last Step frame drained)
            let step = step_id as u32;
            log.span(
                "scatter", Phase::Scatter, step, 0, 0, n_tasks as u32,
                exchange.t0_ns, exchange.scatter_done_ns,
            );
            log.span(
                "gather", Phase::Gather, step, 0, 0, n_tasks as u32,
                exchange.scatter_done_ns, exchange.t1_ns,
            );
        }

        // a lagging executor whose result was speculatively adopted
        // still owes its (stale) reply: finish reading it in blocking
        // mode so the connection is frame-aligned for the next
        // superstep, and degrade the peer if it cannot even do that —
        // this superstep is already complete either way
        let mut drain_failed = false;
        for i in 0..n_execs {
            if let Some((st, buf)) = exchange.pending_drain[i].take() {
                if drain_abandoned(&mut self.conns[i], i, st, buf).is_err() {
                    self.conns[i].alive = false;
                    let _ = self.conns[i].stream.shutdown(Shutdown::Both);
                    drain_failed = true;
                }
            }
        }
        if drain_failed {
            self.sync_layout(part)?;
            if self.admm_prepared {
                self.replay_admm()?;
            }
        }

        // parse replies in arrival order: every task's duration exactly
        // once, result segments (or validated folds) into the slabs
        self.durs.clear();
        self.durs.resize(n_tasks, 0.0);
        self.seen.clear();
        self.seen.resize(n_tasks, false);
        self.folded_away.clear();
        self.folded_away.resize(n_tasks, false);
        let mut first_err: Option<(usize, anyhow::Error)> = None;
        for &i in &exchange.arrival {
            let conn = &self.conns[i];
            let tag = Tag::from_u8(exchange.tags[i])
                .with_context(|| format!("reply tag from executor {i} at {}", conn.addr))?;
            match tag {
                Tag::StepResult => {}
                Tag::Fatal => {
                    let msg = ByteReader::new(&self.recv_bufs[i]).str().unwrap_or_default();
                    bail!("executor {i} at {} failed: {msg}", conn.addr);
                }
                other => bail!(
                    "executor {i} at {}: wanted StepResult, got {other:?}",
                    conn.addr
                ),
            }
            let mut r = ByteReader::new(&self.recv_bufs[i]);
            let sid = r.u64()?;
            if sid != step_id {
                bail!(
                    "executor {i} at {} answered superstep {sid}, expected {step_id}",
                    conn.addr
                );
            }
            let count = r.u32()? as usize;
            for _ in 0..count {
                let task = r.u32()? as usize;
                if task >= n_tasks {
                    bail!("executor {i}: task {task} out of range ({n_tasks} tasks)");
                }
                if self.seen[task] {
                    bail!("executor {i}: task {task} reported twice");
                }
                self.seen[task] = true;
                self.durs[task] = r.f64()?;
                let status = r.u8()?;
                match status {
                    0 => {
                        let folded = r.u32()? as usize;
                        if folded > 1 {
                            validate_fold(
                                &op,
                                part,
                                task,
                                folded,
                                i,
                                n_execs,
                                self.ownership,
                                self.cell_map.as_ref(),
                                fold,
                                n_tasks,
                                &mut self.folded_away,
                                &mut self.fold_log,
                            )?;
                        } else if folded == 0 {
                            bail!("executor {i}: task {task} claims a zero-leaf fold");
                        }
                        let (s, l) = op.out_span(part, task);
                        read_segment(&mut r, &mut out[s..s + l], task, "out")?;
                        let (s2, l2) = op.out2_span(part, task);
                        read_segment(&mut r, &mut out2[s2..s2 + l2], task, "out2")?;
                    }
                    1 => {
                        let msg = r.str()?;
                        let err = anyhow::anyhow!("partition task {task}: {msg}");
                        if first_err.as_ref().map(|(t, _)| task < *t).unwrap_or(true) {
                            first_err = Some((task, err));
                        }
                    }
                    2 => {
                        // absorbed by a fold: its root must have preceded
                        // it in this same reply (owned lists ascend, the
                        // root is a block's lowest task)
                        if !self.folded_away[task] {
                            bail!(
                                "executor {i}: task {task} marked fold-absorbed \
                                 without a preceding fold root"
                            );
                        }
                    }
                    other => bail!("executor {i}: task {task} has unknown status {other}"),
                }
            }
            // wire revision 5: the executor's span table rides behind
            // the task entries iff the driver set the trace bit.  A
            // speculatively adopted reply carries no table (SpecStep is
            // never traced), so the emptiness check skips it.
            if trace_req && !r.is_empty() {
                let frame = obs::decode_trace_frame(&mut r).with_context(|| {
                    format!("trace frame from executor {i} at {}", conn.addr)
                })?;
                // re-base executor ticks onto the driver's clock via the
                // handshake offset, and stamp the slot from connection
                // identity (pid i+1; the driver itself is pid 0)
                let off = self.clock_offsets[i];
                let rebase = |t: u64| (t as i64).saturating_sub(off).max(0) as u64;
                if let Some(log) = self.trace.as_mut() {
                    let ids: Vec<u16> =
                        frame.names.iter().map(|n| log.intern(n)).collect();
                    for ev in &frame.events {
                        log.record_raw(TraceEvent {
                            name: ids[ev.name as usize],
                            phase: ev.phase,
                            flags: ev.flags,
                            step: ev.step,
                            slot: (i + 1) as u16,
                            worker: ev.worker,
                            task_lo: ev.task_lo,
                            task_hi: ev.task_hi,
                            t0_ns: rebase(ev.t0_ns),
                            t1_ns: rebase(ev.t1_ns),
                        });
                    }
                    log.add_dropped(frame.dropped);
                }
            }
        }
        if let Some(missing) = self.seen.iter().position(|&s| !s) {
            bail!(
                "superstep {step_id}: no executor owned task {missing} \
                 ({n_execs} executors, {n_tasks} tasks)"
            );
        }

        let degraded = self.degraded_executors();
        self.metrics.degraded.set(degraded as i64);
        if let Some(log) = self.trace.as_mut() {
            // one instant marker per fault-tolerance event this superstep
            // (Perfetto renders them as flags on the driver track)
            let t = obs::now_ns();
            let step = step_id as u32;
            for _ in 0..step_retries {
                log.instant("retry", Phase::Recover, step, 0, t);
            }
            for _ in 0..step_rejoins {
                log.instant("rejoin", Phase::Recover, step, 0, t);
            }
            for _ in 0..step_spec_launched {
                log.instant("spec-launch", Phase::Spec, step, 0, t);
            }
            for _ in 0..step_spec_won {
                log.instant("spec-win", Phase::Spec, step, 0, t);
            }
            if degraded > 0 {
                log.instant("degraded", Phase::Recover, step, 0, t);
            }
        }

        // the simulated clock advances exactly like the sim backend's,
        // fed with the *measured* executor durations (or the Fixed cost)
        let sim_before = self.sim.clock.now();
        self.sim.charge_measured(&self.durs, op.tolerant());
        self.wire_log.push(WireRecord {
            step: step_id as usize,
            op: op.name(),
            wall_secs: t0.elapsed().as_secs_f64(),
            bytes_out: exchange.scatter.iter().sum(),
            bytes_in: exchange.gather.iter().sum(),
            sim_secs: self.sim.clock.now() - sim_before,
            scatter: exchange.scatter,
            gather: exchange.gather,
            retries: step_retries as usize,
            rejoins: step_rejoins as usize,
            degraded_executors: self.degraded_executors(),
            spec_launched: step_spec_launched,
            spec_won: step_spec_won,
        });
        match first_err {
            Some((_, e)) => Err(e),
            None => Ok(()),
        }
    }

    fn reduce_segments(
        &mut self,
        slab: &mut [f32],
        base: usize,
        stride: usize,
        count: usize,
        len: usize,
    ) {
        // results were already gathered to the driver; the combine (and
        // its comm charge) is bit-identical to the sim backend's, with
        // pairs the executors pre-folded (logged during the gather)
        // skipped but still charged
        let t0 = if self.trace.is_some() { obs::now_ns() } else { 0 };
        self.sim
            .reduce_segments_folded(slab, base, stride, count, len, &self.fold_log);
        if let Some(log) = self.trace.as_mut() {
            log.span(
                "reduce", Phase::Combine, self.step_id as u32, 0,
                0, count as u32, t0, obs::now_ns(),
            );
        }
    }

    fn reduce_cost(&mut self, leaves: usize, bytes_per_leaf: usize) {
        self.sim.reduce_cost(leaves, bytes_per_leaf);
    }

    fn broadcast_cost(&mut self, bytes: usize, fanout: usize) {
        self.sim.broadcast_cost(bytes, fanout);
    }

    fn clock(&self) -> &SimClock {
        &self.sim.clock
    }

    fn clock_mut(&mut self) -> &mut SimClock {
        &mut self.sim.clock
    }

    fn host_secs(&self) -> f64 {
        self.sim.host_secs()
    }

    fn take_wire_log(&mut self) -> Vec<WireRecord> {
        std::mem::take(&mut self.wire_log)
    }

    fn set_trace(&mut self, enabled: bool) {
        if enabled {
            if self.trace.is_none() {
                let mut log = TraceLog::with_capacity(obs::TRACE_LOG_CAPACITY);
                // replay the connect-time staging phase so the timeline
                // starts at the handshake, not the first superstep
                log.span(
                    "stage", Phase::Stage, 0, 0, 0, 0,
                    self.stage_t0_ns, self.stage_t1_ns,
                );
                self.trace = Some(log);
            }
        } else {
            self.trace = None;
        }
    }

    fn take_trace(&mut self) -> Option<TraceLog> {
        self.trace.take()
    }

    fn metrics_snapshot(&self) -> Vec<(String, f64)> {
        self.metrics.registry.snapshot()
    }

    fn shutdown(&mut self) -> Result<()> {
        // orderly release: executors return to their accept loop; errors
        // are ignored (the executor may already be gone, which is fine)
        for conn in &mut self.conns {
            if !conn.alive {
                continue;
            }
            if wire::write_frame(&mut conn.stream, Tag::Shutdown, &[]).is_ok() {
                let _ = wire::expect_frame(&mut conn.stream, &mut self.recv_buf, Tag::Bye);
            }
        }
        self.conns.clear();
        Ok(())
    }
}

/// Outcome of one pipelined Step exchange.
struct Exchange {
    /// Bytes written per executor (header + body; speculative dispatches
    /// land on their backup's row).  Degraded slots stay 0.
    scatter: Vec<usize>,
    /// Bytes read per executor (header + body; an adopted backup reply
    /// is attributed to the lagging slot it answered for).
    gather: Vec<usize>,
    /// Raw reply tag byte per executor (validated by the parser).
    tags: Vec<u8>,
    /// Executor indices in reply-completion order.
    arrival: Vec<usize>,
    /// Per-executor partially-read stale primary reply (receive state +
    /// buffered bytes) left behind when a speculative backup won — the
    /// caller drains it in blocking mode after the exchange.
    pending_drain: Vec<Option<(RecvState, Vec<u8>)>>,
    /// Speculative backup dispatches this exchange.
    spec_launched: usize,
    /// Backup replies adopted over their lagging primary this exchange.
    spec_won: usize,
    /// Driver-clock ticks bounding the exchange: start, the moment the
    /// last Step frame fully drained (scatter→gather boundary), and
    /// completion.  Feed the driver's scatter/gather trace spans.
    t0_ns: u64,
    scatter_done_ns: u64,
    t1_ns: u64,
}

/// Per-connection receive progress of the pipelined exchange.
#[derive(Clone, Copy, Default)]
struct RecvState {
    header: [u8; 5],
    header_got: usize,
    body_len: usize,
    body_got: usize,
    done: bool,
}

/// Everything the in-exchange speculation machinery needs from the
/// driver, borrowed field-disjointly so the exchange can still hold the
/// connections mutably.
struct SpecCtx<'a, 'b> {
    op: &'a GridOp<'b>,
    part: &'a Partitioned,
    /// Per-executor owned task lists of this superstep (cell-map aware).
    owned: &'a [Vec<usize>],
    /// Per-executor staged-cell sets (a backup must hold replicas of
    /// every cell the lagging peer's tasks touch).
    staged: &'a [Vec<bool>],
    /// Per-(executor, op-kind) gather-latency EWMA (updated on primary
    /// completions, read to rank backup candidates).
    ewma: &'a mut HashMap<(usize, &'static str), f64>,
    quantile: f64,
    copies: usize,
}

/// One speculative backup dispatch in flight: the backup executor is
/// computing a copy of the lagging executor's task list, and its reply
/// is being read on the backup's connection.
struct SpecFlight {
    backup: usize,
    lagging: usize,
    recv: RecvState,
    buf: Vec<u8>,
}

/// Write every executor's Step frame and read every reply with
/// nonblocking I/O: no read waits on an unfinished write, and replies
/// complete in whatever order executors finish.  Blocking mode is
/// restored on every exit path (the control-plane frames — acks,
/// shutdown — use plain blocking I/O).  With `spec`, lagging replies may
/// be speculatively re-executed on idle peers (see module docs).
fn pipelined_exchange(
    conns: &mut [ExecConn],
    bodies: &[&[u8]],
    recv_bufs: &mut [Vec<u8>],
    step_id: u64,
    spec: Option<&mut SpecCtx<'_, '_>>,
) -> Result<Exchange> {
    let n = conns.len();
    for conn in conns.iter() {
        if !conn.alive {
            continue;
        }
        conn.stream
            .set_nonblocking(true)
            .with_context(|| format!("nonblocking mode on executor at {}", conn.addr))?;
    }
    let result = exchange_inner(conns, bodies, recv_bufs, step_id, spec);
    // failing to restore blocking mode would make the *next*
    // control-plane read spuriously fail with WouldBlock and blame the
    // wrong layer — surface it here, against the right executor, but
    // never mask the exchange's own error
    let mut restore: Result<()> = Ok(());
    for conn in conns.iter() {
        if !conn.alive {
            continue;
        }
        if let Err(e) = conn.stream.set_nonblocking(false) {
            if restore.is_ok() {
                restore = Err(e).with_context(|| {
                    format!("restore blocking mode on executor at {}", conn.addr)
                });
            }
        }
    }
    debug_assert_eq!(bodies.len(), n);
    match result {
        Err(e) => Err(e),
        Ok(ex) => restore.map(|()| ex),
    }
}

fn exchange_inner(
    conns: &mut [ExecConn],
    bodies: &[&[u8]],
    recv_bufs: &mut [Vec<u8>],
    step_id: u64,
    mut spec: Option<&mut SpecCtx<'_, '_>>,
) -> Result<Exchange> {
    let n = conns.len();
    let started = Instant::now();
    let t0_ns = obs::now_ns();
    // 0 = scatter still in flight; stamped once every live peer's Step
    // frame has fully drained (the driver's scatter→gather boundary)
    let mut scatter_done_ns = 0u64;
    let alive: Vec<bool> = conns.iter().map(|c| c.alive).collect();
    let headers: Vec<[u8; 5]> = bodies
        .iter()
        .map(|b| {
            let mut h = [0u8; 5];
            h[..4].copy_from_slice(&(b.len() as u32).to_le_bytes());
            h[4] = Tag::Step as u8;
            h
        })
        .collect();
    let mut sent = vec![0usize; n];
    let mut recv = vec![RecvState::default(); n];
    let mut arrival = Vec::with_capacity(n);
    // speculation state: wall-clock completion times feed the EWMAs and
    // the stall trigger; `abandoned` marks a peer whose stale reply is
    // owed to `pending_drain` (its socket is off-limits until drained)
    let mut done_at: Vec<Option<f64>> = vec![None; n];
    let mut abandoned = vec![false; n];
    let mut spec_count = vec![0usize; n];
    let mut spec_scatter = vec![0usize; n];
    let mut pending_drain: Vec<Option<(RecvState, Vec<u8>)>> = (0..n).map(|_| None).collect();
    let mut flights: Vec<SpecFlight> = Vec::new();
    let mut spec_launched = 0usize;
    let mut spec_won = 0usize;
    // liveness deadline, not a whole-exchange cap: re-armed on every
    // sweep that moves bytes, so a reply that trickles in slowly but
    // steadily is never killed as "wedged"
    let budget = read_timeout().ok().flatten();
    let mut deadline = budget.map(|t| Instant::now() + t);
    let mut idle_sweeps = 0usize;
    loop {
        let mut progressed = false;
        let mut all_done = true;
        for i in 0..n {
            if !alive[i] {
                continue;
            }
            let total = 5 + bodies[i].len();
            // scatter: push as much of this executor's frame as the
            // socket accepts, then move on — never block on one peer
            while sent[i] < total {
                let chunk: &[u8] = if sent[i] < 5 {
                    &headers[i][sent[i]..]
                } else {
                    &bodies[i][sent[i] - 5..]
                };
                match conns[i].stream.write(chunk) {
                    Ok(0) => bail!(
                        "executor {i} at {} closed the connection during superstep {step_id}",
                        conns[i].addr
                    ),
                    Ok(k) => {
                        sent[i] += k;
                        progressed = true;
                    }
                    Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => break,
                    Err(e) if e.kind() == std::io::ErrorKind::Interrupted => {}
                    Err(e) => {
                        return Err(e).with_context(|| {
                            format!(
                                "send superstep {step_id} to executor {i} at {}",
                                conns[i].addr
                            )
                        })
                    }
                }
            }
            // gather: drain whatever reply bytes have arrived
            let was_done = recv[i].done;
            progressed |= read_some(&mut conns[i], i, &mut recv[i], &mut recv_bufs[i])
                .with_context(|| {
                    format!(
                        "superstep {step_id} reply from executor {i} at {} \
                         (killed or wedged executor?)",
                        conns[i].addr
                    )
                })?;
            if recv[i].done && !was_done {
                let t = started.elapsed().as_secs_f64();
                done_at[i] = Some(t);
                if let Some(ctx) = spec.as_deref_mut() {
                    let key = (i, ctx.op.name());
                    let e = ctx.ewma.entry(key).or_insert(t);
                    *e = 0.7 * *e + 0.3 * t;
                }
            }
            if recv[i].done && arrival.iter().all(|&a: &usize| a != i) {
                arrival.push(i);
            }
            all_done &= sent[i] == total && recv[i].done;
        }
        if scatter_done_ns == 0
            && (0..n).all(|i| !alive[i] || sent[i] == 5 + bodies[i].len())
        {
            scatter_done_ns = obs::now_ns();
        }
        // poll speculative backups: their replies ride the backup's
        // connection after its own reply finished
        let mut f = 0;
        while f < flights.len() {
            {
                let fl = &mut flights[f];
                progressed |=
                    read_some(&mut conns[fl.backup], fl.backup, &mut fl.recv, &mut fl.buf)
                        .with_context(|| {
                            format!(
                                "speculative superstep {step_id} reply from executor {} at {}",
                                fl.backup, conns[fl.backup].addr
                            )
                        })?;
            }
            if !flights[f].recv.done {
                f += 1;
                continue;
            }
            let fl = flights.swap_remove(f);
            match Tag::from_u8(fl.recv.header[4]) {
                Ok(Tag::StepResult) => {}
                Ok(Tag::Fatal) => {
                    let msg = ByteReader::new(&fl.buf).str().unwrap_or_default();
                    bail!(
                        "executor {} at {} failed a speculative step: {msg}",
                        fl.backup,
                        conns[fl.backup].addr
                    );
                }
                Ok(other) => bail!(
                    "executor {} at {}: wanted speculative StepResult, got {other:?}",
                    fl.backup,
                    conns[fl.backup].addr
                ),
                Err(e) => {
                    return Err(e.context(format!(
                        "speculative reply tag from executor {} at {}",
                        fl.backup, conns[fl.backup].addr
                    )))
                }
            }
            if !recv[fl.lagging].done {
                // first valid result wins: adopt the backup's reply for
                // the lagging slot and owe the primary's stale reply to
                // the post-exchange drain
                pending_drain[fl.lagging] =
                    Some((recv[fl.lagging], std::mem::take(&mut recv_bufs[fl.lagging])));
                recv_bufs[fl.lagging] = fl.buf;
                recv[fl.lagging] = fl.recv;
                abandoned[fl.lagging] = true;
                if arrival.iter().all(|&a: &usize| a != fl.lagging) {
                    arrival.push(fl.lagging);
                }
                spec_won += 1;
                progressed = true;
            }
            // else: the primary beat its backup — the duplicate reply
            // was fully read above and is simply dropped
        }
        all_done &= flights.is_empty();
        if all_done {
            break;
        }
        if progressed {
            idle_sweeps = 0;
            deadline = budget.map(|t| Instant::now() + t);
            continue;
        }
        // an idle sweep with most of the fleet done is the speculation
        // trigger's moment: second-guess the laggards on an idle peer
        if let Some(ctx) = spec.as_deref_mut() {
            let sent_done: Vec<bool> = (0..n).map(|i| sent[i] == 5 + bodies[i].len()).collect();
            if maybe_dispatch_spec(
                conns,
                &alive,
                &recv,
                &sent_done,
                &abandoned,
                &mut flights,
                &mut spec_count,
                &mut spec_scatter,
                &mut spec_launched,
                started.elapsed().as_secs_f64(),
                &done_at,
                ctx,
                step_id,
            )? {
                deadline = budget.map(|t| Instant::now() + t);
                continue;
            }
        }
        if let Some(d) = deadline {
            if Instant::now() > d {
                let totals: Vec<usize> = (0..n)
                    .map(|i| if alive[i] { 5 + bodies[i].len() } else { 0 })
                    .collect();
                let done: Vec<bool> = (0..n).map(|i| !alive[i] || recv[i].done).collect();
                let addrs: Vec<&str> = conns.iter().map(|c| c.addr.as_str()).collect();
                bail!(
                    "superstep {step_id} made no progress for {:?}: {} \
                     (killed or wedged executor?)",
                    budget.unwrap_or_default(),
                    describe_stall(&sent, &totals, &done, &addrs)
                );
            }
        }
        // spin briefly for loopback latency, then back off so executor
        // threads on the same host get the cores during long supersteps
        idle_sweeps += 1;
        if idle_sweeps < 200 {
            std::thread::yield_now();
        } else {
            std::thread::sleep(Duration::from_micros(100));
        }
    }
    Ok(Exchange {
        scatter: (0..n)
            .map(|i| if alive[i] { 5 + bodies[i].len() } else { 0 } + spec_scatter[i])
            .collect(),
        gather: (0..n)
            .map(|i| if alive[i] { 5 + recv[i].body_len } else { 0 })
            .collect(),
        tags: recv.iter().map(|s| s.header[4]).collect(),
        arrival,
        pending_drain,
        spec_launched,
        spec_won,
        t0_ns,
        scatter_done_ns: if scatter_done_ns == 0 { t0_ns } else { scatter_done_ns },
        t1_ns: obs::now_ns(),
    })
}

/// The speculation trigger and dispatcher, called on idle sweeps: once
/// `quantile` of the live fleet has replied and a laggard has been
/// outstanding for more than `max(50ms, factor × slowest finisher)`
/// (factor = `1/(1-quantile)`, clamped to [2, 16]), send a backup copy
/// of its task list to the historically fastest idle finisher that holds
/// replicas of every cell those tasks touch.  At most `copies` backups
/// per laggard per superstep; one flight per backup connection (frames
/// on one socket must not interleave).  Returns whether anything was
/// dispatched.
#[allow(clippy::too_many_arguments)]
fn maybe_dispatch_spec(
    conns: &mut [ExecConn],
    alive: &[bool],
    recv: &[RecvState],
    sent_done: &[bool],
    abandoned: &[bool],
    flights: &mut Vec<SpecFlight>,
    spec_count: &mut [usize],
    spec_scatter: &mut [usize],
    launched: &mut usize,
    elapsed: f64,
    done_at: &[Option<f64>],
    ctx: &mut SpecCtx<'_, '_>,
    step_id: u64,
) -> Result<bool> {
    if ctx.copies == 0 {
        return Ok(false);
    }
    // ADMM's projection step reads executor-resident factorizations a
    // replica holder never prepared for foreign cells; everything else
    // is a pure function of the shipped descriptor plus the block
    if ctx.op.name() == "admm-project" {
        return Ok(false);
    }
    let live: Vec<usize> = (0..alive.len()).filter(|&i| alive[i]).collect();
    if live.len() < 2 {
        return Ok(false);
    }
    let done: Vec<usize> = live.iter().copied().filter(|&i| recv[i].done).collect();
    let quota = ((ctx.quantile * live.len() as f64).floor() as usize).max(1);
    if done.len() < quota || done.len() == live.len() {
        return Ok(false);
    }
    let slowest_done = done
        .iter()
        .filter_map(|&i| done_at[i])
        .fold(0.0f64, f64::max);
    let factor = (1.0 / (1.0 - ctx.quantile).max(1e-6)).clamp(2.0, 16.0);
    if elapsed <= (factor * slowest_done).max(SPEC_MIN_STALL_SECS) {
        return Ok(false);
    }
    let mut dispatched = false;
    for &lag in &live {
        if recv[lag].done || abandoned[lag] || !sent_done[lag] {
            continue;
        }
        if spec_count[lag] >= ctx.copies {
            continue;
        }
        let tasks = &ctx.owned[lag];
        if tasks.is_empty() {
            continue;
        }
        // backup: a finisher with no flight of its own already, holding
        // replicas of every cell the laggard's tasks read; ties broken
        // by the lowest gather-latency EWMA for this op kind
        let mut best: Option<(usize, f64)> = None;
        for &b in &done {
            if b == lag || abandoned[b] || flights.iter().any(|f| f.backup == b) {
                continue;
            }
            if !tasks.iter().all(|&t| ctx.staged[b][ctx.op.cell(ctx.part, t)]) {
                continue;
            }
            let score = ctx
                .ewma
                .get(&(b, ctx.op.name()))
                .copied()
                .or(done_at[b])
                .unwrap_or(0.0);
            if best.map(|(_, s)| score < s).unwrap_or(true) {
                best = Some((b, score));
            }
        }
        let Some((backup, _)) = best else { continue };
        // SpecStep body: step id, flags (sliced, never folded — the
        // replica holder's fold subtrees are not the laggard's), the
        // explicit task list, then the sliced descriptor for exactly
        // those tasks
        let mut body = Vec::new();
        bytes::put_u64(&mut body, step_id);
        bytes::put_u8(&mut body, wire::STEP_FLAG_SLICED);
        bytes::put_u32(&mut body, tasks.len() as u32);
        for &t in tasks {
            bytes::put_u32(&mut body, t as u32);
        }
        ops::encode_op_sliced(ctx.op, ctx.part, tasks, &mut body);
        // the backup is idle, so a blocking write is safe and simplest
        let conn = &mut conns[backup];
        conn.stream.set_nonblocking(false).with_context(|| {
            format!("blocking mode on executor {backup} at {}", conn.addr)
        })?;
        let sent = wire::write_frame(&mut conn.stream, Tag::SpecStep, &body).with_context(
            || format!("speculative dispatch to executor {backup} at {}", conn.addr),
        )?;
        conn.stream.set_nonblocking(true).with_context(|| {
            format!("nonblocking mode on executor {backup} at {}", conn.addr)
        })?;
        spec_scatter[backup] += sent;
        spec_count[lag] += 1;
        *launched += 1;
        flights.push(SpecFlight {
            backup,
            lagging: lag,
            recv: RecvState::default(),
            buf: Vec::new(),
        });
        dispatched = true;
    }
    Ok(dispatched)
}

/// Finish reading an abandoned primary reply in blocking mode (the
/// socket's read timeout applies) and discard it, leaving the connection
/// frame-aligned.  `st`/`buf` carry whatever the nonblocking exchange
/// had already consumed.
fn drain_abandoned(
    conn: &mut ExecConn,
    i: usize,
    mut st: RecvState,
    mut buf: Vec<u8>,
) -> Result<()> {
    if st.header_got < 5 {
        conn.stream
            .read_exact(&mut st.header[st.header_got..])
            .with_context(|| format!("drain stale reply header from executor {i}"))?;
        st.header_got = 5;
        let len = u32::from_le_bytes(st.header[..4].try_into().unwrap()) as usize;
        if len > wire::MAX_FRAME {
            bail!("executor {i}: stale reply of {len} bytes exceeds MAX_FRAME");
        }
        st.body_len = len;
        st.body_got = 0;
        buf.clear();
        buf.resize(len, 0);
    }
    if st.body_got < st.body_len {
        conn.stream
            .read_exact(&mut buf[st.body_got..st.body_len])
            .with_context(|| format!("drain stale reply body from executor {i}"))?;
    }
    Tag::from_u8(st.header[4])
        .with_context(|| format!("stale reply tag from executor {i}"))?;
    Ok(())
}

/// Nonblocking read step for one connection: header, then body.  Returns
/// whether any bytes moved.
fn read_some(
    conn: &mut ExecConn,
    i: usize,
    st: &mut RecvState,
    body: &mut Vec<u8>,
) -> Result<bool> {
    let mut progressed = false;
    while !st.done {
        if st.header_got < 5 {
            match conn.stream.read(&mut st.header[st.header_got..]) {
                Ok(0) => bail!("executor {i} closed the connection mid-reply"),
                Ok(k) => {
                    st.header_got += k;
                    progressed = true;
                    if st.header_got == 5 {
                        let len =
                            u32::from_le_bytes(st.header[..4].try_into().unwrap()) as usize;
                        if len > wire::MAX_FRAME {
                            bail!(
                                "executor {i}: incoming frame of {len} bytes exceeds \
                                 MAX_FRAME (corrupt stream?)"
                            );
                        }
                        st.body_len = len;
                        body.clear();
                        body.resize(len, 0);
                    }
                }
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => return Ok(progressed),
                Err(e) if e.kind() == std::io::ErrorKind::Interrupted => {}
                Err(e) => return Err(e.into()),
            }
        } else if st.body_got < st.body_len {
            match conn.stream.read(&mut body[st.body_got..]) {
                Ok(0) => bail!("executor {i} closed the connection mid-reply"),
                Ok(k) => {
                    st.body_got += k;
                    progressed = true;
                }
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => return Ok(progressed),
                Err(e) if e.kind() == std::io::ErrorKind::Interrupted => {}
                Err(e) => return Err(e.into()),
            }
        } else {
            st.done = true;
        }
    }
    Ok(progressed)
}

/// Validate one claimed executor-side fold against the op's combine-tree
/// geometry, mark its absorbed tasks, and log it for
/// [`SimCluster::reduce_segments_folded`].  Ownership of the absorbed
/// tasks is judged by the active [`CellMap`] when the fleet is degraded,
/// by the pure functional layout otherwise — the same rule the scatter
/// used.
#[allow(clippy::too_many_arguments)]
fn validate_fold(
    op: &GridOp<'_>,
    part: &Partitioned,
    task: usize,
    folded: usize,
    exec: usize,
    n_execs: usize,
    ownership: Ownership,
    map: Option<&CellMap>,
    fold_requested: bool,
    n_tasks: usize,
    folded_away: &mut [bool],
    fold_log: &mut Vec<FoldEntry>,
) -> Result<()> {
    if !fold_requested {
        bail!("executor {exec}: task {task} folded {folded} leaves, but folding was not requested");
    }
    let g = op
        .fold_group(part, task)
        .ok_or_else(|| anyhow::anyhow!("executor {exec}: task {task} folded a fold-free op"))?;
    if !folded.is_power_of_two() || g.leaf % folded != 0 || g.leaf + folded > g.count {
        bail!(
            "executor {exec}: task {task} claims a misaligned fold \
             ({folded} leaves at leaf {} of {})",
            g.leaf,
            g.count
        );
    }
    for k in 1..folded {
        let t2 = task + k * g.task_stride;
        if t2 >= n_tasks {
            bail!("executor {exec}: fold at task {task} spills past task {t2}");
        }
        let t2_owner = match map {
            Some(m) => m.slot(op.cell(part, t2)),
            None => op.owner(part, t2, n_execs, ownership),
        };
        if t2_owner != exec {
            bail!(
                "executor {exec}: fold at task {task} absorbs task {t2} it does not own"
            );
        }
        if folded_away[t2] {
            bail!("executor {exec}: task {t2} absorbed by two folds");
        }
        folded_away[t2] = true;
    }
    fold_log.push(FoldEntry {
        base: g.base,
        stride: g.stride,
        count: g.count,
        len: g.len,
        leaf: g.leaf,
        folded,
    });
    Ok(())
}

/// Name the peer(s) actually responsible for a stalled exchange: an
/// executor whose scatter frame never drained is reported separately
/// from one whose reply never finished, so the blame lands on the right
/// side of the pipe (the old code blamed executor 0 whenever every
/// *reply* happened to be done but a send was stuck).
fn describe_stall(sent: &[usize], totals: &[usize], done: &[bool], addrs: &[&str]) -> String {
    let unsent: Vec<String> = (0..sent.len())
        .filter(|&i| sent[i] < totals[i])
        .map(|i| format!("{i} at {} ({}/{} bytes sent)", addrs[i], sent[i], totals[i]))
        .collect();
    let missing: Vec<String> = (0..done.len())
        .filter(|&i| sent[i] >= totals[i] && !done[i])
        .map(|i| format!("{i} at {}", addrs[i]))
        .collect();
    let mut parts = Vec::new();
    if !unsent.is_empty() {
        parts.push(format!("scatter never drained to executor {}", unsent.join(", ")));
    }
    if !missing.is_empty() {
        parts.push(format!("no reply from executor {}", missing.join(", ")));
    }
    if parts.is_empty() {
        // unreachable if the caller checked all_done, kept for safety
        parts.push("all scatters drained and all replies complete".into());
    }
    parts.join("; ")
}

/// A cheap unique-enough session id: FNV-1a over the wall clock, the
/// driver pid, and the fleet's addresses.  Lets an executor prove its
/// cached blocks belong to *this* run when the driver rejoins — without
/// threading any RNG state through the transport.
fn session_token(addrs: &[String]) -> u64 {
    const FNV_PRIME: u64 = 0x0000_0100_0000_01b3;
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    let nanos = std::time::SystemTime::now()
        .duration_since(std::time::UNIX_EPOCH)
        .map(|d| d.as_nanos())
        .unwrap_or(0);
    for b in nanos.to_le_bytes() {
        h = (h ^ b as u64).wrapping_mul(FNV_PRIME);
    }
    for b in std::process::id().to_le_bytes() {
        h = (h ^ b as u64).wrapping_mul(FNV_PRIME);
    }
    for a in addrs {
        for &b in a.as_bytes() {
            h = (h ^ b as u64).wrapping_mul(FNV_PRIME);
        }
        h = (h ^ 0xff).wrapping_mul(FNV_PRIME);
    }
    h
}

/// One reconnect + `Rejoin` handshake (+ restage when the executor lost
/// its cached session).  With `limit`, both the connect and the
/// handshake reads are bounded by it — recovery sweeps use this so one
/// unreachable peer cannot eat the whole rejoin budget — and the
/// session read timeout is restored before returning.  The second
/// element reports whether the peer had to be restaged (it holds its
/// pure-owned blocks again, nothing more); the third is the refreshed
/// clock-offset estimate (exec tick − RTT midpoint, 0 for pre-v5
/// peers) — an executor restart resets its monotonic epoch, so the
/// connect-time estimate is stale after any rejoin.
#[allow(clippy::too_many_arguments)]
fn rejoin_one(
    addr: &str,
    i: usize,
    n_execs: usize,
    token: u64,
    offered: u32,
    session_caps: u32,
    stage_body: &[u8],
    step_id: u64,
    recv_buf: &mut Vec<u8>,
    limit: Option<Duration>,
) -> Result<(ExecConn, bool, i64)> {
    let mut stream = match limit {
        Some(lim) => {
            let sock = addr
                .to_socket_addrs()
                .with_context(|| format!("resolve executor {i} address {addr}"))?
                .next()
                .ok_or_else(|| {
                    anyhow::anyhow!("executor {i} address {addr} resolves to nothing")
                })?;
            TcpStream::connect_timeout(&sock, lim)
                .with_context(|| format!("reconnect to executor {i} at {addr}"))?
        }
        None => TcpStream::connect(addr)
            .with_context(|| format!("reconnect to executor {i} at {addr}"))?,
    };
    stream.set_nodelay(true).ok();
    stream
        .set_read_timeout(limit.or(read_timeout()?))
        .with_context(|| format!("set read timeout on executor {i} at {addr}"))?;
    let mut body = Vec::new();
    bytes::put_u32(&mut body, wire::PROTO_MAGIC);
    bytes::put_u64(&mut body, token);
    bytes::put_u32(&mut body, i as u32);
    bytes::put_u32(&mut body, n_execs as u32);
    bytes::put_u64(&mut body, step_id);
    bytes::put_u32(&mut body, offered);
    let t_send = obs::now_ns();
    wire::write_frame(&mut stream, Tag::Rejoin, &body)?;
    wire::expect_frame(&mut stream, recv_buf, Tag::RejoinAck)
        .with_context(|| format!("rejoin handshake with executor {i} at {addr}"))?;
    let t_recv = obs::now_ns();
    let mut r = ByteReader::new(recv_buf);
    let magic = r.u32()?;
    if magic != wire::PROTO_MAGIC {
        bail!("executor {i} at {addr}: bad magic in RejoinAck");
    }
    let threads = r.u32()? as usize;
    let acked = r.u32()?;
    let have_blocks = r.u8()?;
    if acked & !offered != 0 {
        bail!(
            "executor {i} at {addr} acked capabilities {acked:#x} \
             it was never offered ({offered:#x})"
        );
    }
    if acked & session_caps != session_caps {
        // the run already committed to the negotiated AND; a replacement
        // executor that implements less cannot replay its supersteps
        bail!(
            "executor {i} at {addr} rejoined with capabilities {acked:#x}, \
             session needs {session_caps:#x}"
        );
    }
    // wire revision 5: trailing tick, same offset estimate as HelloAck
    let offset = if r.remaining() >= 8 {
        r.u64()? as i64 - ((t_send + t_recv) / 2) as i64
    } else {
        0
    };
    let restaged = have_blocks == 0;
    if restaged {
        wire::write_frame(&mut stream, Tag::Stage, stage_body)
            .with_context(|| format!("restage blocks on executor {i} at {addr}"))?;
        wire::expect_frame(&mut stream, recv_buf, Tag::StageAck)
            .with_context(|| format!("restage ack from executor {i} at {addr}"))?;
    }
    // the per-attempt limit only governs the handshake; the session's
    // configured read timeout takes over from here
    stream
        .set_read_timeout(read_timeout()?)
        .with_context(|| format!("restore read timeout on executor {i} at {addr}"))?;
    Ok((
        ExecConn { stream, addr: addr.to_string(), threads, alive: true },
        restaged,
        offset,
    ))
}

/// Read one length-prefixed f32 array straight into a slab segment,
/// insisting the length matches the span exactly.
fn read_segment(
    r: &mut ByteReader<'_>,
    dst: &mut [f32],
    task: usize,
    what: &str,
) -> Result<()> {
    let n = r.u64()? as usize;
    if n != dst.len() {
        bail!(
            "task {task}: {what} segment length {n} != expected {}",
            dst.len()
        );
    }
    r.fill_f32s(dst)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stall_blame_names_missing_gather_not_executor_zero() {
        // executor 0 and 1 replied; 2 is the one actually wedged
        let msg = describe_stall(
            &[10, 10, 10],
            &[10, 10, 10],
            &[true, true, false],
            &["a:1", "b:2", "c:3"],
        );
        assert!(msg.contains("no reply from executor 2 at c:3"), "{msg}");
        assert!(!msg.contains("executor 0"), "{msg}");
        assert!(!msg.contains("scatter"), "{msg}");
    }

    #[test]
    fn stall_blame_reports_stuck_send_even_when_replies_done() {
        // the pre-fix fallback blamed executor 0's reply here, although
        // every reply is done and the real problem is 1's stuck scatter
        let msg = describe_stall(
            &[10, 4, 10],
            &[10, 10, 10],
            &[true, false, true],
            &["a:1", "b:2", "c:3"],
        );
        assert!(
            msg.contains("scatter never drained to executor 1 at b:2 (4/10 bytes sent)"),
            "{msg}"
        );
        // an executor whose scatter never drained obviously has no
        // reply; it must not be double-reported on the gather side
        assert!(!msg.contains("no reply"), "{msg}");
    }

    #[test]
    fn stall_blame_separates_send_and_reply_laggards() {
        let msg = describe_stall(
            &[3, 10],
            &[10, 10],
            &[false, false],
            &["a:1", "b:2"],
        );
        assert!(msg.contains("scatter never drained to executor 0"), "{msg}");
        assert!(msg.contains("no reply from executor 1 at b:2"), "{msg}");
    }

    #[test]
    fn session_tokens_differ_across_calls() {
        let addrs = vec!["127.0.0.1:7001".to_string()];
        let a = session_token(&addrs);
        std::thread::sleep(Duration::from_millis(2));
        let b = session_token(&addrs);
        assert_ne!(a, b);
    }

    #[test]
    fn invalid_timeout_env_is_a_hard_error_naming_the_variable() {
        // no other lib unit test reads these variables, so the
        // set/restore dance is race-free under the parallel test runner
        const VAR: &str = "DDOPT_DIST_READ_TIMEOUT_SECS";
        let saved = std::env::var(VAR).ok();
        std::env::set_var(VAR, "1O"); // a typo'd "10"
        let err = read_timeout().unwrap_err().to_string();
        assert!(err.contains(VAR), "error must name the variable: {err}");
        assert!(err.contains("1O"), "error must quote the bad value: {err}");
        std::env::set_var(VAR, "30");
        assert_eq!(read_timeout().unwrap(), Some(Duration::from_secs(30)));
        std::env::set_var(VAR, "0");
        assert_eq!(read_timeout().unwrap(), None);
        match saved {
            Some(v) => std::env::set_var(VAR, v),
            None => std::env::remove_var(VAR),
        }
    }

    #[test]
    fn next_alive_skips_dead_slots_cyclically() {
        assert_eq!(next_alive(&[false, true, false], 0), Some(2));
        assert_eq!(next_alive(&[false, true, false], 2), Some(0));
        assert_eq!(next_alive(&[false, true, true], 0), None);
        assert_eq!(next_alive(&[false, false, false, false], 1), Some(2));
    }
}
