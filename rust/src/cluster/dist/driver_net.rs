//! [`DistCluster`] — the driver-side transport: a [`ClusterBackend`]
//! whose supersteps execute on real executor processes over TCP.
//!
//! Per superstep the driver encodes the [`GridOp`] descriptor (iterates,
//! index streams — kilobytes, never the training data) and exchanges it
//! with the fleet:
//!
//! * **sliced scatter** (negotiated via [`wire::CAP_SLICED`]) — each
//!   executor's Step frame carries only the state ranges and per-task
//!   streams its owned tasks read ([`ops::encode_op_sliced`]); without
//!   the capability every executor receives the identical full payload.
//! * **pipelined, readiness-ordered fan-out** — all per-executor frames
//!   are written with nonblocking I/O before any reply is awaited, and
//!   replies are consumed in *arrival* order, so one slow executor never
//!   serializes the whole exchange.  The sim backend's
//!   lowest-task-index-wins error rule is order-independent, so arrival
//!   order changes nothing observable.
//! * **folded gather** (negotiated via [`wire::CAP_CONTIG_FOLD`], which
//!   also switches cell ownership to contiguous ranges) — executors
//!   pre-combine their locally-owned aligned subtrees of the
//!   segment-combine tree before replying; the driver validates each
//!   fold against [`GridOp::fold_group`] geometry, logs it as a
//!   [`FoldEntry`], and later skips exactly those pairs in
//!   [`SimCluster::reduce_segments_folded`] — same pairing order, same
//!   bits, fewer bytes and adds.
//!
//! Gathered segments land in the coordinator's output slab at the
//! position [`GridOp::out_span`] dictates, and combining reuses
//! [`tree_aggregate`](crate::cluster::comm::tree_aggregate)'s order
//! exactly: final weights are bit-identical to `--cluster sim` at the
//! same seed, in both wire modes (asserted by `tests/dist_parity.rs`).
//!
//! Accounting is double-entry: executors report *measured* per-task
//! seconds, which feed the same scenario/LPT simulated-clock charge as
//! the sim backend ([`SimCluster::charge_measured`]), while every
//! exchange also lands in a [`WireRecord`] — real wall seconds plus
//! per-executor scatter/gather byte splits — so `ddopt train --wire-out`
//! can put the cost model and the measured transport side by side.
//!
//! Failure semantics: per-task kernel errors reproduce the sim backend's
//! lowest-task-index-wins rule across executors (the superstep still
//! charges the clock); a misbehaving executor (protocol violation, fold
//! that fails validation) surfaces as a clean `Err` naming the executor
//! — the driver never hangs on a killed peer.
//!
//! **Fault recovery** (wire revision 3, negotiated via
//! [`wire::CAP_REJOIN`]): when a superstep *exchange* fails on an I/O
//! error — connection reset, EOF, exchange deadline — the driver tears
//! down every connection and rejoins the fleet: each executor is
//! re-dialed with capped exponential backoff (budget:
//! `DDOPT_DIST_REJOIN_TIMEOUT_SECS`, default 10s), sent a `Rejoin` frame
//! carrying the session token, and — if it lost its cached session (a
//! restarted process) — restaged from the Stage body saved at connect
//! time; a surviving executor acks `have_blocks` and skips the block
//! transfer.  ADMM factorizations are replayed when the session had
//! prepared them.  The failed superstep is then retried under the *same*
//! step id: every op is a pure function of driver-side state, so the
//! replay recomputes bit-identical segments and the run loses at most
//! one superstep per failure.  Reply *parse* errors stay fatal (retrying
//! a lying executor is not recovery), and without the negotiated
//! capability (a v2 peer, or `--dist-wire broadcast`) failures keep the
//! pre-v3 fail-fast behavior.  Recovery counters land in the superstep's
//! [`WireRecord`].

use super::ops;
use super::wire::{self, Tag};
use crate::cluster::{
    ClusterBackend, ClusterConfig, FoldAxis, FoldEntry, GridOp, Ownership, SimClock,
    SimCluster, WireMode,
};
use crate::data::{encode_block, Partitioned};
use crate::metrics::WireRecord;
use crate::runtime::StagedGrid;
use crate::util::bytes::{self, ByteReader};
use anyhow::{bail, Context, Result};
use std::io::{Read, Write};
use std::net::TcpStream;
use std::time::{Duration, Instant};

/// Default per-read socket timeout — generous for loopback supersteps,
/// small enough that a wedged executor fails the run instead of hanging
/// CI.  Workloads whose single superstep legitimately computes longer
/// (big datasets, few executor threads) raise it with
/// `DDOPT_DIST_READ_TIMEOUT_SECS` (`0` disables the timeout entirely).
/// The pipelined exchange applies the same budget as its whole-superstep
/// deadline.
const DEFAULT_READ_TIMEOUT_SECS: u64 = 60;

fn read_timeout() -> Option<Duration> {
    let secs = std::env::var("DDOPT_DIST_READ_TIMEOUT_SECS")
        .ok()
        .and_then(|v| v.trim().parse::<u64>().ok())
        .unwrap_or(DEFAULT_READ_TIMEOUT_SECS);
    (secs > 0).then(|| Duration::from_secs(secs))
}

/// Total budget for rejoining the fleet after an exchange failure —
/// reconnect attempts back off exponentially (50ms doubling, capped at
/// 1s) until an executor answers or this budget runs out.  `0` disables
/// recovery even when the capability was negotiated.
const DEFAULT_REJOIN_TIMEOUT_SECS: u64 = 10;

fn rejoin_timeout() -> Option<Duration> {
    let secs = std::env::var("DDOPT_DIST_REJOIN_TIMEOUT_SECS")
        .ok()
        .and_then(|v| v.trim().parse::<u64>().ok())
        .unwrap_or(DEFAULT_REJOIN_TIMEOUT_SECS);
    (secs > 0).then(|| Duration::from_secs(secs))
}

/// Superstep retry ceiling per `grid_exec` call: recovery guarantees "at
/// most one superstep lost per failure", and repeated failures of the
/// *same* superstep get this many chances before the run gives up.
const MAX_STEP_RETRIES: u32 = 2;

struct ExecConn {
    stream: TcpStream,
    addr: String,
    threads: usize,
}

/// The distributed cluster backend (see module docs).
pub struct DistCluster {
    /// Simulated clock + collective cost model + in-place combine — the
    /// exact code the sim backend runs, fed with measured durations.
    sim: SimCluster,
    conns: Vec<ExecConn>,
    /// Effective capability mask: offered by the driver's [`WireMode`],
    /// ANDed over every executor's ack.
    caps: u32,
    /// Cell→executor layout the whole session runs under.
    ownership: Ownership,
    wire_log: Vec<WireRecord>,
    step_id: u64,
    /// Shared full-payload Step body (broadcast mode).
    send_buf: Vec<u8>,
    /// Per-executor sliced Step bodies.
    send_bufs: Vec<Vec<u8>>,
    /// Per-executor reply bodies (pipelined gather).
    recv_bufs: Vec<Vec<u8>>,
    /// Control-plane reply scratch (handshake, acks, shutdown).
    recv_buf: Vec<u8>,
    /// Per-executor owned task lists of the superstep in flight.
    owned_lists: Vec<Vec<usize>>,
    /// Per-task measured durations of the superstep in flight.
    durs: Vec<f64>,
    seen: Vec<bool>,
    /// Tasks absorbed by a validated executor-side fold this superstep.
    folded_away: Vec<bool>,
    /// Validated folds of the last superstep, consumed by
    /// [`ClusterBackend::reduce_segments`].
    fold_log: Vec<FoldEntry>,
    /// Executor addresses in fleet order (rejoin re-dials these).
    addrs: Vec<String>,
    /// Capability mask the driver offered in `Hello` (re-offered on
    /// rejoin; the fleet caps stay the negotiated AND).
    offered: u32,
    /// Session token: lets an executor prove its cached blocks belong to
    /// *this* run when the driver rejoins after a failure.
    token: u64,
    /// The exact Stage body shipped to each executor at connect time,
    /// kept so a restarted executor can be restaged without the driver
    /// re-deriving anything.
    stage_bodies: Vec<Vec<u8>>,
    /// Whether `prepare_admm` ran this session (replayed on rejoin).
    admm_prepared: bool,
    /// Supersteps retried after a recovered exchange failure (run total).
    retries: u64,
    /// Rejoin handshakes performed across all recoveries (run total).
    rejoins: u64,
}

impl DistCluster {
    /// Connect to the executors, run the versioned capability handshake,
    /// and ship each its owned grid blocks under the negotiated
    /// [`Ownership`] layout — the same keying [`GridOp::owner`] uses per
    /// superstep.
    pub fn connect(
        config: ClusterConfig,
        addrs: &[String],
        part: &Partitioned,
    ) -> Result<DistCluster> {
        if addrs.is_empty() {
            bail!("--cluster dist wants at least one executor address");
        }
        let n_execs = addrs.len();
        let offered = match config.wire {
            WireMode::Sliced => wire::CAPS_SUPPORTED,
            WireMode::Broadcast => 0,
        };
        let t0 = Instant::now();
        let mut scatter = vec![0usize; n_execs];
        let mut gather = vec![0usize; n_execs];
        let mut recv_buf = Vec::new();
        let mut conns = Vec::with_capacity(n_execs);
        let mut caps = offered;
        // Session token: unique enough that an executor recycled by a
        // different run cannot satisfy this run's Rejoin with stale
        // blocks.  A v2 executor ignores the trailing token in Hello.
        let token = session_token(addrs);
        for (i, addr) in addrs.iter().enumerate() {
            let mut stream = TcpStream::connect(addr)
                .with_context(|| format!("connect to executor {i} at {addr}"))?;
            stream.set_nodelay(true).ok();
            stream
                .set_read_timeout(read_timeout())
                .with_context(|| format!("set read timeout on executor {i} at {addr}"))?;
            let mut hello = Vec::new();
            bytes::put_u32(&mut hello, wire::PROTO_MAGIC);
            bytes::put_u32(&mut hello, wire::PROTO_VERSION);
            bytes::put_u32(&mut hello, i as u32);
            bytes::put_u32(&mut hello, n_execs as u32);
            bytes::put_u32(&mut hello, offered);
            bytes::put_u64(&mut hello, token);
            scatter[i] += wire::write_frame(&mut stream, Tag::Hello, &hello)?;
            gather[i] += wire::expect_frame(&mut stream, &mut recv_buf, Tag::HelloAck)
                .with_context(|| format!("handshake with executor {i} at {addr}"))?;
            let mut r = ByteReader::new(&recv_buf);
            let magic = r.u32()?;
            let version = r.u32()?;
            if magic != wire::PROTO_MAGIC || version != wire::PROTO_VERSION {
                bail!(
                    "executor {i} at {addr} speaks protocol v{version} \
                     (driver v{}); rebuild the executor binary",
                    wire::PROTO_VERSION
                );
            }
            let threads = r.u32()? as usize;
            let acked = r.u32()?;
            if acked & !offered != 0 {
                bail!(
                    "executor {i} at {addr} acked capabilities {acked:#x} \
                     it was never offered ({offered:#x})"
                );
            }
            // the fleet runs at the AND of every ack: one stale executor
            // downgrades the session instead of breaking it
            caps &= acked;
            conns.push(ExecConn { stream, addr: addr.clone(), threads });
        }
        let ownership = if caps & wire::CAP_CONTIG_FOLD != 0 {
            Ownership::Contiguous
        } else {
            Ownership::RoundRobin
        };

        // stage: metadata to everyone, each block to its one owner —
        // pipelined (all frames written before any ack is awaited).  The
        // bodies are kept verbatim: a rejoin after an executor restart
        // re-ships exactly these bytes, no re-derivation.
        let mut stage_bodies: Vec<Vec<u8>> = Vec::with_capacity(n_execs);
        for (i, conn) in conns.iter_mut().enumerate() {
            let mut body = Vec::new();
            bytes::put_u8(&mut body, ownership.to_u8());
            part.encode_meta(&mut body);
            let owned: Vec<usize> = (0..part.grid.k())
                .filter(|&cell| ownership.owner(cell, part.grid.k(), n_execs) == i)
                .collect();
            bytes::put_u32(&mut body, owned.len() as u32);
            for &cell in &owned {
                bytes::put_usize(&mut body, cell);
                encode_block(&part.blocks[cell], &mut body);
            }
            scatter[i] += wire::write_frame(&mut conn.stream, Tag::Stage, &body)
                .with_context(|| format!("stage blocks on executor {i} at {}", conn.addr))?;
            stage_bodies.push(body);
        }
        for (i, conn) in conns.iter_mut().enumerate() {
            gather[i] += wire::expect_frame(&mut conn.stream, &mut recv_buf, Tag::StageAck)
                .with_context(|| format!("stage ack from executor {i} at {}", conn.addr))?;
        }

        let wire_log = vec![WireRecord {
            step: 0,
            op: "stage",
            wall_secs: t0.elapsed().as_secs_f64(),
            bytes_out: scatter.iter().sum(),
            bytes_in: gather.iter().sum(),
            sim_secs: 0.0,
            scatter,
            gather,
            retries: 0,
            rejoins: 0,
        }];
        Ok(DistCluster {
            sim: SimCluster::new(config),
            conns,
            caps,
            ownership,
            wire_log,
            step_id: 0,
            send_buf: Vec::new(),
            send_bufs: vec![Vec::new(); n_execs],
            recv_bufs: vec![Vec::new(); n_execs],
            recv_buf,
            owned_lists: vec![Vec::new(); n_execs],
            durs: Vec::new(),
            seen: Vec::new(),
            folded_away: Vec::new(),
            fold_log: Vec::new(),
            addrs: addrs.to_vec(),
            offered,
            token,
            stage_bodies,
            admm_prepared: false,
            retries: 0,
            rejoins: 0,
        })
    }

    /// Total executor worker threads (display only).
    pub fn executor_threads(&self) -> usize {
        self.conns.iter().map(|c| c.threads).sum()
    }

    pub fn n_executors(&self) -> usize {
        self.conns.len()
    }

    /// The negotiated capability mask (AND over every executor's ack).
    pub fn capabilities(&self) -> u32 {
        self.caps
    }

    /// The session's cell→executor layout.
    pub fn ownership(&self) -> Ownership {
        self.ownership
    }
}

impl ClusterBackend for DistCluster {
    fn label(&self) -> &'static str {
        "dist"
    }

    fn threads(&self) -> usize {
        self.executor_threads().max(1)
    }

    fn warm_up(&mut self) {
        // executors spawned their pools at staging time; nothing to do
    }

    fn prepare(&mut self, _staged: &StagedGrid<'_>) -> Result<()> {
        // per-worker scratch lives executor-side, sized when blocks land
        Ok(())
    }

    fn prepare_admm(&mut self, _staged: &StagedGrid<'_>) -> Result<()> {
        let t0 = Instant::now();
        // consume a step ordinal so wire records stay uniquely keyed by
        // `step` (staging alone owns 0); superstep records simply skip
        // this number
        self.step_id += 1;
        let n = self.conns.len();
        let mut scatter = vec![0usize; n];
        let mut gather = vec![0usize; n];
        // pipelined: every request is on the wire before the first —
        // possibly expensive — factorization is awaited, so the fleet
        // factors in parallel instead of N serialized round-trips
        for (i, conn) in self.conns.iter_mut().enumerate() {
            scatter[i] += wire::write_frame(&mut conn.stream, Tag::PrepareAdmm, &[])
                .with_context(|| {
                    format!("request admm factorization on executor {i} at {}", conn.addr)
                })?;
        }
        for (i, conn) in self.conns.iter_mut().enumerate() {
            gather[i] +=
                wire::expect_frame(&mut conn.stream, &mut self.recv_buf, Tag::PrepareAdmmAck)
                    .with_context(|| {
                        format!("admm factorization on executor {i} at {}", conn.addr)
                    })?;
        }
        self.admm_prepared = true;
        self.wire_log.push(WireRecord {
            step: self.step_id as usize,
            op: "prepare-admm",
            wall_secs: t0.elapsed().as_secs_f64(),
            bytes_out: scatter.iter().sum(),
            bytes_in: gather.iter().sum(),
            sim_secs: 0.0,
            scatter,
            gather,
            retries: 0,
            rejoins: 0,
        });
        Ok(())
    }

    fn grid_exec(
        &mut self,
        staged: &StagedGrid<'_>,
        op: GridOp<'_>,
        out: &mut [f32],
        out2: &mut [f32],
    ) -> Result<()> {
        let part = staged.part;
        let n_tasks = op.n_tasks(part);
        self.fold_log.clear();
        if n_tasks == 0 {
            return Ok(());
        }
        debug_assert!(out.len() >= op.out_len(part));
        debug_assert!(out2.len() >= op.out2_len(part));
        let t0 = Instant::now();
        self.step_id += 1;
        let step_id = self.step_id;
        let n_execs = self.conns.len();
        let sliced = self.caps & wire::CAP_SLICED != 0;
        let fold = self.caps & wire::CAP_CONTIG_FOLD != 0 && op.fold_axis() != FoldAxis::None;
        let flags = if sliced { wire::STEP_FLAG_SLICED } else { 0 }
            | if fold { wire::STEP_FLAG_FOLD } else { 0 };

        // per-executor owned task lists (ascending by construction)
        for list in self.owned_lists.iter_mut() {
            list.clear();
        }
        for task in 0..n_tasks {
            self.owned_lists[op.owner(part, task, n_execs, self.ownership)].push(task);
        }

        // encode: one shared body (broadcast) or one per executor (sliced)
        if sliced {
            for (e, buf) in self.send_bufs.iter_mut().enumerate() {
                buf.clear();
                bytes::put_u64(buf, step_id);
                bytes::put_u8(buf, flags);
                ops::encode_op_sliced(&op, part, &self.owned_lists[e], buf);
            }
        } else {
            self.send_buf.clear();
            bytes::put_u64(&mut self.send_buf, step_id);
            bytes::put_u8(&mut self.send_buf, flags);
            ops::encode_op(&op, &mut self.send_buf);
        }
        let bodies: Vec<&[u8]> = if sliced {
            self.send_bufs.iter().map(|b| b.as_slice()).collect()
        } else {
            vec![self.send_buf.as_slice(); n_execs]
        };

        // pipelined scatter + readiness-ordered gather, with fault
        // recovery: an I/O failure (dead executor, exchange deadline)
        // rejoins the fleet and replays the superstep under the same
        // step id — the op is a pure function of driver-side state, so
        // the retry recomputes bit-identical segments.  Reply *parse*
        // errors below stay fatal: retrying a lying executor is not
        // recovery.
        let mut step_retries = 0u64;
        let mut step_rejoins = 0u64;
        let exchange = loop {
            match pipelined_exchange(&mut self.conns, &bodies, &mut self.recv_bufs, step_id) {
                Ok(ex) => break ex,
                Err(e) => {
                    let recoverable = self.caps & wire::CAP_REJOIN != 0
                        && step_retries < MAX_STEP_RETRIES as u64
                        && rejoin_timeout().is_some();
                    if !recoverable {
                        return Err(e);
                    }
                    let mut got = 0u64;
                    recover_fleet(
                        &mut self.conns,
                        &self.addrs,
                        self.token,
                        self.offered,
                        self.caps,
                        &self.stage_bodies,
                        self.admm_prepared,
                        step_id,
                        &mut self.recv_buf,
                        &mut got,
                    )
                    .map_err(|re| e.context(format!("fleet rejoin also failed: {re:#}")))?;
                    step_retries += 1;
                    step_rejoins += got;
                }
            }
        };
        self.retries += step_retries;
        self.rejoins += step_rejoins;

        // parse replies in arrival order: every task's duration exactly
        // once, result segments (or validated folds) into the slabs
        self.durs.clear();
        self.durs.resize(n_tasks, 0.0);
        self.seen.clear();
        self.seen.resize(n_tasks, false);
        self.folded_away.clear();
        self.folded_away.resize(n_tasks, false);
        let mut first_err: Option<(usize, anyhow::Error)> = None;
        for &i in &exchange.arrival {
            let conn = &self.conns[i];
            let tag = Tag::from_u8(exchange.tags[i])
                .with_context(|| format!("reply tag from executor {i} at {}", conn.addr))?;
            match tag {
                Tag::StepResult => {}
                Tag::Fatal => {
                    let msg = ByteReader::new(&self.recv_bufs[i]).str().unwrap_or_default();
                    bail!("executor {i} at {} failed: {msg}", conn.addr);
                }
                other => bail!(
                    "executor {i} at {}: wanted StepResult, got {other:?}",
                    conn.addr
                ),
            }
            let mut r = ByteReader::new(&self.recv_bufs[i]);
            let sid = r.u64()?;
            if sid != step_id {
                bail!(
                    "executor {i} at {} answered superstep {sid}, expected {step_id}",
                    conn.addr
                );
            }
            let count = r.u32()? as usize;
            for _ in 0..count {
                let task = r.u32()? as usize;
                if task >= n_tasks {
                    bail!("executor {i}: task {task} out of range ({n_tasks} tasks)");
                }
                if self.seen[task] {
                    bail!("executor {i}: task {task} reported twice");
                }
                self.seen[task] = true;
                self.durs[task] = r.f64()?;
                let status = r.u8()?;
                match status {
                    0 => {
                        let folded = r.u32()? as usize;
                        if folded > 1 {
                            validate_fold(
                                &op,
                                part,
                                task,
                                folded,
                                i,
                                n_execs,
                                self.ownership,
                                fold,
                                n_tasks,
                                &mut self.folded_away,
                                &mut self.fold_log,
                            )?;
                        } else if folded == 0 {
                            bail!("executor {i}: task {task} claims a zero-leaf fold");
                        }
                        let (s, l) = op.out_span(part, task);
                        read_segment(&mut r, &mut out[s..s + l], task, "out")?;
                        let (s2, l2) = op.out2_span(part, task);
                        read_segment(&mut r, &mut out2[s2..s2 + l2], task, "out2")?;
                    }
                    1 => {
                        let msg = r.str()?;
                        let err = anyhow::anyhow!("partition task {task}: {msg}");
                        if first_err.as_ref().map(|(t, _)| task < *t).unwrap_or(true) {
                            first_err = Some((task, err));
                        }
                    }
                    2 => {
                        // absorbed by a fold: its root must have preceded
                        // it in this same reply (owned lists ascend, the
                        // root is a block's lowest task)
                        if !self.folded_away[task] {
                            bail!(
                                "executor {i}: task {task} marked fold-absorbed \
                                 without a preceding fold root"
                            );
                        }
                    }
                    other => bail!("executor {i}: task {task} has unknown status {other}"),
                }
            }
        }
        if let Some(missing) = self.seen.iter().position(|&s| !s) {
            bail!(
                "superstep {step_id}: no executor owned task {missing} \
                 ({n_execs} executors, {n_tasks} tasks)"
            );
        }

        // the simulated clock advances exactly like the sim backend's,
        // fed with the *measured* executor durations (or the Fixed cost)
        let sim_before = self.sim.clock.now();
        self.sim.charge_measured(&self.durs, op.tolerant());
        self.wire_log.push(WireRecord {
            step: step_id as usize,
            op: op.name(),
            wall_secs: t0.elapsed().as_secs_f64(),
            bytes_out: exchange.scatter.iter().sum(),
            bytes_in: exchange.gather.iter().sum(),
            sim_secs: self.sim.clock.now() - sim_before,
            scatter: exchange.scatter,
            gather: exchange.gather,
            retries: step_retries as usize,
            rejoins: step_rejoins as usize,
        });
        match first_err {
            Some((_, e)) => Err(e),
            None => Ok(()),
        }
    }

    fn reduce_segments(
        &mut self,
        slab: &mut [f32],
        base: usize,
        stride: usize,
        count: usize,
        len: usize,
    ) {
        // results were already gathered to the driver; the combine (and
        // its comm charge) is bit-identical to the sim backend's, with
        // pairs the executors pre-folded (logged during the gather)
        // skipped but still charged
        self.sim
            .reduce_segments_folded(slab, base, stride, count, len, &self.fold_log);
    }

    fn reduce_cost(&mut self, leaves: usize, bytes_per_leaf: usize) {
        self.sim.reduce_cost(leaves, bytes_per_leaf);
    }

    fn broadcast_cost(&mut self, bytes: usize, fanout: usize) {
        self.sim.broadcast_cost(bytes, fanout);
    }

    fn clock(&self) -> &SimClock {
        &self.sim.clock
    }

    fn clock_mut(&mut self) -> &mut SimClock {
        &mut self.sim.clock
    }

    fn host_secs(&self) -> f64 {
        self.sim.host_secs()
    }

    fn take_wire_log(&mut self) -> Vec<WireRecord> {
        std::mem::take(&mut self.wire_log)
    }

    fn shutdown(&mut self) -> Result<()> {
        // orderly release: executors return to their accept loop; errors
        // are ignored (the executor may already be gone, which is fine)
        for conn in &mut self.conns {
            if wire::write_frame(&mut conn.stream, Tag::Shutdown, &[]).is_ok() {
                let _ = wire::expect_frame(&mut conn.stream, &mut self.recv_buf, Tag::Bye);
            }
        }
        self.conns.clear();
        Ok(())
    }
}

/// Outcome of one pipelined Step exchange.
struct Exchange {
    /// Bytes written per executor (header + body).
    scatter: Vec<usize>,
    /// Bytes read per executor (header + body).
    gather: Vec<usize>,
    /// Raw reply tag byte per executor (validated by the parser).
    tags: Vec<u8>,
    /// Executor indices in reply-completion order.
    arrival: Vec<usize>,
}

/// Per-connection receive progress of the pipelined exchange.
#[derive(Clone, Copy, Default)]
struct RecvState {
    header: [u8; 5],
    header_got: usize,
    body_len: usize,
    body_got: usize,
    done: bool,
}

/// Write every executor's Step frame and read every reply with
/// nonblocking I/O: no read waits on an unfinished write, and replies
/// complete in whatever order executors finish.  Blocking mode is
/// restored on every exit path (the control-plane frames — acks,
/// shutdown — use plain blocking I/O).
fn pipelined_exchange(
    conns: &mut [ExecConn],
    bodies: &[&[u8]],
    recv_bufs: &mut [Vec<u8>],
    step_id: u64,
) -> Result<Exchange> {
    let n = conns.len();
    for conn in conns.iter() {
        conn.stream
            .set_nonblocking(true)
            .with_context(|| format!("nonblocking mode on executor at {}", conn.addr))?;
    }
    let result = exchange_inner(conns, bodies, recv_bufs, step_id);
    // failing to restore blocking mode would make the *next*
    // control-plane read spuriously fail with WouldBlock and blame the
    // wrong layer — surface it here, against the right executor, but
    // never mask the exchange's own error
    let mut restore: Result<()> = Ok(());
    for conn in conns.iter() {
        if let Err(e) = conn.stream.set_nonblocking(false) {
            if restore.is_ok() {
                restore = Err(e).with_context(|| {
                    format!("restore blocking mode on executor at {}", conn.addr)
                });
            }
        }
    }
    debug_assert_eq!(bodies.len(), n);
    match result {
        Err(e) => Err(e),
        Ok(ex) => restore.map(|()| ex),
    }
}

fn exchange_inner(
    conns: &mut [ExecConn],
    bodies: &[&[u8]],
    recv_bufs: &mut [Vec<u8>],
    step_id: u64,
) -> Result<Exchange> {
    let n = conns.len();
    let headers: Vec<[u8; 5]> = bodies
        .iter()
        .map(|b| {
            let mut h = [0u8; 5];
            h[..4].copy_from_slice(&(b.len() as u32).to_le_bytes());
            h[4] = Tag::Step as u8;
            h
        })
        .collect();
    let mut sent = vec![0usize; n];
    let mut recv = vec![RecvState::default(); n];
    let mut arrival = Vec::with_capacity(n);
    // liveness deadline, not a whole-exchange cap: re-armed on every
    // sweep that moves bytes, so a reply that trickles in slowly but
    // steadily is never killed as "wedged"
    let budget = read_timeout();
    let mut deadline = budget.map(|t| Instant::now() + t);
    let mut idle_sweeps = 0usize;
    loop {
        let mut progressed = false;
        let mut all_done = true;
        for i in 0..n {
            let total = 5 + bodies[i].len();
            // scatter: push as much of this executor's frame as the
            // socket accepts, then move on — never block on one peer
            while sent[i] < total {
                let chunk: &[u8] = if sent[i] < 5 {
                    &headers[i][sent[i]..]
                } else {
                    &bodies[i][sent[i] - 5..]
                };
                match conns[i].stream.write(chunk) {
                    Ok(0) => bail!(
                        "executor {i} at {} closed the connection during superstep {step_id}",
                        conns[i].addr
                    ),
                    Ok(k) => {
                        sent[i] += k;
                        progressed = true;
                    }
                    Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => break,
                    Err(e) if e.kind() == std::io::ErrorKind::Interrupted => {}
                    Err(e) => {
                        return Err(e).with_context(|| {
                            format!(
                                "send superstep {step_id} to executor {i} at {}",
                                conns[i].addr
                            )
                        })
                    }
                }
            }
            // gather: drain whatever reply bytes have arrived
            progressed |= read_some(&mut conns[i], i, &mut recv[i], &mut recv_bufs[i])
                .with_context(|| {
                    format!(
                        "superstep {step_id} reply from executor {i} at {} \
                         (killed or wedged executor?)",
                        conns[i].addr
                    )
                })?;
            if recv[i].done && arrival.iter().all(|&a: &usize| a != i) {
                arrival.push(i);
            }
            all_done &= sent[i] == total && recv[i].done;
        }
        if all_done {
            break;
        }
        if progressed {
            idle_sweeps = 0;
            deadline = budget.map(|t| Instant::now() + t);
            continue;
        }
        if let Some(d) = deadline {
            if Instant::now() > d {
                let totals: Vec<usize> = bodies.iter().map(|b| 5 + b.len()).collect();
                let done: Vec<bool> = recv.iter().map(|s| s.done).collect();
                let addrs: Vec<&str> = conns.iter().map(|c| c.addr.as_str()).collect();
                bail!(
                    "superstep {step_id} made no progress for {:?}: {} \
                     (killed or wedged executor?)",
                    budget.unwrap_or_default(),
                    describe_stall(&sent, &totals, &done, &addrs)
                );
            }
        }
        // spin briefly for loopback latency, then back off so executor
        // threads on the same host get the cores during long supersteps
        idle_sweeps += 1;
        if idle_sweeps < 200 {
            std::thread::yield_now();
        } else {
            std::thread::sleep(Duration::from_micros(100));
        }
    }
    Ok(Exchange {
        scatter: bodies.iter().map(|b| 5 + b.len()).collect(),
        gather: recv.iter().map(|s| 5 + s.body_len).collect(),
        tags: recv.iter().map(|s| s.header[4]).collect(),
        arrival,
    })
}

/// Nonblocking read step for one connection: header, then body.  Returns
/// whether any bytes moved.
fn read_some(
    conn: &mut ExecConn,
    i: usize,
    st: &mut RecvState,
    body: &mut Vec<u8>,
) -> Result<bool> {
    let mut progressed = false;
    while !st.done {
        if st.header_got < 5 {
            match conn.stream.read(&mut st.header[st.header_got..]) {
                Ok(0) => bail!("executor {i} closed the connection mid-reply"),
                Ok(k) => {
                    st.header_got += k;
                    progressed = true;
                    if st.header_got == 5 {
                        let len =
                            u32::from_le_bytes(st.header[..4].try_into().unwrap()) as usize;
                        if len > wire::MAX_FRAME {
                            bail!(
                                "executor {i}: incoming frame of {len} bytes exceeds \
                                 MAX_FRAME (corrupt stream?)"
                            );
                        }
                        st.body_len = len;
                        body.clear();
                        body.resize(len, 0);
                    }
                }
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => return Ok(progressed),
                Err(e) if e.kind() == std::io::ErrorKind::Interrupted => {}
                Err(e) => return Err(e.into()),
            }
        } else if st.body_got < st.body_len {
            match conn.stream.read(&mut body[st.body_got..]) {
                Ok(0) => bail!("executor {i} closed the connection mid-reply"),
                Ok(k) => {
                    st.body_got += k;
                    progressed = true;
                }
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => return Ok(progressed),
                Err(e) if e.kind() == std::io::ErrorKind::Interrupted => {}
                Err(e) => return Err(e.into()),
            }
        } else {
            st.done = true;
        }
    }
    Ok(progressed)
}

/// Validate one claimed executor-side fold against the op's combine-tree
/// geometry, mark its absorbed tasks, and log it for
/// [`SimCluster::reduce_segments_folded`].
#[allow(clippy::too_many_arguments)]
fn validate_fold(
    op: &GridOp<'_>,
    part: &Partitioned,
    task: usize,
    folded: usize,
    exec: usize,
    n_execs: usize,
    ownership: Ownership,
    fold_requested: bool,
    n_tasks: usize,
    folded_away: &mut [bool],
    fold_log: &mut Vec<FoldEntry>,
) -> Result<()> {
    if !fold_requested {
        bail!("executor {exec}: task {task} folded {folded} leaves, but folding was not requested");
    }
    let g = op
        .fold_group(part, task)
        .ok_or_else(|| anyhow::anyhow!("executor {exec}: task {task} folded a fold-free op"))?;
    if !folded.is_power_of_two() || g.leaf % folded != 0 || g.leaf + folded > g.count {
        bail!(
            "executor {exec}: task {task} claims a misaligned fold \
             ({folded} leaves at leaf {} of {})",
            g.leaf,
            g.count
        );
    }
    for k in 1..folded {
        let t2 = task + k * g.task_stride;
        if t2 >= n_tasks {
            bail!("executor {exec}: fold at task {task} spills past task {t2}");
        }
        if op.owner(part, t2, n_execs, ownership) != exec {
            bail!(
                "executor {exec}: fold at task {task} absorbs task {t2} it does not own"
            );
        }
        if folded_away[t2] {
            bail!("executor {exec}: task {t2} absorbed by two folds");
        }
        folded_away[t2] = true;
    }
    fold_log.push(FoldEntry {
        base: g.base,
        stride: g.stride,
        count: g.count,
        len: g.len,
        leaf: g.leaf,
        folded,
    });
    Ok(())
}

/// Name the peer(s) actually responsible for a stalled exchange: an
/// executor whose scatter frame never drained is reported separately
/// from one whose reply never finished, so the blame lands on the right
/// side of the pipe (the old code blamed executor 0 whenever every
/// *reply* happened to be done but a send was stuck).
fn describe_stall(sent: &[usize], totals: &[usize], done: &[bool], addrs: &[&str]) -> String {
    let unsent: Vec<String> = (0..sent.len())
        .filter(|&i| sent[i] < totals[i])
        .map(|i| format!("{i} at {} ({}/{} bytes sent)", addrs[i], sent[i], totals[i]))
        .collect();
    let missing: Vec<String> = (0..done.len())
        .filter(|&i| sent[i] >= totals[i] && !done[i])
        .map(|i| format!("{i} at {}", addrs[i]))
        .collect();
    let mut parts = Vec::new();
    if !unsent.is_empty() {
        parts.push(format!("scatter never drained to executor {}", unsent.join(", ")));
    }
    if !missing.is_empty() {
        parts.push(format!("no reply from executor {}", missing.join(", ")));
    }
    if parts.is_empty() {
        // unreachable if the caller checked all_done, kept for safety
        parts.push("all scatters drained and all replies complete".into());
    }
    parts.join("; ")
}

/// A cheap unique-enough session id: FNV-1a over the wall clock, the
/// driver pid, and the fleet's addresses.  Lets an executor prove its
/// cached blocks belong to *this* run when the driver rejoins — without
/// threading any RNG state through the transport.
fn session_token(addrs: &[String]) -> u64 {
    const FNV_PRIME: u64 = 0x0000_0100_0000_01b3;
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    let nanos = std::time::SystemTime::now()
        .duration_since(std::time::UNIX_EPOCH)
        .map(|d| d.as_nanos())
        .unwrap_or(0);
    for b in nanos.to_le_bytes() {
        h = (h ^ b as u64).wrapping_mul(FNV_PRIME);
    }
    for b in std::process::id().to_le_bytes() {
        h = (h ^ b as u64).wrapping_mul(FNV_PRIME);
    }
    for a in addrs {
        for &b in a.as_bytes() {
            h = (h ^ b as u64).wrapping_mul(FNV_PRIME);
        }
        h = (h ^ 0xff).wrapping_mul(FNV_PRIME);
    }
    h
}

/// Tear down and rebuild every executor connection after a failed
/// exchange (free function rather than a method: the caller still holds
/// immutable borrows of the Step bodies in `send_buf`/`send_bufs`).
///
/// Each executor is re-dialed with capped exponential backoff within the
/// `DDOPT_DIST_REJOIN_TIMEOUT_SECS` budget and sent a `Rejoin` frame
/// carrying the session token; a survivor acks `have_blocks` and skips
/// the block transfer, a restarted process is restaged from the saved
/// Stage body.  ADMM factorizations are replayed if the session had
/// prepared them.  `rejoins` counts completed handshakes.
#[allow(clippy::too_many_arguments)]
fn recover_fleet(
    conns: &mut Vec<ExecConn>,
    addrs: &[String],
    token: u64,
    offered: u32,
    session_caps: u32,
    stage_bodies: &[Vec<u8>],
    admm_prepared: bool,
    step_id: u64,
    recv_buf: &mut Vec<u8>,
    rejoins: &mut u64,
) -> Result<()> {
    let budget = rejoin_timeout()
        .ok_or_else(|| anyhow::anyhow!("rejoin disabled (DDOPT_DIST_REJOIN_TIMEOUT_SECS=0)"))?;
    let deadline = Instant::now() + budget;
    // drop every old connection first: executors notice the hangup and
    // return to their accept loop, keeping the cached session
    conns.clear();
    let n_execs = addrs.len();
    for (i, addr) in addrs.iter().enumerate() {
        let mut delay = Duration::from_millis(50);
        let conn = loop {
            match rejoin_one(
                addr,
                i,
                n_execs,
                token,
                offered,
                session_caps,
                &stage_bodies[i],
                step_id,
                recv_buf,
            ) {
                Ok(c) => break c,
                Err(e) => {
                    if Instant::now() + delay > deadline {
                        return Err(e).with_context(|| {
                            format!(
                                "rejoin executor {i} at {addr} within {budget:?} \
                                 (raise DDOPT_DIST_REJOIN_TIMEOUT_SECS?)"
                            )
                        });
                    }
                    std::thread::sleep(delay);
                    delay = (delay * 2).min(Duration::from_secs(1));
                }
            }
        };
        *rejoins += 1;
        conns.push(conn);
    }
    if admm_prepared {
        // replay factorizations, pipelined like prepare_admm
        for (i, conn) in conns.iter_mut().enumerate() {
            wire::write_frame(&mut conn.stream, Tag::PrepareAdmm, &[]).with_context(|| {
                format!("replay admm factorization on executor {i} at {}", conn.addr)
            })?;
        }
        for (i, conn) in conns.iter_mut().enumerate() {
            wire::expect_frame(&mut conn.stream, recv_buf, Tag::PrepareAdmmAck).with_context(
                || format!("replay admm factorization on executor {i} at {}", conn.addr),
            )?;
        }
    }
    Ok(())
}

/// One reconnect + `Rejoin` handshake (+ restage when the executor lost
/// its cached session).
#[allow(clippy::too_many_arguments)]
fn rejoin_one(
    addr: &str,
    i: usize,
    n_execs: usize,
    token: u64,
    offered: u32,
    session_caps: u32,
    stage_body: &[u8],
    step_id: u64,
    recv_buf: &mut Vec<u8>,
) -> Result<ExecConn> {
    let mut stream = TcpStream::connect(addr)
        .with_context(|| format!("reconnect to executor {i} at {addr}"))?;
    stream.set_nodelay(true).ok();
    stream
        .set_read_timeout(read_timeout())
        .with_context(|| format!("set read timeout on executor {i} at {addr}"))?;
    let mut body = Vec::new();
    bytes::put_u32(&mut body, wire::PROTO_MAGIC);
    bytes::put_u64(&mut body, token);
    bytes::put_u32(&mut body, i as u32);
    bytes::put_u32(&mut body, n_execs as u32);
    bytes::put_u64(&mut body, step_id);
    bytes::put_u32(&mut body, offered);
    wire::write_frame(&mut stream, Tag::Rejoin, &body)?;
    wire::expect_frame(&mut stream, recv_buf, Tag::RejoinAck)
        .with_context(|| format!("rejoin handshake with executor {i} at {addr}"))?;
    let mut r = ByteReader::new(recv_buf);
    let magic = r.u32()?;
    if magic != wire::PROTO_MAGIC {
        bail!("executor {i} at {addr}: bad magic in RejoinAck");
    }
    let threads = r.u32()? as usize;
    let acked = r.u32()?;
    let have_blocks = r.u8()?;
    if acked & !offered != 0 {
        bail!(
            "executor {i} at {addr} acked capabilities {acked:#x} \
             it was never offered ({offered:#x})"
        );
    }
    if acked & session_caps != session_caps {
        // the run already committed to the negotiated AND; a replacement
        // executor that implements less cannot replay its supersteps
        bail!(
            "executor {i} at {addr} rejoined with capabilities {acked:#x}, \
             session needs {session_caps:#x}"
        );
    }
    if have_blocks == 0 {
        wire::write_frame(&mut stream, Tag::Stage, stage_body)
            .with_context(|| format!("restage blocks on executor {i} at {addr}"))?;
        wire::expect_frame(&mut stream, recv_buf, Tag::StageAck)
            .with_context(|| format!("restage ack from executor {i} at {addr}"))?;
    }
    Ok(ExecConn { stream, addr: addr.to_string(), threads })
}

/// Read one length-prefixed f32 array straight into a slab segment,
/// insisting the length matches the span exactly.
fn read_segment(
    r: &mut ByteReader<'_>,
    dst: &mut [f32],
    task: usize,
    what: &str,
) -> Result<()> {
    let n = r.u64()? as usize;
    if n != dst.len() {
        bail!(
            "task {task}: {what} segment length {n} != expected {}",
            dst.len()
        );
    }
    r.fill_f32s(dst)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stall_blame_names_missing_gather_not_executor_zero() {
        // executor 0 and 1 replied; 2 is the one actually wedged
        let msg = describe_stall(
            &[10, 10, 10],
            &[10, 10, 10],
            &[true, true, false],
            &["a:1", "b:2", "c:3"],
        );
        assert!(msg.contains("no reply from executor 2 at c:3"), "{msg}");
        assert!(!msg.contains("executor 0"), "{msg}");
        assert!(!msg.contains("scatter"), "{msg}");
    }

    #[test]
    fn stall_blame_reports_stuck_send_even_when_replies_done() {
        // the pre-fix fallback blamed executor 0's reply here, although
        // every reply is done and the real problem is 1's stuck scatter
        let msg = describe_stall(
            &[10, 4, 10],
            &[10, 10, 10],
            &[true, false, true],
            &["a:1", "b:2", "c:3"],
        );
        assert!(
            msg.contains("scatter never drained to executor 1 at b:2 (4/10 bytes sent)"),
            "{msg}"
        );
        // an executor whose scatter never drained obviously has no
        // reply; it must not be double-reported on the gather side
        assert!(!msg.contains("no reply"), "{msg}");
    }

    #[test]
    fn stall_blame_separates_send_and_reply_laggards() {
        let msg = describe_stall(
            &[3, 10],
            &[10, 10],
            &[false, false],
            &["a:1", "b:2"],
        );
        assert!(msg.contains("scatter never drained to executor 0"), "{msg}");
        assert!(msg.contains("no reply from executor 1 at b:2"), "{msg}");
    }

    #[test]
    fn session_tokens_differ_across_calls() {
        let addrs = vec!["127.0.0.1:7001".to_string()];
        let a = session_token(&addrs);
        std::thread::sleep(Duration::from_millis(2));
        let b = session_token(&addrs);
        assert_ne!(a, b);
    }
}
