//! The real multi-process distributed runtime: driver and executor
//! processes speaking the superstep contract over TCP.
//!
//! This is the subsystem that takes the reproduction from "simulated
//! cluster, real math" to "real cluster, real math".  The paper's
//! algorithms run *unchanged*: coordinators describe each superstep as a
//! typed [`GridOp`](super::GridOp) descriptor, and this module merely
//! swaps where the descriptor executes —
//!
//! * [`executor`] — `ddopt executor --bind ADDR`: a long-lived server
//!   process that receives its assigned grid blocks once at startup
//!   (binary-framed, [`crate::data::encode_block`]), caches them staged
//!   on the native backend, then loops executing superstep ops against
//!   its local [`WorkerPool`](super::WorkerPool);
//! * [`driver_net`] — [`DistCluster`], the driver-side
//!   [`ClusterBackend`](super::ClusterBackend): connects to N executors,
//!   ships each superstep's op descriptor + small state payloads
//!   (iterates, index streams — never the training data), gathers the
//!   per-task results into the coordinator's slabs, and combines them
//!   with exactly [`tree_aggregate`](super::comm::tree_aggregate)'s
//!   pairing order so the final weights are bit-identical to the sim
//!   backend at the same seed;
//! * [`wire`] — the length-prefixed binary frame codec, message tags,
//!   and the versioned handshake;
//! * [`ops`] — ser/de between [`GridOp`](super::GridOp) borrows and wire
//!   bytes (an [`ops::OpBuf`] owns the decoded payloads executor-side).
//!
//! Two clocks run side by side: the executors report *real* per-task
//! compute seconds, which feed the same scenario/LPT simulated-clock
//! accounting as the sim backend, while [`DistCluster`] additionally
//! records real wall-clock and bytes-on-wire per superstep
//! ([`crate::metrics::WireRecord`]) so one report can compare the cost
//! model against measured transport.  Loopback TCP on one host today;
//! the protocol is host-agnostic, so multi-host is a deploy question,
//! not a code one.
//!
//! The runtime is self-healing and *elastic* (wire revision 4):
//! executors cache their staged session across connections
//! ([`wire::CAP_REJOIN`]), and on a mid-superstep I/O failure the driver
//! reconnects with backoff, rejoins (restaging a restarted executor from
//! the saved Stage bytes), and replays the failed superstep —
//! determinism makes the replay bit-identical, so at most one superstep
//! of progress is lost per failure.  When an executor misses the rejoin
//! budget entirely, the driver *degrades* instead of dying: it rewrites
//! the explicit [`CellMap`](super::CellMap) placement
//! ([`wire::CAP_ELASTIC`]), restages the orphaned blocks onto the
//! survivors from its cached Stage bytes, and continues bit-identically
//! on N−1 executors — rebalancing back the moment the peer returns.
//! With `--dist-spec`, the driver additionally re-executes a *lagging*
//! executor's tasks speculatively on an idle peer
//! ([`wire::CAP_SPEC`]), first-valid-result-wins.  The [`chaos`] module
//! is the adversary: a seeded fault-injection shim (executor `--chaos`
//! or the `ddopt chaosproxy` forwarder) that makes all of the above
//! testable deterministically.  See the fault-recovery notes in
//! [`driver_net`].

pub mod chaos;
pub mod driver_net;
pub mod executor;
pub mod ops;
pub mod wire;

pub use chaos::{chaosproxy, ChaosConfig, ChaosState};
pub use driver_net::DistCluster;
pub use executor::{serve, serve_listener, serve_listener_with, ExecutorConfig};
