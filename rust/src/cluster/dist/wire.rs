//! The length-prefixed binary wire protocol between the train driver and
//! its executor processes.
//!
//! Every message is one frame:
//!
//! ```text
//! [ body_len: u32 LE ][ tag: u8 ][ body: body_len bytes ]
//! ```
//!
//! Message flow (tags in parentheses):
//!
//! | driver → executor        | executor → driver        | body |
//! |--------------------------|--------------------------|------|
//! | `Hello` (1)              |                          | magic, proto version, executor index, executor count, offered capability bits |
//! |                          | `HelloAck` (2)           | magic, proto version, worker threads, accepted capability bits; *v5* — trailing monotonic tick (u64 ns) |
//! | `Stage` (3)              |                          | ownership mode byte + partition metadata + the executor's owned blocks |
//! |                          | `StageAck` (4)           | — |
//! | `PrepareAdmm` (5)        |                          | — (factor your cached blocks, off the clock) |
//! |                          | `PrepareAdmmAck` (6)     | — |
//! | `Step` (7)               |                          | step id + flags byte (bit 0: sliced payloads, bit 1: fold gather, bit 2: trace spans, *v5*) + [`GridOp`](crate::cluster::GridOp) descriptor (full or sliced) |
//! |                          | `StepResult` (8)         | step id + per-owned-task (index, seconds, status): ok → fold count + result segment(s); error → message; absorbed-by-fold → nothing; *v5* — a span-table frame appended when the Step carried the trace bit |
//! | `Shutdown` (9)           |                          | — |
//! |                          | `Bye` (10)               | — |
//! | `Fatal` (11), either way |                          | message string |
//! | `Rejoin` (12)            |                          | *v3* — magic, session token, executor index, executor count, failed step id, offered capability bits |
//! |                          | `RejoinAck` (13)         | *v3* — magic, worker threads, accepted capability bits, have-blocks byte (1: blocks still cached under this session token, skip Stage); *v5* — trailing monotonic tick (u64 ns) |
//! | `CellMap` (14)           |                          | *v4* — magic, step id, executor count, explicit cell→slot table, plus any blocks the receiver must (re)stage under the new map |
//! |                          | `CellMapAck` (15)        | *v4* — magic |
//! | `SpecStep` (16)          |                          | *v4* — step id + flags byte + explicit task list + sliced op descriptor: a speculative backup copy of another executor's lagging tasks |
//!
//! The handshake is versioned: both sides check the magic and protocol
//! version before anything else, so a stale executor binary fails fast
//! with a readable error instead of a deserialization panic.  Frame
//! bodies use the [`crate::util::bytes`] little-endian codec; `f32`
//! payloads round-trip by bit pattern (the parity tests assert final
//! weights are bit-identical to the sim backend).
//!
//! ## Protocol v3: the rejoin extension
//!
//! Wire revision 3 adds driver-side fault recovery: a session token
//! appended to the `Hello` body, the [`CAP_REJOIN`] capability bit, and
//! the `Rejoin`/`RejoinAck` handshake a driver uses to re-attach to an
//! executor (surviving or freshly restarted) after a mid-superstep
//! failure.  The version *field* on the wire stays 2 — v3 is negotiated
//! entirely through the existing capability mechanism, so v2 executors
//! interoperate unchanged: a v2 executor ignores the trailing token in
//! `Hello` (its parser reads exactly five words), never acks
//! [`CAP_REJOIN`], and the fleet AND disables recovery — the driver
//! keeps today's fail-fast behavior on executor death.
//!
//! ## Protocol v4: elastic placement and speculative re-execution
//!
//! Wire revision 4 makes cell placement *explicit and rewritable*.  The
//! `CellMap` frame ships a full cell→executor-slot table (plus any
//! blocks the receiver is newly responsible for), letting the driver
//! degrade onto N−1 executors when a peer misses its rejoin budget,
//! rebalance back when it returns, and pre-place replica blocks for
//! speculation.  The `SpecStep` frame carries a backup copy of a lagging
//! executor's tasks to an idle peer — same sliced op encoding as `Step`,
//! but with the task list spelled out instead of derived from ownership.
//! Like v3, the version field stays 2: both features ride new capability
//! bits ([`CAP_ELASTIC`], [`CAP_SPEC`]), so v2/v3 executors interoperate
//! unchanged and simply leave the fleet inelastic.
//!
//! ## Capability negotiation
//!
//! The driver *offers* a capability mask in `Hello`; each executor acks
//! the subset it implements (`offered & `[`CAPS_SUPPORTED`]).  The driver
//! then runs the whole fleet at the AND of every ack, so one stale
//! executor downgrades the session instead of breaking it:
//!
//! * [`CAP_SLICED`] — Step frames may carry per-executor *sliced*
//!   payloads (only the state ranges the receiver's owned tasks read).
//! * [`CAP_CONTIG_FOLD`] — ownership may be contiguous-range instead of
//!   round-robin, and Step frames may set the fold flag asking the
//!   executor to pre-combine its locally-owned, aligned subtrees of the
//!   segment-combine tree before replying (bit-identical to
//!   [`reduce_segments`](crate::cluster::SimCluster::reduce_segments)
//!   order).
//! * [`CAP_REJOIN`] — the executor keeps its staged session (keyed by
//!   the driver's session token) across connections and answers the
//!   `Rejoin` handshake, enabling reconnect-and-retry fault recovery.
//! * [`CAP_ELASTIC`] — the executor accepts `CellMap` frames: explicit,
//!   driver-rewritable cell placement plus mid-run block restaging, the
//!   basis of degraded-mode continuation and elastic rebalancing.
//! * [`CAP_SPEC`] — the executor accepts `SpecStep` frames: speculative
//!   backup execution of another executor's lagging tasks.
//!
//! * [`CAP_TRACE`] — the executor records per-task spans and appends a
//!   compact span-table frame ([`crate::obs::frame`]) to each
//!   `StepResult` whose Step frame set the trace flag, and both
//!   handshake acks carry a trailing monotonic tick the driver uses to
//!   estimate the executor's clock offset (RTT midpoint).
//!
//! A full-broadcast driver (`--dist-wire broadcast`) simply offers no
//! capabilities.
//!
//! ## Protocol v5: fleet-wide tracing
//!
//! Wire revision 5 adds executor telemetry.  Like v3/v4 the version
//! field stays 2 — everything is negotiated through [`CAP_TRACE`]:
//!
//! * `HelloAck` and `RejoinAck` gain a trailing `u64` monotonic tick
//!   (nanoseconds on the executor's trace clock).  Old drivers read
//!   exactly their fixed fields and ignore the tail (the v3 token
//!   precedent); new drivers use it with the handshake send/receive
//!   times to estimate a per-executor clock offset.
//! * `Step` gains flags bit 2 ([`STEP_FLAG_TRACE`]): record per-task
//!   exec/fold spans this superstep and append the encoded span table
//!   after the `StepResult` task entries.  The driver only sets the bit
//!   when the whole fleet acked [`CAP_TRACE`], so old parsers (which
//!   stop after the task entries) never see trailing bytes they would
//!   trip on.  `SpecStep` never carries the trace bit — backup copies
//!   are accounted driver-side as instants.

use anyhow::{bail, Context, Result};
use std::io::{Read, Write};

/// "DDOP" — first field of both handshake messages.
pub const PROTO_MAGIC: u32 = 0x4444_4F50;
/// Bump on any frame-layout change.  v2: capability bits in the
/// handshake, ownership byte in Stage, flags byte + optional sliced
/// payloads in Step, fold count/absorbed statuses in StepResult.
/// Revision 3 (the rejoin extension, [`WIRE_REVISION`]) deliberately
/// keeps this at 2: it is negotiated through [`CAP_REJOIN`] so v2
/// executors interoperate.
pub const PROTO_VERSION: u32 = 2;
/// Wire revision implemented by this build: v5 = v4 (rejoin recovery +
/// elastic placement + speculative re-execution) + fleet-wide tracing
/// (`CAP_TRACE`: span tables piggybacked on step replies, handshake
/// clock ticks), all negotiated purely via capability bits.
pub const WIRE_REVISION: u32 = 5;
/// Ceiling on one frame body (guards a corrupt length prefix).
pub const MAX_FRAME: usize = 1 << 30;

/// Capability bit: per-executor sliced Step payloads.
pub const CAP_SLICED: u32 = 1 << 0;
/// Capability bit: contiguous-range ownership + executor-side gather
/// folding.
pub const CAP_CONTIG_FOLD: u32 = 1 << 1;
/// Capability bit (wire revision 3): the executor caches its session
/// (token + staged blocks) across connections and answers `Rejoin`, so
/// the driver may reconnect and retry a failed superstep.
pub const CAP_REJOIN: u32 = 1 << 2;
/// Capability bit (wire revision 4): the executor accepts `CellMap`
/// frames — explicit cell→slot placement the driver may rewrite mid-run
/// (degrade onto survivors, rebalance on readmission), with block
/// restaging riding the same frame.
pub const CAP_ELASTIC: u32 = 1 << 3;
/// Capability bit (wire revision 4): the executor accepts `SpecStep`
/// frames — speculative backup copies of a lagging peer's tasks.
pub const CAP_SPEC: u32 = 1 << 4;
/// Capability bit (wire revision 5): the executor implements tracing —
/// it appends a span-table frame to `StepResult` when the Step frame
/// set [`STEP_FLAG_TRACE`], and its handshake acks carry a trailing
/// monotonic tick for driver-side clock-offset estimation.
pub const CAP_TRACE: u32 = 1 << 5;
/// Every capability this build implements (what an executor acks).
pub const CAPS_SUPPORTED: u32 =
    CAP_SLICED | CAP_CONTIG_FOLD | CAP_REJOIN | CAP_ELASTIC | CAP_SPEC | CAP_TRACE;

/// Step-frame flags byte, bit 0: the op payload is sliced for this
/// executor (decode with `decode_sliced_into`).
pub const STEP_FLAG_SLICED: u8 = 1 << 0;
/// Step-frame flags byte, bit 1: pre-fold locally-owned aligned combine
/// subtrees before replying.
pub const STEP_FLAG_FOLD: u8 = 1 << 1;
/// Step-frame flags byte, bit 2 (wire revision 5): record per-task
/// spans this superstep and append the encoded span table
/// ([`crate::obs::frame`]) after the `StepResult` task entries.  Only
/// set when the whole fleet acked [`CAP_TRACE`].
pub const STEP_FLAG_TRACE: u8 = 1 << 2;

/// Frame tags (see the module-level message table).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Tag {
    Hello = 1,
    HelloAck = 2,
    Stage = 3,
    StageAck = 4,
    PrepareAdmm = 5,
    PrepareAdmmAck = 6,
    Step = 7,
    StepResult = 8,
    Shutdown = 9,
    Bye = 10,
    Fatal = 11,
    Rejoin = 12,
    RejoinAck = 13,
    CellMap = 14,
    CellMapAck = 15,
    SpecStep = 16,
}

impl Tag {
    pub fn from_u8(v: u8) -> Result<Tag> {
        Ok(match v {
            1 => Tag::Hello,
            2 => Tag::HelloAck,
            3 => Tag::Stage,
            4 => Tag::StageAck,
            5 => Tag::PrepareAdmm,
            6 => Tag::PrepareAdmmAck,
            7 => Tag::Step,
            8 => Tag::StepResult,
            9 => Tag::Shutdown,
            10 => Tag::Bye,
            11 => Tag::Fatal,
            12 => Tag::Rejoin,
            13 => Tag::RejoinAck,
            14 => Tag::CellMap,
            15 => Tag::CellMapAck,
            16 => Tag::SpecStep,
            other => bail!("unknown wire frame tag {other}"),
        })
    }
}

/// Write one frame; returns the total bytes put on the wire (header +
/// body) so callers can account bytes-on-wire exactly.
pub fn write_frame(w: &mut impl Write, tag: Tag, body: &[u8]) -> Result<usize> {
    if body.len() > MAX_FRAME {
        bail!("frame body of {} bytes exceeds MAX_FRAME", body.len());
    }
    let mut header = [0u8; 5];
    header[..4].copy_from_slice(&(body.len() as u32).to_le_bytes());
    header[4] = tag as u8;
    w.write_all(&header).context("write frame header")?;
    w.write_all(body).context("write frame body")?;
    w.flush().context("flush frame")?;
    Ok(5 + body.len())
}

/// Read one frame into `buf` (reused across calls); returns the tag and
/// the total bytes taken off the wire.
pub fn read_frame(r: &mut impl Read, buf: &mut Vec<u8>) -> Result<(Tag, usize)> {
    let mut header = [0u8; 5];
    r.read_exact(&mut header).context("read frame header")?;
    let len = u32::from_le_bytes(header[..4].try_into().unwrap()) as usize;
    if len > MAX_FRAME {
        bail!("incoming frame of {len} bytes exceeds MAX_FRAME (corrupt stream?)");
    }
    let tag = Tag::from_u8(header[4])?;
    buf.clear();
    // grow the buffer as bytes actually arrive instead of trusting the
    // header: a corrupt or malicious 5-byte header must not be able to
    // force a MAX_FRAME-sized allocation up front
    let got = r
        .take(len as u64)
        .read_to_end(buf)
        .with_context(|| format!("read {len}-byte {tag:?} body"))?;
    if got < len {
        bail!("truncated {tag:?} frame: got {got} of {len} body bytes");
    }
    Ok((tag, 5 + len))
}

/// Read a frame and insist on `want`; a `Fatal` frame is surfaced as the
/// peer's error message, anything else as a protocol violation.
pub fn expect_frame(r: &mut impl Read, buf: &mut Vec<u8>, want: Tag) -> Result<usize> {
    let (tag, n) = read_frame(r, buf)?;
    if tag == want {
        return Ok(n);
    }
    if tag == Tag::Fatal {
        let msg = crate::util::bytes::ByteReader::new(buf)
            .str()
            .unwrap_or_else(|_| "<unreadable>".into());
        bail!("peer reported fatal error: {msg}");
    }
    bail!("protocol violation: wanted {want:?}, got {tag:?}");
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Cursor;

    #[test]
    fn frames_round_trip() {
        let mut wire_buf = Vec::new();
        let n1 = write_frame(&mut wire_buf, Tag::Hello, b"abc").unwrap();
        let n2 = write_frame(&mut wire_buf, Tag::Bye, b"").unwrap();
        assert_eq!(n1, 8);
        assert_eq!(n2, 5);
        let mut cur = Cursor::new(wire_buf);
        let mut body = Vec::new();
        let (t1, r1) = read_frame(&mut cur, &mut body).unwrap();
        assert_eq!((t1, r1), (Tag::Hello, 8));
        assert_eq!(body, b"abc");
        let (t2, r2) = read_frame(&mut cur, &mut body).unwrap();
        assert_eq!((t2, r2), (Tag::Bye, 5));
        assert!(body.is_empty());
    }

    #[test]
    fn expect_frame_surfaces_fatal() {
        let mut wire_buf = Vec::new();
        let mut fatal_body = Vec::new();
        crate::util::bytes::put_str(&mut fatal_body, "disk on fire");
        write_frame(&mut wire_buf, Tag::Fatal, &fatal_body).unwrap();
        let mut cur = Cursor::new(wire_buf);
        let mut body = Vec::new();
        let err = expect_frame(&mut cur, &mut body, Tag::StageAck).unwrap_err();
        assert!(err.to_string().contains("disk on fire"));
    }

    #[test]
    fn bad_tag_and_truncation_error() {
        let mut cur = Cursor::new(vec![1, 0, 0, 0, 99, 0]);
        let mut body = Vec::new();
        assert!(read_frame(&mut cur, &mut body).is_err());
        let mut cur2 = Cursor::new(vec![5, 0, 0, 0, 1, 0]); // promises 5, has 1
        assert!(read_frame(&mut cur2, &mut body).is_err());
    }

    #[test]
    fn all_tags_round_trip() {
        for t in [
            Tag::Hello,
            Tag::HelloAck,
            Tag::Stage,
            Tag::StageAck,
            Tag::PrepareAdmm,
            Tag::PrepareAdmmAck,
            Tag::Step,
            Tag::StepResult,
            Tag::Shutdown,
            Tag::Bye,
            Tag::Fatal,
            Tag::Rejoin,
            Tag::RejoinAck,
            Tag::CellMap,
            Tag::CellMapAck,
            Tag::SpecStep,
        ] {
            assert_eq!(Tag::from_u8(t as u8).unwrap(), t);
        }
    }
}
