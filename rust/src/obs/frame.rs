//! Wire codec for executor span tables (the `CAP_TRACE` piggyback
//! frame appended to step replies at superstep boundaries).
//!
//! Layout (little-endian, matching [`crate::util::bytes`]):
//!
//! ```text
//! [n_names u32]                      (≤ 256)
//!   n_names × [len u32][utf-8 bytes] (each ≤ 128 bytes)
//! [n_events u32]                     (bounded by remaining bytes)
//!   n_events × [step u32][name u8][phase u8][flags u8]
//!              [worker u32][task_lo u32][task_hi u32]
//!              [t0_ns u64][t1_ns u64]
//! [dropped u64]
//! ```
//!
//! The executor's slot is deliberately *not* on the wire: the driver
//! stamps it from connection identity when merging, so a confused (or
//! malicious) executor cannot attribute its spans to another slot.
//! Decoding is strict — unknown phases, out-of-range name ids,
//! inverted time or task ranges, unknown flag bits, and trailing bytes
//! are all rejected, mirroring the wire-frame convention of trusting
//! nothing that arrives over TCP.

use anyhow::{bail, Result};

use crate::util::bytes::{put_str, put_u32, put_u64, put_u8, ByteReader};

use super::span::{Phase, SpanEvent, FLAG_INSTANT};

/// Per-frame name-table cap: the vocabulary is op kinds plus a few
/// phase labels, so 256 is generous; a bigger table is a corrupt frame.
pub const TRACE_FRAME_MAX_NAMES: usize = 256;
/// Longest accepted interned name.
pub const TRACE_FRAME_MAX_NAME_LEN: usize = 128;
/// Fixed encoded size of one event record.
const EVENT_BYTES: usize = 4 + 1 + 1 + 1 + 4 + 4 + 4 + 8 + 8;
/// Flag bits this revision understands; anything else is corrupt.
const KNOWN_FLAGS: u8 = FLAG_INSTANT;

/// A decoded span with its name still an index into the frame's own
/// name table (the merger re-interns into the driver [`super::TraceLog`]).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct RawSpan {
    pub name: u16,
    pub phase: Phase,
    pub flags: u8,
    pub step: u32,
    pub worker: u16,
    pub task_lo: u32,
    pub task_hi: u32,
    pub t0_ns: u64,
    pub t1_ns: u64,
}

#[derive(Debug, Default)]
pub struct TraceFrame {
    pub names: Vec<String>,
    pub events: Vec<RawSpan>,
    pub dropped: u64,
}

/// Serialize a span table.  `events` come straight from a drained
/// [`super::SpanRing`]; the name table is built by linear scan (the
/// vocabulary is tiny).  Fails only if the vocabulary overflows the
/// frame cap, which would indicate a recorder bug.
pub fn encode_trace_frame(events: &[SpanEvent], dropped: u64, buf: &mut Vec<u8>) -> Result<()> {
    let mut names: Vec<&str> = Vec::new();
    let mut ids: Vec<u8> = Vec::with_capacity(events.len());
    for ev in events {
        let id = match names.iter().position(|&n| n == ev.name) {
            Some(i) => i,
            None => {
                if names.len() >= TRACE_FRAME_MAX_NAMES {
                    bail!(
                        "trace frame name table overflow (> {TRACE_FRAME_MAX_NAMES} names)"
                    );
                }
                if ev.name.len() > TRACE_FRAME_MAX_NAME_LEN {
                    bail!("trace span name too long: {} bytes", ev.name.len());
                }
                names.push(ev.name);
                names.len() - 1
            }
        };
        ids.push(id as u8);
    }
    put_u32(buf, names.len() as u32);
    for n in &names {
        put_str(buf, n);
    }
    put_u32(buf, events.len() as u32);
    for (ev, &id) in events.iter().zip(&ids) {
        put_u32(buf, ev.step);
        put_u8(buf, id);
        put_u8(buf, ev.phase as u8);
        put_u8(buf, ev.flags);
        put_u32(buf, ev.worker as u32);
        put_u32(buf, ev.task_lo);
        put_u32(buf, ev.task_hi);
        put_u64(buf, ev.t0_ns);
        put_u64(buf, ev.t1_ns);
    }
    put_u64(buf, dropped);
    Ok(())
}

/// Strict decode of one span table; consumes exactly one frame from the
/// reader (the caller checks overall frame emptiness).
pub fn decode_trace_frame(r: &mut ByteReader) -> Result<TraceFrame> {
    let n_names = r.u32()? as usize;
    if n_names > TRACE_FRAME_MAX_NAMES {
        bail!("corrupt trace frame: {n_names} names exceeds cap {TRACE_FRAME_MAX_NAMES}");
    }
    let mut names = Vec::with_capacity(n_names);
    for _ in 0..n_names {
        let name = r.str()?;
        if name.len() > TRACE_FRAME_MAX_NAME_LEN {
            bail!("corrupt trace frame: name of {} bytes", name.len());
        }
        names.push(name);
    }
    let n_events = r.u32()? as usize;
    // bound the alloc by what could actually be present
    if n_events
        .checked_mul(EVENT_BYTES)
        .map(|b| b > r.remaining())
        .unwrap_or(true)
    {
        bail!(
            "corrupt trace frame: {n_events} events exceeds {} remaining bytes",
            r.remaining()
        );
    }
    let mut events = Vec::with_capacity(n_events);
    for _ in 0..n_events {
        let step = r.u32()?;
        let name = r.u8()? as u16;
        let phase = Phase::from_u8(r.u8()?)?;
        let flags = r.u8()?;
        if flags & !KNOWN_FLAGS != 0 {
            bail!("corrupt trace frame: unknown flag bits {flags:#04x}");
        }
        let worker = r.u32()?;
        let task_lo = r.u32()?;
        let task_hi = r.u32()?;
        let t0_ns = r.u64()?;
        let t1_ns = r.u64()?;
        if (name as usize) >= names.len() {
            bail!(
                "corrupt trace frame: name id {name} out of range ({} names)",
                names.len()
            );
        }
        if worker > u16::MAX as u32 {
            bail!("corrupt trace frame: worker id {worker} out of range");
        }
        if t1_ns < t0_ns {
            bail!("corrupt trace frame: span ends before it starts ({t1_ns} < {t0_ns})");
        }
        if task_hi < task_lo {
            bail!("corrupt trace frame: inverted task range [{task_lo}, {task_hi})");
        }
        events.push(RawSpan {
            name,
            phase,
            flags,
            step,
            worker: worker as u16,
            task_lo,
            task_hi,
            t0_ns,
            t1_ns,
        });
    }
    let dropped = r.u64()?;
    Ok(TraceFrame { names, events, dropped })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_events() -> Vec<SpanEvent> {
        vec![
            SpanEvent {
                name: "sdca",
                phase: Phase::Exec,
                flags: 0,
                step: 3,
                slot: 2,
                worker: 1,
                task_lo: 4,
                task_hi: 5,
                t0_ns: 100,
                t1_ns: 250,
            },
            SpanEvent {
                name: "fold",
                phase: Phase::Fold,
                flags: 0,
                step: 3,
                slot: 2,
                worker: 0,
                task_lo: 0,
                task_hi: 8,
                t0_ns: 260,
                t1_ns: 300,
            },
            SpanEvent {
                name: "retry",
                phase: Phase::Recover,
                flags: FLAG_INSTANT,
                step: 3,
                slot: 2,
                worker: 0,
                task_lo: 0,
                task_hi: 0,
                t0_ns: 310,
                t1_ns: 310,
            },
        ]
    }

    #[test]
    fn round_trips() {
        let events = sample_events();
        let mut buf = Vec::new();
        encode_trace_frame(&events, 7, &mut buf).unwrap();
        let mut r = ByteReader::new(&buf);
        let frame = decode_trace_frame(&mut r).unwrap();
        assert!(r.is_empty());
        assert_eq!(frame.dropped, 7);
        assert_eq!(frame.names, vec!["sdca", "fold", "retry"]);
        assert_eq!(frame.events.len(), events.len());
        for (raw, ev) in frame.events.iter().zip(&events) {
            assert_eq!(frame.names[raw.name as usize], ev.name);
            assert_eq!(raw.phase, ev.phase);
            assert_eq!(raw.flags, ev.flags);
            assert_eq!(raw.step, ev.step);
            assert_eq!(raw.worker, ev.worker);
            assert_eq!((raw.task_lo, raw.task_hi), (ev.task_lo, ev.task_hi));
            assert_eq!((raw.t0_ns, raw.t1_ns), (ev.t0_ns, ev.t1_ns));
        }
    }

    #[test]
    fn empty_table_round_trips() {
        let mut buf = Vec::new();
        encode_trace_frame(&[], 0, &mut buf).unwrap();
        let mut r = ByteReader::new(&buf);
        let frame = decode_trace_frame(&mut r).unwrap();
        assert!(r.is_empty());
        assert!(frame.names.is_empty());
        assert!(frame.events.is_empty());
        assert_eq!(frame.dropped, 0);
    }

    #[test]
    fn every_truncated_prefix_is_rejected() {
        let mut buf = Vec::new();
        encode_trace_frame(&sample_events(), 1, &mut buf).unwrap();
        for cut in 0..buf.len() {
            let mut r = ByteReader::new(&buf[..cut]);
            assert!(
                decode_trace_frame(&mut r).is_err(),
                "prefix of {cut} bytes decoded"
            );
        }
    }

    #[test]
    fn semantic_corruption_is_rejected() {
        let ev = |phase: Phase, t0: u64, t1: u64, lo: u32, hi: u32| SpanEvent {
            name: "x",
            phase,
            flags: 0,
            step: 0,
            slot: 0,
            worker: 0,
            task_lo: lo,
            task_hi: hi,
            t0_ns: t0,
            t1_ns: t1,
        };
        // inverted time range
        let mut buf = Vec::new();
        encode_trace_frame(&[ev(Phase::Exec, 50, 10, 0, 1)], 0, &mut buf).unwrap();
        assert!(decode_trace_frame(&mut ByteReader::new(&buf)).is_err());
        // inverted task range
        buf.clear();
        encode_trace_frame(&[ev(Phase::Exec, 0, 1, 5, 2)], 0, &mut buf).unwrap();
        assert!(decode_trace_frame(&mut ByteReader::new(&buf)).is_err());
    }
}
