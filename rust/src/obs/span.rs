//! Compact span events, the process-wide monotonic clock, and the
//! preallocated per-worker [`SpanRing`] recorder.
//!
//! A [`SpanEvent`] is a small `Copy` struct (no owned strings — names
//! are `&'static str` from the op tables), so pushing one is a couple
//! of stores into a preallocated ring: zero allocations per event.  The
//! tracing-off path is a single `capacity == 0` branch per task.

use std::sync::OnceLock;
use std::time::Instant;

use anyhow::{bail, Result};

/// Nanoseconds since the process-wide trace epoch (first call wins).
/// One monotonic axis per process; the driver re-bases executor ticks
/// onto its own axis via the handshake clock-offset estimate.
pub fn now_ns() -> u64 {
    static EPOCH: OnceLock<Instant> = OnceLock::new();
    EPOCH.get_or_init(Instant::now).elapsed().as_nanos() as u64
}

/// Where inside a superstep the time went.  The discriminants are the
/// wire encoding (see [`crate::obs::frame`]) — append only.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
#[repr(u8)]
pub enum Phase {
    /// Block staging / backend prepare (data movement before step 0).
    Stage = 0,
    /// Request fan-out: driver serializing + writing task frames.
    Scatter = 1,
    /// Per-task kernel execution on a worker.
    Exec = 2,
    /// Reply collection: driver reading + decoding result frames.
    Gather = 3,
    /// Executor-side pre-combine (contiguous fold before reply).
    Fold = 4,
    /// Driver-side tree reduce across cells.
    Combine = 5,
    /// Fault-tolerance machinery: retry / rejoin / degrade.
    Recover = 6,
    /// Speculative re-execution of straggler tasks.
    Spec = 7,
}

impl Phase {
    pub const ALL: [Phase; 8] = [
        Phase::Stage,
        Phase::Scatter,
        Phase::Exec,
        Phase::Gather,
        Phase::Fold,
        Phase::Combine,
        Phase::Recover,
        Phase::Spec,
    ];

    pub fn name(self) -> &'static str {
        match self {
            Phase::Stage => "stage",
            Phase::Scatter => "scatter",
            Phase::Exec => "exec",
            Phase::Gather => "gather",
            Phase::Fold => "fold",
            Phase::Combine => "combine",
            Phase::Recover => "recover",
            Phase::Spec => "spec",
        }
    }

    /// Strict decode — an unknown discriminant is a corrupt frame, not
    /// a default.
    pub fn from_u8(v: u8) -> Result<Phase> {
        match v {
            0 => Ok(Phase::Stage),
            1 => Ok(Phase::Scatter),
            2 => Ok(Phase::Exec),
            3 => Ok(Phase::Gather),
            4 => Ok(Phase::Fold),
            5 => Ok(Phase::Combine),
            6 => Ok(Phase::Recover),
            7 => Ok(Phase::Spec),
            _ => bail!("invalid span phase {v}"),
        }
    }
}

/// Event is a zero-duration instant (retry, rejoin, degrade, spec win)
/// rather than a span.
pub const FLAG_INSTANT: u8 = 1 << 0;

/// One recorded span (or instant, per `flags`).  `slot` 0 is the
/// driver; executor slot `s` records as `s + 1`.  `worker` is the
/// pool-scratch cell index that executed the task range.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct SpanEvent {
    pub name: &'static str,
    pub phase: Phase,
    pub flags: u8,
    pub step: u32,
    pub slot: u16,
    pub worker: u16,
    pub task_lo: u32,
    pub task_hi: u32,
    pub t0_ns: u64,
    pub t1_ns: u64,
}

/// Preallocated bounded recorder: overwrites oldest on overflow and
/// counts the drops instead of growing.  Capacity 0 is the disabled
/// state — `on()` is the only check on the hot path.
pub struct SpanRing {
    buf: Vec<SpanEvent>,
    cap: usize,
    /// Next write position once the ring has wrapped.
    head: usize,
    dropped: u64,
    step: u32,
    slot: u16,
    worker: u16,
}

impl SpanRing {
    /// The disabled recorder: no backing storage, every push is a no-op
    /// behind the `on()` check.
    pub fn disabled() -> SpanRing {
        SpanRing::with_capacity(0, 0, 0)
    }

    pub fn with_capacity(cap: usize, slot: u16, worker: u16) -> SpanRing {
        SpanRing {
            buf: Vec::with_capacity(cap),
            cap,
            head: 0,
            dropped: 0,
            step: 0,
            slot,
            worker,
        }
    }

    #[inline]
    pub fn on(&self) -> bool {
        self.cap != 0
    }

    pub fn slot(&self) -> u16 {
        self.slot
    }

    pub fn worker(&self) -> u16 {
        self.worker
    }

    /// Stamp the superstep ordinal subsequent events belong to (set by
    /// the backend before fanning tasks out).
    pub fn set_step(&mut self, step: u32) {
        self.step = step;
    }

    #[inline]
    pub fn push_span(
        &mut self,
        name: &'static str,
        phase: Phase,
        task_lo: u32,
        task_hi: u32,
        t0_ns: u64,
        t1_ns: u64,
    ) {
        self.push(SpanEvent {
            name,
            phase,
            flags: 0,
            step: self.step,
            slot: self.slot,
            worker: self.worker,
            task_lo,
            task_hi,
            t0_ns,
            t1_ns,
        });
    }

    pub fn push_instant(&mut self, name: &'static str, phase: Phase, t_ns: u64) {
        self.push(SpanEvent {
            name,
            phase,
            flags: FLAG_INSTANT,
            step: self.step,
            slot: self.slot,
            worker: self.worker,
            task_lo: 0,
            task_hi: 0,
            t0_ns: t_ns,
            t1_ns: t_ns,
        });
    }

    #[inline]
    fn push(&mut self, ev: SpanEvent) {
        if self.cap == 0 {
            return;
        }
        if self.buf.len() < self.cap {
            // within reserved capacity: no reallocation
            self.buf.push(ev);
        } else {
            self.buf[self.head] = ev;
            self.head = (self.head + 1) % self.cap;
            self.dropped += 1;
        }
    }

    pub fn len(&self) -> usize {
        self.buf.len()
    }

    pub fn is_empty(&self) -> bool {
        self.buf.is_empty()
    }

    /// Events dropped (overwritten) since the last [`SpanRing::drain`].
    pub fn dropped(&self) -> u64 {
        self.dropped
    }

    /// Visit the recorded events oldest-first and reset the ring (the
    /// reserved storage is kept, so refilling stays alloc-free).
    /// Returns the number of events that were overwritten while full.
    pub fn drain(&mut self, mut f: impl FnMut(&SpanEvent)) -> u64 {
        if self.buf.len() == self.cap && self.cap > 0 {
            // wrapped: oldest event sits at head
            for ev in &self.buf[self.head..] {
                f(ev);
            }
            for ev in &self.buf[..self.head] {
                f(ev);
            }
        } else {
            for ev in &self.buf {
                f(ev);
            }
        }
        let dropped = self.dropped;
        self.buf.clear();
        self.head = 0;
        self.dropped = 0;
        dropped
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn clock_is_monotonic() {
        let a = now_ns();
        let b = now_ns();
        assert!(b >= a);
    }

    #[test]
    fn phase_codes_round_trip() {
        for p in Phase::ALL {
            assert_eq!(Phase::from_u8(p as u8).unwrap(), p);
        }
        assert!(Phase::from_u8(8).is_err());
        assert!(Phase::from_u8(255).is_err());
    }

    #[test]
    fn disabled_ring_records_nothing() {
        let mut r = SpanRing::disabled();
        assert!(!r.on());
        r.push_span("sdca", Phase::Exec, 0, 1, 0, 10);
        r.push_instant("retry", Phase::Recover, 5);
        let mut seen = 0;
        r.drain(|_| seen += 1);
        assert_eq!(seen, 0);
        assert_eq!(r.dropped(), 0);
    }

    #[test]
    fn ring_keeps_newest_and_counts_drops() {
        let mut r = SpanRing::with_capacity(3, 1, 2);
        r.set_step(4);
        for i in 0..5u64 {
            r.push_span("sdca", Phase::Exec, i as u32, i as u32 + 1, i, i + 1);
        }
        let mut order = Vec::new();
        let dropped = r.drain(|ev| {
            assert_eq!(ev.step, 4);
            assert_eq!(ev.slot, 1);
            assert_eq!(ev.worker, 2);
            order.push(ev.t0_ns);
        });
        // capacity 3, 5 pushes: events 2,3,4 survive oldest-first
        assert_eq!(order, vec![2, 3, 4]);
        assert_eq!(dropped, 2);
        // ring is reusable after drain
        r.push_span("sdca", Phase::Exec, 0, 1, 9, 10);
        let mut n = 0;
        assert_eq!(r.drain(|_| n += 1), 0);
        assert_eq!(n, 1);
    }

    #[test]
    fn instants_are_flagged_zero_width() {
        let mut r = SpanRing::with_capacity(4, 0, 0);
        r.push_instant("rejoin", Phase::Recover, 42);
        r.drain(|ev| {
            assert_eq!(ev.flags & FLAG_INSTANT, FLAG_INSTANT);
            assert_eq!(ev.t0_ns, ev.t1_ns);
            assert_eq!(ev.t0_ns, 42);
        });
    }
}
