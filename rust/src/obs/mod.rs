//! Observability: fleet-wide tracing and metrics.
//!
//! The paper's claims are about *where time goes* as executors,
//! stragglers and failures vary — this module is the instrument that
//! makes that attribution visible inside a superstep instead of only in
//! per-iteration totals:
//!
//! * [`span`] — compact span events and the preallocated per-worker
//!   [`SpanRing`] recorder.  The hot path is zero-alloc when tracing is
//!   off (one branch per task) and alloc-free per event when on; rings
//!   are drained between supersteps.
//! * [`trace`] — the [`TraceLog`]: a bounded, name-interning event ring
//!   the driver merges every span source into (its own phases, the sim
//!   workers, and — over the wire — every executor's span tables,
//!   re-aligned onto the driver clock via the handshake RTT-midpoint
//!   offset estimate).
//! * [`frame`] — the wire codec for executor span tables
//!   (capability-gated by `CAP_TRACE`; see [`crate::cluster::dist::wire`]).
//! * [`chrome`] — exports: Chrome trace-event JSON (loadable in
//!   Perfetto; process = executor slot, thread = worker, instant events
//!   for retries/rejoins/degrades/speculation) and a raw JSONL event log.
//! * [`metrics`] — the [`MetricsRegistry`] (counters / gauges /
//!   fixed-bucket histograms) unifying the recovery/speculation/wire
//!   counters, rendered as Prometheus text and served over HTTP by
//!   `ddopt executor --metrics-addr`.
//!
//! Span phases ([`Phase`]): `stage` (block staging / prepare), `scatter`
//! (request fan-out), `exec` (per-task kernel execution), `gather`
//! (reply collection), `fold` (executor-side pre-combine), `combine`
//! (driver-side tree reduce), `recover` (retry/rejoin/degrade
//! machinery), `spec` (speculative re-execution).

pub mod chrome;
pub mod frame;
pub mod metrics;
pub mod span;
pub mod trace;

pub use chrome::{chrome_trace, write_chrome_trace, write_events_jsonl};
pub use frame::{
    decode_trace_frame, encode_trace_frame, RawSpan, TraceFrame, TRACE_FRAME_MAX_NAMES,
    TRACE_FRAME_MAX_NAME_LEN,
};
pub use metrics::{serve_metrics, Counter, Gauge, Histogram, MetricsRegistry};
pub use span::{now_ns, Phase, SpanEvent, SpanRing, FLAG_INSTANT};
pub use trace::{TraceEvent, TraceLog};

/// Default driver-side [`TraceLog`] capacity: enough for every CI-scale
/// run without wrapping, bounded so steady state stays alloc-free.
pub const TRACE_LOG_CAPACITY: usize = 1 << 16;

/// Default per-worker [`SpanRing`] capacity (events between drains — one
/// superstep's tasks per worker, with generous slack).
pub const SPAN_RING_CAPACITY: usize = 4096;
