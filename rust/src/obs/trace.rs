//! The driver-side [`TraceLog`]: a bounded, name-interning event ring
//! every span source merges into — the driver's own phase spans, the
//! sim workers' rings, and decoded executor span tables (already
//! re-based onto the driver clock by the caller).
//!
//! Names are interned to `u16` ids so a steady-state push is two array
//! writes; after the first superstep warms the intern table, recording
//! allocates nothing per event (the `alloc_regression` suite holds this
//! to 0 allocs/iter).

use std::collections::HashMap;

use super::span::{Phase, SpanEvent, FLAG_INSTANT};

/// A recorded event with its name resolved to an intern id.
#[derive(Clone, Copy, Debug)]
pub struct TraceEvent {
    pub name: u16,
    pub phase: Phase,
    pub flags: u8,
    pub step: u32,
    pub slot: u16,
    pub worker: u16,
    pub task_lo: u32,
    pub task_hi: u32,
    pub t0_ns: u64,
    pub t1_ns: u64,
}

#[derive(Clone, Debug)]
pub struct TraceLog {
    names: Vec<String>,
    index: HashMap<String, u16>,
    events: Vec<TraceEvent>,
    cap: usize,
    head: usize,
    dropped: u64,
}

impl TraceLog {
    pub fn with_capacity(cap: usize) -> TraceLog {
        TraceLog {
            names: Vec::new(),
            index: HashMap::new(),
            events: Vec::with_capacity(cap),
            cap,
            head: 0,
            dropped: 0,
        }
    }

    /// Intern a name, returning its stable id.  Allocates only on first
    /// sight of a name; the vocabulary is op kinds + a handful of
    /// driver phases, so the table saturates within one superstep.
    pub fn intern(&mut self, name: &str) -> u16 {
        if let Some(&id) = self.index.get(name) {
            return id;
        }
        let id = u16::try_from(self.names.len()).expect("trace name table overflow");
        self.names.push(name.to_string());
        self.index.insert(name.to_string(), id);
        id
    }

    pub fn name(&self, id: u16) -> &str {
        self.names
            .get(id as usize)
            .map(String::as_str)
            .unwrap_or("?")
    }

    pub fn names(&self) -> &[String] {
        &self.names
    }

    pub fn len(&self) -> usize {
        self.events.len()
    }

    pub fn is_empty(&self) -> bool {
        self.events.is_empty()
    }

    /// Events overwritten here plus drops reported by absorbed rings.
    pub fn dropped(&self) -> u64 {
        self.dropped
    }

    pub fn add_dropped(&mut self, n: u64) {
        self.dropped += n;
    }

    #[inline]
    pub fn record(&mut self, ev: &SpanEvent) {
        let name = self.intern(ev.name);
        self.push(TraceEvent {
            name,
            phase: ev.phase,
            flags: ev.flags,
            step: ev.step,
            slot: ev.slot,
            worker: ev.worker,
            task_lo: ev.task_lo,
            task_hi: ev.task_hi,
            t0_ns: ev.t0_ns,
            t1_ns: ev.t1_ns,
        });
    }

    /// Record an event whose name is already an id *into this log* —
    /// the merge path for decoded executor frames (caller maps the
    /// frame's name table through [`TraceLog::intern`] first).
    #[inline]
    pub fn record_raw(&mut self, ev: TraceEvent) {
        self.push(ev);
    }

    /// Driver convenience: record a completed span.
    pub fn span(
        &mut self,
        name: &'static str,
        phase: Phase,
        step: u32,
        slot: u16,
        task_lo: u32,
        task_hi: u32,
        t0_ns: u64,
        t1_ns: u64,
    ) {
        self.record(&SpanEvent {
            name,
            phase,
            flags: 0,
            step,
            slot,
            worker: 0,
            task_lo,
            task_hi,
            t0_ns,
            t1_ns,
        });
    }

    /// Driver convenience: record an instant (retry/rejoin/degrade/…).
    pub fn instant(&mut self, name: &'static str, phase: Phase, step: u32, slot: u16, t_ns: u64) {
        self.record(&SpanEvent {
            name,
            phase,
            flags: FLAG_INSTANT,
            step,
            slot,
            worker: 0,
            task_lo: 0,
            task_hi: 0,
            t0_ns: t_ns,
            t1_ns: t_ns,
        });
    }

    /// Drain a worker ring into the log (between supersteps).  The ring
    /// and the log are disjoint borrows, so events stream straight into
    /// [`TraceLog::record`] with no staging buffer — once the intern
    /// table is warm the whole drain is alloc-free, which is what keeps
    /// the traced steady state at 0 allocs/iter (`alloc_regression`
    /// pins this).
    pub fn absorb(&mut self, ring: &mut super::span::SpanRing) {
        let dropped = ring.drain(|ev| self.record(ev));
        self.dropped += dropped;
    }

    #[inline]
    fn push(&mut self, ev: TraceEvent) {
        if self.cap == 0 {
            return;
        }
        if self.events.len() < self.cap {
            self.events.push(ev);
        } else {
            self.events[self.head] = ev;
            self.head = (self.head + 1) % self.cap;
            self.dropped += 1;
        }
    }

    /// Recorded events oldest-first.
    pub fn events(&self) -> impl Iterator<Item = &TraceEvent> {
        let (wrapped, rest) = if self.events.len() == self.cap && self.cap > 0 {
            (&self.events[self.head..], &self.events[..self.head])
        } else {
            (&self.events[..], &self.events[..0])
        };
        wrapped.iter().chain(rest.iter())
    }
}

#[cfg(test)]
mod tests {
    use super::super::span::SpanRing;
    use super::*;

    #[test]
    fn interning_is_stable_and_deduplicated() {
        let mut log = TraceLog::with_capacity(8);
        let a = log.intern("sdca");
        let b = log.intern("atx");
        assert_eq!(log.intern("sdca"), a);
        assert_ne!(a, b);
        assert_eq!(log.name(a), "sdca");
        assert_eq!(log.names().len(), 2);
    }

    #[test]
    fn absorb_moves_ring_events_and_drop_counts() {
        let mut ring = SpanRing::with_capacity(2, 3, 1);
        ring.set_step(7);
        ring.push_span("sdca", Phase::Exec, 0, 1, 10, 20);
        ring.push_span("sdca", Phase::Exec, 1, 2, 20, 30);
        ring.push_span("sdca", Phase::Exec, 2, 3, 30, 40); // overwrites oldest
        let mut log = TraceLog::with_capacity(8);
        log.absorb(&mut ring);
        assert_eq!(log.len(), 2);
        assert_eq!(log.dropped(), 1);
        assert!(ring.is_empty());
        let first = log.events().next().unwrap();
        assert_eq!(first.step, 7);
        assert_eq!(first.slot, 3);
        assert_eq!(first.t0_ns, 20);
    }

    #[test]
    fn log_ring_overwrites_oldest() {
        let mut log = TraceLog::with_capacity(2);
        for i in 0..4u64 {
            log.span("op", Phase::Combine, 0, 0, 0, 0, i, i + 1);
        }
        let t0s: Vec<u64> = log.events().map(|e| e.t0_ns).collect();
        assert_eq!(t0s, vec![2, 3]);
        assert_eq!(log.dropped(), 2);
    }
}
