//! The [`MetricsRegistry`]: counters, gauges, and fixed-bucket
//! histograms behind cheap cloneable handles, rendered as Prometheus
//! text exposition format and served over a minimal std-only HTTP
//! endpoint (`ddopt executor --metrics-addr HOST:PORT`).
//!
//! Handles are `Arc<Atomic*>` — incrementing on the hot path is one
//! relaxed atomic op, no locking, no allocation.  The registry itself
//! (a name → metric map behind a mutex) is only touched at
//! registration and render time.

use std::collections::BTreeMap;
use std::fmt::Write as _;
use std::io::{Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicI64, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Duration;

use anyhow::{Context, Result};

/// Monotonically increasing count (events, bytes, retries).
#[derive(Clone, Default)]
pub struct Counter(Arc<AtomicU64>);

impl Counter {
    #[inline]
    pub fn inc(&self) {
        self.add(1);
    }

    #[inline]
    pub fn add(&self, n: u64) {
        self.0.fetch_add(n, Ordering::Relaxed);
    }

    pub fn get(&self) -> u64 {
        self.0.load(Ordering::Relaxed)
    }
}

/// Point-in-time signed value (fleet size, degraded executor count).
#[derive(Clone, Default)]
pub struct Gauge(Arc<AtomicI64>);

impl Gauge {
    pub fn set(&self, v: i64) {
        self.0.store(v, Ordering::Relaxed);
    }

    pub fn add(&self, d: i64) {
        self.0.fetch_add(d, Ordering::Relaxed);
    }

    pub fn get(&self) -> i64 {
        self.0.load(Ordering::Relaxed)
    }
}

struct HistogramInner {
    /// Upper bounds of the finite buckets (sorted); an implicit +Inf
    /// bucket catches the rest.
    bounds: Vec<f64>,
    counts: Vec<AtomicU64>,
    count: AtomicU64,
    /// Sum as f64 bits, updated with a CAS loop (observations are rare
    /// relative to counter increments, so contention is a non-issue).
    sum_bits: AtomicU64,
}

/// Fixed-bucket histogram (superstep latencies, frame sizes).
#[derive(Clone)]
pub struct Histogram(Arc<HistogramInner>);

impl Histogram {
    fn new(bounds: &[f64]) -> Histogram {
        let mut sorted = bounds.to_vec();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let counts = (0..=sorted.len()).map(|_| AtomicU64::new(0)).collect();
        Histogram(Arc::new(HistogramInner {
            bounds: sorted,
            counts,
            count: AtomicU64::new(0),
            sum_bits: AtomicU64::new(0f64.to_bits()),
        }))
    }

    pub fn observe(&self, v: f64) {
        let inner = &self.0;
        let idx = inner
            .bounds
            .iter()
            .position(|&b| v <= b)
            .unwrap_or(inner.bounds.len());
        inner.counts[idx].fetch_add(1, Ordering::Relaxed);
        inner.count.fetch_add(1, Ordering::Relaxed);
        let mut cur = inner.sum_bits.load(Ordering::Relaxed);
        loop {
            let next = (f64::from_bits(cur) + v).to_bits();
            match inner.sum_bits.compare_exchange_weak(
                cur,
                next,
                Ordering::Relaxed,
                Ordering::Relaxed,
            ) {
                Ok(_) => break,
                Err(seen) => cur = seen,
            }
        }
    }

    pub fn count(&self) -> u64 {
        self.0.count.load(Ordering::Relaxed)
    }

    pub fn sum(&self) -> f64 {
        f64::from_bits(self.0.sum_bits.load(Ordering::Relaxed))
    }
}

#[derive(Clone)]
enum Metric {
    Counter(Counter),
    Gauge(Gauge),
    Histogram(Histogram),
}

struct Entry {
    help: String,
    metric: Metric,
}

/// Name → metric registry.  `counter`/`gauge`/`histogram` are
/// get-or-register: asking twice for the same name returns handles to
/// the same underlying atomic, which is how the driver, the wire log,
/// and the train summary end up reading one source of truth.
#[derive(Default)]
pub struct MetricsRegistry {
    entries: Mutex<BTreeMap<String, Entry>>,
}

impl MetricsRegistry {
    pub fn new() -> MetricsRegistry {
        MetricsRegistry::default()
    }

    pub fn counter(&self, name: &str, help: &str) -> Counter {
        let mut entries = self.entries.lock().unwrap();
        let entry = entries.entry(name.to_string()).or_insert_with(|| Entry {
            help: help.to_string(),
            metric: Metric::Counter(Counter::default()),
        });
        match &entry.metric {
            Metric::Counter(c) => c.clone(),
            _ => panic!("metric {name} already registered with a different type"),
        }
    }

    pub fn gauge(&self, name: &str, help: &str) -> Gauge {
        let mut entries = self.entries.lock().unwrap();
        let entry = entries.entry(name.to_string()).or_insert_with(|| Entry {
            help: help.to_string(),
            metric: Metric::Gauge(Gauge::default()),
        });
        match &entry.metric {
            Metric::Gauge(g) => g.clone(),
            _ => panic!("metric {name} already registered with a different type"),
        }
    }

    pub fn histogram(&self, name: &str, help: &str, bounds: &[f64]) -> Histogram {
        let mut entries = self.entries.lock().unwrap();
        let entry = entries.entry(name.to_string()).or_insert_with(|| Entry {
            help: help.to_string(),
            metric: Metric::Histogram(Histogram::new(bounds)),
        });
        match &entry.metric {
            Metric::Histogram(h) => h.clone(),
            _ => panic!("metric {name} already registered with a different type"),
        }
    }

    /// Read a single metric by name (counters and gauges).
    pub fn value(&self, name: &str) -> Option<f64> {
        let entries = self.entries.lock().unwrap();
        match &entries.get(name)?.metric {
            Metric::Counter(c) => Some(c.get() as f64),
            Metric::Gauge(g) => Some(g.get() as f64),
            Metric::Histogram(h) => Some(h.sum()),
        }
    }

    /// Flat snapshot of every scalar series, sorted by name —
    /// histograms contribute `_count` and `_sum` entries.
    pub fn snapshot(&self) -> Vec<(String, f64)> {
        let entries = self.entries.lock().unwrap();
        let mut out = Vec::with_capacity(entries.len());
        for (name, entry) in entries.iter() {
            match &entry.metric {
                Metric::Counter(c) => out.push((name.clone(), c.get() as f64)),
                Metric::Gauge(g) => out.push((name.clone(), g.get() as f64)),
                Metric::Histogram(h) => {
                    out.push((format!("{name}_count"), h.count() as f64));
                    out.push((format!("{name}_sum"), h.sum()));
                }
            }
        }
        out
    }

    /// Prometheus text exposition format (version 0.0.4).
    pub fn render_prometheus(&self) -> String {
        let entries = self.entries.lock().unwrap();
        let mut out = String::new();
        for (name, entry) in entries.iter() {
            let _ = writeln!(out, "# HELP {name} {}", entry.help);
            match &entry.metric {
                Metric::Counter(c) => {
                    let _ = writeln!(out, "# TYPE {name} counter");
                    let _ = writeln!(out, "{name} {}", c.get());
                }
                Metric::Gauge(g) => {
                    let _ = writeln!(out, "# TYPE {name} gauge");
                    let _ = writeln!(out, "{name} {}", g.get());
                }
                Metric::Histogram(h) => {
                    let _ = writeln!(out, "# TYPE {name} histogram");
                    let mut cumulative = 0u64;
                    for (i, bound) in h.0.bounds.iter().enumerate() {
                        cumulative += h.0.counts[i].load(Ordering::Relaxed);
                        let _ = writeln!(out, "{name}_bucket{{le=\"{bound}\"}} {cumulative}");
                    }
                    let _ = writeln!(out, "{name}_bucket{{le=\"+Inf\"}} {}", h.count());
                    let _ = writeln!(out, "{name}_sum {}", h.sum());
                    let _ = writeln!(out, "{name}_count {}", h.count());
                }
            }
        }
        out
    }
}

/// Serve `render_prometheus` over HTTP on `addr` from a background
/// thread; returns the bound address (so `:0` picks a free port).
/// Every request gets the current scrape regardless of path or method
/// — this is a scrape endpoint, not a web server.
pub fn serve_metrics(addr: &str, registry: Arc<MetricsRegistry>) -> Result<SocketAddr> {
    let listener = TcpListener::bind(addr)
        .with_context(|| format!("binding metrics endpoint on {addr}"))?;
    let local = listener.local_addr()?;
    std::thread::Builder::new()
        .name("ddopt-metrics".to_string())
        .spawn(move || {
            for conn in listener.incoming() {
                let Ok(mut stream) = conn else { continue };
                let _ = serve_one(&mut stream, registry.as_ref());
            }
        })
        .context("spawning metrics server thread")?;
    Ok(local)
}

fn serve_one(stream: &mut TcpStream, registry: &MetricsRegistry) -> std::io::Result<()> {
    stream.set_read_timeout(Some(Duration::from_millis(500)))?;
    // drain the request head (bounded); we answer anything with a scrape
    let mut head = [0u8; 4096];
    let mut read = 0;
    while read < head.len() {
        match stream.read(&mut head[read..]) {
            Ok(0) => break,
            Ok(n) => {
                read += n;
                if head[..read].windows(4).any(|w| w == b"\r\n\r\n") {
                    break;
                }
            }
            Err(_) => break,
        }
    }
    let body = registry.render_prometheus();
    write!(
        stream,
        "HTTP/1.1 200 OK\r\nContent-Type: text/plain; version=0.0.4; charset=utf-8\r\nContent-Length: {}\r\nConnection: close\r\n\r\n{}",
        body.len(),
        body
    )?;
    stream.flush()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn handles_share_one_source() {
        let reg = MetricsRegistry::new();
        let a = reg.counter("ddopt_retries_total", "retries");
        let b = reg.counter("ddopt_retries_total", "retries");
        a.inc();
        b.add(2);
        assert_eq!(a.get(), 3);
        assert_eq!(reg.value("ddopt_retries_total"), Some(3.0));
    }

    #[test]
    fn gauge_sets_and_adds() {
        let reg = MetricsRegistry::new();
        let g = reg.gauge("ddopt_fleet_size", "executors");
        g.set(3);
        g.add(-1);
        assert_eq!(g.get(), 2);
    }

    #[test]
    fn histogram_buckets_cumulate() {
        let reg = MetricsRegistry::new();
        let h = reg.histogram("ddopt_step_secs", "superstep wall", &[0.1, 1.0]);
        h.observe(0.05);
        h.observe(0.5);
        h.observe(5.0);
        assert_eq!(h.count(), 3);
        assert!((h.sum() - 5.55).abs() < 1e-9);
        let text = reg.render_prometheus();
        assert!(text.contains("ddopt_step_secs_bucket{le=\"0.1\"} 1"));
        assert!(text.contains("ddopt_step_secs_bucket{le=\"1\"} 2"));
        assert!(text.contains("ddopt_step_secs_bucket{le=\"+Inf\"} 3"));
        assert!(text.contains("ddopt_step_secs_count 3"));
    }

    #[test]
    fn snapshot_is_sorted_and_complete() {
        let reg = MetricsRegistry::new();
        reg.counter("b_total", "b").inc();
        reg.gauge("a_gauge", "a").set(7);
        let snap = reg.snapshot();
        let names: Vec<&str> = snap.iter().map(|(n, _)| n.as_str()).collect();
        assert_eq!(names, vec!["a_gauge", "b_total"]);
        assert_eq!(snap[0].1, 7.0);
        assert_eq!(snap[1].1, 1.0);
    }

    #[test]
    fn http_endpoint_serves_prometheus_text() {
        let reg = Arc::new(MetricsRegistry::new());
        reg.counter("ddopt_up", "liveness").inc();
        let addr = serve_metrics("127.0.0.1:0", reg).unwrap();
        let mut stream = TcpStream::connect(addr).unwrap();
        write!(stream, "GET /metrics HTTP/1.1\r\nHost: x\r\n\r\n").unwrap();
        let mut resp = String::new();
        stream.read_to_string(&mut resp).unwrap();
        assert!(resp.starts_with("HTTP/1.1 200 OK"), "{resp}");
        assert!(resp.contains("text/plain"));
        assert!(resp.contains("# TYPE ddopt_up counter"));
        assert!(resp.contains("ddopt_up 1"));
    }
}
