//! Trace exports: Chrome trace-event JSON (Perfetto-loadable) and a raw
//! JSONL event log.
//!
//! Mapping: **process** = executor slot (pid 0 is the driver, pid `s+1`
//! is executor slot `s`), **thread** = worker (pool scratch cell).
//! Spans render as `"X"` complete events with microsecond `ts`/`dur`;
//! retries, rejoins, degrades, and speculation wins render as `"i"`
//! instant events so they show up as markers on the timeline.  Output
//! is deterministic for a given log: objects serialize through
//! [`Json`]'s ordered maps and metadata is emitted in sorted pid/tid
//! order, which is what makes the golden export test byte-stable.

use std::collections::BTreeSet;
use std::io::Write;
use std::path::{Path, PathBuf};

use anyhow::{Context, Result};

use crate::util::json::Json;

use super::span::FLAG_INSTANT;
use super::trace::TraceLog;

fn process_name(pid: u64) -> String {
    if pid == 0 {
        "driver".to_string()
    } else {
        format!("executor {}", pid - 1)
    }
}

/// Build the trace-event document: metadata first (sorted), then events
/// in recording order.
pub fn chrome_trace(log: &TraceLog) -> Json {
    let mut pids: BTreeSet<u64> = BTreeSet::new();
    let mut tids: BTreeSet<(u64, u64)> = BTreeSet::new();
    for ev in log.events() {
        pids.insert(ev.slot as u64);
        tids.insert((ev.slot as u64, ev.worker as u64));
    }
    let mut out: Vec<Json> = Vec::new();
    for &pid in &pids {
        out.push(Json::obj(vec![
            ("args", Json::obj(vec![("name", Json::str(&process_name(pid)))])),
            ("name", Json::str("process_name")),
            ("ph", Json::str("M")),
            ("pid", Json::num(pid as f64)),
            ("tid", Json::num(0.0)),
        ]));
        out.push(Json::obj(vec![
            ("args", Json::obj(vec![("sort_index", Json::num(pid as f64))])),
            ("name", Json::str("process_sort_index")),
            ("ph", Json::str("M")),
            ("pid", Json::num(pid as f64)),
            ("tid", Json::num(0.0)),
        ]));
    }
    for &(pid, tid) in &tids {
        out.push(Json::obj(vec![
            (
                "args",
                Json::obj(vec![("name", Json::str(&format!("worker {tid}")))]),
            ),
            ("name", Json::str("thread_name")),
            ("ph", Json::str("M")),
            ("pid", Json::num(pid as f64)),
            ("tid", Json::num(tid as f64)),
        ]));
    }
    for ev in log.events() {
        let args = Json::obj(vec![
            ("step", Json::num(ev.step as f64)),
            ("task_hi", Json::num(ev.task_hi as f64)),
            ("task_lo", Json::num(ev.task_lo as f64)),
        ]);
        let ts = ev.t0_ns as f64 / 1000.0;
        if ev.flags & FLAG_INSTANT != 0 {
            out.push(Json::obj(vec![
                ("args", args),
                ("cat", Json::str(ev.phase.name())),
                ("name", Json::str(log.name(ev.name))),
                ("ph", Json::str("i")),
                ("pid", Json::num(ev.slot as f64)),
                ("s", Json::str("p")),
                ("tid", Json::num(ev.worker as f64)),
                ("ts", Json::num(ts)),
            ]));
        } else {
            out.push(Json::obj(vec![
                ("args", args),
                ("cat", Json::str(ev.phase.name())),
                ("dur", Json::num((ev.t1_ns - ev.t0_ns) as f64 / 1000.0)),
                ("name", Json::str(log.name(ev.name))),
                ("ph", Json::str("X")),
                ("pid", Json::num(ev.slot as f64)),
                ("tid", Json::num(ev.worker as f64)),
                ("ts", Json::num(ts)),
            ]));
        }
    }
    Json::obj(vec![
        (
            "ddopt",
            Json::obj(vec![
                ("dropped", Json::num(log.dropped() as f64)),
                ("events", Json::num(log.len() as f64)),
            ]),
        ),
        ("displayTimeUnit", Json::str("ms")),
        ("traceEvents", Json::Arr(out)),
    ])
}

/// Write the Chrome trace-event JSON document to `path`.
pub fn write_chrome_trace(log: &TraceLog, path: &Path) -> Result<()> {
    let doc = chrome_trace(log);
    let mut f = std::fs::File::create(path)
        .with_context(|| format!("creating trace file {}", path.display()))?;
    writeln!(f, "{doc}")?;
    Ok(())
}

/// Sibling JSONL path for a trace file: `trace.json` → `trace.jsonl`.
pub fn jsonl_path_for(trace_path: &Path) -> PathBuf {
    trace_path.with_extension("jsonl")
}

/// Write the raw event log, one JSON object per line, in recording
/// order — the grep/jq-friendly counterpart of the Perfetto view.
pub fn write_events_jsonl(log: &TraceLog, path: &Path) -> Result<()> {
    let mut f = std::fs::File::create(path)
        .with_context(|| format!("creating trace event log {}", path.display()))?;
    for ev in log.events() {
        let line = Json::obj(vec![
            ("instant", Json::Bool(ev.flags & FLAG_INSTANT != 0)),
            ("name", Json::str(log.name(ev.name))),
            ("phase", Json::str(ev.phase.name())),
            ("slot", Json::num(ev.slot as f64)),
            ("step", Json::num(ev.step as f64)),
            ("t0_ns", Json::num(ev.t0_ns as f64)),
            ("t1_ns", Json::num(ev.t1_ns as f64)),
            ("task_hi", Json::num(ev.task_hi as f64)),
            ("task_lo", Json::num(ev.task_lo as f64)),
            ("worker", Json::num(ev.worker as f64)),
        ]);
        writeln!(f, "{line}")?;
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::super::span::Phase;
    use super::*;

    fn sample_log() -> TraceLog {
        let mut log = TraceLog::with_capacity(16);
        log.span("sdca", Phase::Exec, 1, 1, 0, 4, 1_000, 5_000);
        log.span("combine", Phase::Combine, 1, 0, 0, 8, 5_500, 6_000);
        log.instant("retry", Phase::Recover, 2, 0, 7_000);
        log
    }

    #[test]
    fn export_is_valid_json_with_metadata_and_events() {
        let doc = chrome_trace(&sample_log());
        let text = doc.to_string();
        let parsed = Json::parse(&text).unwrap();
        let events = parsed.get("traceEvents").unwrap().as_arr().unwrap();
        // 2 pids -> 2x2 process metadata + 2 thread metadata + 3 events
        let metas = events
            .iter()
            .filter(|e| e.get("ph").unwrap().as_str() == Some("M"))
            .count();
        assert_eq!(metas, 6);
        let spans = events
            .iter()
            .filter(|e| e.get("ph").unwrap().as_str() == Some("X"))
            .count();
        let instants = events
            .iter()
            .filter(|e| e.get("ph").unwrap().as_str() == Some("i"))
            .count();
        assert_eq!((spans, instants), (2, 1));
        // microsecond conversion
        let first_span = events
            .iter()
            .find(|e| e.get("ph").unwrap().as_str() == Some("X"))
            .unwrap();
        assert_eq!(first_span.get("ts").unwrap().as_f64(), Some(1.0));
        assert_eq!(first_span.get("dur").unwrap().as_f64(), Some(4.0));
    }

    #[test]
    fn export_is_deterministic() {
        let a = chrome_trace(&sample_log()).to_string();
        let b = chrome_trace(&sample_log()).to_string();
        assert_eq!(a, b);
    }

    #[test]
    fn jsonl_lines_parse_and_sibling_path() {
        let log = sample_log();
        let dir = std::env::temp_dir().join(format!("ddopt-obs-test-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("trace.json");
        let jsonl = jsonl_path_for(&path);
        assert_eq!(jsonl.file_name().unwrap(), "trace.jsonl");
        write_events_jsonl(&log, &jsonl).unwrap();
        let text = std::fs::read_to_string(&jsonl).unwrap();
        let lines: Vec<&str> = text.lines().collect();
        assert_eq!(lines.len(), 3);
        for line in &lines {
            let v = Json::parse(line).unwrap();
            assert!(v.get("phase").unwrap().as_str().is_some());
        }
        std::fs::remove_dir_all(&dir).ok();
    }
}
