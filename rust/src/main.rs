//! `ddopt` — the coordinator CLI.
//!
//! ```text
//! ddopt train [--config cfg.json] [--method radisa|radisa-avg|d3ca|admm]
//!             [--p 4 --q 2] [--lambda 1e-3] [--gamma 0.05] [--iters 30]
//!             [--seed N] [--backend native|xla] [--loss hinge|logistic]
//!             [--cores 8] [--threads N]  (threads default: host parallelism)
//!             [--cluster sim|dist:host:port[,host:port...]]
//!             [--dist-wire sliced|broadcast]  (default: sliced)
//!             [--dist-spec [quantile=0.75,copies=1]]  (speculative re-execution)
//!             [--scenario ideal|stragglers:p=0.1,slow=10x[,shape=S][,spec]
//!                        |hetero:frac=0.25,speed=0.5
//!                        |failures:p=0.05[,retries=R][,burst=executor]
//!                        |<clause>+<clause>]
//!             [--n-per 200 --m-per 150 | --sparse n,m,density | --libsvm file]
//!             [--no-fstar] [--out history.csv] [--wire-out wire.jsonl]
//!             [--trace-out trace.json]  (Chrome trace JSON + .jsonl event log)
//!             [--dump-w weights.hex]
//!             [--checkpoint-dir dir [--checkpoint-every K]] [--resume]
//! ddopt executor --bind 127.0.0.1:7077 [--threads N] [--once]
//!                [--metrics-addr 127.0.0.1:9090]  (Prometheus text on GET /metrics)
//!                [--chaos-abort-step N]  (fault injection: abort on Nth step)
//!                [--chaos seed=1,delay=MS,drop=P,trunc=P,partition=P[,after=K,window=W]]
//! ddopt chaosproxy LISTEN CONNECT --chaos seed=1,...  (seeded faulty TCP forwarder)
//! ddopt exp <table1|fig3|fig4|fig5|fig6|perf|ablations|stragglers|all>
//!           [--scale small|paper] [--seed N]  (seed: stragglers scenario seed)
//! ddopt gen-data --out data.libsvm [--n 1000 --m 500 --density 0.01] [--seed N]
//! ddopt fstar [--lambda 0.1] [dataset flags as in train]
//! ddopt artifacts-info
//! ```
//!
//! `--cluster dist:...` runs each superstep on real executor processes
//! (start them first with `ddopt executor`); final weights are bitwise
//! identical to `--cluster sim` at the same seed, and `--wire-out`
//! records the measured per-superstep wall time and bytes on the wire
//! next to the simulated clock.  `--dist-wire broadcast` disables the
//! negotiated sliced-scatter/folded-gather wire optimizations (same
//! bits, more bytes) — useful as a baseline and for byte A/B tests.
//!
//! `--checkpoint-dir` writes a versioned coordinator snapshot every
//! `--checkpoint-every` iterations (default 1); `--resume` picks up the
//! latest snapshot in that directory and continues bitwise-identically.
//! `executor --chaos-abort-step N` makes the executor `abort()` upon
//! receiving its Nth superstep frame — the fault-injection hook the
//! recovery tests and the CI kill-and-recover scenario use.
//! `executor --chaos ...` injects seeded, deterministic network faults
//! (delays, drops, truncated frames, one-way partitions) into the
//! executor's outgoing frames; `ddopt chaosproxy` applies the same
//! fault model to any TCP link without touching either endpoint.
//! `--dist-spec` arms speculative re-execution: when a gather stalls
//! past the latency quantile, backup copies of the lagging tasks are
//! dispatched to idle executors and the first valid result wins.
//! `--trace-out FILE` records superstep spans (driver phases, per-task
//! executor spans over the wire, instant events for every
//! retry/rejoin/degrade/speculation) and writes Chrome trace-event JSON
//! — load it at <https://ui.perfetto.dev> — plus a raw `.jsonl` event
//! log next to it.  `executor --metrics-addr HOST:PORT` serves the
//! executor's counters as Prometheus text on `GET /metrics`.

use anyhow::{anyhow, bail, Result};
use ddopt::bench_harness::{self, Scale};
use ddopt::cluster::ClusterConfig;
use ddopt::config::{DatasetSpec, ExperimentConfig};
use ddopt::coordinator::{
    Admm, AdmmConfig, BetaSchedule, D3ca, D3caConfig, Driver, Optimizer,
    Radisa, RadisaConfig,
};
use ddopt::data::{Grid, Partitioned};
use ddopt::loss::Loss;
use ddopt::metrics::write_csv;
use ddopt::runtime::Backend;
use ddopt::solvers::exact::reference_optimum;
use ddopt::util::cli::Args;
use std::path::Path;

fn main() {
    let args = Args::from_env();
    let cmd = args.positional.first().cloned().unwrap_or_default();
    let code = match cmd.as_str() {
        "train" => run_train(&args),
        "executor" => run_executor(&args),
        "chaosproxy" => run_chaosproxy(&args),
        "exp" => run_exp(&args),
        "gen-data" => run_gen_data(&args),
        "fstar" => run_fstar(&args),
        "artifacts-info" => run_artifacts_info(&args),
        _ => {
            eprintln!(
                "usage: ddopt <train|executor|chaosproxy|exp|gen-data|fstar|artifacts-info> [flags]"
            );
            eprintln!("  train     train one method (--method radisa|radisa-avg|d3ca|admm,");
            eprintln!("            --cluster sim|dist:host:port[,host:port...], --scenario ..., see README)");
            eprintln!("  executor  serve superstep tasks for a dist driver (--bind host:port)");
            eprintln!("  chaosproxy  seeded faulty TCP forwarder (chaosproxy LISTEN CONNECT --chaos ...)");
            eprintln!("  exp       regenerate paper tables/figures (table1|fig3..fig6|perf|ablations|stragglers|all)");
            eprintln!("  gen-data  write a synthetic LIBSVM file (--out file)");
            eprintln!("  fstar     compute the reference optimum for a dataset");
            eprintln!("  artifacts-info  describe the staged XLA artifacts");
            eprintln!("see rust/src/main.rs docs or rust/README.md for every flag");
            Err(anyhow!("unknown command '{cmd}'"))
        }
    };
    if let Err(e) = code {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn config_from_args(args: &Args) -> Result<ExperimentConfig> {
    let mut cfg = match args.flag_str("config") {
        Some(path) => ExperimentConfig::from_file(Path::new(&path))?,
        None => ExperimentConfig::default(),
    };
    if let Some(p) = args.flag::<usize>("p") {
        cfg.p = p;
    }
    if let Some(q) = args.flag::<usize>("q") {
        cfg.q = q;
    }
    if let Some(l) = args.flag::<f32>("lambda") {
        cfg.lambda = l;
        cfg.rho = l;
    }
    if let Some(g) = args.flag::<f32>("gamma") {
        cfg.gamma = g;
    }
    if let Some(i) = args.flag::<usize>("iters") {
        cfg.iterations = i;
    }
    if let Some(s) = args.flag::<u64>("seed") {
        cfg.seed = s;
    }
    if let Some(b) = args.flag_str("backend") {
        cfg.backend = b;
    }
    if let Some(c) = args.flag::<usize>("cores") {
        cfg.cluster.cores = c;
    }
    if let Some(t) = args.flag::<usize>("threads") {
        cfg.cluster.threads = t;
    }
    if let Some(s) = args.flag_str("scenario") {
        cfg.cluster.scenario = ddopt::cluster::ClusterScenario::parse(&s)?;
    }
    if let Some(c) = args.flag_str("cluster") {
        cfg.cluster.mode = ddopt::cluster::ClusterMode::parse(&c)?;
    }
    if let Some(w) = args.flag_str("dist-wire") {
        cfg.cluster.wire = ddopt::cluster::WireMode::parse(&w)?;
    }
    if let Some(s) = args.flag_str("dist-spec") {
        // bare `--dist-spec` parses as the switch value "true": defaults
        let (q, k) = if s == "true" || s.is_empty() {
            (0.75, 1)
        } else {
            ddopt::cluster::parse_dist_spec(&s)?
        };
        cfg.cluster.dist_spec = true;
        cfg.cluster.scenario.spec_quantile = q;
        cfg.cluster.scenario.spec_copies = k;
    }
    if let Some(l) = args.flag_str("loss") {
        cfg.loss = Loss::parse(&l).ok_or_else(|| anyhow!("bad loss '{l}'"))?;
    }
    if let Some(n_per) = args.flag::<usize>("n-per") {
        let m_per = args.flag::<usize>("m-per").unwrap_or(n_per);
        cfg.dataset = DatasetSpec::Dense { n_per, m_per };
    }
    if let Some(spec) = args.flag_str("sparse") {
        let parts: Vec<&str> = spec.split(',').collect();
        if parts.len() != 3 {
            bail!("--sparse wants n,m,density");
        }
        cfg.dataset = DatasetSpec::Sparse {
            n: parts[0].parse()?,
            m: parts[1].parse()?,
            density: parts[2].parse()?,
        };
    }
    if let Some(path) = args.flag_str("libsvm") {
        cfg.dataset = DatasetSpec::Libsvm { path };
    }
    if let Some(d) = args.flag_str("checkpoint-dir") {
        if !d.is_empty() {
            cfg.checkpoint_dir = Some(d);
        }
    }
    if let Some(k) = args.flag::<usize>("checkpoint-every") {
        cfg.checkpoint_every = k;
    }
    Ok(cfg)
}

fn make_backend(cfg: &ExperimentConfig) -> Result<Backend> {
    match cfg.backend.as_str() {
        "xla" => make_xla_backend(),
        _ => Ok(Backend::native()),
    }
}

#[cfg(feature = "xla")]
fn make_xla_backend() -> Result<Backend> {
    Backend::xla(Path::new("artifacts"))
}

#[cfg(not(feature = "xla"))]
fn make_xla_backend() -> Result<Backend> {
    bail!("this binary was built without the `xla` feature; rebuild with `cargo build --features xla`")
}

fn run_train(args: &Args) -> Result<()> {
    let cfg = config_from_args(args)?;
    let method = args.flag_str("method").unwrap_or_else(|| "radisa".into());
    let no_fstar = args.switch("no-fstar");
    let resume = args.switch("resume");
    let out = args.flag_str("out");
    let wire_out = args.flag_str("wire-out");
    let trace_out = args.flag_str("trace-out");
    let dump_w = args.flag_str("dump-w");
    args.finish().map_err(|e| anyhow!(e))?;

    let ds = cfg.build_dataset()?;
    println!(
        "dataset {} ({} x {}, sparsity {:.3}%)  grid {}x{}  lambda={:.1e}  backend={}  cluster={}  threads={}  scenario={}",
        ds.name, ds.n(), ds.m(), 100.0 * ds.sparsity(),
        cfg.p, cfg.q, cfg.lambda, cfg.backend, cfg.cluster.mode.label(),
        cfg.cluster.threads, cfg.cluster.scenario.label()
    );
    let part = Partitioned::split(&ds, Grid::new(cfg.p, cfg.q));
    let backend = make_backend(&cfg)?;

    let mut opt: Box<dyn Optimizer> = match method.as_str() {
        "radisa" | "radisa-avg" => Box::new(Radisa::new(RadisaConfig {
            lambda: cfg.lambda,
            loss: cfg.loss,
            gamma: cfg.gamma,
            batch: cfg.batch,
            average: method == "radisa-avg",
            grad_refresh: 1,
            seed: cfg.seed,
        })),
        "d3ca" => Box::new(D3ca::new(D3caConfig {
            lambda: cfg.lambda,
            local_epochs: 1.0,
            beta: BetaSchedule::RowNorm,
            seed: cfg.seed,
            ..Default::default()
        })),
        "admm" => Box::new(Admm::new(AdmmConfig {
            lambda: cfg.lambda,
            rho: cfg.rho,
        })),
        other => bail!("unknown method '{other}'"),
    };

    let mut driver = Driver::new(&part, &backend)?
        .iterations(cfg.iterations)
        .cluster(ClusterConfig { cores: cfg.cluster.cores, ..cfg.cluster.clone() })
        .trace(trace_out.is_some());
    if let Some(dir) = &cfg.checkpoint_dir {
        let every = if cfg.checkpoint_every == 0 { 1 } else { cfg.checkpoint_every };
        driver = driver.checkpoints(dir, every).resume(resume);
        println!("checkpoints -> {dir} (every {every} iteration{})",
            if every == 1 { "" } else { "s" });
    } else if resume {
        bail!("--resume needs --checkpoint-dir (where would the snapshot come from?)");
    }
    if !no_fstar && cfg.loss != Loss::Squared {
        let r = reference_optimum(&ds, cfg.loss, cfg.lambda, 1e-8);
        println!("f* = {:.6} (certificate {:.1e})", r.fstar, r.certificate);
        driver = driver.fstar(r.fstar);
    }
    let result = driver.run(opt.as_mut())?;
    println!(
        "\n{:>5} {:>14} {:>14} {:>12} {:>10}",
        "iter", "primal", "dual", "rel gap", "sim time"
    );
    for rec in &result.history.records {
        println!(
            "{:>5} {:>14.6} {:>14.6} {:>12} {:>10.4}",
            rec.iter,
            rec.primal,
            rec.dual,
            if rec.rel_gap.is_finite() {
                format!("{:.3e}", rec.rel_gap)
            } else {
                "-".into()
            },
            rec.sim_time
        );
    }
    println!(
        "\n{}: sim {:.3}s, wall {:.3}s, comm {:.2} MiB over {} supersteps",
        result.method,
        result.sim_time,
        result.wall_time,
        result.comm_bytes as f64 / (1 << 20) as f64,
        result.supersteps
    );
    if result.stragglers > 0 || result.failures > 0 {
        println!(
            "scenario injected {} straggler events and {} failed attempts",
            result.stragglers, result.failures
        );
    }
    if !result.wire.is_empty() {
        let steps = result.wire.len();
        let (mut w_out, mut w_in, mut wall) = (0usize, 0usize, 0.0f64);
        for r in &result.wire {
            w_out += r.bytes_out;
            w_in += r.bytes_in;
            wall += r.wall_secs;
        }
        println!(
            "wire: {} exchanges, {:.2} MiB out / {:.2} MiB in, {:.3}s measured transport+compute",
            steps,
            w_out as f64 / (1 << 20) as f64,
            w_in as f64 / (1 << 20) as f64,
            wall
        );
        // fault-tolerance run totals come from the backend's metrics
        // registry — the same source `--metrics-addr` and the perf
        // harness read — with the per-step wire records as the fallback
        // for registry-less backends
        let metric = |name: &str| -> Option<usize> {
            result
                .metrics
                .iter()
                .find(|(n, _)| n == name)
                .map(|(_, v)| *v as usize)
        };
        let retries = metric("ddopt_step_retries_total")
            .unwrap_or_else(|| result.wire.iter().map(|r| r.retries).sum());
        let rejoins = metric("ddopt_rejoins_total")
            .unwrap_or_else(|| result.wire.iter().map(|r| r.rejoins).sum());
        if retries > 0 || rejoins > 0 {
            println!(
                "recovery: {retries} superstep retr{} after {rejoins} executor rejoin{}",
                if retries == 1 { "y" } else { "ies" },
                if rejoins == 1 { "" } else { "s" }
            );
        }
        let degraded = metric("ddopt_degraded_executors").unwrap_or_else(|| {
            result
                .wire
                .iter()
                .map(|r| r.degraded_executors)
                .max()
                .unwrap_or(0)
        });
        if degraded > 0 {
            println!(
                "degraded: finished with {degraded} executor{} permanently removed (cells rebalanced)",
                if degraded == 1 { "" } else { "s" }
            );
        }
        let spec_launched = metric("ddopt_spec_launched_total")
            .unwrap_or_else(|| result.wire.iter().map(|r| r.spec_launched).sum());
        let spec_won = metric("ddopt_spec_won_total")
            .unwrap_or_else(|| result.wire.iter().map(|r| r.spec_won).sum());
        if spec_launched > 0 {
            println!("speculation: {spec_launched} backup task{} launched, {spec_won} adopted",
                if spec_launched == 1 { "" } else { "s" });
        }
    }
    if !result.metrics.is_empty() {
        println!("metrics:");
        for (name, value) in &result.metrics {
            println!("  {name} {value}");
        }
    }
    if let Some(path) = wire_out {
        if result.wire.is_empty() {
            println!("--wire-out: nothing to write (sim backend has no wire)");
        } else {
            ddopt::metrics::write_wire_jsonl(&result.wire, Path::new(&path))?;
            println!("wire records -> {path}");
        }
    }
    if let Some(path) = trace_out {
        match &result.trace {
            Some(log) => {
                let path = Path::new(&path);
                if let Some(dir) = path.parent() {
                    std::fs::create_dir_all(dir).ok();
                }
                ddopt::obs::write_chrome_trace(log, path)?;
                let events = ddopt::obs::chrome::jsonl_path_for(path);
                ddopt::obs::write_events_jsonl(log, &events)?;
                println!(
                    "trace ({} spans) -> {} (Perfetto) + {} (JSONL)",
                    log.len(),
                    path.display(),
                    events.display()
                );
            }
            None => println!("--trace-out: backend produced no trace"),
        }
    }
    if let Some(path) = dump_w {
        // bit-exact weight dump (hex of the f32 bit patterns): what the
        // dist-smoke CI job diffs between the sim and dist backends
        let mut text = String::with_capacity(result.w.len() * 9);
        for v in &result.w {
            text.push_str(&format!("{:08x}\n", v.to_bits()));
        }
        if let Some(dir) = Path::new(&path).parent() {
            std::fs::create_dir_all(dir).ok();
        }
        std::fs::write(&path, text)?;
        println!("weights (bit-exact hex) -> {path}");
    }
    if let Some(path) = out {
        write_csv(&result.history, Path::new(&path))?;
        println!("history -> {path}");
    }
    Ok(())
}

fn run_executor(args: &Args) -> Result<()> {
    let bind = args
        .flag_str("bind")
        .unwrap_or_else(|| "127.0.0.1:7077".into());
    let threads = args
        .flag::<usize>("threads")
        .unwrap_or_else(ddopt::cluster::host_threads);
    let once = args.switch("once");
    let chaos_abort_step = args.flag::<u64>("chaos-abort-step").unwrap_or(0);
    let chaos = match args.flag_str("chaos") {
        Some(spec) => Some(ddopt::cluster::dist::ChaosConfig::parse(&spec)?),
        None => None,
    };
    let metrics_addr = args.flag_str("metrics-addr");
    args.finish().map_err(|e| anyhow!(e))?;
    ddopt::cluster::dist::serve(&ddopt::cluster::dist::ExecutorConfig {
        bind,
        threads,
        once,
        chaos_abort_step,
        chaos,
        metrics_addr,
    })
}

fn run_chaosproxy(args: &Args) -> Result<()> {
    let listen = args
        .positional
        .get(1)
        .cloned()
        .ok_or_else(|| anyhow!("chaosproxy wants LISTEN and CONNECT addresses"))?;
    let connect = args
        .positional
        .get(2)
        .cloned()
        .ok_or_else(|| anyhow!("chaosproxy wants a CONNECT address"))?;
    let cfg = match args.flag_str("chaos") {
        Some(spec) => ddopt::cluster::dist::ChaosConfig::parse(&spec)?,
        None => ddopt::cluster::dist::ChaosConfig::default(),
    };
    args.finish().map_err(|e| anyhow!(e))?;
    ddopt::cluster::dist::chaosproxy(&listen, &connect, cfg)
}

fn run_exp(args: &Args) -> Result<()> {
    let which = args
        .positional
        .get(1)
        .cloned()
        .ok_or_else(|| anyhow!("exp wants an experiment id"))?;
    let scale = Scale::parse(&args.flag_str("scale").unwrap_or_else(|| "small".into()))
        .ok_or_else(|| anyhow!("--scale small|paper"))?;
    // scenario seed for the stragglers sweep (ignored by the other ids)
    let seed = args.flag::<u64>("seed").unwrap_or(1);
    args.finish().map_err(|e| anyhow!(e))?;
    match which.as_str() {
        "table1" => bench_harness::table1::run(scale),
        "fig3" => bench_harness::fig3::run(scale),
        "fig4" => bench_harness::fig4::run(scale),
        "fig5" => bench_harness::fig5::run(scale),
        "fig6" => bench_harness::fig6::run(scale),
        "perf" => bench_harness::perf::run(scale),
        "ablations" => bench_harness::ablations::run(scale),
        "stragglers" => bench_harness::stragglers::run(scale, seed),
        "all" => {
            bench_harness::table1::run(scale)?;
            bench_harness::fig3::run(scale)?;
            bench_harness::fig4::run(scale)?;
            bench_harness::fig5::run(scale)?;
            bench_harness::fig6::run(scale)?;
            bench_harness::stragglers::run(scale, seed)?;
            bench_harness::perf::run(scale)
        }
        other => bail!("unknown experiment '{other}'"),
    }
}

fn run_gen_data(args: &Args) -> Result<()> {
    let out = args
        .flag_str("out")
        .ok_or_else(|| anyhow!("gen-data wants --out"))?;
    let n = args.flag::<usize>("n").unwrap_or(1000);
    let m = args.flag::<usize>("m").unwrap_or(500);
    let density = args.flag::<f64>("density").unwrap_or(0.01);
    let seed = args.flag::<u64>("seed").unwrap_or(42);
    args.finish().map_err(|e| anyhow!(e))?;
    let ds = ddopt::data::SyntheticSparse::new("generated", n, m, density, seed).build();
    ddopt::data::write_libsvm(&ds, Path::new(&out))?;
    println!(
        "wrote {} ({} x {}, {} nnz) -> {out}",
        ds.name, n, m, ds.x.nnz()
    );
    Ok(())
}

fn run_fstar(args: &Args) -> Result<()> {
    let cfg = config_from_args(args)?;
    args.finish().map_err(|e| anyhow!(e))?;
    let ds = cfg.build_dataset()?;
    let r = reference_optimum(&ds, cfg.loss, cfg.lambda, 1e-9);
    println!(
        "{} lambda={:.3e}: f* = {:.8} (certificate {:.2e}, cached: {})",
        ds.name, cfg.lambda, r.fstar, r.certificate, r.from_cache
    );
    Ok(())
}

fn run_artifacts_info(args: &Args) -> Result<()> {
    args.finish().map_err(|e| anyhow!(e))?;
    let manifest = ddopt::runtime::Manifest::load(Path::new("artifacts"))?;
    println!(
        "{} artifacts, tile {}, buckets {:?}",
        manifest.len(),
        manifest.tile,
        manifest.buckets()
    );
    Ok(())
}
