//! D3CA — Doubly Distributed Dual Coordinate Ascent (Algorithm 1).
//!
//! Per global iteration t:
//!
//! 1. every partition [p,q] runs LOCALDUALMETHOD (Algorithm 2 = SDCA with
//!    the local objective scaled by 1/Q) from the shared (α[p,·], w[·,q]) —
//!    one superstep over the P×Q grid;
//! 2. dual averaging: α[p,·] += (1/(P·Q)) Σ_q Δα[p,q]   (treeAggregate
//!    over the feature partitions of each observation block);
//! 3. primal recovery through the primal-dual map (3):
//!    w[·,q] = (λn)⁻¹ Σ_p x[p,q]ᵀ α[p,·]   (a second superstep, then
//!    treeAggregate over the observation partitions of each feature
//!    block).
//!
//! Each superstep is a typed [`GridOp`] descriptor handed to the active
//! [`ClusterBackend`]: on the sim backend it runs on the in-process
//! worker pool through the zero-allocation path
//! ([`SimCluster::grid_step_into`](crate::cluster::SimCluster::grid_step_into));
//! on the dist backend the same descriptor (plus the small α/w/index
//! payloads it borrows) is shipped over TCP to the executor processes
//! that cache the grid blocks.  A persistent [`D3caWorkspace`] holds the
//! Δα and contribution slabs and the per-task index streams, so
//! steady-state iterations allocate nothing on the sim backend at any
//! `threads` setting — §V's "primal vector computation bottleneck" is
//! all compute, no allocator churn.  Reductions happen in place on the
//! slabs ([`ClusterBackend::reduce_segments`]) with the same binary-tree
//! combine order (and comm charges) as the boxed `reduce_over_*` path,
//! so iterates and clocks stay bit-identical across backends.
//!
//! With Q = 1 this reduces exactly to CoCoA.  Dual feasibility of the
//! averaged iterate is preserved because each per-partition update stays
//! in the conjugate's box and the update is a convex combination
//! (tested in `rust/tests/properties.rs`).

use super::driver::Optimizer;
use crate::cluster::{ClusterBackend, GridOp};
use crate::data::Partitioned;
use crate::loss::Loss;
use crate::runtime::StagedGrid;
use crate::util::rng::Xoshiro;
use anyhow::{bail, Result};

/// Step-size policy for the local SDCA denominator (paper §III: for small
/// λ the ‖x_i‖² denominator destabilizes; β replaces it).
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum BetaSchedule {
    /// Use ‖x_i‖² (vanilla SDCA closed form).
    RowNorm,
    /// β = λ·n / t — the paper's stabilization, scaled by n to live on the
    /// same scale as ‖x_i‖² (its printed form β = λ/t under-scales by n;
    /// see EXPERIMENTS.md notes).
    LambdaNOverT,
    /// Fixed constant.
    Const(f32),
}

#[derive(Clone, Debug)]
pub struct D3caConfig {
    pub lambda: f32,
    /// Local SDCA steps as a multiple of the partition's row count
    /// (1.0 = one local epoch, the CoCoA default).
    pub local_epochs: f32,
    pub beta: BetaSchedule,
    /// Dual averaging factor: `true` = the paper's 1/(P·Q) (Algorithm 1
    /// step 6); `false` = plain 1/Q feature averaging (the CoCoA-adding
    /// flavour) — ablated in `ddopt exp ablations`.
    pub avg_pq: bool,
    /// Primal recovery mode (paper §V: "removing the bottleneck of the
    /// primal vector computation would result into a significant
    /// speedup"): `false` recomputes w[·,q] = (λn)⁻¹ Σ_p x[p,q]ᵀ α[p,·]
    /// from the full dual (Algorithm 1 step 9); `true` applies the exact
    /// incremental identity w += (λn)⁻¹ Σ_p x[p,q]ᵀ Δα[p,·], whose cost
    /// scales with the *visited* rows (a win when local_epochs < 1).
    pub incremental_primal: bool,
    pub seed: u64,
}

impl Default for D3caConfig {
    fn default() -> Self {
        D3caConfig {
            lambda: 1e-2,
            local_epochs: 1.0,
            beta: BetaSchedule::RowNorm,
            avg_pq: true,
            incremental_primal: false,
            seed: 1,
        }
    }
}

/// Persistent per-run working memory — allocated once in `init`, reused
/// by every iteration (steady state allocates nothing).  Per-worker
/// kernel scratch lives backend-side ([`crate::cluster::OpScratch`]).
struct D3caWorkspace {
    /// Δα slab: observation group p starts at `delta_off[p]` and holds qq
    /// segments of n_p each (task (p,q) writes segment q).
    delta: Vec<f32>,
    delta_off: Vec<usize>,
    /// Scaled dual update of the last iteration, length n (feeds the
    /// incremental primal mode).
    upd: Vec<f32>,
    /// Primal contribution slab: task (p,q) at `p*m + c0(q)`, length m_q.
    contrib: Vec<f32>,
    /// Per-task index streams, refilled in place each iteration.
    idx: Vec<i32>,
    /// (start, len) of task (p,q)'s stream in `idx`, indexed `p*qq + q`.
    idx_off: Vec<(usize, usize)>,
    /// Per-task local SDCA step counts (fixed across iterations).
    h: Vec<usize>,
}

/// D3CA state: the global dual α (concatenated over observation
/// partitions) and primal w (concatenated over feature partitions).
pub struct D3ca {
    cfg: D3caConfig,
    alpha: Vec<f32>,
    w: Vec<f32>,
    rng_root: Xoshiro,
    n: usize,
    ws: Option<D3caWorkspace>,
}

impl D3ca {
    pub fn new(cfg: D3caConfig) -> D3ca {
        let rng_root = Xoshiro::new(cfg.seed).substream(0xD3CA, 0, 0);
        D3ca { cfg, alpha: Vec::new(), w: Vec::new(), rng_root, n: 0, ws: None }
    }

    pub fn alpha(&self) -> &[f32] {
        &self.alpha
    }

    fn beta_at(&self, t: usize) -> f32 {
        match self.cfg.beta {
            BetaSchedule::RowNorm => 0.0,
            BetaSchedule::LambdaNOverT => self.cfg.lambda * self.n as f32 / t as f32,
            BetaSchedule::Const(b) => b,
        }
    }
}

impl Optimizer for D3ca {
    fn name(&self) -> String {
        "d3ca".into()
    }

    fn loss(&self) -> Loss {
        Loss::Hinge
    }

    fn lambda(&self) -> f32 {
        self.cfg.lambda
    }

    fn init(
        &mut self,
        staged: &StagedGrid<'_>,
        _cluster: &mut dyn ClusterBackend,
    ) -> Result<()> {
        let part = staged.part;
        if !Loss::Hinge.has_sdca_closed_form() {
            bail!("D3CA requires the hinge closed form");
        }
        self.n = part.n;
        self.alpha = vec![0.0; part.n];
        self.w = vec![0.0; part.m];

        let (pp, qq) = (part.grid.p, part.grid.q);
        let mut delta_off = Vec::with_capacity(pp);
        let mut acc = 0usize;
        for p in 0..pp {
            delta_off.push(acc);
            acc += qq * part.n_p(p);
        }
        let mut idx_off = Vec::with_capacity(pp * qq);
        let mut h = Vec::with_capacity(pp * qq);
        let mut idx_len = 0usize;
        for p in 0..pp {
            let n_p = part.n_p(p);
            let h_p = ((n_p as f32 * self.cfg.local_epochs).round() as usize).max(1);
            for _q in 0..qq {
                let len = n_p.min(h_p);
                idx_off.push((idx_len, len));
                h.push(h_p);
                idx_len += len;
            }
        }
        self.ws = Some(D3caWorkspace {
            delta: vec![0.0; acc],
            delta_off,
            upd: vec![0.0; part.n],
            contrib: vec![0.0; pp * part.m],
            idx: vec![0; idx_len],
            idx_off,
            h,
        });
        Ok(())
    }

    fn iterate(
        &mut self,
        t: usize,
        staged: &StagedGrid<'_>,
        cluster: &mut dyn ClusterBackend,
    ) -> Result<()> {
        let part: &Partitioned = staged.part;
        let (pp, qq) = (part.grid.p, part.grid.q);
        let lamn = self.cfg.lambda * part.n as f32;
        let invq = 1.0 / qq as f32;
        let beta = self.beta_at(t);

        // Broadcast current w[·,q] to the P partitions of each column and
        // α[p,·] to the Q partitions of each row (cost model only — the
        // dist backend ships the actual vectors inside the op payload).
        for q in 0..qq {
            cluster.broadcast_cost(part.m_q(q) * 4, pp);
        }
        for p in 0..pp {
            cluster.broadcast_cost(part.n_p(p) * 4, qq);
        }

        let ws = self.ws.as_mut().expect("init before iterate");

        // Refill the per-task visit streams for this iteration (same
        // substream keys and draws as the allocating path).
        for p in 0..pp {
            for q in 0..qq {
                let (s, len) = ws.idx_off[p * qq + q];
                let mut rng = self.rng_root.substream(p as u64, q as u64, t as u64);
                rng.fill_index_stream(part.n_p(p), &mut ws.idx[s..s + len]);
            }
        }

        // Steps 2-4: local dual methods — one superstep, one task per
        // partition, each writing its Δα into its slab segment.
        cluster.grid_exec(
            staged,
            GridOp::Sdca {
                alpha: &self.alpha,
                w: &self.w,
                idx: &ws.idx,
                idx_off: &ws.idx_off,
                h: &ws.h,
                lamn,
                invq,
                beta,
            },
            &mut ws.delta,
            &mut [],
        )?;

        // Steps 5-7: α[p,·] += scale · Σ_q Δα[p,q]  (in-place tree reduce
        // over q; scale = 1/(P·Q) per the paper, or 1/Q under the
        // ablation).  The scaled update is kept for the incremental
        // primal mode.
        let scale = if self.cfg.avg_pq {
            1.0 / (pp * qq) as f32
        } else {
            1.0 / qq as f32
        };
        for p in 0..pp {
            let (r0, r1) = part.row_ranges[p];
            let n_p = r1 - r0;
            cluster.reduce_segments(&mut ws.delta, ws.delta_off[p], n_p, qq, n_p);
            let sum = &ws.delta[ws.delta_off[p]..ws.delta_off[p] + n_p];
            for (k, &s) in sum.iter().enumerate() {
                let u = scale * s;
                ws.upd[r0 + k] = u;
                self.alpha[r0 + k] += u;
            }
        }

        // Steps 8-10: primal recovery — a second superstep over the grid,
        // then an in-place tree reduce over p per feature column.  Full
        // mode recomputes w from α; incremental mode applies the exact
        // linear identity from the dual *update* only.
        let m = part.m;
        let incremental = self.cfg.incremental_primal;
        {
            let v: &[f32] = if incremental { &ws.upd } else { &self.alpha };
            cluster.grid_exec(staged, GridOp::Atx { v }, &mut ws.contrib, &mut [])?;
        }
        for q in 0..qq {
            let (c0, c1) = part.col_ranges[q];
            cluster.reduce_segments(&mut ws.contrib, c0, m, pp, c1 - c0);
            let sum = &ws.contrib[c0..c1];
            if incremental {
                for (wv, &s) in self.w[c0..c1].iter_mut().zip(sum) {
                    *wv += s / lamn;
                }
            } else {
                for (wv, &s) in self.w[c0..c1].iter_mut().zip(sum) {
                    *wv = s / lamn;
                }
            }
        }
        Ok(())
    }

    fn w(&self) -> &[f32] {
        &self.w
    }

    fn save_state(&self, buf: &mut Vec<u8>) {
        // α and w are the whole mutable state: the RNG is stateless
        // (per-iteration substreams) and the workspace is written before
        // read every iteration
        crate::util::bytes::put_f32s(buf, &self.alpha);
        crate::util::bytes::put_f32s(buf, &self.w);
    }

    fn restore_state(&mut self, r: &mut crate::util::bytes::ByteReader<'_>) -> Result<()> {
        super::checkpoint::restore_f32s(r, &mut self.alpha, "alpha")?;
        super::checkpoint::restore_f32s(r, &mut self.w, "w")
    }

    fn dual_objective(&self, staged: &StagedGrid<'_>) -> Result<Option<f64>> {
        let part = staged.part;
        let mut lin = 0.0f64;
        for p in 0..part.grid.p {
            let (r0, r1) = part.row_ranges[p];
            lin += staged.dual_linear_sum(p, &self.alpha[r0..r1])?;
        }
        let d = lin / part.n as f64
            - 0.5 * self.cfg.lambda as f64 * crate::linalg::nrm2_sq(&self.w) as f64;
        Ok(Some(d))
    }
}
