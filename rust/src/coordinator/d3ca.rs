//! D3CA — Doubly Distributed Dual Coordinate Ascent (Algorithm 1).
//!
//! Per global iteration t:
//!
//! 1. every partition [p,q] runs LOCALDUALMETHOD (Algorithm 2 = SDCA with
//!    the local objective scaled by 1/Q) from the shared (α[p,·], w[·,q]) —
//!    one superstep over the P×Q grid;
//! 2. dual averaging: α[p,·] += (1/(P·Q)) Σ_q Δα[p,q]   (treeAggregate
//!    over the feature partitions of each observation block);
//! 3. primal recovery through the primal-dual map (3):
//!    w[·,q] = (λn)⁻¹ Σ_p x[p,q]ᵀ α[p,·]   (a second superstep, then
//!    treeAggregate over the observation partitions of each feature
//!    block).
//!
//! All per-partition execution flows through
//! [`SimCluster::grid_step`](crate::cluster::SimCluster::grid_step): the
//! engine runs the tasks on the worker pool, measures them, and charges
//! the LPT makespan — this coordinator never touches timers or the
//! schedule directly.
//!
//! With Q = 1 this reduces exactly to CoCoA.  Dual feasibility of the
//! averaged iterate is preserved because each per-partition update stays
//! in the conjugate's box and the update is a convex combination
//! (tested in `rust/tests/properties.rs`).

use super::driver::Optimizer;
use crate::cluster::{SimCluster, StepPlan};
use crate::data::Partitioned;
use crate::loss::Loss;
use crate::runtime::StagedGrid;
use crate::util::rng::Xoshiro;
use anyhow::{bail, Result};

/// Step-size policy for the local SDCA denominator (paper §III: for small
/// λ the ‖x_i‖² denominator destabilizes; β replaces it).
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum BetaSchedule {
    /// Use ‖x_i‖² (vanilla SDCA closed form).
    RowNorm,
    /// β = λ·n / t — the paper's stabilization, scaled by n to live on the
    /// same scale as ‖x_i‖² (its printed form β = λ/t under-scales by n;
    /// see EXPERIMENTS.md notes).
    LambdaNOverT,
    /// Fixed constant.
    Const(f32),
}

#[derive(Clone, Debug)]
pub struct D3caConfig {
    pub lambda: f32,
    /// Local SDCA steps as a multiple of the partition's row count
    /// (1.0 = one local epoch, the CoCoA default).
    pub local_epochs: f32,
    pub beta: BetaSchedule,
    /// Dual averaging factor: `true` = the paper's 1/(P·Q) (Algorithm 1
    /// step 6); `false` = plain 1/Q feature averaging (the CoCoA-adding
    /// flavour) — ablated in `ddopt exp ablations`.
    pub avg_pq: bool,
    /// Primal recovery mode (paper §V: "removing the bottleneck of the
    /// primal vector computation would result into a significant
    /// speedup"): `false` recomputes w[·,q] = (λn)⁻¹ Σ_p x[p,q]ᵀ α[p,·]
    /// from the full dual (Algorithm 1 step 9); `true` applies the exact
    /// incremental identity w += (λn)⁻¹ Σ_p x[p,q]ᵀ Δα[p,·], whose cost
    /// scales with the *visited* rows (a win when local_epochs < 1).
    pub incremental_primal: bool,
    pub seed: u64,
}

impl Default for D3caConfig {
    fn default() -> Self {
        D3caConfig {
            lambda: 1e-2,
            local_epochs: 1.0,
            beta: BetaSchedule::RowNorm,
            avg_pq: true,
            incremental_primal: false,
            seed: 1,
        }
    }
}

/// D3CA state: the global dual α (concatenated over observation
/// partitions) and primal w (concatenated over feature partitions).
pub struct D3ca {
    cfg: D3caConfig,
    alpha: Vec<f32>,
    w: Vec<f32>,
    rng_root: Xoshiro,
    n: usize,
}

impl D3ca {
    pub fn new(cfg: D3caConfig) -> D3ca {
        let rng_root = Xoshiro::new(cfg.seed).substream(0xD3CA, 0, 0);
        D3ca { cfg, alpha: Vec::new(), w: Vec::new(), rng_root, n: 0 }
    }

    pub fn alpha(&self) -> &[f32] {
        &self.alpha
    }

    fn beta_at(&self, t: usize) -> f32 {
        match self.cfg.beta {
            BetaSchedule::RowNorm => 0.0,
            BetaSchedule::LambdaNOverT => self.cfg.lambda * self.n as f32 / t as f32,
            BetaSchedule::Const(b) => b,
        }
    }
}

impl Optimizer for D3ca {
    fn name(&self) -> String {
        "d3ca".into()
    }

    fn loss(&self) -> Loss {
        Loss::Hinge
    }

    fn lambda(&self) -> f32 {
        self.cfg.lambda
    }

    fn init(&mut self, staged: &StagedGrid<'_>, _cluster: &mut SimCluster) -> Result<()> {
        let part = staged.part;
        if !Loss::Hinge.has_sdca_closed_form() {
            bail!("D3CA requires the hinge closed form");
        }
        self.n = part.n;
        self.alpha = vec![0.0; part.n];
        self.w = vec![0.0; part.m];
        Ok(())
    }

    fn iterate(
        &mut self,
        t: usize,
        staged: &StagedGrid<'_>,
        cluster: &mut SimCluster,
    ) -> Result<()> {
        let part: &Partitioned = staged.part;
        let (pp, qq) = (part.grid.p, part.grid.q);
        let lamn = self.cfg.lambda * part.n as f32;
        let invq = 1.0 / qq as f32;
        let beta = self.beta_at(t);

        // Broadcast current w[·,q] to the P partitions of each column and
        // α[p,·] to the Q partitions of each row (cost model only — the
        // data movement is implicit in the shared-memory simulation).
        for q in 0..qq {
            cluster.broadcast_cost(part.m_q(q) * 4, pp);
        }
        for p in 0..pp {
            cluster.broadcast_cost(part.n_p(p) * 4, qq);
        }

        // Steps 2-4: local dual methods — one superstep, one task per
        // partition, sharing α/w by reference across the worker pool.
        let deltas = {
            let (alpha, w) = (&self.alpha, &self.w);
            let mut plan = StepPlan::with_capacity(pp * qq);
            for p in 0..pp {
                let (r0, r1) = part.row_ranges[p];
                for q in 0..qq {
                    let (c0, c1) = part.col_ranges[q];
                    let n_p = r1 - r0;
                    let h = ((n_p as f32 * self.cfg.local_epochs).round() as usize).max(1);
                    let mut rng = self.rng_root.substream(p as u64, q as u64, t as u64);
                    let idx = rng.index_stream(n_p, n_p.min(h));
                    let alpha_p = &alpha[r0..r1];
                    let w_q = &w[c0..c1];
                    plan.task(move || {
                        staged.sdca_epoch(p, q, alpha_p, w_q, &idx, h, lamn, invq, beta)
                    });
                }
            }
            cluster.grid_step(plan)?
        };

        // Steps 5-7: α[p,·] += scale · Σ_q Δα[p,q]  (tree reduce over q;
        // scale = 1/(P·Q) per the paper, or 1/Q under the ablation).
        let scale = if self.cfg.avg_pq {
            1.0 / (pp * qq) as f32
        } else {
            1.0 / qq as f32
        };
        let mut upd = cluster.reduce_over_q(deltas, pp, qq);
        for (p, sum) in upd.iter_mut().enumerate() {
            let (r0, r1) = part.row_ranges[p];
            crate::linalg::scale(scale, sum);
            for (a, &d) in self.alpha[r0..r1].iter_mut().zip(sum.iter()) {
                *a += d;
            }
        }

        // Steps 8-10: primal recovery — a second superstep over the grid,
        // then a tree reduce over p per feature column.  Full mode
        // recomputes w from α; incremental mode applies the exact linear
        // identity from the dual *update* only.
        let contribs = {
            let alpha = &self.alpha;
            let upd = &upd;
            let mut plan = StepPlan::with_capacity(pp * qq);
            for p in 0..pp {
                let (r0, r1) = part.row_ranges[p];
                for q in 0..qq {
                    let v_p: &[f32] = if self.cfg.incremental_primal {
                        &upd[p]
                    } else {
                        &alpha[r0..r1]
                    };
                    plan.task(move || staged.atx(p, q, v_p));
                }
            }
            cluster.grid_step(plan)?
        };
        let sums = cluster.reduce_over_p(contribs, pp, qq);
        for (q, sum) in sums.into_iter().enumerate() {
            let (c0, c1) = part.col_ranges[q];
            if self.cfg.incremental_primal {
                for (wv, &s) in self.w[c0..c1].iter_mut().zip(&sum) {
                    *wv += s / lamn;
                }
            } else {
                for (wv, &s) in self.w[c0..c1].iter_mut().zip(&sum) {
                    *wv = s / lamn;
                }
            }
        }
        Ok(())
    }

    fn w(&self) -> &[f32] {
        &self.w
    }

    fn dual_objective(&self, staged: &StagedGrid<'_>) -> Result<Option<f64>> {
        let part = staged.part;
        let mut lin = 0.0f64;
        for p in 0..part.grid.p {
            let (r0, r1) = part.row_ranges[p];
            lin += staged.dual_linear_sum(p, &self.alpha[r0..r1])?;
        }
        let d = lin / part.n as f64
            - 0.5 * self.cfg.lambda as f64 * crate::linalg::nrm2_sq(&self.w) as f64;
        Ok(Some(d))
    }
}
