//! The run driver: wires an [`Optimizer`] to a staged dataset and a
//! cluster backend, evaluating the paper's metrics each iteration.
//!
//! The backend is chosen by [`ClusterConfig::mode`]: the in-process
//! [`SimBackend`] (simulated cluster, the default) or the multi-process
//! [`DistCluster`](crate::cluster::DistCluster) (real executor processes
//! over TCP; the simulated clock still runs beside the real one).
//!
//! Evaluation (primal/dual objective) happens *off the clock*, and
//! driver-side: the simulated time only advances inside
//! `Optimizer::iterate`, matching the paper's practice of timing the
//! algorithm rather than the monitoring.

use super::checkpoint::{self, Checkpoint};
use crate::cluster::{ClusterBackend, ClusterConfig, ClusterMode, DistCluster, SimBackend};
use crate::data::Partitioned;
use crate::loss::Loss;
use crate::metrics::{Recorder, WireRecord};
use crate::obs::TraceLog;
use crate::runtime::StagedGrid;
use crate::util::bytes::ByteReader;
use anyhow::{bail, Context, Result};
use std::path::PathBuf;

/// A doubly-distributed optimization method.
pub trait Optimizer {
    fn name(&self) -> String;

    fn loss(&self) -> Loss;

    /// Regularization λ (needed by the driver's objective evaluation).
    fn lambda(&self) -> f32;

    /// One-time setup (state allocation, cached factorizations, ...).
    fn init(&mut self, staged: &StagedGrid<'_>, cluster: &mut dyn ClusterBackend)
        -> Result<()>;

    /// One global iteration (t = 1, 2, ...).
    fn iterate(
        &mut self,
        t: usize,
        staged: &StagedGrid<'_>,
        cluster: &mut dyn ClusterBackend,
    ) -> Result<()>;

    /// Current global primal iterate.
    fn w(&self) -> &[f32];

    /// Current dual objective, if the method maintains a dual (D3CA).
    fn dual_objective(&self, staged: &StagedGrid<'_>) -> Result<Option<f64>> {
        let _ = staged;
        Ok(None)
    }

    /// Serialize every piece of *mutable* optimizer state into `buf`
    /// (checkpointing).  Structure rebuilt deterministically by
    /// [`Optimizer::init`] — workspaces, schedules, factorizations — is
    /// excluded; the RNG is stateless (substreams keyed by iteration),
    /// so it needs no saving either.
    fn save_state(&self, buf: &mut Vec<u8>);

    /// Inverse of [`Optimizer::save_state`], applied *after* `init()`
    /// re-ran on the same staged data — restores the saved vectors over
    /// the freshly initialized ones, erroring (never panicking) on a
    /// truncated blob or a shape mismatch.
    fn restore_state(&mut self, r: &mut ByteReader<'_>) -> Result<()>;
}

/// Outcome of a full run.
#[derive(Clone, Debug)]
pub struct RunResult {
    pub method: String,
    pub history: Recorder,
    pub w: Vec<f32>,
    pub sim_time: f64,
    pub wall_time: f64,
    pub comm_bytes: usize,
    pub messages: usize,
    pub supersteps: usize,
    /// Straggler events injected by the cluster scenario (0 when ideal).
    pub stragglers: usize,
    /// Failed task attempts injected by the cluster scenario (0 when ideal).
    pub failures: usize,
    /// Per-superstep measured transport records — real wall seconds and
    /// bytes on the wire next to the simulated charge.  Empty on the sim
    /// backend (nothing crosses a socket there).
    pub wire: Vec<WireRecord>,
    /// Fleet-wide span log when tracing was enabled (`Driver::trace`),
    /// ready for [`crate::obs::write_chrome_trace`].
    pub trace: Option<TraceLog>,
    /// Backend metrics at run end, sorted by name (counters, gauges,
    /// histogram `_count`/`_sum` rows).  Empty for backends without a
    /// registry.
    pub metrics: Vec<(String, f64)>,
}

/// Builder-style driver.
pub struct Driver<'a> {
    part: &'a Partitioned,
    staged: StagedGrid<'a>,
    cluster_config: ClusterConfig,
    iterations: usize,
    fstar: Option<f64>,
    /// Stop early once this relative gap is reached (None = run all).
    target_gap: Option<f64>,
    eval_every: usize,
    /// Directory for periodic state checkpoints (None = disabled).
    checkpoint_dir: Option<PathBuf>,
    /// Snapshot cadence in iterations (only meaningful with a dir).
    checkpoint_every: usize,
    /// Resume from the latest checkpoint in `checkpoint_dir`, if any.
    resume: bool,
    /// Record superstep spans (driver + executors) into a [`TraceLog`]
    /// surfaced on [`RunResult::trace`].
    trace: bool,
}

impl<'a> Driver<'a> {
    pub fn new(part: &'a Partitioned, backend: &'a crate::runtime::Backend) -> Result<Driver<'a>> {
        Ok(Driver {
            part,
            staged: backend.stage(part)?,
            cluster_config: ClusterConfig::default(),
            iterations: 20,
            fstar: None,
            target_gap: None,
            eval_every: 1,
            checkpoint_dir: None,
            checkpoint_every: 1,
            resume: false,
            trace: false,
        })
    }

    pub fn iterations(mut self, n: usize) -> Self {
        self.iterations = n;
        self
    }

    pub fn cluster(mut self, c: ClusterConfig) -> Self {
        self.cluster_config = c;
        self
    }

    pub fn fstar(mut self, f: f64) -> Self {
        self.fstar = Some(f);
        self
    }

    pub fn target_gap(mut self, g: f64) -> Self {
        self.target_gap = Some(g);
        self
    }

    pub fn eval_every(mut self, k: usize) -> Self {
        self.eval_every = k.max(1);
        self
    }

    /// Snapshot optimizer state to `dir` every `every` iterations (the
    /// tentpole's periodic α/w checkpoints).
    pub fn checkpoints(mut self, dir: impl Into<PathBuf>, every: usize) -> Self {
        self.checkpoint_dir = Some(dir.into());
        self.checkpoint_every = every.max(1);
        self
    }

    /// Resume from the latest checkpoint in the checkpoint dir (no-op
    /// when the dir is empty: the run simply starts fresh).
    pub fn resume(mut self, yes: bool) -> Self {
        self.resume = yes;
        self
    }

    /// Record superstep spans into [`RunResult::trace`] (off by
    /// default: the tracing-off hot path costs one branch per step).
    pub fn trace(mut self, yes: bool) -> Self {
        self.trace = yes;
        self
    }

    pub fn staged(&self) -> &StagedGrid<'a> {
        &self.staged
    }

    /// Primal objective of `w` through the staged backend (off the clock).
    pub fn evaluate(&self, w: &[f32], loss: Loss, lam: f32) -> Result<f64> {
        let part = self.part;
        let mut total = 0.0f64;
        for p in 0..part.grid.p {
            let mut mg = vec![0.0f32; part.n_p(p)];
            for q in 0..part.grid.q {
                let (c0, c1) = part.col_ranges[q];
                let local = self.staged.margins(p, q, &w[c0..c1])?;
                for (acc, &v) in mg.iter_mut().zip(&local) {
                    *acc += v;
                }
            }
            total += self.staged.loss_sum(loss, p, &mg)?;
        }
        Ok(total / part.n as f64
            + 0.5 * lam as f64 * crate::linalg::nrm2_sq(w) as f64)
    }

    /// Build the cluster backend [`ClusterConfig::mode`] selects — the
    /// distributed backend connects to its executors and ships them their
    /// grid blocks here, before anything is timed.
    fn make_backend(&self) -> Result<Box<dyn ClusterBackend>> {
        match &self.cluster_config.mode {
            ClusterMode::Sim => Ok(Box::new(SimBackend::new(self.cluster_config.clone()))),
            ClusterMode::Dist(addrs) => {
                #[cfg(feature = "xla")]
                if let crate::runtime::Backend::Xla(_) = self.staged.backend {
                    anyhow::bail!(
                        "--cluster dist requires the native backend \
                         (executors stage their blocks natively)"
                    );
                }
                Ok(Box::new(DistCluster::connect(
                    self.cluster_config.clone(),
                    addrs,
                    self.part,
                )?))
            }
        }
    }

    /// Run `opt` for the configured iterations, recording the paper's
    /// metrics each `eval_every` iterations.
    pub fn run(&mut self, opt: &mut dyn Optimizer) -> Result<RunResult> {
        // The backend owns both clocks: the simulated parallel clock the
        // optimizers charge, and the host wall stopwatch `threads` (or
        // real executors) speed up.
        let mut backend = self.make_backend()?;
        if self.trace {
            // before prepare(): staging and scratch bring-up are spans
            backend.set_trace(true);
        }
        let outcome = self.run_loop(opt, backend.as_mut());
        let rec = match outcome {
            Ok(rec) => rec,
            Err(e) => {
                // orderly teardown on the failure path too: executors
                // return to their accept loop instead of logging a
                // dropped session (best effort — the executor may be
                // exactly what died)
                let _ = backend.shutdown();
                return Err(e);
            }
        };
        let result = RunResult {
            method: opt.name(),
            history: rec,
            w: opt.w().to_vec(),
            sim_time: backend.clock().now(),
            wall_time: backend.host_secs(),
            comm_bytes: backend.clock().comm_bytes(),
            messages: backend.clock().messages(),
            supersteps: backend.clock().supersteps(),
            stragglers: backend.clock().stragglers(),
            failures: backend.clock().failures(),
            wire: backend.take_wire_log(),
            trace: backend.take_trace(),
            metrics: backend.metrics_snapshot(),
        };
        backend.shutdown()?;
        Ok(result)
    }

    /// The fallible middle of [`Driver::run`] — everything between
    /// backend construction and teardown, so the caller can guarantee an
    /// orderly `shutdown()` on both the success and failure paths.
    fn run_loop(
        &self,
        opt: &mut dyn Optimizer,
        backend: &mut dyn ClusterBackend,
    ) -> Result<Recorder> {
        let lam = opt.lambda();
        // Size per-worker scratch and spawn the persistent pool workers
        // before anything is timed: bring-up is the only allocation (and
        // the only spawn) the parallel path ever pays, and it should not
        // land inside t = 1.
        backend.prepare(&self.staged)?;
        backend.warm_up();
        let mut rec = Recorder::new(self.fstar);
        opt.init(&self.staged, backend)?;
        // resume: init() above rebuilt all deterministic structure; now
        // lay the saved state vectors and clock over it, and continue
        // from the checkpointed iteration — bitwise identical to a run
        // that never stopped
        let mut start = 0usize;
        if self.resume {
            if let Some(dir) = &self.checkpoint_dir {
                if let Some(path) = checkpoint::latest_checkpoint(dir)? {
                    let ck = checkpoint::load_checkpoint(&path)?;
                    if ck.method != opt.name() {
                        bail!(
                            "checkpoint {} was written by method {:?}, not {:?}",
                            path.display(),
                            ck.method,
                            opt.name()
                        );
                    }
                    let mut r = ByteReader::new(&ck.state);
                    opt.restore_state(&mut r)
                        .with_context(|| format!("restore state from {}", path.display()))?;
                    if !r.is_empty() {
                        bail!(
                            "checkpoint {}: {} trailing state bytes",
                            path.display(),
                            r.remaining()
                        );
                    }
                    *backend.clock_mut() = ck.clock;
                    start = ck.iteration;
                    eprintln!(
                        "resumed {} from {} (iteration {start})",
                        opt.name(),
                        path.display()
                    );
                }
            }
        }
        for t in (start + 1)..=self.iterations {
            opt.iterate(t, &self.staged, backend)?;
            if let Some(dir) = &self.checkpoint_dir {
                if t % self.checkpoint_every == 0 || t == self.iterations {
                    let mut state = Vec::new();
                    opt.save_state(&mut state);
                    checkpoint::write_checkpoint(
                        dir,
                        &Checkpoint {
                            method: opt.name(),
                            iteration: t,
                            clock: backend.clock().clone(),
                            state,
                        },
                    )?;
                }
            }
            if t % self.eval_every == 0 || t == self.iterations {
                let f = self.evaluate(opt.w(), opt.loss(), lam)?;
                let d = opt
                    .dual_objective(&self.staged)?
                    .unwrap_or(f64::NAN);
                rec.push(
                    t,
                    f,
                    d,
                    backend.clock().now(),
                    backend.host_secs(),
                    backend.clock().comm_bytes(),
                );
                if let (Some(target), Some(last)) = (self.target_gap, rec.last()) {
                    if last.rel_gap.is_finite() && last.rel_gap <= target {
                        break;
                    }
                }
            }
        }
        Ok(rec)
    }
}
