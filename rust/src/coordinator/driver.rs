//! The run driver: wires an [`Optimizer`] to a staged dataset and the
//! simulated cluster, evaluating the paper's metrics each iteration.
//!
//! Evaluation (primal/dual objective) happens *off the clock*: the
//! simulated time only advances inside `Optimizer::iterate`, matching the
//! paper's practice of timing the algorithm rather than the monitoring.

use crate::cluster::{ClusterConfig, SimCluster};
use crate::data::Partitioned;
use crate::loss::Loss;
use crate::metrics::Recorder;
use crate::runtime::StagedGrid;
use anyhow::Result;

/// A doubly-distributed optimization method.
pub trait Optimizer {
    fn name(&self) -> String;

    fn loss(&self) -> Loss;

    /// Regularization λ (needed by the driver's objective evaluation).
    fn lambda(&self) -> f32;

    /// One-time setup (state allocation, cached factorizations, ...).
    fn init(&mut self, staged: &StagedGrid<'_>, cluster: &mut SimCluster) -> Result<()>;

    /// One global iteration (t = 1, 2, ...).
    fn iterate(
        &mut self,
        t: usize,
        staged: &StagedGrid<'_>,
        cluster: &mut SimCluster,
    ) -> Result<()>;

    /// Current global primal iterate.
    fn w(&self) -> &[f32];

    /// Current dual objective, if the method maintains a dual (D3CA).
    fn dual_objective(&self, staged: &StagedGrid<'_>) -> Result<Option<f64>> {
        let _ = staged;
        Ok(None)
    }
}

/// Outcome of a full run.
#[derive(Clone, Debug)]
pub struct RunResult {
    pub method: String,
    pub history: Recorder,
    pub w: Vec<f32>,
    pub sim_time: f64,
    pub wall_time: f64,
    pub comm_bytes: usize,
    pub messages: usize,
    pub supersteps: usize,
    /// Straggler events injected by the cluster scenario (0 when ideal).
    pub stragglers: usize,
    /// Failed task attempts injected by the cluster scenario (0 when ideal).
    pub failures: usize,
}

/// Builder-style driver.
pub struct Driver<'a> {
    part: &'a Partitioned,
    staged: StagedGrid<'a>,
    cluster_config: ClusterConfig,
    iterations: usize,
    fstar: Option<f64>,
    /// Stop early once this relative gap is reached (None = run all).
    target_gap: Option<f64>,
    eval_every: usize,
}

impl<'a> Driver<'a> {
    pub fn new(part: &'a Partitioned, backend: &'a crate::runtime::Backend) -> Result<Driver<'a>> {
        Ok(Driver {
            part,
            staged: backend.stage(part)?,
            cluster_config: ClusterConfig::default(),
            iterations: 20,
            fstar: None,
            target_gap: None,
            eval_every: 1,
        })
    }

    pub fn iterations(mut self, n: usize) -> Self {
        self.iterations = n;
        self
    }

    pub fn cluster(mut self, c: ClusterConfig) -> Self {
        self.cluster_config = c;
        self
    }

    pub fn fstar(mut self, f: f64) -> Self {
        self.fstar = Some(f);
        self
    }

    pub fn target_gap(mut self, g: f64) -> Self {
        self.target_gap = Some(g);
        self
    }

    pub fn eval_every(mut self, k: usize) -> Self {
        self.eval_every = k.max(1);
        self
    }

    pub fn staged(&self) -> &StagedGrid<'a> {
        &self.staged
    }

    /// Primal objective of `w` through the staged backend (off the clock).
    pub fn evaluate(&self, w: &[f32], loss: Loss, lam: f32) -> Result<f64> {
        let part = self.part;
        let mut total = 0.0f64;
        for p in 0..part.grid.p {
            let mut mg = vec![0.0f32; part.n_p(p)];
            for q in 0..part.grid.q {
                let (c0, c1) = part.col_ranges[q];
                let local = self.staged.margins(p, q, &w[c0..c1])?;
                for (acc, &v) in mg.iter_mut().zip(&local) {
                    *acc += v;
                }
            }
            total += self.staged.loss_sum(loss, p, &mg)?;
        }
        Ok(total / part.n as f64
            + 0.5 * lam as f64 * crate::linalg::nrm2_sq(w) as f64)
    }

    /// Run `opt` for the configured iterations, recording the paper's
    /// metrics each `eval_every` iterations.
    pub fn run(&mut self, opt: &mut dyn Optimizer) -> Result<RunResult> {
        let lam = opt.lambda();
        // The cluster owns both clocks: the simulated parallel clock the
        // optimizers charge, and the host wall stopwatch `threads` speeds up.
        let mut cluster = SimCluster::new(self.cluster_config.clone());
        // Spawn the persistent pool workers before anything is timed:
        // bring-up is the only allocation (and the only spawn) the
        // parallel path ever pays, and it should not land inside t = 1.
        cluster.warm_up();
        let mut rec = Recorder::new(self.fstar);
        opt.init(&self.staged, &mut cluster)?;
        for t in 1..=self.iterations {
            opt.iterate(t, &self.staged, &mut cluster)?;
            if t % self.eval_every == 0 || t == self.iterations {
                let f = self.evaluate(opt.w(), opt.loss(), lam)?;
                let d = opt
                    .dual_objective(&self.staged)?
                    .unwrap_or(f64::NAN);
                rec.push(
                    t,
                    f,
                    d,
                    cluster.clock.now(),
                    cluster.host_secs(),
                    cluster.clock.comm_bytes(),
                );
                if let (Some(target), Some(last)) = (self.target_gap, rec.last()) {
                    if last.rel_gap.is_finite() && last.rel_gap <= target {
                        break;
                    }
                }
            }
        }
        Ok(RunResult {
            method: opt.name(),
            history: rec,
            w: opt.w().to_vec(),
            sim_time: cluster.clock.now(),
            wall_time: cluster.host_secs(),
            comm_bytes: cluster.clock.comm_bytes(),
            messages: cluster.clock.messages(),
            supersteps: cluster.clock.supersteps(),
            stragglers: cluster.clock.stragglers(),
            failures: cluster.clock.failures(),
        })
    }
}
