//! Versioned on-disk checkpoints of coordinator state.
//!
//! All mutable optimizer state lives driver-side (the executors cache
//! immutable data blocks and per-superstep scratch only), and the RNG is
//! stateless — substreams are keyed by `(seed, iteration, ...)` — so a
//! checkpoint is small and complete: method name, iteration, the
//! simulated clock, and the optimizer's state vectors.  Resuming
//! re-runs the deterministic `init()` (which rebuilds structure:
//! schedules, factorizations, workspaces), restores the state blob over
//! it, and restores the clock — after which iteration `t+1` onward is
//! *bitwise* identical to an unbroken run, on either cluster substrate.
//!
//! File format (`ckpt-<iteration>.ddck`, little-endian, via
//! [`crate::util::bytes`]):
//!
//! ```text
//! magic "DDCK" (u32) | format version (u32) | method (str)
//! | iteration (usize) | sim clock | optimizer state blob
//! | FNV-1a of everything above (u64)
//! ```
//!
//! Writes go through a temp file + rename so a crash mid-write never
//! leaves a half checkpoint under the real name; loads verify the
//! checksum first, so corrupt or truncated files are rejected with a
//! clear error instead of a panic (or, worse, a silently wrong resume).

use crate::cluster::SimClock;
use crate::util::bytes::{self, ByteReader};
use anyhow::{bail, Context, Result};
use std::path::{Path, PathBuf};

/// "DDCK" — first field of every checkpoint file.
pub const CKPT_MAGIC: u32 = 0x4444_434B;
/// Bump on any layout change of the checkpoint body.
pub const CKPT_VERSION: u32 = 1;

/// One complete coordinator snapshot.
#[derive(Clone, Debug)]
pub struct Checkpoint {
    /// `Optimizer::name()` of the writer — resume refuses a mismatch.
    pub method: String,
    /// Completed global iteration this snapshot was taken after.
    pub iteration: usize,
    /// The simulated clock at that point (restored bitwise).
    pub clock: SimClock,
    /// The optimizer's `save_state` blob.
    pub state: Vec<u8>,
}

/// FNV-1a over `data` — the same dependency-free checksum the session
/// token uses; plenty to catch truncation and bit rot.
fn fnv1a(data: &[u8]) -> u64 {
    const FNV_PRIME: u64 = 0x0000_0100_0000_01b3;
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for &b in data {
        h = (h ^ b as u64).wrapping_mul(FNV_PRIME);
    }
    h
}

impl Checkpoint {
    /// Serialize to the on-disk byte layout (body + trailing checksum).
    pub fn encode(&self) -> Vec<u8> {
        let mut body = Vec::new();
        bytes::put_u32(&mut body, CKPT_MAGIC);
        bytes::put_u32(&mut body, CKPT_VERSION);
        bytes::put_str(&mut body, &self.method);
        bytes::put_usize(&mut body, self.iteration);
        self.clock.encode(&mut body);
        body.extend_from_slice(&self.state);
        let sum = fnv1a(&body);
        bytes::put_u64(&mut body, sum);
        body
    }

    /// Inverse of [`Checkpoint::encode`].  Every failure mode — short
    /// file, flipped bit, wrong magic/version — is a readable `Err`,
    /// never a panic.
    pub fn decode(data: &[u8]) -> Result<Checkpoint> {
        if data.len() < 8 {
            bail!("checkpoint truncated: {} bytes is too short to hold a checksum", data.len());
        }
        let (body, tail) = data.split_at(data.len() - 8);
        let stored = u64::from_le_bytes(tail.try_into().unwrap());
        if fnv1a(body) != stored {
            bail!("checkpoint checksum mismatch (corrupt or truncated file)");
        }
        let mut r = ByteReader::new(body);
        let magic = r.u32()?;
        if magic != CKPT_MAGIC {
            bail!("not a checkpoint file: bad magic {magic:#x}");
        }
        let version = r.u32()?;
        if version != CKPT_VERSION {
            bail!("checkpoint format v{version} is not supported (this build reads v{CKPT_VERSION})");
        }
        let method = r.str()?;
        let iteration = r.usize()?;
        let clock = SimClock::decode(&mut r)?;
        // the optimizer state blob is simply everything after the clock
        let consumed = body.len() - r.remaining();
        let state = body[consumed..].to_vec();
        Ok(Checkpoint { method, iteration, clock, state })
    }
}

/// Replace `dst` with a length-prefixed f32 vector from `r`, insisting
/// the length matches what the optimizer's `init()` allocated — a
/// checkpoint from a differently-shaped run must not resume silently.
pub fn restore_f32s(r: &mut ByteReader<'_>, dst: &mut Vec<f32>, what: &str) -> Result<()> {
    let got = r.f32s().with_context(|| format!("read checkpoint {what}"))?;
    if got.len() != dst.len() {
        bail!(
            "checkpoint {what} has {} elements, this run wants {}",
            got.len(),
            dst.len()
        );
    }
    *dst = got;
    Ok(())
}

/// Length-prefixed list of f32 vectors (ADMM's per-cell duals/shares).
pub fn save_nested_f32s(buf: &mut Vec<u8>, vecs: &[Vec<f32>]) {
    bytes::put_u32(buf, vecs.len() as u32);
    for v in vecs {
        bytes::put_f32s(buf, v);
    }
}

/// Inverse of [`save_nested_f32s`], shape-checked against `dst`.
pub fn restore_nested_f32s(
    r: &mut ByteReader<'_>,
    dst: &mut [Vec<f32>],
    what: &str,
) -> Result<()> {
    let n = r.u32()? as usize;
    if n != dst.len() {
        bail!("checkpoint {what} has {n} vectors, this run wants {}", dst.len());
    }
    for (i, v) in dst.iter_mut().enumerate() {
        restore_f32s(r, v, &format!("{what}[{i}]"))?;
    }
    Ok(())
}

/// Canonical file name of the checkpoint taken after `iteration`.
pub fn checkpoint_path(dir: &Path, iteration: usize) -> PathBuf {
    dir.join(format!("ckpt-{iteration}.ddck"))
}

/// Write `ck` under its canonical name, atomically (temp + rename).
pub fn write_checkpoint(dir: &Path, ck: &Checkpoint) -> Result<PathBuf> {
    std::fs::create_dir_all(dir)
        .with_context(|| format!("create checkpoint dir {}", dir.display()))?;
    let path = checkpoint_path(dir, ck.iteration);
    let tmp = dir.join(format!(".ckpt-{}.ddck.tmp", ck.iteration));
    std::fs::write(&tmp, ck.encode())
        .with_context(|| format!("write checkpoint {}", tmp.display()))?;
    std::fs::rename(&tmp, &path)
        .with_context(|| format!("publish checkpoint {}", path.display()))?;
    Ok(path)
}

/// Load and verify one checkpoint file.
pub fn load_checkpoint(path: &Path) -> Result<Checkpoint> {
    let data = std::fs::read(path)
        .with_context(|| format!("read checkpoint {}", path.display()))?;
    Checkpoint::decode(&data).with_context(|| format!("decode checkpoint {}", path.display()))
}

/// The highest-iteration `ckpt-*.ddck` in `dir`, if any (a missing or
/// empty directory is simply "nothing to resume from").
pub fn latest_checkpoint(dir: &Path) -> Result<Option<PathBuf>> {
    let entries = match std::fs::read_dir(dir) {
        Ok(e) => e,
        Err(e) if e.kind() == std::io::ErrorKind::NotFound => return Ok(None),
        Err(e) => {
            return Err(e).with_context(|| format!("scan checkpoint dir {}", dir.display()))
        }
    };
    let mut best: Option<(usize, PathBuf)> = None;
    for entry in entries {
        let entry = entry?;
        let name = entry.file_name();
        let name = name.to_string_lossy();
        let iter = name
            .strip_prefix("ckpt-")
            .and_then(|s| s.strip_suffix(".ddck"))
            .and_then(|s| s.parse::<usize>().ok());
        if let Some(i) = iter {
            if best.as_ref().map(|(b, _)| i > *b).unwrap_or(true) {
                best = Some((i, entry.path()));
            }
        }
    }
    Ok(best.map(|(_, p)| p))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> Checkpoint {
        let mut clock = SimClock::new();
        clock.add_compute(0.125);
        Checkpoint {
            method: "d3ca".into(),
            iteration: 7,
            clock,
            state: vec![1, 2, 3, 4, 5],
        }
    }

    #[test]
    fn encode_decode_round_trips() {
        let ck = sample();
        let d = Checkpoint::decode(&ck.encode()).unwrap();
        assert_eq!(d.method, "d3ca");
        assert_eq!(d.iteration, 7);
        assert_eq!(d.state, vec![1, 2, 3, 4, 5]);
        assert_eq!(d.clock.now().to_bits(), ck.clock.now().to_bits());
    }

    #[test]
    fn corruption_and_truncation_are_errors_not_panics() {
        let enc = sample().encode();
        // flip one bit anywhere in the body
        for pos in [0, 5, enc.len() / 2, enc.len() - 9] {
            let mut bad = enc.clone();
            bad[pos] ^= 0x40;
            let err = Checkpoint::decode(&bad).unwrap_err();
            assert!(err.to_string().contains("checksum"), "pos {pos}: {err}");
        }
        // every truncation length must error cleanly
        for len in 0..enc.len() {
            assert!(Checkpoint::decode(&enc[..len]).is_err(), "len {len}");
        }
    }

    #[test]
    fn latest_checkpoint_picks_highest_iteration() {
        let dir = std::env::temp_dir().join(format!("ddck-test-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        assert!(latest_checkpoint(&dir).unwrap().is_none());
        let mut ck = sample();
        for it in [3, 12, 5] {
            ck.iteration = it;
            write_checkpoint(&dir, &ck).unwrap();
        }
        let best = latest_checkpoint(&dir).unwrap().unwrap();
        assert!(best.ends_with("ckpt-12.ddck"), "{}", best.display());
        let loaded = load_checkpoint(&best).unwrap();
        assert_eq!(loaded.iteration, 12);
        std::fs::remove_dir_all(&dir).ok();
    }
}
