//! Block-splitting ADMM (Parikh & Boyd, *Block Splitting for Distributed
//! Optimization*, 2014) — the doubly-distributed baseline the paper
//! compares against.
//!
//! Formulation (DESIGN.md):  min Σ_p ℓ_p(z_p) + (λ/2)‖w‖²,
//! z_p = Σ_q x[p,q] w_q, split with per-partition copies (w_pq, z_pq)
//! constrained to the graph G_pq = {z = x[p,q] w}, consensus w_pq = w_q,
//! and response shares z_pq = s_pq with z_p = Σ_q s_pq.  Two-block ADMM
//! then gives, per iteration:
//!
//!   1. per partition [p,q]:  (w_pq, z_pq) ← Π_{G_pq}(w_q − ůw_pq,
//!      s_pq − ůz_pq) — the graph projection through the **cached**
//!      Cholesky factor of (I + x x ᵀ) (the paper excludes this one-time
//!      factorization from reported times; so do we: it happens in
//!      `init`, off the clock);
//!   2. feature consensus + ridge prox:
//!      w_q ← (ρP/(λ+ρP)) · avg_p(w_pq + ůw_pq);
//!   3. response sharing + hinge prox (exchange trick):
//!      v_p ← prox_{ℓ_p, ρ/Q}( Σ_q (z_pq + ůz_pq) ),
//!      s_pq ← c_pq + (v_p − Σ_q c_pq)/Q  with  c_pq = z_pq + ůz_pq;
//!   4. scaled dual updates  ůw_pq += w_pq − w_q,  ůz_pq += z_pq − s_pq.
//!
//! Standard two-block convex ADMM ⇒ convergence to the global optimum;
//! the integration tests verify the gap against `f*` shrinks.

use super::driver::Optimizer;
use crate::cluster::SimCluster;
use crate::data::Partitioned;
use crate::loss::Loss;
use crate::runtime::{FactorHandle, StagedGrid};
use anyhow::Result;

#[derive(Clone, Debug)]
pub struct AdmmConfig {
    pub lambda: f32,
    /// Penalty parameter; the paper sets ρ = λ.
    pub rho: f32,
}

impl Default for AdmmConfig {
    fn default() -> Self {
        AdmmConfig { lambda: 1e-2, rho: 1e-2 }
    }
}

pub struct Admm {
    cfg: AdmmConfig,
    w: Vec<f32>,                 // consensus primal, concatenated over q
    s: Vec<Vec<f32>>,            // s_pq shares, indexed [p*Q+q][n_p]
    uw: Vec<Vec<f32>>,           // scaled duals for w consensus [p*Q+q][m_q]
    uz: Vec<Vec<f32>>,           // scaled duals for z shares    [p*Q+q][n_p]
    factors: Vec<FactorHandle>,  // cached graph-projection factors
}

impl Admm {
    pub fn new(cfg: AdmmConfig) -> Admm {
        Admm {
            cfg,
            w: Vec::new(),
            s: Vec::new(),
            uw: Vec::new(),
            uz: Vec::new(),
            factors: Vec::new(),
        }
    }
}

impl Optimizer for Admm {
    fn name(&self) -> String {
        "admm".into()
    }

    fn loss(&self) -> Loss {
        Loss::Hinge
    }

    fn lambda(&self) -> f32 {
        self.cfg.lambda
    }

    fn init(&mut self, staged: &StagedGrid<'_>, _cluster: &mut SimCluster) -> Result<()> {
        let part = staged.part;
        let (pp, qq) = (part.grid.p, part.grid.q);
        self.w = vec![0.0; part.m];
        self.s.clear();
        self.uw.clear();
        self.uz.clear();
        self.factors.clear();
        for p in 0..pp {
            for q in 0..qq {
                let n_p = part.n_p(p);
                let m_q = part.m_q(q);
                self.s.push(vec![0.0; n_p]);
                self.uw.push(vec![0.0; m_q]);
                self.uz.push(vec![0.0; n_p]);
                // Cached factorization — mirrors the paper's accounting:
                // "the Cholesky factorization ... is computed once and
                // cached"; excluded from iteration timings.
                self.factors.push(staged.admm_factor(p, q)?);
            }
        }
        Ok(())
    }

    fn iterate(
        &mut self,
        _t: usize,
        staged: &StagedGrid<'_>,
        cluster: &mut SimCluster,
    ) -> Result<()> {
        let part: &Partitioned = staged.part;
        let (pp, qq) = (part.grid.p, part.grid.q);
        let rho = self.cfg.rho;
        let lam = self.cfg.lambda;
        let k = |p: usize, q: usize| p * qq + q;

        // broadcast w_q / s targets to partitions (cost model)
        for q in 0..qq {
            cluster.broadcast_cost(part.m_q(q) * 4, pp);
        }

        // 1. graph projections (the per-iteration hot spot)
        let mut w_loc: Vec<Vec<f32>> = vec![Vec::new(); pp * qq];
        let mut z_loc: Vec<Vec<f32>> = vec![Vec::new(); pp * qq];
        let mut durations = Vec::with_capacity(pp * qq);
        for p in 0..pp {
            for q in 0..qq {
                let (c0, c1) = part.col_ranges[q];
                let i = k(p, q);
                let w_hat: Vec<f32> = self.w[c0..c1]
                    .iter()
                    .zip(&self.uw[i])
                    .map(|(&a, &b)| a - b)
                    .collect();
                let z_hat: Vec<f32> = self.s[i]
                    .iter()
                    .zip(&self.uz[i])
                    .map(|(&a, &b)| a - b)
                    .collect();
                let timer = crate::util::timer::Timer::start();
                let (wp, zp) = staged.admm_project(p, q, &self.factors[i], &w_hat, &z_hat)?;
                durations.push(timer.secs());
                w_loc[i] = wp;
                z_loc[i] = zp;
            }
        }
        cluster
            .clock
            .add_compute(crate::cluster::lpt_makespan(&durations, cluster.config.cores));

        // 2. feature consensus + ridge prox (tree reduce over p per column)
        for q in 0..qq {
            let (c0, c1) = part.col_ranges[q];
            let per_p: Vec<Vec<f32>> = (0..pp)
                .map(|p| {
                    let i = k(p, q);
                    w_loc[i]
                        .iter()
                        .zip(&self.uw[i])
                        .map(|(&a, &b)| a + b)
                        .collect()
                })
                .collect();
            let sum = cluster.reduce_sum(per_p);
            let scale = rho / (lam + rho * pp as f32);
            for (wv, &sv) in self.w[c0..c1].iter_mut().zip(&sum) {
                *wv = scale * sv;
            }
        }

        // 3. response sharing + hinge prox (tree reduce over q per row)
        for p in 0..pp {
            let n_p = part.n_p(p);
            let per_q: Vec<Vec<f32>> = (0..qq)
                .map(|q| {
                    let i = k(p, q);
                    z_loc[i]
                        .iter()
                        .zip(&self.uz[i])
                        .map(|(&a, &b)| a + b)
                        .collect()
                })
                .collect();
            let c_tot = cluster.reduce_sum(per_q);
            let v = staged.prox_hinge(p, &c_tot, rho / qq as f32, 1.0 / part.n as f32)?;
            // redistribute: s_pq = c_pq + (v − c_tot)/Q
            for q in 0..qq {
                let i = k(p, q);
                for r in 0..n_p {
                    let c_pq = z_loc[i][r] + self.uz[i][r];
                    self.s[i][r] = c_pq + (v[r] - c_tot[r]) / qq as f32;
                }
            }
        }

        // 4. scaled dual updates
        for p in 0..pp {
            for q in 0..qq {
                let (c0, c1) = part.col_ranges[q];
                let i = k(p, q);
                for (r, u) in self.uw[i].iter_mut().enumerate() {
                    *u += w_loc[i][r] - self.w[c0 + r];
                    let _ = c1;
                }
                for (r, u) in self.uz[i].iter_mut().enumerate() {
                    *u += z_loc[i][r] - self.s[i][r];
                }
            }
        }
        Ok(())
    }

    fn w(&self) -> &[f32] {
        &self.w
    }
}
