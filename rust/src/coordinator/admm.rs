//! Block-splitting ADMM (Parikh & Boyd, *Block Splitting for Distributed
//! Optimization*, 2014) — the doubly-distributed baseline the paper
//! compares against.
//!
//! Formulation (DESIGN.md):  min Σ_p ℓ_p(z_p) + (λ/2)‖w‖²,
//! z_p = Σ_q x[p,q] w_q, split with per-partition copies (w_pq, z_pq)
//! constrained to the graph G_pq = {z = x[p,q] w}, consensus w_pq = w_q,
//! and response shares z_pq = s_pq with z_p = Σ_q s_pq.  Two-block ADMM
//! then gives, per iteration:
//!
//!   1. per partition [p,q]:  (w_pq, z_pq) ← Π_{G_pq}(w_q − ůw_pq,
//!      s_pq − ůz_pq) — the graph projection through the **cached**
//!      Cholesky factor of (I + x x ᵀ) (the paper excludes this one-time
//!      factorization from reported times; so do we: it happens in
//!      `init`, off the clock);
//!   2. feature consensus + ridge prox:
//!      w_q ← (ρP/(λ+ρP)) · avg_p(w_pq + ůw_pq);
//!   3. response sharing + hinge prox (exchange trick):
//!      v_p ← prox_{ℓ_p, ρ/Q}( Σ_q (z_pq + ůz_pq) ),
//!      s_pq ← c_pq + (v_p − Σ_q c_pq)/Q  with  c_pq = z_pq + ůz_pq;
//!   4. scaled dual updates  ůw_pq += w_pq − w_q,  ůz_pq += z_pq − s_pq.
//!
//! The graph projections (one task per partition) and the hinge proxes
//! (one task per row partition) are supersteps executed through
//! [`SimCluster::grid_step`](crate::cluster::SimCluster::grid_step); the
//! consensus/sharing collectives are the cluster's grouped tree reduces.
//!
//! Standard two-block convex ADMM ⇒ convergence to the global optimum;
//! the integration tests verify the gap against `f*` shrinks.

use super::driver::Optimizer;
use crate::cluster::{SimCluster, StepPlan};
use crate::data::Partitioned;
use crate::loss::Loss;
use crate::runtime::{FactorHandle, StagedGrid};
use anyhow::Result;

#[derive(Clone, Debug)]
pub struct AdmmConfig {
    pub lambda: f32,
    /// Penalty parameter; the paper sets ρ = λ.
    pub rho: f32,
}

impl Default for AdmmConfig {
    fn default() -> Self {
        AdmmConfig { lambda: 1e-2, rho: 1e-2 }
    }
}

pub struct Admm {
    cfg: AdmmConfig,
    w: Vec<f32>,                 // consensus primal, concatenated over q
    s: Vec<Vec<f32>>,            // s_pq shares, indexed [p*Q+q][n_p]
    uw: Vec<Vec<f32>>,           // scaled duals for w consensus [p*Q+q][m_q]
    uz: Vec<Vec<f32>>,           // scaled duals for z shares    [p*Q+q][n_p]
    factors: Vec<FactorHandle>,  // cached graph-projection factors
}

impl Admm {
    pub fn new(cfg: AdmmConfig) -> Admm {
        Admm {
            cfg,
            w: Vec::new(),
            s: Vec::new(),
            uw: Vec::new(),
            uz: Vec::new(),
            factors: Vec::new(),
        }
    }
}

impl Optimizer for Admm {
    fn name(&self) -> String {
        "admm".into()
    }

    fn loss(&self) -> Loss {
        Loss::Hinge
    }

    fn lambda(&self) -> f32 {
        self.cfg.lambda
    }

    fn init(&mut self, staged: &StagedGrid<'_>, _cluster: &mut SimCluster) -> Result<()> {
        let part = staged.part;
        let (pp, qq) = (part.grid.p, part.grid.q);
        self.w = vec![0.0; part.m];
        self.s.clear();
        self.uw.clear();
        self.uz.clear();
        self.factors.clear();
        for p in 0..pp {
            for q in 0..qq {
                let n_p = part.n_p(p);
                let m_q = part.m_q(q);
                self.s.push(vec![0.0; n_p]);
                self.uw.push(vec![0.0; m_q]);
                self.uz.push(vec![0.0; n_p]);
                // Cached factorization — mirrors the paper's accounting:
                // "the Cholesky factorization ... is computed once and
                // cached"; excluded from iteration timings.
                self.factors.push(staged.admm_factor(p, q)?);
            }
        }
        Ok(())
    }

    fn iterate(
        &mut self,
        _t: usize,
        staged: &StagedGrid<'_>,
        cluster: &mut SimCluster,
    ) -> Result<()> {
        let part: &Partitioned = staged.part;
        let (pp, qq) = (part.grid.p, part.grid.q);
        let rho = self.cfg.rho;
        let lam = self.cfg.lambda;
        let k = |p: usize, q: usize| p * qq + q;

        // broadcast w_q / s targets to partitions (cost model)
        for q in 0..qq {
            cluster.broadcast_cost(part.m_q(q) * 4, pp);
        }

        // 1. graph projections (the per-iteration hot spot) — one
        // superstep over the grid, results in [p*Q+q] order
        let projections = {
            let (w, s, uw, uz, factors) =
                (&self.w, &self.s, &self.uw, &self.uz, &self.factors);
            let mut plan = StepPlan::with_capacity(pp * qq);
            for p in 0..pp {
                for q in 0..qq {
                    let (c0, c1) = part.col_ranges[q];
                    let i = k(p, q);
                    let w_hat: Vec<f32> = w[c0..c1]
                        .iter()
                        .zip(&uw[i])
                        .map(|(&a, &b)| a - b)
                        .collect();
                    let z_hat: Vec<f32> = s[i]
                        .iter()
                        .zip(&uz[i])
                        .map(|(&a, &b)| a - b)
                        .collect();
                    let factor = &factors[i];
                    plan.task(move || staged.admm_project(p, q, factor, &w_hat, &z_hat));
                }
            }
            cluster.grid_step(plan)?
        };
        let (w_loc, z_loc): (Vec<Vec<f32>>, Vec<Vec<f32>>) =
            projections.into_iter().unzip();

        // 2. feature consensus + ridge prox (tree reduce over p per column)
        let consensus_parts: Vec<Vec<f32>> = (0..pp * qq)
            .map(|i| {
                w_loc[i]
                    .iter()
                    .zip(&self.uw[i])
                    .map(|(&a, &b)| a + b)
                    .collect()
            })
            .collect();
        let sums = cluster.reduce_over_p(consensus_parts, pp, qq);
        let scale = rho / (lam + rho * pp as f32);
        for (q, sum) in sums.into_iter().enumerate() {
            let (c0, c1) = part.col_ranges[q];
            for (wv, &sv) in self.w[c0..c1].iter_mut().zip(&sum) {
                *wv = scale * sv;
            }
        }

        // 3. response sharing (tree reduce over q per row) + hinge prox —
        // the prox is a per-row-partition task, so it is its own superstep
        let share_parts: Vec<Vec<f32>> = (0..pp * qq)
            .map(|i| {
                z_loc[i]
                    .iter()
                    .zip(&self.uz[i])
                    .map(|(&a, &b)| a + b)
                    .collect()
            })
            .collect();
        let c_tots = cluster.reduce_over_q(share_parts, pp, qq);
        let vs = {
            let rho_q = rho / qq as f32;
            let inv_n = 1.0 / part.n as f32;
            let mut plan = StepPlan::with_capacity(pp);
            for (p, c_tot) in c_tots.iter().enumerate() {
                plan.task(move || staged.prox_hinge(p, c_tot, rho_q, inv_n));
            }
            cluster.grid_step(plan)?
        };
        for p in 0..pp {
            let n_p = part.n_p(p);
            let (c_tot, v) = (&c_tots[p], &vs[p]);
            // redistribute: s_pq = c_pq + (v − c_tot)/Q
            for q in 0..qq {
                let i = k(p, q);
                for r in 0..n_p {
                    let c_pq = z_loc[i][r] + self.uz[i][r];
                    self.s[i][r] = c_pq + (v[r] - c_tot[r]) / qq as f32;
                }
            }
        }

        // 4. scaled dual updates
        for p in 0..pp {
            for q in 0..qq {
                let (c0, _c1) = part.col_ranges[q];
                let i = k(p, q);
                for (r, u) in self.uw[i].iter_mut().enumerate() {
                    *u += w_loc[i][r] - self.w[c0 + r];
                }
                for (r, u) in self.uz[i].iter_mut().enumerate() {
                    *u += z_loc[i][r] - self.s[i][r];
                }
            }
        }
        Ok(())
    }

    fn w(&self) -> &[f32] {
        &self.w
    }
}
