//! Block-splitting ADMM (Parikh & Boyd, *Block Splitting for Distributed
//! Optimization*, 2014) — the doubly-distributed baseline the paper
//! compares against.
//!
//! Formulation (DESIGN.md):  min Σ_p ℓ_p(z_p) + (λ/2)‖w‖²,
//! z_p = Σ_q x[p,q] w_q, split with per-partition copies (w_pq, z_pq)
//! constrained to the graph G_pq = {z = x[p,q] w}, consensus w_pq = w_q,
//! and response shares z_pq = s_pq with z_p = Σ_q s_pq.  Two-block ADMM
//! then gives, per iteration:
//!
//!   1. per partition [p,q]:  (w_pq, z_pq) ← Π_{G_pq}(w_q − ůw_pq,
//!      s_pq − ůz_pq) — the graph projection through the **cached**
//!      Cholesky factor of (I + x x ᵀ) (the paper excludes this one-time
//!      factorization from reported times; so do we: it happens in
//!      `init` via [`ClusterBackend::prepare_admm`], off the clock, and
//!      the factors live where the blocks live — in-process on the sim
//!      backend, on the executor processes on the dist backend);
//!   2. feature consensus + ridge prox:
//!      w_q ← (ρP/(λ+ρP)) · avg_p(w_pq + ůw_pq);
//!   3. response sharing + hinge prox (exchange trick):
//!      v_p ← prox_{ℓ_p, ρ/Q}( Σ_q (z_pq + ůz_pq) ),
//!      s_pq ← c_pq + (v_p − Σ_q c_pq)/Q  with  c_pq = z_pq + ůz_pq;
//!   4. scaled dual updates  ůw_pq += w_pq − w_q,  ůz_pq += z_pq − s_pq.
//!
//! The graph projections (one task per partition) and the hinge proxes
//! (one task per row partition) are typed [`GridOp`] supersteps on the
//! active [`ClusterBackend`]: a persistent [`AdmmWorkspace`] holds the
//! ŵ/ẑ input slabs and the projection output slabs, and the
//! consensus/sharing collectives reduce in place on those slabs
//! ([`ClusterBackend::reduce_segments`]), so iterations after the first
//! allocate nothing on the sim backend at any `threads` setting.
//!
//! Standard two-block convex ADMM ⇒ convergence to the global optimum;
//! the integration tests verify the gap against `f*` shrinks.

use super::driver::Optimizer;
use crate::cluster::{ClusterBackend, GridOp};
use crate::data::Partitioned;
use crate::loss::Loss;
use crate::runtime::StagedGrid;
use anyhow::Result;

#[derive(Clone, Debug)]
pub struct AdmmConfig {
    pub lambda: f32,
    /// Penalty parameter; the paper sets ρ = λ.
    pub rho: f32,
}

impl Default for AdmmConfig {
    fn default() -> Self {
        AdmmConfig { lambda: 1e-2, rho: 1e-2 }
    }
}

/// Persistent per-run working memory — allocated once in `init`, reused
/// by every iteration (steady state allocates nothing).  Per-worker
/// solve scratch and the cached Cholesky factors live backend-side.
struct AdmmWorkspace {
    /// ŵ inputs, overwritten with the consensus parts after projection:
    /// task (p,q) at `p*m + c0(q)`, length m_q.
    w_hat: Vec<f32>,
    /// ẑ inputs, overwritten with the share parts after projection:
    /// group p at `z_off[p]`, qq segments of n_p each.
    z_hat: Vec<f32>,
    z_off: Vec<usize>,
    /// Projection outputs w_pq (same layout as `w_hat`).
    w_loc: Vec<f32>,
    /// Projection outputs z_pq (same layout as `z_hat`).
    z_loc: Vec<f32>,
    /// Reduced share totals Σ_q c_pq, length n.
    c_tot: Vec<f32>,
    /// Prox outputs v_p, length n.
    vs: Vec<f32>,
}

pub struct Admm {
    cfg: AdmmConfig,
    w: Vec<f32>,      // consensus primal, concatenated over q
    s: Vec<Vec<f32>>, // s_pq shares, indexed [p*Q+q][n_p]
    uw: Vec<Vec<f32>>, // scaled duals for w consensus [p*Q+q][m_q]
    uz: Vec<Vec<f32>>, // scaled duals for z shares    [p*Q+q][n_p]
    ws: Option<AdmmWorkspace>,
}

impl Admm {
    pub fn new(cfg: AdmmConfig) -> Admm {
        Admm {
            cfg,
            w: Vec::new(),
            s: Vec::new(),
            uw: Vec::new(),
            uz: Vec::new(),
            ws: None,
        }
    }
}

impl Optimizer for Admm {
    fn name(&self) -> String {
        "admm".into()
    }

    fn loss(&self) -> Loss {
        Loss::Hinge
    }

    fn lambda(&self) -> f32 {
        self.cfg.lambda
    }

    fn init(
        &mut self,
        staged: &StagedGrid<'_>,
        cluster: &mut dyn ClusterBackend,
    ) -> Result<()> {
        let part = staged.part;
        let (pp, qq) = (part.grid.p, part.grid.q);
        self.w = vec![0.0; part.m];
        self.s.clear();
        self.uw.clear();
        self.uz.clear();
        for p in 0..pp {
            for _q in 0..qq {
                let n_p = part.n_p(p);
                self.s.push(vec![0.0; n_p]);
                self.uz.push(vec![0.0; n_p]);
            }
        }
        for _p in 0..pp {
            for q in 0..qq {
                self.uw.push(vec![0.0; part.m_q(q)]);
            }
        }
        // Cached factorizations — mirrors the paper's accounting: "the
        // Cholesky factorization ... is computed once and cached";
        // excluded from iteration timings.  The backend owns them (the
        // dist backend has each executor factor its own cached blocks).
        cluster.prepare_admm(staged)?;
        let mut z_off = Vec::with_capacity(pp);
        let mut acc = 0usize;
        for p in 0..pp {
            z_off.push(acc);
            acc += qq * part.n_p(p);
        }
        self.ws = Some(AdmmWorkspace {
            w_hat: vec![0.0; pp * part.m],
            z_hat: vec![0.0; acc],
            z_off,
            w_loc: vec![0.0; pp * part.m],
            z_loc: vec![0.0; acc],
            c_tot: vec![0.0; part.n],
            vs: vec![0.0; part.n],
        });
        Ok(())
    }

    fn iterate(
        &mut self,
        _t: usize,
        staged: &StagedGrid<'_>,
        cluster: &mut dyn ClusterBackend,
    ) -> Result<()> {
        let part: &Partitioned = staged.part;
        let (pp, qq) = (part.grid.p, part.grid.q);
        let m = part.m;
        let rho = self.cfg.rho;
        let lam = self.cfg.lambda;
        let k = |p: usize, q: usize| p * qq + q;

        // broadcast w_q / s targets to partitions (cost model)
        for q in 0..qq {
            cluster.broadcast_cost(part.m_q(q) * 4, pp);
        }

        let ws = self.ws.as_mut().expect("init before iterate");

        // stage the projection inputs: ŵ_pq = w_q − ůw_pq, ẑ_pq = s_pq − ůz_pq
        for p in 0..pp {
            let n_p = part.n_p(p);
            let zb = ws.z_off[p];
            for q in 0..qq {
                let (c0, c1) = part.col_ranges[q];
                let i = k(p, q);
                let wh = &mut ws.w_hat[p * m + c0..p * m + c1];
                for ((h, &wv), &uv) in wh.iter_mut().zip(&self.w[c0..c1]).zip(&self.uw[i]) {
                    *h = wv - uv;
                }
                let zh = &mut ws.z_hat[zb + q * n_p..zb + (q + 1) * n_p];
                for ((h, &sv), &uv) in zh.iter_mut().zip(&self.s[i]).zip(&self.uz[i]) {
                    *h = sv - uv;
                }
            }
        }

        // 1. graph projections (the per-iteration hot spot) — one
        // superstep over the grid, outputs in the (p,q) slabs
        {
            let (w_hat, z_hat) = (&ws.w_hat, &ws.z_hat);
            cluster.grid_exec(
                staged,
                GridOp::AdmmProject { w_hat, z_hat },
                &mut ws.w_loc,
                &mut ws.z_loc,
            )?;
        }

        // 2. feature consensus + ridge prox: overwrite the ŵ slab with
        // w_pq + ůw_pq, tree-reduce in place over p per column, rescale
        for p in 0..pp {
            for q in 0..qq {
                let i = k(p, q);
                let base = p * m + part.col_ranges[q].0;
                for (r, &uv) in self.uw[i].iter().enumerate() {
                    ws.w_hat[base + r] = ws.w_loc[base + r] + uv;
                }
            }
        }
        let scale = rho / (lam + rho * pp as f32);
        for q in 0..qq {
            let (c0, c1) = part.col_ranges[q];
            cluster.reduce_segments(&mut ws.w_hat, c0, m, pp, c1 - c0);
            for (wv, &sv) in self.w[c0..c1].iter_mut().zip(&ws.w_hat[c0..c1]) {
                *wv = scale * sv;
            }
        }

        // 3. response sharing (in-place tree reduce over q per row) +
        // hinge prox — the prox is a per-row-partition task, so it is its
        // own superstep
        for p in 0..pp {
            let n_p = part.n_p(p);
            for q in 0..qq {
                let i = k(p, q);
                let base = ws.z_off[p] + q * n_p;
                for (r, &uv) in self.uz[i].iter().enumerate() {
                    ws.z_hat[base + r] = ws.z_loc[base + r] + uv;
                }
            }
        }
        for p in 0..pp {
            let (r0, r1) = part.row_ranges[p];
            let n_p = r1 - r0;
            cluster.reduce_segments(&mut ws.z_hat, ws.z_off[p], n_p, qq, n_p);
            ws.c_tot[r0..r1]
                .copy_from_slice(&ws.z_hat[ws.z_off[p]..ws.z_off[p] + n_p]);
        }
        {
            let rho_q = rho / qq as f32;
            let inv_n = 1.0 / part.n as f32;
            cluster.grid_exec(
                staged,
                GridOp::ProxHinge { c: &ws.c_tot, rho: rho_q, inv_n },
                &mut ws.vs,
                &mut [],
            )?;
        }
        for p in 0..pp {
            let (r0, r1) = part.row_ranges[p];
            let n_p = r1 - r0;
            // redistribute: s_pq = c_pq + (v − c_tot)/Q
            for q in 0..qq {
                let i = k(p, q);
                let base = ws.z_off[p] + q * n_p;
                for r in 0..n_p {
                    let c_pq = ws.z_loc[base + r] + self.uz[i][r];
                    self.s[i][r] =
                        c_pq + (ws.vs[r0 + r] - ws.c_tot[r0 + r]) / qq as f32;
                }
            }
        }

        // 4. scaled dual updates
        for p in 0..pp {
            let n_p = part.n_p(p);
            for q in 0..qq {
                let (c0, _c1) = part.col_ranges[q];
                let i = k(p, q);
                for (r, u) in self.uw[i].iter_mut().enumerate() {
                    *u += ws.w_loc[p * m + c0 + r] - self.w[c0 + r];
                }
                for (r, u) in self.uz[i].iter_mut().enumerate() {
                    *u += ws.z_loc[ws.z_off[p] + q * n_p + r] - self.s[i][r];
                }
            }
        }
        Ok(())
    }

    fn w(&self) -> &[f32] {
        &self.w
    }

    fn save_state(&self, buf: &mut Vec<u8>) {
        // consensus iterate plus every per-cell share/dual; the cached
        // factorizations are rebuilt by init() (prepare_admm) on resume
        crate::util::bytes::put_f32s(buf, &self.w);
        super::checkpoint::save_nested_f32s(buf, &self.s);
        super::checkpoint::save_nested_f32s(buf, &self.uw);
        super::checkpoint::save_nested_f32s(buf, &self.uz);
    }

    fn restore_state(&mut self, r: &mut crate::util::bytes::ByteReader<'_>) -> Result<()> {
        super::checkpoint::restore_f32s(r, &mut self.w, "w")?;
        super::checkpoint::restore_nested_f32s(r, &mut self.s, "s")?;
        super::checkpoint::restore_nested_f32s(r, &mut self.uw, "uw")?;
        super::checkpoint::restore_nested_f32s(r, &mut self.uz, "uz")
    }
}
