//! The coordinator — the paper's L3 contribution.
//!
//! * [`D3ca`] — Algorithm 1: per-partition local SDCA (1/Q-scaled), dual
//!   averaging over feature partitions, primal recovery via the
//!   primal-dual map.
//! * [`Radisa`] — Algorithm 3: SVRG snapshot + full gradient, random
//!   non-overlapping sub-block exchange, local stochastic steps,
//!   concatenation (or averaging: RADiSA-avg).
//! * [`Admm`] — the block-splitting ADMM baseline (Parikh & Boyd 2014):
//!   cached-factor graph projections + separable proxes + consensus
//!   averaging.
//!
//! All three run under the same [`Driver`], against either compute
//! backend (native/XLA) and either cluster substrate — the in-process
//! simulated cluster or the real multi-process TCP runtime
//! ([`crate::cluster::ClusterBackend`]); per-iteration state
//! (primal/dual objective, simulated time, communication bytes) lands in
//! a [`crate::metrics::Recorder`], and distributed runs additionally
//! carry per-superstep wall-clock + bytes-on-wire records.

mod admm;
pub mod checkpoint;
mod d3ca;
mod driver;
mod radisa;
pub mod schedule;

pub use admm::{Admm, AdmmConfig};
pub use checkpoint::Checkpoint;
pub use d3ca::{BetaSchedule, D3ca, D3caConfig};
pub use driver::{Driver, Optimizer, RunResult};
pub use radisa::{Radisa, RadisaConfig};
