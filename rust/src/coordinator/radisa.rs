//! RADiSA — RAndom DIstributed Stochastic Algorithm (Algorithm 3).
//!
//! Per global iteration t:
//!
//! 1. snapshot w̃ ← w; full gradient μ̃ = ∇F(w̃) computed doubly
//!    distributed: margins m̃[p] = Σ_q x[p,q] w̃[·,q] (reduce over q), then
//!    μ̃[·,q] = Σ_p (1/n) x[p,q]ᵀ ψ(m̃[p]) (reduce over p) + λ w̃;
//!    the m̃ vectors are *kept* on the row partitions — they are what lets
//!    a partition evaluate full-data stochastic gradients locally
//!    (DESIGN.md margin bookkeeping);
//! 2. each column's sub-blocks are re-dealt by a random permutation
//!    (non-overlapping exchange, Fig. 2);
//! 3. every partition runs L SVRG steps on its assigned sub-block;
//! 4. the new global iterate is the concatenation of the sub-block
//!    results — or, for RADiSA-avg (`average: true`), every partition
//!    works on the whole w[·,q] and the results are averaged over p.
//!
//! Each numbered phase is one typed [`GridOp`] superstep on the active
//! [`ClusterBackend`] — in-process worker pool on the sim backend
//! (zero-allocation steady state at any `threads`), real TCP executors
//! on the dist backend, bit-identical iterates either way.  A persistent
//! [`RadisaWorkspace`] holds the margin/gradient/result slabs and the
//! per-task index streams, and the grouped reductions run in place on
//! the slabs ([`ClusterBackend::reduce_segments`]).  On sparse blocks
//! the SVRG inner loop uses the staged sub-block window index (O(nnz in
//! window) per step).  RADiSA-avg's full-block shipping uses the
//! data-free [`ClusterBackend::reduce_cost`].

use super::driver::Optimizer;
use super::schedule::{radisa_eta, SubBlockSchedule};
use crate::cluster::{ClusterBackend, GridOp};
use crate::data::{Partitioned, SubBlocks};
use crate::loss::Loss;
use crate::runtime::StagedGrid;
use crate::util::rng::Xoshiro;
use anyhow::Result;

#[derive(Clone, Debug)]
pub struct RadisaConfig {
    pub lambda: f32,
    pub loss: Loss,
    /// Step-size constant γ in η_t = γ/(1+√(t−1)).  `0.0` selects the
    /// auto rule γ = P·Q / E‖x_i‖² (mean squared row norm measured at
    /// init): the local stochastic gradient lives on a 1/(P·Q) coordinate
    /// window, so its squared norm is ≈ E‖x_i‖²/(P·Q), and γ ≈
    /// 1/E‖x_j|win‖² keeps steps on the curvature scale.  This is also
    /// the paper's strong-scaling adjustment ("adjust the step-size as K
    /// increases by taking into account the number of observation
    /// partitions P") made explicit.
    pub gamma: f32,
    /// Inner steps per partition per iteration (0 → one pass: L = n_p).
    pub batch: usize,
    /// RADiSA-avg: full-block overlap + parameter averaging.
    pub average: bool,
    /// Delayed gradient updates (paper §V: "delaying the gradient updates
    /// can be a viable alternative"): one full-gradient snapshot anchors
    /// `grad_refresh` successive exchange+SVRG rounds; between rounds only
    /// the (much cheaper) margins pass is refreshed, so the variance
    /// anchor μ̃ is stale by at most `grad_refresh − 1` rounds — the
    /// "practical SVRG" regime of Babanezhad et al. (paper ref. [28]).
    /// 1 = vanilla RADiSA.
    pub grad_refresh: usize,
    pub seed: u64,
}

impl Default for RadisaConfig {
    fn default() -> Self {
        RadisaConfig {
            lambda: 1e-3,
            loss: Loss::Hinge,
            gamma: 0.0,
            batch: 0,
            average: false,
            grad_refresh: 1,
            seed: 1,
        }
    }
}

/// Persistent per-run working memory — allocated once in `init`, reused
/// by every iteration (steady state allocates nothing).  Per-worker
/// kernel scratch lives backend-side ([`crate::cluster::OpScratch`]).
struct RadisaWorkspace {
    /// Margin slab: group p at `mar_off[p]`, qq segments of n_p each.
    margins: Vec<f32>,
    mar_off: Vec<usize>,
    /// Reduced snapshot margins m̃, length n (partition p at row range p).
    mt: Vec<f32>,
    /// Gradient slab: task (p,q) at `p*m + c0(q)`, length m_q.
    grad: Vec<f32>,
    /// Full snapshot gradient μ̃ (+ λw̃), length m.
    mu: Vec<f32>,
    /// SVRG result slab: task (q,p) at `pp*c0(q) + p*m_q`, length m_q.
    result: Vec<f32>,
    /// Window of task (q,p), indexed `q*pp + p` (refilled per round).
    windows: Vec<(usize, usize)>,
    /// Per-task index streams (task order (q,p)), refilled per round.
    idx: Vec<i32>,
    idx_off: Vec<(usize, usize)>,
    /// Sub-block assignment scratch (length pp).
    assign: Vec<usize>,
    /// f64 accumulator for RADiSA-avg's exact average (length max m_q).
    avg_acc: Vec<f64>,
}

pub struct Radisa {
    cfg: RadisaConfig,
    w: Vec<f32>,
    rng_root: Xoshiro,
    schedule: Option<SubBlockSchedule>,
    subblocks: Option<SubBlocks>,
    gamma_eff: f32,
    ws: Option<RadisaWorkspace>,
}

impl Radisa {
    pub fn new(cfg: RadisaConfig) -> Radisa {
        let rng_root = Xoshiro::new(cfg.seed).substream(0x4AD1, 0, 0);
        let gamma_eff = cfg.gamma;
        Radisa {
            cfg,
            w: Vec::new(),
            rng_root,
            schedule: None,
            subblocks: None,
            gamma_eff,
            ws: None,
        }
    }

    /// The step-size constant actually in use (resolved after `init`).
    pub fn gamma_effective(&self) -> f32 {
        self.gamma_eff
    }

    pub fn config(&self) -> &RadisaConfig {
        &self.cfg
    }

    /// Margins pass: m[p] = Σ_q x[p,q] w[·,q] — one superstep over the
    /// grid, then an in-place reduce over q per row partition into `mt`.
    /// Run once per round — it is what keeps the local margin identity
    /// exact between delayed-gradient rounds.
    fn margins_pass(
        &mut self,
        staged: &StagedGrid<'_>,
        cluster: &mut dyn ClusterBackend,
    ) -> Result<()> {
        let part = staged.part;
        let (_pp, qq) = (part.grid.p, part.grid.q);
        let ws = self.ws.as_mut().expect("init before iterate");
        cluster.grid_exec(staged, GridOp::Margins { w: &self.w }, &mut ws.margins, &mut [])?;
        for p in 0..part.grid.p {
            let (r0, r1) = part.row_ranges[p];
            let n_p = r1 - r0;
            cluster.reduce_segments(&mut ws.margins, ws.mar_off[p], n_p, qq, n_p);
            ws.mt[r0..r1]
                .copy_from_slice(&ws.margins[ws.mar_off[p]..ws.mar_off[p] + n_p]);
        }
        Ok(())
    }

    /// Gradient pass: μ[·,q] = Σ_p (1/n) x[p,q]ᵀ ψ(m[p]) + λ w — one
    /// superstep, then an in-place reduce over p per feature partition
    /// into `mu` — the expensive half of the snapshot, skipped on delayed
    /// rounds.
    fn grad_pass(
        &mut self,
        staged: &StagedGrid<'_>,
        cluster: &mut dyn ClusterBackend,
    ) -> Result<()> {
        let part = staged.part;
        let (pp, qq) = (part.grid.p, part.grid.q);
        let m = part.m;
        let loss = self.cfg.loss;
        let ws = self.ws.as_mut().expect("init before iterate");
        cluster.grid_exec(staged, GridOp::Grad { loss, mt: &ws.mt }, &mut ws.grad, &mut [])?;
        for q in 0..qq {
            let (c0, c1) = part.col_ranges[q];
            cluster.reduce_segments(&mut ws.grad, c0, m, pp, c1 - c0);
            // + λ w̃ (the regularizer's exact gradient at the snapshot)
            for k in c0..c1 {
                ws.mu[k] = ws.grad[k] + self.cfg.lambda * self.w[k];
            }
        }
        Ok(())
    }
}

impl Optimizer for Radisa {
    fn name(&self) -> String {
        if self.cfg.average {
            "radisa-avg".into()
        } else {
            "radisa".into()
        }
    }

    fn loss(&self) -> Loss {
        self.cfg.loss
    }

    fn lambda(&self) -> f32 {
        self.cfg.lambda
    }

    fn init(
        &mut self,
        staged: &StagedGrid<'_>,
        _cluster: &mut dyn ClusterBackend,
    ) -> Result<()> {
        let part = staged.part;
        self.w = vec![0.0; part.m];
        self.schedule = Some(SubBlockSchedule::new(&self.rng_root, part.grid.p));
        self.subblocks = Some(SubBlocks::split(part));
        if self.cfg.gamma <= 0.0 {
            // mean squared row norm, accumulated across the grid
            let mut total = 0.0f64;
            for p in 0..part.grid.p {
                for q in 0..part.grid.q {
                    let b = part.block(p, q);
                    for i in 0..b.rows() {
                        total += b.row_norm_sq(i) as f64;
                    }
                }
            }
            let mean = (total / part.n as f64).max(1e-12) as f32;
            self.gamma_eff = (part.grid.p * part.grid.q) as f32 / mean;
        }

        let (pp, qq) = (part.grid.p, part.grid.q);
        let mut mar_off = Vec::with_capacity(pp);
        let mut acc = 0usize;
        for p in 0..pp {
            mar_off.push(acc);
            acc += qq * part.n_p(p);
        }
        // index streams in SVRG task order (q, p); lengths fixed across
        // iterations (they depend only on n_p and the batch size)
        let mut idx_off = Vec::with_capacity(pp * qq);
        let mut idx_len = 0usize;
        for _q in 0..qq {
            for p in 0..pp {
                let n_p = part.n_p(p);
                let l = if self.cfg.batch == 0 { n_p } else { self.cfg.batch };
                let len = n_p.min(l).max(1);
                idx_off.push((idx_len, len));
                idx_len += len;
            }
        }
        let max_mq = (0..qq).map(|q| part.m_q(q)).max().unwrap_or(0);
        self.ws = Some(RadisaWorkspace {
            margins: vec![0.0; acc],
            mar_off,
            mt: vec![0.0; part.n],
            grad: vec![0.0; pp * part.m],
            mu: vec![0.0; part.m],
            result: vec![0.0; pp * part.m],
            windows: vec![(0, 0); pp * qq],
            idx: vec![0; idx_len],
            idx_off,
            assign: vec![0; pp],
            avg_acc: vec![0.0; max_mq],
        });
        Ok(())
    }

    fn iterate(
        &mut self,
        t: usize,
        staged: &StagedGrid<'_>,
        cluster: &mut dyn ClusterBackend,
    ) -> Result<()> {
        let part: &Partitioned = staged.part;
        let (pp, qq) = (part.grid.p, part.grid.q);
        let rounds = self.cfg.grad_refresh.max(1);

        // broadcast the snapshot w̃ to every partition (cost model)
        cluster.broadcast_cost(part.m * 4, pp * qq);

        // steps 2-3: snapshot margins + full gradient (the gradient pass is
        // computed once and anchors all `rounds` exchange+SVRG rounds)
        self.margins_pass(staged, cluster)?;
        self.grad_pass(staged, cluster)?;

        for round in 0..rounds {
            if round > 0 {
                // delayed-gradient round: refresh only the margins so the
                // local margin identity stays exact; μ̃ stays stale
                self.margins_pass(staged, cluster)?;
            }
            // a distinct schedule/rng/step-size epoch per round, so k
            // delayed rounds anneal exactly like k vanilla iterations
            let tick = (t - 1) * rounds + round + 1;
            let eta = radisa_eta(self.gamma_eff, tick);
            let average = self.cfg.average;

            // refill windows + visit streams for this round (task order
            // (q, p), same substream keys as ever)
            let ws = self.ws.as_mut().expect("init before iterate");
            let schedule = self.schedule.as_ref().unwrap();
            let subblocks = self.subblocks.as_ref().unwrap();
            for q in 0..qq {
                let (c0, c1) = part.col_ranges[q];
                schedule.assignment_into(q, tick, &mut ws.assign);
                for p in 0..pp {
                    let task = q * pp + p;
                    ws.windows[task] = if average {
                        (0, c1 - c0)
                    } else {
                        subblocks.range(q, ws.assign[p])
                    };
                    let (s, len) = ws.idx_off[task];
                    let mut rng =
                        self.rng_root.substream(p as u64, q as u64, tick as u64);
                    rng.fill_index_stream(part.n_p(p), &mut ws.idx[s..s + len]);
                }
            }

            // steps 4-11: local SVRG on randomly exchanged sub-blocks —
            // one superstep over the grid, tasks ordered (q, p).
            // RADiSA-avg's combine is an average of full-block partial
            // solutions, so the coordinator "does not wait for
            // stragglers" (paper §IV): its superstep is tolerant and the
            // makespan ignores injected straggler delays and failure
            // re-charges.
            cluster.grid_exec(
                staged,
                GridOp::Svrg {
                    loss: self.cfg.loss,
                    w: &self.w,
                    mu: &ws.mu,
                    mt: &ws.mt,
                    windows: &ws.windows,
                    idx: &ws.idx,
                    idx_off: &ws.idx_off,
                    batch: self.cfg.batch,
                    eta,
                    lam: self.cfg.lambda,
                    tolerant: average,
                },
                &mut ws.result,
                &mut [],
            )?;

            // step 12: combine in task order — concatenate each partition's
            // window, or average full blocks over p (RADiSA-avg)
            for q in 0..qq {
                let (c0, c1) = part.col_ranges[q];
                let m_q = c1 - c0;
                if average {
                    let acc = &mut ws.avg_acc[..m_q];
                    acc.fill(0.0);
                    for p in 0..pp {
                        let seg = &ws.result[pp * c0 + p * m_q..pp * c0 + (p + 1) * m_q];
                        for (a, &v) in acc.iter_mut().zip(seg) {
                            *a += v as f64;
                        }
                    }
                    for (k, &a) in acc.iter().enumerate() {
                        self.w[c0 + k] = (a / pp as f64) as f32;
                    }
                    // averaging ships full blocks: reduce of P vectors of
                    // m_q f32s (cost only — the average itself is exact
                    // driver-side arithmetic)
                    cluster.reduce_cost(pp.max(2), m_q * 4);
                } else {
                    for p in 0..pp {
                        let (lo, hi) = ws.windows[q * pp + p];
                        let seg = &ws.result[pp * c0 + p * m_q..pp * c0 + (p + 1) * m_q];
                        // the P windows tile [0, m_q), so every coordinate
                        // of this column block is overwritten exactly once
                        self.w[c0 + lo..c0 + hi].copy_from_slice(&seg[lo..hi]);
                    }
                    // concatenation ships one sub-block per partition
                    cluster.broadcast_cost(m_q * 4 / pp.max(1), pp);
                }
            }
        }
        Ok(())
    }

    fn w(&self) -> &[f32] {
        &self.w
    }

    fn save_state(&self, buf: &mut Vec<u8>) {
        // w is the only state carried across iterations: the schedule,
        // sub-blocks, and γ are recomputed deterministically by init(),
        // the SVRG snapshot is rebuilt inside each iteration, and the
        // RNG streams are keyed by (iteration, round)
        crate::util::bytes::put_f32s(buf, &self.w);
    }

    fn restore_state(&mut self, r: &mut crate::util::bytes::ByteReader<'_>) -> Result<()> {
        super::checkpoint::restore_f32s(r, &mut self.w, "w")
    }
}
