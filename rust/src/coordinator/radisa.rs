//! RADiSA — RAndom DIstributed Stochastic Algorithm (Algorithm 3).
//!
//! Per global iteration t:
//!
//! 1. snapshot w̃ ← w; full gradient μ̃ = ∇F(w̃) computed doubly
//!    distributed: margins m̃[p] = Σ_q x[p,q] w̃[·,q] (reduce over q), then
//!    μ̃[·,q] = Σ_p (1/n) x[p,q]ᵀ ψ(m̃[p]) (reduce over p) + λ w̃;
//!    the m̃ vectors are *kept* on the row partitions — they are what lets
//!    a partition evaluate full-data stochastic gradients locally
//!    (DESIGN.md margin bookkeeping);
//! 2. each column's sub-blocks are re-dealt by a random permutation
//!    (non-overlapping exchange, Fig. 2);
//! 3. every partition runs L SVRG steps on its assigned sub-block;
//! 4. the new global iterate is the concatenation of the sub-block
//!    results — or, for RADiSA-avg (`average: true`), every partition
//!    works on the whole w[·,q] and the results are averaged over p.
//!
//! Each numbered phase is one superstep: the margins pass, the gradient
//! pass and the SVRG pass are [`StepPlan`]s executed by
//! [`SimCluster::grid_step`](crate::cluster::SimCluster::grid_step) on
//! the worker pool, with the collectives charged through the cluster's
//! reduce/broadcast cost model (RADiSA-avg's full-block shipping uses the
//! data-free [`SimCluster::reduce_cost`](crate::cluster::SimCluster::reduce_cost)).

use super::driver::Optimizer;
use super::schedule::{radisa_eta, SubBlockSchedule};
use crate::cluster::{SimCluster, StepPlan};
use crate::data::{Partitioned, SubBlocks};
use crate::loss::Loss;
use crate::runtime::StagedGrid;
use crate::util::rng::Xoshiro;
use anyhow::Result;

#[derive(Clone, Debug)]
pub struct RadisaConfig {
    pub lambda: f32,
    pub loss: Loss,
    /// Step-size constant γ in η_t = γ/(1+√(t−1)).  `0.0` selects the
    /// auto rule γ = P·Q / E‖x_i‖² (mean squared row norm measured at
    /// init): the local stochastic gradient lives on a 1/(P·Q) coordinate
    /// window, so its squared norm is ≈ E‖x_i‖²/(P·Q), and γ ≈
    /// 1/E‖x_j|win‖² keeps steps on the curvature scale.  This is also
    /// the paper's strong-scaling adjustment ("adjust the step-size as K
    /// increases by taking into account the number of observation
    /// partitions P") made explicit.
    pub gamma: f32,
    /// Inner steps per partition per iteration (0 → one pass: L = n_p).
    pub batch: usize,
    /// RADiSA-avg: full-block overlap + parameter averaging.
    pub average: bool,
    /// Delayed gradient updates (paper §V: "delaying the gradient updates
    /// can be a viable alternative"): one full-gradient snapshot anchors
    /// `grad_refresh` successive exchange+SVRG rounds; between rounds only
    /// the (much cheaper) margins pass is refreshed, so the variance
    /// anchor μ̃ is stale by at most `grad_refresh − 1` rounds — the
    /// "practical SVRG" regime of Babanezhad et al. (paper ref. [28]).
    /// 1 = vanilla RADiSA.
    pub grad_refresh: usize,
    pub seed: u64,
}

impl Default for RadisaConfig {
    fn default() -> Self {
        RadisaConfig {
            lambda: 1e-3,
            loss: Loss::Hinge,
            gamma: 0.0,
            batch: 0,
            average: false,
            grad_refresh: 1,
            seed: 1,
        }
    }
}

pub struct Radisa {
    cfg: RadisaConfig,
    w: Vec<f32>,
    rng_root: Xoshiro,
    schedule: Option<SubBlockSchedule>,
    subblocks: Option<SubBlocks>,
    gamma_eff: f32,
}

impl Radisa {
    pub fn new(cfg: RadisaConfig) -> Radisa {
        let rng_root = Xoshiro::new(cfg.seed).substream(0x4AD1, 0, 0);
        let gamma_eff = cfg.gamma;
        Radisa { cfg, w: Vec::new(), rng_root, schedule: None, subblocks: None, gamma_eff }
    }

    /// The step-size constant actually in use (resolved after `init`).
    pub fn gamma_effective(&self) -> f32 {
        self.gamma_eff
    }

    pub fn config(&self) -> &RadisaConfig {
        &self.cfg
    }

    /// Margins pass: m[p] = Σ_q x[p,q] w[·,q] — one superstep over the
    /// grid, then a reduce over q per row partition.  Run once per round —
    /// it is what keeps the local margin identity exact between
    /// delayed-gradient rounds.
    fn margins_pass(
        &self,
        staged: &StagedGrid<'_>,
        cluster: &mut SimCluster,
    ) -> Result<Vec<Vec<f32>>> {
        let part = staged.part;
        let (pp, qq) = (part.grid.p, part.grid.q);
        let w = &self.w;
        let mut plan = StepPlan::with_capacity(pp * qq);
        for p in 0..pp {
            for q in 0..qq {
                let (c0, c1) = part.col_ranges[q];
                let w_q = &w[c0..c1];
                plan.task(move || staged.margins(p, q, w_q));
            }
        }
        let local = cluster.grid_step(plan)?;
        Ok(cluster.reduce_over_q(local, pp, qq))
    }

    /// Gradient pass: μ[·,q] = Σ_p (1/n) x[p,q]ᵀ ψ(m[p]) + λ w — one
    /// superstep, then a reduce over p per feature partition — the
    /// expensive half of the snapshot, skipped on delayed rounds.
    fn grad_pass(
        &self,
        staged: &StagedGrid<'_>,
        cluster: &mut SimCluster,
        mt: &[Vec<f32>],
    ) -> Result<Vec<Vec<f32>>> {
        let part = staged.part;
        let (pp, qq) = (part.grid.p, part.grid.q);
        let loss = self.cfg.loss;
        let mut plan = StepPlan::with_capacity(pp * qq);
        for p in 0..pp {
            let mt_p = &mt[p];
            for q in 0..qq {
                plan.task(move || staged.grad(loss, p, q, mt_p, part.n));
            }
        }
        let local = cluster.grid_step(plan)?;
        let mut mu = cluster.reduce_over_p(local, pp, qq);
        for (q, g) in mu.iter_mut().enumerate() {
            let (c0, c1) = part.col_ranges[q];
            // + λ w̃ (the regularizer's exact gradient at the snapshot)
            for (gv, &wv) in g.iter_mut().zip(&self.w[c0..c1]) {
                *gv += self.cfg.lambda * wv;
            }
        }
        Ok(mu)
    }
}

impl Optimizer for Radisa {
    fn name(&self) -> String {
        if self.cfg.average {
            "radisa-avg".into()
        } else {
            "radisa".into()
        }
    }

    fn loss(&self) -> Loss {
        self.cfg.loss
    }

    fn lambda(&self) -> f32 {
        self.cfg.lambda
    }

    fn init(&mut self, staged: &StagedGrid<'_>, _cluster: &mut SimCluster) -> Result<()> {
        let part = staged.part;
        self.w = vec![0.0; part.m];
        self.schedule = Some(SubBlockSchedule::new(&self.rng_root, part.grid.p));
        self.subblocks = Some(SubBlocks::split(part));
        if self.cfg.gamma <= 0.0 {
            // mean squared row norm, accumulated across the grid
            let mut total = 0.0f64;
            for p in 0..part.grid.p {
                for q in 0..part.grid.q {
                    let b = part.block(p, q);
                    for i in 0..b.rows() {
                        total += b.row_norm_sq(i) as f64;
                    }
                }
            }
            let mean = (total / part.n as f64).max(1e-12) as f32;
            self.gamma_eff = (part.grid.p * part.grid.q) as f32 / mean;
        }
        Ok(())
    }

    fn iterate(
        &mut self,
        t: usize,
        staged: &StagedGrid<'_>,
        cluster: &mut SimCluster,
    ) -> Result<()> {
        let part: &Partitioned = staged.part;
        let (pp, qq) = (part.grid.p, part.grid.q);
        let rounds = self.cfg.grad_refresh.max(1);

        // broadcast the snapshot w̃ to every partition (cost model)
        cluster.broadcast_cost(part.m * 4, pp * qq);

        // steps 2-3: snapshot margins + full gradient (the gradient pass is
        // computed once and anchors all `rounds` exchange+SVRG rounds)
        let mut mt = self.margins_pass(staged, cluster)?;
        let mu = self.grad_pass(staged, cluster, &mt)?;

        for round in 0..rounds {
            if round > 0 {
                // delayed-gradient round: refresh only the margins so the
                // local margin identity stays exact; μ̃ stays stale
                mt = self.margins_pass(staged, cluster)?;
            }
            // a distinct schedule/rng/step-size epoch per round, so k
            // delayed rounds anneal exactly like k vanilla iterations
            let tick = (t - 1) * rounds + round + 1;
            let eta = radisa_eta(self.gamma_eff, tick);

            // steps 4-11: local SVRG on randomly exchanged sub-blocks —
            // one superstep over the grid, tasks ordered (q, p)
            let schedule = self.schedule.as_ref().unwrap();
            let subblocks = self.subblocks.as_ref().unwrap();
            let w_snap = &self.w;
            let mut windows: Vec<(usize, usize)> = Vec::with_capacity(pp * qq);
            let mut plan = StepPlan::with_capacity(pp * qq);
            for q in 0..qq {
                let (c0, c1) = part.col_ranges[q];
                let wt_q = &w_snap[c0..c1];
                let assign = schedule.assignment(q, tick);
                for p in 0..pp {
                    let n_p = part.n_p(p);
                    let l = if self.cfg.batch == 0 { n_p } else { self.cfg.batch };
                    let window = if self.cfg.average {
                        (0, c1 - c0)
                    } else {
                        subblocks.range(q, assign[p])
                    };
                    windows.push(window);
                    let mu_win = &mu[q][window.0..window.1];
                    let mt_p = &mt[p];
                    let mut rng =
                        self.rng_root.substream(p as u64, q as u64, tick as u64);
                    let idx = rng.index_stream(n_p, n_p.min(l).max(1));
                    let (loss, lam) = (self.cfg.loss, self.cfg.lambda);
                    plan.task(move || {
                        staged.svrg_block(
                            loss, p, q, wt_q, wt_q, mu_win, window, mt_p, &idx, l,
                            eta, lam,
                        )
                    });
                }
            }
            if self.cfg.average {
                // RADiSA-avg's combine is an average of full-block partial
                // solutions, so the coordinator "does not wait for
                // stragglers" (paper §IV): under a cluster scenario this
                // superstep's makespan ignores injected straggler delays
                // and failure re-charges.
                plan.mark_tolerant();
            }
            let results = cluster.grid_step(plan)?; // [q*pp + p]

            // step 12: combine in task order — concatenate each partition's
            // window, or average full blocks over p (RADiSA-avg)
            let mut new_w = self.w.clone();
            for q in 0..qq {
                let (c0, c1) = part.col_ranges[q];
                if self.cfg.average {
                    let mut avg_acc = vec![0.0f64; c1 - c0];
                    for p in 0..pp {
                        for (acc, &v) in avg_acc.iter_mut().zip(&results[q * pp + p]) {
                            *acc += v as f64;
                        }
                    }
                    for (k, acc) in avg_acc.iter().enumerate() {
                        new_w[c0 + k] = (*acc / pp as f64) as f32;
                    }
                    // averaging ships full blocks: reduce of P vectors of
                    // m_q f32s (cost only — the average itself is exact
                    // driver-side arithmetic)
                    cluster.reduce_cost(pp.max(2), (c1 - c0) * 4);
                } else {
                    for p in 0..pp {
                        let (lo, hi) = windows[q * pp + p];
                        new_w[c0 + lo..c0 + hi]
                            .copy_from_slice(&results[q * pp + p][lo..hi]);
                    }
                    // concatenation ships one sub-block per partition
                    cluster.broadcast_cost((c1 - c0) * 4 / pp.max(1), pp);
                }
            }
            self.w = new_w;
        }
        Ok(())
    }

    fn w(&self) -> &[f32] {
        &self.w
    }
}
